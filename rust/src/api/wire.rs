//! Versioned newline-JSON *event-frame* wire protocol (v2).
//!
//! One JSON object per line, multiplexed by request id.  Server→client
//! frames carry `"v":2` and an `"event"` discriminator:
//!
//! ```text
//! {"v":2,"event":"queued","id":7,"cid":3}
//! {"v":2,"event":"started","id":7,"ttft_ms":1.2}
//! {"v":2,"event":"token","id":7,"token":123,"index":0}
//! {"v":2,"event":"finished","id":7,"reason":"stop","prompt_len":8,
//!  "generated":24,"ttft_ms":1.2,"decode_ms":30.1,"queued_ms":31.9,
//!  "tokens_per_sec":797.3}
//! {"v":2,"event":"failed","id":7,"error":"..."}
//! {"v":2,"event":"rejected","cid":3,"reason":"queue_full","bound":64}
//! {"v":2,"event":"stats", ...engine counters...}
//! {"v":2,"event":"error","error":"...","id":7}      // id optional
//! {"v":2,"event":"shutdown","ok":true}
//! ```
//!
//! Client→server frames carry a `"cmd"` discriminator:
//!
//! ```text
//! {"v":2,"cmd":"submit","cid":3,"prompt":[1,2,3],"max_new_tokens":16,
//!  "temperature":0.8,"top_k":4,"stop_token":9,
//!  "priority":"batch","deadline_ms":500}
//! {"v":2,"cmd":"chat","cid":3,"prompt":[4,5],"max_new_tokens":16,
//!  "session":12}                                    // session absent = new
//! {"v":2,"cmd":"cancel","id":7}
//! {"v":2,"cmd":"stats"}
//! {"v":2,"cmd":"metrics"}
//! {"v":2,"cmd":"trace"}
//! {"v":2,"cmd":"flush-prefix"}
//! {"v":2,"cmd":"shutdown"}
//! ```
//!
//! `priority` (absent ⇒ `"interactive"`) selects the fair-share admission
//! class; `deadline_ms` (absent ⇒ none) is a server-side deadline from
//! submission — an expired request finishes with reason
//! `"deadline_exceeded"`.  `tier` (`"kv4"`|`"kv8"`, absent ⇒ derived from
//! the priority class at admission) pins the request's KV-cache precision
//! tier.  `chat` is a `submit` whose `prompt` is only the *new user
//! text*: the server prepends the session's stored conversation history
//! and replays it from donated prefix-cache pages; with no `"session"`
//! field a new session is opened and its id comes back on the terminal
//! `finished` frame's `"session"` key.  `flush-prefix` drops every
//! shard's prefix-cache entries and is acked with
//! `{"v":2,"event":"flush-prefix","ok":true}` (ops / test hygiene).
//! `stats` answers flat cluster aggregates
//! (including live `queue_depth` / `active_slots`); `metrics` adds the
//! full per-shard breakdown (`{"v":2,"event":"metrics","per_shard":[..]}`).
//!
//! `cid` is a client-chosen correlation id echoed on the `queued` /
//! `rejected` frame so pipelined submits can be matched to server ids.
//! A line with a `"prompt"` but no `"cmd"` is the legacy v1 one-shot
//! protocol and is still answered with a single completion object.
//!
//! Version notes: frames are append-only — every protocol revision adds
//! keys strictly after the pre-existing ones (`kv4_*`/`kv8_*` stats keys
//! in the tier revision; the `chat`/`flush-prefix` cmds, the session
//! gauges, and the optional `finished.session` key in the session
//! revision; the `trace` cmd plus the `*_p50/p90/p99/p999_ms` latency
//! percentile keys on `stats`/`metrics` frames in the telemetry
//! revision), so a v2 client older than the server parses every frame it
//! knew about unchanged.  `trace` answers
//! `{"v":2,"event":"trace","traceEvents":[..]}` — the drained span ring
//! in Chrome-trace JSON array format (load the `traceEvents` value in
//! `chrome://tracing` or Perfetto).

use anyhow::{bail, Context, Result};

use super::{FinishReason, GenerationEvent, GenerationParams, Priority,
            QualityTier, RequestId, RequestStats, SessionSpec, SubmitError,
            Sampling};
use crate::util::json::{self, n, obj, Value};

/// Wire protocol revision carried in every frame's `v` key.
pub const PROTOCOL_VERSION: u32 = 2;

fn tag(mut pairs: Vec<(&str, Value)>, event: &str) -> Value {
    pairs.insert(0, ("v", n(PROTOCOL_VERSION as f64)));
    pairs.insert(1, ("event", json::s(event)));
    obj(pairs)
}

/// Encode one generation event as a server→client frame.  `cid` is
/// attached to `queued` frames only (submit correlation).
pub fn encode_event(id: RequestId, ev: &GenerationEvent, cid: Option<u64>)
                    -> Value {
    let idv = ("id", n(id as f64));
    match ev {
        GenerationEvent::Queued => {
            let mut pairs = vec![idv];
            if let Some(c) = cid {
                pairs.push(("cid", n(c as f64)));
            }
            tag(pairs, "queued")
        }
        GenerationEvent::Started { ttft_ms } => {
            tag(vec![idv, ("ttft_ms", n(*ttft_ms))], "started")
        }
        GenerationEvent::Token { token, index } => {
            tag(vec![idv, ("token", n(*token as f64)),
                     ("index", n(*index as f64))], "token")
        }
        GenerationEvent::Finished { reason, stats } => {
            let mut pairs = vec![
                idv,
                ("reason", json::s(reason.as_str())),
                ("prompt_len", n(stats.prompt_len as f64)),
                ("generated", n(stats.generated as f64)),
                ("ttft_ms", n(stats.ttft_ms)),
                ("decode_ms", n(stats.decode_ms)),
                ("queued_ms", n(stats.queued_ms)),
                ("tokens_per_sec", n(stats.tokens_per_sec())),
            ];
            // appended after every pre-session key, and only for chat
            // turns — one-shot finished frames stay byte-identical
            if let Some(sid) = stats.session {
                pairs.push(("session", n(sid as f64)));
            }
            tag(pairs, "finished")
        }
        GenerationEvent::Failed { error } => {
            tag(vec![idv, ("error", json::s(error))], "failed")
        }
    }
}

/// Admission failure for submit correlation id `cid` (the request never
/// got an id or a stream).
pub fn encode_rejected(cid: u64, err: &SubmitError) -> Value {
    let mut pairs = vec![("cid", n(cid as f64))];
    match err {
        SubmitError::QueueFull { bound } => {
            pairs.push(("reason", json::s("queue_full")));
            pairs.push(("bound", n(*bound as f64)));
        }
        SubmitError::InvalidParams(m) => {
            pairs.push(("reason", json::s("invalid_params")));
            pairs.push(("error", json::s(m)));
        }
        SubmitError::Transport(m) => {
            pairs.push(("reason", json::s("transport")));
            pairs.push(("error", json::s(m)));
        }
    }
    tag(pairs, "rejected")
}

/// Aggregate counters reply (`{"cmd":"stats"}` answer).
pub fn encode_stats(fields: Vec<(&str, Value)>) -> Value {
    tag(fields, "stats")
}

/// Full per-shard metrics reply (`{"cmd":"metrics"}` answer).
pub fn encode_metrics(fields: Vec<(&str, Value)>) -> Value {
    tag(fields, "metrics")
}

/// Chrome-trace reply (`{"cmd":"trace"}` answer): the drained span
/// ring as a `traceEvents` array (Chrome-trace / Perfetto JSON).
pub fn encode_trace(trace_events: Vec<Value>) -> Value {
    tag(vec![("traceEvents", Value::Arr(trace_events))], "trace")
}

/// Protocol-level error, optionally tied to a request id.
pub fn encode_error(id: Option<RequestId>, error: &str) -> Value {
    let mut pairs = Vec::new();
    if let Some(id) = id {
        pairs.push(("id", n(id as f64)));
    }
    pairs.push(("error", json::s(error)));
    tag(pairs, "error")
}

/// `{"cmd":"shutdown"}` acknowledgement (last frame before close).
pub fn encode_shutdown_ack() -> Value {
    tag(vec![("ok", Value::Bool(true))], "shutdown")
}

/// `{"cmd":"flush-prefix"}` acknowledgement.
pub fn encode_flush_prefix_ack() -> Value {
    tag(vec![("ok", Value::Bool(true))], "flush-prefix")
}

fn submit_pairs<'a>(cmd: &'a str, cid: u64, p: &GenerationParams)
                    -> Vec<(&'a str, Value)> {
    let toks: Vec<Value> = p.prompt.iter().map(|&t| n(t as f64)).collect();
    let mut pairs = vec![
        ("v", n(PROTOCOL_VERSION as f64)),
        ("cmd", json::s(cmd)),
        ("cid", n(cid as f64)),
        ("prompt", Value::Arr(toks)),
        ("max_new_tokens", n(p.max_new_tokens as f64)),
    ];
    if let Sampling::TopK { temperature, k } = p.sampling {
        pairs.push(("temperature", n(temperature as f64)));
        pairs.push(("top_k", n(k as f64)));
    }
    if let Some(st) = p.stop_token {
        pairs.push(("stop_token", n(st as f64)));
    }
    if p.priority != Priority::Interactive {
        pairs.push(("priority", json::s(p.priority.as_str())));
    }
    if let Some(d) = p.deadline_ms {
        pairs.push(("deadline_ms", n(d as f64)));
    }
    // only an explicit tier crosses the wire — an absent field keeps the
    // server-side priority-derived default (mirrors priority/deadline)
    if let Some(t) = p.tier {
        pairs.push(("tier", json::s(t.as_str())));
    }
    pairs
}

/// Encode a submit command.  Sampling maps to `temperature` / `top_k`
/// (absent ⇒ greedy, matching the v1 convention).
pub fn encode_submit(cid: u64, p: &GenerationParams) -> Value {
    obj(submit_pairs("submit", cid, p))
}

/// Encode a chat command: a submit whose `prompt` is only the new user
/// text.  `session: None` opens a new conversation; `Some(id)` resumes
/// one (the server replays the stored history from cache).
pub fn encode_chat(cid: u64, session: Option<u64>, p: &GenerationParams)
                   -> Value {
    let mut pairs = submit_pairs("chat", cid, p);
    if let Some(id) = session {
        pairs.push(("session", n(id as f64)));
    }
    obj(pairs)
}

/// Encode a cancel command for a previously-submitted request id.
pub fn encode_cancel(id: RequestId) -> Value {
    obj(vec![
        ("v", n(PROTOCOL_VERSION as f64)),
        ("cmd", json::s("cancel")),
        ("id", n(id as f64)),
    ])
}

/// Encode a bare command frame (`stats`, `metrics`, `flush-prefix`,
/// `shutdown`).
pub fn encode_cmd(cmd: &str) -> Value {
    obj(vec![("v", n(PROTOCOL_VERSION as f64)), ("cmd", json::s(cmd))])
}

/// Generation parameters from a submit (v2) or legacy (v1) frame.
pub fn decode_params(v: &Value) -> Result<GenerationParams> {
    let prompt: Vec<u16> = v.get("prompt").and_then(|p| p.as_arr())
        .context("missing prompt")?
        .iter()
        .map(|t| t.as_usize().context("bad prompt token").map(|x| x as u16))
        .collect::<Result<_>>()?;
    let max_new = v.get("max_new_tokens").and_then(|x| x.as_usize()).unwrap_or(16);
    let temperature = v.get("temperature").and_then(|x| x.as_f64()).unwrap_or(0.0);
    let top_k = v.get("top_k").and_then(|x| x.as_usize()).unwrap_or(0);
    let sampling = if temperature > 0.0 {
        Sampling::TopK { temperature: temperature as f32, k: top_k }
    } else {
        Sampling::Greedy
    };
    let mut p = GenerationParams::new(prompt).max_new(max_new).sampling(sampling);
    p.stop_token = v.get("stop_token").and_then(|x| x.as_usize()).map(|t| t as u16);
    if let Some(pv) = v.get("priority") {
        let pr = pv.as_str().context("priority must be a string")?;
        p.priority = Priority::parse(pr)
            .with_context(|| format!("unknown priority '{pr}' \
                                      (interactive|batch)"))?;
    }
    if let Some(dv) = v.get("deadline_ms") {
        let d = dv.as_f64().context("deadline_ms must be a number")?;
        // `as usize` would silently saturate -1 to 0 = instant expiry
        if !(d >= 0.0) {
            bail!("deadline_ms must be non-negative, got {d}");
        }
        p.deadline_ms = Some(d as u64);
    }
    if let Some(tv) = v.get("tier") {
        let ts = tv.as_str().context("tier must be a string")?;
        p.tier = Some(QualityTier::parse(ts)
            .with_context(|| format!("unknown tier '{ts}' (kv4|kv8)"))?);
    }
    Ok(p)
}

/// A parsed client→server line.
#[derive(Clone, Debug)]
pub enum ClientFrame {
    Submit { cid: u64, params: GenerationParams },
    Cancel { id: RequestId },
    Stats,
    /// Full per-shard cluster metrics.
    Metrics,
    /// Drain every shard's span ring as Chrome-trace JSON
    /// (`{"cmd":"trace"}`).
    Trace,
    /// Drop every shard's prefix-cache entries (`{"cmd":"flush-prefix"}`).
    FlushPrefix,
    Shutdown,
    /// v1 compatibility: bare `{"prompt": ...}` one-shot generation.
    LegacyGenerate { params: GenerationParams },
}

/// Classify one client→server JSON line (v2 commands plus the v1 bare
/// `{"prompt": ...}` form).
pub fn parse_client_frame(v: &Value) -> Result<ClientFrame> {
    match v.get("cmd").and_then(|c| c.as_str()) {
        Some("submit") => Ok(ClientFrame::Submit {
            cid: v.get("cid").and_then(|c| c.as_usize()).unwrap_or(0) as u64,
            params: decode_params(v)?,
        }),
        // a chat frame IS a submit carrying a session spec — the server
        // needs no chat-specific routing, the engine resolves the rest
        Some("chat") => {
            let mut params = decode_params(v)?;
            params.session = Some(match v.get("session") {
                Some(sv) => SessionSpec::Resume(
                    sv.as_usize().context("session must be a number")? as u64),
                None => SessionSpec::New,
            });
            Ok(ClientFrame::Submit {
                cid: v.get("cid").and_then(|c| c.as_usize()).unwrap_or(0) as u64,
                params,
            })
        }
        Some("cancel") => Ok(ClientFrame::Cancel {
            id: v.get("id").and_then(|i| i.as_usize())
                .context("cancel frame needs an id")? as u64,
        }),
        Some("stats") => Ok(ClientFrame::Stats),
        Some("metrics") => Ok(ClientFrame::Metrics),
        Some("trace") => Ok(ClientFrame::Trace),
        Some("flush-prefix") => Ok(ClientFrame::FlushPrefix),
        Some("shutdown") => Ok(ClientFrame::Shutdown),
        Some(other) => bail!("unknown cmd '{other}'"),
        None => {
            if v.get("prompt").is_some() {
                Ok(ClientFrame::LegacyGenerate { params: decode_params(v)? })
            } else {
                bail!("not a protocol frame (no cmd, no prompt)")
            }
        }
    }
}

/// A parsed server→client line.
#[derive(Clone, Debug)]
pub enum ServerFrame {
    Event { id: RequestId, cid: Option<u64>, event: GenerationEvent },
    Rejected { cid: u64, error: SubmitError },
    Stats(Value),
    /// Per-shard cluster metrics payload.
    Metrics(Value),
    /// Chrome-trace payload (the whole frame, `traceEvents` inside).
    Trace(Value),
    /// `flush-prefix` acknowledgement.
    FlushPrefixAck,
    Error { id: Option<RequestId>, error: String },
    Shutdown,
}

/// Classify one server→client JSON line by its `event` key.
pub fn parse_server_frame(v: &Value) -> Result<ServerFrame> {
    let kind = v.get("event").and_then(|e| e.as_str())
        .context("frame missing event")?;
    let id = || -> Result<RequestId> {
        Ok(v.get("id").and_then(|i| i.as_usize())
            .context("frame missing id")? as u64)
    };
    let f = |k: &str| v.get(k).and_then(|x| x.as_f64()).unwrap_or(0.0);
    let us = |k: &str| v.get(k).and_then(|x| x.as_usize()).unwrap_or(0);
    Ok(match kind {
        "queued" => ServerFrame::Event {
            id: id()?,
            cid: v.get("cid").and_then(|c| c.as_usize()).map(|c| c as u64),
            event: GenerationEvent::Queued,
        },
        "started" => ServerFrame::Event {
            id: id()?, cid: None,
            event: GenerationEvent::Started { ttft_ms: f("ttft_ms") },
        },
        "token" => ServerFrame::Event {
            id: id()?, cid: None,
            event: GenerationEvent::Token {
                token: us("token") as u16,
                index: us("index"),
            },
        },
        "finished" => {
            let rs = v.get("reason").and_then(|r| r.as_str())
                .context("finished frame missing reason")?;
            let reason = FinishReason::parse(rs)
                .with_context(|| format!("unknown finish reason '{rs}'"))?;
            ServerFrame::Event {
                id: id()?, cid: None,
                event: GenerationEvent::Finished {
                    reason,
                    stats: RequestStats {
                        prompt_len: us("prompt_len"),
                        generated: us("generated"),
                        ttft_ms: f("ttft_ms"),
                        decode_ms: f("decode_ms"),
                        queued_ms: f("queued_ms"),
                        session: v.get("session").and_then(|x| x.as_usize())
                            .map(|s| s as u64),
                    },
                },
            }
        }
        "failed" => ServerFrame::Event {
            id: id()?, cid: None,
            event: GenerationEvent::Failed {
                error: v.get("error").and_then(|e| e.as_str())
                    .unwrap_or("unknown").to_string(),
            },
        },
        "rejected" => {
            let cid = v.get("cid").and_then(|c| c.as_usize()).unwrap_or(0) as u64;
            let msg = v.get("error").and_then(|e| e.as_str())
                .unwrap_or("").to_string();
            let error = match v.get("reason").and_then(|r| r.as_str()) {
                Some("queue_full") => SubmitError::QueueFull { bound: us("bound") },
                Some("invalid_params") => SubmitError::InvalidParams(msg),
                _ => SubmitError::Transport(msg),
            };
            ServerFrame::Rejected { cid, error }
        }
        "stats" => ServerFrame::Stats(v.clone()),
        "metrics" => ServerFrame::Metrics(v.clone()),
        "trace" => ServerFrame::Trace(v.clone()),
        "flush-prefix" => ServerFrame::FlushPrefixAck,
        "error" => ServerFrame::Error {
            id: v.get("id").and_then(|i| i.as_usize()).map(|i| i as u64),
            error: v.get("error").and_then(|e| e.as_str())
                .unwrap_or("unknown").to_string(),
        },
        "shutdown" => ServerFrame::Shutdown,
        other => bail!("unknown event kind '{other}'"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reparse(v: &Value) -> Value {
        json::parse(&json::write(v)).unwrap()
    }

    #[test]
    fn event_frames_roundtrip() {
        let stats = RequestStats {
            prompt_len: 8, generated: 24,
            ttft_ms: 1.5, decode_ms: 30.0, queued_ms: 31.5,
            session: None,
        };
        let evs = [
            GenerationEvent::Queued,
            GenerationEvent::Started { ttft_ms: 1.5 },
            GenerationEvent::Token { token: 123, index: 4 },
            GenerationEvent::Finished { reason: FinishReason::Stop, stats },
            GenerationEvent::Failed { error: "boom".into() },
        ];
        for ev in &evs {
            let frame = reparse(&encode_event(7, ev, None));
            match parse_server_frame(&frame).unwrap() {
                ServerFrame::Event { id, event, .. } => {
                    assert_eq!(id, 7);
                    assert_eq!(&event, ev);
                }
                other => panic!("wrong frame {other:?}"),
            }
        }
    }

    #[test]
    fn queued_carries_cid() {
        let frame = reparse(&encode_event(9, &GenerationEvent::Queued, Some(3)));
        match parse_server_frame(&frame).unwrap() {
            ServerFrame::Event { id, cid, event } => {
                assert_eq!((id, cid), (9, Some(3)));
                assert_eq!(event, GenerationEvent::Queued);
            }
            other => panic!("wrong frame {other:?}"),
        }
    }

    #[test]
    fn rejected_roundtrip() {
        let frame = reparse(&encode_rejected(
            5, &SubmitError::QueueFull { bound: 64 }));
        match parse_server_frame(&frame).unwrap() {
            ServerFrame::Rejected { cid, error } => {
                assert_eq!(cid, 5);
                assert_eq!(error, SubmitError::QueueFull { bound: 64 });
            }
            other => panic!("wrong frame {other:?}"),
        }
        let frame = reparse(&encode_rejected(
            6, &SubmitError::InvalidParams("empty prompt".into())));
        match parse_server_frame(&frame).unwrap() {
            ServerFrame::Rejected { error, .. } => {
                assert_eq!(error,
                           SubmitError::InvalidParams("empty prompt".into()));
            }
            other => panic!("wrong frame {other:?}"),
        }
    }

    #[test]
    fn submit_command_roundtrip() {
        let p = GenerationParams::new(vec![1, 2, 3])
            .max_new(16)
            .sampling(Sampling::TopK { temperature: 0.8, k: 4 })
            .stop_at(9);
        let frame = reparse(&encode_submit(3, &p));
        match parse_client_frame(&frame).unwrap() {
            ClientFrame::Submit { cid, params } => {
                assert_eq!(cid, 3);
                assert_eq!(params.prompt, vec![1, 2, 3]);
                assert_eq!(params.max_new_tokens, 16);
                assert_eq!(params.stop_token, Some(9));
                match params.sampling {
                    Sampling::TopK { temperature, k } => {
                        assert!((temperature - 0.8).abs() < 1e-6);
                        assert_eq!(k, 4);
                    }
                    s => panic!("wrong sampling {s:?}"),
                }
            }
            other => panic!("wrong frame {other:?}"),
        }
    }

    #[test]
    fn admin_and_cancel_frames() {
        match parse_client_frame(&reparse(&encode_cancel(11))).unwrap() {
            ClientFrame::Cancel { id } => assert_eq!(id, 11),
            other => panic!("wrong frame {other:?}"),
        }
        assert!(matches!(parse_client_frame(&reparse(&encode_cmd("stats"))),
                         Ok(ClientFrame::Stats)));
        assert!(matches!(parse_client_frame(&reparse(&encode_cmd("metrics"))),
                         Ok(ClientFrame::Metrics)));
        assert!(matches!(parse_client_frame(&reparse(&encode_cmd("shutdown"))),
                         Ok(ClientFrame::Shutdown)));
        assert!(matches!(parse_server_frame(&reparse(&encode_shutdown_ack())),
                         Ok(ServerFrame::Shutdown)));
        let mf = reparse(&encode_metrics(vec![("shards", n(2.0))]));
        match parse_server_frame(&mf).unwrap() {
            ServerFrame::Metrics(v) => {
                assert_eq!(v.get("shards").unwrap().as_usize(), Some(2));
            }
            other => panic!("wrong frame {other:?}"),
        }
    }

    #[test]
    fn trace_frames_roundtrip() {
        assert!(matches!(parse_client_frame(&reparse(&encode_cmd("trace"))),
                         Ok(ClientFrame::Trace)));
        let span = crate::telemetry::Span::new("prefill", 7, 1.5, 2.0)
            .arg("graft_tokens", 16.0);
        let events = crate::telemetry::chrome_trace_events(&[span], 0);
        let frame = reparse(&encode_trace(events));
        match parse_server_frame(&frame).unwrap() {
            ServerFrame::Trace(v) => {
                let evs = v.get("traceEvents").and_then(|e| e.as_arr())
                    .expect("traceEvents array");
                assert_eq!(evs.len(), 1);
                assert_eq!(evs[0].get("name").unwrap().as_str(),
                           Some("prefill"));
                // Chrome-trace timestamps are microseconds
                assert_eq!(evs[0].get("ts").unwrap().as_f64(), Some(1500.0));
                assert_eq!(evs[0].get("ph").unwrap().as_str(), Some("X"));
            }
            other => panic!("wrong frame {other:?}"),
        }
    }

    #[test]
    fn priority_and_deadline_roundtrip() {
        let p = GenerationParams::new(vec![1, 2])
            .priority(Priority::Batch)
            .deadline(750);
        match parse_client_frame(&reparse(&encode_submit(1, &p))).unwrap() {
            ClientFrame::Submit { params, .. } => {
                assert_eq!(params.priority, Priority::Batch);
                assert_eq!(params.deadline_ms, Some(750));
            }
            other => panic!("wrong frame {other:?}"),
        }
        // absent fields fall back to interactive / no deadline — the v1
        // and pre-scheduler v2 submit shapes stay valid
        let bare = json::parse(r#"{"cmd":"submit","prompt":[3]}"#).unwrap();
        match parse_client_frame(&bare).unwrap() {
            ClientFrame::Submit { params, .. } => {
                assert_eq!(params.priority, Priority::Interactive);
                assert_eq!(params.deadline_ms, None);
            }
            other => panic!("wrong frame {other:?}"),
        }
        // unknown class is a parse error, not a silent default
        let bad = json::parse(
            r#"{"cmd":"submit","prompt":[3],"priority":"urgent"}"#).unwrap();
        assert!(parse_client_frame(&bad).is_err());
        // so are wrong-typed fields — a stringified deadline must not
        // silently become "no deadline"
        let bad = json::parse(
            r#"{"cmd":"submit","prompt":[3],"priority":1}"#).unwrap();
        assert!(parse_client_frame(&bad).is_err());
        let bad = json::parse(
            r#"{"cmd":"submit","prompt":[3],"deadline_ms":"500"}"#).unwrap();
        assert!(parse_client_frame(&bad).is_err());
        // a negative deadline must not saturate to 0 (= instant expiry)
        let bad = json::parse(
            r#"{"cmd":"submit","prompt":[3],"deadline_ms":-1}"#).unwrap();
        assert!(parse_client_frame(&bad).is_err());
        // the deadline-exceeded terminal crosses the wire intact
        let ev = GenerationEvent::Finished {
            reason: FinishReason::DeadlineExceeded,
            stats: RequestStats::default(),
        };
        match parse_server_frame(&reparse(&encode_event(4, &ev, None))).unwrap() {
            ServerFrame::Event { event, .. } => assert_eq!(event, ev),
            other => panic!("wrong frame {other:?}"),
        }
    }

    #[test]
    fn tier_field_roundtrip_and_typed_errors() {
        // explicit tier crosses the wire
        let p = GenerationParams::new(vec![1]).tier(QualityTier::Kv8);
        match parse_client_frame(&reparse(&encode_submit(1, &p))).unwrap() {
            ClientFrame::Submit { params, .. } => {
                assert_eq!(params.tier, Some(QualityTier::Kv8));
            }
            other => panic!("wrong frame {other:?}"),
        }
        // an unset tier is NOT encoded and decodes back as unset, so the
        // server resolves it from priority at admission — pre-tier v2
        // clients keep their exact behavior
        let p = GenerationParams::new(vec![1]).priority(Priority::Batch);
        let frame = reparse(&encode_submit(2, &p));
        assert!(frame.get("tier").is_none(), "unset tier must not encode");
        match parse_client_frame(&frame).unwrap() {
            ClientFrame::Submit { params, .. } => {
                assert_eq!(params.tier, None);
                assert_eq!(params.resolved_tier(), QualityTier::Kv8);
            }
            other => panic!("wrong frame {other:?}"),
        }
        // unknown value and wrong type are typed parse errors
        let bad = json::parse(
            r#"{"cmd":"submit","prompt":[3],"tier":"kv16"}"#).unwrap();
        let err = parse_client_frame(&bad).unwrap_err().to_string();
        assert!(err.contains("kv4|kv8"), "{err}");
        let bad = json::parse(
            r#"{"cmd":"submit","prompt":[3],"tier":4}"#).unwrap();
        assert!(parse_client_frame(&bad).is_err());
    }

    #[test]
    fn chat_and_flush_prefix_frames_roundtrip() {
        // new conversation: no session field on the wire
        let p = GenerationParams::new(vec![4, 5]).max_new(8);
        let frame = reparse(&encode_chat(2, None, &p));
        assert!(frame.get("session").is_none());
        match parse_client_frame(&frame).unwrap() {
            ClientFrame::Submit { cid, params } => {
                assert_eq!(cid, 2);
                assert_eq!(params.session, Some(SessionSpec::New));
                assert_eq!(params.prompt, vec![4, 5]);
            }
            other => panic!("wrong frame {other:?}"),
        }
        // resume: the session id rides a dedicated key
        let frame = reparse(&encode_chat(3, Some(12), &p));
        match parse_client_frame(&frame).unwrap() {
            ClientFrame::Submit { params, .. } => {
                assert_eq!(params.session, Some(SessionSpec::Resume(12)));
            }
            other => panic!("wrong frame {other:?}"),
        }
        // a wrong-typed session is a parse error, not a silent new session
        let bad = json::parse(
            r#"{"cmd":"chat","prompt":[3],"session":"twelve"}"#).unwrap();
        assert!(parse_client_frame(&bad).is_err());
        // plain submits never carry a session spec
        let frame = reparse(&encode_submit(4, &p));
        match parse_client_frame(&frame).unwrap() {
            ClientFrame::Submit { params, .. } => {
                assert_eq!(params.session, None);
            }
            other => panic!("wrong frame {other:?}"),
        }
        // flush-prefix cmd + ack
        assert!(matches!(
            parse_client_frame(&reparse(&encode_cmd("flush-prefix"))),
            Ok(ClientFrame::FlushPrefix)));
        assert!(matches!(
            parse_server_frame(&reparse(&encode_flush_prefix_ack())),
            Ok(ServerFrame::FlushPrefixAck)));
    }

    #[test]
    fn finished_session_key_appends_after_existing_keys() {
        // a chat turn's terminal frame carries the session id, appended
        // strictly after every pre-session key; one-shot frames omit it
        let stats = RequestStats {
            prompt_len: 4, generated: 2,
            ttft_ms: 1.0, decode_ms: 2.0, queued_ms: 3.0,
            session: Some(12),
        };
        let ev = GenerationEvent::Finished {
            reason: FinishReason::Stop, stats: stats.clone(),
        };
        let line = json::write(&encode_event(7, &ev, None));
        // NB: util::json serializes objects in BTreeMap (alphabetical)
        // order, so byte position says nothing about append order.  The
        // append-after contract lives in the SOURCE pair list, enforced
        // by quarot-lint against tests/golden/wire_keys.txt; here we
        // check the key rides the frame alongside every pre-session key.
        assert!(line.contains("tokens_per_sec"), "pre-session key: {line}");
        assert!(line.contains("\"session\""), "session key: {line}");
        match parse_server_frame(&json::parse(&line).unwrap()).unwrap() {
            ServerFrame::Event { event: GenerationEvent::Finished {
                stats: got, .. }, .. } => assert_eq!(got.session, Some(12)),
            other => panic!("wrong frame {other:?}"),
        }
        // None → key absent → decodes back as None
        let ev = GenerationEvent::Finished {
            reason: FinishReason::Stop,
            stats: RequestStats { session: None, ..stats },
        };
        let line = json::write(&encode_event(7, &ev, None));
        assert!(!line.contains("session"), "{line}");
    }

    #[test]
    fn legacy_v1_line_is_recognised() {
        let v = json::parse(r#"{"prompt":[5,6],"max_new_tokens":4}"#).unwrap();
        match parse_client_frame(&v).unwrap() {
            ClientFrame::LegacyGenerate { params } => {
                assert_eq!(params.prompt, vec![5, 6]);
                assert_eq!(params.max_new_tokens, 4);
                assert_eq!(params.sampling, Sampling::Greedy);
            }
            other => panic!("wrong frame {other:?}"),
        }
        assert!(parse_client_frame(&json::parse(r#"{"x":1}"#).unwrap()).is_err());
    }
}
