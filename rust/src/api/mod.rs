//! The unified inference API — the single way to talk to the system,
//! in-process or over the wire.
//!
//! Everything a caller does goes through the same small vocabulary:
//!
//! * [`GenerationParams`] — typed request parameters (prompt, budget,
//!   sampling, stop token) replacing ad-hoc `Request` construction.
//! * [`InferenceService`] — `submit -> RequestHandle`, implemented by
//!   [`LocalSession`] (in-process, wraps the generation engine) and
//!   [`Client`] (TCP, speaks the v2 event-frame protocol).
//! * [`GenerationEvent`] — the per-request event stream: `Queued`,
//!   `Started{ttft_ms}`, `Token{token, index}`, `Finished{reason}`,
//!   `Failed{error}`.  Every submitted request terminates in **exactly
//!   one** `Finished` or `Failed` event.
//! * [`RequestHandle`] — pull events with [`RequestHandle::next_event`],
//!   drain to a terminal with [`RequestHandle::wait`], or abort with
//!   [`RequestHandle::cancel`] — cancellation frees the slot's KV pages
//!   mid-flight.
//! * [`SubmitError`] — typed admission control: the engine queue is
//!   bounded and rejects with [`SubmitError::QueueFull`] instead of
//!   growing without bound (the system's backpressure mechanism).
//!
//! The legacy `GenerationEngine::run_to_completion` survives as a thin
//! compatibility shim that folds this event stream back into
//! `Completion` records, so existing benches stay deterministic.

use std::cell::{Cell, RefCell};
use std::fmt;
use std::rc::Rc;

use anyhow::{bail, Result};

pub mod local;
pub mod remote;
pub mod wire;

pub use local::{LocalSession, SessionConfig};
pub use remote::Client;

pub use crate::coordinator::sampler::Sampling;
pub use crate::session::SessionSpec;

/// Engine-assigned request identifier (also the wire multiplexing key).
pub type RequestId = u64;

/// Scheduling class of a request.  The admission queue is fair-share
/// across classes (weighted deficit round-robin, see
/// `coordinator::batcher::FairQueue`): `Interactive` traffic is admitted
/// ahead of a `Batch` backlog without ever starving it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Priority {
    /// latency-sensitive traffic (default); admission weight 4
    #[default]
    Interactive,
    /// throughput traffic (offline eval, bulk scoring); admission weight 1
    Batch,
}

impl Priority {
    /// Number of priority classes (sizes per-class metric arrays).
    pub const COUNT: usize = 2;

    /// Stable class index (also the fair-queue class slot).
    pub fn index(self) -> usize {
        match self {
            Priority::Interactive => 0,
            Priority::Batch => 1,
        }
    }

    /// Admission weight in the weighted-deficit scheduler.
    pub const fn weight(self) -> i64 {
        match self {
            Priority::Interactive => 4,
            Priority::Batch => 1,
        }
    }

    /// Wire name of the class (inverse of [`Self::parse`]).
    pub fn as_str(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }

    /// Parse a wire name back to the class; `None` for unknown strings.
    pub fn parse(s: &str) -> Option<Priority> {
        Some(match s {
            "interactive" => Priority::Interactive,
            "batch" => Priority::Batch,
            _ => return None,
        })
    }
}

impl fmt::Display for Priority {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Per-request KV-cache precision tier — the quality/cost knob QuaRot's
/// near-lossless-at-4-bit result makes safe to expose per request.
///
/// `Kv4` stores the sequence's K/V at 4 bits (the paper's fast serving
/// point), `Kv8` at 8 bits (lossless-grade RTN).  The tier only selects
/// the *cache* width of the sequence; weights and activations stay on the
/// engine's compiled `QuantSpec`, and the fp16-baseline engine ignores
/// tiers entirely (its K/V never hit the paged cache).  Left unset, the
/// tier defaults from [`Priority`]: latency-sensitive `Interactive`
/// traffic takes the fast `Kv4` path, offline `Batch` work gets `Kv8`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum QualityTier {
    /// 4-bit KV cache — near-lossless, fastest, smallest (default for
    /// `Interactive`)
    #[default]
    Kv4,
    /// 8-bit KV cache — lossless-grade (default for `Batch`)
    Kv8,
}

impl QualityTier {
    /// Number of tiers (sizes per-tier metric arrays).
    pub const COUNT: usize = 2;

    /// Stable tier index (metrics slots).
    pub fn index(self) -> usize {
        match self {
            QualityTier::Kv4 => 0,
            QualityTier::Kv8 => 1,
        }
    }

    /// KV-cache width this tier pins for the sequence.
    pub fn kv_bits(self) -> u32 {
        match self {
            QualityTier::Kv4 => 4,
            QualityTier::Kv8 => 8,
        }
    }

    /// Default tier of a priority class when the request leaves the
    /// tier unset.
    pub fn from_priority(p: Priority) -> QualityTier {
        match p {
            Priority::Interactive => QualityTier::Kv4,
            Priority::Batch => QualityTier::Kv8,
        }
    }

    /// Wire name of the tier (inverse of [`Self::parse`]).
    pub fn as_str(self) -> &'static str {
        match self {
            QualityTier::Kv4 => "kv4",
            QualityTier::Kv8 => "kv8",
        }
    }

    /// Parse a wire name back to the tier; `None` for unknown strings.
    pub fn parse(s: &str) -> Option<QualityTier> {
        Some(match s {
            "kv4" => QualityTier::Kv4,
            "kv8" => QualityTier::Kv8,
            _ => return None,
        })
    }
}

impl fmt::Display for QualityTier {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Typed generation request parameters.
///
/// Build with [`GenerationParams::new`] and the chainable setters:
///
/// ```ignore
/// let p = GenerationParams::new(vec![1, 2, 3]).max_new(32).stop_at(0);
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct GenerationParams {
    pub prompt: Vec<u16>,
    pub max_new_tokens: usize,
    pub sampling: Sampling,
    /// stop generation at this token (e.g. a synthetic EOS); None = run
    /// to `max_new_tokens`.
    pub stop_token: Option<u16>,
    /// scheduling class for fair-share admission.
    pub priority: Priority,
    /// server-side deadline in milliseconds from submission.  An expired
    /// request is retired — queued or mid-stream — with
    /// [`FinishReason::DeadlineExceeded`], its KV pages returning to the
    /// pool immediately (like cancellation).
    pub deadline_ms: Option<u64>,
    /// KV-cache precision tier; `None` defaults from the priority class
    /// at admission ([`QualityTier::from_priority`]).
    pub tier: Option<QualityTier>,
    /// Multi-turn chat: `Some(New)` starts a conversation,
    /// `Some(Resume(id))` makes the server prepend the session's stored
    /// history to `prompt` and replay it from donated prefix-cache pages
    /// — `prompt` is just the *new user text*.  `None` (the default) is
    /// a plain one-shot request.
    pub session: Option<SessionSpec>,
}

impl GenerationParams {
    /// Request with defaults: 32 new tokens, greedy sampling, no stop
    /// token, `Interactive` priority, no deadline, tier from priority.
    pub fn new(prompt: Vec<u16>) -> GenerationParams {
        GenerationParams {
            prompt,
            max_new_tokens: 32,
            sampling: Sampling::Greedy,
            stop_token: None,
            priority: Priority::Interactive,
            deadline_ms: None,
            tier: None,
            session: None,
        }
    }

    /// Builder: cap the number of generated tokens.
    pub fn max_new(mut self, n: usize) -> GenerationParams {
        self.max_new_tokens = n;
        self
    }

    /// Builder: select the sampling strategy.
    pub fn sampling(mut self, s: Sampling) -> GenerationParams {
        self.sampling = s;
        self
    }

    /// Builder: stop the stream when this token is sampled.
    pub fn stop_at(mut self, token: u16) -> GenerationParams {
        self.stop_token = Some(token);
        self
    }

    /// Builder: set the admission class (scheduling weight).
    pub fn priority(mut self, p: Priority) -> GenerationParams {
        self.priority = p;
        self
    }

    /// Builder: server-side deadline in ms from submission; a lapsed
    /// request finishes with `DeadlineExceeded`.
    pub fn deadline(mut self, ms: u64) -> GenerationParams {
        self.deadline_ms = Some(ms);
        self
    }

    /// Builder: pin the KV-cache precision tier explicitly (otherwise
    /// it defaults from the priority class).
    pub fn tier(mut self, t: QualityTier) -> GenerationParams {
        self.tier = Some(t);
        self
    }

    /// Start a new conversation (the server assigns the session id,
    /// delivered in the terminal event's [`RequestStats::session`]).
    pub fn new_session(mut self) -> GenerationParams {
        self.session = Some(SessionSpec::New);
        self
    }

    /// Continue conversation `id`: the server prepends the stored
    /// history and replays it from cache, so `prompt` is only the new
    /// user text.
    pub fn resume_session(mut self, id: u64) -> GenerationParams {
        self.session = Some(SessionSpec::Resume(id));
        self
    }

    /// The tier this request runs at: the explicit setting, else the
    /// priority class's default.
    pub fn resolved_tier(&self) -> QualityTier {
        self.tier.unwrap_or_else(|| QualityTier::from_priority(self.priority))
    }

    /// Model-independent validation (the engine additionally checks the
    /// prompt against its `max_seq`).
    pub fn validate(&self) -> Result<(), SubmitError> {
        if self.prompt.is_empty() {
            return Err(SubmitError::InvalidParams("empty prompt".into()));
        }
        if self.max_new_tokens == 0 {
            return Err(SubmitError::InvalidParams(
                "max_new_tokens must be >= 1".into()));
        }
        if let Sampling::TopK { temperature, .. } = self.sampling {
            if !temperature.is_finite() || temperature <= 0.0 {
                return Err(SubmitError::InvalidParams(
                    "temperature must be > 0 for top-k sampling".into()));
            }
        }
        Ok(())
    }

    pub(crate) fn into_request(self) -> crate::coordinator::batcher::Request {
        let tier = self.resolved_tier();
        crate::coordinator::batcher::Request {
            id: 0,
            prompt: self.prompt,
            max_new_tokens: self.max_new_tokens,
            sampling: self.sampling,
            stop_token: self.stop_token,
            priority: self.priority,
            deadline_ms: self.deadline_ms,
            tier,
            session: self.session,
        }
    }
}

/// Why a request stopped producing tokens.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// the sampled token matched `stop_token`
    Stop,
    /// the `max_new_tokens` budget is spent
    MaxTokens,
    /// the slot's sequence cache reached its capacity
    CacheFull,
    /// the caller cancelled the request mid-flight
    Cancelled,
    /// the request's server-side deadline lapsed (queued or mid-stream);
    /// its KV pages were freed like a cancellation
    DeadlineExceeded,
}

impl FinishReason {
    /// Wire name of the reason (inverse of [`Self::parse`]).
    pub fn as_str(self) -> &'static str {
        match self {
            FinishReason::Stop => "stop",
            FinishReason::MaxTokens => "max_tokens",
            FinishReason::CacheFull => "cache_full",
            FinishReason::Cancelled => "cancelled",
            FinishReason::DeadlineExceeded => "deadline_exceeded",
        }
    }

    /// Parse a wire name back to the reason; `None` for unknown strings.
    pub fn parse(s: &str) -> Option<FinishReason> {
        Some(match s {
            "stop" => FinishReason::Stop,
            "max_tokens" => FinishReason::MaxTokens,
            "cache_full" => FinishReason::CacheFull,
            "cancelled" => FinishReason::Cancelled,
            "deadline_exceeded" => FinishReason::DeadlineExceeded,
            _ => return None,
        })
    }
}

impl fmt::Display for FinishReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Per-request latency/shape metrics, delivered with the terminal
/// `Finished` event (and folded into legacy `Completion` records).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RequestStats {
    pub prompt_len: usize,
    /// tokens generated (== number of `Token` events emitted)
    pub generated: usize,
    pub ttft_ms: f64,
    pub decode_ms: f64,
    pub queued_ms: f64,
    /// the session this turn belongs to (chat requests only) — a `New`
    /// submit learns its server-assigned id here, and the cluster router
    /// learns session → shard ownership from the same field
    pub session: Option<u64>,
}

impl RequestStats {
    /// 0.0 when no decode time was spent (e.g. a request that finished
    /// at admission) — not an absurd divide-by-epsilon figure.
    pub fn tokens_per_sec(&self) -> f64 {
        if self.decode_ms <= 0.0 {
            return 0.0;
        }
        self.generated as f64 / (self.decode_ms / 1e3)
    }
}

/// One step of a request's lifecycle.  Streams are strictly ordered:
/// `Queued` → `Started` → `Token`* → exactly one `Finished` / `Failed`
/// (a request may fail straight from `Queued` if prefill errors).
#[derive(Clone, Debug, PartialEq)]
pub enum GenerationEvent {
    Queued,
    Started { ttft_ms: f64 },
    Token { token: u16, index: usize },
    Finished { reason: FinishReason, stats: RequestStats },
    Failed { error: String },
}

impl GenerationEvent {
    /// `true` for `Finished`/`Failed` — no further event can follow.
    pub fn is_terminal(&self) -> bool {
        matches!(self,
                 GenerationEvent::Finished { .. } | GenerationEvent::Failed { .. })
    }
}

/// Typed admission failure — returned by `submit`, never by the stream.
#[derive(Clone, Debug, PartialEq)]
pub enum SubmitError {
    /// The bounded admission queue is at capacity; retry after in-flight
    /// requests drain (this is the API's backpressure signal).
    QueueFull { bound: usize },
    InvalidParams(String),
    /// The transport or engine is gone (connection closed, engine died).
    Transport(String),
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::QueueFull { bound } => {
                write!(f, "admission queue full (bound {bound})")
            }
            SubmitError::InvalidParams(m) => write!(f, "invalid params: {m}"),
            SubmitError::Transport(m) => write!(f, "transport error: {m}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// A service you can submit generation requests to — implemented by
/// [`LocalSession`] (in-process) and [`Client`] (TCP event frames).
pub trait InferenceService {
    fn submit(&mut self, params: GenerationParams)
              -> Result<RequestHandle, SubmitError>;
    /// Cancel by id.  `Ok(true)` means the cancel was *accepted*: for a
    /// local session, the request was live; for a remote client, the
    /// cancel frame was sent (best-effort — the authoritative answer is
    /// whether the stream's terminal event says `Cancelled`).  Prefer
    /// [`RequestHandle::cancel`].
    fn cancel(&mut self, id: RequestId) -> Result<bool>;
}

/// Where a handle pulls its events from (local engine pump or socket
/// demultiplexer).  Single-threaded by design: the PJRT executables are
/// not `Send`, so local sessions are driven by the consuming thread.
pub(crate) trait EventSource {
    /// Block until the next event for `id` is available; `Ok(None)` once
    /// no further event can ever arrive for it.
    fn next_event_for(&mut self, id: RequestId)
                      -> Result<Option<GenerationEvent>>;
    fn cancel_request(&mut self, id: RequestId) -> Result<bool>;
    /// The handle for `id` is gone with the stream undrained: cancel the
    /// request and discard its buffered/future events so they cannot
    /// accumulate with nobody left to read them.
    fn release_request(&mut self, id: RequestId);
}

/// Handle to one in-flight request: pull events, wait, or cancel.
pub struct RequestHandle {
    id: RequestId,
    src: Rc<RefCell<dyn EventSource>>,
    done: Cell<bool>,
}

impl RequestHandle {
    pub(crate) fn new(id: RequestId, src: Rc<RefCell<dyn EventSource>>)
                      -> RequestHandle {
        RequestHandle { id, src, done: Cell::new(false) }
    }

    /// The request id this handle streams (for cancel-by-id and logs).
    pub fn id(&self) -> RequestId {
        self.id
    }

    /// Next event for this request, driving the underlying session as
    /// needed.  `Ok(None)` after the terminal event has been delivered.
    pub fn next_event(&self) -> Result<Option<GenerationEvent>> {
        if self.done.get() {
            return Ok(None);
        }
        let ev = self.src.borrow_mut().next_event_for(self.id)?;
        match &ev {
            Some(e) if e.is_terminal() => self.done.set(true),
            None => self.done.set(true),
            _ => {}
        }
        Ok(ev)
    }

    /// Cancel the request.  The confirmation is the stream's
    /// `Finished { reason: Cancelled }` event; cancelling an
    /// already-finished request is a no-op (`Ok(false)` locally; remote
    /// cancels resolve best-effort on the server).
    pub fn cancel(&self) -> Result<bool> {
        if self.done.get() {
            return Ok(false);
        }
        self.src.borrow_mut().cancel_request(self.id)
    }

    /// Drain the stream to its terminal event, collecting tokens.
    /// `Failed` becomes an `Err`.
    pub fn wait(&self) -> Result<GenerationOutcome> {
        let mut tokens = Vec::new();
        let mut ttft_ms = 0.0;
        while let Some(ev) = self.next_event()? {
            match ev {
                GenerationEvent::Started { ttft_ms: t } => ttft_ms = t,
                GenerationEvent::Token { token, .. } => tokens.push(token),
                GenerationEvent::Finished { reason, mut stats } => {
                    if stats.ttft_ms == 0.0 {
                        stats.ttft_ms = ttft_ms;
                    }
                    return Ok(GenerationOutcome {
                        id: self.id, tokens, reason, stats,
                    });
                }
                GenerationEvent::Failed { error } => {
                    bail!("request {} failed: {error}", self.id);
                }
                GenerationEvent::Queued => {}
            }
        }
        bail!("request {} stream ended without a terminal event", self.id)
    }
}

impl Drop for RequestHandle {
    /// An abandoned handle must not leave the engine generating tokens
    /// nobody will read: cancel the request and tell the source to drop
    /// its events.  `try_borrow_mut` keeps this a no-op in the pathological
    /// case of a drop while the source is borrowed.
    fn drop(&mut self) {
        if !self.done.get() {
            if let Ok(mut src) = self.src.try_borrow_mut() {
                src.release_request(self.id);
            }
        }
    }
}

/// Everything a drained request produced.
#[derive(Clone, Debug)]
pub struct GenerationOutcome {
    pub id: RequestId,
    pub tokens: Vec<u16>,
    pub reason: FinishReason,
    pub stats: RequestStats,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_builder_and_validation() {
        let p = GenerationParams::new(vec![1, 2, 3]).max_new(8).stop_at(7)
            .priority(Priority::Batch).deadline(250);
        assert_eq!(p.max_new_tokens, 8);
        assert_eq!(p.stop_token, Some(7));
        assert_eq!(p.priority, Priority::Batch);
        assert_eq!(p.deadline_ms, Some(250));
        assert!(p.validate().is_ok());
        // defaults: interactive, no deadline
        let d = GenerationParams::new(vec![1]);
        assert_eq!(d.priority, Priority::Interactive);
        assert_eq!(d.deadline_ms, None);

        assert!(matches!(GenerationParams::new(vec![]).validate(),
                         Err(SubmitError::InvalidParams(_))));
        assert!(matches!(GenerationParams::new(vec![1]).max_new(0).validate(),
                         Err(SubmitError::InvalidParams(_))));
        let bad_temp = GenerationParams::new(vec![1])
            .sampling(Sampling::TopK { temperature: 0.0, k: 4 });
        assert!(bad_temp.validate().is_err());
    }

    #[test]
    fn finish_reason_roundtrip() {
        for r in [FinishReason::Stop, FinishReason::MaxTokens,
                  FinishReason::CacheFull, FinishReason::Cancelled,
                  FinishReason::DeadlineExceeded] {
            assert_eq!(FinishReason::parse(r.as_str()), Some(r));
        }
        assert_eq!(FinishReason::parse("nope"), None);
    }

    #[test]
    fn priority_roundtrip_and_weights() {
        for p in [Priority::Interactive, Priority::Batch] {
            assert_eq!(Priority::parse(p.as_str()), Some(p));
        }
        assert_eq!(Priority::parse("urgent"), None);
        // the scheduler's invariants: interactive outweighs batch, and
        // neither class has weight 0 (which would starve it outright)
        assert!(Priority::Interactive.weight() > Priority::Batch.weight());
        assert!(Priority::Batch.weight() > 0);
        assert_ne!(Priority::Interactive.index(), Priority::Batch.index());
    }

    #[test]
    fn tier_roundtrip_defaults_and_resolution() {
        for t in [QualityTier::Kv4, QualityTier::Kv8] {
            assert_eq!(QualityTier::parse(t.as_str()), Some(t));
        }
        assert_eq!(QualityTier::parse("kv16"), None);
        assert_eq!(QualityTier::Kv4.kv_bits(), 4);
        assert_eq!(QualityTier::Kv8.kv_bits(), 8);
        assert_ne!(QualityTier::Kv4.index(), QualityTier::Kv8.index());
        // unset tier defaults from the priority class: interactive
        // traffic takes the fast 4-bit path, batch the lossless-grade one
        let p = GenerationParams::new(vec![1]);
        assert_eq!(p.resolved_tier(), QualityTier::Kv4);
        let p = GenerationParams::new(vec![1]).priority(Priority::Batch);
        assert_eq!(p.resolved_tier(), QualityTier::Kv8);
        // explicit tier wins over the priority default
        let p = GenerationParams::new(vec![1]).priority(Priority::Batch)
            .tier(QualityTier::Kv4);
        assert_eq!(p.resolved_tier(), QualityTier::Kv4);
        assert_eq!(p.clone().into_request().tier, QualityTier::Kv4);
        let p = GenerationParams::new(vec![1]);
        assert_eq!(p.into_request().tier, QualityTier::Kv4);
    }

    #[test]
    fn terminal_classification() {
        assert!(GenerationEvent::Failed { error: "x".into() }.is_terminal());
        assert!(GenerationEvent::Finished {
            reason: FinishReason::Stop, stats: RequestStats::default(),
        }.is_terminal());
        assert!(!GenerationEvent::Queued.is_terminal());
        assert!(!GenerationEvent::Token { token: 1, index: 0 }.is_terminal());
    }

    #[test]
    fn submit_error_display() {
        let e = SubmitError::QueueFull { bound: 4 };
        assert!(e.to_string().contains("bound 4"));
    }
}
