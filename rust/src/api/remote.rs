//! TCP [`InferenceService`]: [`Client`] speaks the v2 newline-JSON
//! event-frame protocol to the server in `quarot::server`.
//!
//! The client is single-threaded and pull-driven: frames are read off
//! the socket when a [`RequestHandle`] asks for its next event, and
//! frames belonging to *other* in-flight requests are buffered — so one
//! connection can interleave any number of concurrent requests and
//! cancel any of them mid-generation.

use std::cell::{Cell, RefCell};
use std::collections::{HashMap, HashSet, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::wire::{self, ServerFrame};
use super::{EventSource, GenerationEvent, GenerationOutcome, GenerationParams,
            InferenceService, RequestHandle, RequestId, SubmitError};
use crate::util::json::{self, n, obj, Value};

struct RemoteCore {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// Event frames for requests nobody is currently reading.
    buffered: VecDeque<(RequestId, GenerationEvent)>,
    /// cid → server request id, learned from `queued` frames.
    acks: HashMap<u64, RequestId>,
    /// cid → admission rejection.
    rejected: HashMap<u64, SubmitError>,
    /// Ids whose handle was dropped undrained: frames are discarded.
    released: HashSet<RequestId>,
    stats: VecDeque<Value>,
    metrics: VecDeque<Value>,
    traces: VecDeque<Value>,
    /// Pending `flush-prefix` acknowledgements.
    flush_acks: usize,
    saw_shutdown: bool,
}

impl RemoteCore {
    fn send(&mut self, frame: &Value) -> Result<()> {
        writeln!(self.writer, "{}", json::write(frame)).context("send frame")
    }

    /// Read and dispatch exactly one frame from the socket.
    fn pump_one(&mut self) -> Result<()> {
        let mut line = String::new();
        loop {
            line.clear();
            if self.reader.read_line(&mut line).context("read frame")? == 0 {
                bail!("connection closed by server");
            }
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            let v = json::parse(trimmed)
                .map_err(|e| anyhow::anyhow!("bad frame: {e}"))?;
            match wire::parse_server_frame(&v)? {
                ServerFrame::Event { id, cid, event } => {
                    if let (GenerationEvent::Queued, Some(cid)) = (&event, cid) {
                        self.acks.insert(cid, id);
                    }
                    if event.is_terminal() {
                        // a terminal frame is the last for this id; stop
                        // discarding in case the id is ever reused
                        if self.released.remove(&id) {
                            return Ok(());
                        }
                    } else if self.released.contains(&id) {
                        return Ok(());
                    }
                    self.buffered.push_back((id, event));
                }
                ServerFrame::Rejected { cid, error } => {
                    self.rejected.insert(cid, error);
                }
                ServerFrame::Stats(v) => self.stats.push_back(v),
                ServerFrame::Metrics(v) => self.metrics.push_back(v),
                ServerFrame::Trace(v) => self.traces.push_back(v),
                ServerFrame::FlushPrefixAck => self.flush_acks += 1,
                ServerFrame::Error { id, error } => {
                    // Id-tagged advisory errors are never injected into a
                    // request's stream — they could arrive after the real
                    // terminal frame and fake a second terminal.  The
                    // stream's own `failed` frame is the only Failed
                    // source.  Id-less errors are protocol-fatal.
                    if id.is_none() {
                        bail!("server error: {error}");
                    }
                }
                ServerFrame::Shutdown => self.saw_shutdown = true,
            }
            return Ok(());
        }
    }
}

impl EventSource for RemoteCore {
    fn next_event_for(&mut self, id: RequestId)
                      -> Result<Option<GenerationEvent>> {
        loop {
            if let Some(pos) = self.buffered.iter().position(|(i, _)| *i == id) {
                return Ok(self.buffered.remove(pos).map(|(_, ev)| ev));
            }
            self.pump_one()?;
        }
    }

    fn cancel_request(&mut self, id: RequestId) -> Result<bool> {
        self.send(&wire::encode_cancel(id))?;
        // Confirmation arrives as the stream's Finished{Cancelled} frame.
        Ok(true)
    }

    fn release_request(&mut self, id: RequestId) {
        // If the terminal frame already arrived, the stream is complete —
        // just discard its buffered frames; a cancel or a `released`
        // entry (whose cleanup keys off a *future* terminal frame that
        // will never come) would leak.
        let had_terminal = self.buffered.iter()
            .any(|(i, ev)| *i == id && ev.is_terminal());
        self.buffered.retain(|(i, _)| *i != id);
        if !had_terminal {
            // best-effort: the server stops generating, and frames still
            // in flight for this id are discarded instead of accumulating
            let _ = self.send(&wire::encode_cancel(id));
            self.released.insert(id);
        }
    }
}

/// Blocking event-frame client for tests, examples and the CLI.
pub struct Client {
    core: Rc<RefCell<RemoteCore>>,
    next_cid: Cell<u64>,
}

impl Client {
    /// Open a TCP connection to a server on localhost.
    pub fn connect(port: u16) -> Result<Client> {
        let stream = TcpStream::connect(("127.0.0.1", port))?;
        let writer = stream.try_clone()?;
        Ok(Client {
            core: Rc::new(RefCell::new(RemoteCore {
                reader: BufReader::new(stream),
                writer,
                buffered: VecDeque::new(),
                acks: HashMap::new(),
                rejected: HashMap::new(),
                released: HashSet::new(),
                stats: VecDeque::new(),
                metrics: VecDeque::new(),
                traces: VecDeque::new(),
                flush_acks: 0,
                saw_shutdown: false,
            })),
            next_cid: Cell::new(1),
        })
    }

    /// Send a submit-shaped frame and block until the server's `queued`
    /// ack (or typed rejection) for it arrives; event frames for other
    /// requests seen meanwhile are buffered, not lost.
    fn submit_frame(&self, frame: Value, cid: u64)
                    -> Result<RequestHandle, SubmitError> {
        let mut core = self.core.borrow_mut();
        core.send(&frame)
            .map_err(|e| SubmitError::Transport(format!("{e:#}")))?;
        loop {
            if let Some(id) = core.acks.remove(&cid) {
                drop(core);
                return Ok(RequestHandle::new(id, self.core.clone()));
            }
            if let Some(err) = core.rejected.remove(&cid) {
                return Err(err);
            }
            core.pump_one()
                .map_err(|e| SubmitError::Transport(format!("{e:#}")))?;
        }
    }

    /// Submit and block until the server acks (or rejects) the request.
    pub fn submit(&self, params: &GenerationParams)
                  -> Result<RequestHandle, SubmitError> {
        params.validate()?;
        let cid = self.next_cid.get();
        self.next_cid.set(cid + 1);
        self.submit_frame(wire::encode_submit(cid, params), cid)
    }

    /// Multi-turn chat: submit `params.prompt` as the *new user text* of
    /// a conversation.  `session: None` opens a new session (read the
    /// assigned id off the outcome's `stats.session`); `Some(id)` resumes
    /// one — the server prepends the stored history and replays it from
    /// donated prefix-cache pages, so only the new text is prefilled.
    pub fn chat(&self, session: Option<u64>, params: &GenerationParams)
                -> Result<RequestHandle, SubmitError> {
        params.validate()?;
        let cid = self.next_cid.get();
        self.next_cid.set(cid + 1);
        self.submit_frame(wire::encode_chat(cid, session, params), cid)
    }

    /// Drop every shard's prefix-cache entries (ops / test hygiene);
    /// blocks until the server acks.
    pub fn flush_prefix(&mut self) -> Result<()> {
        let mut core = self.core.borrow_mut();
        core.send(&wire::encode_cmd("flush-prefix"))?;
        while core.flush_acks == 0 {
            core.pump_one()?;
        }
        core.flush_acks -= 1;
        Ok(())
    }

    /// v1-style convenience: submit, drain to the terminal event, and
    /// shape the outcome like the old one-shot response object.
    pub fn generate(&mut self, prompt: &[u16], max_new: usize) -> Result<Value> {
        let handle = self.submit(&GenerationParams::new(prompt.to_vec())
                                     .max_new(max_new))
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let out = handle.wait()?;
        Ok(outcome_to_value(&out))
    }

    /// Engine counters (`{"v":2,"event":"stats", ...}` frame payload) —
    /// flat cluster-wide aggregates including live queue depth and
    /// active-slot count.
    pub fn stats(&mut self) -> Result<Value> {
        let mut core = self.core.borrow_mut();
        core.send(&wire::encode_cmd("stats"))?;
        loop {
            if let Some(v) = core.stats.pop_front() {
                return Ok(v);
            }
            core.pump_one()?;
        }
    }

    /// Full cluster metrics (`{"v":2,"event":"metrics", ...}`) with the
    /// per-shard breakdown under `"per_shard"`.
    pub fn metrics(&mut self) -> Result<Value> {
        let mut core = self.core.borrow_mut();
        core.send(&wire::encode_cmd("metrics"))?;
        loop {
            if let Some(v) = core.metrics.pop_front() {
                return Ok(v);
            }
            core.pump_one()?;
        }
    }

    /// Drain every shard's span ring into a Chrome-trace frame
    /// (`{"v":2,"event":"trace","traceEvents":[..]}`).  The
    /// `traceEvents` value is a complete Chrome-trace / Perfetto
    /// document body; each call returns the window recorded since the
    /// previous one (the server-side rings are emptied by the drain).
    pub fn trace(&mut self) -> Result<Value> {
        let mut core = self.core.borrow_mut();
        core.send(&wire::encode_cmd("trace"))?;
        loop {
            if let Some(v) = core.traces.pop_front() {
                return Ok(v);
            }
            core.pump_one()?;
        }
    }

    /// Ask the server to shut down (engine + accept loops exit); resolves
    /// on the ack frame or the connection closing.
    pub fn shutdown_server(&mut self) -> Result<()> {
        let mut core = self.core.borrow_mut();
        core.send(&wire::encode_cmd("shutdown"))?;
        while !core.saw_shutdown {
            if core.pump_one().is_err() {
                break; // connection closed — shutdown took effect
            }
        }
        Ok(())
    }
}

impl InferenceService for Client {
    fn submit(&mut self, params: GenerationParams)
              -> Result<RequestHandle, SubmitError> {
        Client::submit(self, &params)
    }

    fn cancel(&mut self, id: RequestId) -> Result<bool> {
        self.core.borrow_mut().cancel_request(id)
    }
}

/// Shape a drained outcome like the legacy v1 one-shot response.
pub fn outcome_to_value(out: &GenerationOutcome) -> Value {
    let toks: Vec<Value> = out.tokens.iter().map(|&t| n(t as f64)).collect();
    obj(vec![
        ("id", n(out.id as f64)),
        ("tokens", Value::Arr(toks)),
        ("finish_reason", json::s(out.reason.as_str())),
        ("ttft_ms", n(out.stats.ttft_ms)),
        ("decode_ms", n(out.stats.decode_ms)),
        ("queued_ms", n(out.stats.queued_ms)),
        ("tokens_per_sec", n(out.stats.tokens_per_sec())),
    ])
}
