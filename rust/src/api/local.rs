//! In-process [`InferenceService`]: a [`LocalSession`] wraps the
//! continuous-batching [`GenerationEngine`] and drives it lazily — the
//! consuming thread ticks the engine whenever a handle asks for an event
//! (the PJRT executables are not `Send`, so there is no background
//! thread; the TCP server puts the session on its own engine thread).

use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;

use anyhow::Result;

use super::{EventSource, GenerationEvent, GenerationParams, InferenceService,
            RequestHandle, RequestId, SubmitError};
use crate::coordinator::batcher::{EngineStats, GenerationEngine};
use crate::coordinator::prefix::PrefixStats;
use crate::telemetry::Span;

/// Session-level knobs.
#[derive(Clone, Copy, Debug)]
pub struct SessionConfig {
    /// Bound on the waiting queue; submissions beyond it are rejected
    /// with [`SubmitError::QueueFull`].
    pub queue_bound: usize,
}

impl Default for SessionConfig {
    fn default() -> SessionConfig {
        SessionConfig { queue_bound: 256 }
    }
}

struct LocalCore {
    engine: GenerationEngine,
    /// Undelivered events in arrival order.  One shared queue serves both
    /// consumption styles: handles remove the first event matching their
    /// id; multiplexed consumers ([`LocalSession::poll_events`]) drain
    /// from the front regardless of id.
    events: VecDeque<(RequestId, GenerationEvent)>,
}

impl LocalCore {
    fn drain_engine(&mut self) {
        self.events.extend(self.engine.take_events());
    }

    /// One engine tick; a tick-level error fails every in-flight request
    /// (each gets its `Failed` event) instead of wedging the session.
    fn tick_once(&mut self) {
        if let Err(e) = self.engine.tick() {
            self.engine.fail_all(&format!("engine tick failed: {e:#}"));
        }
        self.drain_engine();
    }
}

impl EventSource for LocalCore {
    fn next_event_for(&mut self, id: RequestId)
                      -> Result<Option<GenerationEvent>> {
        loop {
            self.drain_engine();
            if let Some(pos) = self.events.iter().position(|(i, _)| *i == id) {
                return Ok(self.events.remove(pos).map(|(_, ev)| ev));
            }
            if self.engine.pending() == 0 {
                return Ok(None);
            }
            self.tick_once();
        }
    }

    fn cancel_request(&mut self, id: RequestId) -> Result<bool> {
        let hit = self.engine.cancel(id);
        self.drain_engine();
        Ok(hit)
    }

    fn release_request(&mut self, id: RequestId) {
        self.engine.cancel(id);
        self.drain_engine();
        self.events.retain(|(i, _)| *i != id);
    }
}

/// The in-process implementation of the unified inference API.
pub struct LocalSession {
    core: Rc<RefCell<LocalCore>>,
}

impl LocalSession {
    /// Wrap an engine, applying the config's queue bound.
    pub fn new(mut engine: GenerationEngine, cfg: SessionConfig) -> LocalSession {
        engine.set_queue_bound(cfg.queue_bound);
        LocalSession {
            core: Rc::new(RefCell::new(LocalCore {
                engine,
                events: VecDeque::new(),
            })),
        }
    }

    /// Submit and get a [`RequestHandle`] for pulling this request's
    /// events.
    pub fn submit(&self, params: GenerationParams)
                  -> Result<RequestHandle, SubmitError> {
        let id = self.submit_detached(params)?;
        Ok(RequestHandle::new(id, self.core.clone()))
    }

    /// Submit without a handle — for multiplexed consumers (the TCP
    /// server) that read every request's events via
    /// [`Self::poll_events`].
    pub fn submit_detached(&self, params: GenerationParams)
                           -> Result<RequestId, SubmitError> {
        params.validate()?;
        let mut core = self.core.borrow_mut();
        let id = core.engine.try_submit(params.into_request())?;
        core.drain_engine();
        Ok(id)
    }

    /// Advance the engine by at most one tick and drain *all* buffered
    /// events in emission order (the multiplexed consumption mode — do
    /// not mix with handle-based reads, which would race for the same
    /// events).
    pub fn poll_events(&self) -> Vec<(RequestId, GenerationEvent)> {
        let mut core = self.core.borrow_mut();
        core.drain_engine();
        if core.events.is_empty() && core.engine.pending() > 0 {
            core.tick_once();
        }
        core.events.drain(..).collect()
    }

    /// Cancel by id; pages return to the pool immediately and the
    /// request's stream terminates with `Finished { Cancelled }`.
    pub fn cancel(&self, id: RequestId) -> bool {
        self.core.borrow_mut().cancel_request(id).unwrap_or(false)
    }

    /// Queued + active requests.
    pub fn pending(&self) -> usize {
        self.core.borrow().engine.pending()
    }

    /// Snapshot of the engine's cumulative counters.
    pub fn stats(&self) -> EngineStats {
        self.core.borrow().engine.stats.clone()
    }

    /// KV pages currently allocated from the engine's page pool.
    pub fn pool_in_use(&self) -> usize {
        self.core.borrow().engine.pool_in_use()
    }

    /// Shared prefix-cache counters and pinned-page gauge.
    pub fn prefix_stats(&self) -> PrefixStats {
        self.core.borrow().engine.prefix_stats()
    }

    /// Flush the prefix cache, releasing the pages it pins (pages still
    /// grafted by live sequences survive until those sequences finish).
    pub fn clear_prefix_cache(&self) {
        self.core.borrow_mut().engine.clear_prefix_cache();
    }

    /// Cap the number of live chat sessions (0 disables the session
    /// subsystem); evicted sessions release their pinned trie chains.
    pub fn set_session_budget(&self, max_sessions: usize) {
        self.core.borrow_mut().engine.set_session_budget(max_sessions);
    }

    /// Live conversations (the `sessions_live` gauge).
    pub fn sessions_live(&self) -> usize {
        self.core.borrow().engine.sessions_live()
    }

    /// Enable request-lifecycle tracing with a span ring of `capacity`
    /// entries (0 disables; the ring overwrites oldest-first).
    pub fn set_trace_buffer(&self, capacity: usize) {
        self.core.borrow_mut().engine.set_trace_buffer(capacity);
    }

    /// Keep one in every `every` per-token `decode_token` spans
    /// (1 = keep all; lifecycle and tick-phase spans are never sampled).
    pub fn set_trace_sample(&self, every: u64) {
        self.core.borrow_mut().engine.set_trace_sample(every);
    }

    /// Drain the recorded spans in record order, emptying the ring.
    pub fn drain_spans(&self) -> Vec<Span> {
        self.core.borrow_mut().engine.drain_spans()
    }
}

impl InferenceService for LocalSession {
    fn submit(&mut self, params: GenerationParams)
              -> Result<RequestHandle, SubmitError> {
        LocalSession::submit(self, params)
    }

    fn cancel(&mut self, id: RequestId) -> Result<bool> {
        Ok(LocalSession::cancel(self, id))
    }
}
