//! The native [`ModelExecutor`]: a pure-rust forward pass over the
//! [`crate::backend::ComputeBackend`] ops — int4/int8 GEMM projections,
//! online Hadamards, activation quant, RMSNorm — plus the fused
//! tail-attention kernels in [`super::attn`].  `quarot serve --executor
//! native` runs entirely through this path with zero PJRT graphs loaded.
//!
//! # Semantics (mirrors `python/compile/model.py`)
//!
//! *Prefill* uses the prefill-graph convention: causal f32 attention over
//! the **fake-quantized** K/V (self token included), returning the raw
//! K/V streams.  *Decode* and *prefill chunks* use the decode-graph
//! convention: quantized staging history per lane plus the new token's
//! K/V as a full-precision softmax tail.  The split matches the compiled
//! graphs exactly — a prefix-cache partial hit already replays its suffix
//! under decode semantics on the PJRT path, and the repo's golden tests
//! accept that as bit-comparable.
//!
//! # Numerical parity vs the graph path
//!
//! Weight grids are bit-identical (see [`super::weights`]); activation
//! grids are the same formula.  Floating-point *summation order* inside
//! GEMMs and softmaxes differs from XLA's, so native logits track the
//! graph path to tight tolerance and equal argmax, not bitwise — the
//! artifact-gated parity test in `rust/tests/integration.rs` pins that.
//! Within the native path itself, chunked prefill is **bitwise** equal to
//! token-at-a-time prefill at any chunk size: every per-row op is
//! independent of the batch dimension (per-output-element GEMM
//! accumulation, per-row norms/quant/rotations), and the staging lane
//! evolves through the identical sequence of `stage_kv_row` writes.  The
//! tests below pin this at chunk sizes 1/3/N for both staging layouts.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::attention::{KvCodes, KvF32View, KvQuantView};
use crate::backend::ComputeBackend;
use crate::coordinator::runner::{CalibStats, QuantSpec, Variant};
use crate::hadamard::{had_headdim, had_heads};
use crate::model::{ModelConfig, Weights};
use crate::quant::kv;

use super::weights::{LayerWeights, NativeWeights};
use super::{attn, stage_kv_row, ChunkResult, DecodeStaging, ModelExecutor,
            Prefilled};

/// RMSNorm epsilon — matches `python/compile/model.py::_NORM_EPS`.
const NORM_EPS: f32 = 1e-5;

/// Graph-free model executor over packed native weights.
pub struct NativeExecutor {
    cfg: ModelConfig,
    spec: QuantSpec,
    backend: Arc<dyn ComputeBackend>,
    weights: NativeWeights,
}

/// How a forward pass touches the staging lanes: decode reads history
/// only; prefill chunks also write each fresh token's quantized K/V.
enum StagingAccess<'a> {
    Read(&'a DecodeStaging),
    Write { staging: &'a mut DecodeStaging, bits: u32 },
}

impl StagingAccess<'_> {
    fn staging(&self) -> &DecodeStaging {
        match self {
            StagingAccess::Read(s) => s,
            StagingAccess::Write { staging, .. } => staging,
        }
    }
}

fn rmsnorm_row(x: &[f32], gamma: &[f32], out: &mut [f32]) {
    let d = x.len();
    let ss: f32 = x.iter().map(|v| v * v).sum();
    let inv = 1.0 / (ss / d as f32 + NORM_EPS).sqrt();
    for i in 0..d {
        out[i] = x[i] * inv * gamma[i];
    }
}

/// Half-split RoPE at one position (python `rope`): `x1 = x[..half]`,
/// `x2 = x[half..]` per head; values are never applied (caller skips v).
fn rope_row(x: &mut [f32], n_heads: usize, d_head: usize, pos: usize,
            theta: f32) {
    let half = d_head / 2;
    for h in 0..n_heads {
        let xh = &mut x[h * d_head..(h + 1) * d_head];
        for i in 0..half {
            let freq = theta.powf(-(i as f32) / half as f32);
            let ang = pos as f32 * freq;
            let (sin, cos) = ang.sin_cos();
            let (x1, x2) = (xh[i], xh[i + half]);
            xh[i] = x1 * cos - x2 * sin;
            xh[i + half] = x1 * sin + x2 * cos;
        }
    }
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Round-to-nearest-even f32 → bf16 → f32 (the `had_bf16` graph variant
/// casts every online-Hadamard output through bf16).
fn round_bf16(x: f32) -> f32 {
    let bits = x.to_bits();
    let rounded = bits.wrapping_add(0x7FFF + ((bits >> 16) & 1));
    f32::from_bits(rounded & 0xFFFF_0000)
}

fn round_bf16_slice(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = round_bf16(*v);
    }
}

impl NativeExecutor {
    /// Pack `weights` per `spec` and build the executor.  `order` is the
    /// manifest weight order; `stats` feeds GPTQ/SmoothQuant preparation
    /// exactly like the graph path.
    pub fn new(cfg: &ModelConfig, order: &[String], weights: &Weights,
               spec: QuantSpec, stats: Option<&CalibStats>,
               backend: Arc<dyn ComputeBackend>) -> Result<NativeExecutor> {
        if cfg.d_head % cfg.kv_group != 0 {
            bail!("native executor needs d_head % kv_group == 0 \
                   (got {} % {})", cfg.d_head, cfg.kv_group);
        }
        let packed = NativeWeights::build(cfg, order, weights, &spec, stats)?;
        Ok(NativeExecutor {
            cfg: cfg.clone(),
            spec,
            backend,
            weights: packed,
        })
    }

    /// Packed weight footprint in bytes (bench table).
    pub fn weight_bytes(&self) -> usize {
        self.weights.bytes()
    }

    fn embed_rows(&self, tokens: &[i32], x: &mut [f32]) {
        let d = self.cfg.d_model;
        for (i, &t) in tokens.iter().enumerate() {
            let t = (t.max(0) as usize).min(self.cfg.vocab - 1);
            x[i * d..(i + 1) * d]
                .copy_from_slice(&self.weights.embed[t * d..(t + 1) * d]);
        }
    }

    /// Pre-attention half of a layer: norm → QKV projections → RoPE →
    /// per-head Hadamard (rotated variants).  Returns `(q, k, v)` rows.
    fn qkv_rows(&self, lw: &LayerWeights, x: &[f32], n: usize, poss: &[usize])
                -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let cfg = &self.cfg;
        let (d, da, dkv, dh) = (cfg.d_model, cfg.d_attn(), cfg.d_kv(), cfg.d_head);
        let (ab, ac) = (self.spec.act_bits, self.spec.act_clip);
        let be = &*self.backend;
        let rot = self.spec.variant.is_rotated();
        let h16 = self.spec.variant == Variant::QuarotH16;
        let theta = cfg.rope_theta as f32;
        let mut h = vec![0.0f32; n * d];
        for i in 0..n {
            rmsnorm_row(&x[i * d..(i + 1) * d], &lw.attn_norm,
                        &mut h[i * d..(i + 1) * d]);
        }
        let mut q = vec![0.0f32; n * da];
        let mut k = vec![0.0f32; n * dkv];
        let mut v = vec![0.0f32; n * dkv];
        lw.wq.apply(be, &h, n, ab, ac, &mut q);
        lw.wk.apply(be, &h, n, ab, ac, &mut k);
        lw.wv.apply(be, &h, n, ab, ac, &mut v);
        for i in 0..n {
            let qi = &mut q[i * da..(i + 1) * da];
            let ki = &mut k[i * dkv..(i + 1) * dkv];
            rope_row(qi, cfg.n_heads, dh, poss[i], theta);
            rope_row(ki, cfg.n_kv_heads, dh, poss[i], theta);
            if rot {
                had_headdim(qi, dh);
                had_headdim(ki, dh);
                if h16 {
                    round_bf16_slice(qi);
                    round_bf16_slice(ki);
                }
            }
        }
        (q, k, v)
    }

    /// Post-attention half: per-head-mixing Hadamard → output projection →
    /// residual → FFN (norm, up·silu(gate), online WHT, down) → residual.
    fn finish_layer(&self, lw: &LayerWeights, x: &mut [f32], att: &mut [f32],
                    n: usize) {
        let cfg = &self.cfg;
        let (d, da, dff) = (cfg.d_model, cfg.d_attn(), cfg.d_ff);
        let (ab, ac) = (self.spec.act_bits, self.spec.act_clip);
        let be = &*self.backend;
        let rot = self.spec.variant.is_rotated();
        let h16 = self.spec.variant == Variant::QuarotH16;
        if rot {
            for i in 0..n {
                let ai = &mut att[i * da..(i + 1) * da];
                had_heads(ai, cfg.n_heads);
                if h16 {
                    round_bf16_slice(ai);
                }
            }
        }
        let mut proj = vec![0.0f32; n * d];
        lw.wo.apply(be, att, n, ab, ac, &mut proj);
        for (xv, pv) in x.iter_mut().zip(&proj) {
            *xv += pv;
        }
        let mut h = vec![0.0f32; n * d];
        for i in 0..n {
            rmsnorm_row(&x[i * d..(i + 1) * d], &lw.ffn_norm,
                        &mut h[i * d..(i + 1) * d]);
        }
        let mut up = vec![0.0f32; n * dff];
        let mut gate = vec![0.0f32; n * dff];
        lw.wup.apply(be, &h, n, ab, ac, &mut up);
        lw.wgate.apply(be, &h, n, ab, ac, &mut gate);
        for (u, g) in up.iter_mut().zip(&gate) {
            *u *= silu(*g);
        }
        if rot {
            be.had_rows(&mut up, dff);
            if h16 {
                round_bf16_slice(&mut up);
            }
        }
        lw.wdown.apply(be, &up, n, ab, ac, &mut proj);
        for (xv, pv) in x.iter_mut().zip(&proj) {
            *xv += pv;
        }
    }

    /// Final norm + LM head (never activation-quantized, like the graphs).
    fn head_logits(&self, x: &[f32], n: usize) -> Vec<f32> {
        let d = self.cfg.d_model;
        let mut h = vec![0.0f32; n * d];
        for i in 0..n {
            rmsnorm_row(&x[i * d..(i + 1) * d], &self.weights.final_norm,
                        &mut h[i * d..(i + 1) * d]);
        }
        let mut logits = vec![0.0f32; n * self.cfg.vocab];
        self.backend.gemm_f32(&h, n, &self.weights.lm_head, &mut logits);
        logits
    }

    /// Decode-semantics forward over `n` rows: row `i` is token
    /// `tokens[i]` of staging lane `lanes[i]` at position `poss[i]`,
    /// attending over the lane's first `poss[i]` staged entries plus its
    /// own fp K/V tail.  In `Write` mode each row's K/V is staged at its
    /// position *before* the next row runs — within a chunk, row `i+1`
    /// sees row `i` exactly as a later decode step would.  Returns
    /// `(logits (n, vocab), k, v (L, n, d_kv) raw)`.
    fn forward_rows(&self, tokens: &[i32], lanes: &[usize], poss: &[usize],
                    mut access: StagingAccess<'_>)
                    -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let cfg = &self.cfg;
        let n = tokens.len();
        let (d, da, dkv) = (cfg.d_model, cfg.d_attn(), cfg.d_kv());
        let (b, s) = (cfg.decode_batch, cfg.cache_seq);
        let ng = dkv / cfg.kv_group;
        let fp = self.spec.kv_is_fp();
        let mut x = vec![0.0f32; n * d];
        self.embed_rows(tokens, &mut x);
        let mut ks = vec![0.0f32; cfg.n_layers * n * dkv];
        let mut vs = vec![0.0f32; cfg.n_layers * n * dkv];
        let mut att = vec![0.0f32; n * da];
        for (l, lw) in self.weights.layers.iter().enumerate() {
            let (q, k, v) = self.qkv_rows(lw, &x, n, poss);
            for i in 0..n {
                let (lane, pos) = (lanes[i], poss[i]);
                let qi = &q[i * da..(i + 1) * da];
                let ki = &k[i * dkv..(i + 1) * dkv];
                let vi = &v[i * dkv..(i + 1) * dkv];
                let ai = &mut att[i * da..(i + 1) * da];
                let lane_tok = (l * b + lane) * s;
                let st = access.staging();
                if fp {
                    let kview = KvF32View {
                        n_kv_heads: cfg.n_kv_heads, d_head: cfg.d_head,
                        len: pos,
                        data: &st.k_f32[lane_tok * dkv..(lane_tok + pos) * dkv],
                    };
                    let vview = KvF32View {
                        data: &st.v_f32[lane_tok * dkv..(lane_tok + pos) * dkv],
                        ..kview
                    };
                    attn::decode_tail_f32(qi, &kview, &vview, cfg.n_heads,
                                          ki, vi, ai);
                } else {
                    let co = lane_tok * dkv;
                    let go = lane_tok * ng;
                    let kview = KvQuantView {
                        n_kv_heads: cfg.n_kv_heads, d_head: cfg.d_head,
                        group: cfg.kv_group, len: pos,
                        codes: KvCodes::I8(&st.k_codes[co..co + pos * dkv]),
                        scales: &st.k_scale[go..go + pos * ng],
                        zeros: &st.k_zero[go..go + pos * ng],
                    };
                    let vview = KvQuantView {
                        codes: KvCodes::I8(&st.v_codes[co..co + pos * dkv]),
                        scales: &st.v_scale[go..go + pos * ng],
                        zeros: &st.v_zero[go..go + pos * ng],
                        ..kview
                    };
                    attn::decode_tail_quant(qi, &kview, &vview, cfg.n_heads,
                                            ki, vi, ai);
                }
                if let StagingAccess::Write { staging, bits } = &mut access {
                    stage_kv_row(staging, cfg, l, lane, pos, *bits,
                                 self.spec.kv_clip, fp, ki, vi);
                }
                ks[(l * n + i) * dkv..(l * n + i + 1) * dkv]
                    .copy_from_slice(ki);
                vs[(l * n + i) * dkv..(l * n + i + 1) * dkv]
                    .copy_from_slice(vi);
            }
            self.finish_layer(lw, &mut x, &mut att, n);
        }
        Ok((self.head_logits(&x, n), ks, vs))
    }

    /// Fake-quantize a `(n, d_kv)` K or V slab through the grouped codec
    /// (prefill-graph `kv_fake_quant`); `bits >= 16` passes through.
    fn fake_kv(&self, raw: &[f32], bits: u32) -> Vec<f32> {
        if bits >= 16 {
            return raw.to_vec();
        }
        let (codes, scales, zeros) = self.backend.kv_quant_slab(
            raw, self.cfg.d_kv(), self.cfg.kv_group, bits, self.spec.kv_clip);
        let mut out = vec![0.0f32; raw.len()];
        self.backend.kv_dequant(&codes, &scales, &zeros, self.cfg.kv_group,
                                &mut out);
        out
    }
}

impl ModelExecutor for NativeExecutor {
    fn name(&self) -> &'static str {
        "native"
    }

    fn prefill(&self, tokens: &[u16]) -> Result<Prefilled> {
        let cfg = &self.cfg;
        let n = tokens.len();
        if n == 0 || n > cfg.max_seq {
            bail!("prefill length {n} outside 1..={}", cfg.max_seq);
        }
        let (d, da, dkv) = (cfg.d_model, cfg.d_attn(), cfg.d_kv());
        let toks: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let poss: Vec<usize> = (0..n).collect();
        let mut x = vec![0.0f32; n * d];
        self.embed_rows(&toks, &mut x);
        let mut ks = vec![0.0f32; cfg.n_layers * n * dkv];
        let mut vs = vec![0.0f32; cfg.n_layers * n * dkv];
        let mut att = vec![0.0f32; n * da];
        for (l, lw) in self.weights.layers.iter().enumerate() {
            let (q, k, v) = self.qkv_rows(lw, &x, n, &poss);
            // prefill-graph semantics: attend over fake-quantized K/V,
            // self token included; the returned streams stay raw
            let k_att = self.fake_kv(&k, self.spec.kv_bits);
            let v_att = self.fake_kv(&v, self.spec.kv_bits_v);
            attn::causal_prefill(&q, &k_att, &v_att, n, cfg.n_heads,
                                 cfg.n_kv_heads, cfg.d_head, &mut att);
            ks[l * n * dkv..(l + 1) * n * dkv].copy_from_slice(&k);
            vs[l * n * dkv..(l + 1) * n * dkv].copy_from_slice(&v);
            self.finish_layer(lw, &mut x, &mut att, n);
        }
        Ok(Prefilled { logits: self.head_logits(&x, n), ks, vs, len: n })
    }

    fn decode(&self, tokens: &[i32], cur_lens: &[i32], staging: &DecodeStaging)
              -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let b = self.cfg.decode_batch;
        if tokens.len() != b || cur_lens.len() != b {
            bail!("decode expects {b}-lane token/len vectors");
        }
        let lanes: Vec<usize> = (0..b).collect();
        let poss: Vec<usize> = cur_lens.iter().map(|&l| l.max(0) as usize)
            .collect();
        if let Some(&p) = poss.iter().max() {
            if p >= self.cfg.cache_seq {
                bail!("decode position {p} beyond cache_seq {}",
                      self.cfg.cache_seq);
            }
        }
        self.forward_rows(tokens, &lanes, &poss, StagingAccess::Read(staging))
    }

    fn prefill_chunk(&self, tokens: &[u16], start_pos: usize, slot: usize,
                     kv_bits: u32, staging: &mut DecodeStaging)
                     -> Result<ChunkResult> {
        let cfg = &self.cfg;
        let t = tokens.len();
        if t == 0 {
            bail!("empty prefill chunk");
        }
        if slot >= cfg.decode_batch {
            bail!("chunk slot {slot} out of range");
        }
        if start_pos + t > cfg.cache_seq {
            bail!("chunk [{start_pos}, {}) beyond cache_seq {}",
                  start_pos + t, cfg.cache_seq);
        }
        let toks: Vec<i32> = tokens.iter().map(|&x| x as i32).collect();
        let lanes = vec![slot; t];
        let poss: Vec<usize> = (start_pos..start_pos + t).collect();
        let (logits, k, v) = self.forward_rows(
            &toks, &lanes, &poss,
            StagingAccess::Write { staging, bits: kv_bits })?;
        Ok(ChunkResult { logits, k, v })
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use std::collections::BTreeMap;

    use crate::backend;
    use crate::coordinator::runner::QuantSpec;
    use crate::forward::weights::canonical_weight_order;
    use crate::model::transform::{self, tests::{demo_cfg, demo_weights}};
    use crate::model::weights::Tensor;
    use crate::util::prng::Rng;

    /// Archive with both `base.*` (raw) and `rot.*` (QuaRot-rotated)
    /// weight sets — like a real artifact dir — for an arbitrary config
    /// (engine-level tests want longer sequence dims than [`demo_cfg`]).
    pub(crate) fn archive_for(cfg: &ModelConfig, seed: u64) -> Weights {
        let mut rng = Rng::new(seed);
        let base = demo_weights(cfg, &mut rng);
        let signs = Rng::new(seed ^ 0x5eed).signs(cfg.d_model);
        let q = transform::q_from_signs(cfg.d_model, &signs);
        let refs: BTreeMap<String, &Tensor> =
            base.iter().map(|(k, v)| (k.clone(), v)).collect();
        let rot = transform::rotate(cfg, &refs, &q).unwrap();
        let mut tensors = BTreeMap::new();
        for (k, v) in base {
            tensors.insert(format!("base.{k}"), v);
        }
        for (k, v) in rot {
            tensors.insert(format!("rot.{k}"), v);
        }
        Weights { tensors }
    }

    /// Demo archive at the [`demo_cfg`] shape.
    pub(crate) fn demo_archive(seed: u64) -> (ModelConfig, Weights) {
        let cfg = demo_cfg();
        let weights = archive_for(&cfg, seed);
        (cfg, weights)
    }

    pub(crate) fn demo_executor(spec: QuantSpec, seed: u64)
                                -> (ModelConfig, NativeExecutor) {
        let (cfg, weights) = demo_archive(seed);
        let exec = NativeExecutor::new(&cfg, &canonical_weight_order(),
                                       &weights, spec, None,
                                       backend::make(backend::BackendKind::Scalar))
            .unwrap();
        (cfg, exec)
    }

    fn rotated_fp_spec() -> QuantSpec {
        QuantSpec { variant: Variant::Quarot, ..QuantSpec::fp16_baseline() }
    }

    // The rotation is an exact reparameterization: with quantization off,
    // the rotated native forward must reproduce the unrotated one.  This
    // exercises every piece at once — folded norms, RoPE placement,
    // per-head and cross-head Hadamards, wo/wdown transforms.
    #[test]
    fn rotated_fp_forward_matches_baseline() {
        let (_, base) = demo_executor(QuantSpec::fp16_baseline(), 42);
        let (_, rot) = demo_executor(rotated_fp_spec(), 42);
        let prompt: Vec<u16> = vec![3, 9, 1, 27, 5, 14];
        let pb = base.prefill(&prompt).unwrap();
        let pr = rot.prefill(&prompt).unwrap();
        let amax = pb.logits.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        for (i, (a, b)) in pb.logits.iter().zip(&pr.logits).enumerate() {
            assert!((a - b).abs() <= 1e-3 * amax.max(1.0),
                    "logit {i}: baseline {a} vs rotated {b}");
        }
    }

    // int4 QuaRot spec: the whole int path must run and stay finite, and
    // greedy argmax should still track the fp forward most of the time on
    // this tiny random model (weak but catches catastrophic breakage).
    #[test]
    fn quarot_int4_prefill_is_finite() {
        let (_, exec) = demo_executor(QuantSpec::quarot(4), 7);
        let prompt: Vec<u16> = vec![1, 2, 3, 4, 5, 6, 7];
        let p = exec.prefill(&prompt).unwrap();
        assert!(p.logits.iter().all(|v| v.is_finite()));
        assert!(p.ks.iter().chain(&p.vs).all(|v| v.is_finite()));
    }

    /// Drive prefill_chunk over `prompt` in chunks of `chunk` from an
    /// empty lane, returning (staging, all logits, all k, all v).
    fn run_chunked(exec: &NativeExecutor, cfg: &ModelConfig, prompt: &[u16],
                   chunk: usize, kv_bits: u32)
                   -> (DecodeStaging, Vec<f32>, Vec<f32>, Vec<f32>) {
        let fp = exec.spec.kv_is_fp();
        let mut staging = DecodeStaging::new(cfg, fp);
        let mut logits = Vec::new();
        let mut ks = Vec::new();
        let mut vs = Vec::new();
        let mut pos = 0;
        for piece in prompt.chunks(chunk) {
            let r = exec.prefill_chunk(piece, pos, 1, kv_bits, &mut staging)
                .unwrap();
            logits.extend_from_slice(&r.logits);
            ks.push(r.k);
            vs.push(r.v);
            pos += piece.len();
        }
        // flatten [L][T][d] chunk slabs into per-token-order streams
        let d = cfg.d_kv();
        let flat = |chunks: &[Vec<f32>]| -> Vec<f32> {
            let mut out = Vec::new();
            for l in 0..cfg.n_layers {
                for c in chunks {
                    let t = c.len() / (cfg.n_layers * d);
                    out.extend_from_slice(&c[l * t * d..(l + 1) * t * d]);
                }
            }
            out
        };
        (staging, logits, flat(&ks), flat(&vs))
    }

    fn assert_chunk_invariance(spec: QuantSpec, kv_bits: u32) {
        let (cfg, exec) = demo_executor(spec, 99);
        let prompt: Vec<u16> = vec![5, 1, 19, 2, 30, 11, 4];
        let n = prompt.len();
        let (st1, lg1, k1, v1) = run_chunked(&exec, &cfg, &prompt, 1, kv_bits);
        for chunk in [3, n] {
            let (st, lg, k, v) = run_chunked(&exec, &cfg, &prompt, chunk,
                                             kv_bits);
            assert!(lg1.iter().zip(&lg)
                        .all(|(a, b)| a.to_bits() == b.to_bits()),
                    "chunk={chunk}: logits diverged from token-at-a-time");
            assert!(k1.iter().zip(&k).all(|(a, b)| a.to_bits() == b.to_bits())
                    && v1.iter().zip(&v)
                        .all(|(a, b)| a.to_bits() == b.to_bits()),
                    "chunk={chunk}: raw K/V diverged");
            assert_eq!(st1.k_codes, st.k_codes, "chunk={chunk}: staging codes");
            assert!(st1.k_scale.iter().zip(&st.k_scale)
                        .all(|(a, b)| a.to_bits() == b.to_bits()),
                    "chunk={chunk}: staging scales");
            assert!(st1.k_f32.iter().zip(&st.k_f32)
                        .all(|(a, b)| a.to_bits() == b.to_bits()),
                    "chunk={chunk}: fp staging");
        }
    }

    #[test]
    fn chunked_prefill_bitwise_equals_token_at_a_time_int4() {
        assert_chunk_invariance(QuantSpec::quarot(4), 4);
    }

    #[test]
    fn chunked_prefill_bitwise_equals_token_at_a_time_kv8() {
        assert_chunk_invariance(QuantSpec::quarot(8), 8);
    }

    #[test]
    fn chunked_prefill_bitwise_equals_token_at_a_time_fp() {
        assert_chunk_invariance(QuantSpec::fp16_baseline(), 16);
    }

    // A chunked suffix must be bitwise identical to the same tokens
    // decoded one-at-a-time through `decode()` — the PJRT suffix loop's
    // contract, transplanted to the native path.
    #[test]
    fn chunk_matches_decode_loop() {
        let spec = QuantSpec::quarot(4);
        let (cfg, exec) = demo_executor(spec.clone(), 13);
        let prompt: Vec<u16> = vec![8, 21, 2, 17, 9];
        let (st_chunk, lg_chunk, _, _) =
            run_chunked(&exec, &cfg, &prompt, prompt.len(), 4);
        // token-at-a-time through the public decode() + manual staging
        let mut staging = DecodeStaging::new(&cfg, false);
        let b = cfg.decode_batch;
        let mut lg_loop = Vec::new();
        for (t, &tok) in prompt.iter().enumerate() {
            let mut toks = vec![0i32; b];
            let mut lens = vec![0i32; b];
            toks[1] = tok as i32;
            lens[1] = t as i32;
            let (lg, kn, vn) = exec.decode(&toks, &lens, &staging).unwrap();
            lg_loop.extend_from_slice(&lg[cfg.vocab..2 * cfg.vocab]);
            super::super::stage_kv_token(&mut staging, &cfg, 1, t, 4,
                                         spec.kv_clip, false, &kn, &vn);
        }
        assert!(lg_chunk.iter().zip(&lg_loop)
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                "chunked prefill != decode loop");
        assert_eq!(st_chunk.k_codes, staging.k_codes);
        assert_eq!(st_chunk.v_codes, staging.v_codes);
    }

    // On the fp path, prefill-graph semantics and decode semantics
    // coincide (no codec anywhere), so cold prefill and chunked prefill
    // must agree to fp round-off.
    #[test]
    fn fp_prefill_agrees_with_chunked() {
        let (cfg, exec) = demo_executor(QuantSpec::fp16_baseline(), 3);
        let prompt: Vec<u16> = vec![2, 7, 18, 25, 6];
        let cold = exec.prefill(&prompt).unwrap();
        let (_, lg, _, _) = run_chunked(&exec, &cfg, &prompt, 2, 16);
        let amax = cold.logits.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        for (a, b) in cold.logits.iter().zip(&lg) {
            assert!((a - b).abs() <= 1e-4 * amax.max(1.0),
                    "cold {a} vs chunked {b}");
        }
    }
}
