//! Graph-free model execution: the [`ModelExecutor`] trait and its impls.
//!
//! The coordinator used to be welded to the PJRT engine — every QKV/FFN
//! projection round-tripped an AOT-compiled graph even though
//! `staged_decode_attention` already ran natively.  This module breaks that
//! coupling: [`ModelExecutor`] is the model-level contract the batcher and
//! the eval harness actually need (whole-prompt prefill, batched decode,
//! chunked suffix prefill), and `coordinator/runner.rs` becomes a thin
//! dispatcher over two implementations:
//!
//! * `PjrtExecutor` (in `coordinator/runner.rs`) — the existing graph
//!   path, kept bit-for-bit;
//! * [`NativeExecutor`] — a pure-rust forward pass built from the
//!   [`crate::backend::ComputeBackend`] ops (int4/int8 GEMM, online
//!   Hadamard, activation quant) plus the fused tail-attention kernels in
//!   [`attn`], so `quarot serve --executor native` runs with **zero** PJRT
//!   graphs loaded.
//!
//! Chunked prefill is part of the contract: [`ModelExecutor::prefill_chunk`]
//! processes N suffix tokens at their true positions against a slot's
//! staging lane, writing the freshly quantized K/V back into the lane as it
//! goes.  Both executors share [`stage_kv_row`], which is bit-identical to
//! the `SeqCache::write_token` → `stage_token` round-trip the old
//! token-at-a-time suffix loop performed, so chunked prefill reproduces the
//! old path's numerics exactly.

pub mod attn;
pub mod native;
pub mod weights;

pub use native::NativeExecutor;
pub use weights::NativeWeights;

use anyhow::{bail, Result};

use crate::model::ModelConfig;
use crate::quant::kv;

/// Full-sequence prefill output: logits for every real position plus the
/// raw (pre-quantization) per-layer K/V streams, layer-major
/// `[L][S][d_kv]`, trimmed to the real length.
pub struct Prefilled {
    /// `(S, vocab)` logits for the real (unpadded) prompt length.
    pub logits: Vec<f32>,
    /// Raw key stream, `[L][S][d_kv]` (post-RoPE / post-Hadamard).
    pub ks: Vec<f32>,
    /// Raw value stream, `[L][S][d_kv]`.
    pub vs: Vec<f32>,
    /// Real prompt length S.
    pub len: usize,
}

/// Dense staging buffers for the decode path's cache inputs: per
/// (layer, slot) lanes of `cache_seq` token rows, either group-quantized
/// codes + scales + zeros or raw f32 (the fp16-baseline path).
pub struct DecodeStaging {
    /// Key codes, `[L][B][cache_seq][d_kv]` (unpacked i8, any bit width).
    pub k_codes: Vec<i8>,
    /// Key group scales, `[L][B][cache_seq][d_kv / kv_group]`.
    pub k_scale: Vec<f32>,
    /// Key group zero-points, same shape as `k_scale`.
    pub k_zero: Vec<f32>,
    /// Value codes, same shape as `k_codes`.
    pub v_codes: Vec<i8>,
    /// Value group scales, same shape as `k_scale`.
    pub v_scale: Vec<f32>,
    /// Value group zero-points, same shape as `k_scale`.
    pub v_zero: Vec<f32>,
    /// fp16-baseline path (kv_bits == 16): raw f32 key cache.
    pub k_f32: Vec<f32>,
    /// fp16-baseline path: raw f32 value cache.
    pub v_f32: Vec<f32>,
}

impl DecodeStaging {
    /// Allocate zeroed staging for `cfg.decode_batch` slots; `fp` selects
    /// the raw-f32 layout over the quantized one.
    pub fn new(cfg: &ModelConfig, fp: bool) -> DecodeStaging {
        let (l, b, s) = (cfg.n_layers, cfg.decode_batch, cfg.cache_seq);
        let d = cfg.d_kv();
        let ng = d / cfg.kv_group;
        if fp {
            DecodeStaging {
                k_codes: vec![], k_scale: vec![], k_zero: vec![],
                v_codes: vec![], v_scale: vec![], v_zero: vec![],
                k_f32: vec![0.0; l * b * s * d], v_f32: vec![0.0; l * b * s * d],
            }
        } else {
            DecodeStaging {
                k_codes: vec![0; l * b * s * d],
                k_scale: vec![0.0; l * b * s * ng],
                k_zero: vec![0.0; l * b * s * ng],
                v_codes: vec![0; l * b * s * d],
                v_scale: vec![0.0; l * b * s * ng],
                v_zero: vec![0.0; l * b * s * ng],
                k_f32: vec![], v_f32: vec![],
            }
        }
    }
}

/// Output of one [`ModelExecutor::prefill_chunk`] call.
pub struct ChunkResult {
    /// `(T, vocab)` logits — one row per chunk token, in order.  The last
    /// row is the one the batcher samples from when the chunk finishes the
    /// prompt.
    pub logits: Vec<f32>,
    /// Raw per-layer keys for the chunk, `[L][T][d_kv]` — what the batcher
    /// appends to the paged `SeqCache` (the staging lane is already
    /// written by the executor).
    pub k: Vec<f32>,
    /// Raw per-layer values, `[L][T][d_kv]`.
    pub v: Vec<f32>,
}

/// A model execution path the coordinator can drive: whole-prompt prefill,
/// one batched decode step, and chunked suffix prefill against a slot's
/// staging lane.  Implementations must be drop-in equivalent at the
/// contract level (same shapes, same staging layout); see
/// `rust/src/forward/native.rs` for the numerical-parity notes between the
/// graph and native paths.
pub trait ModelExecutor: Send + Sync {
    /// Short name for metrics / logs ("pjrt" / "native").
    fn name(&self) -> &'static str;

    /// Prefill `tokens` (length 1..=max_seq).  Prefill-graph semantics:
    /// causal attention over the *fake-quantized* K/V including the self
    /// token; returned K/V are raw.
    fn prefill(&self, tokens: &[u16]) -> Result<Prefilled>;

    /// One batched decode step over all `decode_batch` lanes.  Decode-graph
    /// semantics: quantized (or fp) staging history per lane plus the new
    /// token's K/V as a full-precision softmax tail.  Returns
    /// `(logits (B, vocab), k_new, v_new (L, B, d_kv))`.
    fn decode(&self, tokens: &[i32], cur_lens: &[i32], staging: &DecodeStaging)
              -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)>;

    /// Process `tokens` at true positions `start_pos..start_pos+T` for slot
    /// `slot`, with decode-step semantics per token (history + fp tail),
    /// quantizing each token's K/V into the slot's staging lane at `kv_bits`
    /// as it goes.  The caller appends the returned raw K/V to the paged
    /// cache afterwards.
    fn prefill_chunk(&self, tokens: &[u16], start_pos: usize, slot: usize,
                     kv_bits: u32, staging: &mut DecodeStaging)
                     -> Result<ChunkResult>;
}

/// Which [`ModelExecutor`] implementation serves requests
/// (`--executor` flag).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecutorKind {
    /// AOT-compiled PJRT graphs (the original path).
    Pjrt,
    /// Pure-rust forward pass over the compute backend; no graphs loaded.
    Native,
}

impl ExecutorKind {
    /// Parse a `--executor` flag value.
    pub fn parse(s: &str) -> Result<ExecutorKind> {
        match s {
            "pjrt" => Ok(ExecutorKind::Pjrt),
            "native" => Ok(ExecutorKind::Native),
            other => bail!("unknown executor '{other}' (expected pjrt|native)"),
        }
    }

    /// The wire/metrics name ("pjrt" / "native").
    pub fn name(self) -> &'static str {
        match self {
            ExecutorKind::Pjrt => "pjrt",
            ExecutorKind::Native => "native",
        }
    }
}

/// Quantize (or copy, on the fp path) one freshly computed K/V token row
/// into slot `slot`'s staging lane at position `t`, for one layer.
///
/// Bit-identical to the `SeqCache::write_token` → `stage_token` round-trip
/// the old token-at-a-time suffix loop performed: both call
/// [`crate::quant::kv::quant_slab`] on the same raw row (nibble pack +
/// sign-extending unpack are exact), so chunked prefill leaves the staging
/// lane byte-for-byte as the old path did.
#[allow(clippy::too_many_arguments)]
pub fn stage_kv_row(staging: &mut DecodeStaging, cfg: &ModelConfig, layer: usize,
                    slot: usize, t: usize, bits: u32, clip: f32, fp: bool,
                    k_row: &[f32], v_row: &[f32]) {
    let (b, s) = (cfg.decode_batch, cfg.cache_seq);
    let d = cfg.d_kv();
    let ng = d / cfg.kv_group;
    let co = ((layer * b + slot) * s + t) * d;
    if fp {
        staging.k_f32[co..co + d].copy_from_slice(k_row);
        staging.v_f32[co..co + d].copy_from_slice(v_row);
        return;
    }
    let go = ((layer * b + slot) * s + t) * ng;
    let (kc, ks, kz) = kv::quant_slab(k_row, d, cfg.kv_group, bits, clip);
    staging.k_codes[co..co + d].copy_from_slice(&kc);
    staging.k_scale[go..go + ng].copy_from_slice(&ks);
    staging.k_zero[go..go + ng].copy_from_slice(&kz);
    let (vc, vs, vz) = kv::quant_slab(v_row, d, cfg.kv_group, bits, clip);
    staging.v_codes[co..co + d].copy_from_slice(&vc);
    staging.v_scale[go..go + ng].copy_from_slice(&vs);
    staging.v_zero[go..go + ng].copy_from_slice(&vz);
}

/// [`stage_kv_row`] over a whole decode-step `(L, B, d_kv)` K/V slab:
/// stages every layer of slot `slot`'s new token at position `t`.
#[allow(clippy::too_many_arguments)]
pub fn stage_kv_token(staging: &mut DecodeStaging, cfg: &ModelConfig, slot: usize,
                      t: usize, bits: u32, clip: f32, fp: bool,
                      k_new: &[f32], v_new: &[f32]) {
    let b = cfg.decode_batch;
    let d = cfg.d_kv();
    for l in 0..cfg.n_layers {
        let o = (l * b + slot) * d;
        stage_kv_row(staging, cfg, l, slot, t, bits, clip, fp,
                     &k_new[o..o + d], &v_new[o..o + d]);
    }
}
