//! Attention kernels for the native executor.
//!
//! The decode graphs (and the python reference `kv_decode_attention`) score
//! the new token's K/V in **full precision** alongside the quantized cache:
//! the softmax jointly covers cache positions `0..len` plus the self token
//! at index `len`, whose K/V never round-trip through the codec.  The
//! batcher's `staged_decode_attention` kernels cover cached positions only,
//! so the native executor needs the tail-augmented variants here — the same
//! fused-dequant inner loops as `attention::decode_head_quant`
//! (`q·deq(c) = scale·(q·c) + zero·Σq`), with the fp self-token folded into
//! the same online softmax.  Quantizing the self token into the lane first
//! and attending over `len + 1` cached rows would *not* be equivalent: the
//! graph's tail is exact, the cache is not.
//!
//! [`causal_prefill`] is the prefill-graph counterpart: plain f32 causal
//! attention over K/V the caller has already fake-quantized (the prefill
//! graphs run `kv_fake_quant` on the whole sequence, self token included).

use crate::attention::{unpack_nibble_pair, KvCodes, KvF32View, KvQuantView};

/// One decode step for all `n_heads` of one sequence over a group-quantized
/// KV view, with the new token's raw `k_tail`/`v_tail` (`d_kv` each) as a
/// full-precision softmax tail.  `q` is `d_attn` long; `out` receives
/// `d_attn`.  An empty cache degenerates to attending over the tail alone
/// (`out = v_tail` per head).
pub fn decode_tail_quant(q: &[f32], k: &KvQuantView<'_>, v: &KvQuantView<'_>,
                         n_heads: usize, k_tail: &[f32], v_tail: &[f32],
                         out: &mut [f32]) {
    let (hk, dh, group) = (k.n_kv_heads, k.d_head, k.group);
    let d = hk * dh;
    let rep = n_heads / hk;
    let sm = 1.0 / (dh as f32).sqrt();
    let groups_per_tok = d / group;
    let gh = dh / group;
    let s = k.len;
    let mut scores = vec![0.0f32; s];
    let mut qsum = vec![0.0f32; gh];
    let mut zacc = vec![0.0f32; gh];
    for h in 0..n_heads {
        let kvh = h / rep;
        let qh = &q[h * dh..(h + 1) * dh];
        let kt = &k_tail[kvh * dh..(kvh + 1) * dh];
        let vt = &v_tail[kvh * dh..(kvh + 1) * dh];
        let oh = &mut out[h * dh..(h + 1) * dh];
        for (dst, g) in qsum.iter_mut().zip(qh.chunks_exact(group)) {
            *dst = g.iter().sum();
        }
        // score pass: fused dequant over the cache, then the fp tail
        let mut tail = 0.0f32;
        for i in 0..dh {
            tail += qh[i] * kt[i];
        }
        tail *= sm;
        let mut mx = tail;
        for (t, sc_out) in scores.iter_mut().enumerate() {
            let base = t * d + kvh * dh;
            let gbase = t * groups_per_tok + kvh * gh;
            let mut sc = 0.0f32;
            for gi in 0..gh {
                let scale = k.scales[gbase + gi];
                let zero = k.zeros[gbase + gi];
                let goff = gi * group;
                let mut dot = 0.0f32;
                match k.codes {
                    KvCodes::Packed4(codes) => {
                        let cb = (base + goff) / 2;
                        for (j, &byte) in codes[cb..cb + group / 2].iter()
                            .enumerate() {
                            let (lo, hi) = unpack_nibble_pair(byte);
                            dot += qh[goff + 2 * j] * lo
                                 + qh[goff + 2 * j + 1] * hi;
                        }
                    }
                    KvCodes::I8(codes) => {
                        let cb = base + goff;
                        for (j, &c) in codes[cb..cb + group].iter().enumerate() {
                            dot += qh[goff + j] * c as f32;
                        }
                    }
                }
                sc += scale * dot + zero * qsum[gi];
            }
            let sc = sc * sm;
            *sc_out = sc;
            mx = mx.max(sc);
        }
        // value pass: cache contribution with the zero-point accumulator,
        // then the fp tail, one joint softmax denominator
        let p_tail = (tail - mx).exp();
        let mut denom = p_tail;
        oh.fill(0.0);
        zacc.fill(0.0);
        for (t, &sc) in scores.iter().enumerate() {
            let p = (sc - mx).exp();
            denom += p;
            let base = t * d + kvh * dh;
            let gbase = t * groups_per_tok + kvh * gh;
            for gi in 0..gh {
                let ps = p * v.scales[gbase + gi];
                zacc[gi] += p * v.zeros[gbase + gi];
                let goff = gi * group;
                match v.codes {
                    KvCodes::Packed4(codes) => {
                        let cb = (base + goff) / 2;
                        for (j, &byte) in codes[cb..cb + group / 2].iter()
                            .enumerate() {
                            let (lo, hi) = unpack_nibble_pair(byte);
                            oh[goff + 2 * j] += ps * lo;
                            oh[goff + 2 * j + 1] += ps * hi;
                        }
                    }
                    KvCodes::I8(codes) => {
                        let cb = base + goff;
                        for (j, &c) in codes[cb..cb + group].iter().enumerate() {
                            oh[goff + j] += ps * c as f32;
                        }
                    }
                }
            }
        }
        let inv = 1.0 / denom;
        for (i, o) in oh.iter_mut().enumerate() {
            let gi = i / group;
            *o = (*o + zacc[gi] + p_tail * vt[i]) * inv;
        }
    }
}

/// [`decode_tail_quant`] over raw f32 KV streams (fp16-baseline staging).
pub fn decode_tail_f32(q: &[f32], k: &KvF32View<'_>, v: &KvF32View<'_>,
                       n_heads: usize, k_tail: &[f32], v_tail: &[f32],
                       out: &mut [f32]) {
    let (hk, dh) = (k.n_kv_heads, k.d_head);
    let rep = n_heads / hk;
    let sm = 1.0 / (dh as f32).sqrt();
    let s = k.len;
    let mut scores = vec![0.0f32; s];
    for h in 0..n_heads {
        let kvh = h / rep;
        let qh = &q[h * dh..(h + 1) * dh];
        let kt = &k_tail[kvh * dh..(kvh + 1) * dh];
        let vt = &v_tail[kvh * dh..(kvh + 1) * dh];
        let oh = &mut out[h * dh..(h + 1) * dh];
        let mut tail = 0.0f32;
        for i in 0..dh {
            tail += qh[i] * kt[i];
        }
        tail *= sm;
        let mut mx = tail;
        for (t, sc_out) in scores.iter_mut().enumerate() {
            let krow = &k.data[(t * hk + kvh) * dh..][..dh];
            let mut dot = 0.0f32;
            for i in 0..dh {
                dot += qh[i] * krow[i];
            }
            let sc = dot * sm;
            *sc_out = sc;
            mx = mx.max(sc);
        }
        let p_tail = (tail - mx).exp();
        let mut denom = p_tail;
        oh.fill(0.0);
        for (t, &sc) in scores.iter().enumerate() {
            let p = (sc - mx).exp();
            denom += p;
            let vrow = &v.data[(t * hk + kvh) * dh..][..dh];
            for i in 0..dh {
                oh[i] += p * vrow[i];
            }
        }
        let inv = 1.0 / denom;
        for (i, o) in oh.iter_mut().enumerate() {
            *o = (*o + p_tail * vt[i]) * inv;
        }
    }
}

/// Causal f32 attention over a whole prompt (prefill-graph semantics).
///
/// `q` is `(S, d_attn)`; `k`/`v` are `(S, d_kv)` token rows the caller has
/// already fake-quantized (or left raw on the fp path).  Row `i` attends
/// to positions `0..=i` (self included).  `out` receives `(S, d_attn)`.
#[allow(clippy::too_many_arguments)]
pub fn causal_prefill(q: &[f32], k: &[f32], v: &[f32], s: usize,
                      n_heads: usize, n_kv_heads: usize, d_head: usize,
                      out: &mut [f32]) {
    let d_attn = n_heads * d_head;
    let d_kv = n_kv_heads * d_head;
    let rep = n_heads / n_kv_heads;
    let sm = 1.0 / (d_head as f32).sqrt();
    let mut scores = vec![0.0f32; s];
    for i in 0..s {
        for h in 0..n_heads {
            let kvh = h / rep;
            let qh = &q[i * d_attn + h * d_head..][..d_head];
            let oh = &mut out[i * d_attn + h * d_head..][..d_head];
            let mut mx = f32::MIN;
            for (j, sc_out) in scores[..=i].iter_mut().enumerate() {
                let krow = &k[j * d_kv + kvh * d_head..][..d_head];
                let mut dot = 0.0f32;
                for e in 0..d_head {
                    dot += qh[e] * krow[e];
                }
                let sc = dot * sm;
                *sc_out = sc;
                mx = mx.max(sc);
            }
            let mut denom = 0.0f32;
            oh.fill(0.0);
            for (j, &sc) in scores[..=i].iter().enumerate() {
                let p = (sc - mx).exp();
                denom += p;
                let vrow = &v[j * d_kv + kvh * d_head..][..d_head];
                for e in 0..d_head {
                    oh[e] += p * vrow[e];
                }
            }
            let inv = 1.0 / denom;
            for o in oh.iter_mut() {
                *o *= inv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{DecodeScratch, DecodeQuantSeq, KvQuantView};
    use crate::quant::kv;
    use crate::util::prng::Rng;

    // With the tail score pushed to -inf (impossible via a real dot, so we
    // instead compare against a cache that *contains* the tail token in
    // quantized form at 16-wide precision), the fused kernel must agree
    // with a straightforward dequant-then-softmax oracle.
    fn oracle_tail_quant(q: &[f32], k: &KvQuantView<'_>, v: &KvQuantView<'_>,
                         n_heads: usize, k_tail: &[f32], v_tail: &[f32],
                         out: &mut [f32]) {
        let (hk, dh, group) = (k.n_kv_heads, k.d_head, k.group);
        let d = hk * dh;
        let s = k.len;
        let mut kd = vec![0.0f32; s * d];
        let mut vd = vec![0.0f32; s * d];
        if let (KvCodes::I8(kc), KvCodes::I8(vc)) = (&k.codes, &v.codes) {
            for g in 0..s * d / group {
                kv::dequant_group(&kc[g * group..(g + 1) * group], k.scales[g],
                                  k.zeros[g], &mut kd[g * group..(g + 1) * group]);
                kv::dequant_group(&vc[g * group..(g + 1) * group], v.scales[g],
                                  v.zeros[g], &mut vd[g * group..(g + 1) * group]);
            }
        } else {
            panic!("oracle expects unpacked codes");
        }
        let rep = n_heads / hk;
        let sm = 1.0 / (dh as f32).sqrt();
        for h in 0..n_heads {
            let kvh = h / rep;
            let qh = &q[h * dh..(h + 1) * dh];
            let oh = &mut out[h * dh..(h + 1) * dh];
            let mut scores: Vec<f32> = (0..s).map(|t| {
                let kr = &kd[t * d + kvh * dh..][..dh];
                qh.iter().zip(kr).map(|(a, b)| a * b).sum::<f32>() * sm
            }).collect();
            let kt = &k_tail[kvh * dh..(kvh + 1) * dh];
            scores.push(qh.iter().zip(kt).map(|(a, b)| a * b).sum::<f32>() * sm);
            let mx = scores.iter().fold(f32::MIN, |m, &x| m.max(x));
            let ps: Vec<f32> = scores.iter().map(|&x| (x - mx).exp()).collect();
            let denom: f32 = ps.iter().sum();
            oh.fill(0.0);
            for (t, &p) in ps[..s].iter().enumerate() {
                let vr = &vd[t * d + kvh * dh..][..dh];
                for i in 0..dh {
                    oh[i] += p * vr[i];
                }
            }
            let vt = &v_tail[kvh * dh..(kvh + 1) * dh];
            for i in 0..dh {
                oh[i] = (oh[i] + ps[s] * vt[i]) / denom;
            }
        }
    }

    #[test]
    fn tail_quant_matches_dequant_oracle() {
        let (hk, nh, dh, group, s) = (2usize, 4usize, 8usize, 4usize, 5usize);
        let d = hk * dh;
        let mut rng = Rng::new(7);
        let raw_k = rng.normal_vec(s * d);
        let raw_v = rng.normal_vec(s * d);
        let (kc, ksc, kz) = kv::quant_slab(&raw_k, d, group, 4, 0.95);
        let (vc, vsc, vz) = kv::quant_slab(&raw_v, d, group, 4, 0.95);
        let kview = KvQuantView { n_kv_heads: hk, d_head: dh, group, len: s,
                                  codes: KvCodes::I8(&kc), scales: &ksc, zeros: &kz };
        let vview = KvQuantView { n_kv_heads: hk, d_head: dh, group, len: s,
                                  codes: KvCodes::I8(&vc), scales: &vsc, zeros: &vz };
        let q = rng.normal_vec(nh * dh);
        let k_tail = rng.normal_vec(d);
        let v_tail = rng.normal_vec(d);
        let mut got = vec![0.0f32; nh * dh];
        let mut want = vec![0.0f32; nh * dh];
        decode_tail_quant(&q, &kview, &vview, nh, &k_tail, &v_tail, &mut got);
        oracle_tail_quant(&q, &kview, &vview, nh, &k_tail, &v_tail, &mut want);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "fused {a} vs oracle {b}");
        }
    }

    #[test]
    fn empty_cache_returns_tail_value() {
        let (hk, nh, dh, group) = (2usize, 4usize, 8usize, 4usize);
        let d = hk * dh;
        let mut rng = Rng::new(9);
        let q = rng.normal_vec(nh * dh);
        let k_tail = rng.normal_vec(d);
        let v_tail = rng.normal_vec(d);
        let kview = KvQuantView { n_kv_heads: hk, d_head: dh, group, len: 0,
                                  codes: KvCodes::I8(&[]), scales: &[], zeros: &[] };
        let vview = KvQuantView { n_kv_heads: hk, d_head: dh, group, len: 0,
                                  codes: KvCodes::I8(&[]), scales: &[], zeros: &[] };
        let mut out = vec![0.0f32; nh * dh];
        decode_tail_quant(&q, &kview, &vview, nh, &k_tail, &v_tail, &mut out);
        let rep = nh / hk;
        for h in 0..nh {
            let kvh = h / rep;
            for i in 0..dh {
                let want = v_tail[kvh * dh + i];
                let got = out[h * dh + i];
                assert!((got - want).abs() < 1e-6, "softmax over the tail \
                         alone must return the tail value: {got} vs {want}");
            }
        }
    }

    // When the tail has already been quantized *into* the cache, the
    // cache-only kernel over len+1 rows is a different computation than the
    // fp-tail kernel over len rows + tail — the whole reason these kernels
    // exist.  Sanity-check they agree loosely (the codec error bounds the
    // difference) but are not the identical computation.
    #[test]
    fn fp_tail_tracks_quantized_tail() {
        let (hk, nh, dh, group, s) = (2usize, 2usize, 8usize, 4usize, 6usize);
        let d = hk * dh;
        let mut rng = Rng::new(11);
        let raw_k = rng.normal_vec((s + 1) * d);
        let raw_v = rng.normal_vec((s + 1) * d);
        let (kc, ksc, kz) = kv::quant_slab(&raw_k, d, group, 8, 1.0);
        let (vc, vsc, vz) = kv::quant_slab(&raw_v, d, group, 8, 1.0);
        let q = rng.normal_vec(nh * dh);
        // fp-tail over the first s rows + raw tail
        let kview = KvQuantView { n_kv_heads: hk, d_head: dh, group, len: s,
                                  codes: KvCodes::I8(&kc[..s * d]),
                                  scales: &ksc[..s * d / group],
                                  zeros: &kz[..s * d / group] };
        let vview = KvQuantView { n_kv_heads: hk, d_head: dh, group, len: s,
                                  codes: KvCodes::I8(&vc[..s * d]),
                                  scales: &vsc[..s * d / group],
                                  zeros: &vz[..s * d / group] };
        let mut with_tail = vec![0.0f32; nh * dh];
        decode_tail_quant(&q, &kview, &vview, nh,
                          &raw_k[s * d..], &raw_v[s * d..], &mut with_tail);
        // cache-only kernel over all s+1 quantized rows
        let kfull = KvQuantView { n_kv_heads: hk, d_head: dh, group, len: s + 1,
                                  codes: KvCodes::I8(&kc), scales: &ksc, zeros: &kz };
        let vfull = KvQuantView { n_kv_heads: hk, d_head: dh, group, len: s + 1,
                                  codes: KvCodes::I8(&vc), scales: &vsc, zeros: &vz };
        let seq = DecodeQuantSeq { q: &q, k: kfull, v: vfull };
        let mut quantized = vec![0.0f32; nh * dh];
        crate::attention::decode_seq_quant_ref(&seq, nh, &mut quantized,
                                               &mut DecodeScratch::default());
        for (a, b) in with_tail.iter().zip(&quantized) {
            assert!((a - b).abs() < 0.05, "fp tail should track 8-bit \
                     quantized tail closely: {a} vs {b}");
        }
    }
}
