//! Native weight preparation: pack the spec-quantized model weights into
//! the [`crate::gemm`] containers the `ComputeBackend` GEMMs consume.
//!
//! Parity contract with the graph path: the compiled graphs are handed
//! *fake-quantized* f32 weights (`prepare_weights`) and multiply them
//! against fake-quantized activations in f32.  The native path must
//! compute on the **same weight grid**:
//!
//! * For the flagship per-channel symmetric RTN specs (QuaRot's W4A4 /
//!   W8A8), [`crate::quant::rtn::quant_weight_int_searched`] re-derives
//!   the exact clip-searched integer codes + scales, so the int4/int8
//!   GEMM kernels compute `Σ qx·qw · sx·sw` on precisely the values the
//!   graph saw — a true integer path, not a second lossy quantization.
//!   (`WeightsI8::quantize`'s full-range grid would *shift* every weight
//!   by `levels/(levels+0.5)`; never re-quantize prepared weights.)
//! * Every other weight scheme (GPTQ, grouped/asymmetric RTN,
//!   SmoothQuant folds, FP16) falls back to the prepared f32 matrices
//!   with explicit activation fake-quant before an f32 GEMM — exactly the
//!   graph's arithmetic for all spec combinations.
//!
//! QUIK outlier masks (`spec.outliers > 0`) are a baseline-graph-only
//! feature and are rejected at construction.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::backend::ComputeBackend;
use crate::coordinator::runner::{prepare_weights, QuantSpec, WeightQuant};
use crate::gemm::{WeightsF32, WeightsI4, WeightsI8};
use crate::model::{ModelConfig, Weights};
use crate::quant::rtn;
use crate::tensor::Mat;

/// One projection weight in whichever container the spec maps to.
pub enum ProjWeight {
    /// f32 columns; `quant_acts` replays the graph's activation
    /// fake-quant before the GEMM (false on the FP16 path).
    F32 {
        /// Column-major f32 weight.
        w: WeightsF32,
        /// Fake-quantize activation rows before multiplying.
        quant_acts: bool,
    },
    /// int8 codes on the exact clip-searched RTN grid.
    I8(WeightsI8),
    /// nibble-packed int4 codes on the exact clip-searched RTN grid.
    I4(WeightsI4),
}

impl ProjWeight {
    /// `y (t×n) = quant_site(x) @ W` through the backend: the integer
    /// containers quantize activations inside the fused GEMM; the f32
    /// container fake-quantizes explicitly (when `quant_acts`) then runs
    /// the f32 GEMM.
    pub fn apply(&self, backend: &dyn ComputeBackend, x: &[f32], t: usize,
                 act_bits: u32, act_clip: f32, y: &mut [f32]) {
        match self {
            ProjWeight::F32 { w, quant_acts } => {
                if *quant_acts && act_bits > 0 {
                    let d = w.k;
                    let mut codes = vec![0i8; t * d];
                    let mut scales = vec![0.0f32; t];
                    backend.quant_rows(x, d, act_bits, act_clip,
                                       &mut codes, &mut scales);
                    let mut xq = vec![0.0f32; t * d];
                    for r in 0..t {
                        let s = scales[r];
                        for i in 0..d {
                            xq[r * d + i] = codes[r * d + i] as f32 * s;
                        }
                    }
                    backend.gemm_f32(&xq, t, w, y);
                } else {
                    backend.gemm_f32(x, t, w, y);
                }
            }
            ProjWeight::I8(w) => backend.gemm_i8(x, t, w, act_bits, act_clip, y),
            ProjWeight::I4(w) => backend.gemm_i4(x, t, w, act_clip, y),
        }
    }

    /// Container memory footprint (weight bytes + scales).
    pub fn bytes(&self) -> usize {
        match self {
            ProjWeight::F32 { w, .. } => w.bytes(),
            ProjWeight::I8(w) => w.bytes(),
            ProjWeight::I4(w) => w.bytes(),
        }
    }
}

/// Per-layer packed projection weights + folded norm gammas.
pub struct LayerWeights {
    /// Pre-attention RMSNorm gamma (ones after rotation folding).
    pub attn_norm: Vec<f32>,
    /// Pre-FFN RMSNorm gamma.
    pub ffn_norm: Vec<f32>,
    /// Query projection `(d_model, d_attn)`.
    pub wq: ProjWeight,
    /// Key projection `(d_model, d_kv)`.
    pub wk: ProjWeight,
    /// Value projection `(d_model, d_kv)`.
    pub wv: ProjWeight,
    /// Attention output projection `(d_attn, d_model)`.
    pub wo: ProjWeight,
    /// FFN up projection `(d_model, d_ff)`.
    pub wup: ProjWeight,
    /// FFN gate projection `(d_model, d_ff)`.
    pub wgate: ProjWeight,
    /// FFN down projection `(d_ff, d_model)`.
    pub wdown: ProjWeight,
}

/// The whole model, packed for the native executor.
pub struct NativeWeights {
    /// Embedding table, row-major `(vocab, d_model)`, always f32.
    pub embed: Vec<f32>,
    /// Final RMSNorm gamma.
    pub final_norm: Vec<f32>,
    /// LM head `(d_model, vocab)`, always f32 (never activation-quantized).
    pub lm_head: WeightsF32,
    /// Per-layer projections.
    pub layers: Vec<LayerWeights>,
}

/// The canonical weight-name set every archive variant carries — the
/// manifest `weight_order` for artifact-backed models, and the order the
/// artifact-free test constructors use.
pub fn canonical_weight_order() -> Vec<String> {
    ["embed", "final_norm", "lm_head", "attn_norm", "wq", "wk", "wv", "wo",
     "ffn_norm", "wup", "wgate", "wdown"]
        .iter().map(|s| s.to_string()).collect()
}

/// Row/col shape of each per-layer projection.
fn proj_shape(cfg: &ModelConfig, name: &str) -> (usize, usize) {
    let (d, da, dkv, dff) = (cfg.d_model, cfg.d_attn(), cfg.d_kv(), cfg.d_ff);
    match name {
        "wq" => (d, da),
        "wk" | "wv" => (d, dkv),
        "wo" => (da, d),
        "wup" | "wgate" => (d, dff),
        "wdown" => (dff, d),
        other => panic!("not a projection: {other}"),
    }
}

const PROJ_NAMES: [&str; 7] = ["wq", "wk", "wv", "wo", "wup", "wgate", "wdown"];

impl NativeWeights {
    /// Quantize + pack the archive per `spec`.  `order` is the manifest
    /// weight order (which names exist); `stats` feeds GPTQ/SmoothQuant
    /// like the graph path.
    pub fn build(cfg: &ModelConfig, order: &[String], weights: &Weights,
                 spec: &QuantSpec,
                 stats: Option<&crate::coordinator::runner::CalibStats>)
                 -> Result<NativeWeights> {
        if spec.outliers > 0 {
            bail!("native executor does not support QUIK outlier masks \
                   (baseline graph only)");
        }
        let int_grid = match &spec.weights {
            WeightQuant::Rtn(q) => {
                (q.symmetric && q.group == 0 && !spec.smooth
                 && (1..=8).contains(&spec.act_bits))
                    .then_some(*q)
            }
            _ => None,
        };
        if let Some(qcfg) = int_grid {
            Self::build_int(cfg, order, weights, spec, qcfg)
        } else {
            Self::build_f32(cfg, order, weights, spec, stats)
        }
    }

    /// Integer containers on the exact clip-searched RTN grid
    /// (per-channel symmetric RTN, no smooth fold, quantized acts).
    fn build_int(cfg: &ModelConfig, order: &[String], weights: &Weights,
                 spec: &QuantSpec, qcfg: rtn::WeightQuantCfg)
                 -> Result<NativeWeights> {
        let prefix = spec.variant.weight_prefix();
        let load = |name: &str| -> Result<Vec<f32>> {
            Ok(weights.get(&format!("{prefix}{name}"))?.as_f32())
        };
        for name in PROJ_NAMES {
            if !order.iter().any(|n| n == name) {
                bail!("weight order missing '{name}'");
            }
        }
        let pack = |m: &Mat| -> ProjWeight {
            let (codes, scales) = rtn::quant_weight_int_searched(m, &qcfg);
            if spec.act_bits == 4 && qcfg.bits == 4 {
                let kp = m.rows.div_ceil(2);
                let mut cols = vec![0u8; kp * m.cols];
                for c in 0..m.cols {
                    let col = &codes[c * m.rows..(c + 1) * m.rows];
                    for (i, pair) in col.chunks(2).enumerate() {
                        let lo = (pair[0] as u8) & 0x0F;
                        let hi = if pair.len() > 1 { (pair[1] as u8) & 0x0F }
                                 else { 0 };
                        cols[c * kp + i] = lo | (hi << 4);
                    }
                }
                ProjWeight::I4(WeightsI4 { k: m.rows, n: m.cols, cols, scales })
            } else {
                ProjWeight::I8(WeightsI8 { k: m.rows, n: m.cols,
                                           cols: codes, scales })
            }
        };
        let mut projs: BTreeMap<&str, Vec<ProjWeight>> = BTreeMap::new();
        for name in PROJ_NAMES {
            let (r, c) = proj_shape(cfg, name);
            let flat = load(name)?;
            let per: Vec<ProjWeight> = (0..cfg.n_layers).map(|l| {
                let m = Mat::from_vec(r, c,
                                      flat[l * r * c..(l + 1) * r * c].to_vec());
                pack(&m)
            }).collect();
            projs.insert(name, per);
        }
        Self::assemble(cfg, load("embed")?, load("final_norm")?,
                       load("lm_head")?, load("attn_norm")?,
                       load("ffn_norm")?, projs)
    }

    /// Fallback: run the graph path's `prepare_weights` verbatim and wrap
    /// the fake-quantized f32 matrices, replaying activation fake-quant
    /// explicitly — graph arithmetic for every spec combination.
    fn build_f32(cfg: &ModelConfig, order: &[String], weights: &Weights,
                 spec: &QuantSpec,
                 stats: Option<&crate::coordinator::runner::CalibStats>)
                 -> Result<NativeWeights> {
        let prepared = prepare_weights(cfg, order, weights, spec, stats)?;
        let by_name: BTreeMap<&str, &[f32]> = order.iter()
            .zip(&prepared)
            .map(|(n, t)| (n.as_str(), t.f32()))
            .collect();
        let get = |name: &str| -> Result<Vec<f32>> {
            Ok(by_name.get(name)
                .with_context(|| format!("weight order missing '{name}'"))?
                .to_vec())
        };
        let quant_acts = spec.act_bits > 0;
        let mut projs: BTreeMap<&str, Vec<ProjWeight>> = BTreeMap::new();
        for name in PROJ_NAMES {
            let (r, c) = proj_shape(cfg, name);
            let flat = get(name)?;
            let per: Vec<ProjWeight> = (0..cfg.n_layers).map(|l| {
                ProjWeight::F32 {
                    w: WeightsF32::from_row_major(
                        &flat[l * r * c..(l + 1) * r * c], r, c),
                    quant_acts,
                }
            }).collect();
            projs.insert(name, per);
        }
        Self::assemble(cfg, get("embed")?, get("final_norm")?,
                       get("lm_head")?, get("attn_norm")?,
                       get("ffn_norm")?, projs)
    }

    fn assemble(cfg: &ModelConfig, embed: Vec<f32>, final_norm: Vec<f32>,
                lm_head: Vec<f32>, attn_norm: Vec<f32>, ffn_norm: Vec<f32>,
                mut projs: BTreeMap<&str, Vec<ProjWeight>>)
                -> Result<NativeWeights> {
        let d = cfg.d_model;
        if embed.len() != cfg.vocab * d {
            bail!("embed shape mismatch: {} != {}", embed.len(), cfg.vocab * d);
        }
        let mut take = |name: &str| -> Vec<ProjWeight> {
            projs.remove(name).expect("packed above")
        };
        let (mut wq, mut wk, mut wv, mut wo) =
            (take("wq"), take("wk"), take("wv"), take("wo"));
        let (mut wup, mut wgate, mut wdown) =
            (take("wup"), take("wgate"), take("wdown"));
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for l in (0..cfg.n_layers).rev() {
            layers.push(LayerWeights {
                attn_norm: attn_norm[l * d..(l + 1) * d].to_vec(),
                ffn_norm: ffn_norm[l * d..(l + 1) * d].to_vec(),
                wq: wq.pop().expect("layer count"),
                wk: wk.pop().expect("layer count"),
                wv: wv.pop().expect("layer count"),
                wo: wo.pop().expect("layer count"),
                wup: wup.pop().expect("layer count"),
                wgate: wgate.pop().expect("layer count"),
                wdown: wdown.pop().expect("layer count"),
            });
        }
        layers.reverse();
        Ok(NativeWeights {
            embed,
            final_norm,
            lm_head: WeightsF32::from_row_major(&lm_head, d, cfg.vocab),
            layers,
        })
    }

    /// Total packed weight bytes (embed + head + projections + norms).
    pub fn bytes(&self) -> usize {
        let mut b = (self.embed.len() + self.final_norm.len()) * 4
            + self.lm_head.bytes();
        for l in &self.layers {
            b += (l.attn_norm.len() + l.ffn_norm.len()) * 4;
            for p in [&l.wq, &l.wk, &l.wv, &l.wo, &l.wup, &l.wgate, &l.wdown] {
                b += p.bytes();
            }
        }
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend;
    use crate::util::prng::Rng;

    // I4 and I8 containers built from the same searched codes must produce
    // bit-identical GEMM results: integer accumulation is order-exact, and
    // the epilogue is the same expression.
    #[test]
    fn i4_and_i8_containers_agree_bitwise() {
        let mut rng = Rng::new(3);
        let (k, n, t) = (16usize, 6usize, 3usize);
        let m = Mat::randn(k, n, &mut rng);
        let qcfg = rtn::WeightQuantCfg::rtn(4);
        let (codes, scales) = rtn::quant_weight_int_searched(&m, &qcfg);
        let i8w = WeightsI8 { k, n, cols: codes.clone(), scales: scales.clone() };
        let kp = k.div_ceil(2);
        let mut cols = vec![0u8; kp * n];
        for c in 0..n {
            let col = &codes[c * k..(c + 1) * k];
            for (i, pair) in col.chunks(2).enumerate() {
                cols[c * kp + i] = ((pair[0] as u8) & 0x0F)
                    | (((pair[1] as u8) & 0x0F) << 4);
            }
        }
        let i4w = WeightsI4 { k, n, cols, scales };
        let be = backend::make(backend::BackendKind::Scalar);
        let x = rng.normal_vec(t * k);
        let mut y8 = vec![0.0f32; t * n];
        let mut y4 = vec![0.0f32; t * n];
        be.gemm_i8(&x, t, &i8w, 4, 0.9, &mut y8);
        be.gemm_i4(&x, t, &i4w, 0.9, &mut y4);
        for (a, b) in y8.iter().zip(&y4) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
    }
}
