//! Native decode attention over f32 and quantized KV caches — the rust twin
//! of the paper's FlashInfer-based `Decode` routine (Appendix A.10,
//! Table 15) and the substrate for the memory table (Table 17).
//!
//! Layout per sequence: cache[s][h][dh] (token-major), matching the decode
//! graphs.  The quantized variant streams nibble-packed codes + per-group
//! scales and fuses dequantization into the score/value loops — the IO
//! reduction that makes the 4-bit cache win at large batch/long context.

use crate::quant::kv;

/// f32 cache for one sequence: the FP16-equivalent baseline.
pub struct CacheF32 {
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub data: Vec<f32>, // s * h * dh, appended per token
    pub len: usize,
}

impl CacheF32 {
    pub fn new(n_kv_heads: usize, d_head: usize, capacity: usize) -> Self {
        CacheF32 {
            n_kv_heads,
            d_head,
            data: Vec::with_capacity(capacity * n_kv_heads * d_head),
            len: 0,
        }
    }

    pub fn append(&mut self, kv_token: &[f32]) {
        assert_eq!(kv_token.len(), self.n_kv_heads * self.d_head);
        self.data.extend_from_slice(kv_token);
        self.len += 1;
    }

    pub fn bytes(&self) -> usize {
        // report fp16-equivalent (the paper's baseline is fp16)
        self.data.len() * 2
    }
}

/// Quantized cache for one sequence: nibble/byte-packed codes + group scales.
pub struct CacheQuant {
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub group: usize,
    pub bits: u32,
    pub codes: Vec<u8>, // packed
    pub scales: Vec<f32>,
    pub zeros: Vec<f32>,
    pub len: usize,
}

impl CacheQuant {
    pub fn new(n_kv_heads: usize, d_head: usize, group: usize, bits: u32) -> Self {
        assert!(bits == 4 || bits == 8, "packed cache supports 4/8 bits");
        CacheQuant {
            n_kv_heads,
            d_head,
            group,
            bits,
            codes: Vec::new(),
            scales: Vec::new(),
            zeros: Vec::new(),
            len: 0,
        }
    }

    /// Quantize + append one token's (h × dh) keys-or-values.
    pub fn append(&mut self, kv_token: &[f32], clip: f32) {
        let d = self.n_kv_heads * self.d_head;
        assert_eq!(kv_token.len(), d);
        let (codes, scales, zeros) = kv::quant_slab(kv_token, d, self.group,
                                                    self.bits, clip);
        if self.bits == 4 {
            self.codes.extend_from_slice(&kv::pack_nibbles(&codes));
        } else {
            self.codes.extend(codes.iter().map(|&c| c as u8));
        }
        self.scales.extend_from_slice(&scales);
        self.zeros.extend_from_slice(&zeros);
        self.len += 1;
    }

    pub fn bytes(&self) -> usize {
        self.codes.len() + (self.scales.len() + self.zeros.len()) * 4
    }

    /// Dequantize token s, head h into `out` (d_head values).
    pub fn dequant_head(&self, s: usize, h: usize, out: &mut [f32], scratch: &mut [i8]) {
        let d = self.n_kv_heads * self.d_head;
        let groups_per_tok = d / self.group;
        let tok_groups = s * groups_per_tok + h * (self.d_head / self.group);
        let start_code = s * d + h * self.d_head;
        let codes = &mut scratch[..self.d_head];
        if self.bits == 4 {
            // packed stream: codes for this head start at bit offset
            for (i, c) in codes.iter_mut().enumerate() {
                let idx = start_code + i;
                let byte = self.codes[idx / 2];
                let nib = if idx % 2 == 0 { byte & 0x0F } else { byte >> 4 };
                *c = ((nib << 4) as i8) >> 4;
            }
        } else {
            for (i, c) in codes.iter_mut().enumerate() {
                *c = self.codes[start_code + i] as i8;
            }
        }
        for (gi, chunk) in out.chunks_mut(self.group).enumerate() {
            let s_ = self.scales[tok_groups + gi];
            let z_ = self.zeros[tok_groups + gi];
            for (o, &c) in chunk.iter_mut().zip(&codes[gi * self.group..]) {
                *o = c as f32 * s_ + z_;
            }
        }
    }
}

/// One decode step over an f32 cache: q (H × dh) → out (H × dh).
/// GQA: `rep` q-heads share each kv-head.
pub fn decode_f32(q: &[f32], n_heads: usize, k: &CacheF32, v: &CacheF32,
                  out: &mut [f32], scores: &mut Vec<f32>) {
    let (hk, dh) = (k.n_kv_heads, k.d_head);
    let rep = n_heads / hk;
    let s = k.len;
    let sm = 1.0 / (dh as f32).sqrt();
    scores.resize(s, 0.0);
    for h in 0..n_heads {
        let kvh = h / rep;
        let qh = &q[h * dh..(h + 1) * dh];
        let mut mx = f32::MIN;
        for t in 0..s {
            let kt = &k.data[(t * hk + kvh) * dh..][..dh];
            let mut dot = 0.0f32;
            for i in 0..dh {
                dot += qh[i] * kt[i];
            }
            let sc = dot * sm;
            scores[t] = sc;
            mx = mx.max(sc);
        }
        let mut denom = 0.0f32;
        let oh = &mut out[h * dh..(h + 1) * dh];
        oh.fill(0.0);
        for t in 0..s {
            let p = (scores[t] - mx).exp();
            denom += p;
            let vt = &v.data[(t * hk + kvh) * dh..][..dh];
            for i in 0..dh {
                oh[i] += p * vt[i];
            }
        }
        let inv = 1.0 / denom;
        for o in oh {
            *o *= inv;
        }
    }
}

/// One decode step over a quantized cache (fused dequant + online softmax).
///
/// Perf notes (EXPERIMENTS.md §Perf): the naive version dequantized each
/// (token, head) into a buffer and then ran the dot — two passes and a
/// nibble-extract per element.  This version folds the affine dequant into
/// the reductions analytically:
///   q·deq(c)   = scale·(q·c) + zero·Σq            (score pass)
///   Σₜ pₜ·deq(cₜ) = Σₜ (pₜ·scaleₜ)·cₜ + (Σₜ pₜ·zeroₜ) (value pass)
/// so the inner loops touch each packed byte once and use integer-from-
/// nibble directly, with Σq precomputed per (head, group).
pub fn decode_quant(q: &[f32], n_heads: usize, k: &CacheQuant, v: &CacheQuant,
                    out: &mut [f32], scores: &mut Vec<f32>,
                    kbuf: &mut Vec<f32>, scratch: &mut Vec<i8>) {
    let (hk, dh) = (k.n_kv_heads, k.d_head);
    let rep = n_heads / hk;
    let s = k.len;
    let sm = 1.0 / (dh as f32).sqrt();
    let d = hk * dh;
    let groups_per_tok = d / k.group;
    let gh = dh / k.group; // groups per head
    scores.resize(s, 0.0);
    kbuf.resize(dh, 0.0);
    scratch.resize(dh, 0);
    for h in 0..n_heads {
        let kvh = h / rep;
        let qh = &q[h * dh..(h + 1) * dh];
        // per-group Σq for the zero-point correction
        let qsum: Vec<f32> = qh.chunks_exact(k.group)
            .map(|g| g.iter().sum()).collect();
        let mut mx = f32::MIN;
        for t in 0..s {
            let base = t * d + kvh * dh;
            let gbase = t * groups_per_tok + kvh * gh;
            let mut sc = 0.0f32;
            for gi in 0..gh {
                let scale = k.scales[gbase + gi];
                let zero = k.zeros[gbase + gi];
                let mut dot = 0.0f32;
                let goff = gi * k.group;
                if k.bits == 4 {
                    // packed stream: group starts nibble-aligned (group even)
                    let cb = (base + goff) / 2;
                    for (j, &byte) in k.codes[cb..cb + k.group / 2].iter()
                        .enumerate() {
                        let lo = (((byte & 0x0F) << 4) as i8 >> 4) as f32;
                        let hi = ((byte & 0xF0) as i8 >> 4) as f32;
                        dot += qh[goff + 2 * j] * lo + qh[goff + 2 * j + 1] * hi;
                    }
                } else {
                    let cb = base + goff;
                    for (j, &c) in k.codes[cb..cb + k.group].iter().enumerate() {
                        dot += qh[goff + j] * (c as i8) as f32;
                    }
                }
                sc += scale * dot + zero * qsum[gi];
            }
            let sc = sc * sm;
            scores[t] = sc;
            mx = mx.max(sc);
        }
        let mut denom = 0.0f32;
        let oh = &mut out[h * dh..(h + 1) * dh];
        oh.fill(0.0);
        let mut zacc = vec![0.0f32; gh]; // Σₜ pₜ·zeroₜ per group
        for t in 0..s {
            let p = (scores[t] - mx).exp();
            denom += p;
            let base = t * d + kvh * dh;
            let gbase = t * groups_per_tok + kvh * gh;
            for gi in 0..gh {
                let ps = p * v.scales[gbase + gi];
                zacc[gi] += p * v.zeros[gbase + gi];
                let goff = gi * v.group;
                if v.bits == 4 {
                    let cb = (base + goff) / 2;
                    for (j, &byte) in v.codes[cb..cb + v.group / 2].iter()
                        .enumerate() {
                        let lo = (((byte & 0x0F) << 4) as i8 >> 4) as f32;
                        let hi = ((byte & 0xF0) as i8 >> 4) as f32;
                        oh[goff + 2 * j] += ps * lo;
                        oh[goff + 2 * j + 1] += ps * hi;
                    }
                } else {
                    let cb = base + goff;
                    for (j, &c) in v.codes[cb..cb + v.group].iter().enumerate() {
                        oh[goff + j] += ps * (c as i8) as f32;
                    }
                }
            }
        }
        let inv = 1.0 / denom;
        for gi in 0..gh {
            for o in &mut oh[gi * v.group..(gi + 1) * v.group] {
                *o = (*o + zacc[gi]) * inv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prng::Rng, prop};

    fn fill_caches(s: usize, hk: usize, dh: usize, bits: u32, seed: u64)
                   -> (CacheF32, CacheF32, CacheQuant, CacheQuant) {
        let mut rng = Rng::new(seed);
        let mut kf = CacheF32::new(hk, dh, s);
        let mut vf = CacheF32::new(hk, dh, s);
        let mut kq = CacheQuant::new(hk, dh, dh, bits);
        let mut vq = CacheQuant::new(hk, dh, dh, bits);
        for _ in 0..s {
            let kt = rng.normal_vec(hk * dh);
            let vt = rng.normal_vec(hk * dh);
            kf.append(&kt);
            vf.append(&vt);
            kq.append(&kt, 1.0);
            vq.append(&vt, 1.0);
        }
        (kf, vf, kq, vq)
    }

    #[test]
    fn quant_cache_roundtrip() {
        let (kf, _, kq, _) = fill_caches(5, 2, 16, 8, 0);
        let mut buf = vec![0.0; 16];
        let mut scratch = vec![0i8; 16];
        for s in 0..5 {
            for h in 0..2 {
                kq.dequant_head(s, h, &mut buf, &mut scratch);
                let want = &kf.data[(s * 2 + h) * 16..][..16];
                prop::assert_close(&buf, want, 0.05).unwrap();
            }
        }
    }

    #[test]
    fn decode_quant_tracks_f32_at_8bit() {
        let (hk, dh, s, nh) = (2usize, 16usize, 12usize, 4usize);
        let (kf, vf, kq, vq) = fill_caches(s, hk, dh, 8, 1);
        let mut rng = Rng::new(9);
        let q = rng.normal_vec(nh * dh);
        let mut o0 = vec![0.0; nh * dh];
        let mut o1 = vec![0.0; nh * dh];
        decode_f32(&q, nh, &kf, &vf, &mut o0, &mut Vec::new());
        decode_quant(&q, nh, &kq, &vq, &mut o1, &mut Vec::new(),
                     &mut Vec::new(), &mut Vec::new());
        prop::assert_close(&o1, &o0, 0.06).unwrap();
    }

    #[test]
    fn decode_4bit_reasonable() {
        let (hk, dh, s, nh) = (2usize, 32usize, 20usize, 2usize);
        let (kf, vf, kq, vq) = fill_caches(s, hk, dh, 4, 2);
        let mut rng = Rng::new(10);
        let q = rng.normal_vec(nh * dh);
        let mut o0 = vec![0.0; nh * dh];
        let mut o1 = vec![0.0; nh * dh];
        decode_f32(&q, nh, &kf, &vf, &mut o0, &mut Vec::new());
        decode_quant(&q, nh, &kq, &vq, &mut o1, &mut Vec::new(),
                     &mut Vec::new(), &mut Vec::new());
        let scale = o0.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        prop::assert_close(&o1, &o0, 0.35 * scale.max(0.1)).unwrap();
    }

    #[test]
    fn softmax_normalized_output_in_hull() {
        // output is a convex combination of values → within [min, max] of V
        let (hk, dh, s, nh) = (1usize, 8usize, 6usize, 1usize);
        let (kf, vf, _, _) = fill_caches(s, hk, dh, 8, 3);
        let mut rng = Rng::new(11);
        let q = rng.normal_vec(nh * dh);
        let mut out = vec![0.0; nh * dh];
        decode_f32(&q, nh, &kf, &vf, &mut out, &mut Vec::new());
        for i in 0..dh {
            let col: Vec<f32> = (0..s).map(|t| vf.data[t * dh + i]).collect();
            let (mn, mx) = col.iter().fold((f32::MAX, f32::MIN),
                                           |(a, b), &v| (a.min(v), b.max(v)));
            assert!(out[i] >= mn - 1e-4 && out[i] <= mx + 1e-4);
        }
    }

    #[test]
    fn memory_saving_factor_matches_paper_shape() {
        // fp16 cache vs int4+scales: paper reports 3.6-3.9× (Table 17)
        let (hk, dh, s) = (8usize, 128usize, 2048usize);
        let mut kf = CacheF32::new(hk, dh, s);
        let mut kq = CacheQuant::new(hk, dh, 128, 4);
        let mut rng = Rng::new(4);
        for _ in 0..s {
            let t = rng.normal_vec(hk * dh);
            kf.append(&t);
            kq.append(&t, 0.95);
        }
        let factor = kf.bytes() as f64 / kq.bytes() as f64;
        assert!(factor > 3.0 && factor < 4.0, "saving {factor}");
    }

    #[test]
    fn gqa_heads_share_kv() {
        let (hk, dh, s, nh) = (1usize, 8usize, 4usize, 4usize);
        let (kf, vf, _, _) = fill_caches(s, hk, dh, 8, 5);
        let mut rng = Rng::new(12);
        // identical q for all heads → identical outputs per head
        let qh = rng.normal_vec(dh);
        let mut q = Vec::new();
        for _ in 0..nh {
            q.extend_from_slice(&qh);
        }
        let mut out = vec![0.0; nh * dh];
        decode_f32(&q, nh, &kf, &vf, &mut out, &mut Vec::new());
        for h in 1..nh {
            prop::assert_close(&out[h * dh..(h + 1) * dh], &out[..dh], 1e-5).unwrap();
        }
    }
}
