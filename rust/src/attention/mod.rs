//! Native decode attention over f32 and quantized KV caches — the rust twin
//! of the paper's FlashInfer-based `Decode` routine (Appendix A.10,
//! Table 15) and the substrate for the memory table (Table 17).
//!
//! Layout per sequence: cache[s][h][dh] (token-major), matching the decode
//! graphs.  The quantized variant streams nibble-packed codes + per-group
//! scales and fuses dequantization into the score/value loops — the IO
//! reduction that makes the 4-bit cache win at large batch/long context.
//!
//! Since the backend routing PR this module is the **scalar oracle** of
//! the batched decode ops on [`crate::backend::ComputeBackend`]:
//! [`decode_seq_f32_ref`] / [`decode_seq_quant_ref`] hold the reference
//! loops `ScalarRef` dispatches to, while the borrowed-view types
//! ([`KvF32View`], [`KvQuantView`], [`DecodeF32Seq`], [`DecodeQuantSeq`])
//! let the same kernels run over owned caches ([`CacheF32`]/[`CacheQuant`])
//! *and* the batcher's dense staging slabs without copies.  The public
//! [`decode_f32`] / [`decode_quant`] entry points dispatch through the
//! process-default backend — the scalar loops are never called directly
//! by serving or bench code.
//!
//! Empty caches (`len == 0`) are well-defined everywhere: the attention
//! output is all zeros (there is nothing to attend to), never the
//! `0/0 = NaN` the pre-fix loops produced.

use crate::quant::kv;

/// f32 cache for one sequence: the FP16-equivalent baseline.
pub struct CacheF32 {
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub data: Vec<f32>, // s * h * dh, appended per token
    pub len: usize,
}

impl CacheF32 {
    pub fn new(n_kv_heads: usize, d_head: usize, capacity: usize) -> Self {
        CacheF32 {
            n_kv_heads,
            d_head,
            data: Vec::with_capacity(capacity * n_kv_heads * d_head),
            len: 0,
        }
    }

    pub fn append(&mut self, kv_token: &[f32]) {
        assert_eq!(kv_token.len(), self.n_kv_heads * self.d_head);
        self.data.extend_from_slice(kv_token);
        self.len += 1;
    }

    pub fn bytes(&self) -> usize {
        // report fp16-equivalent (the paper's baseline is fp16)
        self.data.len() * 2
    }

    /// Borrowed view of this cache for the batched backend decode ops.
    pub fn view(&self) -> KvF32View<'_> {
        KvF32View {
            n_kv_heads: self.n_kv_heads,
            d_head: self.d_head,
            len: self.len,
            data: &self.data,
        }
    }
}

/// Quantized cache for one sequence: nibble/byte-packed codes + group scales.
pub struct CacheQuant {
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub group: usize,
    pub bits: u32,
    pub codes: Vec<u8>, // packed
    pub scales: Vec<f32>,
    pub zeros: Vec<f32>,
    pub len: usize,
}

impl CacheQuant {
    pub fn new(n_kv_heads: usize, d_head: usize, group: usize, bits: u32) -> Self {
        assert!(bits == 4 || bits == 8, "packed cache supports 4/8 bits");
        CacheQuant {
            n_kv_heads,
            d_head,
            group,
            bits,
            codes: Vec::new(),
            scales: Vec::new(),
            zeros: Vec::new(),
            len: 0,
        }
    }

    /// Quantize + append one token's (h × dh) keys-or-values.
    pub fn append(&mut self, kv_token: &[f32], clip: f32) {
        let d = self.n_kv_heads * self.d_head;
        assert_eq!(kv_token.len(), d);
        let (codes, scales, zeros) = kv::quant_slab(kv_token, d, self.group,
                                                    self.bits, clip);
        if self.bits == 4 {
            self.codes.extend_from_slice(&kv::pack_nibbles(&codes));
        } else {
            self.codes.extend(codes.iter().map(|&c| c as u8));
        }
        self.scales.extend_from_slice(&scales);
        self.zeros.extend_from_slice(&zeros);
        self.len += 1;
    }

    pub fn bytes(&self) -> usize {
        self.codes.len() + (self.scales.len() + self.zeros.len()) * 4
    }

    /// Borrowed view of this cache for the batched backend decode ops.
    pub fn view(&self) -> KvQuantView<'_> {
        let codes = if self.bits == 4 {
            KvCodes::Packed4(&self.codes)
        } else {
            // SAFETY: i8 and u8 have identical size/alignment; the 8-bit
            // cache stores signed codes bit-cast into its u8 stream.
            KvCodes::I8(unsafe {
                std::slice::from_raw_parts(self.codes.as_ptr() as *const i8,
                                           self.codes.len())
            })
        };
        KvQuantView {
            n_kv_heads: self.n_kv_heads,
            d_head: self.d_head,
            group: self.group,
            len: self.len,
            codes,
            scales: &self.scales,
            zeros: &self.zeros,
        }
    }

    /// Dequantize token s, head h into `out` (d_head values).
    pub fn dequant_head(&self, s: usize, h: usize, out: &mut [f32], scratch: &mut [i8]) {
        let d = self.n_kv_heads * self.d_head;
        let groups_per_tok = d / self.group;
        let tok_groups = s * groups_per_tok + h * (self.d_head / self.group);
        let start_code = s * d + h * self.d_head;
        let codes = &mut scratch[..self.d_head];
        if self.bits == 4 {
            // packed stream: codes for this head start at bit offset
            for (i, c) in codes.iter_mut().enumerate() {
                let idx = start_code + i;
                let byte = self.codes[idx / 2];
                let nib = if idx % 2 == 0 { byte & 0x0F } else { byte >> 4 };
                *c = ((nib << 4) as i8) >> 4;
            }
        } else {
            for (i, c) in codes.iter_mut().enumerate() {
                *c = self.codes[start_code + i] as i8;
            }
        }
        for (gi, chunk) in out.chunks_mut(self.group).enumerate() {
            let s_ = self.scales[tok_groups + gi];
            let z_ = self.zeros[tok_groups + gi];
            for (o, &c) in chunk.iter_mut().zip(&codes[gi * self.group..]) {
                *o = c as f32 * s_ + z_;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// borrowed KV views + batch descriptors (the ComputeBackend decode surface)

/// Borrowed token-major f32 KV stream: `data[(t * n_kv_heads + h) * d_head ..]`.
#[derive(Clone, Copy)]
pub struct KvF32View<'a> {
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub len: usize,
    pub data: &'a [f32],
}

/// Code storage of a quantized KV stream.
#[derive(Clone, Copy)]
pub enum KvCodes<'a> {
    /// One sign-extended code per element (8-bit caches and the batcher's
    /// dense staging slabs, which keep 4-bit codes unpacked too).
    I8(&'a [i8]),
    /// Two 4-bit codes per byte, lo nibble first (packed int4 caches).
    Packed4(&'a [u8]),
}

/// Sign-extend one packed byte's two 4-bit codes (lo nibble first) — the
/// single definition of the nibble decode every kernel (oracle and
/// blocked/threaded tiles) shares, so the bit-exactness contract cannot
/// drift between copies.
#[inline(always)]
pub(crate) fn unpack_nibble_pair(byte: u8) -> (f32, f32) {
    ((((byte & 0x0F) << 4) as i8 >> 4) as f32,
     ((byte & 0xF0) as i8 >> 4) as f32)
}

/// Borrowed token-major quantized KV stream (group-wise asymmetric codec):
/// codes laid out like [`KvF32View::data`], one (scale, zero) pair per
/// `group` consecutive elements of each token row.
#[derive(Clone, Copy)]
pub struct KvQuantView<'a> {
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub group: usize,
    pub len: usize,
    pub codes: KvCodes<'a>,
    pub scales: &'a [f32],
    pub zeros: &'a [f32],
}

/// One sequence of a batched f32 decode step: query `q` (n_heads × d_head)
/// against its K/V streams.
pub struct DecodeF32Seq<'a> {
    pub q: &'a [f32],
    pub k: KvF32View<'a>,
    pub v: KvF32View<'a>,
}

/// One sequence of a batched quantized decode step.
pub struct DecodeQuantSeq<'a> {
    pub q: &'a [f32],
    pub k: KvQuantView<'a>,
    pub v: KvQuantView<'a>,
}

/// Reusable scratch for the decode kernels — score rows, per-group Σq,
/// zero-point accumulators, per-head reduction state.  Backends keep one
/// per call (or per worker task) so the inner loops never allocate.
#[derive(Default)]
pub struct DecodeScratch {
    pub scores: Vec<f32>,
    pub qsum: Vec<f32>,
    pub zacc: Vec<f32>,
    pub mxs: Vec<f32>,
    pub denoms: Vec<f32>,
}

// ---------------------------------------------------------------------------
// public entry points — dispatch through the process-default backend

/// One decode step over an f32 cache: q (H × dh) → out (H × dh).
/// GQA: `rep` q-heads share each kv-head.  Dispatches through the
/// process-default [`crate::backend::ComputeBackend`]; the scalar loops
/// live in [`decode_seq_f32_ref`] (the `ScalarRef` oracle).
pub fn decode_f32(q: &[f32], n_heads: usize, k: &CacheF32, v: &CacheF32,
                  out: &mut [f32]) {
    let seqs = [DecodeF32Seq { q, k: k.view(), v: v.view() }];
    crate::backend::default_backend().decode_f32_batch(&seqs, n_heads, out);
}

/// One decode step over a quantized cache (fused dequant + online softmax),
/// dispatched through the process-default backend like [`decode_f32`].
pub fn decode_quant(q: &[f32], n_heads: usize, k: &CacheQuant, v: &CacheQuant,
                    out: &mut [f32]) {
    let seqs = [DecodeQuantSeq { q, k: k.view(), v: v.view() }];
    crate::backend::default_backend().decode_quant_batch(&seqs, n_heads, out);
}

// ---------------------------------------------------------------------------
// scalar oracle kernels (ScalarRef's decode implementation)

/// Scalar oracle: one q-head of one sequence against an f32 stream.
/// `oh` is the head's d_head output slice; `scores` is reused scratch.
pub(crate) fn decode_head_f32(qh: &[f32], kvh: usize, k: &KvF32View,
                              v: &KvF32View, oh: &mut [f32],
                              scores: &mut Vec<f32>) {
    let (hk, dh) = (k.n_kv_heads, k.d_head);
    let s = k.len;
    let sm = 1.0 / (dh as f32).sqrt();
    scores.resize(s, 0.0);
    let mut mx = f32::MIN;
    for t in 0..s {
        let kt = &k.data[(t * hk + kvh) * dh..][..dh];
        let mut dot = 0.0f32;
        for i in 0..dh {
            dot += qh[i] * kt[i];
        }
        let sc = dot * sm;
        scores[t] = sc;
        mx = mx.max(sc);
    }
    let mut denom = 0.0f32;
    oh.fill(0.0);
    for t in 0..s {
        let p = (scores[t] - mx).exp();
        denom += p;
        let vt = &v.data[(t * hk + kvh) * dh..][..dh];
        for i in 0..dh {
            oh[i] += p * vt[i];
        }
    }
    let inv = 1.0 / denom;
    for o in oh {
        *o *= inv;
    }
}

/// Scalar oracle: one full f32 decode step for one sequence (all heads).
/// An empty cache yields an all-zero output (nothing to attend to).
pub fn decode_seq_f32_ref(seq: &DecodeF32Seq, n_heads: usize, out: &mut [f32],
                          scratch: &mut DecodeScratch) {
    let (hk, dh) = (seq.k.n_kv_heads, seq.k.d_head);
    if seq.k.len == 0 {
        out.fill(0.0);
        return;
    }
    let rep = n_heads / hk;
    for h in 0..n_heads {
        let kvh = h / rep;
        decode_head_f32(&seq.q[h * dh..(h + 1) * dh], kvh, &seq.k, &seq.v,
                        &mut out[h * dh..(h + 1) * dh], &mut scratch.scores);
    }
}

/// Scalar oracle: one q-head of one sequence against quantized streams
/// (fused dequant + online softmax).
///
/// Perf notes (EXPERIMENTS.md §Perf): the naive version dequantized each
/// (token, head) into a buffer and then ran the dot — two passes and a
/// nibble-extract per element.  This version folds the affine dequant into
/// the reductions analytically:
///   q·deq(c)   = scale·(q·c) + zero·Σq            (score pass)
///   Σₜ pₜ·deq(cₜ) = Σₜ (pₜ·scaleₜ)·cₜ + (Σₜ pₜ·zeroₜ) (value pass)
/// so the inner loops touch each packed byte once and use integer-from-
/// nibble directly, with Σq precomputed per (head, group).
pub(crate) fn decode_head_quant(qh: &[f32], kvh: usize, k: &KvQuantView,
                                v: &KvQuantView, oh: &mut [f32],
                                scratch: &mut DecodeScratch) {
    let (hk, dh) = (k.n_kv_heads, k.d_head);
    let s = k.len;
    let sm = 1.0 / (dh as f32).sqrt();
    let d = hk * dh;
    let groups_per_tok = d / k.group;
    let gh = dh / k.group; // groups per head
    scratch.scores.resize(s, 0.0);
    let scores = &mut scratch.scores;
    // per-group Σq for the zero-point correction
    scratch.qsum.clear();
    scratch.qsum.extend(qh.chunks_exact(k.group).map(|g| g.iter().sum::<f32>()));
    let qsum = &scratch.qsum;
    let mut mx = f32::MIN;
    for t in 0..s {
        let base = t * d + kvh * dh;
        let gbase = t * groups_per_tok + kvh * gh;
        let mut sc = 0.0f32;
        for gi in 0..gh {
            let scale = k.scales[gbase + gi];
            let zero = k.zeros[gbase + gi];
            let mut dot = 0.0f32;
            let goff = gi * k.group;
            match k.codes {
                KvCodes::Packed4(codes) => {
                    // packed stream: group starts nibble-aligned (group even)
                    let cb = (base + goff) / 2;
                    for (j, &byte) in codes[cb..cb + k.group / 2].iter()
                        .enumerate() {
                        let (lo, hi) = unpack_nibble_pair(byte);
                        dot += qh[goff + 2 * j] * lo + qh[goff + 2 * j + 1] * hi;
                    }
                }
                KvCodes::I8(codes) => {
                    let cb = base + goff;
                    for (j, &c) in codes[cb..cb + k.group].iter().enumerate() {
                        dot += qh[goff + j] * c as f32;
                    }
                }
            }
            sc += scale * dot + zero * qsum[gi];
        }
        let sc = sc * sm;
        scores[t] = sc;
        mx = mx.max(sc);
    }
    let mut denom = 0.0f32;
    oh.fill(0.0);
    scratch.zacc.clear();
    scratch.zacc.resize(gh, 0.0); // Σₜ pₜ·zeroₜ per group
    let zacc = &mut scratch.zacc;
    for t in 0..s {
        let p = (scores[t] - mx).exp();
        denom += p;
        let base = t * d + kvh * dh;
        let gbase = t * groups_per_tok + kvh * gh;
        for gi in 0..gh {
            let ps = p * v.scales[gbase + gi];
            zacc[gi] += p * v.zeros[gbase + gi];
            let goff = gi * v.group;
            match v.codes {
                KvCodes::Packed4(codes) => {
                    let cb = (base + goff) / 2;
                    for (j, &byte) in codes[cb..cb + v.group / 2].iter()
                        .enumerate() {
                        let (lo, hi) = unpack_nibble_pair(byte);
                        oh[goff + 2 * j] += ps * lo;
                        oh[goff + 2 * j + 1] += ps * hi;
                    }
                }
                KvCodes::I8(codes) => {
                    let cb = base + goff;
                    for (j, &c) in codes[cb..cb + v.group].iter().enumerate() {
                        oh[goff + j] += ps * c as f32;
                    }
                }
            }
        }
    }
    let inv = 1.0 / denom;
    for gi in 0..gh {
        for o in &mut oh[gi * v.group..(gi + 1) * v.group] {
            *o = (*o + zacc[gi]) * inv;
        }
    }
}

/// Scalar oracle: one full quantized decode step for one sequence.
/// An empty cache yields an all-zero output (nothing to attend to).
pub fn decode_seq_quant_ref(seq: &DecodeQuantSeq, n_heads: usize,
                            out: &mut [f32], scratch: &mut DecodeScratch) {
    let (hk, dh) = (seq.k.n_kv_heads, seq.k.d_head);
    if seq.k.len == 0 {
        out.fill(0.0);
        return;
    }
    let rep = n_heads / hk;
    for h in 0..n_heads {
        let kvh = h / rep;
        decode_head_quant(&seq.q[h * dh..(h + 1) * dh], kvh, &seq.k, &seq.v,
                          &mut out[h * dh..(h + 1) * dh], scratch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prng::Rng, prop};

    fn fill_caches(s: usize, hk: usize, dh: usize, bits: u32, seed: u64)
                   -> (CacheF32, CacheF32, CacheQuant, CacheQuant) {
        let mut rng = Rng::new(seed);
        let mut kf = CacheF32::new(hk, dh, s);
        let mut vf = CacheF32::new(hk, dh, s);
        let mut kq = CacheQuant::new(hk, dh, dh, bits);
        let mut vq = CacheQuant::new(hk, dh, dh, bits);
        for _ in 0..s {
            let kt = rng.normal_vec(hk * dh);
            let vt = rng.normal_vec(hk * dh);
            kf.append(&kt);
            vf.append(&vt);
            kq.append(&kt, 1.0);
            vq.append(&vt, 1.0);
        }
        (kf, vf, kq, vq)
    }

    #[test]
    fn quant_cache_roundtrip() {
        let (kf, _, kq, _) = fill_caches(5, 2, 16, 8, 0);
        let mut buf = vec![0.0; 16];
        let mut scratch = vec![0i8; 16];
        for s in 0..5 {
            for h in 0..2 {
                kq.dequant_head(s, h, &mut buf, &mut scratch);
                let want = &kf.data[(s * 2 + h) * 16..][..16];
                prop::assert_close(&buf, want, 0.05).unwrap();
            }
        }
    }

    #[test]
    fn decode_quant_tracks_f32_at_8bit() {
        let (hk, dh, s, nh) = (2usize, 16usize, 12usize, 4usize);
        let (kf, vf, kq, vq) = fill_caches(s, hk, dh, 8, 1);
        let mut rng = Rng::new(9);
        let q = rng.normal_vec(nh * dh);
        let mut o0 = vec![0.0; nh * dh];
        let mut o1 = vec![0.0; nh * dh];
        decode_f32(&q, nh, &kf, &vf, &mut o0);
        decode_quant(&q, nh, &kq, &vq, &mut o1);
        prop::assert_close(&o1, &o0, 0.06).unwrap();
    }

    #[test]
    fn decode_4bit_reasonable() {
        let (hk, dh, s, nh) = (2usize, 32usize, 20usize, 2usize);
        let (kf, vf, kq, vq) = fill_caches(s, hk, dh, 4, 2);
        let mut rng = Rng::new(10);
        let q = rng.normal_vec(nh * dh);
        let mut o0 = vec![0.0; nh * dh];
        let mut o1 = vec![0.0; nh * dh];
        decode_f32(&q, nh, &kf, &vf, &mut o0);
        decode_quant(&q, nh, &kq, &vq, &mut o1);
        let scale = o0.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        prop::assert_close(&o1, &o0, 0.35 * scale.max(0.1)).unwrap();
    }

    #[test]
    fn softmax_normalized_output_in_hull() {
        // output is a convex combination of values → within [min, max] of V
        let (hk, dh, s, nh) = (1usize, 8usize, 6usize, 1usize);
        let (kf, vf, _, _) = fill_caches(s, hk, dh, 8, 3);
        let mut rng = Rng::new(11);
        let q = rng.normal_vec(nh * dh);
        let mut out = vec![0.0; nh * dh];
        decode_f32(&q, nh, &kf, &vf, &mut out);
        for i in 0..dh {
            let col: Vec<f32> = (0..s).map(|t| vf.data[t * dh + i]).collect();
            let (mn, mx) = col.iter().fold((f32::MAX, f32::MIN),
                                           |(a, b), &v| (a.min(v), b.max(v)));
            assert!(out[i] >= mn - 1e-4 && out[i] <= mx + 1e-4);
        }
    }

    #[test]
    fn memory_saving_factor_matches_paper_shape() {
        // fp16 cache vs int4+scales: paper reports 3.6-3.9× (Table 17)
        let (hk, dh, s) = (8usize, 128usize, 2048usize);
        let mut kf = CacheF32::new(hk, dh, s);
        let mut kq = CacheQuant::new(hk, dh, 128, 4);
        let mut rng = Rng::new(4);
        for _ in 0..s {
            let t = rng.normal_vec(hk * dh);
            kf.append(&t);
            kq.append(&t, 0.95);
        }
        let factor = kf.bytes() as f64 / kq.bytes() as f64;
        assert!(factor > 3.0 && factor < 4.0, "saving {factor}");
    }

    #[test]
    fn empty_cache_decode_is_zero() {
        // regression: s == 0 used to leave denom == 0 → inv = inf → NaN
        let (hk, dh, nh) = (2usize, 16usize, 4usize);
        let kf = CacheF32::new(hk, dh, 0);
        let vf = CacheF32::new(hk, dh, 0);
        let kq = CacheQuant::new(hk, dh, dh, 4);
        let vq = CacheQuant::new(hk, dh, dh, 4);
        let mut rng = Rng::new(7);
        let q = rng.normal_vec(nh * dh);
        let mut out = vec![f32::NAN; nh * dh];
        decode_f32(&q, nh, &kf, &vf, &mut out);
        assert!(out.iter().all(|&v| v == 0.0), "f32 empty-cache decode: {out:?}");
        out.fill(f32::NAN);
        decode_quant(&q, nh, &kq, &vq, &mut out);
        assert!(out.iter().all(|&v| v == 0.0), "quant empty-cache decode: {out:?}");
    }

    #[test]
    fn gqa_heads_share_kv() {
        let (hk, dh, s, nh) = (1usize, 8usize, 4usize, 4usize);
        let (kf, vf, _, _) = fill_caches(s, hk, dh, 8, 5);
        let mut rng = Rng::new(12);
        // identical q for all heads → identical outputs per head
        let qh = rng.normal_vec(dh);
        let mut q = Vec::new();
        for _ in 0..nh {
            q.extend_from_slice(&qh);
        }
        let mut out = vec![0.0; nh * dh];
        decode_f32(&q, nh, &kf, &vf, &mut out);
        for h in 1..nh {
            prop::assert_close(&out[h * dh..(h + 1) * dh], &out[..dh], 1e-5).unwrap();
        }
    }
}
