//! # QuaRot — Outlier-Free 4-Bit Inference in Rotated LLMs
//!
//! A three-layer reproduction of the NeurIPS 2024 paper (DESIGN.md):
//! this crate is **Layer 3** — the serving coordinator, quantization
//! toolchain, evaluation harness and native performance kernels.  It loads
//! AOT-compiled HLO artifacts produced by the build-time python layers
//! (L2 jax model + L1 Pallas kernels) and runs them through the PJRT C API
//! (`xla` crate); python is never on the request path.
//!
//! Module map (bottom-up):
//!
//! * [`util`]      — zero-dependency substrates: JSON, PRNG, CLI, bench and
//!                   property-test harnesses.
//! * [`audit`]     — debug-gated runtime invariant auditors: lock-order
//!                   (deadlock-potential) detection over the concurrent
//!                   subsystems, a page-refcount ledger with owner
//!                   labels, and a prefix-pin balance mirror; compiled
//!                   to no-ops in release builds.  (The static
//!                   companion checks live in the `quarot-lint` binary.)
//! * [`tensor`]    — row-major f32 matrices for the offline toolchain.
//! * [`linalg`]    — Cholesky / triangular solves / QR (GPTQ + Table 8).
//! * [`hadamard`]  — fast Walsh–Hadamard transforms incl. Kronecker H12/H20.
//! * [`backend`]   — pluggable compute backends (`ComputeBackend` trait):
//!                   scalar oracle, cache-blocked, and pool-threaded
//!                   kernels for every hot op, with shape-aware auto
//!                   selection (`--backend` / `QUAROT_BACKEND` override).
//! * [`quant`]     — RTN / GPTQ / SmoothQuant / QUIK weight quantizers,
//!                   group-wise asymmetric KV codec, int4 packing.
//! * [`gemm`]      — native f32 / int8 / packed-int4 GEMM (Fig. 7 substrate).
//! * [`attention`] — native decode attention over f32 and quantized caches
//!                   (Table 15 substrate); scalar oracle + borrowed KV
//!                   views for the backend's batched decode ops.
//! * [`model`]     — artifact containers: configs, weights.bin, corpus.bin,
//!                   probes.bin, and the rust-side QuaRot transform.
//! * [`rotation`]  — pluggable rotation schemes (`RotationScheme` trait):
//!                   randomized Hadamard, random orthogonal (Table 8),
//!                   channel-scaled Hadamard — `--rotation` selects one
//!                   end-to-end (spec → weight prep → verify).
//! * [`runtime`]   — PJRT engine: manifest-driven executable registry.
//! * [`forward`]   — graph-free model execution: the `ModelExecutor`
//!                   contract (prefill / batched decode / chunked suffix
//!                   prefill) and the native pure-rust forward pass built
//!                   from the backend ops, so `--executor native` serves
//!                   with zero PJRT graphs loaded.
//! * [`coordinator`] — the serving layer: continuous batcher, paged
//!                   quantized KV-cache manager with refcounted pages,
//!                   the shared prompt-prefix trie (grafted at
//!                   admission, CoW by page), sampler, metrics.
//! * [`api`]       — the unified inference API: typed `GenerationParams`,
//!                   the `InferenceService` trait, per-request
//!                   `GenerationEvent` streams with cancellation and
//!                   bounded admission, a `LocalSession` over the engine,
//!                   the TCP `Client`, and the v2 event-frame wire codec.
//! * [`cluster`]   — sharded serving: N engine shards (one tick thread
//!                   each) behind one `InferenceService` front, with a
//!                   session-affine + prefix-affine load-aware router
//!                   (owning shard, then longest cached prefix, then
//!                   queue depth / active slots / KV-page pressure),
//!                   fair-share priority + deadline scheduling, and a
//!                   runtime metrics registry.
//! * [`session`]   — multi-turn chat serving: per-engine `SessionStore`
//!                   tracking conversation chains, generated-token page
//!                   donation back into the prefix trie at retirement
//!                   (turn k+1 grafts the whole history), chain pinning
//!                   with TTL/LRU session eviction under `--sessions N`.
//! * [`server`]    — threaded TCP front-end speaking the v2 event-frame
//!                   protocol (one JSON frame per event, multiplexed by
//!                   request id; v1 one-shot lines still answered),
//!                   serving a `ClusterService` (`--shards N`).
//! * [`telemetry`] — request-lifecycle tracing and latency histograms:
//!                   injectable `Clock`, mergeable log-bucketed
//!                   `Histogram` (p50/p90/p99/p99.9 on the wire),
//!                   lock-free `SpanRecorder` ring with Chrome-trace /
//!                   Perfetto export (`{"cmd":"trace"}`, `quarot trace`),
//!                   and the `Timed` backend decorator for op-level
//!                   attribution.
//! * [`eval`]      — perplexity, zero-shot probes, outlier statistics
//!                   (NLL reductions batched through the backend).
//! * [`bench_support`] — shared workload generators for `cargo bench`.

pub mod api;
pub mod attention;
pub mod audit;
pub mod backend;
pub mod bench_support;
pub mod cluster;
pub mod coordinator;
pub mod eval;
pub mod forward;
pub mod gemm;
pub mod hadamard;
pub mod linalg;
pub mod model;
pub mod quant;
pub mod rotation;
pub mod runtime;
pub mod server;
pub mod session;
pub mod telemetry;
pub mod tensor;
pub mod util;
