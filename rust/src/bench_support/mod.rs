//! Shared plumbing for the `benches/` targets and table-producing CLI
//! subcommands: artifact discovery, engine/runner construction, spec
//! shorthands and result recording.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::api::{GenerationEvent, RequestHandle};
use crate::coordinator::runner::{CalibStats, ExecutorKind, QuantSpec, Runner};
use crate::model::corpus::{load_probes, Corpus, ProbeTask};
use crate::model::{transform, ModelConfig, Tensor, Weights};
use crate::runtime::Engine;
use crate::util::prng::Rng;

pub const ARTIFACTS: &str = "artifacts";

/// Default eval budget for table sweeps (windows of max_seq tokens).
/// Raise with QUAROT_EVAL_WINDOWS for higher-fidelity runs.
pub fn eval_windows() -> usize {
    std::env::var("QUAROT_EVAL_WINDOWS").ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(12)
}

pub fn probe_items() -> usize {
    std::env::var("QUAROT_PROBE_ITEMS").ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(40)
}

/// `-- --check` CI accounting shared by the table benches: a reduced
/// eval budget, a finiteness gate on every metric cell, and a one-line
/// verdict.  Every bench binary must expose the mode (enforced by
/// `quarot-lint`'s bench-check rule); like the serving smokes, a table
/// bench self-skips models whose artifacts are absent, so `--check`
/// stays green on runners without `make artifacts` while still
/// compiling and driving the whole sweep.
pub struct CheckSink {
    name: &'static str,
    active: bool,
    cells: usize,
}

impl CheckSink {
    pub fn new(name: &'static str) -> CheckSink {
        CheckSink {
            name,
            active: std::env::args().any(|a| a == "--check"),
            cells: 0,
        }
    }

    pub fn active(&self) -> bool {
        self.active
    }

    /// Eval budget: one window in `--check` mode, the usual
    /// [`eval_windows`] sweep otherwise.
    pub fn windows(&self) -> usize {
        if self.active { 1 } else { eval_windows() }
    }

    /// Record one metric cell; in `--check` mode a non-finite value
    /// fails the smoke.
    pub fn cell(&mut self, label: &str, v: f64) -> Result<()> {
        if self.active {
            anyhow::ensure!(v.is_finite(),
                            "[check] {}: non-finite {label}: {v}", self.name);
        }
        self.cells += 1;
        Ok(())
    }

    /// In `--check` mode prints the verdict and returns `true` — the
    /// caller skips the `record` step; `false` means run the bench's
    /// normal tail.
    pub fn done(&self) -> bool {
        if self.active {
            println!("[check] {} OK ({} finite metric cell(s))",
                     self.name, self.cells);
        }
        self.active
    }
}

pub struct Artifacts {
    pub dir: String,
    pub weights: Weights,
    pub corpus: Corpus,
    pub probes: Vec<ProbeTask>,
}

impl Artifacts {
    pub fn load(model: &str) -> Result<Artifacts> {
        let dir = format!("{ARTIFACTS}/{model}");
        if !std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
            anyhow::bail!(
                "artifacts for '{model}' not found — run `make artifacts` first");
        }
        Ok(Artifacts {
            weights: Weights::load(&format!("{dir}/weights.bin"))?,
            corpus: Corpus::load(&format!("{ARTIFACTS}/corpus.bin"))?,
            probes: load_probes(&format!("{ARTIFACTS}/probes.bin"))?,
            dir,
        })
    }

    /// Fresh engine compiling only the graphs a runner for `spec` needs.
    pub fn engine_for(&self, spec: &QuantSpec) -> Result<Engine> {
        let needed: Vec<&str> = vec![
            spec.variant.prefill_graph(),
            spec.variant.decode_graph(),
        ];
        Engine::load(&self.dir, Some(&needed))
    }

    pub fn engine_graphs(&self, names: &[&str]) -> Result<Engine> {
        Engine::load(&self.dir, Some(names))
    }

    /// Build a runner (engine compiled fresh — PJRT executables are cheap
    /// to keep but compilation is ~1s per graph, so benches reuse runners).
    pub fn runner(&self, spec: QuantSpec, stats: Option<&CalibStats>) -> Result<Runner> {
        let engine = self.engine_for(&spec)?;
        Runner::new(engine, &self.weights, spec, stats)
    }

    /// Runner that only compiles the prefill graph — the right tool for the
    /// ppl/zeroshot table sweeps (decode compilation dominates otherwise).
    pub fn runner_prefill_only(&self, spec: QuantSpec, stats: Option<&CalibStats>)
                               -> Result<Runner> {
        let engine = self.engine_graphs(&[spec.variant.prefill_graph()])?;
        Runner::new(engine, &self.weights, spec, stats)
    }

    /// Graph-free native runner: the engine contributes only its manifest
    /// (no PJRT client is created, no graphs are compiled) and the
    /// forward pass runs on the in-process compute backend.
    pub fn runner_native(&self, spec: QuantSpec, stats: Option<&CalibStats>)
                         -> Result<Runner> {
        let engine = self.engine_graphs(&[])?;
        Runner::new_native(engine, &self.weights, spec, stats)
    }

    /// Runner on the requested executor (`--executor` dispatch): `Pjrt`
    /// compiles this spec's graphs, `Native` is [`Self::runner_native`].
    pub fn runner_kind(&self, kind: ExecutorKind, spec: QuantSpec,
                       stats: Option<&CalibStats>) -> Result<Runner> {
        match kind {
            ExecutorKind::Pjrt => self.runner(spec, stats),
            ExecutorKind::Native => self.runner_native(spec, stats),
        }
    }

    /// Calibration stats via the collect graph (cached per rotation).
    pub fn calib(&self, rotated: bool, windows: usize) -> Result<CalibStats> {
        let graph = if rotated { "collect_quarot" } else { "collect_baseline" };
        let engine = self.engine_graphs(&[graph])?;
        Runner::collect_stats(&engine, &self.weights, rotated,
                              self.corpus.split("calib")?, windows)
    }
}

/// Synthetic `base.*` + `rot.*` weight archive at `cfg`'s shape — the
/// tensor layout a real artifact dir holds, generated in memory.  Lets
/// the native (graph-free) executor run benches and smokes on machines
/// without `make artifacts`.  Deterministic in `seed`: the base set is
/// seeded gaussian noise, the rotated set is the exact QuaRot Stage-1
/// transform of it.
pub fn synthetic_archive(cfg: &ModelConfig, seed: u64) -> Result<Weights> {
    let mut rng = Rng::new(seed);
    let (d, da, dkv, dff, l, v) = (cfg.d_model, cfg.d_attn(), cfg.d_kv(),
                                   cfg.d_ff, cfg.n_layers, cfg.vocab);
    let t = |shape: Vec<usize>, rng: &mut Rng| {
        let n: usize = shape.iter().product();
        Tensor::from_f32(shape, &rng.normal_vec(n))
    };
    let mut base = BTreeMap::new();
    base.insert("embed".to_string(), t(vec![v, d], &mut rng));
    base.insert("final_norm".to_string(), t(vec![d], &mut rng));
    base.insert("lm_head".to_string(), t(vec![d, v], &mut rng));
    base.insert("attn_norm".to_string(), t(vec![l, d], &mut rng));
    base.insert("wq".to_string(), t(vec![l, d, da], &mut rng));
    base.insert("wk".to_string(), t(vec![l, d, dkv], &mut rng));
    base.insert("wv".to_string(), t(vec![l, d, dkv], &mut rng));
    base.insert("wo".to_string(), t(vec![l, da, d], &mut rng));
    base.insert("ffn_norm".to_string(), t(vec![l, d], &mut rng));
    base.insert("wup".to_string(), t(vec![l, d, dff], &mut rng));
    base.insert("wgate".to_string(), t(vec![l, d, dff], &mut rng));
    base.insert("wdown".to_string(), t(vec![l, dff, d], &mut rng));
    let q = transform::q_from_signs(cfg.d_model,
                                    &Rng::new(seed ^ 0x5eed).signs(cfg.d_model));
    let refs: BTreeMap<String, &Tensor> =
        base.iter().map(|(k, t)| (k.clone(), t)).collect();
    let rot = transform::rotate(cfg, &refs, &q)?;
    let mut tensors = BTreeMap::new();
    for (k, t) in base {
        tensors.insert(format!("base.{k}"), t);
    }
    for (k, t) in rot {
        tensors.insert(format!("rot.{k}"), t);
    }
    Ok(Weights { tensors })
}

/// Timing-free signature of one generation event — what the 1-shard
/// cluster ≡ `LocalSession` parity checks compare (tick scheduling
/// differs by design, so `ttft`/`decode` timings are excluded; tokens,
/// indices, finish reason and counts must match exactly).
pub fn event_signature(ev: &GenerationEvent) -> String {
    match ev {
        GenerationEvent::Queued => "queued".into(),
        GenerationEvent::Started { .. } => "started".into(),
        GenerationEvent::Token { token, index } => format!("tok {token}@{index}"),
        GenerationEvent::Finished { reason, stats } => format!(
            "fin {reason} gen={} plen={}", stats.generated, stats.prompt_len),
        GenerationEvent::Failed { error } => format!("fail {error}"),
    }
}

/// Drain every handle to its terminal event, collecting each request's
/// [`event_signature`] stream (shared by `benches/serving_cluster.rs`
/// `--check` and the `api_stream` parity test).
pub fn drain_event_signatures(handles: &[RequestHandle])
                              -> Result<Vec<Vec<String>>> {
    handles.iter().map(|h| {
        let mut evs = Vec::new();
        while let Some(ev) = h.next_event()? {
            evs.push(event_signature(&ev));
        }
        Ok(evs)
    }).collect()
}

/// Drained outcomes of one scheduling class: raw TTFT and mean
/// inter-token-latency samples (unsorted) plus total generated tokens.
/// Feed the samples to [`crate::cluster::LatencySummary::of`] for
/// mean/p50/p95/p99.
pub struct DrainedClass {
    pub ttfts: Vec<f64>,
    /// One sample per request that generated ≥ 2 tokens: its decode
    /// time divided by its token gaps (a per-request mean ITL — the
    /// engine-side `itl_hist` has the true per-gap distribution).
    pub itls: Vec<f64>,
    pub tokens: usize,
}

/// Block until every handle reaches its terminal event, collecting the
/// class's TTFT/ITL samples and token count (shared by
/// `benches/serving_cluster.rs` and `quarot cluster-bench`).
pub fn drain_class(handles: &[RequestHandle]) -> Result<DrainedClass> {
    let mut ttfts = Vec::with_capacity(handles.len());
    let mut itls = Vec::with_capacity(handles.len());
    let mut tokens = 0usize;
    for h in handles {
        let out = h.wait()?;
        ttfts.push(out.stats.ttft_ms);
        if out.stats.generated > 1 {
            itls.push(out.stats.decode_ms / (out.stats.generated - 1) as f64);
        }
        tokens += out.tokens.len();
    }
    Ok(DrainedClass { ttfts, itls, tokens })
}

/// Write a rendered table into bench_out/<name>.txt (and echo to stdout).
pub fn record(name: &str, body: &str) -> Result<()> {
    std::fs::create_dir_all("bench_out").context("mkdir bench_out")?;
    std::fs::write(format!("bench_out/{name}.txt"), body)?;
    println!("{body}");
    println!("[recorded bench_out/{name}.txt]");
    Ok(())
}

/// Which model configs exist locally (some benches sweep all of them).
pub fn available_models() -> Vec<String> {
    let mut out = Vec::new();
    for name in ["tiny-mha", "small-mha", "tiny-gqa", "phi-proxy"] {
        if std::path::Path::new(&format!("{ARTIFACTS}/{name}/manifest.json")).exists() {
            out.push(name.to_string());
        }
    }
    out
}
