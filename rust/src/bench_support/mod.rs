//! Shared plumbing for the `benches/` targets and table-producing CLI
//! subcommands: artifact discovery, engine/runner construction, spec
//! shorthands and result recording.

use anyhow::{Context, Result};

use crate::coordinator::runner::{CalibStats, QuantSpec, Runner};
use crate::model::corpus::{load_probes, Corpus, ProbeTask};
use crate::model::Weights;
use crate::runtime::Engine;

pub const ARTIFACTS: &str = "artifacts";

/// Default eval budget for table sweeps (windows of max_seq tokens).
/// Raise with QUAROT_EVAL_WINDOWS for higher-fidelity runs.
pub fn eval_windows() -> usize {
    std::env::var("QUAROT_EVAL_WINDOWS").ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(12)
}

pub fn probe_items() -> usize {
    std::env::var("QUAROT_PROBE_ITEMS").ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(40)
}

pub struct Artifacts {
    pub dir: String,
    pub weights: Weights,
    pub corpus: Corpus,
    pub probes: Vec<ProbeTask>,
}

impl Artifacts {
    pub fn load(model: &str) -> Result<Artifacts> {
        let dir = format!("{ARTIFACTS}/{model}");
        if !std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
            anyhow::bail!(
                "artifacts for '{model}' not found — run `make artifacts` first");
        }
        Ok(Artifacts {
            weights: Weights::load(&format!("{dir}/weights.bin"))?,
            corpus: Corpus::load(&format!("{ARTIFACTS}/corpus.bin"))?,
            probes: load_probes(&format!("{ARTIFACTS}/probes.bin"))?,
            dir,
        })
    }

    /// Fresh engine compiling only the graphs a runner for `spec` needs.
    pub fn engine_for(&self, spec: &QuantSpec) -> Result<Engine> {
        let needed: Vec<&str> = vec![
            spec.variant.prefill_graph(),
            spec.variant.decode_graph(),
        ];
        Engine::load(&self.dir, Some(&needed))
    }

    pub fn engine_graphs(&self, names: &[&str]) -> Result<Engine> {
        Engine::load(&self.dir, Some(names))
    }

    /// Build a runner (engine compiled fresh — PJRT executables are cheap
    /// to keep but compilation is ~1s per graph, so benches reuse runners).
    pub fn runner(&self, spec: QuantSpec, stats: Option<&CalibStats>) -> Result<Runner> {
        let engine = self.engine_for(&spec)?;
        Runner::new(engine, &self.weights, spec, stats)
    }

    /// Runner that only compiles the prefill graph — the right tool for the
    /// ppl/zeroshot table sweeps (decode compilation dominates otherwise).
    pub fn runner_prefill_only(&self, spec: QuantSpec, stats: Option<&CalibStats>)
                               -> Result<Runner> {
        let engine = self.engine_graphs(&[spec.variant.prefill_graph()])?;
        Runner::new(engine, &self.weights, spec, stats)
    }

    /// Calibration stats via the collect graph (cached per rotation).
    pub fn calib(&self, rotated: bool, windows: usize) -> Result<CalibStats> {
        let graph = if rotated { "collect_quarot" } else { "collect_baseline" };
        let engine = self.engine_graphs(&[graph])?;
        Runner::collect_stats(&engine, &self.weights, rotated,
                              self.corpus.split("calib")?, windows)
    }
}

/// Write a rendered table into bench_out/<name>.txt (and echo to stdout).
pub fn record(name: &str, body: &str) -> Result<()> {
    std::fs::create_dir_all("bench_out").context("mkdir bench_out")?;
    std::fs::write(format!("bench_out/{name}.txt"), body)?;
    println!("{body}");
    println!("[recorded bench_out/{name}.txt]");
    Ok(())
}

/// Which model configs exist locally (some benches sweep all of them).
pub fn available_models() -> Vec<String> {
    let mut out = Vec::new();
    for name in ["tiny-mha", "small-mha", "tiny-gqa", "phi-proxy"] {
        if std::path::Path::new(&format!("{ARTIFACTS}/{name}/manifest.json")).exists() {
            out.push(name.to_string());
        }
    }
    out
}
