//! Bench harness (criterion is unavailable offline; `cargo bench` targets
//! use this instead).  Measures wall-clock with warmup, reports
//! median/mean/p10/p90, and renders aligned tables matching the paper's
//! layout so EXPERIMENTS.md can be diffed against the paper by eye.

use std::time::Instant;

#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
}

impl Stats {
    pub fn median_ms(&self) -> f64 {
        self.median_ns / 1e6
    }

    pub fn median_us(&self) -> f64 {
        self.median_ns / 1e3
    }
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let q = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
    Stats {
        iters,
        mean_ns: mean,
        median_ns: q(0.5),
        p10_ns: q(0.1),
        p90_ns: q(0.9),
    }
}

/// Adaptive variant: aims for ~`budget_ms` of total measurement time.
pub fn bench_auto<F: FnMut()>(budget_ms: f64, mut f: F) -> Stats {
    let t0 = Instant::now();
    f(); // warmup + cost probe
    let probe = t0.elapsed().as_nanos().max(1) as f64;
    let iters = ((budget_ms * 1e6 / probe) as usize).clamp(3, 10_000);
    bench(1, iters, f)
}

/// Aligned-table printer for paper-style result tables.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("\n== {} ==\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// f with 2/3 significant decimals, matching the paper's table style.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let s = bench(1, 10, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.median_ns > 0.0);
        assert!(s.p10_ns <= s.median_ns && s.median_ns <= s.p90_ns);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["name", "ms"]);
        t.row(vec!["a".into(), "1.00".into()]);
        t.row(vec!["longer".into(), "12.34".into()]);
        let r = t.render();
        assert!(r.contains("demo"));
        assert!(r.contains("longer"));
        let lines: Vec<&str> = r.lines().filter(|l| !l.is_empty()).collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic]
    fn table_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
