//! Deterministic PRNG: splitmix64 seeding + xoshiro256** core, with the
//! usual derived samplers (uniform, range, Gaussian via Box–Muller,
//! categorical).  Every random choice in the crate routes through this so
//! experiments are reproducible from a single seed.

/// xoshiro256** with splitmix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    cached_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: std::array::from_fn(|_| splitmix64(&mut sm)), cached_normal: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal (Box–Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.cached_normal.take() {
            return v;
        }
        let (mut u1, u2) = (self.f64(), self.f64());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        self.cached_normal = Some(r * th.sin());
        r * th.cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32()).collect()
    }

    /// Random ±1 signs.
    pub fn signs(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| if self.next_u64() & 1 == 0 { 1.0 } else { -1.0 }).collect()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|&w| w.max(0.0) as f64).sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut u = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w.max(0.0) as f64;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let n = r.below(7);
            assert!(n < 7);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let xs: Vec<f64> = (0..20000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn categorical_prefers_heavy() {
        let mut r = Rng::new(3);
        let w = [0.0f32, 0.0, 10.0, 0.0];
        for _ in 0..100 {
            assert_eq!(r.categorical(&w), 2);
        }
    }

    #[test]
    fn signs_are_pm_one() {
        let mut r = Rng::new(4);
        let s = r.signs(256);
        assert!(s.iter().all(|&x| x == 1.0 || x == -1.0));
        let pos = s.iter().filter(|&&x| x > 0.0).count();
        assert!(pos > 64 && pos < 192); // roughly balanced
    }
}
