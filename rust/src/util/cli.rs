//! Tiny CLI argument parser: `--flag`, `--key value`, positionals.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let has_value = it
                    .peek()
                    .map(|v| !v.starts_with("--"))
                    .unwrap_or(false);
                if has_value {
                    out.flags.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.insert(key.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn mixed() {
        let a = parse("serve --port 9000 --verbose --model tiny-mha extra");
        assert_eq!(a.positional, vec!["serve", "extra"]);
        assert_eq!(a.usize_or("port", 0), 9000);
        assert!(a.bool("verbose"));
        assert_eq!(a.str_or("model", ""), "tiny-mha");
        assert_eq!(a.usize_or("missing", 7), 7);
    }

    #[test]
    fn flag_then_flag() {
        let a = parse("--a --b v");
        assert!(a.bool("a"));
        assert_eq!(a.get("b"), Some("v"));
    }
}
