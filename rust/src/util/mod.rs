//! Zero-dependency substrates the rest of the crate builds on.
//!
//! The offline environment has no `serde`, `rand`, `clap`, `criterion` or
//! `proptest`, so per the reproduction brief these are built from scratch:
//! [`json`] (parser + writer), [`prng`] (splitmix/xoshiro + Gaussian),
//! [`cli`] (flag parser), [`bench`] (timing harness used by `cargo bench`),
//! [`prop`] (property-test runner with seed reporting).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prng;
pub mod prop;
