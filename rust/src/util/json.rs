//! Minimal JSON: recursive-descent parser + writer.
//!
//! Used for the artifact manifests (runtime), the server wire protocol and
//! bench result dumps.  Supports the full JSON grammar; numbers are f64.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: `v.path(&["graphs", "quarot_prefill", "file"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Value> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(a)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(s),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("unknown escape")),
                },
                c => {
                    // collect the full utf-8 sequence
                    let len = match c {
                        0x00..=0x7f => 0,
                        0xc0..=0xdf => 1,
                        0xe0..=0xef => 2,
                        _ => 3,
                    };
                    let start = self.pos - 1;
                    self.pos += len;
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        while matches!(self.peek(),
                       Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')) {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Value::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

/// Serialize a [`Value`] to compact JSON.
pub fn write(v: &Value) -> String {
    let mut s = String::new();
    write_into(v, &mut s);
    s
}

fn write_into(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\r' => out.push_str("\\r"),
                    '\t' => out.push_str("\\t"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Value::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(x, out);
            }
            out.push(']');
        }
        Value::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(&Value::Str(k.clone()), out);
                out.push(':');
                write_into(x, out);
            }
            out.push('}');
        }
    }
}

/// Builder helpers for constructing objects tersely.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

pub fn n(v: f64) -> Value {
    Value::Num(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\nthere", "d": null}, "e": true}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.path(&["b", "c"]).unwrap().as_str().unwrap(), "hi\nthere");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64().unwrap(), -300.0);
        let re = parse(&write(&v)).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = parse(r#""A\t\"ß""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "A\t\"ß");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn nested_manifest_shape() {
        let src = r#"{"graphs": {"g": {"inputs": [{"name": "x", "shape": [1, 128]}]}}}"#;
        let v = parse(src).unwrap();
        let shape = v.path(&["graphs", "g", "inputs"]).unwrap().as_arr().unwrap()[0]
            .get("shape").unwrap().as_arr().unwrap();
        let dims: Vec<usize> = shape.iter().map(|d| d.as_usize().unwrap()).collect();
        assert_eq!(dims, vec![1, 128]);
    }

    #[test]
    fn writer_escapes_control() {
        let v = Value::Str("a\u{1}b".into());
        assert_eq!(write(&v), "\"a\\u0001b\"");
    }
}
