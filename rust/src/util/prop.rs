//! proptest-lite: run a property over many PRNG-generated cases and report
//! the failing seed so a failure reproduces with `case(seed)`.
//!
//! No shrinking — cases are parameterized directly by a seed, which in
//! practice localizes failures well enough for this crate's invariants.

use super::prng::Rng;

/// Run `prop(rng)` for `cases` seeds; panic with the failing seed on error.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for seed in 0..cases {
        let mut rng = Rng::new(0x9A0C_u64.wrapping_mul(seed + 1));
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at seed {seed}: {msg}");
        }
    }
}

/// Assert helper producing `Result` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Approximate-equality helper for slices.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > atol {
            return Err(format!("elem {i}: {x} vs {y} (atol {atol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("sum-commutes", 50, |rng| {
            let a = rng.f32();
            let b = rng.f32();
            prop_assert!((a + b - (b + a)).abs() < 1e-9, "{a} {b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "seed")]
    fn failing_property_reports_seed() {
        check("always-false", 3, |_| Err("nope".into()));
    }

    #[test]
    fn close_helper() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0001], 1e-3).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-3).is_err());
    }
}
