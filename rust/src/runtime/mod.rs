//! PJRT runtime: loads the AOT artifacts (`artifacts/<model>/`) and exposes
//! typed execution of the lowered graphs.  This is the only place the `xla`
//! crate is touched; everything above works with plain slices.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, HostTensor};
pub use manifest::{GraphSpec, IoSpec, Manifest};
