//! The PJRT engine: compiles the HLO-text artifacts once at startup and
//! executes them with host tensors.  Weights can be pinned as device
//! buffers (`set_weights`) so the per-call upload on the serving hot path
//! is only the small dynamic inputs (tokens, caches, scalars).

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use super::manifest::{ElemType, GraphSpec, Manifest};

/// A host-side tensor handed to / returned from the engine.
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
    I8(Vec<i8>),
}

impl HostTensor {
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I32(v) => v.len(),
            HostTensor::I8(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> ElemType {
        match self {
            HostTensor::F32(_) => ElemType::F32,
            HostTensor::I32(_) => ElemType::I32,
            HostTensor::I8(_) => ElemType::I8,
        }
    }

    pub fn f32(&self) -> &[f32] {
        match self {
            HostTensor::F32(v) => v,
            _ => panic!("not f32"),
        }
    }

    pub fn i8(&self) -> &[i8] {
        match self {
            HostTensor::I8(v) => v,
            _ => panic!("not i8"),
        }
    }

    pub fn i32(&self) -> &[i32] {
        match self {
            HostTensor::I32(v) => v,
            _ => panic!("not i32"),
        }
    }
}

struct LoadedGraph {
    spec: GraphSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// Engine = PJRT client + compiled executables + pinned weight buffers.
///
/// The client is created lazily: loading with `only: Some(&[])` (the
/// native executor's manifest-only mode) compiles nothing and never
/// touches PJRT, so construction succeeds with zero graphs loaded.
pub struct Engine {
    client: Option<xla::PjRtClient>,
    graphs: HashMap<String, LoadedGraph>,
    pub manifest: Manifest,
    /// graph name → (first weight arg index, device buffers)
    pinned: HashMap<String, (usize, Vec<xla::PjRtBuffer>)>,
}

impl Engine {
    /// Load every graph in `dir`'s manifest.  `only` restricts compilation
    /// to the named graphs (compiling all ~12 takes a few seconds each);
    /// `Some(&[])` loads the manifest alone — no PJRT client, no graphs.
    pub fn load(dir: &str, only: Option<&[&str]>) -> Result<Engine> {
        let manifest = Manifest::load(&format!("{dir}/manifest.json"))?;
        let wanted: Vec<&GraphSpec> = manifest.graphs.iter()
            .filter(|spec| only.map_or(true,
                |names| names.contains(&spec.name.as_str())))
            .collect();
        let client = if wanted.is_empty() {
            None
        } else {
            Some(xla::PjRtClient::cpu().context("PJRT cpu client")?)
        };
        let mut graphs = HashMap::new();
        if let Some(client) = &client {
            for spec in wanted {
                let path = format!("{dir}/{}", spec.file);
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .with_context(|| format!("parse {path}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client.compile(&comp)
                    .with_context(|| format!("compile {}", spec.name))?;
                graphs.insert(spec.name.clone(),
                              LoadedGraph { spec: spec.clone(), exe });
            }
        }
        Ok(Engine { client, graphs, manifest, pinned: HashMap::new() })
    }

    fn client(&self) -> Result<&xla::PjRtClient> {
        self.client.as_ref()
            .context("engine was loaded graph-free (no PJRT client)")
    }

    pub fn has_graph(&self, name: &str) -> bool {
        self.graphs.contains_key(name)
    }

    pub fn spec(&self, name: &str) -> Result<&GraphSpec> {
        Ok(&self.graphs.get(name).with_context(|| format!("graph {name}"))?.spec)
    }

    fn to_buffer(&self, t: &HostTensor, shape: &[usize]) -> Result<xla::PjRtBuffer> {
        let client = self.client()?;
        Ok(match t {
            HostTensor::F32(v) => client.buffer_from_host_buffer(v, shape, None)?,
            HostTensor::I32(v) => client.buffer_from_host_buffer(v, shape, None)?,
            HostTensor::I8(v) => client.buffer_from_host_buffer(v, shape, None)?,
        })
    }

    fn check(&self, spec: &GraphSpec, idx: usize, t: &HostTensor) -> Result<()> {
        let want = &spec.inputs[idx];
        if t.dtype() != want.dtype || t.len() != want.len() {
            bail!(
                "graph {} input {} ({}): got {:?}×{}, want {:?}×{}",
                spec.name, idx, want.name, t.dtype(), t.len(), want.dtype, want.len()
            );
        }
        Ok(())
    }

    /// Pin trailing weight arguments as device buffers.  `weights` must
    /// match the tail of the graph's input list exactly.
    pub fn set_weights(&mut self, graph: &str, weights: &[HostTensor]) -> Result<()> {
        let spec = self.spec(graph)?.clone();
        let first = spec.inputs.len() - weights.len();
        let mut bufs = Vec::with_capacity(weights.len());
        for (i, w) in weights.iter().enumerate() {
            self.check(&spec, first + i, w)?;
            bufs.push(self.to_buffer(w, &spec.inputs[first + i].shape)?);
        }
        self.pinned.insert(graph.to_string(), (first, bufs));
        Ok(())
    }

    pub fn unpin(&mut self, graph: &str) {
        self.pinned.remove(graph);
    }

    /// Execute with dynamic inputs; pinned weights (if any) fill the tail.
    pub fn run(&self, graph: &str, dynamic: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let lg = self.graphs.get(graph).with_context(|| format!("graph {graph}"))?;
        let spec = &lg.spec;
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(spec.inputs.len());
        if let Some((first, pinned)) = self.pinned.get(graph) {
            if dynamic.len() != *first {
                bail!("graph {graph}: {} dynamic inputs given, {} expected",
                      dynamic.len(), first);
            }
            for (i, t) in dynamic.iter().enumerate() {
                self.check(spec, i, t)?;
                bufs.push(self.to_buffer(t, &spec.inputs[i].shape)?);
            }
            // PjRtBuffer isn't Clone; re-borrow via a second vec of refs below.
            let all: Vec<&xla::PjRtBuffer> =
                bufs.iter().chain(pinned.iter()).collect();
            return self.collect_outputs(spec, lg.exe.execute_b(&all)?);
        }
        if dynamic.len() != spec.inputs.len() {
            bail!("graph {graph}: {} inputs given, {} expected",
                  dynamic.len(), spec.inputs.len());
        }
        for (i, t) in dynamic.iter().enumerate() {
            self.check(spec, i, t)?;
            bufs.push(self.to_buffer(t, &spec.inputs[i].shape)?);
        }
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        self.collect_outputs(spec, lg.exe.execute_b(&refs)?)
    }

    fn collect_outputs(&self, spec: &GraphSpec,
                       results: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<HostTensor>> {
        let mut lit = results[0][0].to_literal_sync()?;
        let parts = lit.decompose_tuple()?;
        if parts.len() != spec.outputs.len() {
            bail!("graph {}: {} outputs, manifest says {}",
                  spec.name, parts.len(), spec.outputs.len());
        }
        parts.iter().zip(&spec.outputs).map(|(l, os)| {
            Ok(match os.dtype {
                ElemType::F32 => HostTensor::F32(l.to_vec::<f32>()?),
                ElemType::I32 => HostTensor::I32(l.to_vec::<i32>()?),
                ElemType::I8 => HostTensor::I8(l.to_vec::<i8>()?),
            })
        }).collect()
    }
}
