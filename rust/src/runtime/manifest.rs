//! manifest.json parser: the graph inventory written by python/compile/aot.py.
//! Input/output order in the manifest is the execution ABI — the engine
//! validates every call against it.

use anyhow::{bail, Context, Result};

use crate::model::ModelConfig;
use crate::util::json::{self, Value};

#[derive(Clone, Debug, PartialEq)]
pub enum ElemType {
    F32,
    I32,
    I8,
}

impl ElemType {
    fn parse(s: &str) -> Result<ElemType> {
        Ok(match s {
            "f32" => ElemType::F32,
            "i32" => ElemType::I32,
            "i8" => ElemType::I8,
            other => bail!("unknown dtype {other}"),
        })
    }

    pub fn size(&self) -> usize {
        match self {
            ElemType::F32 | ElemType::I32 => 4,
            ElemType::I8 => 1,
        }
    }
}

#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub dtype: ElemType,
    pub shape: Vec<usize>,
}

impl IoSpec {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn from_json(v: &Value) -> Result<IoSpec> {
        Ok(IoSpec {
            name: v.get("name").and_then(|x| x.as_str())
                .context("io spec missing name")?.to_string(),
            dtype: ElemType::parse(
                v.get("dtype").and_then(|x| x.as_str()).context("dtype")?)?,
            shape: v.get("shape").and_then(|x| x.as_arr()).context("shape")?
                .iter().map(|d| d.as_usize().context("dim")).collect::<Result<_>>()?,
        })
    }
}

#[derive(Clone, Debug)]
pub struct GraphSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl GraphSpec {
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|s| s.name == name)
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub model: ModelConfig,
    pub weight_order: Vec<String>,
    pub mask_order: Vec<String>,
    pub graphs: Vec<GraphSpec>,
    /// Rotation scheme the exporter baked into the weights, when the
    /// manifest records one ("hadamard" | "random" | "scaled-hadamard").
    /// Optional for backward compatibility with pre-rotation manifests;
    /// consumers (`quarot verify`) treat it as the default that a
    /// `--rotation` flag overrides.
    pub rotation: Option<String>,
}

impl Manifest {
    pub fn load(path: &str) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {path}"))?;
        let v = json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        Self::from_json(&v)
    }

    pub fn from_json(v: &Value) -> Result<Manifest> {
        let model = ModelConfig::from_json(v.get("model").context("model")?)
            .context("model config")?;
        let strings = |key: &str| -> Result<Vec<String>> {
            v.get(key).and_then(|x| x.as_arr()).with_context(|| key.to_string())?
                .iter()
                .map(|s| Ok(s.as_str().context("string")?.to_string()))
                .collect()
        };
        let graphs_obj = v.get("graphs").and_then(|x| x.as_obj()).context("graphs")?;
        let mut graphs = Vec::new();
        for (name, g) in graphs_obj {
            let io = |key: &str| -> Result<Vec<IoSpec>> {
                g.get(key).and_then(|x| x.as_arr()).with_context(|| key.to_string())?
                    .iter().map(IoSpec::from_json).collect()
            };
            graphs.push(GraphSpec {
                name: name.clone(),
                file: g.get("file").and_then(|x| x.as_str()).context("file")?.into(),
                inputs: io("inputs")?,
                outputs: io("outputs")?,
            });
        }
        Ok(Manifest {
            model,
            weight_order: strings("weight_order")?,
            mask_order: strings("mask_order")?,
            graphs,
            rotation: v.get("rotation").and_then(|x| x.as_str())
                .map(|s| s.to_string()),
        })
    }

    pub fn graph(&self, name: &str) -> Result<&GraphSpec> {
        self.graphs.iter().find(|g| g.name == name)
            .with_context(|| format!("graph {name} not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DEMO: &str = r#"{
      "model": {"name":"t","vocab":512,"d_model":256,"n_layers":4,"n_heads":8,
                "n_kv_heads":8,"d_head":32,"d_ff":1024,"max_seq":128,
                "cache_seq":256,"decode_batch":8,"kv_group":32,
                "rope_theta":10000.0,"train_ppl":10.0},
      "weight_order": ["embed","final_norm"],
      "mask_order": ["mask_attn"],
      "graphs": {
        "quarot_prefill": {
          "file": "quarot_prefill.hlo.txt",
          "inputs": [{"name":"tokens","dtype":"i32","shape":[1,128]},
                     {"name":"act_levels","dtype":"f32","shape":[1]}],
          "outputs": [{"name":"logits","dtype":"f32","shape":[1,128,512]}]
        }
      }
    }"#;

    #[test]
    fn parses_demo() {
        let m = Manifest::from_json(&json::parse(DEMO).unwrap()).unwrap();
        assert_eq!(m.model.d_model, 256);
        assert_eq!(m.weight_order, vec!["embed", "final_norm"]);
        let g = m.graph("quarot_prefill").unwrap();
        assert_eq!(g.inputs.len(), 2);
        assert_eq!(g.inputs[0].dtype, ElemType::I32);
        assert_eq!(g.inputs[0].len(), 128);
        assert_eq!(g.outputs[0].shape, vec![1, 128, 512]);
        assert!(m.graph("nope").is_err());
        assert_eq!(g.input_index("act_levels"), Some(1));
        // pre-rotation manifests omit the field entirely
        assert_eq!(m.rotation, None);
    }

    #[test]
    fn rotation_field_is_optional_and_parsed() {
        let with = DEMO.replacen(
            "\"weight_order\"",
            "\"rotation\": \"scaled-hadamard\", \"weight_order\"", 1);
        let m = Manifest::from_json(&json::parse(&with).unwrap()).unwrap();
        assert_eq!(m.rotation.as_deref(), Some("scaled-hadamard"));
    }
}
