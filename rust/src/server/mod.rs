//! Threaded TCP front-end: newline-delimited JSON requests over a socket,
//! served by the generation engine on a dedicated engine thread (the engine
//! owns the PJRT executables; connections only exchange messages).
//!
//! Wire protocol (one JSON object per line):
//!   → {"prompt": [1,2,3], "max_new_tokens": 16, "temperature": 0.8, "top_k": 4}
//!   ← {"id": 7, "tokens": [..], "ttft_ms": 1.2, "decode_ms": 30.1,
//!      "tokens_per_sec": 412.0}
//! and {"cmd": "stats"} / {"cmd": "shutdown"} admin messages.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::coordinator::batcher::{Completion, GenerationEngine, Request};
use crate::coordinator::sampler::Sampling;
use crate::util::json::{self, n, obj, Value};

pub struct ServerHandle {
    pub port: u16,
    shutdown: Arc<Mutex<bool>>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    pub fn shutdown(mut self) {
        *self.shutdown.lock().unwrap() = true;
        // poke the accept loop
        let _ = TcpStream::connect(("127.0.0.1", self.port));
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

enum EngineMsg {
    Submit(Request, mpsc::Sender<Completion>),
    Stats(mpsc::Sender<String>),
}

/// Start serving on `port` (0 → ephemeral).  Returns once the socket is
/// bound; the engine loop runs on a background thread.
///
/// The engine is built *inside* the engine thread via `make_engine`
/// because PJRT handles are not `Send`.
pub fn serve<F>(make_engine: F, port: u16) -> Result<ServerHandle>
where
    F: FnOnce() -> Result<GenerationEngine> + Send + 'static,
{
    let listener = TcpListener::bind(("127.0.0.1", port)).context("bind")?;
    let port = listener.local_addr()?.port();
    let shutdown = Arc::new(Mutex::new(false));
    let (tx, rx) = mpsc::channel::<EngineMsg>();

    // engine thread: owns the engine, runs ticks, routes completions
    let sd_engine = shutdown.clone();
    std::thread::spawn(move || {
        let mut engine = match make_engine() {
            Ok(e) => e,
            Err(e) => {
                eprintln!("engine construction failed: {e:#}");
                return;
            }
        };
        let mut waiters: std::collections::HashMap<u64, mpsc::Sender<Completion>> =
            Default::default();
        loop {
            if *sd_engine.lock().unwrap() {
                break;
            }
            // drain control messages
            while let Ok(msg) = rx.try_recv() {
                match msg {
                    EngineMsg::Submit(req, reply) => {
                        let id = engine.submit(req);
                        waiters.insert(id, reply);
                    }
                    EngineMsg::Stats(reply) => {
                        let s = &engine.stats;
                        let _ = reply.send(json::write(&obj(vec![
                            ("completed", n(s.completed as f64)),
                            ("decode_steps", n(s.decode_steps as f64)),
                            ("tokens_per_sec", n(s.tokens_per_sec())),
                            ("peak_cache_bytes", n(s.peak_cache_bytes as f64)),
                            ("peak_cache_fp16_bytes",
                             n(s.peak_cache_fp16_bytes as f64)),
                            ("pool_pages_in_use", n(engine.pool_in_use() as f64)),
                        ])));
                    }
                }
            }
            if engine.pending() > 0 {
                if let Err(e) = engine.tick() {
                    eprintln!("engine tick failed: {e:#}");
                }
                for c in engine.take_completions() {
                    if let Some(w) = waiters.remove(&c.id) {
                        let _ = w.send(c);
                    }
                }
            } else {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        }
    });

    // accept loop thread
    let sd_accept = shutdown.clone();
    let join = std::thread::spawn(move || {
        for stream in listener.incoming() {
            if *sd_accept.lock().unwrap() {
                break;
            }
            let Ok(stream) = stream else { continue };
            let tx = tx.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(stream, tx);
            });
        }
    });

    Ok(ServerHandle { port, shutdown, join: Some(join) })
}

fn handle_conn(stream: TcpStream, tx: mpsc::Sender<EngineMsg>) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(());
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let v = match json::parse(trimmed) {
            Ok(v) => v,
            Err(e) => {
                writeln!(out, "{}", json::write(&obj(vec![
                    ("error", json::s(&format!("{e}"))),
                ])))?;
                continue;
            }
        };
        if v.get("cmd").and_then(|c| c.as_str()) == Some("stats") {
            let (rtx, rrx) = mpsc::channel();
            tx.send(EngineMsg::Stats(rtx)).ok();
            let stats = rrx.recv().unwrap_or_else(|_| "{}".into());
            writeln!(out, "{stats}")?;
            continue;
        }
        if v.get("cmd").and_then(|c| c.as_str()) == Some("shutdown") {
            writeln!(out, "{}", json::write(&obj(vec![("ok", Value::Bool(true))])))?;
            return Ok(());
        }
        let req = match parse_request(&v) {
            Ok(r) => r,
            Err(e) => {
                writeln!(out, "{}", json::write(&obj(vec![
                    ("error", json::s(&format!("{e}"))),
                ])))?;
                continue;
            }
        };
        let (rtx, rrx) = mpsc::channel();
        tx.send(EngineMsg::Submit(req, rtx)).ok();
        match rrx.recv() {
            Ok(c) => {
                let toks: Vec<Value> =
                    c.tokens.iter().map(|&t| n(t as f64)).collect();
                let tps = c.tokens.len() as f64 / (c.decode_ms / 1e3).max(1e-9);
                writeln!(out, "{}", json::write(&obj(vec![
                    ("id", n(c.id as f64)),
                    ("tokens", Value::Arr(toks)),
                    ("ttft_ms", n(c.ttft_ms)),
                    ("decode_ms", n(c.decode_ms)),
                    ("queued_ms", n(c.queued_ms)),
                    ("tokens_per_sec", n(tps)),
                ])))?;
            }
            Err(_) => {
                writeln!(out, "{}", json::write(&obj(vec![
                    ("error", json::s("engine dropped request")),
                ])))?;
            }
        }
    }
}

fn parse_request(v: &Value) -> Result<Request> {
    let prompt: Vec<u16> = v.get("prompt").and_then(|p| p.as_arr())
        .context("missing prompt")?
        .iter()
        .map(|t| t.as_usize().context("bad token").map(|x| x as u16))
        .collect::<Result<_>>()?;
    let max_new = v.get("max_new_tokens").and_then(|x| x.as_usize()).unwrap_or(16);
    let temperature = v.get("temperature").and_then(|x| x.as_f64()).unwrap_or(0.0);
    let top_k = v.get("top_k").and_then(|x| x.as_usize()).unwrap_or(0);
    let sampling = if temperature > 0.0 {
        Sampling::TopK { temperature: temperature as f32, k: top_k }
    } else {
        Sampling::Greedy
    };
    Ok(Request {
        id: 0,
        prompt,
        max_new_tokens: max_new,
        sampling,
        stop_token: v.get("stop_token").and_then(|x| x.as_usize()).map(|t| t as u16),
    })
}

/// Blocking client for tests, examples and the CLI.
pub struct Client {
    stream: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(port: u16) -> Result<Client> {
        let s = TcpStream::connect(("127.0.0.1", port))?;
        Ok(Client { stream: BufReader::new(s) })
    }

    pub fn call(&mut self, msg: &Value) -> Result<Value> {
        let mut w = self.stream.get_ref().try_clone()?;
        writeln!(w, "{}", json::write(msg))?;
        let mut line = String::new();
        self.stream.read_line(&mut line)?;
        json::parse(line.trim()).map_err(|e| anyhow::anyhow!("{e}"))
    }

    pub fn generate(&mut self, prompt: &[u16], max_new: usize) -> Result<Value> {
        let toks: Vec<Value> = prompt.iter().map(|&t| n(t as f64)).collect();
        self.call(&obj(vec![
            ("prompt", Value::Arr(toks)),
            ("max_new_tokens", n(max_new as f64)),
        ]))
    }

    pub fn stats(&mut self) -> Result<Value> {
        self.call(&obj(vec![("cmd", json::s("stats"))]))
    }
}
