//! Threaded TCP front-end speaking the v2 newline-JSON **event-frame**
//! protocol (see `quarot::api::wire` for the frame schema), built on top
//! of the unified inference API: the cluster thread owns a
//! [`ClusterService`] (`--shards N` engine shards, each with its own tick
//! thread) and multiplexes its event stream to connections by request id.
//! Connections submit, receive `queued` / `started` / `token` /
//! `finished` / `failed` frames as they are produced, and may
//! `{"cmd":"cancel","id":..}` a request mid-generation — its KV pages
//! return to the owning shard's pool immediately.
//!
//! Backpressure: every shard's admission queue is bounded; a submit is
//! routed to the least-loaded shard and gets a typed `rejected` frame
//! only when **all** shards are at their bound.  Legacy v1 one-shot lines
//! (`{"prompt": ...}` with no `"cmd"`) are still answered with a single
//! completion object.
//!
//! `{"cmd":"chat"}` submits a conversation turn: it parses into the
//! same submit path with a session spec attached, so the engine prepends
//! the stored history, grafts the donated generated-token pages from the
//! prefix trie, and prefills only the new user text (see
//! `quarot::session`).  `{"cmd":"flush-prefix"}` drops every shard's
//! prefix-cache entries and acks once all shards have flushed.
//!
//! `{"cmd":"stats"}` answers flat cluster aggregates (live queue depth,
//! active slots, retire counters, prefix-cache hit rate / tokens saved /
//! pinned pages, session gauges, merged latency percentiles);
//! `{"cmd":"metrics"}` adds the full per-shard breakdown (including each
//! shard's prefix-cache and session gauges).  `{"cmd":"trace"}` drains
//! every shard's span ring into one Chrome-trace frame
//! (`{"v":2,"event":"trace","traceEvents":[..]}`); the serve flags
//! `--trace-buffer N` / `--trace-sample K` size the per-shard rings and
//! the decode-token sampling rate.
//!
//! `{"cmd":"shutdown"}` stops the whole server: it sets the shared
//! shutdown flag (cluster thread and accept loop both exit) rather than
//! just closing the issuing connection, and [`ServerHandle::shutdown`]
//! joins *both* threads.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::api::wire::{self, ClientFrame};
use crate::api::{GenerationEvent, GenerationParams, RequestId, SubmitError};
use crate::audit::AuditedMutex;
use crate::cluster::{ClusterConfig, ClusterService, EngineFactory};
use crate::coordinator::batcher::GenerationEngine;
use crate::util::json::{self, Value};

pub use crate::api::remote::Client;

/// Default admission-queue bound for served engines.
pub const DEFAULT_QUEUE_BOUND: usize = 64;

pub struct ServerHandle {
    pub port: u16,
    shutdown: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    engine: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Block until the server shuts down (e.g. a wire `{"cmd":"shutdown"}`),
    /// joining the accept and engine threads.
    pub fn wait(mut self) {
        if let Some(j) = self.accept.take() {
            let _ = j.join();
        }
        if let Some(j) = self.engine.take() {
            let _ = j.join();
        }
    }

    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // poke the accept loop out of `incoming()`
        let _ = TcpStream::connect(("127.0.0.1", self.port));
        if let Some(j) = self.accept.take() {
            let _ = j.join();
        }
        if let Some(j) = self.engine.take() {
            let _ = j.join();
        }
    }
}

/// Typed event routed from the engine thread to a connection's writer.
type RoutedEvent = (RequestId, GenerationEvent, Option<u64>);

enum EngineMsg {
    Submit {
        params: GenerationParams,
        /// client correlation id, echoed on the `queued` frame
        cid: u64,
        events: mpsc::Sender<RoutedEvent>,
        reply: mpsc::Sender<Result<RequestId, SubmitError>>,
    },
    Cancel {
        id: RequestId,
        reply: mpsc::Sender<bool>,
    },
    Stats {
        reply: mpsc::Sender<String>,
    },
    Metrics {
        reply: mpsc::Sender<String>,
    },
    /// Drain every shard's span ring into one Chrome-trace frame.
    Trace {
        reply: mpsc::Sender<String>,
    },
    /// Flush every shard's prefix cache; the reply fires after all
    /// shards have acked their flush.
    FlushPrefix {
        reply: mpsc::Sender<()>,
    },
}

/// Start serving a single-shard cluster on `port` (0 → ephemeral) with
/// the given admission bound.  See [`serve_sharded`].
pub fn serve<F>(make_engine: F, port: u16, queue_bound: usize) -> Result<ServerHandle>
where
    F: Fn() -> Result<GenerationEngine> + Send + Sync + 'static,
{
    serve_sharded(make_engine, port, queue_bound, 1)
}

/// Start serving on `port` (0 → ephemeral) over `shards` engine shards,
/// each with admission bound `queue_bound`.  Returns once the socket is
/// bound; the cluster loop runs on a background thread.
///
/// `make_engine` is called once *inside each shard's thread* (PJRT
/// handles are not `Send`), so it must be `Fn`, not `FnOnce`.
pub fn serve_sharded<F>(make_engine: F, port: u16, queue_bound: usize,
                        shards: usize) -> Result<ServerHandle>
where
    F: Fn() -> Result<GenerationEngine> + Send + Sync + 'static,
{
    let listener = TcpListener::bind(("127.0.0.1", port)).context("bind")?;
    let port = listener.local_addr()?.port();
    let shutdown = Arc::new(AtomicBool::new(false));
    let (tx, rx) = mpsc::channel::<EngineMsg>();

    // cluster thread: owns the ClusterService (which spawns one tick
    // thread per shard), routes events by request id.  A shard whose
    // engine fails to construct degrades to typed submit errors inside
    // the cluster, so there is no separate failure branch here.
    let sd_engine = shutdown.clone();
    let factory: EngineFactory = Arc::new(make_engine);
    let engine_join = std::thread::spawn(move || {
        let cluster = ClusterService::new(
            factory, ClusterConfig { shards, queue_bound });
        // request id → (connection event sender, cid to echo on Queued)
        let mut routes: HashMap<RequestId,
                                (mpsc::Sender<RoutedEvent>, Option<u64>)> =
            HashMap::new();
        loop {
            if sd_engine.load(Ordering::SeqCst) {
                // cancel everything in flight so every stream still gets
                // its single terminal event before the senders drop
                let live: Vec<RequestId> = routes.keys().copied().collect();
                for id in live {
                    cluster.cancel(id);
                }
                // The terminal events arrive from the shard threads
                // asynchronously, but promptly: each cancel's reply means
                // the shard already emitted (and, per its message loop,
                // immediately flushed) the Finished{cancelled} event, and
                // poll_events synthesizes terminals for dead shards.  The
                // deadline is a safety net against a wedged shard thread,
                // not the expected path.
                let deadline = Instant::now() + Duration::from_secs(2);
                while !routes.is_empty() && Instant::now() < deadline {
                    if !route_all(&cluster, &mut routes) {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }
                break; // dropping the cluster joins the shard threads
            }
            // drain control messages
            while let Ok(msg) = rx.try_recv() {
                match msg {
                    EngineMsg::Submit { params, cid, events, reply } => {
                        match cluster.submit_detached(params) {
                            Ok(id) => {
                                routes.insert(id, (events, Some(cid)));
                                let _ = reply.send(Ok(id));
                            }
                            Err(e) => {
                                let _ = reply.send(Err(e));
                            }
                        }
                    }
                    EngineMsg::Cancel { id, reply } => {
                        let _ = reply.send(cluster.cancel(id));
                    }
                    EngineMsg::Stats { reply } => {
                        let m = cluster.metrics();
                        let _ = reply.send(json::write(
                            &wire::encode_stats(m.summary_pairs())));
                    }
                    EngineMsg::Metrics { reply } => {
                        let m = cluster.metrics();
                        let _ = reply.send(json::write(
                            &wire::encode_metrics(m.full_pairs())));
                    }
                    EngineMsg::Trace { reply } => {
                        let _ = reply.send(json::write(
                            &wire::encode_trace(cluster.trace_events())));
                    }
                    EngineMsg::FlushPrefix { reply } => {
                        cluster.clear_prefix_caches();
                        let _ = reply.send(());
                    }
                }
            }
            // Unlike the pre-cluster server (where poll_events itself ran
            // the engine tick), decode work happens on the shard threads
            // and poll_events is a pure channel drain — so sleep whenever
            // nothing moved, even mid-generation, instead of spinning a
            // core while shards do the real work.
            let routed = route_all(&cluster, &mut routes);
            if !routed {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
    });

    // accept loop thread
    let sd_accept = shutdown.clone();
    let accept_join = std::thread::spawn(move || {
        for stream in listener.incoming() {
            if sd_accept.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let tx = tx.clone();
            let sd = sd_accept.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(stream, tx, sd);
            });
        }
    });

    Ok(ServerHandle {
        port,
        shutdown,
        accept: Some(accept_join),
        engine: Some(engine_join),
    })
}

/// Advance the cluster and fan its events out to the owning connections.
/// Terminal events drop the route.  Returns whether anything moved.
fn route_all(cluster: &ClusterService,
             routes: &mut HashMap<RequestId,
                                  (mpsc::Sender<RoutedEvent>, Option<u64>)>)
             -> bool {
    let events = cluster.poll_events();
    let moved = !events.is_empty();
    for (id, ev) in events {
        let terminal = ev.is_terminal();
        if let Some((sender, cid)) = routes.get_mut(&id) {
            let cid = if matches!(ev, GenerationEvent::Queued) {
                cid.take()
            } else {
                None
            };
            let _ = sender.send((id, ev, cid));
        }
        if terminal {
            routes.remove(&id);
        }
    }
    moved
}

/// Serialize one frame onto the shared connection stream.  Uses the
/// poison-recovering lock: a writer that panicked mid-frame must not
/// take down every other thread of this connection — the client sees a
/// torn line (and resyncs at the next newline) instead of a dead socket
/// with leaked in-flight requests.
fn write_frame(out: &AuditedMutex<TcpStream>, v: &Value) -> std::io::Result<()> {
    let mut w = out.lock_recover();
    writeln!(w, "{}", json::write(v))
}

fn handle_conn(stream: TcpStream, tx: mpsc::Sender<EngineMsg>,
               shutdown: Arc<AtomicBool>) -> Result<()> {
    let local_addr = stream.local_addr()?;
    let out = Arc::new(AuditedMutex::new("server.conn.out",
                                         stream.try_clone()?));
    let mut reader = BufReader::new(stream);

    // one writer per connection: encodes routed events as v2 frames.
    // It also prunes the shared live-set on terminal frames, so the
    // disconnect cleanup below only cancels requests still in flight
    // instead of round-tripping a no-op Cancel per request ever served.
    let (etx, erx) = mpsc::channel::<RoutedEvent>();
    let live: Arc<AuditedMutex<std::collections::HashSet<RequestId>>> =
        Arc::new(AuditedMutex::new("server.conn.live", Default::default()));
    let out_w = out.clone();
    let live_w = live.clone();
    let writer = std::thread::spawn(move || {
        for (id, ev, cid) in erx {
            if ev.is_terminal() {
                live_w.lock_recover().remove(&id);
            }
            if write_frame(&out_w, &wire::encode_event(id, &ev, cid)).is_err() {
                break; // client went away; events drain into the void
            }
        }
    });
    // the loop runs inside a closure so every exit path (including io
    // errors) still reaches the disconnect cleanup below
    let mut conn_loop = || -> Result<()> {
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            break Ok(());
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let v = match json::parse(trimmed) {
            Ok(v) => v,
            Err(e) => {
                write_frame(&out, &wire::encode_error(None, &format!("{e}")))?;
                continue;
            }
        };
        let frame = match wire::parse_client_frame(&v) {
            Ok(f) => f,
            Err(e) => {
                // A malformed *submit* still gets the typed, cid-tagged
                // rejection the protocol defines — an id-less error frame
                // is protocol-fatal client-side and would poison every
                // healthy stream multiplexed on this connection.
                if v.get("cmd").and_then(|c| c.as_str()) == Some("submit") {
                    let cid = v.get("cid").and_then(|c| c.as_usize())
                        .unwrap_or(0) as u64;
                    write_frame(&out, &wire::encode_rejected(
                        cid,
                        &SubmitError::InvalidParams(format!("{e:#}"))))?;
                } else {
                    write_frame(&out,
                                &wire::encode_error(None, &format!("{e:#}")))?;
                }
                continue;
            }
        };
        match frame {
            ClientFrame::Submit { cid, params } => {
                match submit_to_engine(&tx, params, cid, etx.clone()) {
                    Ok(id) => {
                        live.lock_recover().insert(id);
                    }
                    Err(e) => {
                        write_frame(&out, &wire::encode_rejected(cid, &e))?;
                    }
                }
            }
            ClientFrame::Cancel { id } => {
                // best-effort and idempotent: a live request confirms via
                // its Finished{cancelled} frame; a miss (unknown id, or a
                // race with natural completion) is deliberately silent —
                // an id-tagged error frame here could overtake the real
                // terminal frame sitting in the writer channel and fake a
                // second terminal on the client.
                let (rtx, rrx) = mpsc::channel();
                if tx.send(EngineMsg::Cancel { id, reply: rtx }).is_ok() {
                    let _ = rrx.recv();
                }
            }
            ClientFrame::Stats => {
                let (rtx, rrx) = mpsc::channel();
                let _ = tx.send(EngineMsg::Stats { reply: rtx });
                let stats = rrx.recv().unwrap_or_else(|_| "{}".into());
                let mut w = out.lock_recover();
                writeln!(w, "{stats}")?;
            }
            ClientFrame::Metrics => {
                let (rtx, rrx) = mpsc::channel();
                let _ = tx.send(EngineMsg::Metrics { reply: rtx });
                let metrics = rrx.recv().unwrap_or_else(|_| "{}".into());
                let mut w = out.lock_recover();
                writeln!(w, "{metrics}")?;
            }
            ClientFrame::Trace => {
                let (rtx, rrx) = mpsc::channel();
                let _ = tx.send(EngineMsg::Trace { reply: rtx });
                let trace = rrx.recv().unwrap_or_else(|_| "{}".into());
                let mut w = out.lock_recover();
                writeln!(w, "{trace}")?;
            }
            ClientFrame::FlushPrefix => {
                let (rtx, rrx) = mpsc::channel();
                if tx.send(EngineMsg::FlushPrefix { reply: rtx }).is_ok() {
                    let _ = rrx.recv();
                }
                write_frame(&out, &wire::encode_flush_prefix_ack())?;
            }
            ClientFrame::Shutdown => {
                // the satellite fix: stop the *whole server*, not just
                // this connection — flag first, then poke the accept loop
                shutdown.store(true, Ordering::SeqCst);
                let _ = TcpStream::connect(local_addr);
                write_frame(&out, &wire::encode_shutdown_ack())?;
                break Ok(());
            }
            ClientFrame::LegacyGenerate { params } => {
                // v1 one-shot: private event channel, folded into the
                // old single-object response
                let (ltx, lrx) = mpsc::channel::<RoutedEvent>();
                match submit_to_engine(&tx, params, 0, ltx) {
                    Ok(_) => {
                        let resp = fold_legacy(&lrx);
                        let mut w = out.lock_recover();
                        writeln!(w, "{}", json::write(&resp))?;
                    }
                    Err(e) => {
                        let mut w = out.lock_recover();
                        writeln!(w, "{}", json::write(&json::obj(vec![
                            ("error", json::s(&format!("{e}"))),
                        ])))?;
                    }
                }
            }
        }
    }
    };
    let result = conn_loop();
    // a dropped connection must not leak slots: cancel whatever is still
    // in flight (terminal requests were already pruned by the writer).
    // lock_recover: even if the writer thread panicked holding the set,
    // this cleanup must still run — a poisoned lock here would leak the
    // very slots it exists to reclaim
    let still_live: Vec<RequestId> =
        live.lock_recover().iter().copied().collect();
    for id in still_live {
        let (rtx, rrx) = mpsc::channel();
        if tx.send(EngineMsg::Cancel { id, reply: rtx }).is_ok() {
            let _ = rrx.recv();
        }
    }
    drop(etx);
    let _ = writer.join();
    result
}

fn submit_to_engine(tx: &mpsc::Sender<EngineMsg>, params: GenerationParams,
                    cid: u64, events: mpsc::Sender<RoutedEvent>)
                    -> Result<RequestId, SubmitError> {
    let (rtx, rrx) = mpsc::channel();
    tx.send(EngineMsg::Submit { params, cid, events, reply: rtx })
        .map_err(|_| SubmitError::Transport("engine gone".into()))?;
    match rrx.recv() {
        Ok(r) => r,
        Err(_) => Err(SubmitError::Transport("engine dropped request".into())),
    }
}

/// Fold a private event stream into the legacy v1 one-shot response —
/// the same shaping `Client::generate` uses ([`outcome_to_value`]), so
/// the v1 contract lives in exactly one place.
fn fold_legacy(rx: &mpsc::Receiver<RoutedEvent>) -> Value {
    let mut tokens: Vec<u16> = Vec::new();
    for (id, ev, _) in rx {
        match ev {
            GenerationEvent::Token { token, .. } => tokens.push(token),
            GenerationEvent::Finished { reason, stats } => {
                return crate::api::remote::outcome_to_value(
                    &crate::api::GenerationOutcome { id, tokens, reason, stats });
            }
            GenerationEvent::Failed { error } => {
                return json::obj(vec![("error", json::s(&error))]);
            }
            _ => {}
        }
    }
    json::obj(vec![("error", json::s("engine dropped request"))])
}
