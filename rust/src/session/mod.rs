//! Session subsystem — multi-turn chat serving over the prefix cache.
//!
//! A *session* is one conversation: the server remembers the full token
//! chain (every user prompt and every generated reply) so a follow-up
//! turn submits only the new user text and the engine replays history
//! from the shared prefix trie instead of re-prefilling it.  Two
//! mechanisms make that work:
//!
//! * **Generated-token donation.**  The prefix cache (PR 5) only ever
//!   cached *prompt* pages, so turn k+1 — whose prompt is
//!   `history ++ new text` — missed on everything past turn k's prompt.
//!   At natural retirement the engine now donates full pages of
//!   `prompt ++ generated` back into the trie
//!   (`GenerationEngine::complete_session_turn`), so the next turn grafts the
//!   whole turn-1..k chain and prefills only the new user text: TTFT on
//!   turn k is proportional to the new text, not the conversation.
//! * **Chain pinning.**  Donated pages are only useful if they survive
//!   until the next turn, so each session pins its latest chain in the
//!   trie ([`crate::coordinator::prefix::PrefixCache::pin_chain`]),
//!   exempting it from LRU eviction.  The pin moves forward every turn
//!   (pin the new, longer chain; unpin the previous one) and is released
//!   when the session itself is evicted — sessions, not pages, are the
//!   retention unit, bounded by the `--sessions N` budget (LRU) and an
//!   optional TTL.
//!
//! The [`SessionStore`] lives inside each engine shard: one store per
//! shard, histories resident where the pages are.  The cluster router
//! learns session → shard ownership from finished events and routes
//! resumes back to the owning shard ahead of prefix affinity and load
//! (`cluster::ClusterCore`), falling through gracefully when that shard
//! is dead or full — the landing shard then re-registers the id with an
//! empty history and serves the turn cold (correct, just uncached).

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::api::QualityTier;

/// Engine-side default for the session budget (`serve --sessions N`
/// overrides; 0 disables the subsystem entirely).
pub const DEFAULT_SESSION_BUDGET: usize = 64;

/// What a submit asks of the session layer.  `New` allocates an id and
/// starts an empty conversation; `Resume(id)` prepends the stored
/// history to the request's prompt.  Resuming an unknown id (evicted,
/// or a cluster-fallback landing on a foreign shard) re-registers it
/// with an empty history instead of erroring — the turn runs cold.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SessionSpec {
    New,
    Resume(u64),
}

struct Session {
    tier: QualityTier,
    /// full conversation chain: prompt₁ ++ reply₁ ++ prompt₂ ++ reply₂ …
    history: Vec<u16>,
    /// the chain currently pinned in the prefix trie (page-aligned
    /// prefix of a previous turn's cache contents); None before the
    /// first donation
    pinned: Option<Vec<u16>>,
    /// retired turns recorded into `history`
    turns: usize,
    /// LRU stamp (store clock)
    last_used: u64,
    /// wall-clock touch for TTL eviction
    touched: Instant,
}

/// A session the store evicted; the engine must release its trie pin.
pub struct EvictedSession {
    pub id: u64,
    pub tier: QualityTier,
    pub pinned: Option<Vec<u16>>,
}

/// Outcome of resolving a [`SessionSpec`] at submit time.
pub struct Resolution {
    /// the assigned (or resumed) session id
    pub id: u64,
    /// stored history to prepend to the request's prompt (empty on a
    /// fresh or re-registered session)
    pub history: Vec<u16>,
    /// turns already retired into `history` — > 0 means this request
    /// benefits from donated pages (the donation-savings gauge keys on
    /// this)
    pub prior_turns: usize,
    /// the session's tier (fixed at creation; resumes inherit it so the
    /// chain stays graftable — the trie is tier-keyed)
    pub tier: QualityTier,
    /// sessions evicted to make room (budget / TTL); unpin their chains
    pub evicted: Vec<EvictedSession>,
}

/// Pin handover returned by [`SessionStore::complete`]: pin the new
/// chain first, then unpin the old one (pins are counts, so the shared
/// prefix nets out).
pub struct PinUpdate {
    pub tier: QualityTier,
    pub pin: Option<Vec<u16>>,
    pub unpin: Option<Vec<u16>>,
}

/// Per-engine conversation registry: assigns session ids at submit
/// time, stores each session's token chain, and tracks which chain is
/// pinned in the prefix trie.  Eviction is LRU under `max_sessions`
/// plus an optional idle TTL; both return the evicted chains so the
/// engine can unpin them.
pub struct SessionStore {
    max_sessions: usize,
    ttl: Option<Duration>,
    /// id space: `start + k·stride` — the cluster gives each shard a
    /// disjoint residue class so ids are unique cluster-wide
    next_id: u64,
    stride: u64,
    clock: u64,
    sessions: HashMap<u64, Session>,
}

impl SessionStore {
    pub fn new(max_sessions: usize) -> SessionStore {
        SessionStore {
            max_sessions,
            ttl: None,
            next_id: 1,
            stride: 1,
            clock: 0,
            sessions: HashMap::new(),
        }
    }

    /// 0 disables the subsystem: resolves return `None` and requests run
    /// as plain one-shots.
    pub fn enabled(&self) -> bool {
        self.max_sessions > 0
    }

    pub fn live(&self) -> usize {
        self.sessions.len()
    }

    /// Shrink (or grow) the budget; sessions over the new budget are
    /// evicted LRU-first and returned for unpinning.
    pub fn set_budget(&mut self, max_sessions: usize) -> Vec<EvictedSession> {
        self.max_sessions = max_sessions;
        let mut evicted = Vec::new();
        while self.sessions.len() > self.max_sessions {
            if let Some(e) = self.evict_lru() {
                evicted.push(e);
            } else {
                break;
            }
        }
        evicted
    }

    /// Idle sessions older than `ttl_ms` are evicted lazily at the next
    /// resolve.  `None` disables TTL eviction (the default).
    pub fn set_ttl_ms(&mut self, ttl_ms: Option<u64>) {
        self.ttl = ttl_ms.map(Duration::from_millis);
    }

    /// Partition the id space (`start + k·stride`) so every shard of a
    /// cluster assigns globally-unique session ids.
    pub fn set_id_space(&mut self, start: u64, stride: u64) {
        assert!(stride > 0);
        self.next_id = start.max(1);
        self.stride = stride;
    }

    /// Stored conversation chain (None for unknown ids).
    pub fn history(&self, id: u64) -> Option<&[u16]> {
        self.sessions.get(&id).map(|s| s.history.as_slice())
    }

    /// Turns already retired into the session's history.
    pub fn prior_turns(&self, id: u64) -> usize {
        self.sessions.get(&id).map_or(0, |s| s.turns)
    }

    fn evict_lru(&mut self) -> Option<EvictedSession> {
        let id = *self.sessions.iter()
            .min_by_key(|(_, s)| s.last_used)
            .map(|(id, _)| id)?;
        self.evict(id)
    }

    fn evict(&mut self, id: u64) -> Option<EvictedSession> {
        self.sessions.remove(&id)
            .map(|s| EvictedSession { id, tier: s.tier, pinned: s.pinned })
    }

    fn sweep_expired(&mut self, out: &mut Vec<EvictedSession>) {
        let Some(ttl) = self.ttl else { return };
        let now = Instant::now();
        let expired: Vec<u64> = self.sessions.iter()
            .filter(|(_, s)| now.duration_since(s.touched) > ttl)
            .map(|(&id, _)| id)
            .collect();
        for id in expired {
            out.extend(self.evict(id));
        }
    }

    /// Resolve a submit's [`SessionSpec`] — assign or look up the id,
    /// hand back the history to prepend, and evict (budget/TTL) as
    /// needed.  Returns `None` when the subsystem is disabled.
    pub fn resolve(&mut self, spec: SessionSpec, default_tier: QualityTier)
                   -> Option<Resolution> {
        if !self.enabled() {
            return None;
        }
        self.clock += 1;
        let mut evicted = Vec::new();
        self.sweep_expired(&mut evicted);
        let id = match spec {
            SessionSpec::Resume(id) if self.sessions.contains_key(&id) => {
                let s = self.sessions.get_mut(&id).unwrap();
                s.last_used = self.clock;
                s.touched = Instant::now();
                return Some(Resolution {
                    id,
                    history: s.history.clone(),
                    prior_turns: s.turns,
                    tier: s.tier,
                    evicted,
                });
            }
            // unknown id: re-register gracefully (evicted session, or a
            // cluster fallback landing off the owning shard)
            SessionSpec::Resume(id) => id,
            SessionSpec::New => {
                let id = self.next_id;
                self.next_id += self.stride;
                id
            }
        };
        while self.sessions.len() >= self.max_sessions {
            match self.evict_lru() {
                Some(e) => evicted.push(e),
                None => break,
            }
        }
        self.sessions.insert(id, Session {
            tier: default_tier,
            history: Vec::new(),
            pinned: None,
            turns: 0,
            last_used: self.clock,
            touched: Instant::now(),
        });
        Some(Resolution {
            id,
            history: Vec::new(),
            prior_turns: 0,
            tier: default_tier,
            evicted,
        })
    }

    /// Record a retired turn: `history` becomes the full chain including
    /// the reply, and — when the engine donated pages — the pin moves
    /// from the previous chain to `donated_chain`.  Returns `None` when
    /// the session vanished mid-flight (evicted under pressure); the
    /// reply is simply not remembered.
    pub fn complete(&mut self, id: u64, new_history: Vec<u16>,
                    donated_chain: Option<Vec<u16>>) -> Option<PinUpdate> {
        self.clock += 1;
        let s = self.sessions.get_mut(&id)?;
        s.history = new_history;
        s.turns += 1;
        s.last_used = self.clock;
        s.touched = Instant::now();
        match donated_chain {
            // no donation this turn (prefix cache disabled, or the turn
            // retired at admission): the previous pin stands
            None => Some(PinUpdate { tier: s.tier, pin: None, unpin: None }),
            Some(chain) => {
                let unpin = s.pinned.replace(chain.clone());
                Some(PinUpdate { tier: s.tier, pin: Some(chain), unpin })
            }
        }
    }

    /// Evict every session (engine shutdown / tests), returning the
    /// chains to unpin.
    pub fn clear(&mut self) -> Vec<EvictedSession> {
        let ids: Vec<u64> = self.sessions.keys().copied().collect();
        ids.into_iter().filter_map(|id| self.evict(id)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const T: QualityTier = QualityTier::Kv4;

    #[test]
    fn new_then_resume_threads_history_and_turns() {
        let mut store = SessionStore::new(4);
        let r1 = store.resolve(SessionSpec::New, T).unwrap();
        assert_eq!((r1.id, r1.prior_turns), (1, 0));
        assert!(r1.history.is_empty() && r1.evicted.is_empty());

        // turn 1 retires: prompt [1,2] + reply [3,4]
        let upd = store.complete(r1.id, vec![1, 2, 3, 4],
                                 Some(vec![1, 2, 3])).unwrap();
        assert_eq!(upd.pin.as_deref(), Some(&[1, 2, 3][..]));
        assert_eq!(upd.unpin, None);

        let r2 = store.resolve(SessionSpec::Resume(r1.id), T).unwrap();
        assert_eq!(r2.id, r1.id);
        assert_eq!(r2.history, vec![1, 2, 3, 4]);
        assert_eq!(r2.prior_turns, 1);

        // turn 2 retires with a longer chain: pin moves forward
        let upd = store.complete(r1.id, vec![1, 2, 3, 4, 5, 6],
                                 Some(vec![1, 2, 3, 4, 5])).unwrap();
        assert_eq!(upd.pin.as_deref(), Some(&[1, 2, 3, 4, 5][..]));
        assert_eq!(upd.unpin.as_deref(), Some(&[1, 2, 3][..]));
        assert_eq!(store.prior_turns(r1.id), 2);
    }

    #[test]
    fn lru_eviction_under_budget_returns_pinned_chains() {
        let mut store = SessionStore::new(2);
        let a = store.resolve(SessionSpec::New, T).unwrap().id;
        let b = store.resolve(SessionSpec::New, T).unwrap().id;
        store.complete(a, vec![1], Some(vec![1])).unwrap();
        store.complete(b, vec![2], Some(vec![2])).unwrap();
        // touch a so b is the LRU
        store.resolve(SessionSpec::Resume(a), T).unwrap();

        let r = store.resolve(SessionSpec::New, T).unwrap();
        assert_eq!(r.evicted.len(), 1, "budget of 2 must evict one");
        let e = &r.evicted[0];
        assert_eq!(e.id, b, "LRU session must go first");
        assert_eq!(e.pinned.as_deref(), Some(&[2][..]));
        assert_eq!(store.live(), 2);
        assert!(store.history(b).is_none());
    }

    #[test]
    fn unknown_resume_reregisters_cold() {
        let mut store = SessionStore::new(2);
        let r = store.resolve(SessionSpec::Resume(77), T).unwrap();
        assert_eq!(r.id, 77);
        assert!(r.history.is_empty());
        assert_eq!(r.prior_turns, 0);
        assert_eq!(store.live(), 1);
        // a completion for an id evicted mid-flight is dropped, not a panic
        assert!(store.complete(99, vec![1], None).is_none());
    }

    #[test]
    fn disabled_store_is_inert_and_id_space_partitions() {
        let mut store = SessionStore::new(0);
        assert!(!store.enabled());
        assert!(store.resolve(SessionSpec::New, T).is_none());

        let mut store = SessionStore::new(8);
        store.set_id_space(3, 4); // shard 2 of 4
        let a = store.resolve(SessionSpec::New, T).unwrap().id;
        let b = store.resolve(SessionSpec::New, T).unwrap().id;
        assert_eq!((a, b), (3, 7), "ids must stay in the shard's residue");
    }

    #[test]
    fn shrinking_budget_and_clear_hand_back_pins() {
        let mut store = SessionStore::new(4);
        for i in 0..4u64 {
            let id = store.resolve(SessionSpec::New, T).unwrap().id;
            store.complete(id, vec![i as u16], Some(vec![i as u16])).unwrap();
        }
        let evicted = store.set_budget(2);
        assert_eq!(evicted.len(), 2);
        assert!(evicted.iter().all(|e| e.pinned.is_some()));
        let rest = store.clear();
        assert_eq!(rest.len(), 2);
        assert_eq!(store.live(), 0);
    }
}
