//! Row-major f32 matrix for the offline quantization toolchain.
//!
//! This is deliberately *not* the serving hot path (that's [`crate::gemm`]
//! and the PJRT executables) — it's the convenience container GPTQ,
//! SmoothQuant, the rust-side QuaRot transform and the tests are written
//! against.

use crate::util::prng::Rng;

#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
        Mat { rows, cols, data: rng.normal_vec(rows * cols) }
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self[(r, c)]).collect()
    }

    pub fn set_col(&mut self, c: usize, v: &[f32]) {
        assert_eq!(v.len(), self.rows);
        for r in 0..self.rows {
            self[(r, c)] = v[r];
        }
    }

    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[(c, r)] = self[(r, c)];
            }
        }
        out
    }

    /// Blocked matmul with k-inner loop kept contiguous for both operands.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let a_row = self.row(i);
            let o_row = &mut out.data[i * n..(i + 1) * n];
            for (p, &a) in a_row.iter().enumerate().take(k) {
                let b_row = &other.data[p * n..(p + 1) * n];
                if a != 0.0 {
                    for (o, &b) in o_row.iter_mut().zip(b_row) {
                        *o += a * b;
                    }
                }
            }
        }
        out
    }

    pub fn scale(&self, s: f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    pub fn frob(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// μ-incoherence (paper eq. 2): max|W| / (||W||_F / sqrt(mn)).
    pub fn incoherence(&self) -> f64 {
        let rms = self.frob() / (self.data.len() as f64).sqrt();
        self.abs_max() as f64 / rms.max(1e-12)
    }

    /// Scale every row in place.
    pub fn scale_rows(&mut self, s: &[f32]) {
        assert_eq!(s.len(), self.rows);
        for r in 0..self.rows {
            let sr = s[r];
            for v in self.row_mut(r) {
                *v *= sr;
            }
        }
    }

    /// Scale every column in place.
    pub fn scale_cols(&mut self, s: &[f32]) {
        assert_eq!(s.len(), self.cols);
        for r in 0..self.rows {
            for (c, v) in self.row_mut(r).iter_mut().enumerate() {
                *v *= s[c];
            }
        }
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;

    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(0);
        let a = Mat::randn(4, 6, &mut rng);
        let got = a.matmul(&Mat::eye(6));
        for (x, y) in got.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let a = Mat::randn(5, 3, &mut rng);
        assert_eq!(a.t().t(), a);
    }

    #[test]
    fn incoherence_of_spike() {
        let mut m = Mat::zeros(8, 8);
        m[(0, 0)] = 8.0;
        // rms = 8/8 = 1, max = 8 → incoherence 8
        assert!((m.incoherence() - 8.0).abs() < 1e-9);
        // uniform matrix has incoherence 1
        let u = Mat::from_vec(2, 2, vec![3.0; 4]);
        assert!((u.incoherence() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn row_col_scaling() {
        let mut m = Mat::from_vec(2, 2, vec![1.0; 4]);
        m.scale_rows(&[2.0, 3.0]);
        m.scale_cols(&[1.0, 10.0]);
        assert_eq!(m.data, vec![2.0, 20.0, 3.0, 30.0]);
    }
}
