//! Native GEMM substrate for the performance tables (Fig. 7 / Tables 14-16).
//!
//! The paper measures a CUTLASS INT4 TensorCore GEMM against FP16 cuBLAS on
//! an RTX 3090.  This environment is a CPU, so the comparison is re-staged
//! with the same *mechanism*: a packed-int4 GEMM moves 8× fewer weight
//! bytes than f32 (4× vs the paper's fp16 baseline) and its multiplies are
//! cheap integer ops, so at memory-bound shapes it wins by roughly the
//! bandwidth ratio — the same roofline argument that gives CUTLASS its
//! speedup.  Reported numbers are *ratios*, matching the paper's framing.
//!
//! Three kernels, one loop structure (k-inner, 4-column unrolled panels):
//!   * `gemm_f32`      — the FP16-baseline stand-in,
//!   * `gemm_i8`       — INT8 codes, i32 accumulation,
//!   * `gemm_i4packed` — 2 codes/byte, unpacked in-register, i32 accum.
//!
//! All take activations row-major (T × K) and weights column-major panels
//! (K × N packed as N-major), and fuse the dequant epilogue
//! (row-scale × col-scale) like the paper's kernel.
//!
//! These free functions are the `ScalarRef` kernels of the pluggable
//! [`crate::backend`] subsystem — the bit-exact oracle the `Blocked` and
//! `Threaded` backends are property-tested against.  Serving and bench
//! code should go through [`crate::backend::ComputeBackend`] rather than
//! calling these directly.

/// Column-major weight container for the GEMM kernels: `data[c][k]`.
pub struct WeightsF32 {
    pub k: usize,
    pub n: usize,
    pub cols: Vec<f32>, // n * k, column-major
}

pub struct WeightsI8 {
    pub k: usize,
    pub n: usize,
    pub cols: Vec<i8>,
    pub scales: Vec<f32>, // per column
}

pub struct WeightsI4 {
    pub k: usize,
    pub n: usize,
    pub cols: Vec<u8>, // n * ceil(k/2), nibble-packed per column
    pub scales: Vec<f32>,
}

impl WeightsF32 {
    pub fn from_row_major(w: &[f32], k: usize, n: usize) -> Self {
        let mut cols = vec![0.0f32; k * n];
        for r in 0..k {
            for c in 0..n {
                cols[c * k + r] = w[r * n + c];
            }
        }
        WeightsF32 { k, n, cols }
    }

    pub fn bytes(&self) -> usize {
        self.cols.len() * 4
    }
}

impl WeightsI8 {
    /// Per-column symmetric quantization of a row-major (k × n) f32 weight.
    ///
    /// Codes use the **full signed range** `[-2^(b-1), 2^(b-1)-1]` (with
    /// `levels = sym_levels(bits) = 2^(b-1)-1`): the scale maps ±amax to
    /// ±(levels + 0.5), so the negative extreme rounds to -(levels+1)
    /// (e.g. -8 at 4 bits) while the positive extreme clamps to +levels.
    /// The old code clamped at -levels, wasting the bottom code — an
    /// off-by-one at the negative end of the packed containers.
    ///
    /// Note this full-range convention applies to the *perf-path*
    /// integer containers (`WeightsI8`/`WeightsI4`) only; the accuracy
    /// pipeline's fake-quantizers (`quant::rtn`, and the python reference
    /// kernel they mirror) deliberately keep the restricted ±levels grid
    /// so their outputs stay bit-comparable with the compiled graphs.
    pub fn quantize(w: &[f32], k: usize, n: usize, bits: u32) -> Self {
        let levels = crate::quant::sym_levels(bits) as f32;
        let mut scales = vec![0.0f32; n];
        for c in 0..n {
            let amax = (0..k).fold(0.0f32, |m, r| m.max(w[r * n + c].abs()));
            scales[c] = amax.max(1e-8) / (levels + 0.5);
        }
        let mut cols = vec![0i8; k * n];
        for c in 0..n {
            for r in 0..k {
                cols[c * k + r] = (w[r * n + c] / scales[c])
                    .round()
                    .clamp(-(levels + 1.0), levels) as i8;
            }
        }
        WeightsI8 { k, n, cols, scales }
    }

    pub fn bytes(&self) -> usize {
        self.cols.len() + self.scales.len() * 4
    }
}

impl WeightsI4 {
    pub fn quantize(w: &[f32], k: usize, n: usize) -> Self {
        let q8 = WeightsI8::quantize(w, k, n, 4);
        let kp = k.div_ceil(2);
        let mut cols = vec![0u8; kp * n];
        for c in 0..n {
            let col = &q8.cols[c * k..(c + 1) * k];
            for (i, pair) in col.chunks(2).enumerate() {
                let lo = (pair[0] as u8) & 0x0F;
                let hi = if pair.len() > 1 { (pair[1] as u8) & 0x0F } else { 0 };
                cols[c * kp + i] = lo | (hi << 4);
            }
        }
        WeightsI4 { k, n, cols, scales: q8.scales }
    }

    pub fn bytes(&self) -> usize {
        self.cols.len() + self.scales.len() * 4
    }
}

/// y (T×N) = x (T×K) @ W, f32 reference path.
pub fn gemm_f32(x: &[f32], t: usize, w: &WeightsF32, y: &mut [f32]) {
    let (k, n) = (w.k, w.n);
    assert_eq!(x.len(), t * k);
    assert_eq!(y.len(), t * n);
    for row in 0..t {
        let xr = &x[row * k..(row + 1) * k];
        let yr = &mut y[row * n..(row + 1) * n];
        for c in 0..n {
            let wc = &w.cols[c * k..(c + 1) * k];
            let mut acc = 0.0f32;
            // 4-way unrolled dot
            let mut i = 0;
            let kk = k & !3;
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0, 0.0, 0.0);
            while i < kk {
                a0 += xr[i] * wc[i];
                a1 += xr[i + 1] * wc[i + 1];
                a2 += xr[i + 2] * wc[i + 2];
                a3 += xr[i + 3] * wc[i + 3];
                i += 4;
            }
            acc += a0 + a1 + a2 + a3;
            while i < k {
                acc += xr[i] * wc[i];
                i += 1;
            }
            yr[c] = acc;
        }
    }
}

/// Quantize one activation row per-token symmetric, emitting i8 codes.
pub fn quant_row(x: &[f32], bits: u32, clip: f32, out: &mut [i8]) -> f32 {
    let levels = crate::quant::sym_levels(bits) as f32;
    let amax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let s = (amax * clip).max(1e-8) / levels;
    let inv = 1.0 / s;
    for (o, &v) in out.iter_mut().zip(x) {
        *o = (v * inv).round().clamp(-levels, levels) as i8;
    }
    s
}

/// Full 4/8-bit linear layer: quantize per token, i8 GEMM, dequant epilogue.
pub fn gemm_i8(x: &[f32], t: usize, w: &WeightsI8, bits: u32, clip: f32,
               y: &mut [f32], scratch: &mut Vec<i8>) {
    let (k, n) = (w.k, w.n);
    scratch.resize(k, 0);
    for row in 0..t {
        let xr = &x[row * k..(row + 1) * k];
        let xs = quant_row(xr, bits, clip, scratch);
        let yr = &mut y[row * n..(row + 1) * n];
        for c in 0..n {
            let wc = &w.cols[c * k..(c + 1) * k];
            let mut acc = 0i32;
            let mut i = 0;
            let kk = k & !3;
            let (mut a0, mut a1, mut a2, mut a3) = (0i32, 0, 0, 0);
            while i < kk {
                a0 += scratch[i] as i32 * wc[i] as i32;
                a1 += scratch[i + 1] as i32 * wc[i + 1] as i32;
                a2 += scratch[i + 2] as i32 * wc[i + 2] as i32;
                a3 += scratch[i + 3] as i32 * wc[i + 3] as i32;
                i += 4;
            }
            acc += a0 + a1 + a2 + a3;
            while i < k {
                acc += scratch[i] as i32 * wc[i] as i32;
                i += 1;
            }
            yr[c] = acc as f32 * xs * w.scales[c];
        }
    }
}

/// byte → (lo nibble, hi nibble) sign-extended, precomputed once.
/// Replaces two shift/sign-extend chains per byte with one indexed load —
/// the §Perf iteration that closed most of the int4-vs-f32 gap on the
/// scalar core (EXPERIMENTS.md §Perf).
static NIBBLE_LUT: std::sync::OnceLock<[(i8, i8); 256]> = std::sync::OnceLock::new();

pub(crate) fn nibble_lut() -> &'static [(i8, i8); 256] {
    NIBBLE_LUT.get_or_init(|| {
        std::array::from_fn(|b| {
            let byte = b as u8;
            ((((byte & 0x0F) << 4) as i8) >> 4, (byte & 0xF0) as i8 >> 4)
        })
    })
}

/// Packed-int4 linear layer: weights stream as nibbles (the IO win).
pub fn gemm_i4(x: &[f32], t: usize, w: &WeightsI4, clip: f32,
               y: &mut [f32], scratch: &mut Vec<i8>) {
    let (k, n) = (w.k, w.n);
    let kp = k.div_ceil(2);
    let lut = nibble_lut();
    scratch.resize(k, 0);
    for row in 0..t {
        let xr = &x[row * k..(row + 1) * k];
        let xs = quant_row(xr, 4, clip, scratch);
        let yr = &mut y[row * n..(row + 1) * n];
        for c in 0..n {
            let wc = &w.cols[c * kp..(c + 1) * kp];
            let pairs = k / 2;
            // two independent accumulators break the dependency chain
            let (mut a0, mut a1) = (0i32, 0i32);
            for i in 0..pairs {
                let (lo, hi) = lut[wc[i] as usize];
                a0 += scratch[2 * i] as i32 * lo as i32;
                a1 += scratch[2 * i + 1] as i32 * hi as i32;
            }
            let mut acc = a0 + a1;
            if k % 2 == 1 {
                let (lo, _) = lut[wc[kp - 1] as usize];
                acc += scratch[k - 1] as i32 * lo as i32;
            }
            yr[c] = acc as f32 * xs * w.scales[c];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prng::Rng, prop};

    fn setup(t: usize, k: usize, n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (rng.normal_vec(t * k), rng.normal_vec(k * n))
    }

    #[test]
    fn f32_matches_naive() {
        let (x, w) = setup(3, 17, 5, 0);
        let wf = WeightsF32::from_row_major(&w, 17, 5);
        let mut y = vec![0.0; 15];
        gemm_f32(&x, 3, &wf, &mut y);
        for r in 0..3 {
            for c in 0..5 {
                let want: f32 = (0..17).map(|i| x[r * 17 + i] * w[i * 5 + c]).sum();
                assert!((y[r * 5 + c] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn i8_tracks_f32() {
        let (x, w) = setup(4, 64, 8, 1);
        let wf = WeightsF32::from_row_major(&w, 64, 8);
        let wq = WeightsI8::quantize(&w, 64, 8, 8);
        let mut y0 = vec![0.0; 32];
        let mut y1 = vec![0.0; 32];
        gemm_f32(&x, 4, &wf, &mut y0);
        gemm_i8(&x, 4, &wq, 8, 1.0, &mut y1, &mut Vec::new());
        let scale = y0.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        prop::assert_close(&y1, &y0, 0.05 * scale).unwrap();
    }

    #[test]
    fn i4_packed_equals_i8_at_4bits() {
        // same codes, different storage: results must match exactly
        let (x, w) = setup(2, 32, 6, 2);
        let w8 = WeightsI8::quantize(&w, 32, 6, 4);
        let w4 = WeightsI4::quantize(&w, 32, 6);
        let mut y8 = vec![0.0; 12];
        let mut y4 = vec![0.0; 12];
        gemm_i8(&x, 2, &w8, 4, 0.9, &mut y8, &mut Vec::new());
        gemm_i4(&x, 2, &w4, 0.9, &mut y4, &mut Vec::new());
        prop::assert_close(&y4, &y8, 1e-5).unwrap();
    }

    #[test]
    fn odd_k_handled() {
        let (x, w) = setup(2, 33, 4, 3);
        let w8 = WeightsI8::quantize(&w, 33, 4, 4);
        let w4 = WeightsI4::quantize(&w, 33, 4);
        let mut y8 = vec![0.0; 8];
        let mut y4 = vec![0.0; 8];
        gemm_i8(&x, 2, &w8, 4, 0.9, &mut y8, &mut Vec::new());
        gemm_i4(&x, 2, &w4, 0.9, &mut y4, &mut Vec::new());
        prop::assert_close(&y4, &y8, 1e-5).unwrap();
    }

    #[test]
    fn symmetric_weight_quant_uses_full_signed_range() {
        // regression: the negative extreme must reach -(2^(b-1)), not
        // stop one code short at -(2^(b-1)-1).  amax = 7.5 makes the
        // scale exactly 1.0, so ±amax/scale = ±7.5 exactly: round() goes
        // away from zero, the negative end lands on -8, the positive end
        // clamps to +7.
        let w = vec![7.5f32, -7.5, 3.0, -1.0];
        let q = WeightsI8::quantize(&w, 4, 1, 4);
        let min = q.cols.iter().copied().min().unwrap();
        let max = q.cols.iter().copied().max().unwrap();
        assert_eq!(min, -8, "negative end must use the full signed range");
        assert_eq!(max, 7);
        // round-trip error stays within half a quantization step
        for (&wi, &c) in w.iter().zip(&q.cols) {
            let back = c as f32 * q.scales[0];
            assert!((wi - back).abs() <= q.scales[0] * 0.5 + 1e-6,
                    "{wi} vs {back}");
        }
        // int4 packed container carries the same full-range codes
        let q4 = WeightsI4::quantize(&w, 4, 1);
        let mut codes = vec![0i8; 4];
        crate::quant::kv::unpack_nibbles(&q4.cols, 4, &mut codes);
        assert_eq!(codes, q.cols);
    }

    #[test]
    fn memory_footprint_ratios() {
        let w4 = WeightsI4::quantize(&vec![0.5; 4096 * 4096], 4096, 4096);
        let w8 = WeightsI8::quantize(&vec![0.5; 4096 * 4096], 4096, 4096, 8);
        let wf = WeightsF32::from_row_major(&vec![0.5; 4096 * 4096], 4096, 4096);
        let r48 = w8.bytes() as f64 / w4.bytes() as f64;
        let r4f = wf.bytes() as f64 / w4.bytes() as f64;
        assert!((r48 - 2.0).abs() < 0.05, "{r48}");
        assert!((r4f - 8.0).abs() < 0.2, "{r4f}");
    }

    #[test]
    fn quant_property_i4_bound() {
        prop::check("gemm-i4-error", 10, |rng| {
            let (t, k, n) = (2, 16 + rng.below(32) * 2, 4);
            let x = rng.normal_vec(t * k);
            let w = rng.normal_vec(k * n);
            let wf = WeightsF32::from_row_major(&w, k, n);
            let w4 = WeightsI4::quantize(&w, k, n);
            let mut y0 = vec![0.0; t * n];
            let mut y1 = vec![0.0; t * n];
            gemm_f32(&x, t, &wf, &mut y0);
            gemm_i4(&x, t, &w4, 1.0, &mut y1, &mut Vec::new());
            let scale: f32 = y0.iter().map(|v| v.abs()).sum::<f32>() / y0.len() as f32;
            let err: f32 = y0.iter().zip(&y1).map(|(a, b)| (a - b).abs()).sum::<f32>()
                / y0.len() as f32;
            // int4 on both operands: relative error grows with 1/levels on
            // each side plus cancellation in the dot — 0.45·mean|y| is a
            // safe envelope that still catches systematic bugs.
            crate::prop_assert!(err < 0.45 * scale.max(1.0),
                                "int4 gemm error {err} vs scale {scale}");
            Ok(())
        });
    }
}
