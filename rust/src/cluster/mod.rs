//! Sharded serving cluster: N engine shards behind one
//! [`InferenceService`] front.
//!
//! Each shard is a dedicated tick thread that *owns* a
//! [`GenerationEngine`] (PJRT executables are not `Send`, so engines are
//! built by the factory **inside** their thread and never move).  The
//! shard drains its control channel, runs the continuous-batching tick
//! whenever work is pending, streams every [`GenerationEvent`] into one
//! shared cluster channel, and publishes live load gauges (queue depth,
//! active slots, KV-page occupancy) after every message and tick.
//!
//! [`ClusterService`] is the single front door:
//!
//! * **router** — placement is *session-affine, then prefix-affine,
//!   then load-ranked*: a chat turn resuming a session routes to the
//!   shard that owns that session's [`crate::session::SessionStore`]
//!   entry (only it holds the conversation history and the donated
//!   generated-token pages), ahead of the prefix-affinity ranking; for
//!   sessionless requests, the shard that most recently served the
//!   longest page-aligned prefix of this prompt ranks first (its shared
//!   prefix cache most likely still holds those pages — see
//!   `coordinator::prefix`), and the existing load ranking (queue
//!   depth, then active slots, then KV-page pressure) orders the rest
//!   and breaks ties.  Both affinity maps are advisory: a stale entry
//!   costs one cache miss (or, for sessions, one cold re-registration
//!   on the landing shard), never correctness.  A shard at its
//!   admission bound answers `QueueFull` and the router tries the next;
//!   only when **every** live shard is at bound does the caller see the
//!   cluster-level [`SubmitError::QueueFull`] — the cluster's
//!   backpressure signal.
//! * **scheduler** — per-shard admission is fair-share across
//!   [`crate::api::Priority`] classes and the engine tick retires
//!   deadline-expired requests with `FinishReason::DeadlineExceeded`
//!   (both live in `coordinator::batcher`; the cluster just carries the
//!   request fields through).
//! * **metrics** — [`ClusterService::metrics`] snapshots every shard into
//!   a [`metrics::ClusterMetrics`] (wire `stats` / `metrics` frames, the
//!   `cluster-bench` table).
//!
//! A 1-shard cluster is behaviorally identical to
//! [`crate::api::LocalSession`] for the same seeded requests (asserted in
//! `rust/tests/api_stream.rs` and `benches/serving_cluster.rs --check`);
//! the difference is purely that ticks run on the shard thread instead of
//! the consuming thread.

use std::cell::RefCell;
use std::collections::{HashMap, HashSet, VecDeque};
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use anyhow::Result;

use crate::api::{EventSource, GenerationEvent, GenerationParams,
                 InferenceService, RequestHandle, RequestId, SubmitError};
use crate::coordinator::batcher::{GenerationEngine, Request, TOKENS_PER_PAGE};
use crate::session::SessionSpec;
use crate::telemetry::{chrome_trace_events, Span};
use crate::util::json::Value;

pub mod metrics;

pub use metrics::{ClusterMetrics, LatencySummary, ShardMetrics};

/// Builds one engine per shard, called inside each shard's thread.
pub type EngineFactory = Arc<dyn Fn() -> Result<GenerationEngine> + Send + Sync>;

/// Cluster-level knobs.
#[derive(Clone, Copy, Debug)]
pub struct ClusterConfig {
    /// Number of engine shards (≥ 1; each owns its own KV page pool,
    /// worker-pool lanes and admission queue).
    pub shards: usize,
    /// Per-shard admission-queue bound.  The cluster rejects with
    /// `QueueFull` only once every live shard is at this bound.
    pub queue_bound: usize,
}

impl Default for ClusterConfig {
    fn default() -> ClusterConfig {
        ClusterConfig { shards: 1, queue_bound: 256 }
    }
}

/// Live load gauges one shard publishes for the router (lock-free reads
/// from the submitting thread).
#[derive(Default)]
struct ShardGauges {
    queue_depth: AtomicUsize,
    active_slots: AtomicUsize,
    pages_in_use: AtomicUsize,
    pages_total: AtomicUsize,
    alive: AtomicBool,
}

enum ShardMsg {
    Submit {
        req: Request,
        reply: mpsc::Sender<Result<RequestId, SubmitError>>,
    },
    Cancel {
        id: RequestId,
        reply: mpsc::Sender<bool>,
    },
    Metrics {
        reply: mpsc::Sender<ShardMetrics>,
    },
    /// Drain the shard's span ring (tracing; empties the ring).
    Trace {
        reply: mpsc::Sender<Vec<Span>>,
    },
    /// Flush the shard's prefix cache, releasing its pinned pages.
    ClearPrefix {
        reply: mpsc::Sender<()>,
    },
}

/// Router-side memory of which shard last served each prompt-prefix
/// run-chain (page-granular FNV-1a chain hashes).  Purely advisory: a
/// stale or colliding entry only costs a prefix-cache miss on the
/// chosen shard, never correctness — the shard-side trie compares exact
/// tokens before grafting anything.
struct PrefixAffinity {
    /// chain hash → (shard, stamp of the last placement)
    map: HashMap<u64, (usize, u64)>,
    clock: u64,
    cap: usize,
}

/// Cap on hashed runs per prompt — prefixes deeper than this share the
/// placement decision of their 32-page ancestor.
const AFFINITY_MAX_RUNS: usize = 32;

impl PrefixAffinity {
    fn new(cap: usize) -> PrefixAffinity {
        PrefixAffinity { map: HashMap::new(), clock: 0, cap }
    }

    /// FNV-1a chain hashes of the prompt's successive
    /// [`TOKENS_PER_PAGE`]-token runs: `hashes[k]` covers runs `0..=k`,
    /// matching the page granularity of the shard-side prefix trie.
    fn chain_hashes(prompt: &[u16]) -> Vec<u64> {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        prompt.chunks_exact(TOKENS_PER_PAGE)
            .take(AFFINITY_MAX_RUNS)
            .map(|run| {
                for &t in run {
                    h ^= t as u64;
                    h = h.wrapping_mul(0x0000_0100_0000_01b3);
                }
                h
            })
            .collect()
    }

    /// Deepest recorded run-chain per shard for this prompt.
    fn match_depths(&self, hashes: &[u64], n_shards: usize) -> Vec<usize> {
        let mut depths = vec![0usize; n_shards];
        for (k, h) in hashes.iter().enumerate() {
            if let Some(&(shard, _)) = self.map.get(h) {
                if shard < n_shards {
                    depths[shard] = depths[shard].max(k + 1);
                }
            }
        }
        depths
    }

    /// Remember that `shard` now holds this prompt's prefix chain
    /// (latest placement wins).
    fn record(&mut self, hashes: &[u64], shard: usize) {
        if hashes.is_empty() {
            return;
        }
        self.clock += 1;
        for &h in hashes {
            self.map.insert(h, (shard, self.clock));
        }
        if self.map.len() > self.cap {
            // drop the stalest half in one sweep (rare, O(n log n))
            let mut stamps: Vec<u64> =
                self.map.values().map(|&(_, s)| s).collect();
            stamps.sort_unstable();
            let cut = stamps[stamps.len() / 2];
            self.map.retain(|_, &mut (_, s)| s >= cut);
        }
    }
}

struct Shard {
    ctl: mpsc::Sender<ShardMsg>,
    gauges: Arc<ShardGauges>,
    join: Option<std::thread::JoinHandle<()>>,
}

/// Bound on remembered session → shard ownership entries.
const SESSION_OWNERS_CAP: usize = 8192;

/// Session affinity outranks prefix affinity and load: move the owning
/// shard to the head of the probe order (it is already in `order` iff
/// alive — a dead owner simply isn't promoted, and the turn falls
/// through to the normal ranking).
fn promote_owner(order: &mut Vec<usize>, owner: usize) {
    if let Some(pos) = order.iter().position(|&i| i == owner) {
        let s = order.remove(pos);
        order.insert(0, s);
    }
}

fn publish_gauges(engine: &GenerationEngine, g: &ShardGauges) {
    let ps = engine.pool_stats();
    g.queue_depth.store(engine.queue_depth(), Ordering::SeqCst);
    g.active_slots.store(engine.active_slot_count(), Ordering::SeqCst);
    g.pages_in_use.store(ps.in_use, Ordering::SeqCst);
    g.pages_total.store(ps.pages_total, Ordering::SeqCst);
}

fn flush_events(engine: &mut GenerationEngine,
                tx: &mpsc::Sender<(RequestId, GenerationEvent)>) {
    for ev in engine.take_events() {
        // a send error means the ClusterService is gone; events drain
        // into the void, which is fine — nobody is left to read them
        let _ = tx.send(ev);
    }
}

fn handle_msg(shard_idx: usize, engine: &mut GenerationEngine, msg: ShardMsg,
              gauges: &ShardGauges) {
    // lock-order class: control handling sits above everything the
    // engine acquires (engine.tick, coordinator.prefix, …)
    let _audit = crate::audit::LockScope::enter("cluster.shard");
    match msg {
        ShardMsg::Submit { req, reply } => {
            let r = engine.try_submit(req);
            // publish BEFORE replying so the router's next placement
            // decision always sees this submit reflected in the gauges
            publish_gauges(engine, gauges);
            let _ = reply.send(r);
        }
        ShardMsg::Cancel { id, reply } => {
            let hit = engine.cancel(id);
            publish_gauges(engine, gauges);
            let _ = reply.send(hit);
        }
        ShardMsg::Metrics { reply } => {
            let _ = reply.send(ShardMetrics::from_engine(shard_idx, engine));
        }
        ShardMsg::Trace { reply } => {
            // the tick thread drains its own ring — readers never touch
            // the recorder, so tracing cannot block or race the hot path
            let _ = reply.send(engine.drain_spans());
        }
        ShardMsg::ClearPrefix { reply } => {
            engine.clear_prefix_cache();
            publish_gauges(engine, gauges);
            let _ = reply.send(());
        }
    }
}

/// Clears the shard's `alive` gauge on every exit path — including a
/// panic unwinding the shard thread (an engine-internal assert, a slice
/// OOB in a kernel) — so `next_event_for`'s dead-shard detection fires
/// instead of consumers waiting forever.
struct AliveGuard(Arc<ShardGauges>);

impl Drop for AliveGuard {
    fn drop(&mut self) {
        self.0.alive.store(false, Ordering::SeqCst);
    }
}

fn shard_loop(shard_idx: usize, n_shards: usize, factory: EngineFactory,
              queue_bound: usize, ctl: mpsc::Receiver<ShardMsg>,
              events: mpsc::Sender<(RequestId, GenerationEvent)>,
              gauges: Arc<ShardGauges>, shutdown: Arc<AtomicBool>) {
    let _alive = AliveGuard(gauges.clone());
    let mut engine = match factory() {
        Ok(mut e) => {
            e.set_queue_bound(queue_bound);
            // disjoint residue classes: shard i assigns session ids
            // i+1, i+1+n, i+1+2n, … so a session id is cluster-unique
            // and a stale owner entry can never alias another shard's
            // session
            e.set_session_id_space(shard_idx as u64 + 1, n_shards as u64);
            e
        }
        Err(e) => {
            eprintln!("cluster shard {shard_idx}: engine construction \
                       failed: {e:#}");
            gauges.alive.store(false, Ordering::SeqCst);
            // answer control traffic with typed failures until shutdown,
            // so a degraded cluster errors instead of hanging
            while !shutdown.load(Ordering::SeqCst) {
                match ctl.recv_timeout(Duration::from_millis(20)) {
                    Ok(ShardMsg::Submit { reply, .. }) => {
                        let _ = reply.send(Err(SubmitError::Transport(
                            format!("shard {shard_idx} unavailable"))));
                    }
                    Ok(ShardMsg::Cancel { reply, .. }) => {
                        let _ = reply.send(false);
                    }
                    Ok(ShardMsg::Metrics { reply }) => {
                        let _ = reply.send(ShardMetrics::dead(shard_idx));
                    }
                    Ok(ShardMsg::Trace { reply }) => {
                        let _ = reply.send(Vec::new());
                    }
                    Ok(ShardMsg::ClearPrefix { reply }) => {
                        let _ = reply.send(());
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
            return;
        }
    };
    publish_gauges(&engine, &gauges);
    let mut running = true;
    while running {
        if shutdown.load(Ordering::SeqCst) {
            // terminate every in-flight request so each stream still gets
            // its single terminal event before the channel drops
            engine.fail_all("cluster shutting down");
            flush_events(&mut engine, &events);
            break;
        }
        // drain the control channel without blocking; flush after every
        // message so a cancel's terminal event reaches consumers before
        // the next (possibly long) decode tick, not after it — the
        // server's shutdown drain depends on that promptness
        let mut handled = false;
        loop {
            match ctl.try_recv() {
                Ok(msg) => {
                    handled = true;
                    handle_msg(shard_idx, &mut engine, msg, &gauges);
                    flush_events(&mut engine, &events);
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    running = false;
                    break;
                }
            }
        }
        let ticked = engine.pending() > 0;
        if ticked {
            let _audit = crate::audit::LockScope::enter("cluster.shard");
            if let Err(e) = engine.tick() {
                engine.fail_all(&format!("engine tick failed: {e:#}"));
            }
        }
        flush_events(&mut engine, &events);
        publish_gauges(&engine, &gauges);
        if running && !ticked && !handled {
            // idle: park on the control channel instead of spinning
            match ctl.recv_timeout(Duration::from_millis(1)) {
                Ok(msg) => {
                    handle_msg(shard_idx, &mut engine, msg, &gauges);
                    flush_events(&mut engine, &events);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => running = false,
            }
        }
    }
    // `_alive` drops here, clearing the gauge on normal exit too
}

struct ClusterCore {
    shards: Vec<Shard>,
    events_rx: mpsc::Receiver<(RequestId, GenerationEvent)>,
    /// Events received but not yet delivered to their handle/consumer.
    buffered: VecDeque<(RequestId, GenerationEvent)>,
    /// request id → owning shard; removed once the terminal event arrives.
    owner: HashMap<RequestId, usize>,
    /// Ids whose handle was dropped undrained: frames are discarded until
    /// the terminal event clears the entry.
    released: HashSet<RequestId>,
    /// Prompt-prefix → shard placement memory (the affinity ranking).
    affinity: PrefixAffinity,
    /// session id → owning shard, learned from the `session` field of
    /// terminal `Finished` stats (the only place clients learn the id
    /// from, so it is always recorded before any resume can reference
    /// it).  Advisory like the prefix map: a stale entry sends the turn
    /// to a shard that re-registers the session cold.
    session_owners: HashMap<u64, usize>,
    next_id: u64,
    queue_bound: usize,
    shutdown: Arc<AtomicBool>,
}

impl ClusterCore {
    fn load_score(g: &ShardGauges) -> u64 {
        let total = g.pages_total.load(Ordering::SeqCst).max(1);
        let page_pressure = g.pages_in_use.load(Ordering::SeqCst) * 1000 / total;
        (g.queue_depth.load(Ordering::SeqCst) as u64) * 1_000_000
            + (g.active_slots.load(Ordering::SeqCst) as u64) * 1_000
            + page_pressure as u64
    }

    fn submit_detached(&mut self, params: GenerationParams)
                       -> Result<RequestId, SubmitError> {
        params.validate()?;
        let resumed = match params.session {
            Some(SessionSpec::Resume(sid)) => Some(sid),
            _ => None,
        };
        let mut req = params.into_request();
        req.id = self.next_id;
        self.next_id += 1;
        // place by session affinity first — only the owning shard holds
        // the conversation history and its donated pages — then prefix
        // affinity, then load; fall through the ranking on per-shard
        // QueueFull / transport failure
        let hashes = PrefixAffinity::chain_hashes(&req.prompt);
        let depths = self.affinity.match_depths(&hashes, self.shards.len());
        let mut order: Vec<usize> = (0..self.shards.len())
            .filter(|&i| self.shards[i].gauges.alive.load(Ordering::SeqCst))
            .collect();
        order.sort_by_key(|&i| (std::cmp::Reverse(depths[i]),
                                Self::load_score(&self.shards[i].gauges)));
        if let Some(owner) = resumed
            .and_then(|sid| self.session_owners.get(&sid).copied())
        {
            promote_owner(&mut order, owner);
        }
        if order.is_empty() {
            return Err(SubmitError::Transport("no live shards".into()));
        }
        let mut full = 0usize;
        let mut last_err = SubmitError::Transport("no shard accepted".into());
        // Every shard in the ranking gets an *authoritative* probe before
        // the cluster-level QueueFull verdict: gauges can be a whole
        // decode tick stale (a shard republishes only after its tick, but
        // admit() may have drained its queue at the tick's start), so a
        // gauge-based skip here would reject submits that a live shard
        // would in fact accept.  The common case costs one probe — the
        // serial walk only happens when better-ranked shards reject.
        let mut req = Some(req);
        for (rank, &si) in order.iter().enumerate() {
            // the last candidate takes the request by move; earlier
            // probes clone (a rejected probe needs the request back)
            let payload = if rank + 1 == order.len() {
                req.take().unwrap()
            } else {
                req.as_ref().unwrap().clone()
            };
            let (rtx, rrx) = mpsc::channel();
            if self.shards[si].ctl
                .send(ShardMsg::Submit { req: payload, reply: rtx })
                .is_err()
            {
                last_err = SubmitError::Transport(format!("shard {si} gone"));
                continue;
            }
            match rrx.recv() {
                Ok(Ok(id)) => {
                    self.affinity.record(&hashes, si);
                    if let Some(sid) = resumed {
                        // recorded at accept, not just at Finished: turn
                        // k+2 may be submitted before turn k+1 retires,
                        // and a fallback placement (owner dead/full)
                        // must move the ownership with the session
                        self.record_session_owner(sid, si);
                    }
                    self.owner.insert(id, si);
                    return Ok(id);
                }
                Ok(Err(SubmitError::QueueFull { .. })) => {
                    full += 1;
                    continue;
                }
                // parameter rejections are shard-independent — surface
                // them immediately instead of retrying everywhere
                Ok(Err(e @ SubmitError::InvalidParams(_))) => return Err(e),
                Ok(Err(e)) => {
                    last_err = e;
                    continue;
                }
                Err(_) => {
                    last_err = SubmitError::Transport(
                        format!("shard {si} dropped the request"));
                    continue;
                }
            }
        }
        if full == order.len() {
            // every live shard is at its bound: the cluster-level
            // backpressure signal (bound = aggregate admission capacity)
            Err(SubmitError::QueueFull { bound: self.queue_bound * order.len() })
        } else {
            Err(last_err)
        }
    }

    /// Remember which shard owns a session (latest placement wins),
    /// bounded so a long-lived router cannot grow without limit — on
    /// overflow the map is dropped wholesale, costing at most one cold
    /// re-registration per live session.
    fn record_session_owner(&mut self, sid: u64, shard: usize) {
        if self.session_owners.len() >= SESSION_OWNERS_CAP
            && !self.session_owners.contains_key(&sid)
        {
            self.session_owners.clear();
        }
        self.session_owners.insert(sid, shard);
    }

    /// Buffer-or-discard decision for an arriving event; also clears the
    /// owner/released bookkeeping on terminals.
    fn accept_event(&mut self, id: RequestId, ev: &GenerationEvent) -> bool {
        if let GenerationEvent::Finished { stats, .. } = ev {
            if let Some(sid) = stats.session {
                // the terminal frame is where a `New` chat turn's
                // assigned session id first surfaces — record ownership
                // before the request→shard entry is cleared below, so
                // the client's next Resume(sid) routes home
                if let Some(&si) = self.owner.get(&id) {
                    self.record_session_owner(sid, si);
                }
            }
        }
        if ev.is_terminal() {
            self.owner.remove(&id);
            if self.released.remove(&id) {
                return false;
            }
        } else if self.released.contains(&id) {
            return false;
        }
        true
    }

    /// Synthesize a `Failed` terminal for every request owned by a shard
    /// whose tick thread died without emitting one (a panic unwound it —
    /// `AliveGuard` cleared the gauge).  The id is marked released so a
    /// real terminal still in flight cannot deliver a second terminal.
    /// Shared by both consumption paths: `next_event_for` (handles) and
    /// `poll_events` (the TCP server's multiplexed drain).
    fn reap_dead_shards(&mut self) {
        let dead: Vec<(RequestId, usize)> = self.owner.iter()
            .filter(|&(_, &si)| {
                !self.shards[si].gauges.alive.load(Ordering::SeqCst)
            })
            .map(|(&id, &si)| (id, si))
            .collect();
        for (id, si) in dead {
            self.owner.remove(&id);
            self.released.insert(id);
            self.buffered.push_back((id, GenerationEvent::Failed {
                error: format!("shard {si} died mid-request"),
            }));
        }
    }

    fn poll_events(&mut self) -> Vec<(RequestId, GenerationEvent)> {
        while let Ok((id, ev)) = self.events_rx.try_recv() {
            if self.accept_event(id, &ev) {
                self.buffered.push_back((id, ev));
            }
        }
        self.reap_dead_shards();
        self.buffered.drain(..).collect()
    }

    fn pending(&self) -> usize {
        self.shards.iter()
            .map(|s| {
                s.gauges.queue_depth.load(Ordering::SeqCst)
                    + s.gauges.active_slots.load(Ordering::SeqCst)
            })
            .sum()
    }

    fn metrics(&self) -> ClusterMetrics {
        // fan the requests out to every shard first, then collect — the
        // wait overlaps across shards (one worst-case tick, not N)
        let pending: Vec<Option<mpsc::Receiver<ShardMetrics>>> = self.shards
            .iter()
            .map(|s| {
                let (rtx, rrx) = mpsc::channel();
                s.ctl.send(ShardMsg::Metrics { reply: rtx }).ok().map(|_| rrx)
            })
            .collect();
        let shards = pending.into_iter().enumerate()
            .map(|(i, rrx)| match rrx {
                // a dead shard thread drops its `rtx`, turning the recv
                // into an Err instead of a hang
                Some(rrx) => rrx.recv().unwrap_or_else(|_| ShardMetrics::dead(i)),
                None => ShardMetrics::dead(i),
            })
            .collect();
        ClusterMetrics { queue_bound: self.queue_bound, shards }
    }
}

impl EventSource for ClusterCore {
    fn next_event_for(&mut self, id: RequestId)
                      -> Result<Option<GenerationEvent>> {
        loop {
            if let Some(pos) = self.buffered.iter().position(|(i, _)| *i == id) {
                return Ok(self.buffered.remove(pos).map(|(_, ev)| ev));
            }
            // terminal already delivered (owner cleared) or unknown id
            let Some(&si) = self.owner.get(&id) else {
                return Ok(None);
            };
            // drain everything already in flight before concluding the
            // owner is dead: a shard that exited cleanly sends its real
            // terminals before clearing `alive`, and those must win
            let mut drained = false;
            while let Ok((i, ev)) = self.events_rx.try_recv() {
                if self.accept_event(i, &ev) {
                    self.buffered.push_back((i, ev));
                }
                drained = true;
            }
            if drained {
                continue;
            }
            // checked every iteration, not just on timeout: a busy
            // sibling shard streaming events within every 50 ms window
            // must not mask a crashed owner indefinitely.  The reap
            // buffers a synthetic Failed the loop's next pass delivers
            // (and marks the id released so a late real terminal cannot
            // deliver a second one).
            if !self.shards[si].gauges.alive.load(Ordering::SeqCst) {
                self.reap_dead_shards();
                continue;
            }
            match self.events_rx.recv_timeout(Duration::from_millis(50)) {
                Ok((i, ev)) => {
                    if self.accept_event(i, &ev) {
                        self.buffered.push_back((i, ev));
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => return Ok(None),
            }
        }
    }

    fn cancel_request(&mut self, id: RequestId) -> Result<bool> {
        let Some(&si) = self.owner.get(&id) else {
            return Ok(false);
        };
        let (rtx, rrx) = mpsc::channel();
        if self.shards[si].ctl.send(ShardMsg::Cancel { id, reply: rtx }).is_err() {
            return Ok(false);
        }
        Ok(rrx.recv().unwrap_or(false))
    }

    fn release_request(&mut self, id: RequestId) {
        let had_terminal = self.buffered.iter()
            .any(|(i, ev)| *i == id && ev.is_terminal());
        self.buffered.retain(|(i, _)| *i != id);
        if had_terminal {
            self.owner.remove(&id);
        } else if self.owner.contains_key(&id) {
            let _ = self.cancel_request(id);
            self.released.insert(id);
        }
    }
}

impl Drop for ClusterCore {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for s in &mut self.shards {
            if let Some(j) = s.join.take() {
                let _ = j.join();
            }
        }
    }
}

/// Multi-shard [`InferenceService`]: one submit/cancel/event surface over
/// N engine shards.  See the module docs for the router / scheduler /
/// metrics split.
pub struct ClusterService {
    core: Rc<RefCell<ClusterCore>>,
}

impl ClusterService {
    /// Spawn `cfg.shards` shard threads, each building its engine via
    /// `factory`.  Returns immediately — engine construction proceeds on
    /// the shard threads, and early submits simply wait on their reply.
    pub fn new(factory: EngineFactory, cfg: ClusterConfig) -> ClusterService {
        let n = cfg.shards.max(1);
        let shutdown = Arc::new(AtomicBool::new(false));
        let (etx, erx) = mpsc::channel();
        let mut shards = Vec::with_capacity(n);
        for i in 0..n {
            let (ctx, crx) = mpsc::channel();
            let gauges = Arc::new(ShardGauges {
                // optimistic until the factory verdict: a submit that
                // races construction waits on the shard's reply rather
                // than failing spuriously
                alive: AtomicBool::new(true),
                ..Default::default()
            });
            let (f, g, e, sd) = (factory.clone(), gauges.clone(), etx.clone(),
                                 shutdown.clone());
            let qb = cfg.queue_bound;
            let join = std::thread::Builder::new()
                .name(format!("quarot-shard-{i}"))
                .spawn(move || shard_loop(i, n, f, qb, crx, e, g, sd))
                .expect("spawn shard thread");
            shards.push(Shard { ctl: ctx, gauges, join: Some(join) });
        }
        ClusterService {
            core: Rc::new(RefCell::new(ClusterCore {
                shards,
                events_rx: erx,
                buffered: VecDeque::new(),
                owner: HashMap::new(),
                released: HashSet::new(),
                affinity: PrefixAffinity::new(4096),
                session_owners: HashMap::new(),
                next_id: 1,
                queue_bound: cfg.queue_bound,
                shutdown,
            })),
        }
    }

    /// Submit and get a [`RequestHandle`] for this request's events.
    pub fn submit(&self, params: GenerationParams)
                  -> Result<RequestHandle, SubmitError> {
        let id = self.core.borrow_mut().submit_detached(params)?;
        Ok(RequestHandle::new(id, self.core.clone()))
    }

    /// Submit without a handle — for multiplexed consumers (the TCP
    /// server) that read every request's events via [`Self::poll_events`].
    pub fn submit_detached(&self, params: GenerationParams)
                           -> Result<RequestId, SubmitError> {
        self.core.borrow_mut().submit_detached(params)
    }

    /// Drain all buffered events in arrival order (multiplexed mode — do
    /// not mix with handle-based reads, which would race for the same
    /// events).
    pub fn poll_events(&self) -> Vec<(RequestId, GenerationEvent)> {
        self.core.borrow_mut().poll_events()
    }

    /// Cancel by id, routed to the owning shard; pages return to that
    /// shard's pool and the stream terminates with `Finished{Cancelled}`.
    pub fn cancel(&self, id: RequestId) -> bool {
        self.core.borrow_mut().cancel_request(id).unwrap_or(false)
    }

    /// Queued + active requests across all shards (gauge-based; exact
    /// between ticks).
    pub fn pending(&self) -> usize {
        self.core.borrow().pending()
    }

    /// Number of shards this cluster was built with (live or not).
    pub fn shards(&self) -> usize {
        self.core.borrow().shards.len()
    }

    /// Snapshot every shard's live load and lifetime counters.
    pub fn metrics(&self) -> ClusterMetrics {
        self.core.borrow().metrics()
    }

    /// Drain every shard's span ring into Chrome-trace complete-event
    /// objects (`pid` = shard index, `tid` = request id, 0 = engine
    /// phases).  Draining empties the rings: each call returns the
    /// window recorded since the previous one.  Shards with tracing
    /// disabled (or dead) contribute nothing.
    pub fn trace_events(&self) -> Vec<Value> {
        let core = self.core.borrow();
        // fan out first, collect second — like `metrics`, the wait
        // overlaps across shards
        let pending: Vec<Option<mpsc::Receiver<Vec<Span>>>> = core.shards
            .iter()
            .map(|s| {
                let (rtx, rrx) = mpsc::channel();
                s.ctl.send(ShardMsg::Trace { reply: rtx }).ok().map(|_| rrx)
            })
            .collect();
        let mut events = Vec::new();
        for (i, rrx) in pending.into_iter().enumerate() {
            let spans = match rrx {
                Some(rrx) => rrx.recv().unwrap_or_default(),
                None => Vec::new(),
            };
            events.extend(chrome_trace_events(&spans, i as u64));
        }
        events
    }

    /// Flush every shard's prefix cache, releasing the pages it pins
    /// (pages still grafted by live sequences survive until those
    /// sequences finish) — the admin flush behind leak checks and
    /// cache reconfiguration.
    pub fn clear_prefix_caches(&self) {
        let core = self.core.borrow();
        let pending: Vec<Option<mpsc::Receiver<()>>> = core.shards.iter()
            .map(|s| {
                let (rtx, rrx) = mpsc::channel();
                s.ctl.send(ShardMsg::ClearPrefix { reply: rtx }).ok()
                    .map(|_| rrx)
            })
            .collect();
        for rrx in pending.into_iter().flatten() {
            let _ = rrx.recv();
        }
    }
}

impl InferenceService for ClusterService {
    fn submit(&mut self, params: GenerationParams)
              -> Result<RequestHandle, SubmitError> {
        ClusterService::submit(self, params)
    }

    fn cancel(&mut self, id: RequestId) -> Result<bool> {
        Ok(ClusterService::cancel(self, id))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prompt(n: usize, seed: u16) -> Vec<u16> {
        (0..n as u16).map(|i| i.wrapping_mul(7).wrapping_add(seed)).collect()
    }

    #[test]
    fn affinity_ranks_the_recording_shard_by_longest_prefix() {
        let mut aff = PrefixAffinity::new(1024);
        let p = prompt(3 * TOKENS_PER_PAGE, 1);
        let h = PrefixAffinity::chain_hashes(&p);
        assert_eq!(h.len(), 3);
        aff.record(&h, 2);
        // full-prompt resubmit: shard 2 matches all 3 runs
        assert_eq!(aff.match_depths(&h, 4), vec![0, 0, 3, 0]);
        // a prompt diverging in run 1 still matches depth 1 on shard 2
        let mut q = p.clone();
        q[TOKENS_PER_PAGE] ^= 1;
        let hq = PrefixAffinity::chain_hashes(&q);
        assert_eq!(hq[0], h[0], "shared first run must hash alike");
        assert_ne!(hq[1], h[1], "divergent chain must hash apart");
        assert_eq!(aff.match_depths(&hq, 4), vec![0, 0, 1, 0]);
        // a later placement of the same chain takes the ownership over
        aff.record(&h, 0);
        assert_eq!(aff.match_depths(&h, 4)[0], 3);
        // sub-page prompts produce no runs, hence no affinity signal
        assert!(PrefixAffinity::chain_hashes(&p[..TOKENS_PER_PAGE - 1])
                    .is_empty());
        assert_eq!(aff.match_depths(&[], 4), vec![0; 4]);
    }

    #[test]
    fn session_owner_promotion_outranks_the_existing_order() {
        // owner mid-ranking moves to the head; the rest keep their
        // prefix/load order
        let mut order = vec![2, 0, 3, 1];
        promote_owner(&mut order, 3);
        assert_eq!(order, vec![3, 2, 0, 1]);
        // already first: stable
        promote_owner(&mut order, 3);
        assert_eq!(order, vec![3, 2, 0, 1]);
        // a dead owner was filtered out of `order` upstream — promotion
        // is a no-op and the turn falls through to the normal ranking
        promote_owner(&mut order, 7);
        assert_eq!(order, vec![3, 2, 0, 1]);
    }

    #[test]
    fn affinity_map_trims_to_capacity_keeping_fresh_entries() {
        let mut aff = PrefixAffinity::new(8);
        for i in 0..64u16 {
            let h = PrefixAffinity::chain_hashes(&prompt(TOKENS_PER_PAGE, i));
            assert_eq!(h.len(), 1);
            aff.record(&h, (i % 4) as usize);
        }
        assert!(aff.map.len() <= 8, "map grew past its cap: {}", aff.map.len());
        let h = PrefixAffinity::chain_hashes(&prompt(TOKENS_PER_PAGE, 63));
        assert_eq!(aff.match_depths(&h, 4)[63 % 4], 1,
                   "the most recent entry must survive trimming");
    }
}
