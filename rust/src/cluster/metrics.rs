//! Runtime metrics registry for the sharded serving cluster.
//!
//! Two shapes, both cheap snapshots (no background aggregation thread):
//!
//! * [`ShardMetrics`] — one engine shard's live gauges (queue depth,
//!   active slots, KV-page occupancy) and lifetime counters (retire
//!   reasons, decode throughput, average TTFT).  Built by the shard's
//!   tick thread straight off its `GenerationEngine`.
//! * [`ClusterMetrics`] — every shard's snapshot plus cluster-wide
//!   aggregates.  This is what the v2 wire `stats` frame (summary) and
//!   the `{"cmd":"metrics"}` reply (full, per-shard) serialize, and what
//!   `quarot cluster-bench` renders as a table.

use crate::coordinator::batcher::GenerationEngine;
use crate::coordinator::kvcache::PoolStats;
use crate::coordinator::prefix::PrefixStats;
use crate::telemetry::Histogram;
use crate::util::bench::Table;
use crate::util::json::{n, obj, Value};

/// Mean / percentiles over a batch of latency samples — the one
/// reduction the bench harnesses and `cluster-bench` share.  Backed by
/// [`telemetry::Histogram`](crate::telemetry::Histogram): the mean is
/// exact (sum/count), percentiles are log-bucket quantized (≲19 %
/// relative error) and therefore consistent with the wire
/// `stats`/`metrics` percentile keys, which flow through the same
/// histogram.
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencySummary {
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
}

impl LatencySummary {
    /// Reduce a sample batch (order irrelevant; empty yields zeros).
    pub fn of(samples: &[f64]) -> LatencySummary {
        let mut h = Histogram::new();
        for &s in samples {
            h.record(s);
        }
        LatencySummary::of_hist(&h)
    }

    /// Reduce an already-built histogram (e.g. a merged shard
    /// aggregate) to the bench-facing summary.
    pub fn of_hist(h: &Histogram) -> LatencySummary {
        LatencySummary {
            mean_ms: h.mean_ms(),
            p50_ms: h.quantile(0.50),
            p95_ms: h.quantile(0.95),
            p99_ms: h.quantile(0.99),
        }
    }
}

/// Point-in-time snapshot of one engine shard.
#[derive(Clone, Debug, Default)]
pub struct ShardMetrics {
    pub shard: usize,
    /// false for a shard whose engine failed to construct or whose tick
    /// thread has exited
    pub alive: bool,
    pub queue_depth: usize,
    pub active_slots: usize,
    pub queue_bound: usize,
    pub pool: PoolStats,
    /// shared prefix-cache counters (hit rate, pinned pages, evictions)
    pub prefix: PrefixStats,
    pub completed: usize,
    pub cancelled: usize,
    pub failed: usize,
    pub deadline_exceeded: usize,
    pub decode_steps: usize,
    pub decode_tokens: usize,
    /// per-tier splits of `completed` / `decode_tokens` (mixed KV4/KV8
    /// workload observability)
    pub kv4_completed: usize,
    pub kv8_completed: usize,
    pub kv4_decode_tokens: usize,
    pub kv8_decode_tokens: usize,
    pub tokens_per_sec: f64,
    pub ttft_sum_ms: f64,
    pub ttft_count: usize,
    pub peak_cache_bytes: usize,
    pub peak_cache_fp16_bytes: usize,
    /// live chat sessions registered on this shard (gauge)
    pub sessions_live: usize,
    /// chat turns retired with their history remembered (counter)
    pub session_turns: usize,
    /// prompt tokens resume turns skipped prefilling because the
    /// session's donated chain was grafted from the prefix trie
    pub session_prefill_tokens_saved: usize,
    /// time-to-first-token distribution (mergeable log histogram)
    pub ttft_hist: Histogram,
    /// inter-token latency distribution
    pub itl_hist: Histogram,
    /// admission queue-wait distribution
    pub queue_wait_hist: Histogram,
    /// decode-tick duration distribution
    pub tick_hist: Histogram,
    /// executor this shard's engine runs on ("pjrt" / "native"; empty
    /// for a dead shard that never built its engine)
    pub executor: String,
    /// prefill chunks executed (each covers up to the engine's
    /// per-tick chunk budget of uncached suffix tokens)
    pub prefill_chunks: usize,
    /// uncached suffix tokens prefilled through the chunked path
    pub prefill_chunk_tokens: usize,
}

impl ShardMetrics {
    /// Snapshot a live engine's counters and gauges into one row.
    pub fn from_engine(shard: usize, engine: &GenerationEngine) -> ShardMetrics {
        let st = &engine.stats;
        ShardMetrics {
            shard,
            alive: true,
            queue_depth: engine.queue_depth(),
            active_slots: engine.active_slot_count(),
            queue_bound: engine.queue_bound(),
            pool: engine.pool_stats(),
            prefix: engine.prefix_stats(),
            completed: st.completed,
            cancelled: st.cancelled,
            failed: st.failed,
            deadline_exceeded: st.deadline_exceeded,
            decode_steps: st.decode_steps,
            decode_tokens: st.decode_tokens,
            kv4_completed: st.kv4_completed,
            kv8_completed: st.kv8_completed,
            kv4_decode_tokens: st.kv4_decode_tokens,
            kv8_decode_tokens: st.kv8_decode_tokens,
            tokens_per_sec: st.tokens_per_sec(),
            ttft_sum_ms: st.ttft_sum_ms,
            ttft_count: st.ttft_count,
            peak_cache_bytes: st.peak_cache_bytes,
            peak_cache_fp16_bytes: st.peak_cache_fp16_bytes,
            sessions_live: engine.sessions_live(),
            session_turns: st.session_turns,
            session_prefill_tokens_saved: st.session_prefill_tokens_saved,
            ttft_hist: st.ttft_hist.clone(),
            itl_hist: st.itl_hist.clone(),
            queue_wait_hist: st.queue_wait_hist.clone(),
            tick_hist: st.tick_hist.clone(),
            executor: engine.runner.executor_name().to_string(),
            prefill_chunks: st.prefill_chunks,
            prefill_chunk_tokens: st.prefill_chunk_tokens,
        }
    }

    /// Placeholder row for a shard that cannot answer (engine failed to
    /// build, thread gone).
    pub fn dead(shard: usize) -> ShardMetrics {
        ShardMetrics { shard, ..Default::default() }
    }

    /// Mean time-to-first-token over this shard's started requests.
    pub fn avg_ttft_ms(&self) -> f64 {
        if self.ttft_count == 0 {
            return 0.0;
        }
        self.ttft_sum_ms / self.ttft_count as f64
    }

    /// One `per_shard` JSON row (key order is part of the wire contract
    /// — see `tests/golden/wire_keys.txt`).
    pub fn to_value(&self) -> Value {
        obj(vec![
            ("shard", n(self.shard as f64)),
            ("alive", Value::Bool(self.alive)),
            ("queue_depth", n(self.queue_depth as f64)),
            ("active_slots", n(self.active_slots as f64)),
            ("queue_bound", n(self.queue_bound as f64)),
            ("pages_total", n(self.pool.pages_total as f64)),
            ("pages_in_use", n(self.pool.in_use as f64)),
            ("pages_high_water", n(self.pool.high_water as f64)),
            ("prefix_lookups", n(self.prefix.lookups as f64)),
            ("prefix_hits", n(self.prefix.hits as f64)),
            ("prefix_hit_rate", n(self.prefix.hit_rate())),
            ("prefix_hit_tokens", n(self.prefix.hit_tokens as f64)),
            ("prefix_pages_pinned", n(self.prefix.pages_pinned as f64)),
            ("prefix_evicted_pages", n(self.prefix.evicted_pages as f64)),
            ("completed", n(self.completed as f64)),
            ("cancelled", n(self.cancelled as f64)),
            ("failed", n(self.failed as f64)),
            ("deadline_exceeded", n(self.deadline_exceeded as f64)),
            ("decode_steps", n(self.decode_steps as f64)),
            ("decode_tokens", n(self.decode_tokens as f64)),
            ("kv4_completed", n(self.kv4_completed as f64)),
            ("kv8_completed", n(self.kv8_completed as f64)),
            ("kv4_decode_tokens", n(self.kv4_decode_tokens as f64)),
            ("kv8_decode_tokens", n(self.kv8_decode_tokens as f64)),
            ("tokens_per_sec", n(self.tokens_per_sec)),
            ("avg_ttft_ms", n(self.avg_ttft_ms())),
            ("peak_cache_bytes", n(self.peak_cache_bytes as f64)),
            ("peak_cache_fp16_bytes", n(self.peak_cache_fp16_bytes as f64)),
            // session additions — appended after every pre-existing key
            ("sessions_live", n(self.sessions_live as f64)),
            ("session_turns", n(self.session_turns as f64)),
            ("session_prefill_tokens_saved",
             n(self.session_prefill_tokens_saved as f64)),
            // latency-percentile additions — appended after the session
            // tail key so positional consumers keep working
            ("ttft_p50_ms", n(self.ttft_hist.quantile(0.50))),
            ("ttft_p90_ms", n(self.ttft_hist.quantile(0.90))),
            ("ttft_p99_ms", n(self.ttft_hist.quantile(0.99))),
            ("ttft_p999_ms", n(self.ttft_hist.quantile(0.999))),
            ("itl_p50_ms", n(self.itl_hist.quantile(0.50))),
            ("itl_p90_ms", n(self.itl_hist.quantile(0.90))),
            ("itl_p99_ms", n(self.itl_hist.quantile(0.99))),
            ("itl_p999_ms", n(self.itl_hist.quantile(0.999))),
            ("queue_wait_p50_ms", n(self.queue_wait_hist.quantile(0.50))),
            ("queue_wait_p90_ms", n(self.queue_wait_hist.quantile(0.90))),
            ("queue_wait_p99_ms", n(self.queue_wait_hist.quantile(0.99))),
            ("queue_wait_p999_ms", n(self.queue_wait_hist.quantile(0.999))),
            ("tick_p50_ms", n(self.tick_hist.quantile(0.50))),
            ("tick_p90_ms", n(self.tick_hist.quantile(0.90))),
            ("tick_p99_ms", n(self.tick_hist.quantile(0.99))),
            ("tick_p999_ms", n(self.tick_hist.quantile(0.999))),
            // executor additions — appended after the percentile tail
            // key so positional consumers keep working
            ("executor", Value::Str(self.executor.clone())),
            ("prefill_chunks", n(self.prefill_chunks as f64)),
            ("prefill_chunk_tokens", n(self.prefill_chunk_tokens as f64)),
        ])
    }
}

/// All shards plus cluster-wide aggregates.
#[derive(Clone, Debug, Default)]
pub struct ClusterMetrics {
    /// per-shard admission bound (the cluster-level bound is this times
    /// the number of live shards)
    pub queue_bound: usize,
    pub shards: Vec<ShardMetrics>,
}

impl ClusterMetrics {
    fn sum(&self, f: impl Fn(&ShardMetrics) -> usize) -> usize {
        self.shards.iter().map(f).sum()
    }

    /// Shards that answered the snapshot (engine thread still alive).
    pub fn live_shards(&self) -> usize {
        self.shards.iter().filter(|s| s.alive).count()
    }

    /// Queued (not yet scheduled) requests across all shards.
    pub fn queue_depth(&self) -> usize {
        self.sum(|s| s.queue_depth)
    }

    /// Requests currently decoding across all shards.
    pub fn active_slots(&self) -> usize {
        self.sum(|s| s.active_slots)
    }

    /// Requests finished normally, summed across shards.
    pub fn completed(&self) -> usize {
        self.sum(|s| s.completed)
    }

    /// Requests cancelled by the caller, summed across shards.
    pub fn cancelled(&self) -> usize {
        self.sum(|s| s.cancelled)
    }

    /// Requests that errored mid-stream, summed across shards.
    pub fn failed(&self) -> usize {
        self.sum(|s| s.failed)
    }

    /// Requests dropped for a lapsed deadline, summed across shards.
    pub fn deadline_exceeded(&self) -> usize {
        self.sum(|s| s.deadline_exceeded)
    }

    /// KV pages currently allocated, summed across shard pools.
    pub fn pool_pages_in_use(&self) -> usize {
        self.sum(|s| s.pool.in_use)
    }

    /// Total provisioned KV pages, summed across shard pools.
    pub fn pool_pages_total(&self) -> usize {
        self.sum(|s| s.pool.pages_total)
    }

    /// Sum of per-shard high-water marks.  Each shard sizes its own pool,
    /// so this is the total page provisioning the observed load required —
    /// an *upper bound* on any concurrent cluster-wide peak (the shards
    /// need not have peaked at the same time; per-shard values are in
    /// `per_shard`).  `peak_cache_bytes` aggregates the same way.
    pub fn kv_high_water(&self) -> usize {
        self.sum(|s| s.pool.high_water)
    }

    /// Aggregate decode throughput: shards decode in parallel, so rates
    /// add.
    pub fn tokens_per_sec(&self) -> f64 {
        self.shards.iter().map(|s| s.tokens_per_sec).sum()
    }

    /// Prefix-cache probe count, summed across shards.
    pub fn prefix_lookups(&self) -> usize {
        self.sum(|s| s.prefix.lookups)
    }

    /// Prefix-cache probes that matched a cached chain, summed.
    pub fn prefix_hits(&self) -> usize {
        self.sum(|s| s.prefix.hits)
    }

    /// Cluster-wide prefix-cache hit rate (hits over lookups, across
    /// shards — per-shard rates are in `per_shard`).
    pub fn prefix_hit_rate(&self) -> f64 {
        let lookups = self.prefix_lookups();
        if lookups == 0 {
            return 0.0;
        }
        self.prefix_hits() as f64 / lookups as f64
    }

    /// Prompt tokens served from shared prefix caches instead of being
    /// prefilled — the cluster's prefill-work-saved counter.
    pub fn prefix_tokens_saved(&self) -> usize {
        self.sum(|s| s.prefix.hit_tokens)
    }

    /// Pool pages currently pinned by the shards' prefix tries.
    pub fn prefix_pages_pinned(&self) -> usize {
        self.sum(|s| s.prefix.pages_pinned)
    }

    /// Completed requests that ran on the 4-bit KV tier, summed.
    pub fn kv4_completed(&self) -> usize {
        self.sum(|s| s.kv4_completed)
    }

    /// Completed requests that ran on the 8-bit KV tier, summed.
    pub fn kv8_completed(&self) -> usize {
        self.sum(|s| s.kv8_completed)
    }

    /// Tokens decoded on the 4-bit KV tier, summed across shards.
    pub fn kv4_decode_tokens(&self) -> usize {
        self.sum(|s| s.kv4_decode_tokens)
    }

    /// Tokens decoded on the 8-bit KV tier, summed across shards.
    pub fn kv8_decode_tokens(&self) -> usize {
        self.sum(|s| s.kv8_decode_tokens)
    }

    /// Live chat sessions across all shards.
    pub fn sessions_live(&self) -> usize {
        self.sum(|s| s.sessions_live)
    }

    /// Chat turns served (with history remembered) across all shards.
    pub fn session_turns(&self) -> usize {
        self.sum(|s| s.session_turns)
    }

    /// Prompt tokens resume turns never prefilled because the session's
    /// donated generated-token chain was grafted from the prefix trie.
    pub fn session_prefill_tokens_saved(&self) -> usize {
        self.sum(|s| s.session_prefill_tokens_saved)
    }

    /// Executor the cluster's live shards run on: the shared name when
    /// they agree ("pjrt" / "native"), "mixed" when heterogeneous
    /// factories built different paths, "none" when no shard ever built
    /// an engine.
    pub fn executor(&self) -> String {
        let mut names = self.shards.iter()
            .filter(|s| !s.executor.is_empty())
            .map(|s| s.executor.as_str());
        match names.next() {
            None => "none".to_string(),
            Some(first) if names.all(|x| x == first) => first.to_string(),
            Some(_) => "mixed".to_string(),
        }
    }

    /// Prefill chunks executed across all shards.
    pub fn prefill_chunks(&self) -> usize {
        self.sum(|s| s.prefill_chunks)
    }

    /// Uncached suffix tokens prefilled through the chunked path,
    /// summed across shards.
    pub fn prefill_chunk_tokens(&self) -> usize {
        self.sum(|s| s.prefill_chunk_tokens)
    }

    /// TTFT averaged over every request that started, across shards.
    pub fn avg_ttft_ms(&self) -> f64 {
        let count: usize = self.sum(|s| s.ttft_count);
        if count == 0 {
            return 0.0;
        }
        let sum: f64 = self.shards.iter().map(|s| s.ttft_sum_ms).sum();
        sum / count as f64
    }

    /// Cluster-wide TTFT distribution: the shard histograms *merged*
    /// (bucket-count addition), never averaged — a shard serving 9× the
    /// traffic weighs 9× in every quantile, exactly as the union of the
    /// underlying samples would.
    pub fn ttft_hist(&self) -> Histogram {
        let mut h = Histogram::new();
        for s in &self.shards {
            h.merge(&s.ttft_hist);
        }
        h
    }

    /// Cluster-wide inter-token latency distribution (merged shards).
    pub fn itl_hist(&self) -> Histogram {
        let mut h = Histogram::new();
        for s in &self.shards {
            h.merge(&s.itl_hist);
        }
        h
    }

    /// Cluster-wide admission queue-wait distribution (merged shards).
    pub fn queue_wait_hist(&self) -> Histogram {
        let mut h = Histogram::new();
        for s in &self.shards {
            h.merge(&s.queue_wait_hist);
        }
        h
    }

    /// Cluster-wide decode-tick duration distribution (merged shards).
    pub fn tick_hist(&self) -> Histogram {
        let mut h = Histogram::new();
        for s in &self.shards {
            h.merge(&s.tick_hist);
        }
        h
    }

    /// Flat cluster-wide aggregates — the v2 `stats` frame payload.  The
    /// pre-cluster keys (`completed`, `pool_pages_in_use`, `queue_bound`,
    /// ...) keep their meaning; `queue_depth` / `active_slots` / `shards`
    /// / `deadline_exceeded` / `kv_high_water` / `avg_ttft_ms` are the
    /// live-load additions.
    pub fn summary_pairs(&self) -> Vec<(&'static str, Value)> {
        let (ttft, itl, qw, tick) =
            (self.ttft_hist(), self.itl_hist(),
             self.queue_wait_hist(), self.tick_hist());
        let mut pairs = vec![
            ("shards", n(self.shards.len() as f64)),
            ("live_shards", n(self.live_shards() as f64)),
            ("queue_bound", n(self.queue_bound as f64)),
            ("queue_depth", n(self.queue_depth() as f64)),
            ("active_slots", n(self.active_slots() as f64)),
            ("completed", n(self.completed() as f64)),
            ("cancelled", n(self.cancelled() as f64)),
            ("failed", n(self.failed() as f64)),
            ("deadline_exceeded", n(self.deadline_exceeded() as f64)),
            ("decode_steps", n(self.sum(|s| s.decode_steps) as f64)),
            ("tokens_per_sec", n(self.tokens_per_sec())),
            ("avg_ttft_ms", n(self.avg_ttft_ms())),
            ("peak_cache_bytes", n(self.sum(|s| s.peak_cache_bytes) as f64)),
            ("peak_cache_fp16_bytes",
             n(self.sum(|s| s.peak_cache_fp16_bytes) as f64)),
            ("pool_pages_in_use", n(self.pool_pages_in_use() as f64)),
            ("pool_pages_total", n(self.pool_pages_total() as f64)),
            ("kv_high_water", n(self.kv_high_water() as f64)),
            ("prefix_lookups", n(self.prefix_lookups() as f64)),
            ("prefix_hits", n(self.prefix_hits() as f64)),
            ("prefix_hit_rate", n(self.prefix_hit_rate())),
            ("prefix_tokens_saved", n(self.prefix_tokens_saved() as f64)),
            ("prefix_pages_pinned", n(self.prefix_pages_pinned() as f64)),
            // precision-tier additions — appended after every
            // pre-existing key so v1 `stats` consumers are unaffected
            ("kv4_completed", n(self.kv4_completed() as f64)),
            ("kv8_completed", n(self.kv8_completed() as f64)),
            ("kv4_decode_tokens", n(self.kv4_decode_tokens() as f64)),
            ("kv8_decode_tokens", n(self.kv8_decode_tokens() as f64)),
            // session additions — appended after the tier tail key so
            // positional consumers of older frames keep working
            ("sessions_live", n(self.sessions_live() as f64)),
            ("session_turns", n(self.session_turns() as f64)),
            ("session_prefill_tokens_saved",
             n(self.session_prefill_tokens_saved() as f64)),
        ];
        // latency-percentile additions — merged shard histograms (never
        // averages of shard averages), appended after the session tail
        // key so positional consumers of older frames keep working
        pairs.extend([
            ("ttft_p50_ms", n(ttft.quantile(0.50))),
            ("ttft_p90_ms", n(ttft.quantile(0.90))),
            ("ttft_p99_ms", n(ttft.quantile(0.99))),
            ("ttft_p999_ms", n(ttft.quantile(0.999))),
            ("itl_p50_ms", n(itl.quantile(0.50))),
            ("itl_p90_ms", n(itl.quantile(0.90))),
            ("itl_p99_ms", n(itl.quantile(0.99))),
            ("itl_p999_ms", n(itl.quantile(0.999))),
            ("queue_wait_p50_ms", n(qw.quantile(0.50))),
            ("queue_wait_p90_ms", n(qw.quantile(0.90))),
            ("queue_wait_p99_ms", n(qw.quantile(0.99))),
            ("queue_wait_p999_ms", n(qw.quantile(0.999))),
            ("tick_p50_ms", n(tick.quantile(0.50))),
            ("tick_p90_ms", n(tick.quantile(0.90))),
            ("tick_p99_ms", n(tick.quantile(0.99))),
            ("tick_p999_ms", n(tick.quantile(0.999))),
        ]);
        // executor additions — appended after the percentile tail key
        // so positional consumers of older frames keep working
        pairs.extend([
            ("executor", Value::Str(self.executor())),
            ("prefill_chunks", n(self.prefill_chunks() as f64)),
            ("prefill_chunk_tokens", n(self.prefill_chunk_tokens() as f64)),
        ]);
        pairs
    }

    /// Summary plus the per-shard breakdown — the `{"cmd":"metrics"}`
    /// reply payload.
    pub fn full_pairs(&self) -> Vec<(&'static str, Value)> {
        let mut pairs = self.summary_pairs();
        pairs.push(("per_shard",
                    Value::Arr(self.shards.iter()
                               .map(|s| s.to_value())
                               .collect())));
        pairs
    }

    /// Human-readable per-shard table (the `cluster-bench` readout).
    pub fn render(&self) -> String {
        let mut t = Table::new(
            "Cluster shards — live load and lifetime counters",
            &["shard", "alive", "queue", "active", "pages", "hi-water",
              "pfx hit%", "pfx pages", "sess", "done", "ddl", "cxl", "fail",
              "tok/s", "ttft ms"]);
        for s in &self.shards {
            t.row(vec![
                format!("{}", s.shard),
                if s.alive { "yes".into() } else { "NO".into() },
                format!("{}", s.queue_depth),
                format!("{}", s.active_slots),
                format!("{}/{}", s.pool.in_use, s.pool.pages_total),
                format!("{}", s.pool.high_water),
                format!("{:.0}", s.prefix.hit_rate() * 100.0),
                format!("{}", s.prefix.pages_pinned),
                format!("{}", s.sessions_live),
                format!("{}", s.completed),
                format!("{}", s.deadline_exceeded),
                format!("{}", s.cancelled),
                format!("{}", s.failed),
                format!("{:.1}", s.tokens_per_sec),
                format!("{:.2}", s.avg_ttft_ms()),
            ]);
        }
        t.row(vec![
            "Σ".into(),
            format!("{}/{}", self.live_shards(), self.shards.len()),
            format!("{}", self.queue_depth()),
            format!("{}", self.active_slots()),
            format!("{}/{}", self.pool_pages_in_use(), self.pool_pages_total()),
            format!("{}", self.kv_high_water()),
            format!("{:.0}", self.prefix_hit_rate() * 100.0),
            format!("{}", self.prefix_pages_pinned()),
            format!("{}", self.sessions_live()),
            format!("{}", self.completed()),
            format!("{}", self.deadline_exceeded()),
            format!("{}", self.cancelled()),
            format!("{}", self.failed()),
            format!("{:.1}", self.tokens_per_sec()),
            format!("{:.2}", self.avg_ttft_ms()),
        ]);
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shard(i: usize, q: usize, a: usize, done: usize) -> ShardMetrics {
        ShardMetrics {
            shard: i,
            alive: true,
            queue_depth: q,
            active_slots: a,
            queue_bound: 8,
            pool: PoolStats { pages_total: 100, in_use: 10 * i, high_water: 20 },
            prefix: PrefixStats {
                lookups: 4, hits: 2, misses: 2, hit_tokens: 32, hit_pages: 8,
                inserted_pages: 8, evicted_pages: 0, pages_pinned: 8,
            },
            completed: done,
            kv4_completed: done / 2,
            kv8_completed: done - done / 2,
            kv4_decode_tokens: 10 * done,
            kv8_decode_tokens: 5 * done,
            sessions_live: 1,
            session_turns: done,
            session_prefill_tokens_saved: 16 * done,
            tokens_per_sec: 50.0,
            ttft_sum_ms: 30.0 * done as f64,
            ttft_count: done,
            executor: "pjrt".to_string(),
            prefill_chunks: 2 * done,
            prefill_chunk_tokens: 24 * done,
            ..Default::default()
        }
    }

    #[test]
    fn aggregates_sum_across_shards() {
        let m = ClusterMetrics {
            queue_bound: 8,
            shards: vec![shard(0, 1, 2, 4), shard(1, 3, 1, 6),
                         ShardMetrics::dead(2)],
        };
        assert_eq!(m.live_shards(), 2);
        assert_eq!(m.queue_depth(), 4);
        assert_eq!(m.active_slots(), 3);
        assert_eq!(m.completed(), 10);
        assert_eq!(m.pool_pages_in_use(), 10);
        assert_eq!(m.pool_pages_total(), 200);
        assert!((m.tokens_per_sec() - 100.0).abs() < 1e-9);
        assert!((m.avg_ttft_ms() - 30.0).abs() < 1e-9);
        assert_eq!(m.prefix_lookups(), 8);
        assert_eq!(m.prefix_hits(), 4);
        assert!((m.prefix_hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(m.prefix_tokens_saved(), 64);
        assert_eq!(m.prefix_pages_pinned(), 16);
        assert_eq!(m.kv4_completed() + m.kv8_completed(), m.completed(),
                   "tier splits must partition completed");
        assert_eq!(m.kv4_decode_tokens(), 100);
        assert_eq!(m.kv8_decode_tokens(), 50);
        assert_eq!(m.sessions_live(), 2);
        assert_eq!(m.session_turns(), 10);
        assert_eq!(m.session_prefill_tokens_saved(), 160);
        assert_eq!(m.prefill_chunks(), 20);
        assert_eq!(m.prefill_chunk_tokens(), 240);
        // dead shard 2 never built an engine (empty executor) and must
        // not turn an otherwise-uniform cluster "mixed"
        assert_eq!(m.executor(), "pjrt");
    }

    #[test]
    fn cluster_executor_reports_mixed_and_none() {
        let mut native = shard(1, 0, 0, 1);
        native.executor = "native".to_string();
        let m = ClusterMetrics {
            queue_bound: 8,
            shards: vec![shard(0, 0, 0, 1), native],
        };
        assert_eq!(m.executor(), "mixed");
        assert_eq!(ClusterMetrics::default().executor(), "none");
        let dead_only = ClusterMetrics {
            queue_bound: 8,
            shards: vec![ShardMetrics::dead(0)],
        };
        assert_eq!(dead_only.executor(), "none");
    }

    #[test]
    fn summary_keeps_pre_cluster_stats_keys() {
        // the wire `stats` frame consumers (serve_e2e, older clients) read
        // these keys — renaming them is a protocol break
        let m = ClusterMetrics { queue_bound: 8, shards: vec![shard(0, 0, 0, 1)] };
        let v = obj(m.summary_pairs());
        for key in ["completed", "cancelled", "failed", "tokens_per_sec",
                    "peak_cache_bytes", "peak_cache_fp16_bytes",
                    "pool_pages_in_use", "queue_bound",
                    // live-load additions
                    "queue_depth", "active_slots", "shards",
                    "deadline_exceeded",
                    // prefix-cache additions
                    "prefix_lookups", "prefix_hits", "prefix_hit_rate",
                    "prefix_tokens_saved", "prefix_pages_pinned",
                    // precision-tier additions
                    "kv4_completed", "kv8_completed",
                    "kv4_decode_tokens", "kv8_decode_tokens",
                    // session additions
                    "sessions_live", "session_turns",
                    "session_prefill_tokens_saved",
                    // latency-percentile additions
                    "ttft_p50_ms", "ttft_p999_ms", "itl_p50_ms",
                    "queue_wait_p99_ms", "tick_p90_ms",
                    // executor additions
                    "executor", "prefill_chunks", "prefill_chunk_tokens"] {
            assert!(v.get(key).is_some(), "summary missing key {key}");
        }
        // new keys append strictly after every pre-existing key: a v1
        // consumer indexing by position keeps working
        let pairs = m.summary_pairs();
        let idx = |k: &str| pairs.iter().position(|(p, _)| *p == k).unwrap();
        assert!(idx("kv4_completed") > idx("prefix_pages_pinned"),
                "tier keys must append after the v1 tail key");
        assert!(idx("sessions_live") > idx("kv8_decode_tokens"),
                "session keys must append after the tier tail key");
        assert!(idx("ttft_p50_ms") > idx("session_prefill_tokens_saved"),
                "percentile keys must append after the session tail key");
        assert!(idx("executor") > idx("tick_p999_ms"),
                "executor keys must append after the percentile tail key");
        // same contract on the per-shard rows
        let row = m.shards[0].to_value();
        assert_eq!(row.get("sessions_live").unwrap().as_usize(), Some(1));
        assert_eq!(row.get("session_prefill_tokens_saved").unwrap().as_usize(),
                   Some(16));
        assert_eq!(row.get("executor"),
                   Some(&Value::Str("pjrt".to_string())));
        assert_eq!(row.get("prefill_chunk_tokens").unwrap().as_usize(),
                   Some(24));
    }

    #[test]
    fn full_pairs_carry_per_shard_rows() {
        let m = ClusterMetrics {
            queue_bound: 4,
            shards: vec![shard(0, 0, 1, 2), shard(1, 1, 0, 3)],
        };
        let v = obj(m.full_pairs());
        let rows = v.get("per_shard").and_then(|p| p.as_arr()).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1].get("shard").unwrap().as_usize(), Some(1));
        assert_eq!(rows[1].get("completed").unwrap().as_usize(), Some(3));
        assert_eq!(rows[1].get("prefix_hits").unwrap().as_usize(), Some(2));
        assert_eq!(rows[1].get("prefix_pages_pinned").unwrap().as_usize(),
                   Some(8));
        // the render path must not panic and must mention every shard
        let rendered = m.render();
        assert!(rendered.contains("Σ"));
    }

    #[test]
    fn latency_summary_reduces_through_the_histogram() {
        let samples = [5.0, 1.0, 3.0, 2.0, 4.0];
        let s = LatencySummary::of(&samples);
        assert!((s.mean_ms - 3.0).abs() < 1e-12, "mean is exact");
        // log buckets at 4/octave have <=~19% width: percentile reads are
        // representative values, not exact order statistics
        assert!((s.p50_ms - 3.0).abs() / 3.0 < 0.2, "p50 ~ median");
        // the tail quantiles of 5 samples land in the max's bucket, and
        // quantile() clamps to the observed max — small batches must not
        // understate (or overstate) their tail
        assert_eq!(s.p95_ms, 5.0);
        assert_eq!(s.p99_ms, 5.0);
        // constant stream: every percentile is the value, exactly
        let c = LatencySummary::of(&[7.0; 9]);
        assert_eq!((c.p50_ms, c.p99_ms), (7.0, 7.0));
        let empty = LatencySummary::of(&[]);
        assert_eq!((empty.mean_ms, empty.p50_ms, empty.p95_ms, empty.p99_ms),
                   (0.0, 0.0, 0.0, 0.0));
    }

    #[test]
    fn cluster_percentiles_merge_histograms_not_shard_averages() {
        // shard 0: 90 fast requests (2ms TTFT); shard 1: 10 slow (200ms).
        // Averaging per-shard medians would claim ~101ms "typical" —
        // merging the histograms must keep p50 at the fast cohort and
        // the p99.9 tail at the slow one.
        let mut fast = shard(0, 0, 0, 90);
        for _ in 0..90 {
            fast.ttft_hist.record(2.0);
        }
        let mut slow = shard(1, 0, 0, 10);
        for _ in 0..10 {
            slow.ttft_hist.record(200.0);
        }
        let naive_avg_of_medians =
            (fast.ttft_hist.quantile(0.5) + slow.ttft_hist.quantile(0.5)) / 2.0;
        assert!(naive_avg_of_medians > 50.0,
                "precondition: the biased estimate is way off");

        let m = ClusterMetrics { queue_bound: 8, shards: vec![fast, slow] };
        let merged = m.ttft_hist();
        assert_eq!(merged.count(), 100);
        let p50 = merged.quantile(0.50);
        assert!((p50 - 2.0).abs() / 2.0 < 0.2,
                "merged p50 must track the 90% fast cohort, got {p50}");
        let p999 = merged.quantile(0.999);
        assert!((p999 - 200.0).abs() / 200.0 < 0.2,
                "merged p99.9 must surface the slow tail, got {p999}");
        // and that is what the wire summary reports
        let v = obj(m.summary_pairs());
        let wire_p50 = v.get("ttft_p50_ms").unwrap().as_f64().unwrap();
        assert!((wire_p50 - p50).abs() < 1e-9);
    }

    #[test]
    fn empty_cluster_metrics_are_all_zero() {
        let m = ClusterMetrics::default();
        assert_eq!(m.queue_depth(), 0);
        assert_eq!(m.tokens_per_sec(), 0.0);
        assert_eq!(m.avg_ttft_ms(), 0.0);
        assert_eq!(m.live_shards(), 0);
    }
}
