//! Typed façade over the PJRT engine: assembles graph argument lists from a
//! quantization spec + the weight archive, and exposes model-level
//! `prefill` / `decode` / `collect` calls the batcher and the eval harness
//! share.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::backend::{self, ComputeBackend};
use crate::model::{ModelConfig, Weights};
use crate::quant::{self, sym_levels};
use crate::runtime::{Engine, HostTensor};
use crate::tensor::Mat;

/// Which graph family + weight prefix to serve.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// unrotated graph + `base.*` weights (FP16 baseline, SmoothQuant, QUIK)
    Baseline,
    /// rotated graph + `rot.*` weights (QuaRot)
    Quarot,
    /// rotated graph, bf16 online Hadamards (Table 10)
    QuarotH16,
    /// rotated graph + `rnd.*` random-orthogonal weights (Table 8)
    QuarotRandom,
}

impl Variant {
    pub fn weight_prefix(self) -> &'static str {
        match self {
            Variant::Baseline => "base.",
            Variant::Quarot | Variant::QuarotH16 => "rot.",
            Variant::QuarotRandom => "rnd.",
        }
    }

    pub fn prefill_graph(self) -> &'static str {
        match self {
            Variant::Baseline => "baseline_prefill",
            Variant::Quarot | Variant::QuarotRandom => "quarot_prefill",
            Variant::QuarotH16 => "quarot_prefill_h16",
        }
    }

    pub fn decode_graph(self) -> &'static str {
        match self {
            Variant::Baseline => "baseline_decode",
            _ => "quarot_decode",
        }
    }

    pub fn is_rotated(self) -> bool {
        !matches!(self, Variant::Baseline)
    }
}

/// Weight-side quantization applied before pinning weights to the engine.
#[derive(Clone, Debug)]
pub enum WeightQuant {
    None,
    Rtn(quant::rtn::WeightQuantCfg),
    /// GPTQ needs per-site Hessians (from [`Runner::collect_stats`]).
    Gptq(quant::gptq::GptqCfg, CalibStats),
}

/// Full serving/eval specification.
#[derive(Clone, Debug)]
pub struct QuantSpec {
    pub variant: Variant,
    pub act_bits: u32,  // 0 → FP16 activations
    pub act_clip: f32,
    /// key-cache bits; 16 → f32 cache (baseline decode graph / quant off)
    pub kv_bits: u32,
    /// value-cache bits; defaults to kv_bits (Table 6 sweeps them apart)
    pub kv_bits_v: u32,
    pub kv_clip: f32,
    pub weights: WeightQuant,
    /// QUIK-style outlier retention count per site (baseline graph only).
    pub outliers: usize,
    /// SmoothQuant α-migration before quantization: the baseline graph's
    /// SmoothQuant mode, and the `scaled-hadamard` rotation's
    /// scale-then-rotate fold on rotated weights.
    pub smooth: bool,
}

impl QuantSpec {
    pub fn fp16_baseline() -> Self {
        QuantSpec {
            variant: Variant::Baseline, act_bits: 0, act_clip: 1.0,
            kv_bits: 16, kv_bits_v: 16, kv_clip: 1.0, weights: WeightQuant::None,
            outliers: 0, smooth: false,
        }
    }

    pub fn quarot(bits: u32) -> Self {
        let kv = bits.min(8);
        QuantSpec {
            variant: Variant::Quarot, act_bits: bits, act_clip: 0.9,
            kv_bits: kv, kv_bits_v: kv, kv_clip: 0.95,
            weights: WeightQuant::Rtn(quant::rtn::WeightQuantCfg::rtn(bits)),
            outliers: 0, smooth: false,
        }
    }

    pub fn act_levels(&self) -> f32 {
        if self.act_bits == 0 { 0.0 } else { sym_levels(self.act_bits) as f32 }
    }

    /// True when the KV cache stays in floating point (the fp16
    /// baseline): no paged quantized cache — the dense f32 staging is
    /// the authoritative store.  Single source of truth for every
    /// "is this the fp path" branch in the serving stack.
    pub fn kv_is_fp(&self) -> bool {
        self.kv_bits >= 16
    }

    fn qmax(bits: u32) -> f32 {
        if bits >= 16 { 0.0 } else { ((1u32 << bits) - 1) as f32 }
    }

    pub fn k_qmax(&self) -> f32 {
        Self::qmax(self.kv_bits)
    }

    pub fn v_qmax(&self) -> f32 {
        Self::qmax(self.kv_bits_v)
    }
}

/// Calibration statistics from the collect graphs: per-layer, per-site
/// Hessians (site dims) and channel amax.
#[derive(Clone, Debug, Default)]
pub struct CalibStats {
    /// [site][layer] → Hessian (d_site × d_site)
    pub hessians: Vec<Vec<Mat>>,
    /// [site][layer] → channel amax
    pub amax: Vec<Vec<Vec<f32>>>,
}

/// Site index → which weight matrices it feeds.
pub const SITE_WEIGHTS: [&[&str]; 4] =
    [&["wq", "wk", "wv"], &["wo"], &["wup", "wgate"], &["wdown"]];
pub const SITE_MASKS: [&str; 4] = ["mask_attn", "mask_out", "mask_ffn", "mask_down"];

pub struct Runner {
    pub engine: Engine,
    pub cfg: ModelConfig,
    pub spec: QuantSpec,
    /// Native compute backend for the serving hot paths (weight prep
    /// fan-out here; staging dequant + slot fan-out in the batcher).
    /// Selected via `backend::default_backend()` — `--backend` flag /
    /// `QUAROT_BACKEND` env, defaulting to shape-aware auto.
    pub backend: Arc<dyn ComputeBackend>,
    prefill_graph: String,
    decode_graph: String,
}

impl Runner {
    /// Build a runner: quantize the weights per `spec`, pin them (+ masks)
    /// on the prefill/decode graphs.
    pub fn new(mut engine: Engine, weights: &Weights, spec: QuantSpec,
               stats: Option<&CalibStats>) -> Result<Runner> {
        let cfg = engine.manifest.model.clone();
        let prepared = prepare_weights(&cfg, &engine.manifest.weight_order,
                                       weights, &spec, stats)?;
        let masks = build_masks(&cfg, &spec, stats)?;
        let prefill_graph = spec.variant.prefill_graph().to_string();
        let decode_graph = spec.variant.decode_graph().to_string();
        let mut prefill_args = Vec::new();
        if spec.variant == Variant::Baseline {
            prefill_args.extend(masks.iter().cloned());
        }
        prefill_args.extend(prepared.iter().cloned());
        if engine.has_graph(&prefill_graph) {
            engine.set_weights(&prefill_graph, &prefill_args)?;
        }
        if engine.has_graph(&decode_graph) {
            engine.set_weights(&decode_graph, &prepared)?;
        }
        Ok(Runner {
            engine,
            cfg,
            spec,
            backend: backend::default_backend(),
            prefill_graph,
            decode_graph,
        })
    }

    /// Prefill `tokens` (padded to max_seq internally).  Returns
    /// (logits (S, V) for the real length, k, v (L, S_real, d_kv)).
    pub fn prefill(&self, tokens: &[u16]) -> Result<Prefilled> {
        let (cfg, s_max) = (&self.cfg, self.cfg.max_seq);
        let s_real = tokens.len();
        if s_real == 0 || s_real > s_max {
            bail!("prefill length {s_real} outside 1..={s_max}");
        }
        let mut padded = vec![0i32; s_max];
        for (p, &t) in padded.iter_mut().zip(tokens) {
            *p = t as i32;
        }
        let dynamic = vec![
            HostTensor::I32(padded),
            HostTensor::F32(vec![self.spec.act_levels()]),
            HostTensor::F32(vec![self.spec.act_clip]),
            HostTensor::F32(vec![self.spec.k_qmax()]),
            HostTensor::F32(vec![self.spec.v_qmax()]),
            HostTensor::F32(vec![self.spec.kv_clip]),
        ];
        let out = self.engine.run(&self.prefill_graph, &dynamic)?;
        let (v, d_kv, l) = (cfg.vocab, cfg.d_kv(), cfg.n_layers);
        let logits_full = out[0].f32();
        let ks_full = out[1].f32();
        let vs_full = out[2].f32();
        let mut logits = Vec::with_capacity(s_real * v);
        logits.extend_from_slice(&logits_full[..s_real * v]);
        // k/v layout (L, 1, S, hk, dh) → keep first s_real tokens per layer
        let mut ks = Vec::with_capacity(l * s_real * d_kv);
        let mut vs = Vec::with_capacity(l * s_real * d_kv);
        for li in 0..l {
            let o = li * s_max * d_kv;
            ks.extend_from_slice(&ks_full[o..o + s_real * d_kv]);
            vs.extend_from_slice(&vs_full[o..o + s_real * d_kv]);
        }
        Ok(Prefilled { logits, ks, vs, len: s_real })
    }

    /// One batched decode step.  `staging` carries the dense cache views.
    /// Returns (logits (B, V), k_new, v_new (L, B, d_kv)).
    pub fn decode(&self, tokens: &[i32], cur_lens: &[i32], staging: &DecodeStaging)
                  -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let dynamic: Vec<HostTensor> = if self.spec.kv_is_fp() {
            vec![
                HostTensor::I32(tokens.to_vec()),
                HostTensor::I32(cur_lens.to_vec()),
                HostTensor::F32(staging.k_f32.clone()),
                HostTensor::F32(staging.v_f32.clone()),
                HostTensor::F32(vec![self.spec.act_levels()]),
                HostTensor::F32(vec![self.spec.act_clip]),
            ]
        } else {
            vec![
                HostTensor::I32(tokens.to_vec()),
                HostTensor::I32(cur_lens.to_vec()),
                HostTensor::I8(staging.k_codes.clone()),
                HostTensor::F32(staging.k_scale.clone()),
                HostTensor::F32(staging.k_zero.clone()),
                HostTensor::I8(staging.v_codes.clone()),
                HostTensor::F32(staging.v_scale.clone()),
                HostTensor::F32(staging.v_zero.clone()),
                HostTensor::F32(vec![self.spec.act_levels()]),
                HostTensor::F32(vec![self.spec.act_clip]),
            ]
        };
        let out = self.engine.run(&self.decode_graph, &dynamic)?;
        Ok((out[0].f32().to_vec(), out[1].f32().to_vec(), out[2].f32().to_vec()))
    }

    /// Run the matching collect graph over calibration windows and
    /// accumulate Hessians + amax (GPTQ / SmoothQuant / QUIK inputs).
    pub fn collect_stats(engine: &Engine, weights: &Weights, rotated: bool,
                         calib: &[u16], windows: usize) -> Result<CalibStats> {
        let cfg = engine.manifest.model.clone();
        let graph = if rotated { "collect_quarot" } else { "collect_baseline" };
        let prefix = if rotated { "rot." } else { "base." };
        let wlist = ordered_weights(&engine.manifest.weight_order, weights, prefix)?;
        let s = cfg.max_seq;
        let site_dims = [cfg.d_model, cfg.d_attn(), cfg.d_model, cfg.d_ff];
        let mut stats = CalibStats {
            hessians: site_dims.iter()
                .map(|&d| (0..cfg.n_layers).map(|_| Mat::zeros(d, d)).collect())
                .collect(),
            amax: site_dims.iter()
                .map(|&d| vec![vec![0.0f32; d]; cfg.n_layers])
                .collect(),
        };
        let n_windows = windows.min(calib.len() / s);
        for w in 0..n_windows {
            let toks: Vec<i32> = calib[w * s..(w + 1) * s].iter()
                .map(|&t| t as i32).collect();
            let mut args = vec![HostTensor::I32(toks)];
            args.extend(wlist.iter().cloned());
            let out = engine.run(graph, &args)?;
            for site in 0..4 {
                let h = out[site * 2].f32();
                let a = out[site * 2 + 1].f32();
                let d = site_dims[site];
                for l in 0..cfg.n_layers {
                    let hm = &mut stats.hessians[site][l];
                    for (dst, src) in hm.data.iter_mut()
                        .zip(&h[l * d * d..(l + 1) * d * d]) {
                        *dst += src;
                    }
                    for (dst, src) in stats.amax[site][l].iter_mut()
                        .zip(&a[l * d..(l + 1) * d]) {
                        *dst = dst.max(*src);
                    }
                }
            }
        }
        Ok(stats)
    }
}

pub struct Prefilled {
    pub logits: Vec<f32>,
    pub ks: Vec<f32>,
    pub vs: Vec<f32>,
    pub len: usize,
}

/// Dense staging buffers for the decode graph's cache inputs.
pub struct DecodeStaging {
    pub k_codes: Vec<i8>,
    pub k_scale: Vec<f32>,
    pub k_zero: Vec<f32>,
    pub v_codes: Vec<i8>,
    pub v_scale: Vec<f32>,
    pub v_zero: Vec<f32>,
    /// fp16-baseline path (kv_bits == 16): raw f32 caches.
    pub k_f32: Vec<f32>,
    pub v_f32: Vec<f32>,
}

impl DecodeStaging {
    pub fn new(cfg: &ModelConfig, fp: bool) -> DecodeStaging {
        let (l, b, s) = (cfg.n_layers, cfg.decode_batch, cfg.cache_seq);
        let d = cfg.d_kv();
        let ng = d / cfg.kv_group;
        if fp {
            DecodeStaging {
                k_codes: vec![], k_scale: vec![], k_zero: vec![],
                v_codes: vec![], v_scale: vec![], v_zero: vec![],
                k_f32: vec![0.0; l * b * s * d], v_f32: vec![0.0; l * b * s * d],
            }
        } else {
            DecodeStaging {
                k_codes: vec![0; l * b * s * d],
                k_scale: vec![0.0; l * b * s * ng],
                k_zero: vec![0.0; l * b * s * ng],
                v_codes: vec![0; l * b * s * d],
                v_scale: vec![0.0; l * b * s * ng],
                v_zero: vec![0.0; l * b * s * ng],
                k_f32: vec![], v_f32: vec![],
            }
        }
    }
}

/// Pull the named weights out of the archive in manifest order.
fn ordered_weights(order: &[String], weights: &Weights, prefix: &str)
                   -> Result<Vec<HostTensor>> {
    order.iter()
        .map(|name| {
            let t = weights.get(&format!("{prefix}{name}"))?;
            Ok(HostTensor::F32(t.as_f32()))
        })
        .collect()
}

/// Apply the spec's weight-side quantization (RTN/GPTQ ± SmoothQuant/QUIK)
/// and return graph-ready tensors in manifest order.
pub fn prepare_weights(cfg: &ModelConfig, order: &[String], weights: &Weights,
                       spec: &QuantSpec, stats: Option<&CalibStats>)
                       -> Result<Vec<HostTensor>> {
    let prefix = spec.variant.weight_prefix();
    // load all layer weights into Mats per layer
    let mut mats: std::collections::BTreeMap<String, Vec<Mat>> = Default::default();
    let mut vecs: std::collections::BTreeMap<String, Vec<f32>> = Default::default();
    for name in order {
        let t = weights.get(&format!("{prefix}{name}"))?;
        match t.shape.len() {
            3 => {
                let (l, r, c) = (t.shape[0], t.shape[1], t.shape[2]);
                let data = t.as_f32();
                mats.insert(name.clone(), (0..l).map(|li| {
                    Mat::from_vec(r, c, data[li * r * c..(li + 1) * r * c].to_vec())
                }).collect());
            }
            _ => {
                vecs.insert(name.clone(), t.as_f32());
            }
        }
    }

    // SmoothQuant migration (baseline only): fold per-channel scales
    if spec.smooth {
        let stats = stats.context("SmoothQuant requires calibration stats")?;
        apply_smoothquant(cfg, &mut mats, &mut vecs, stats);
    }

    // weight quantization (embed/lm_head stay f32, like the paper)
    match &spec.weights {
        WeightQuant::None => {}
        WeightQuant::Rtn(qcfg) => {
            if spec.outliers > 0 {
                for (name, layers) in mats.iter_mut() {
                    if name == "embed" || name == "lm_head" {
                        continue;
                    }
                    for m in layers.iter_mut() {
                        // QUIK: keep calibrated outlier input rows exact
                        let site = site_of_weight(name);
                        let stats = stats.context("QUIK requires calib stats")?;
                        // layer index unknown here; approximate with max over layers
                        let mut amax = vec![0.0f32; m.rows];
                        for l in 0..cfg.n_layers {
                            for (a, b) in amax.iter_mut()
                                .zip(&stats.amax[site][l]) {
                                *a = a.max(*b);
                            }
                        }
                        let outl = quant::outlier::top_k_outliers(&amax, spec.outliers);
                        quant::outlier::fake_quant_weight_with_outliers(m, &outl, qcfg);
                    }
                }
            } else {
                // Plain RTN: the per-column clip search is independent per
                // matrix — fan it over the compute backend (disjoint &mut
                // access through SendPtr; par_for joins before we read).
                let ptrs: Vec<crate::backend::pool::SendPtr<Mat>> = mats
                    .iter_mut()
                    .filter(|(name, _)| name.as_str() != "embed"
                            && name.as_str() != "lm_head")
                    .flat_map(|(_, layers)| layers.iter_mut())
                    .map(|m| crate::backend::pool::SendPtr::new(m as *mut Mat))
                    .collect();
                let backend = backend::default_backend();
                let qcfg = *qcfg;
                backend.par_for(ptrs.len(), &|i| {
                    let m = unsafe { &mut *ptrs[i].get() };
                    quant::rtn::fake_quant_weight(m, &qcfg);
                });
            }
        }
        WeightQuant::Gptq(gcfg, stats) => {
            for (name, layers) in mats.iter_mut() {
                if name == "embed" || name == "lm_head" {
                    continue;
                }
                let site = site_of_weight(name);
                for (l, m) in layers.iter_mut().enumerate() {
                    quant::gptq::gptq_quantize(m, &stats.hessians[site][l], gcfg);
                }
            }
        }
    }

    // reassemble in manifest order
    order.iter().map(|name| {
        if let Some(layers) = mats.get(name) {
            let mut flat = Vec::new();
            for m in layers {
                flat.extend_from_slice(&m.data);
            }
            Ok(HostTensor::F32(flat))
        } else {
            Ok(HostTensor::F32(vecs[name].clone()))
        }
    }).collect()
}

fn site_of_weight(name: &str) -> usize {
    match name {
        "wq" | "wk" | "wv" => 0,
        "wo" => 1,
        "wup" | "wgate" => 2,
        "wdown" => 3,
        _ => panic!("no site for {name}"),
    }
}

fn apply_smoothquant(cfg: &ModelConfig,
                     mats: &mut std::collections::BTreeMap<String, Vec<Mat>>,
                     vecs: &mut std::collections::BTreeMap<String, Vec<f32>>,
                     stats: &CalibStats) {
    let scfg = quant::smooth::SmoothCfg::default();
    for l in 0..cfg.n_layers {
        // site 0: attn inputs ← fold 1/s into attn_norm gamma
        let s0 = quant::smooth::smooth_scales(&stats.amax[0][l],
                                              &mats["wq"][l], &scfg);
        for name in ["wq", "wk", "wv"] {
            quant::smooth::apply_to_weight(&mut mats.get_mut(name).unwrap()[l], &s0);
        }
        let d = cfg.d_model;
        quant::smooth::fold_into_producer(
            &mut vecs.get_mut("attn_norm").unwrap()[l * d..(l + 1) * d], &s0);
        // site 2: ffn inputs ← fold into ffn_norm
        let s2 = quant::smooth::smooth_scales(&stats.amax[2][l],
                                              &mats["wup"][l], &scfg);
        for name in ["wup", "wgate"] {
            quant::smooth::apply_to_weight(&mut mats.get_mut(name).unwrap()[l], &s2);
        }
        quant::smooth::fold_into_producer(
            &mut vecs.get_mut("ffn_norm").unwrap()[l * d..(l + 1) * d], &s2);
        // site 3: down-proj input ← fold 1/s into wup's output columns
        let s3 = quant::smooth::smooth_scales(&stats.amax[3][l],
                                              &mats["wdown"][l], &scfg);
        quant::smooth::apply_to_weight(&mut mats.get_mut("wdown").unwrap()[l], &s3);
        let wup = &mut mats.get_mut("wup").unwrap()[l];
        let inv: Vec<f32> = s3.iter().map(|s| 1.0 / s).collect();
        wup.scale_cols(&inv);
        // site 1: out-proj input ← fold 1/s into wv's output columns.
        // Only exact for MHA: with GQA one wv column feeds several q-heads,
        // so per-channel migration is ill-defined there — skip (SmoothQuant
        // never targeted GQA models anyway).
        if cfg.n_heads == cfg.n_kv_heads {
            let s1 = quant::smooth::smooth_scales(&stats.amax[1][l],
                                                  &mats["wo"][l], &scfg);
            quant::smooth::apply_to_weight(&mut mats.get_mut("wo").unwrap()[l], &s1);
            let wv = &mut mats.get_mut("wv").unwrap()[l];
            let inv1: Vec<f32> = s1.iter().map(|s| 1.0 / s).collect();
            wv.scale_cols(&inv1);
        }
    }
}

/// Build the QUIK outlier masks for the baseline graph (zeroes if unused).
pub fn build_masks(cfg: &ModelConfig, spec: &QuantSpec, stats: Option<&CalibStats>)
                   -> Result<Vec<HostTensor>> {
    let dims = [cfg.d_model, cfg.d_attn(), cfg.d_model, cfg.d_ff];
    let mut out = Vec::with_capacity(4);
    for (site, &d) in dims.iter().enumerate() {
        let mut mask = vec![0.0f32; cfg.n_layers * d];
        if spec.outliers > 0 {
            let stats = stats.context("outlier masks require calib stats")?;
            for l in 0..cfg.n_layers {
                let idx = quant::outlier::top_k_outliers(&stats.amax[site][l],
                                                         spec.outliers);
                for i in idx {
                    mask[l * d + i] = 1.0;
                }
            }
        }
        out.push(HostTensor::F32(mask));
    }
    Ok(out)
}
