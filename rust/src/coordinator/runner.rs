//! Model-level dispatcher: a [`Runner`] owns one [`ModelExecutor`] —
//! either the AOT-graph [`PjrtExecutor`] or the pure-rust
//! [`crate::forward::NativeExecutor`] — and exposes the `prefill` /
//! `prefill_chunk` / `decode` / `collect` calls the batcher and the eval
//! harness share, plus the weight-preparation pipeline both executors
//! reuse.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::backend::{self, ComputeBackend};
pub use crate::forward::{ChunkResult, DecodeStaging, ExecutorKind,
                         ModelExecutor, Prefilled};
use crate::forward::{stage_kv_token, NativeExecutor};
use crate::model::{ModelConfig, Weights};
use crate::quant::{self, sym_levels};
use crate::runtime::{Engine, HostTensor};
use crate::tensor::Mat;

/// Which graph family + weight prefix to serve.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// unrotated graph + `base.*` weights (FP16 baseline, SmoothQuant, QUIK)
    Baseline,
    /// rotated graph + `rot.*` weights (QuaRot)
    Quarot,
    /// rotated graph, bf16 online Hadamards (Table 10)
    QuarotH16,
    /// rotated graph + `rnd.*` random-orthogonal weights (Table 8)
    QuarotRandom,
}

impl Variant {
    pub fn weight_prefix(self) -> &'static str {
        match self {
            Variant::Baseline => "base.",
            Variant::Quarot | Variant::QuarotH16 => "rot.",
            Variant::QuarotRandom => "rnd.",
        }
    }

    pub fn prefill_graph(self) -> &'static str {
        match self {
            Variant::Baseline => "baseline_prefill",
            Variant::Quarot | Variant::QuarotRandom => "quarot_prefill",
            Variant::QuarotH16 => "quarot_prefill_h16",
        }
    }

    pub fn decode_graph(self) -> &'static str {
        match self {
            Variant::Baseline => "baseline_decode",
            _ => "quarot_decode",
        }
    }

    pub fn is_rotated(self) -> bool {
        !matches!(self, Variant::Baseline)
    }
}

/// Weight-side quantization applied before pinning weights to the engine.
#[derive(Clone, Debug)]
pub enum WeightQuant {
    None,
    Rtn(quant::rtn::WeightQuantCfg),
    /// GPTQ needs per-site Hessians (from [`Runner::collect_stats`]).
    Gptq(quant::gptq::GptqCfg, CalibStats),
}

/// Full serving/eval specification.
#[derive(Clone, Debug)]
pub struct QuantSpec {
    pub variant: Variant,
    pub act_bits: u32,  // 0 → FP16 activations
    pub act_clip: f32,
    /// key-cache bits; 16 → f32 cache (baseline decode graph / quant off)
    pub kv_bits: u32,
    /// value-cache bits; defaults to kv_bits (Table 6 sweeps them apart)
    pub kv_bits_v: u32,
    pub kv_clip: f32,
    pub weights: WeightQuant,
    /// QUIK-style outlier retention count per site (baseline graph only).
    pub outliers: usize,
    /// SmoothQuant α-migration before quantization: the baseline graph's
    /// SmoothQuant mode, and the `scaled-hadamard` rotation's
    /// scale-then-rotate fold on rotated weights.
    pub smooth: bool,
}

impl QuantSpec {
    pub fn fp16_baseline() -> Self {
        QuantSpec {
            variant: Variant::Baseline, act_bits: 0, act_clip: 1.0,
            kv_bits: 16, kv_bits_v: 16, kv_clip: 1.0, weights: WeightQuant::None,
            outliers: 0, smooth: false,
        }
    }

    pub fn quarot(bits: u32) -> Self {
        let kv = bits.min(8);
        QuantSpec {
            variant: Variant::Quarot, act_bits: bits, act_clip: 0.9,
            kv_bits: kv, kv_bits_v: kv, kv_clip: 0.95,
            weights: WeightQuant::Rtn(quant::rtn::WeightQuantCfg::rtn(bits)),
            outliers: 0, smooth: false,
        }
    }

    pub fn act_levels(&self) -> f32 {
        if self.act_bits == 0 { 0.0 } else { sym_levels(self.act_bits) as f32 }
    }

    /// True when the KV cache stays in floating point (the fp16
    /// baseline): no paged quantized cache — the dense f32 staging is
    /// the authoritative store.  Single source of truth for every
    /// "is this the fp path" branch in the serving stack.
    pub fn kv_is_fp(&self) -> bool {
        self.kv_bits >= 16
    }

    fn qmax(bits: u32) -> f32 {
        if bits >= 16 { 0.0 } else { ((1u32 << bits) - 1) as f32 }
    }

    pub fn k_qmax(&self) -> f32 {
        Self::qmax(self.kv_bits)
    }

    pub fn v_qmax(&self) -> f32 {
        Self::qmax(self.kv_bits_v)
    }
}

/// Calibration statistics from the collect graphs: per-layer, per-site
/// Hessians (site dims) and channel amax.
#[derive(Clone, Debug, Default)]
pub struct CalibStats {
    /// [site][layer] → Hessian (d_site × d_site)
    pub hessians: Vec<Vec<Mat>>,
    /// [site][layer] → channel amax
    pub amax: Vec<Vec<Vec<f32>>>,
}

/// Site index → which weight matrices it feeds.
pub const SITE_WEIGHTS: [&[&str]; 4] =
    [&["wq", "wk", "wv"], &["wo"], &["wup", "wgate"], &["wdown"]];
pub const SITE_MASKS: [&str; 4] = ["mask_attn", "mask_out", "mask_ffn", "mask_down"];

/// The original AOT-graph execution path: assembles PJRT argument lists
/// and runs the compiled prefill/decode executables.  Kept bit-for-bit —
/// `prefill` and `decode` are the pre-refactor `Runner` methods moved
/// behind the trait, and `prefill_chunk` replays the decode graph
/// token-at-a-time exactly like the old partial-hit suffix loop did
/// (same graph, same lane layout, same `quant_slab` staging arithmetic).
pub struct PjrtExecutor {
    engine: Engine,
    cfg: ModelConfig,
    spec: QuantSpec,
    prefill_graph: String,
    decode_graph: String,
}

impl PjrtExecutor {
    /// Quantize the weights per `spec` and pin them (+ masks) on the
    /// prefill/decode graphs.
    pub fn new(mut engine: Engine, weights: &Weights, spec: QuantSpec,
               stats: Option<&CalibStats>) -> Result<PjrtExecutor> {
        let cfg = engine.manifest.model.clone();
        let prepared = prepare_weights(&cfg, &engine.manifest.weight_order,
                                       weights, &spec, stats)?;
        let masks = build_masks(&cfg, &spec, stats)?;
        let prefill_graph = spec.variant.prefill_graph().to_string();
        let decode_graph = spec.variant.decode_graph().to_string();
        let mut prefill_args = Vec::new();
        if spec.variant == Variant::Baseline {
            prefill_args.extend(masks.iter().cloned());
        }
        prefill_args.extend(prepared.iter().cloned());
        if engine.has_graph(&prefill_graph) {
            engine.set_weights(&prefill_graph, &prefill_args)?;
        }
        if engine.has_graph(&decode_graph) {
            engine.set_weights(&decode_graph, &prepared)?;
        }
        Ok(PjrtExecutor { engine, cfg, spec, prefill_graph, decode_graph })
    }

    fn prefill_impl(&self, tokens: &[u16]) -> Result<Prefilled> {
        let (cfg, s_max) = (&self.cfg, self.cfg.max_seq);
        let s_real = tokens.len();
        if s_real == 0 || s_real > s_max {
            bail!("prefill length {s_real} outside 1..={s_max}");
        }
        let mut padded = vec![0i32; s_max];
        for (p, &t) in padded.iter_mut().zip(tokens) {
            *p = t as i32;
        }
        let dynamic = vec![
            HostTensor::I32(padded),
            HostTensor::F32(vec![self.spec.act_levels()]),
            HostTensor::F32(vec![self.spec.act_clip]),
            HostTensor::F32(vec![self.spec.k_qmax()]),
            HostTensor::F32(vec![self.spec.v_qmax()]),
            HostTensor::F32(vec![self.spec.kv_clip]),
        ];
        let out = self.engine.run(&self.prefill_graph, &dynamic)?;
        let (v, d_kv, l) = (cfg.vocab, cfg.d_kv(), cfg.n_layers);
        let logits_full = out[0].f32();
        let ks_full = out[1].f32();
        let vs_full = out[2].f32();
        let mut logits = Vec::with_capacity(s_real * v);
        logits.extend_from_slice(&logits_full[..s_real * v]);
        // k/v layout (L, 1, S, hk, dh) → keep first s_real tokens per layer
        let mut ks = Vec::with_capacity(l * s_real * d_kv);
        let mut vs = Vec::with_capacity(l * s_real * d_kv);
        for li in 0..l {
            let o = li * s_max * d_kv;
            ks.extend_from_slice(&ks_full[o..o + s_real * d_kv]);
            vs.extend_from_slice(&vs_full[o..o + s_real * d_kv]);
        }
        Ok(Prefilled { logits, ks, vs, len: s_real })
    }

    fn decode_impl(&self, tokens: &[i32], cur_lens: &[i32],
                   staging: &DecodeStaging)
                   -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let dynamic: Vec<HostTensor> = if self.spec.kv_is_fp() {
            vec![
                HostTensor::I32(tokens.to_vec()),
                HostTensor::I32(cur_lens.to_vec()),
                HostTensor::F32(staging.k_f32.clone()),
                HostTensor::F32(staging.v_f32.clone()),
                HostTensor::F32(vec![self.spec.act_levels()]),
                HostTensor::F32(vec![self.spec.act_clip]),
            ]
        } else {
            vec![
                HostTensor::I32(tokens.to_vec()),
                HostTensor::I32(cur_lens.to_vec()),
                HostTensor::I8(staging.k_codes.clone()),
                HostTensor::F32(staging.k_scale.clone()),
                HostTensor::F32(staging.k_zero.clone()),
                HostTensor::I8(staging.v_codes.clone()),
                HostTensor::F32(staging.v_scale.clone()),
                HostTensor::F32(staging.v_zero.clone()),
                HostTensor::F32(vec![self.spec.act_levels()]),
                HostTensor::F32(vec![self.spec.act_clip]),
            ]
        };
        let out = self.engine.run(&self.decode_graph, &dynamic)?;
        Ok((out[0].f32().to_vec(), out[1].f32().to_vec(), out[2].f32().to_vec()))
    }

    /// Replay `tokens` at positions `start_pos..` through the decode
    /// graph, one token per step — the same graph invocation sequence
    /// (and therefore the same bits) as the old token-at-a-time suffix
    /// loop in the batcher, with the staging writes hoisted here.
    fn prefill_chunk_impl(&self, tokens: &[u16], start_pos: usize,
                          slot: usize, kv_bits: u32,
                          staging: &mut DecodeStaging) -> Result<ChunkResult> {
        let cfg = &self.cfg;
        let b = cfg.decode_batch;
        let (v, d_kv, l) = (cfg.vocab, cfg.d_kv(), cfg.n_layers);
        let t_n = tokens.len();
        if t_n == 0 {
            bail!("empty prefill chunk");
        }
        if slot >= b {
            bail!("chunk slot {slot} out of range");
        }
        if start_pos + t_n > cfg.cache_seq {
            bail!("chunk [{start_pos}, {}) beyond cache_seq {}",
                  start_pos + t_n, cfg.cache_seq);
        }
        let fp = self.spec.kv_is_fp();
        let mut logits = vec![0.0f32; t_n * v];
        let mut ks = vec![0.0f32; l * t_n * d_kv];
        let mut vs = vec![0.0f32; l * t_n * d_kv];
        for (j, &tok) in tokens.iter().enumerate() {
            let mut toks = vec![0i32; b];
            let mut lens = vec![0i32; b];
            toks[slot] = tok as i32;
            lens[slot] = (start_pos + j) as i32;
            let (lg, kn, vn) = self.decode_impl(&toks, &lens, staging)?;
            logits[j * v..(j + 1) * v]
                .copy_from_slice(&lg[slot * v..(slot + 1) * v]);
            for li in 0..l {
                let o = (li * b + slot) * d_kv;
                ks[(li * t_n + j) * d_kv..(li * t_n + j + 1) * d_kv]
                    .copy_from_slice(&kn[o..o + d_kv]);
                vs[(li * t_n + j) * d_kv..(li * t_n + j + 1) * d_kv]
                    .copy_from_slice(&vn[o..o + d_kv]);
            }
            stage_kv_token(staging, cfg, slot, start_pos + j, kv_bits,
                           self.spec.kv_clip, fp, &kn, &vn);
        }
        Ok(ChunkResult { logits, k: ks, v: vs })
    }
}

impl ModelExecutor for PjrtExecutor {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn prefill(&self, tokens: &[u16]) -> Result<Prefilled> {
        self.prefill_impl(tokens)
    }

    fn decode(&self, tokens: &[i32], cur_lens: &[i32], staging: &DecodeStaging)
              -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        self.decode_impl(tokens, cur_lens, staging)
    }

    fn prefill_chunk(&self, tokens: &[u16], start_pos: usize, slot: usize,
                     kv_bits: u32, staging: &mut DecodeStaging)
                     -> Result<ChunkResult> {
        self.prefill_chunk_impl(tokens, start_pos, slot, kv_bits, staging)
    }
}

/// Model-level dispatcher the batcher / eval harness / benches drive:
/// one [`ModelExecutor`] behind a stable façade, plus the shared config,
/// spec, and compute backend.
pub struct Runner {
    exec: Box<dyn ModelExecutor>,
    pub cfg: ModelConfig,
    pub spec: QuantSpec,
    /// Native compute backend for the serving hot paths (weight prep
    /// fan-out here; staging dequant + slot fan-out in the batcher).
    /// Selected via `backend::default_backend()` — `--backend` flag /
    /// `QUAROT_BACKEND` env, defaulting to shape-aware auto.
    pub backend: Arc<dyn ComputeBackend>,
}

impl Runner {
    /// Build a runner on the PJRT graph path: quantize the weights per
    /// `spec`, pin them (+ masks) on the prefill/decode graphs.
    pub fn new(engine: Engine, weights: &Weights, spec: QuantSpec,
               stats: Option<&CalibStats>) -> Result<Runner> {
        let exec = PjrtExecutor::new(engine, weights, spec.clone(), stats)?;
        let cfg = exec.cfg.clone();
        Ok(Runner {
            exec: Box::new(exec),
            cfg,
            spec,
            backend: backend::default_backend(),
        })
    }

    /// Build a runner on the native path: the engine contributes only its
    /// manifest (model config + weight order) and is dropped — no PJRT
    /// client, no graphs.  Load it with `Engine::load(dir, Some(&[]))`.
    pub fn new_native(engine: Engine, weights: &Weights, spec: QuantSpec,
                      stats: Option<&CalibStats>) -> Result<Runner> {
        let cfg = engine.manifest.model.clone();
        let order = engine.manifest.weight_order.clone();
        Self::new_native_from_parts(&cfg, &order, weights, spec, stats)
    }

    /// Artifact-free native construction (tests / benches build the
    /// config + weight archive in memory).
    pub fn new_native_from_parts(cfg: &ModelConfig, order: &[String],
                                 weights: &Weights, spec: QuantSpec,
                                 stats: Option<&CalibStats>) -> Result<Runner> {
        Self::new_native_with_backend(cfg, order, weights, spec, stats,
                                      backend::default_backend())
    }

    /// Native construction on an explicit compute backend.  Tests and
    /// benches pin the scalar oracle here when they compare runs across
    /// different forward shapes (chunk sizes): per-row arithmetic is
    /// bit-stable on a fixed backend, while the auto backend may pick
    /// differently-tiled kernels for different row counts.
    pub fn new_native_with_backend(cfg: &ModelConfig, order: &[String],
                                   weights: &Weights, spec: QuantSpec,
                                   stats: Option<&CalibStats>,
                                   backend: Arc<dyn ComputeBackend>)
                                   -> Result<Runner> {
        let exec = NativeExecutor::new(cfg, order, weights, spec.clone(),
                                       stats, backend.clone())?;
        Ok(Runner {
            exec: Box::new(exec),
            cfg: cfg.clone(),
            spec,
            backend,
        })
    }

    /// Which execution path serves this runner ("pjrt" / "native").
    pub fn executor_name(&self) -> &'static str {
        self.exec.name()
    }

    /// Prefill `tokens` (graph path pads to max_seq internally).  Returns
    /// (logits (S, V) for the real length, k, v (L, S_real, d_kv)).
    pub fn prefill(&self, tokens: &[u16]) -> Result<Prefilled> {
        self.exec.prefill(tokens)
    }

    /// One batched decode step.  `staging` carries the dense cache views.
    /// Returns (logits (B, V), k_new, v_new (L, B, d_kv)).
    pub fn decode(&self, tokens: &[i32], cur_lens: &[i32],
                  staging: &DecodeStaging)
                  -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        self.exec.decode(tokens, cur_lens, staging)
    }

    /// Process `tokens` at true positions `start_pos..start_pos+T` for
    /// slot `slot`, staging each token's K/V at `kv_bits` as it goes.
    /// Returns per-token logits plus the raw chunk K/V for the paged
    /// cache.
    pub fn prefill_chunk(&self, tokens: &[u16], start_pos: usize,
                         slot: usize, kv_bits: u32,
                         staging: &mut DecodeStaging) -> Result<ChunkResult> {
        self.exec.prefill_chunk(tokens, start_pos, slot, kv_bits, staging)
    }

    /// Run the matching collect graph over calibration windows and
    /// accumulate Hessians + amax (GPTQ / SmoothQuant / QUIK inputs).
    pub fn collect_stats(engine: &Engine, weights: &Weights, rotated: bool,
                         calib: &[u16], windows: usize) -> Result<CalibStats> {
        let cfg = engine.manifest.model.clone();
        let graph = if rotated { "collect_quarot" } else { "collect_baseline" };
        let prefix = if rotated { "rot." } else { "base." };
        let wlist = ordered_weights(&engine.manifest.weight_order, weights, prefix)?;
        let s = cfg.max_seq;
        let site_dims = [cfg.d_model, cfg.d_attn(), cfg.d_model, cfg.d_ff];
        let mut stats = CalibStats {
            hessians: site_dims.iter()
                .map(|&d| (0..cfg.n_layers).map(|_| Mat::zeros(d, d)).collect())
                .collect(),
            amax: site_dims.iter()
                .map(|&d| vec![vec![0.0f32; d]; cfg.n_layers])
                .collect(),
        };
        let n_windows = windows.min(calib.len() / s);
        for w in 0..n_windows {
            let toks: Vec<i32> = calib[w * s..(w + 1) * s].iter()
                .map(|&t| t as i32).collect();
            let mut args = vec![HostTensor::I32(toks)];
            args.extend(wlist.iter().cloned());
            let out = engine.run(graph, &args)?;
            for site in 0..4 {
                let h = out[site * 2].f32();
                let a = out[site * 2 + 1].f32();
                let d = site_dims[site];
                for l in 0..cfg.n_layers {
                    let hm = &mut stats.hessians[site][l];
                    for (dst, src) in hm.data.iter_mut()
                        .zip(&h[l * d * d..(l + 1) * d * d]) {
                        *dst += src;
                    }
                    for (dst, src) in stats.amax[site][l].iter_mut()
                        .zip(&a[l * d..(l + 1) * d]) {
                        *dst = dst.max(*src);
                    }
                }
            }
        }
        Ok(stats)
    }
}

/// Pull the named weights out of the archive in manifest order.
fn ordered_weights(order: &[String], weights: &Weights, prefix: &str)
                   -> Result<Vec<HostTensor>> {
    order.iter()
        .map(|name| {
            let t = weights.get(&format!("{prefix}{name}"))?;
            Ok(HostTensor::F32(t.as_f32()))
        })
        .collect()
}

/// Apply the spec's weight-side quantization (RTN/GPTQ ± SmoothQuant/QUIK)
/// and return graph-ready tensors in manifest order.
pub fn prepare_weights(cfg: &ModelConfig, order: &[String], weights: &Weights,
                       spec: &QuantSpec, stats: Option<&CalibStats>)
                       -> Result<Vec<HostTensor>> {
    let prefix = spec.variant.weight_prefix();
    // load all layer weights into Mats per layer
    let mut mats: std::collections::BTreeMap<String, Vec<Mat>> = Default::default();
    let mut vecs: std::collections::BTreeMap<String, Vec<f32>> = Default::default();
    for name in order {
        let t = weights.get(&format!("{prefix}{name}"))?;
        match t.shape.len() {
            3 => {
                let (l, r, c) = (t.shape[0], t.shape[1], t.shape[2]);
                let data = t.as_f32();
                mats.insert(name.clone(), (0..l).map(|li| {
                    Mat::from_vec(r, c, data[li * r * c..(li + 1) * r * c].to_vec())
                }).collect());
            }
            _ => {
                vecs.insert(name.clone(), t.as_f32());
            }
        }
    }

    // SmoothQuant migration (baseline only): fold per-channel scales
    if spec.smooth {
        let stats = stats.context("SmoothQuant requires calibration stats")?;
        apply_smoothquant(cfg, &mut mats, &mut vecs, stats);
    }

    // weight quantization (embed/lm_head stay f32, like the paper)
    match &spec.weights {
        WeightQuant::None => {}
        WeightQuant::Rtn(qcfg) => {
            if spec.outliers > 0 {
                for (name, layers) in mats.iter_mut() {
                    if name == "embed" || name == "lm_head" {
                        continue;
                    }
                    for m in layers.iter_mut() {
                        // QUIK: keep calibrated outlier input rows exact
                        let site = site_of_weight(name);
                        let stats = stats.context("QUIK requires calib stats")?;
                        // layer index unknown here; approximate with max over layers
                        let mut amax = vec![0.0f32; m.rows];
                        for l in 0..cfg.n_layers {
                            for (a, b) in amax.iter_mut()
                                .zip(&stats.amax[site][l]) {
                                *a = a.max(*b);
                            }
                        }
                        let outl = quant::outlier::top_k_outliers(&amax, spec.outliers);
                        quant::outlier::fake_quant_weight_with_outliers(m, &outl, qcfg);
                    }
                }
            } else {
                // Plain RTN: the per-column clip search is independent per
                // matrix — fan it over the compute backend (disjoint &mut
                // access through SendPtr; par_for joins before we read).
                let ptrs: Vec<crate::backend::pool::SendPtr<Mat>> = mats
                    .iter_mut()
                    .filter(|(name, _)| name.as_str() != "embed"
                            && name.as_str() != "lm_head")
                    .flat_map(|(_, layers)| layers.iter_mut())
                    .map(|m| crate::backend::pool::SendPtr::new(m as *mut Mat))
                    .collect();
                let backend = backend::default_backend();
                let qcfg = *qcfg;
                backend.par_for(ptrs.len(), &|i| {
                    let m = unsafe { &mut *ptrs[i].get() };
                    quant::rtn::fake_quant_weight(m, &qcfg);
                });
            }
        }
        WeightQuant::Gptq(gcfg, stats) => {
            for (name, layers) in mats.iter_mut() {
                if name == "embed" || name == "lm_head" {
                    continue;
                }
                let site = site_of_weight(name);
                for (l, m) in layers.iter_mut().enumerate() {
                    quant::gptq::gptq_quantize(m, &stats.hessians[site][l], gcfg);
                }
            }
        }
    }

    // reassemble in manifest order
    order.iter().map(|name| {
        if let Some(layers) = mats.get(name) {
            let mut flat = Vec::new();
            for m in layers {
                flat.extend_from_slice(&m.data);
            }
            Ok(HostTensor::F32(flat))
        } else {
            Ok(HostTensor::F32(vecs[name].clone()))
        }
    }).collect()
}

fn site_of_weight(name: &str) -> usize {
    match name {
        "wq" | "wk" | "wv" => 0,
        "wo" => 1,
        "wup" | "wgate" => 2,
        "wdown" => 3,
        _ => panic!("no site for {name}"),
    }
}

fn apply_smoothquant(cfg: &ModelConfig,
                     mats: &mut std::collections::BTreeMap<String, Vec<Mat>>,
                     vecs: &mut std::collections::BTreeMap<String, Vec<f32>>,
                     stats: &CalibStats) {
    let scfg = quant::smooth::SmoothCfg::default();
    for l in 0..cfg.n_layers {
        // site 0: attn inputs ← fold 1/s into attn_norm gamma
        let s0 = quant::smooth::smooth_scales(&stats.amax[0][l],
                                              &mats["wq"][l], &scfg);
        for name in ["wq", "wk", "wv"] {
            quant::smooth::apply_to_weight(&mut mats.get_mut(name).unwrap()[l], &s0);
        }
        let d = cfg.d_model;
        quant::smooth::fold_into_producer(
            &mut vecs.get_mut("attn_norm").unwrap()[l * d..(l + 1) * d], &s0);
        // site 2: ffn inputs ← fold into ffn_norm
        let s2 = quant::smooth::smooth_scales(&stats.amax[2][l],
                                              &mats["wup"][l], &scfg);
        for name in ["wup", "wgate"] {
            quant::smooth::apply_to_weight(&mut mats.get_mut(name).unwrap()[l], &s2);
        }
        quant::smooth::fold_into_producer(
            &mut vecs.get_mut("ffn_norm").unwrap()[l * d..(l + 1) * d], &s2);
        // site 3: down-proj input ← fold 1/s into wup's output columns
        let s3 = quant::smooth::smooth_scales(&stats.amax[3][l],
                                              &mats["wdown"][l], &scfg);
        quant::smooth::apply_to_weight(&mut mats.get_mut("wdown").unwrap()[l], &s3);
        let wup = &mut mats.get_mut("wup").unwrap()[l];
        let inv: Vec<f32> = s3.iter().map(|s| 1.0 / s).collect();
        wup.scale_cols(&inv);
        // site 1: out-proj input ← fold 1/s into wv's output columns.
        // Only exact for MHA: with GQA one wv column feeds several q-heads,
        // so per-channel migration is ill-defined there — skip (SmoothQuant
        // never targeted GQA models anyway).
        if cfg.n_heads == cfg.n_kv_heads {
            let s1 = quant::smooth::smooth_scales(&stats.amax[1][l],
                                                  &mats["wo"][l], &scfg);
            quant::smooth::apply_to_weight(&mut mats.get_mut("wo").unwrap()[l], &s1);
            let wv = &mut mats.get_mut("wv").unwrap()[l];
            let inv1: Vec<f32> = s1.iter().map(|s| 1.0 / s).collect();
            wv.scale_cols(&inv1);
        }
    }
}

/// Build the QUIK outlier masks for the baseline graph (zeroes if unused).
pub fn build_masks(cfg: &ModelConfig, spec: &QuantSpec, stats: Option<&CalibStats>)
                   -> Result<Vec<HostTensor>> {
    let dims = [cfg.d_model, cfg.d_attn(), cfg.d_model, cfg.d_ff];
    let mut out = Vec::with_capacity(4);
    for (site, &d) in dims.iter().enumerate() {
        let mut mask = vec![0.0f32; cfg.n_layers * d];
        if spec.outliers > 0 {
            let stats = stats.context("outlier masks require calib stats")?;
            for l in 0..cfg.n_layers {
                let idx = quant::outlier::top_k_outliers(&stats.amax[site][l],
                                                         spec.outliers);
                for i in idx {
                    mask[l * d + i] = 1.0;
                }
            }
        }
        out.push(HostTensor::F32(mask));
    }
    Ok(out)
}
