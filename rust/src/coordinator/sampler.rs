//! Token sampling over the decode logits: greedy, temperature, top-k.

use crate::util::prng::Rng;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sampling {
    Greedy,
    /// temperature > 0; top_k == 0 → full distribution
    TopK { temperature: f32, k: usize },
}

pub fn sample(logits: &[f32], mode: Sampling, rng: &mut Rng) -> usize {
    match mode {
        Sampling::Greedy => argmax(logits),
        Sampling::TopK { temperature, k } => {
            let t = temperature.max(1e-3);
            let mut idx: Vec<usize> = (0..logits.len()).collect();
            if k > 0 && k < logits.len() {
                idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
                idx.truncate(k);
            }
            let mx = idx.iter().map(|&i| logits[i]).fold(f32::MIN, f32::max);
            let weights: Vec<f32> = idx.iter()
                .map(|&i| ((logits[i] - mx) / t).exp())
                .collect();
            idx[rng.categorical(&weights)]
        }
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

/// log-softmax value of one index — the single-row convenience form of
/// [`crate::backend::ComputeBackend::nll_rows`] (same scalar oracle).
pub fn log_softmax_at(logits: &[f32], idx: usize) -> f64 {
    crate::backend::log_softmax_row(logits, idx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let l = [0.1f32, 3.0, -1.0, 2.9];
        assert_eq!(sample(&l, Sampling::Greedy, &mut Rng::new(0)), 1);
    }

    #[test]
    fn topk_restricts_support() {
        let l = [0.0f32, 10.0, 9.5, -5.0];
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let s = sample(&l, Sampling::TopK { temperature: 1.0, k: 2 }, &mut rng);
            assert!(s == 1 || s == 2);
        }
    }

    #[test]
    fn low_temperature_approaches_greedy() {
        let l = [0.0f32, 1.0, 0.8];
        let mut rng = Rng::new(2);
        let hits = (0..200)
            .filter(|_| sample(&l, Sampling::TopK { temperature: 0.05, k: 0 },
                               &mut rng) == 1)
            .count();
        assert!(hits > 190);
    }

    #[test]
    fn log_softmax_normalizes() {
        let l = [1.0f32, 2.0, 3.0];
        let total: f64 = (0..3).map(|i| log_softmax_at(&l, i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }
}
