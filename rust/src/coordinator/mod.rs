//! The serving coordinator (Layer 3 proper): continuous batching over the
//! AOT-compiled prefill/decode graphs with a paged, *quantized* KV cache —
//! the paper's inference system re-staged as a vLLM-style runtime.
//!
//! * [`kvcache`]  — refcounted page-pool allocator + per-sequence packed
//!                  caches (the 3.9× memory story of Fig. 4/Table 17
//!                  lives here; refcounts make pages shareable).
//! * [`prefix`]   — shared rotated-KV prefix cache: a page-granular trie
//!                  over prompt token runs with LRU eviction, grafted
//!                  into new sequences at admission (CoW by page).
//! * [`runner`]   — typed façade over the engine: prefill / decode steps
//!                  with the weight set of a [`runner::QuantSpec`].
//! * [`sampler`]  — greedy / temperature / top-k token sampling.
//! * [`batcher`]  — request queue, slot assignment, the decode loop, and
//!                  per-request latency/throughput metrics.
//! * [`selfspec`] — self-speculative decoding: KV4 drafts, one causal
//!                  prefill verifies — 8-bit-exact output, fewer
//!                  prefills (`generate --self-spec`).

pub mod batcher;
pub mod kvcache;
pub mod prefix;
pub mod runner;
pub mod sampler;
pub mod selfspec;
