//! Paged quantized KV-cache manager.
//!
//! Storage model: a global [`PagePool`] of fixed-size byte pages; each
//! sequence slot owns a chain of pages per (layer, K/V) stream holding
//! nibble/byte-packed codes plus f32 group scales/zeros.  The decode graph
//! consumes a dense int8 staging view, refreshed incrementally on append —
//! the packed pages remain the *authoritative* store and are what the
//! memory benches account (paper Table 17).
//!
//! The paper's `Append` routine (Appendix A.10) corresponds to
//! [`SeqCache::append`]; `Init` to [`SeqCache::init_from_prefill`].

use anyhow::{bail, Result};

use crate::audit::{LockScope, PageLedger};
use crate::model::ModelConfig;
use crate::quant::kv;

/// Fixed-size page pool with explicit alloc/free and usage accounting.
/// Pages are *refcounted*: `alloc` hands out a page at refcount 1 (the
/// old exclusive-ownership bitmap, so pre-sharing call sites behave
/// unchanged), [`PagePool::retain`] adds a reference when the prefix
/// cache or a grafted sequence shares the page, and [`PagePool::release`]
/// returns it to the free list only when the last reference drops.
/// Releasing a free page is rejected with a hard panic — a freed-twice
/// page would otherwise be handed to two sequences and silently
/// cross-contaminate their caches.
pub struct PagePool {
    page_bytes: usize,
    pages: Vec<Box<[u8]>>,
    free: Vec<usize>,
    refcount: Vec<u32>,
    pub high_water: usize,
    /// Debug-build refcount ledger: every reference is charged to the
    /// ambient [`crate::audit::owner`] label so leaks name their holder
    /// ([`Self::assert_drained`]).  Zero-sized in release builds.
    ledger: PageLedger,
}

pub type PageId = usize;

/// Cheap point-in-time snapshot of a pool's occupancy — the one shape the
/// router and the metrics registry consume, so neither pokes pool fields
/// ad hoc.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// total pages the pool was built with
    pub pages_total: usize,
    /// pages currently allocated to sequences
    pub in_use: usize,
    /// peak concurrent allocation since construction (never recedes)
    pub high_water: usize,
}

impl PoolStats {
    /// Occupancy in [0, 1] — the router's KV-pressure signal.
    pub fn pressure(&self) -> f64 {
        if self.pages_total == 0 {
            return 0.0;
        }
        self.in_use as f64 / self.pages_total as f64
    }
}

impl PagePool {
    pub fn new(page_bytes: usize, n_pages: usize) -> PagePool {
        PagePool {
            page_bytes,
            pages: (0..n_pages)
                .map(|_| vec![0u8; page_bytes].into_boxed_slice())
                .collect(),
            free: (0..n_pages).rev().collect(),
            refcount: vec![0; n_pages],
            high_water: 0,
            ledger: PageLedger::new(),
        }
    }

    pub fn alloc(&mut self) -> Result<PageId> {
        let _audit = LockScope::enter("coordinator.pagepool");
        match self.free.pop() {
            Some(id) => {
                self.refcount[id] = 1;
                self.high_water = self.high_water.max(self.in_use());
                self.ledger.on_alloc(id);
                Ok(id)
            }
            None => bail!("KV page pool exhausted ({} pages)", self.pages.len()),
        }
    }

    /// Take an extra reference on a live page (prefix-cache entries and
    /// grafted shared prefixes).  Retaining a free page panics: sharing
    /// is only defined for pages some owner is keeping alive.
    pub fn retain(&mut self, id: PageId) {
        let _audit = LockScope::enter("coordinator.pagepool");
        assert!(self.refcount[id] > 0,
                "retain of free page {id} (only live pages can be shared)");
        self.refcount[id] += 1;
        self.ledger.on_retain(id);
    }

    /// Drop one reference; the page returns to the free list when the
    /// last owner releases it.
    pub fn release(&mut self, id: PageId) {
        let _audit = LockScope::enter("coordinator.pagepool");
        assert!(self.refcount[id] > 0,
                "double free of page {id} (or free of a never-allocated page)");
        self.refcount[id] -= 1;
        self.ledger.on_release(id);
        if self.refcount[id] == 0 {
            self.free.push(id);
        }
    }

    /// Current reference count of a page (0 = free).
    pub fn refcount(&self, id: PageId) -> u32 {
        self.refcount[id]
    }

    pub fn in_use(&self) -> usize {
        self.pages.len() - self.free.len()
    }

    /// Pages currently allocatable (admission control consults this
    /// before prefilling a request whose cache init would exhaust us).
    pub fn available(&self) -> usize {
        self.free.len()
    }

    /// Total pages the pool was built with (allocated + free).
    pub fn capacity(&self) -> usize {
        self.pages.len()
    }

    pub fn page(&self, id: PageId) -> &[u8] {
        &self.pages[id]
    }

    pub fn page_mut(&mut self, id: PageId) -> &mut [u8] {
        &mut self.pages[id]
    }

    pub fn page_bytes(&self) -> usize {
        self.page_bytes
    }

    pub fn bytes_in_use(&self) -> usize {
        self.in_use() * self.page_bytes
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            pages_total: self.pages.len(),
            in_use: self.in_use(),
            high_water: self.high_water,
        }
    }

    /// End-of-test leak check: every page back in the free list, and (in
    /// debug builds) the owner ledger empty.  A leak panics with the
    /// per-owner breakdown — *who* still holds each page — instead of a
    /// bare count.
    pub fn assert_drained(&self, context: &str) {
        self.ledger.assert_drained(context);
        assert_eq!(self.in_use(), 0,
                   "page pool not drained ({context}): {} page(s) in use",
                   self.in_use());
    }

    /// Outstanding `(page, owner labels)` pairs from the debug ledger
    /// (always empty in release builds) — diagnostics for leak hunts.
    pub fn outstanding_owners(&self) -> Vec<(PageId, Vec<String>)> {
        self.ledger.outstanding()
    }
}

/// One packed stream (codes+scales+zeros for K or V of one layer) of one
/// sequence, chunked into pool pages of `tokens_per_page` tokens each.
struct PackedStream {
    pages: Vec<PageId>,
    len_tokens: usize,
}

impl PackedStream {
    /// Whether appending one token requires a fresh pool page.
    fn needs_page(&self, tokens_per_page: usize) -> bool {
        self.len_tokens % tokens_per_page == 0
            && self.len_tokens / tokens_per_page >= self.pages.len()
    }
}

/// Per-layer page ids covering one *full* page worth of tokens
/// (`tokens_per_page`) of already-quantized K and V — the prefix cache's
/// unit of sharing.  `k[l]` / `v[l]` are the layer-`l` pages.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PageGroup {
    pub k: Vec<PageId>,
    pub v: Vec<PageId>,
}

/// Geometry of a packed token within a stream page.
#[derive(Clone, Copy, Debug)]
pub struct StreamGeom {
    pub d_kv: usize,          // n_kv_heads * d_head
    pub groups: usize,        // d_kv / group
    pub bits: u32,
    pub tokens_per_page: usize,
}

impl StreamGeom {
    pub fn token_bytes(&self) -> usize {
        (self.d_kv * self.bits as usize).div_ceil(8) + self.groups * 8
    }

    pub fn page_bytes(&self) -> usize {
        self.token_bytes() * self.tokens_per_page
    }
}

/// The quantized KV cache of a single sequence across all layers.
pub struct SeqCache {
    geom: StreamGeom,
    n_layers: usize,
    clip: f32,
    k: Vec<PackedStream>,
    v: Vec<PackedStream>,
    pub len: usize,
}

impl SeqCache {
    pub fn new(cfg: &ModelConfig, bits: u32, clip: f32, tokens_per_page: usize) -> SeqCache {
        let geom = StreamGeom {
            d_kv: cfg.d_kv(),
            groups: cfg.d_kv() / cfg.kv_group,
            bits,
            tokens_per_page,
        };
        SeqCache {
            geom,
            n_layers: cfg.n_layers,
            clip,
            k: (0..cfg.n_layers)
                .map(|_| PackedStream { pages: vec![], len_tokens: 0 })
                .collect(),
            v: (0..cfg.n_layers)
                .map(|_| PackedStream { pages: vec![], len_tokens: 0 })
                .collect(),
            len: 0,
        }
    }

    pub fn geom(&self) -> StreamGeom {
        self.geom
    }

    fn write_token(geom: &StreamGeom, pool: &mut PagePool, stream: &mut PackedStream,
                   values: &[f32], group: usize, clip: f32) -> Result<()> {
        let tok = stream.len_tokens;
        if stream.needs_page(geom.tokens_per_page) {
            stream.pages.push(pool.alloc()?);
        }
        let page = stream.pages[tok / geom.tokens_per_page];
        // CoW invariant: grafted shared pages are always full, so writes
        // only ever land on the (exclusively owned) tail page
        debug_assert_eq!(pool.refcount(page), 1,
                         "write into a shared KV page {page}");
        let off = (tok % geom.tokens_per_page) * geom.token_bytes();
        let (codes, scales, zeros) = kv::quant_slab(values, values.len(), group,
                                                    geom.bits, clip);
        let buf = pool.page_mut(page);
        let code_bytes = (geom.d_kv * geom.bits as usize).div_ceil(8);
        if geom.bits == 4 {
            buf[off..off + code_bytes].copy_from_slice(&kv::pack_nibbles(&codes));
        } else {
            for (b, &c) in buf[off..off + code_bytes].iter_mut().zip(&codes) {
                *b = c as u8;
            }
        }
        let mut p = off + code_bytes;
        for &s in &scales {
            buf[p..p + 4].copy_from_slice(&s.to_le_bytes());
            p += 4;
        }
        for &z in &zeros {
            buf[p..p + 4].copy_from_slice(&z.to_le_bytes());
            p += 4;
        }
        stream.len_tokens += 1;
        Ok(())
    }

    /// Append one token's K and V (each `(n_kv_heads * d_head)` f32, laid
    /// out head-major) for layer `l`.
    ///
    /// All-or-nothing: both streams' pages are reserved up front, so a
    /// pool exhausted between the K and V writes can never leave the
    /// stream lengths skewed (with shared refcounted pages that skew
    /// would read as silent cross-request corruption, not a crash).
    pub fn append_layer(&mut self, pool: &mut PagePool, l: usize,
                        k_tok: &[f32], v_tok: &[f32], group: usize) -> Result<()> {
        let tpp = self.geom.tokens_per_page;
        let need = usize::from(self.k[l].needs_page(tpp))
            + usize::from(self.v[l].needs_page(tpp));
        if pool.available() < need {
            bail!("KV page pool exhausted (append needs {need} pages, {} free \
                   of {})", pool.available(), pool.capacity());
        }
        Self::write_token(&self.geom, pool, &mut self.k[l], k_tok, group, self.clip)?;
        Self::write_token(&self.geom, pool, &mut self.v[l], v_tok, group, self.clip)?;
        Ok(())
    }

    /// Pool pages the next one-token append across *all* layers
    /// ([`Self::append_layer`] for `l` in `0..n_layers`) will allocate —
    /// 0 mid-page, `2 * n_layers` at a page boundary.  The engine checks
    /// this against [`PagePool::available`] before its per-layer append
    /// loop so the whole-token append is all-or-nothing too.
    pub fn pages_needed_for_append(&self) -> usize {
        let tpp = self.geom.tokens_per_page;
        self.k.iter().chain(self.v.iter())
            .filter(|s| s.needs_page(tpp))
            .count()
    }

    /// Bulk-load from a prefill's returned K/V (layout (L, S, d_kv) flat).
    ///
    /// Atomic like [`Self::append_layer`]: every page the load needs is
    /// reserved before anything is written, so a mid-loop pool
    /// exhaustion cannot leave some layers longer than others.
    pub fn init_from_prefill(&mut self, pool: &mut PagePool, ks: &[f32], vs: &[f32],
                             seq: usize, group: usize) -> Result<()> {
        let d = self.geom.d_kv;
        assert_eq!(ks.len(), self.n_layers * seq * d);
        debug_assert_eq!(self.len, 0, "init into a non-empty cache");
        let tpp = self.geom.tokens_per_page;
        let need: usize = self.k.iter().chain(self.v.iter())
            .map(|s| (s.len_tokens + seq).div_ceil(tpp) - s.pages.len())
            .sum();
        if pool.available() < need {
            bail!("KV page pool exhausted (cache init needs {need} pages, \
                   {} free of {})", pool.available(), pool.capacity());
        }
        for l in 0..self.n_layers {
            for s in 0..seq {
                let o = (l * seq + s) * d;
                Self::write_token(&self.geom, pool, &mut self.k[l],
                                  &ks[o..o + d], group, self.clip)?;
                Self::write_token(&self.geom, pool, &mut self.v[l],
                                  &vs[o..o + d], group, self.clip)?;
            }
        }
        self.len = seq;
        Ok(())
    }

    /// Graft a shared, already-quantized prefix into an empty cache: each
    /// [`PageGroup`] covers one *full* page (`tokens_per_page` tokens) of
    /// every layer's K and V, and is retained rather than copied.  The
    /// grafted pages are read-only by construction — they are full, and
    /// [`SeqCache`] only ever writes at the append position, so the first
    /// token past the shared prefix lands on a fresh exclusively-owned
    /// page (copy-on-write at page granularity, with no copying).
    pub fn graft_prefix(&mut self, pool: &mut PagePool, groups: &[PageGroup]) {
        assert_eq!(self.len, 0, "graft into a non-empty cache");
        for g in groups {
            assert_eq!(g.k.len(), self.n_layers, "page group layer count");
            assert_eq!(g.v.len(), self.n_layers, "page group layer count");
            for l in 0..self.n_layers {
                pool.retain(g.k[l]);
                pool.retain(g.v[l]);
                self.k[l].pages.push(g.k[l]);
                self.v[l].pages.push(g.v[l]);
            }
        }
        let toks = groups.len() * self.geom.tokens_per_page;
        for s in self.k.iter_mut().chain(self.v.iter_mut()) {
            s.len_tokens = toks;
        }
        self.len = toks;
    }

    /// The page ids covering tokens `[idx·tpp, (idx+1)·tpp)` of every
    /// layer — must be a full page (the donation path hands these to the
    /// prefix cache, which retains them).
    pub fn page_group(&self, idx: usize) -> PageGroup {
        let tpp = self.geom.tokens_per_page;
        assert!((idx + 1) * tpp <= self.k[0].len_tokens,
                "page {idx} is not full ({} tokens cached)", self.k[0].len_tokens);
        PageGroup {
            k: self.k.iter().map(|s| s.pages[idx]).collect(),
            v: self.v.iter().map(|s| s.pages[idx]).collect(),
        }
    }

    /// The page ids holding the trailing *partial* page (`len % tpp`
    /// tokens) of every layer, plus that token count — what the session
    /// retirement path donates beyond [`Self::page_group`]'s full pages.
    /// `None` when the length is page-aligned (nothing partial) or the
    /// tail lives outside the pool (fp16 pass-through slots).
    pub fn tail_page_group(&self) -> Option<(PageGroup, usize)> {
        let tpp = self.geom.tokens_per_page;
        let tail = self.len % tpp;
        let idx = self.len / tpp;
        if tail == 0 || self.k.iter().chain(self.v.iter())
            .any(|s| s.pages.len() <= idx)
        {
            return None;
        }
        Some((PageGroup {
            k: self.k.iter().map(|s| s.pages[idx]).collect(),
            v: self.v.iter().map(|s| s.pages[idx]).collect(),
        }, tail))
    }

    /// Continue a grafted chain through a donated *partial* tail page.
    /// Unlike [`Self::graft_prefix`]'s full pages, a partial page will be
    /// written again (the sequence keeps appending into it), so sharing
    /// it would break the CoW invariant — instead the first `tail_len`
    /// tokens' bytes are **copied** into fresh exclusively-owned pages.
    /// Atomic: all `2·n_layers` pages are reserved before any copy.
    pub fn graft_partial_tail(&mut self, pool: &mut PagePool,
                              group: &PageGroup, tail_len: usize) -> Result<()> {
        let tpp = self.geom.tokens_per_page;
        assert!(tail_len > 0 && tail_len < tpp,
                "tail graft must be a partial page ({tail_len} of {tpp})");
        assert_eq!(self.len % tpp, 0,
                   "tail graft must land on a page boundary");
        assert_eq!(group.k.len(), self.n_layers, "page group layer count");
        assert_eq!(group.v.len(), self.n_layers, "page group layer count");
        let need = 2 * self.n_layers;
        if pool.available() < need {
            bail!("KV page pool exhausted (tail graft needs {need} pages, \
                   {} free of {})", pool.available(), pool.capacity());
        }
        let bytes = tail_len * self.geom.token_bytes();
        for (streams, pages) in [(&mut self.k, &group.k),
                                 (&mut self.v, &group.v)] {
            for (s, &src) in streams.iter_mut().zip(pages) {
                let data = pool.page(src)[..bytes].to_vec();
                let dst = pool.alloc()?;
                pool.page_mut(dst)[..bytes].copy_from_slice(&data);
                s.pages.push(dst);
                s.len_tokens += tail_len;
            }
        }
        self.len += tail_len;
        Ok(())
    }

    pub fn bump(&mut self) {
        self.len += 1;
    }

    /// Length override for pass-through (fp16 baseline) slots that keep the
    /// authoritative values in the dense staging view instead of pages.
    pub fn set_len(&mut self, len: usize) {
        self.len = len;
    }

    /// Unpack token `tok` of layer `l` into int8 codes + scales + zeros
    /// (the decode graph's staging layout).
    pub fn read_token(&self, pool: &PagePool, l: usize, tok: usize, want_v: bool,
                      codes: &mut [i8], scales: &mut [f32], zeros: &mut [f32]) {
        let stream = if want_v { &self.v[l] } else { &self.k[l] };
        debug_assert!(tok < stream.len_tokens);
        let geom = &self.geom;
        let page = stream.pages[tok / geom.tokens_per_page];
        let off = (tok % geom.tokens_per_page) * geom.token_bytes();
        let buf = pool.page(page);
        let code_bytes = (geom.d_kv * geom.bits as usize).div_ceil(8);
        if geom.bits == 4 {
            kv::unpack_nibbles(&buf[off..off + code_bytes], geom.d_kv, codes);
        } else {
            for (c, &b) in codes.iter_mut().zip(&buf[off..off + code_bytes]) {
                *c = b as i8;
            }
        }
        let mut p = off + code_bytes;
        for s in scales.iter_mut().take(geom.groups) {
            *s = f32::from_le_bytes(buf[p..p + 4].try_into().unwrap());
            p += 4;
        }
        for z in zeros.iter_mut().take(geom.groups) {
            *z = f32::from_le_bytes(buf[p..p + 4].try_into().unwrap());
            p += 4;
        }
    }

    /// Token length of one packed stream.  Every one of the `2·n_layers`
    /// streams holds the same count unless an append was torn —
    /// consistency assertions (tests, debug checks) compare these.
    pub fn stream_len(&self, l: usize, want_v: bool) -> usize {
        if want_v { self.v[l].len_tokens } else { self.k[l].len_tokens }
    }

    /// Release all pages back to the pool.
    pub fn free(&mut self, pool: &mut PagePool) {
        for s in self.k.iter_mut().chain(self.v.iter_mut()) {
            for pid in s.pages.drain(..) {
                pool.release(pid);
            }
            s.len_tokens = 0;
        }
        self.len = 0;
    }

    /// Packed bytes currently held (page-granular, what the pool accounts).
    pub fn bytes(&self) -> usize {
        let pages: usize = self.k.iter().chain(self.v.iter())
            .map(|s| s.pages.len()).sum();
        pages * self.geom.page_bytes()
    }

    /// FP16-equivalent bytes of the same cache (the paper's baseline).
    pub fn fp16_equiv_bytes(&self) -> usize {
        2 * self.n_layers * self.len * self.geom.d_kv * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prng::Rng, prop};

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(), vocab: 64, d_model: 64, n_layers: 2, n_heads: 4,
            n_kv_heads: 2, d_head: 16, d_ff: 128, max_seq: 16, cache_seq: 32,
            decode_batch: 2, kv_group: 16, rope_theta: 1e4, train_ppl: 0.0,
        }
    }

    #[test]
    fn pool_alloc_free_accounting() {
        let mut pool = PagePool::new(64, 4);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        assert_eq!(pool.in_use(), 2);
        pool.release(a);
        assert_eq!(pool.in_use(), 1);
        let c = pool.alloc().unwrap();
        let d = pool.alloc().unwrap();
        let e = pool.alloc().unwrap();
        assert_eq!(pool.in_use(), 4);
        assert!(pool.alloc().is_err(), "exhaustion must error");
        pool.release(b);
        pool.release(c);
        pool.release(d);
        pool.release(e);
        assert_eq!(pool.in_use(), 0);
        assert_eq!(pool.high_water, 4);
    }

    #[test]
    fn stats_snapshot_tracks_pool_fields() {
        let mut pool = PagePool::new(32, 6);
        assert_eq!(pool.stats(), PoolStats {
            pages_total: 6, in_use: 0, high_water: 0,
        });
        let a = pool.alloc().unwrap();
        let _b = pool.alloc().unwrap();
        let s = pool.stats();
        assert_eq!((s.pages_total, s.in_use, s.high_water), (6, 2, 2));
        assert!((s.pressure() - 2.0 / 6.0).abs() < 1e-12);
        pool.release(a);
        let s = pool.stats();
        assert_eq!((s.in_use, s.high_water), (1, 2), "high water must persist");
        assert_eq!(PoolStats::default().pressure(), 0.0, "empty pool = no pressure");
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_rejected() {
        let mut pool = PagePool::new(8, 2);
        let a = pool.alloc().unwrap();
        pool.release(a);
        pool.release(a);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn free_of_never_allocated_page_rejected() {
        let mut pool = PagePool::new(8, 4);
        pool.release(3);
    }

    #[test]
    fn high_water_tracks_peak_not_current() {
        let mut pool = PagePool::new(16, 8);
        let ids: Vec<_> = (0..5).map(|_| pool.alloc().unwrap()).collect();
        assert_eq!(pool.high_water, 5);
        for id in ids {
            pool.release(id);
        }
        assert_eq!(pool.in_use(), 0);
        assert_eq!(pool.high_water, 5, "high water must not recede");
        let _ = pool.alloc().unwrap();
        assert_eq!(pool.high_water, 5, "re-alloc below peak keeps peak");
    }

    /// SeqCache append → dequant round-trip at both serving KV widths.
    #[test]
    fn append_dequant_roundtrip_kv4_kv8() {
        for bits in [4u32, 8] {
            let cfg = cfg();
            let geom = SeqCache::new(&cfg, bits, 1.0, 8).geom();
            let mut pool = PagePool::new(geom.page_bytes(), 64);
            let mut cache = SeqCache::new(&cfg, bits, 1.0, 8);
            let mut rng = Rng::new(bits as u64);
            let d = cfg.d_kv();
            let mut toks = Vec::new();
            for _ in 0..7 {
                let k: Vec<f32> = rng.normal_vec(d);
                let v: Vec<f32> = rng.normal_vec(d);
                for l in 0..cfg.n_layers {
                    cache.append_layer(&mut pool, l, &k, &v, cfg.kv_group).unwrap();
                }
                cache.bump();
                toks.push((k, v));
            }
            let qmax = ((1u32 << bits) - 1) as f32;
            let mut codes = vec![0i8; d];
            let mut scales = vec![0.0f32; geom.groups];
            let mut zeros = vec![0.0f32; geom.groups];
            for (t, (k, v)) in toks.iter().enumerate() {
                for (want_v, x) in [(false, k), (true, v)] {
                    cache.read_token(&pool, 0, t, want_v,
                                     &mut codes, &mut scales, &mut zeros);
                    let mut back = vec![0.0f32; d];
                    for (gi, chunk) in back.chunks_mut(cfg.kv_group).enumerate() {
                        for (i, o) in chunk.iter_mut().enumerate() {
                            *o = codes[gi * cfg.kv_group + i] as f32 * scales[gi]
                                + zeros[gi];
                        }
                    }
                    // per-group half-step bound at the group's own range
                    for (gi, g) in x.chunks(cfg.kv_group).enumerate() {
                        let mx = g.iter().fold(f32::MIN, |m, &v| m.max(v));
                        let mn = g.iter().fold(f32::MAX, |m, &v| m.min(v));
                        let step = (mx - mn) / qmax;
                        for (i, (&a, &b)) in g.iter()
                            .zip(&back[gi * cfg.kv_group..(gi + 1) * cfg.kv_group])
                            .enumerate()
                        {
                            assert!((a - b).abs() <= step / 2.0 + 1e-4,
                                    "kv{bits} tok {t} group {gi} elem {i}: {a} vs {b}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn append_read_roundtrip() {
        let cfg = cfg();
        let geomcheck = SeqCache::new(&cfg, 4, 1.0, 8).geom();
        let mut pool = PagePool::new(geomcheck.page_bytes(), 64);
        let mut cache = SeqCache::new(&cfg, 4, 1.0, 8);
        let mut rng = Rng::new(0);
        let d = cfg.d_kv();
        let mut toks = Vec::new();
        for _ in 0..10 {
            let k: Vec<f32> = rng.normal_vec(d);
            let v: Vec<f32> = rng.normal_vec(d);
            for l in 0..cfg.n_layers {
                cache.append_layer(&mut pool, l, &k, &v, cfg.kv_group).unwrap();
            }
            cache.bump();
            toks.push((k, v));
        }
        let g = cache.geom();
        let mut codes = vec![0i8; d];
        let mut scales = vec![0.0f32; g.groups];
        let mut zeros = vec![0.0f32; g.groups];
        for (t, (k, _)) in toks.iter().enumerate() {
            cache.read_token(&pool, 1, t, false, &mut codes, &mut scales, &mut zeros);
            // dequantize and compare within quantization error
            let mut back = vec![0.0f32; d];
            for (gi, chunk) in back.chunks_mut(cfg.kv_group).enumerate() {
                for (i, o) in chunk.iter_mut().enumerate() {
                    *o = codes[gi * cfg.kv_group + i] as f32 * scales[gi] + zeros[gi];
                }
            }
            let range = k.iter().fold(f32::MIN, |m, &x| m.max(x))
                - k.iter().fold(f32::MAX, |m, &x| m.min(x));
            prop::assert_close(&back, k, range / 15.0 + 1e-4).unwrap();
        }
    }

    #[test]
    fn free_releases_everything() {
        let cfg = cfg();
        let geom = SeqCache::new(&cfg, 4, 1.0, 4).geom();
        let mut pool = PagePool::new(geom.page_bytes(), 128);
        let mut caches: Vec<SeqCache> = (0..3)
            .map(|_| SeqCache::new(&cfg, 4, 1.0, 4))
            .collect();
        let mut rng = Rng::new(1);
        let d = cfg.d_kv();
        for c in caches.iter_mut() {
            for _ in 0..9 {
                let k = rng.normal_vec(d);
                let v = rng.normal_vec(d);
                for l in 0..cfg.n_layers {
                    c.append_layer(&mut pool, l, &k, &v, cfg.kv_group).unwrap();
                }
                c.bump();
            }
        }
        assert!(pool.in_use() > 0);
        for c in caches.iter_mut() {
            c.free(&mut pool);
        }
        assert_eq!(pool.in_use(), 0, "pages leaked");
        pool.assert_drained("free_releases_everything");
    }

    #[test]
    fn memory_saving_vs_fp16() {
        let cfg = cfg();
        let geom = SeqCache::new(&cfg, 4, 0.95, 16).geom();
        let mut pool = PagePool::new(geom.page_bytes(), 256);
        let mut cache = SeqCache::new(&cfg, 4, 0.95, 16);
        let mut rng = Rng::new(2);
        let d = cfg.d_kv();
        for _ in 0..32 {
            let k = rng.normal_vec(d);
            let v = rng.normal_vec(d);
            for l in 0..cfg.n_layers {
                cache.append_layer(&mut pool, l, &k, &v, cfg.kv_group).unwrap();
            }
            cache.bump();
        }
        let saving = cache.fp16_equiv_bytes() as f64 / cache.bytes() as f64;
        // group=16 → scale overhead is heavier than the paper's 128;
        // still a substantial saving
        assert!(saving > 1.5, "saving {saving}");
    }

    #[test]
    fn retain_release_refcount_semantics() {
        let mut pool = PagePool::new(8, 2);
        let a = pool.alloc().unwrap();
        assert_eq!(pool.refcount(a), 1);
        pool.retain(a);
        pool.retain(a);
        assert_eq!(pool.refcount(a), 3);
        assert_eq!(pool.in_use(), 1, "retain must not change occupancy");
        pool.release(a);
        pool.release(a);
        assert_eq!(pool.in_use(), 1,
                   "page stays allocated until the last release");
        pool.release(a);
        assert_eq!((pool.refcount(a), pool.in_use()), (0, 0));
        // and the page is allocatable again afterwards
        let _b = pool.alloc().unwrap();
        let _c = pool.alloc().unwrap();
        assert_eq!(pool.in_use(), 2);
        assert!(pool.alloc().is_err());
    }

    #[test]
    #[should_panic(expected = "retain of free page")]
    fn retain_of_free_page_rejected() {
        let mut pool = PagePool::new(8, 2);
        pool.retain(1);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn release_past_last_reference_rejected() {
        let mut pool = PagePool::new(8, 2);
        let a = pool.alloc().unwrap();
        pool.retain(a);
        pool.release(a);
        pool.release(a);
        pool.release(a); // one release too many
    }

    /// A cache grafted from a donor's shared full pages plus its own
    /// appended suffix must be byte-identical (codes, scales, zeros) to
    /// a cold cache built purely by appends, and freeing every owner
    /// must drain the pool (no refcount leaks).
    #[test]
    fn grafted_prefix_is_byte_identical_to_cold_build() {
        let cfg = cfg();
        let tpp = 4usize;
        let geom = SeqCache::new(&cfg, 4, 0.95, tpp).geom();
        let mut pool = PagePool::new(geom.page_bytes(), 256);
        let d = cfg.d_kv();
        let mut rng = Rng::new(7);
        let toks: Vec<(Vec<f32>, Vec<f32>)> = (0..11)
            .map(|_| (rng.normal_vec(d), rng.normal_vec(d)))
            .collect();

        let build = |pool: &mut PagePool, from: usize,
                     base: Option<&[PageGroup]>| -> SeqCache {
            let mut c = SeqCache::new(&cfg, 4, 0.95, tpp);
            if let Some(groups) = base {
                c.graft_prefix(pool, groups);
            }
            for (k, v) in &toks[from..] {
                for l in 0..cfg.n_layers {
                    c.append_layer(pool, l, k, v, cfg.kv_group).unwrap();
                }
                c.bump();
            }
            c
        };
        let donor = {
            let _o = crate::audit::owner(|| "seq:donor".to_string());
            build(&mut pool, 0, None)
        };
        // "donate" the two full pages (8 of the 11 tokens) like the trie:
        // retain every page in the donated groups
        let groups: Vec<PageGroup> = (0..2).map(|i| donor.page_group(i)).collect();
        {
            let _o = crate::audit::owner(|| "prefix:donated".to_string());
            for g in &groups {
                for &p in g.k.iter().chain(g.v.iter()) {
                    pool.retain(p);
                }
            }
        }
        let cold = build(&mut pool, 0, None);
        let hot = build(&mut pool, 2 * tpp, Some(&groups));
        assert_eq!(hot.len, cold.len);

        let mut want = (vec![0i8; d], vec![0.0f32; geom.groups],
                        vec![0.0f32; geom.groups]);
        let mut got = want.clone();
        for l in 0..cfg.n_layers {
            for t in 0..toks.len() {
                for want_v in [false, true] {
                    cold.read_token(&pool, l, t, want_v,
                                    &mut want.0, &mut want.1, &mut want.2);
                    hot.read_token(&pool, l, t, want_v,
                                   &mut got.0, &mut got.1, &mut got.2);
                    assert!(got == want, "layer {l} tok {t} v={want_v} diverged");
                }
            }
        }
        for mut c in [donor, cold, hot] {
            c.free(&mut pool);
        }
        assert!(pool.in_use() > 0,
                "donated refs must keep the shared pages alive");
        #[cfg(debug_assertions)]
        assert!(pool.outstanding_owners().iter()
                    .all(|(_, owners)| owners.contains(&"prefix:donated".to_string())),
                "surviving refs must be the donated ones");
        {
            let _o = crate::audit::owner(|| "prefix:donated".to_string());
            for g in &groups {
                for &p in g.k.iter().chain(g.v.iter()) {
                    pool.release(p);
                }
            }
        }
        assert_eq!(pool.in_use(), 0, "refcount leak after the last owner");
        pool.assert_drained("graft leak smoke");
    }

    /// A partial tail page donated at retirement and grafted by COPY
    /// must read back byte-identical to a cold build, stay independent
    /// of the donor's pages (the donor can free first), and keep
    /// appending past the copied tokens without a CoW violation.
    #[test]
    fn tail_graft_copies_bytes_and_stays_independent() {
        let cfg = cfg();
        let tpp = 4usize;
        let geom = SeqCache::new(&cfg, 4, 0.95, tpp).geom();
        let mut pool = PagePool::new(geom.page_bytes(), 256);
        let d = cfg.d_kv();
        let mut rng = Rng::new(21);
        let toks: Vec<(Vec<f32>, Vec<f32>)> = (0..9)
            .map(|_| (rng.normal_vec(d), rng.normal_vec(d)))
            .collect();
        let append = |pool: &mut PagePool, c: &mut SeqCache,
                      range: std::ops::Range<usize>| {
            for (k, v) in &toks[range] {
                for l in 0..cfg.n_layers {
                    c.append_layer(pool, l, k, v, cfg.kv_group).unwrap();
                }
                c.bump();
            }
        };

        // donor: 6 tokens = one full page + a 2-token tail
        let mut donor = SeqCache::new(&cfg, 4, 0.95, tpp);
        append(&mut pool, &mut donor, 0..6);
        assert!(SeqCache::new(&cfg, 4, 0.95, tpp).tail_page_group().is_none(),
                "empty cache has no tail");
        let full = vec![donor.page_group(0)];
        let (tail, tail_len) = donor.tail_page_group().unwrap();
        assert_eq!(tail_len, 2);
        // the trie's donation: retain both the full and the tail pages
        for g in full.iter().chain([&tail]) {
            for &p in g.k.iter().chain(g.v.iter()) {
                pool.retain(p);
            }
        }
        donor.free(&mut pool);

        // grafted build: full page shared, tail copied, rest appended
        let mut hot = SeqCache::new(&cfg, 4, 0.95, tpp);
        hot.graft_prefix(&mut pool, &full);
        hot.graft_partial_tail(&mut pool, &tail, tail_len).unwrap();
        assert_eq!(hot.len, 6);
        append(&mut pool, &mut hot, 6..9);

        let mut cold = SeqCache::new(&cfg, 4, 0.95, tpp);
        append(&mut pool, &mut cold, 0..9);

        let mut want = (vec![0i8; d], vec![0.0f32; geom.groups],
                        vec![0.0f32; geom.groups]);
        let mut got = want.clone();
        for l in 0..cfg.n_layers {
            for t in 0..toks.len() {
                for want_v in [false, true] {
                    cold.read_token(&pool, l, t, want_v,
                                    &mut want.0, &mut want.1, &mut want.2);
                    hot.read_token(&pool, l, t, want_v,
                                   &mut got.0, &mut got.1, &mut got.2);
                    assert!(got == want, "layer {l} tok {t} v={want_v} diverged");
                }
            }
        }
        hot.free(&mut pool);
        cold.free(&mut pool);
        // the "trie" still holds its donated refs; releasing them drains
        for g in full.iter().chain([&tail]) {
            for &p in g.k.iter().chain(g.v.iter()) {
                pool.release(p);
            }
        }
        assert_eq!(pool.in_use(), 0, "refcount leak after tail graft");
    }

    /// Exhausting the pool mid-append fails atomically: nothing is
    /// allocated by the failing call and every stream keeps a
    /// consistent K/V length (the skew this regression guards against
    /// would read as silent corruption once pages are shared).
    #[test]
    fn append_exhaustion_is_atomic() {
        let cfg = cfg(); // n_layers = 2
        let tpp = 2usize;
        let geom = SeqCache::new(&cfg, 4, 1.0, tpp).geom();
        // room for exactly one layer's K+V pages: layer 0 appends fine,
        // layer 1 must fail without touching anything
        let mut pool = PagePool::new(geom.page_bytes(), 2);
        let mut cache = SeqCache::new(&cfg, 4, 1.0, tpp);
        let d = cfg.d_kv();
        let (k, v) = (vec![0.5f32; d], vec![-0.5f32; d]);
        assert_eq!(cache.pages_needed_for_append(), 2 * cfg.n_layers);
        assert!(cache.append_layer(&mut pool, 0, &k, &v, cfg.kv_group).is_ok());
        assert_eq!(pool.in_use(), 2);
        let err = cache.append_layer(&mut pool, 1, &k, &v, cfg.kv_group);
        assert!(err.is_err(), "layer 1 must exhaust the pool");
        assert_eq!(pool.in_use(), 2, "failed append must not leak pages");
        for l in 0..cfg.n_layers {
            assert_eq!(cache.stream_len(l, false), cache.stream_len(l, true),
                       "K/V stream lengths skewed at layer {l}");
        }
        assert_eq!((cache.stream_len(0, false), cache.stream_len(1, false)),
                   (1, 0));
    }

    #[test]
    fn init_from_prefill_exhaustion_allocates_nothing() {
        let cfg = cfg();
        let tpp = 4usize;
        let geom = SeqCache::new(&cfg, 4, 1.0, tpp).geom();
        // needs 2·L·ceil(6/4) = 8 pages; give it 3
        let mut pool = PagePool::new(geom.page_bytes(), 3);
        let mut cache = SeqCache::new(&cfg, 4, 1.0, tpp);
        let (seq, d) = (6usize, cfg.d_kv());
        let ks = vec![0.1f32; cfg.n_layers * seq * d];
        let vs = vec![0.2f32; cfg.n_layers * seq * d];
        assert!(cache.init_from_prefill(&mut pool, &ks, &vs, seq,
                                        cfg.kv_group).is_err());
        assert_eq!(pool.in_use(), 0, "failed init must allocate nothing");
        for l in 0..cfg.n_layers {
            assert_eq!(cache.stream_len(l, false), 0);
            assert_eq!(cache.stream_len(l, true), 0);
        }
        assert_eq!(cache.len, 0);
    }

    #[test]
    fn property_pool_never_double_allocates() {
        prop::check("pool-unique", 20, |rng| {
            let mut pool = PagePool::new(16, 8);
            let mut held: Vec<usize> = Vec::new();
            for _ in 0..50 {
                if rng.f64() < 0.6 && pool.in_use() < 8 {
                    let id = pool.alloc().map_err(|e| e.to_string())?;
                    crate::prop_assert!(!held.contains(&id), "dup page {id}");
                    held.push(id);
                } else if let Some(i) = (!held.is_empty())
                    .then(|| rng.below(held.len()))
                {
                    let id = held.swap_remove(i);
                    pool.release(id);
                }
            }
            Ok(())
        });
    }

    /// N threads churn alloc/retain/release against one shared pool,
    /// each under its own ledger owner label.  Afterwards the pool must
    /// be fully drained — ledger included — and the high-water mark
    /// must equal the peak occupancy actually observed (tracked under
    /// the same lock, so the comparison is exact, not racy).
    #[test]
    fn concurrent_pool_churn_drains_and_high_water_is_exact() {
        use std::sync::{Arc, Mutex};
        const THREADS: usize = 4;
        const OPS: usize = 500;
        // (pool, observed peak occupancy) under one lock
        let shared = Arc::new(Mutex::new((PagePool::new(8, 48), 0usize)));
        let mut handles = Vec::new();
        for t in 0..THREADS {
            let shared = Arc::clone(&shared);
            handles.push(std::thread::spawn(move || {
                let _o = crate::audit::owner(|| format!("stress:{t}"));
                let mut rng = Rng::new(0xC0FFEE ^ t as u64);
                // one entry per reference this thread holds
                let mut held: Vec<usize> = Vec::new();
                for _ in 0..OPS {
                    let mut g = shared.lock().unwrap();
                    let (pool, observed) = &mut *g;
                    let roll = rng.f64();
                    if roll < 0.45 {
                        if let Ok(id) = pool.alloc() {
                            held.push(id);
                        }
                    } else if roll < 0.65 && !held.is_empty() {
                        let id = held[rng.below(held.len())];
                        pool.retain(id);
                        held.push(id);
                    } else if !held.is_empty() {
                        let id = held.swap_remove(rng.below(held.len()));
                        pool.release(id);
                    }
                    *observed = (*observed).max(pool.in_use());
                }
                let mut g = shared.lock().unwrap();
                for id in held.drain(..) {
                    g.0.release(id);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let g = shared.lock().unwrap();
        assert_eq!(g.0.in_use(), 0, "churn must return every page");
        g.0.assert_drained("concurrent churn");
        assert_eq!(g.0.high_water, g.1,
                   "high-water mark must equal the observed peak");
    }

    /// Deliberately-broken negative: an unreleased reference must make
    /// `assert_drained` fire and name the owner label that held it.
    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "page ledger leak")]
    fn undrained_pool_names_the_leaking_owner() {
        let mut pool = PagePool::new(8, 4);
        let _o = crate::audit::owner(|| "seq:leaker".to_string());
        let _page = pool.alloc().unwrap();
        pool.assert_drained("negative leak test");
    }
}
