//! Self-speculative decoding: the model drafts against its own cheap
//! KV4 cache and verifies against the full-precision prefill path.
//!
//! QuaRot's near-lossless KV4 result (Table 6) means the 4-bit-cache
//! model is an unusually good *draft model for itself*: it shares every
//! weight with the target, so drafts agree with the target almost
//! always and the speculation machinery needs no second network.  One
//! round is:
//!
//! 1. **Draft** `k` tokens greedily through the decode graph over a
//!    4-bit [`SeqCache`] (the KV4 tier's exact serving configuration).
//! 2. **Verify** with ONE prefill over `accepted ++ drafts`.  The
//!    prefill graph is causal, so its logits at position `p` depend
//!    only on tokens `0..=p` — every draft position gets the logits an
//!    iterated-prefill decode would have produced, in a single pass.
//! 3. **Accept** the longest prefix of drafts that matches the
//!    verifier's greedy choice; take the verifier's token at the first
//!    mismatch (or the bonus token after a full accept).  The output is
//!    therefore *token-for-token identical* to plain greedy decoding
//!    through [`prefill_greedy`] — the KV4 cache only ever decides how
//!    many verifier tokens each prefill yields, never which tokens.
//! 4. **Rebuild** the draft cache from the verify prefill's exact K/V,
//!    so draft-cache quantization error can never compound across
//!    rounds.
//!
//! The decoder is deliberately single-sequence (lane 0 of a
//! [`DecodeStaging`]): it is the `generate --self-spec` CLI mode and
//! the bit-exactness test substrate, not a batch scheduler.  Fusing
//! speculation into the continuous batcher is a ROADMAP follow-up.

use anyhow::{bail, Result};

use crate::api::QualityTier;
use crate::model::ModelConfig;

use super::batcher::TOKENS_PER_PAGE;
use super::kvcache::{PagePool, SeqCache};
use super::runner::{DecodeStaging, Prefilled, Runner};

/// Default speculative window (tokens drafted per verify prefill).
pub const DEFAULT_DRAFT: usize = 4;

/// Lifetime counters of one generation.
#[derive(Clone, Copy, Debug, Default)]
pub struct SelfSpecStats {
    /// tokens proposed by the KV4 draft pass
    pub drafted: usize,
    /// drafted tokens the verifier accepted
    pub accepted: usize,
    /// draft→verify rounds run (excludes the seed prefill)
    pub rounds: usize,
    /// verify prefills run (seed included)
    pub verify_prefills: usize,
}

impl SelfSpecStats {
    /// Fraction of drafted tokens the verifier kept — the paper-style
    /// acceptance rate; high values mean KV4 ≈ the verifier (Table 6).
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            return 0.0;
        }
        self.accepted as f64 / self.drafted as f64
    }
}

pub struct SelfSpecOutput {
    pub tokens: Vec<u16>,
    pub stats: SelfSpecStats,
}

/// Greedy self-speculative decoder over one [`Runner`].
pub struct SelfSpecDecoder<'a> {
    runner: &'a Runner,
    draft_k: usize,
}

impl<'a> SelfSpecDecoder<'a> {
    /// `draft_k` tokens are drafted per verify prefill.  Fails on the
    /// fp16 baseline (its decode graph has no quantized-cache inputs to
    /// draft over — and with fp K/V there is nothing to speculate away).
    pub fn new(runner: &'a Runner, draft_k: usize)
               -> Result<SelfSpecDecoder<'a>> {
        if runner.spec.kv_is_fp() {
            bail!("--self-spec needs a quantized-KV scheme (the fp16 \
                   baseline has no KV4 draft path)");
        }
        if draft_k == 0 {
            bail!("draft window must be >= 1");
        }
        Ok(SelfSpecDecoder { runner, draft_k })
    }

    /// Generate up to `max_new` tokens greedily.  Output is
    /// token-for-token identical to [`prefill_greedy`] on the same
    /// runner; both stop early if the sequence reaches `max_seq`.
    pub fn generate(&self, prompt: &[u16], max_new: usize)
                    -> Result<SelfSpecOutput> {
        let cfg = self.runner.cfg.clone();
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        if max_new == 0 {
            bail!("max_new must be >= 1");
        }
        if prompt.len() > cfg.max_seq {
            bail!("prompt length {} exceeds max_seq {}", prompt.len(),
                  cfg.max_seq);
        }
        let mut stats = SelfSpecStats::default();
        let v = cfg.vocab;
        let tpp = TOKENS_PER_PAGE;
        // one sequence's worth of 4-bit pages, fully provisioned
        let draft_bits = QualityTier::Kv4.kv_bits();
        let geom = SeqCache::new(&cfg, draft_bits, self.runner.spec.kv_clip,
                                 tpp).geom();
        let mut pool = PagePool::new(
            geom.page_bytes(),
            2 * cfg.n_layers * cfg.cache_seq.div_ceil(tpp));
        let mut staging = DecodeStaging::new(&cfg, false);

        // Seed: one verify prefill over the prompt yields the first
        // token and the draft cache's initial contents.
        let pre = self.runner.prefill(prompt)?;
        stats.verify_prefills += 1;
        let mut seq = prompt.to_vec();
        seq.push(argmax(&pre.logits[(pre.len - 1) * v..pre.len * v]));
        let mut cache = self.rebuild_cache(&cfg, &mut pool, &mut staging,
                                           &pre, seq.len() - 1)?;

        while seq.len() - prompt.len() < max_new {
            if seq.len() > cfg.max_seq {
                break; // same stopping rule as prefill_greedy
            }
            // Draft window: the verify prefill must fit max_seq, the
            // drafted positions must fit the cache/staging geometry.
            let m = self.draft_k
                .min(max_new - (seq.len() - prompt.len()))
                .min(cfg.max_seq.saturating_sub(seq.len()))
                .min((cfg.cache_seq + 1).saturating_sub(seq.len()));
            if m == 0 {
                // no draft room left (sequence at max_seq): finish with
                // plain verifier steps so truncation matches
                // prefill_greedy exactly
                let pre = self.runner.prefill(&seq)?;
                stats.verify_prefills += 1;
                stats.rounds += 1;
                seq.push(argmax(&pre.logits[(pre.len - 1) * v
                                            ..pre.len * v]));
                continue;
            }

            // ---- draft m tokens at KV4 through the decode graph ----
            let b = cfg.decode_batch;
            let d = cfg.d_kv();
            let mut drafts: Vec<u16> = Vec::with_capacity(m);
            for _ in 0..m {
                let cur = *drafts.last().unwrap_or(seq.last().unwrap());
                let mut tokens = vec![0i32; b];
                let mut lens = vec![0i32; b];
                tokens[0] = cur as i32;
                lens[0] = cache.len as i32;
                let (logits, k_new, v_new) =
                    self.runner.decode(&tokens, &lens, &staging)?;
                for l in 0..cfg.n_layers {
                    let o = (l * b) * d; // lane 0
                    cache.append_layer(&mut pool, l, &k_new[o..o + d],
                                       &v_new[o..o + d], cfg.kv_group)?;
                }
                cache.bump();
                stage_token(&mut staging, &pool, &cfg, &cache,
                            cache.len - 1);
                drafts.push(argmax(&logits[..v]));
            }
            stats.drafted += m;

            // ---- verify: one causal prefill over seq ++ drafts ----
            let n0 = seq.len();
            let mut ver_seq = seq.clone();
            ver_seq.extend_from_slice(&drafts);
            let pre = self.runner.prefill(&ver_seq)?;
            stats.verify_prefills += 1;
            stats.rounds += 1;
            let target_at = |p: usize| argmax(&pre.logits[p * v..(p + 1) * v]);
            let mut acc = 0;
            while acc < m && target_at(n0 + acc - 1) == drafts[acc] {
                acc += 1;
            }
            stats.accepted += acc;
            // accepted drafts, then the verifier's next token (the
            // correction on mismatch, the bonus on a full accept)
            seq.extend_from_slice(&drafts[..acc]);
            seq.push(target_at(n0 + acc - 1));
            let over = (seq.len() - prompt.len()).saturating_sub(max_new);
            seq.truncate(seq.len() - over);
            if seq.len() - prompt.len() >= max_new {
                break;
            }

            // ---- rebuild the draft cache from the verifier's K/V ----
            cache.free(&mut pool);
            cache = self.rebuild_cache(&cfg, &mut pool, &mut staging, &pre,
                                       seq.len() - 1)?;
        }
        let tokens = seq[prompt.len()..].to_vec();
        Ok(SelfSpecOutput { tokens, stats })
    }

    /// Fresh 4-bit cache holding the first `n` tokens of a verify
    /// prefill's K/V (the last accepted token stays out — it is the
    /// next decode input), with the staging view loaded to match.
    fn rebuild_cache(&self, cfg: &ModelConfig, pool: &mut PagePool,
                     staging: &mut DecodeStaging, pre: &Prefilled,
                     n: usize) -> Result<SeqCache> {
        let d = cfg.d_kv();
        let mut cache = SeqCache::new(cfg, QualityTier::Kv4.kv_bits(),
                                      self.runner.spec.kv_clip,
                                      TOKENS_PER_PAGE);
        // repack (L, pre.len, d) → (L, n, d)
        let mut ks = Vec::with_capacity(cfg.n_layers * n * d);
        let mut vs = Vec::with_capacity(cfg.n_layers * n * d);
        for l in 0..cfg.n_layers {
            let o = l * pre.len * d;
            ks.extend_from_slice(&pre.ks[o..o + n * d]);
            vs.extend_from_slice(&pre.vs[o..o + n * d]);
        }
        cache.init_from_prefill(pool, &ks, &vs, n, cfg.kv_group)?;
        for t in 0..n {
            stage_token(staging, pool, cfg, &cache, t);
        }
        Ok(cache)
    }
}

/// Write one cached token into lane 0 of the dense staging view — the
/// single-sequence twin of the batcher's staging write-through.
fn stage_token(staging: &mut DecodeStaging, pool: &PagePool,
               cfg: &ModelConfig, cache: &SeqCache, t: usize) {
    let (l_n, b, s) = (cfg.n_layers, cfg.decode_batch, cfg.cache_seq);
    let d = cfg.d_kv();
    let ng = d / cfg.kv_group;
    let mut codes = vec![0i8; d];
    let mut scales = vec![0.0f32; ng];
    let mut zeros = vec![0.0f32; ng];
    for l in 0..l_n {
        for want_v in [false, true] {
            cache.read_token(pool, l, t, want_v,
                             &mut codes, &mut scales, &mut zeros);
            let co = (l * b * s + t) * d; // lane 0
            let go = (l * b * s + t) * ng;
            let (dc, ds, dz) = if want_v {
                (&mut staging.v_codes, &mut staging.v_scale,
                 &mut staging.v_zero)
            } else {
                (&mut staging.k_codes, &mut staging.k_scale,
                 &mut staging.k_zero)
            };
            dc[co..co + d].copy_from_slice(&codes);
            ds[go..go + ng].copy_from_slice(&scales);
            dz[go..go + ng].copy_from_slice(&zeros);
        }
    }
}

/// Plain greedy decoding by iterated prefill — the reference the
/// self-speculative path must match token-for-token, and the ppl-grade
/// "pure verifier" baseline for its speedup claims.
pub fn prefill_greedy(runner: &Runner, prompt: &[u16], max_new: usize)
                      -> Result<Vec<u16>> {
    if prompt.is_empty() {
        bail!("empty prompt");
    }
    let v = runner.cfg.vocab;
    let mut seq = prompt.to_vec();
    while seq.len() - prompt.len() < max_new && seq.len() <= runner.cfg.max_seq {
        let pre = runner.prefill(&seq)?;
        seq.push(argmax(&pre.logits[(pre.len - 1) * v..pre.len * v]));
    }
    Ok(seq[prompt.len()..].to_vec())
}

/// First-maximum argmax — both the draft and verify sides of the accept
/// rule use this exact reduction, so ties cannot break the equality.
fn argmax(logits: &[f32]) -> u16 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &x) in logits.iter().enumerate() {
        if x > best_v {
            best_v = x;
            best = i;
        }
    }
    best as u16
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_is_first_maximum() {
        assert_eq!(argmax(&[0.0, 2.0, 1.0]), 1);
        assert_eq!(argmax(&[3.0, 3.0, 3.0]), 0, "ties break low");
        assert_eq!(argmax(&[-2.0, -1.0]), 1);
    }

    #[test]
    fn stats_acceptance_rate() {
        let mut s = SelfSpecStats::default();
        assert_eq!(s.acceptance_rate(), 0.0, "no drafts → rate 0");
        s.drafted = 8;
        s.accepted = 6;
        assert!((s.acceptance_rate() - 0.75).abs() < 1e-12);
    }
}
