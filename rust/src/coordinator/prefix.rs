//! Shared rotated-KV prefix cache: a page-granular trie over prompt
//! token runs.
//!
//! QuaRot's KV-4 quantization (Table 6: near-lossless; Table 17: ~3.9×
//! smaller) makes cached prompt prefixes ~4× cheaper to keep resident
//! than fp16 — exactly the regime where a shared prefix cache pays for
//! itself under multi-user traffic with common system prompts.  This
//! module is that cache:
//!
//! * **Entries are whole pages.**  A trie node stands for one
//!   `tokens_per_page`-token run of a prompt and pins that run's
//!   already-quantized, rotated K and V pages for every layer (a
//!   [`PageGroup`]).  Page granularity keeps sharing safe: full pages
//!   are never written again ([`super::kvcache::SeqCache`] only writes
//!   at its append position), so a grafted prefix is read-only by
//!   construction and the first divergent token lands on a fresh
//!   exclusively-owned page — copy-on-write at page granularity, with
//!   no copying.
//! * **Refcounts, not ownership.**  Insertion retains pages
//!   ([`PagePool::retain`]); eviction and [`PrefixCache::clear`]
//!   release them.  An entry evicted while a live sequence still grafts
//!   its pages keeps those pages allocated until the last sequence
//!   frees them — the trie only ever drops *its own* reference.
//! * **LRU eviction.**  Under the page budget, or under pool pressure
//!   via [`PrefixCache::evict_for`], the least-recently-used *leaves*
//!   go first (keeping the trie prefix-closed: an interior node's pages
//!   are an ancestor of some live chain).  Nodes touched by the
//!   operation currently in flight (same clock stamp) are protected, so
//!   an admission can never evict the chain it is about to graft.
//! * **Tier isolation.**  Roots are keyed by the donor's
//!   [`QualityTier`]: pages hold tier-width codes (4-bit vs 8-bit), so
//!   a KV4 prefix grafted into a KV8 sequence would silently misdecode.
//!   Keying by tier makes a cross-tier graft structurally impossible —
//!   the same prompt may be cached once per tier, each chain pinning
//!   its own pages.

use std::collections::HashMap;

use crate::api::QualityTier;
use crate::audit::{LockScope, PinAudit};

use super::kvcache::{PageGroup, PagePool};

/// Counters and live gauges of one prefix cache — per-shard on the wire
/// `metrics` frame, aggregated on the `stats` frame.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PrefixStats {
    /// admissions that consulted the trie
    pub lookups: usize,
    /// admissions that grafted at least one shared page group
    pub hits: usize,
    /// admissions that found no (page-aligned) cached prefix
    pub misses: usize,
    /// prompt tokens served from the cache instead of prefill
    pub hit_tokens: usize,
    /// pool pages grafted from the cache (`2·n_layers` per group)
    pub hit_pages: usize,
    /// pool pages the trie retained over its lifetime
    pub inserted_pages: usize,
    /// pool pages released by LRU eviction or a cache clear
    pub evicted_pages: usize,
    /// live gauge: pool pages the trie currently pins
    pub pages_pinned: usize,
}

impl PrefixStats {
    /// Fraction of admissions that grafted a shared prefix.
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            return 0.0;
        }
        self.hits as f64 / self.lookups as f64
    }
}

struct Node {
    /// the `tokens_per_page`-token run this node extends its parent by
    run: Box<[u16]>,
    /// precision tier of the cached pages (needed to unlink roots)
    tier: QualityTier,
    parent: Option<usize>,
    children: HashMap<Box<[u16]>, usize>,
    pages: PageGroup,
    /// clock stamp of the last lookup/insert that touched this node
    last_used: u64,
    /// sessions currently pinning this node ([`PrefixCache::pin_chain`]):
    /// a pinned node is exempt from LRU eviction so a live conversation's
    /// chain cannot be aged out between turns.  `clear` still force-evicts
    /// pinned nodes, which is why unpins tolerate missing chains.
    pins: u32,
}

/// The trie.  Keys are exact token runs (no hashing — a collision would
/// graft the wrong K/V); payloads are retained page groups.  Roots are
/// additionally keyed by precision tier — see the module doc.
pub struct PrefixCache {
    tokens_per_page: usize,
    n_layers: usize,
    /// Max pool pages the trie may pin; 0 disables the cache entirely.
    max_pages: usize,
    roots: HashMap<QualityTier, HashMap<Box<[u16]>, usize>>,
    nodes: Vec<Option<Node>>,
    free_slots: Vec<usize>,
    clock: u64,
    stats: PrefixStats,
    /// Debug-build mirror of per-node pin counts (slot-reuse aware);
    /// tests opt into strictness via [`Self::assert_pins_balanced`].
    /// Zero-sized in release builds.
    audit: PinAudit,
}

impl PrefixCache {
    pub fn new(tokens_per_page: usize, n_layers: usize, max_pages: usize)
               -> PrefixCache {
        assert!(tokens_per_page > 0 && n_layers > 0);
        PrefixCache {
            tokens_per_page,
            n_layers,
            max_pages,
            roots: HashMap::new(),
            nodes: Vec::new(),
            free_slots: Vec::new(),
            clock: 0,
            stats: PrefixStats::default(),
            audit: PinAudit::new(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.max_pages > 0
    }

    pub fn stats(&self) -> PrefixStats {
        self.stats
    }

    pub fn pages_pinned(&self) -> usize {
        self.stats.pages_pinned
    }

    /// Pool pages one group pins (K + V page per layer).
    fn group_pages(&self) -> usize {
        2 * self.n_layers
    }

    fn child(&self, tier: QualityTier, cur: Option<usize>, run: &[u16])
             -> Option<usize> {
        let table = match cur {
            None => match self.roots.get(&tier) {
                Some(t) => t,
                None => return None,
            },
            Some(p) => &self.nodes[p].as_ref().unwrap().children,
        };
        table.get(run).copied()
    }

    /// Longest chain of cached full-page groups matching `prompt`,
    /// capped at `max_groups` (the caller leaves at least one suffix
    /// token uncached — the first-token logits have to come from a live
    /// forward pass).  Bumps the LRU stamps along the match; hit/miss
    /// counters are recorded by [`Self::record_use`] at the actual
    /// admission, so a request re-peeked for many ticks while holding
    /// for pages does not inflate the hit rate.
    /// Only chains donated at the same `tier` match — the pages hold
    /// tier-width codes.
    pub fn lookup(&mut self, tier: QualityTier, prompt: &[u16],
                  max_groups: usize) -> Vec<PageGroup> {
        if self.max_pages == 0 {
            return Vec::new();
        }
        let _audit = LockScope::enter("coordinator.prefix");
        self.clock += 1;
        let mut out = Vec::new();
        let mut cur = None;
        for run in prompt.chunks_exact(self.tokens_per_page).take(max_groups) {
            let Some(id) = self.child(tier, cur, run) else { break };
            let node = self.nodes[id].as_mut().unwrap();
            node.last_used = self.clock;
            out.push(node.pages.clone());
            cur = Some(id);
        }
        out
    }

    /// Record one admission's outcome — how many groups it actually
    /// grafted (0 = miss).
    pub fn record_use(&mut self, grafted_groups: usize) {
        if self.max_pages == 0 {
            return;
        }
        self.stats.lookups += 1;
        if grafted_groups == 0 {
            self.stats.misses += 1;
        } else {
            self.stats.hits += 1;
            self.stats.hit_tokens += grafted_groups * self.tokens_per_page;
            self.stats.hit_pages += grafted_groups * self.group_pages();
        }
    }

    /// Donate the full-page groups of a freshly built cache
    /// (`groups[i]` covers `prompt[i·tpp..(i+1)·tpp]`), walking and
    /// extending the trie.  Existing nodes win over re-donation — the
    /// codes are identical by construction (same tokens, same
    /// deterministic quantizer), so keeping the first donor's pages
    /// maximizes sharing.  New nodes retain their pages; the page
    /// budget is enforced by evicting LRU leaves first and truncating
    /// the donation when nothing evictable remains.
    pub fn insert(&mut self, pool: &mut PagePool, tier: QualityTier,
                  prompt: &[u16], groups: &[PageGroup]) {
        if self.max_pages == 0 || groups.is_empty() {
            return;
        }
        assert!(groups.len() * self.tokens_per_page <= prompt.len(),
                "donated groups exceed the prompt");
        let _audit = LockScope::enter("coordinator.prefix");
        self.clock += 1;
        let mut cur: Option<usize> = None;
        for (i, g) in groups.iter().enumerate() {
            let run = &prompt[i * self.tokens_per_page
                              ..(i + 1) * self.tokens_per_page];
            if let Some(id) = self.child(tier, cur, run) {
                self.nodes[id].as_mut().unwrap().last_used = self.clock;
                cur = Some(id);
                continue;
            }
            match self.attach_node(pool, tier, cur, run, g) {
                Some(id) => cur = Some(id),
                // budget held by entries hotter than this donation
                None => break,
            }
        }
    }

    /// Retain `g`'s pages and hang a new node for `run` off `parent`
    /// (the shared tail of [`Self::insert`] and [`Self::insert_tail`]).
    /// Evicts LRU leaves to make budget room first; `None` when the
    /// budget is held by hotter entries.
    fn attach_node(&mut self, pool: &mut PagePool, tier: QualityTier,
                   parent: Option<usize>, run: &[u16], g: &PageGroup)
                   -> Option<usize> {
        let gp = self.group_pages();
        while self.stats.pages_pinned + gp > self.max_pages {
            let Some(leaf) = self.lru_leaf() else { break };
            self.evict_node(pool, leaf, false);
        }
        if self.stats.pages_pinned + gp > self.max_pages {
            return None;
        }
        // the slot this node will land in (free_slots pops from the
        // back) — charged as the ledger owner of the retained refs
        let slot_hint = self.free_slots.last().copied()
            .unwrap_or(self.nodes.len());
        {
            let _own = crate::audit::owner(
                || format!("prefix:node{slot_hint}"));
            for l in 0..self.n_layers {
                pool.retain(g.k[l]);
                pool.retain(g.v[l]);
            }
        }
        let node = Node {
            run: run.into(),
            tier,
            parent,
            children: HashMap::new(),
            pages: g.clone(),
            last_used: self.clock,
            pins: 0,
        };
        let id = match self.free_slots.pop() {
            Some(slot) => {
                self.nodes[slot] = Some(node);
                slot
            }
            None => {
                self.nodes.push(Some(node));
                self.nodes.len() - 1
            }
        };
        debug_assert_eq!(id, slot_hint, "owner label names the wrong slot");
        self.audit.on_insert(id);
        match parent {
            None => {
                self.roots.entry(tier).or_default()
                    .insert(run.into(), id);
            }
            Some(p) => {
                self.nodes[p].as_mut().unwrap()
                    .children.insert(run.into(), id);
            }
        }
        self.stats.pages_pinned += gp;
        self.stats.inserted_pages += gp;
        Some(id)
    }

    /// Donate the *partial* trailing page of a retired chain: a leaf
    /// keyed by the sub-page run `chain[⌊len/tpp⌋·tpp..]`.  Partial runs
    /// are invisible to [`Self::lookup`] / [`Self::pin_chain`] (both walk
    /// `chunks_exact` full runs) — only [`Self::lookup_tail`] reaches
    /// them, so grafting semantics of full pages are untouched.  The
    /// tail only attaches when every full page ahead of it is cached
    /// (otherwise no lookup could ever reach it); identical re-donations
    /// keep the first donor, like [`Self::insert`].
    pub fn insert_tail(&mut self, pool: &mut PagePool, tier: QualityTier,
                       chain: &[u16], group: &PageGroup) {
        if self.max_pages == 0 {
            return;
        }
        let tpp = self.tokens_per_page;
        let tail = chain.len() % tpp;
        if tail == 0 {
            return;
        }
        let _audit = LockScope::enter("coordinator.prefix");
        self.clock += 1;
        let mut cur = None;
        for run in chain[..chain.len() - tail].chunks_exact(tpp) {
            let Some(id) = self.child(tier, cur, run) else { return };
            self.nodes[id].as_mut().unwrap().last_used = self.clock;
            cur = Some(id);
        }
        let run = &chain[chain.len() - tail..];
        if self.child(tier, cur, run).is_none() {
            self.attach_node(pool, tier, cur, run, group);
        }
    }

    /// Longest donated partial-tail run extending a `matched`-group
    /// [`Self::lookup`] chain of `prompt`.  The run must be a *strict*
    /// prefix of the prompt's remainder — at least one suffix token
    /// always stays uncached for the first-token logits.  Returns the
    /// tail's pages (to **copy**, never share — see
    /// [`super::kvcache::SeqCache::graft_partial_tail`]) and its token
    /// count.  Does not advance the LRU clock: it rides the admission's
    /// in-flight stamp so the chain [`Self::lookup`] just touched stays
    /// eviction-protected.
    pub fn lookup_tail(&mut self, tier: QualityTier, prompt: &[u16],
                       matched: usize) -> Option<(PageGroup, usize)> {
        if self.max_pages == 0 {
            return None;
        }
        let _audit = LockScope::enter("coordinator.prefix");
        let tpp = self.tokens_per_page;
        let mut cur = None;
        for run in prompt.chunks_exact(tpp).take(matched) {
            cur = Some(self.child(tier, cur, run)?);
        }
        let rest = &prompt[matched * tpp..];
        let table = match cur {
            None => self.roots.get(&tier)?,
            Some(p) => &self.nodes[p].as_ref().unwrap().children,
        };
        // longest strict-prefix partial run; ties are impossible (two
        // equal-length prefixes of `rest` are the same run)
        let best = table.iter()
            .filter(|(run, _)| run.len() < tpp && run.len() < rest.len()
                    && rest.starts_with(run))
            .max_by_key(|(run, _)| run.len())
            .map(|(_, &id)| id)?;
        let node = self.nodes[best].as_mut().unwrap();
        node.last_used = self.clock;
        Some((node.pages.clone(), node.run.len()))
    }

    /// Walk the page-aligned chain of `tokens` and pin every matched
    /// node, exempting it from LRU eviction (budget pressure and
    /// [`Self::evict_for`]).  Sessions pin their conversation chain after
    /// each donation so a live conversation's KV pages survive between
    /// turns.  Returns how many nodes were pinned — the walk stops at the
    /// first uncached run, so a partially-donated chain pins its cached
    /// prefix only.  Pins are counts: overlapping chains stack.
    pub fn pin_chain(&mut self, tier: QualityTier, tokens: &[u16]) -> usize {
        let _audit = LockScope::enter("coordinator.prefix");
        let mut cur = None;
        let mut pinned = 0;
        for run in tokens.chunks_exact(self.tokens_per_page) {
            let Some(id) = self.child(tier, cur, run) else { break };
            let node = self.nodes[id].as_mut().unwrap();
            node.pins += 1;
            let pins_after = node.pins;
            self.audit.on_pin(id, pins_after);
            pinned += 1;
            cur = Some(id);
        }
        pinned
    }

    /// Undo one [`Self::pin_chain`] over the same tokens.  Tolerant by
    /// design: nodes force-evicted by [`Self::clear`] (or re-donated
    /// fresh afterwards) simply end the walk or saturate at zero — a
    /// stale unpin is a no-op, never a panic.
    pub fn unpin_chain(&mut self, tier: QualityTier, tokens: &[u16]) -> usize {
        let _audit = LockScope::enter("coordinator.prefix");
        let mut cur = None;
        let mut unpinned = 0;
        for run in tokens.chunks_exact(self.tokens_per_page) {
            let Some(id) = self.child(tier, cur, run) else { break };
            let node = self.nodes[id].as_mut().unwrap();
            let saturated = node.pins == 0;
            node.pins = node.pins.saturating_sub(1);
            self.audit.on_unpin(id, saturated);
            unpinned += 1;
            cur = Some(id);
        }
        unpinned
    }

    /// Least-recently-used evictable leaf: childless, not pinned by a
    /// session, and not touched by the operation currently in flight
    /// (`last_used < clock`, so an admission cannot evict the chain it
    /// just matched).
    fn lru_leaf(&self) -> Option<usize> {
        self.nodes.iter().enumerate()
            .filter_map(|(i, n)| n.as_ref().map(|n| (i, n)))
            .filter(|(_, n)| n.children.is_empty() && n.pins == 0
                    && n.last_used < self.clock)
            .min_by_key(|&(_, n)| n.last_used)
            .map(|(i, _)| i)
    }

    fn evict_node(&mut self, pool: &mut PagePool, id: usize, forced: bool) {
        self.audit.on_evict(id, forced);
        let node = self.nodes[id].take().unwrap();
        debug_assert!(node.children.is_empty(), "evicting an interior node");
        for l in 0..self.n_layers {
            pool.release(node.pages.k[l]);
            pool.release(node.pages.v[l]);
        }
        match node.parent {
            None => {
                if let Some(t) = self.roots.get_mut(&node.tier) {
                    t.remove(&node.run);
                }
            }
            Some(p) => {
                self.nodes[p].as_mut().unwrap().children.remove(&node.run);
            }
        }
        self.free_slots.push(id);
        let gp = self.group_pages();
        self.stats.pages_pinned -= gp;
        self.stats.evicted_pages += gp;
    }

    /// Evict LRU leaves until the pool has `target` available pages, or
    /// nothing evictable remains.  A page still grafted by a live
    /// sequence survives its trie eviction (the trie only drops its own
    /// reference), so under pressure this converges on releasing
    /// exactly the pages nobody is actively decoding over.
    pub fn evict_for(&mut self, pool: &mut PagePool, target: usize) {
        let _audit = LockScope::enter("coordinator.prefix");
        while pool.available() < target {
            let Some(leaf) = self.lru_leaf() else { return };
            self.evict_node(pool, leaf, false);
        }
    }

    /// Release every cached page (counted into `evicted_pages`) — the
    /// admin flush and the engine-reconfiguration path.  Session pins are
    /// NOT honored here: a flush force-evicts pinned chains too (their
    /// sessions re-donate on the next turn; the later stale unpins are
    /// no-ops by construction).
    pub fn clear(&mut self, pool: &mut PagePool) {
        let _audit = LockScope::enter("coordinator.prefix");
        loop {
            let Some(leaf) = self.nodes.iter().enumerate()
                .find(|(_, n)| n.as_ref().is_some_and(|n| n.children.is_empty()))
                .map(|(i, _)| i)
            else { break };
            self.evict_node(pool, leaf, true);
        }
        debug_assert_eq!(self.stats.pages_pinned, 0, "pinned pages leaked");
        self.roots.clear();
        self.nodes.clear();
        self.free_slots.clear();
        self.audit.on_clear();
    }

    /// Opt-in strict pin check for tests and leak smokes: every node's
    /// pin count is back at zero and no unpin on a *live* node ever hit
    /// an already-zero count (stale unpins after [`Self::clear`] never
    /// reach the auditor — the chain walk ends at the missing node).
    /// No-op in release builds.
    pub fn assert_pins_balanced(&self) {
        self.audit.assert_balanced();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const L: usize = 2;
    const TPP: usize = 4;
    /// Most tests exercise one tier; tier isolation has its own test.
    const T: QualityTier = QualityTier::Kv4;

    /// A "sequence-owned" group: freshly allocated pages (refcount 1).
    fn group(pool: &mut PagePool) -> PageGroup {
        PageGroup {
            k: (0..L).map(|_| pool.alloc().unwrap()).collect(),
            v: (0..L).map(|_| pool.alloc().unwrap()).collect(),
        }
    }

    fn release_group(pool: &mut PagePool, g: &PageGroup) {
        for &p in g.k.iter().chain(g.v.iter()) {
            pool.release(p);
        }
    }

    fn prompt(n: usize, seed: u16) -> Vec<u16> {
        (0..n as u16).map(|i| i * 3 + seed).collect()
    }

    #[test]
    fn insert_lookup_roundtrip_and_partial_match() {
        let mut pool = PagePool::new(8, 64);
        let mut trie = PrefixCache::new(TPP, L, usize::MAX);
        let pa = prompt(12, 0); // 3 groups
        let ga: Vec<PageGroup> = (0..3).map(|_| group(&mut pool)).collect();
        trie.insert(&mut pool, T, &pa, &ga);
        assert_eq!(trie.pages_pinned(), 3 * 2 * L);

        assert_eq!(trie.lookup(T, &pa, 3), ga);
        assert_eq!(trie.lookup(T, &pa, 2), ga[..2], "cap must truncate the chain");
        // diverging at the second run matches only the first group
        let mut pb = pa.clone();
        pb[TPP] ^= 1;
        assert_eq!(trie.lookup(T, &pb, 3), ga[..1]);
        // a different first run misses outright
        assert!(trie.lookup(T, &prompt(12, 9), 3).is_empty());
        // short prompts never produce a full run
        assert!(trie.lookup(T, &pa[..TPP - 1], 3).is_empty());

        trie.record_use(3);
        trie.record_use(0);
        let s = trie.stats();
        assert_eq!((s.lookups, s.hits, s.misses), (2, 1, 1));
        assert_eq!(s.hit_tokens, 3 * TPP);
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);

        // drain: sequences release, then the trie
        for g in &ga {
            release_group(&mut pool, g);
        }
        assert_eq!(pool.in_use(), 3 * 2 * L, "trie must keep pages alive");
        trie.clear(&mut pool);
        assert_eq!(pool.in_use(), 0, "refcount leak");
        assert_eq!(trie.stats().evicted_pages, 3 * 2 * L);
    }

    #[test]
    fn redonation_keeps_first_donor_and_pins_nothing_new() {
        let mut pool = PagePool::new(8, 64);
        let mut trie = PrefixCache::new(TPP, L, usize::MAX);
        let p = prompt(8, 0);
        let first: Vec<PageGroup> = (0..2).map(|_| group(&mut pool)).collect();
        trie.insert(&mut pool, T, &p, &first);
        let pinned = trie.pages_pinned();
        let second: Vec<PageGroup> = (0..2).map(|_| group(&mut pool)).collect();
        trie.insert(&mut pool, T, &p, &second);
        assert_eq!(trie.pages_pinned(), pinned, "re-donation must not pin");
        assert_eq!(trie.lookup(T, &p, 2), first, "first donor must win");
        for g in first.iter().chain(&second) {
            release_group(&mut pool, g);
        }
        trie.clear(&mut pool);
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn budget_evicts_lru_leaves_first() {
        let mut pool = PagePool::new(8, 64);
        // budget: exactly two groups
        let mut trie = PrefixCache::new(TPP, L, 2 * 2 * L);
        let pa = prompt(8, 0); // 2 groups: A1 → A2
        let ga: Vec<PageGroup> = (0..2).map(|_| group(&mut pool)).collect();
        trie.insert(&mut pool, T, &pa, &ga);
        for g in &ga {
            release_group(&mut pool, g); // trie is now the sole owner
        }
        let _ = trie.lookup(T, &pa, 2); // make A recently used

        let pb = prompt(4, 9); // 1 group
        let gb = vec![group(&mut pool)];
        trie.insert(&mut pool, T, &pb, &gb);
        release_group(&mut pool, &gb[0]);

        // the LRU *leaf* (A2) was evicted; A1 (interior → now leaf) stays
        assert_eq!(trie.pages_pinned(), 2 * 2 * L);
        assert_eq!(trie.stats().evicted_pages, 2 * L);
        assert_eq!(trie.lookup(T, &pa, 2).len(), 1, "A1 must survive");
        assert_eq!(trie.lookup(T, &pb, 1).len(), 1, "B must be cached");
        // A2's pages went back to the pool (trie was sole owner)
        assert_eq!(pool.in_use(), 2 * 2 * L);
        trie.clear(&mut pool);
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn evict_for_frees_pool_pages_but_protects_the_matched_chain() {
        // pool sized so the trie's two chains fill it completely
        let mut pool = PagePool::new(8, 4 * 2 * L);
        let mut trie = PrefixCache::new(TPP, L, usize::MAX);
        let (pa, pb) = (prompt(8, 0), prompt(8, 9));
        for p in [&pa, &pb] {
            let gs: Vec<PageGroup> = (0..2).map(|_| group(&mut pool)).collect();
            trie.insert(&mut pool, T, p, &gs);
            for g in &gs {
                release_group(&mut pool, g);
            }
        }
        assert_eq!(pool.available(), 0);

        // an admission that just matched A must evict from B, not A
        let matched = trie.lookup(T, &pa, 2);
        assert_eq!(matched.len(), 2);
        trie.evict_for(&mut pool, 2 * L);
        assert!(pool.available() >= 2 * L);
        assert_eq!(trie.lookup(T, &pa, 2).len(), 2,
                   "the just-matched chain must be protected");
        assert!(trie.lookup(T, &pb, 2).len() < 2, "B must have shrunk");
        trie.clear(&mut pool);
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn eviction_spares_pages_still_grafted_by_sequences() {
        let mut pool = PagePool::new(8, 2 * L);
        let mut trie = PrefixCache::new(TPP, L, usize::MAX);
        let p = prompt(4, 0);
        let g = vec![group(&mut pool)];
        trie.insert(&mut pool, T, &p, &g);
        let _ = trie.lookup(T, &prompt(4, 5), 1); // advance the clock
        // the "sequence" keeps its graft; evicting everything must not
        // free the pages out from under it
        trie.evict_for(&mut pool, 1);
        assert_eq!(trie.pages_pinned(), 0, "entry evicted");
        assert_eq!(pool.available(), 0,
                   "grafted pages must survive their trie eviction");
        release_group(&mut pool, &g[0]);
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn tiers_never_share_pages() {
        // The tier-mismatch regression gate: a chain donated at KV4
        // must be invisible to KV8 lookups (its pages hold 4-bit
        // codes), and each tier caches the same prompt independently.
        let mut pool = PagePool::new(8, 64);
        let mut trie = PrefixCache::new(TPP, L, usize::MAX);
        let p = prompt(8, 0);
        let g4: Vec<PageGroup> = (0..2).map(|_| group(&mut pool)).collect();
        trie.insert(&mut pool, QualityTier::Kv4, &p, &g4);

        assert!(trie.lookup(QualityTier::Kv8, &p, 2).is_empty(),
                "KV4 pages must never graft into a KV8 sequence");
        assert_eq!(trie.lookup(QualityTier::Kv4, &p, 2), g4);

        // the other tier donates the same prompt: both chains coexist,
        // each pinning its own pages
        let g8: Vec<PageGroup> = (0..2).map(|_| group(&mut pool)).collect();
        trie.insert(&mut pool, QualityTier::Kv8, &p, &g8);
        assert_eq!(trie.pages_pinned(), 2 * 2 * 2 * L,
                   "per-tier chains must not share pins");
        assert_eq!(trie.lookup(QualityTier::Kv8, &p, 2), g8);
        assert_eq!(trie.lookup(QualityTier::Kv4, &p, 2), g4,
                   "the KV8 donation must not displace the KV4 chain");

        for g in g4.iter().chain(&g8) {
            release_group(&mut pool, g);
        }
        trie.clear(&mut pool);
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn pinned_chains_survive_eviction_until_unpinned() {
        let mut pool = PagePool::new(8, 64);
        let mut trie = PrefixCache::new(TPP, L, usize::MAX);
        let (pa, pb) = (prompt(8, 0), prompt(8, 9));
        for p in [&pa, &pb] {
            let gs: Vec<PageGroup> = (0..2).map(|_| group(&mut pool)).collect();
            trie.insert(&mut pool, T, p, &gs);
            for g in &gs {
                release_group(&mut pool, g);
            }
        }
        // pin A (a session's live chain); a partial-page tail is ignored
        let mut pa_tail = pa.clone();
        pa_tail.extend_from_slice(&[7; TPP - 1]);
        assert_eq!(trie.pin_chain(T, &pa_tail), 2);
        let _ = trie.lookup(T, &prompt(4, 5), 1); // advance the clock

        // pressure that wants everything: only B's chain may go
        trie.evict_for(&mut pool, usize::MAX);
        assert_eq!(trie.lookup(T, &pa, 2).len(), 2,
                   "pinned chain must survive eviction pressure");
        assert!(trie.lookup(T, &pb, 2).is_empty(), "unpinned chain evicts");

        // unpinning re-arms eviction; a second stale unpin is a no-op
        assert_eq!(trie.unpin_chain(T, &pa), 2);
        assert_eq!(trie.unpin_chain(T, &pa), 2, "saturates at zero");
        let _ = trie.lookup(T, &prompt(4, 5), 1);
        trie.evict_for(&mut pool, usize::MAX);
        assert_eq!(trie.pages_pinned(), 0, "unpinned chain must evict");
        assert_eq!(pool.in_use(), 0);

        // clear() force-evicts pinned chains; the stale unpin that
        // follows must be harmless (missing chain ⇒ walk ends)
        let gs: Vec<PageGroup> = (0..2).map(|_| group(&mut pool)).collect();
        trie.insert(&mut pool, T, &pa, &gs);
        for g in &gs {
            release_group(&mut pool, g);
        }
        trie.pin_chain(T, &pa);
        trie.clear(&mut pool);
        assert_eq!(pool.in_use(), 0, "flush must override pins");
        assert_eq!(trie.unpin_chain(T, &pa), 0, "stale unpin is a no-op");
    }

    #[test]
    fn pin_audit_balances_across_stacking_and_slot_reuse() {
        let mut pool = PagePool::new(8, 64);
        let mut trie = PrefixCache::new(TPP, L, usize::MAX);
        let pa = prompt(8, 0);
        let ga: Vec<PageGroup> = (0..2).map(|_| group(&mut pool)).collect();
        trie.insert(&mut pool, T, &pa, &ga);
        for g in &ga {
            release_group(&mut pool, g);
        }
        // two sessions stack pins on the shared chain; both unpin
        assert_eq!(trie.pin_chain(T, &pa), 2);
        assert_eq!(trie.pin_chain(T, &pa), 2);
        assert_eq!(trie.unpin_chain(T, &pa), 2);
        assert_eq!(trie.unpin_chain(T, &pa), 2);
        trie.assert_pins_balanced();

        // evict the chain, re-donate into the recycled slots, pin again:
        // the mirror must restart from zero per slot
        let _ = trie.lookup(T, &prompt(4, 5), 1); // advance the clock
        trie.evict_for(&mut pool, usize::MAX);
        assert_eq!(trie.pages_pinned(), 0);
        let gb: Vec<PageGroup> = (0..2).map(|_| group(&mut pool)).collect();
        trie.insert(&mut pool, T, &pa, &gb);
        for g in &gb {
            release_group(&mut pool, g);
        }
        assert_eq!(trie.pin_chain(T, &pa), 2);
        assert_eq!(trie.unpin_chain(T, &pa), 2);
        trie.assert_pins_balanced();
        trie.clear(&mut pool);
        assert_eq!(pool.in_use(), 0);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "pin audit unbalanced")]
    fn stale_unpin_on_a_live_chain_fails_the_strict_check() {
        let mut pool = PagePool::new(8, 64);
        let mut trie = PrefixCache::new(TPP, L, usize::MAX);
        let pa = prompt(8, 0);
        let ga: Vec<PageGroup> = (0..2).map(|_| group(&mut pool)).collect();
        trie.insert(&mut pool, T, &pa, &ga);
        for g in &ga {
            release_group(&mut pool, g);
        }
        trie.pin_chain(T, &pa);
        trie.unpin_chain(T, &pa);
        // the chain is still cached, so this stale unpin saturates on
        // live nodes — tolerated at runtime, fatal under strictness
        trie.unpin_chain(T, &pa);
        trie.assert_pins_balanced();
    }

    /// Partial-tail donations: reachable only through `lookup_tail`
    /// with a strictly-longer remainder, invisible to full-run lookups
    /// and pins, first donor wins, and evictable as ordinary leaves.
    #[test]
    fn tail_donation_lookup_and_isolation() {
        let mut pool = PagePool::new(8, 64);
        let mut trie = PrefixCache::new(TPP, L, usize::MAX);
        let chain = prompt(10, 0); // 2 full groups + 2-token tail
        let gs: Vec<PageGroup> = (0..2).map(|_| group(&mut pool)).collect();
        trie.insert(&mut pool, T, &chain, &gs);
        let gt = group(&mut pool);
        trie.insert_tail(&mut pool, T, &chain, &gt);
        assert_eq!(trie.pages_pinned(), 3 * 2 * L);

        // full-run lookup still sees exactly the full groups
        assert_eq!(trie.lookup(T, &chain, 3), gs);
        // a next-turn prompt: same chain plus new user text
        let mut next = chain.clone();
        next.extend_from_slice(&[40, 41, 42]);
        assert_eq!(trie.lookup_tail(T, &next, 2), Some((gt.clone(), 2)));
        // the remainder must be strictly longer than the tail — a
        // prompt that *ends* at the tail keeps its last token uncached
        assert_eq!(trie.lookup_tail(T, &chain, 2), None);
        // diverging tail tokens miss
        let mut div = chain.clone();
        div[9] ^= 1;
        div.push(40);
        assert_eq!(trie.lookup_tail(T, &div, 2), None);
        // wrong tier misses
        assert_eq!(trie.lookup_tail(QualityTier::Kv8, &next, 2), None);
        // re-donation keeps the first donor and pins nothing new
        let gt2 = group(&mut pool);
        trie.insert_tail(&mut pool, T, &chain, &gt2);
        assert_eq!(trie.pages_pinned(), 3 * 2 * L, "re-donation must not pin");
        assert_eq!(trie.lookup_tail(T, &next, 2), Some((gt.clone(), 2)));
        // pins walk full runs only: the tail leaf stays evictable
        assert_eq!(trie.pin_chain(T, &chain), 2);
        let _ = trie.lookup(T, &prompt(4, 9), 1); // advance the clock
        trie.evict_for(&mut pool, usize::MAX);
        assert_eq!(trie.lookup_tail(T, &next, 2), None, "tail leaf evicts");
        assert_eq!(trie.lookup(T, &chain, 2).len(), 2,
                   "pinned full chain survives");
        assert_eq!(trie.unpin_chain(T, &chain), 2);

        for g in gs.iter().chain([&gt, &gt2]) {
            release_group(&mut pool, g);
        }
        trie.clear(&mut pool);
        assert_eq!(pool.in_use(), 0);
    }

    /// A tail whose full-page chain was never donated must not attach —
    /// no lookup could ever reach it, and its pages must not be pinned.
    #[test]
    fn orphan_tail_is_rejected() {
        let mut pool = PagePool::new(8, 64);
        let mut trie = PrefixCache::new(TPP, L, usize::MAX);
        let chain = prompt(10, 0);
        let gt = group(&mut pool);
        trie.insert_tail(&mut pool, T, &chain, &gt); // nothing cached ahead
        assert_eq!(trie.pages_pinned(), 0);
        // page-aligned chains have no tail to donate
        let aligned = prompt(8, 0);
        let gs: Vec<PageGroup> = (0..2).map(|_| group(&mut pool)).collect();
        trie.insert(&mut pool, T, &aligned, &gs);
        let before = trie.pages_pinned();
        trie.insert_tail(&mut pool, T, &aligned, &gt);
        assert_eq!(trie.pages_pinned(), before);
        for g in gs.iter().chain([&gt]) {
            release_group(&mut pool, g);
        }
        trie.clear(&mut pool);
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn disabled_cache_is_inert() {
        let mut pool = PagePool::new(8, 16);
        let mut trie = PrefixCache::new(TPP, L, 0);
        let p = prompt(8, 0);
        let gs: Vec<PageGroup> = (0..2).map(|_| group(&mut pool)).collect();
        let before = pool.in_use();
        trie.insert(&mut pool, T, &p, &gs);
        assert!(trie.lookup(T, &p, 2).is_empty());
        trie.record_use(0);
        assert_eq!(trie.stats(), PrefixStats::default());
        assert_eq!(pool.in_use(), before, "disabled cache must not retain");
        assert!(!trie.enabled());
        for g in &gs {
            release_group(&mut pool, g);
        }
        assert_eq!(pool.in_use(), 0);
    }
}
