//! Continuous-batching generation engine.
//!
//! vLLM-style loop specialised to the AOT decode graph's fixed batch width:
//! requests queue FIFO; free slots take the next request (prefill on the
//! B=1 graph, K/V quantized into the paged cache = the paper's `Init`),
//! then every engine tick runs ONE batched decode step over all active
//! slots (`Decode`), appends the new K/V (`Append`) and samples the next
//! token.  Finished/failed slots release their pages immediately.
//!
//! Metrics per request: time-to-first-token, per-token latency, totals —
//! the numbers the serving benches and the e2e example report.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::kvcache::{PagePool, SeqCache};
use super::runner::{DecodeStaging, Runner};
use super::sampler::{sample, Sampling};
use crate::backend::pool::SendPtr;
use crate::backend::ComputeBackend;
use crate::util::prng::Rng;

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u16>,
    pub max_new_tokens: usize,
    pub sampling: Sampling,
    /// stop generation at this token (e.g. a synthetic EOS); None = run to max
    pub stop_token: Option<u16>,
}

#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<u16>,
    pub ttft_ms: f64,
    pub decode_ms: f64,
    pub queued_ms: f64,
}

struct Slot {
    req: Request,
    cache: SeqCache,
    generated: Vec<u16>,
    next_token: u16,
    enqueued: Instant,
    started: Instant,
    ttft_ms: f64,
}

#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub completed: usize,
    pub decode_steps: usize,
    pub decode_tokens: usize,
    pub total_decode_ms: f64,
    pub total_prefill_ms: f64,
    pub peak_cache_bytes: usize,
    pub peak_cache_fp16_bytes: usize,
}

impl EngineStats {
    pub fn tokens_per_sec(&self) -> f64 {
        if self.total_decode_ms <= 0.0 {
            return 0.0;
        }
        self.decode_tokens as f64 / (self.total_decode_ms / 1e3)
    }
}

/// The generation engine: owns the runner, page pool and slot table.
pub struct GenerationEngine {
    pub runner: Runner,
    /// Native compute backend (shared with the runner): staging dequant
    /// and the per-slot decode-tick fan-out route through this.
    backend: Arc<dyn ComputeBackend>,
    pool: PagePool,
    slots: Vec<Option<Slot>>,
    queue: VecDeque<(Request, Instant)>,
    staging: DecodeStaging,
    rng: Rng,
    pub stats: EngineStats,
    tokens_per_page: usize,
    completions: Vec<Completion>,
    next_id: u64,
}

impl GenerationEngine {
    pub fn new(runner: Runner, pool_pages: usize, seed: u64) -> GenerationEngine {
        let cfg = runner.cfg.clone();
        let tokens_per_page = 16usize;
        let kv_bits = if runner.spec.kv_bits == 16 { 8 } else { runner.spec.kv_bits };
        let geom = SeqCache::new(&cfg, kv_bits, runner.spec.kv_clip,
                                 tokens_per_page).geom();
        let fp = runner.spec.kv_bits == 16;
        GenerationEngine {
            backend: runner.backend.clone(),
            staging: DecodeStaging::new(&cfg, fp),
            pool: PagePool::new(geom.page_bytes(), pool_pages),
            slots: (0..cfg.decode_batch).map(|_| None).collect(),
            queue: VecDeque::new(),
            rng: Rng::new(seed),
            stats: EngineStats::default(),
            tokens_per_page,
            completions: Vec::new(),
            next_id: 1,
            runner,
        }
    }

    pub fn submit(&mut self, mut req: Request) -> u64 {
        if req.id == 0 {
            req.id = self.next_id;
            self.next_id += 1;
        }
        let id = req.id;
        self.queue.push_back((req, Instant::now()));
        id
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn take_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.completions)
    }

    fn cache_bits(&self) -> u32 {
        if self.runner.spec.kv_bits == 16 { 8 } else { self.runner.spec.kv_bits }
    }

    /// Admit queued requests into free slots (prefill + cache init).
    fn admit(&mut self) -> Result<()> {
        for slot_idx in 0..self.slots.len() {
            if self.slots[slot_idx].is_some() {
                continue;
            }
            let Some((req, enq)) = self.queue.pop_front() else {
                break;
            };
            let t0 = Instant::now();
            let pre = self.runner.prefill(&req.prompt)?;
            self.stats.total_prefill_ms += t0.elapsed().as_secs_f64() * 1e3;

            let cfg = self.runner.cfg.clone();
            let fp = self.runner.spec.kv_bits == 16;
            let mut cache = SeqCache::new(&cfg, self.cache_bits(),
                                          self.runner.spec.kv_clip,
                                          self.tokens_per_page);
            if fp {
                // fp16-baseline: authoritative values live in the f32 staging
                let (l_n, b, s, d) = (cfg.n_layers, cfg.decode_batch,
                                      cfg.cache_seq, cfg.d_kv());
                for l in 0..l_n {
                    for t in 0..pre.len {
                        let src = (l * pre.len + t) * d;
                        let dst = ((l * b + slot_idx) * s + t) * d;
                        self.staging.k_f32[dst..dst + d]
                            .copy_from_slice(&pre.ks[src..src + d]);
                        self.staging.v_f32[dst..dst + d]
                            .copy_from_slice(&pre.vs[src..src + d]);
                    }
                }
                cache.set_len(pre.len);
            } else {
                cache.init_from_prefill(&mut self.pool, &pre.ks, &pre.vs, pre.len,
                                        cfg.kv_group)?;
                // also write the dense staging region for this slot
                self.load_slot_staging(slot_idx, &cache);
            }

            let v = cfg.vocab;
            let last = &pre.logits[(pre.len - 1) * v..pre.len * v];
            let first_tok = sample(last, req.sampling, &mut self.rng) as u16;
            let ttft = enq.elapsed().as_secs_f64() * 1e3;
            self.slots[slot_idx] = Some(Slot {
                generated: vec![first_tok],
                next_token: first_tok,
                enqueued: enq,
                started: Instant::now(),
                ttft_ms: ttft,
                req,
                cache,
            });
        }
        Ok(())
    }

    /// Refresh the whole dense staging view of one slot from its pages.
    fn load_slot_staging(&mut self, slot: usize, cache: &SeqCache) {
        let cfg = self.runner.cfg.clone();
        let (l_n, b, s) = (cfg.n_layers, cfg.decode_batch, cfg.cache_seq);
        let d = cfg.d_kv();
        let ng = d / cfg.kv_group;
        let fp = self.runner.spec.kv_bits == 16;
        let backend = self.backend.clone();
        let mut codes = vec![0i8; d];
        let mut scales = vec![0.0f32; ng];
        let mut zeros = vec![0.0f32; ng];
        for l in 0..l_n {
            for t in 0..cache.len {
                for (want_v, which) in [(false, 0), (true, 1)] {
                    cache.read_token(&self.pool, l, t, want_v,
                                     &mut codes, &mut scales, &mut zeros);
                    let co = ((l * b + slot) * s + t) * d;
                    let go = ((l * b + slot) * s + t) * ng;
                    if fp {
                        let dst = if which == 0 { &mut self.staging.k_f32 }
                                  else { &mut self.staging.v_f32 };
                        backend.kv_dequant(&codes, &scales, &zeros, cfg.kv_group,
                                           &mut dst[co..co + d]);
                    } else {
                        let (dst_c, dst_s, dst_z) = if which == 0 {
                            (&mut self.staging.k_codes, &mut self.staging.k_scale,
                             &mut self.staging.k_zero)
                        } else {
                            (&mut self.staging.v_codes, &mut self.staging.v_scale,
                             &mut self.staging.v_zero)
                        };
                        dst_c[co..co + d].copy_from_slice(&codes);
                        dst_s[go..go + ng].copy_from_slice(&scales);
                        dst_z[go..go + ng].copy_from_slice(&zeros);
                    }
                }
            }
        }
    }

    /// Append one token's K/V into the authoritative store of one slot:
    /// the dense staging view for the fp16 baseline, the packed pages
    /// otherwise.  Paged slots get their staging write-through afterwards,
    /// batched over all active slots, in [`Self::refresh_staging_for`].
    fn append_to_cache(&mut self, slot: usize, k_new: &[f32], v_new: &[f32])
                       -> Result<()> {
        let cfg = self.runner.cfg.clone();
        let (l_n, b, s) = (cfg.n_layers, cfg.decode_batch, cfg.cache_seq);
        let d = cfg.d_kv();
        let fp = self.runner.spec.kv_bits == 16;
        if fp {
            let sl = self.slots[slot].as_mut().unwrap();
            let t = sl.cache.len;
            for l in 0..l_n {
                let src = (l * b + slot) * d;
                let dst = ((l * b + slot) * s + t) * d;
                self.staging.k_f32[dst..dst + d]
                    .copy_from_slice(&k_new[src..src + d]);
                self.staging.v_f32[dst..dst + d]
                    .copy_from_slice(&v_new[src..src + d]);
            }
            sl.cache.bump();
            return Ok(());
        }
        let sl = self.slots[slot].as_mut().unwrap();
        for l in 0..l_n {
            let o = (l * b + slot) * d;
            sl.cache.append_layer(&mut self.pool, l, &k_new[o..o + d],
                                  &v_new[o..o + d], cfg.kv_group)?;
        }
        sl.cache.bump();
        Ok(())
    }

    /// Staging write-through for the just-appended token of every slot in
    /// `active` (paged caches only): read back the quantized token so the
    /// dense view is bit-identical to the authoritative pages.  This is
    /// the decode tick's per-batch-slot fan-out — slots are independent
    /// and write disjoint staging regions, so the backend may run them in
    /// parallel ([`ComputeBackend::par_for`]).
    fn refresh_staging_for(&mut self, active: &[usize]) {
        let cfg = self.runner.cfg.clone();
        let (l_n, b, s) = (cfg.n_layers, cfg.decode_batch, cfg.cache_seq);
        let d = cfg.d_kv();
        let ng = d / cfg.kv_group;
        let backend = self.backend.clone();
        let pool = &self.pool;
        let slots = &self.slots;
        let kc = SendPtr::new(self.staging.k_codes.as_mut_ptr());
        let ks = SendPtr::new(self.staging.k_scale.as_mut_ptr());
        let kz = SendPtr::new(self.staging.k_zero.as_mut_ptr());
        let vc = SendPtr::new(self.staging.v_codes.as_mut_ptr());
        let vs = SendPtr::new(self.staging.v_scale.as_mut_ptr());
        let vz = SendPtr::new(self.staging.v_zero.as_mut_ptr());
        backend.par_for(active.len(), &|ai| {
            let slot = active[ai];
            let sl = slots[slot].as_ref().unwrap();
            let t = sl.cache.len - 1; // the token appended this tick
            let mut codes = vec![0i8; d];
            let mut scales = vec![0.0f32; ng];
            let mut zeros = vec![0.0f32; ng];
            for l in 0..l_n {
                for want_v in [false, true] {
                    sl.cache.read_token(pool, l, t, want_v,
                                        &mut codes, &mut scales, &mut zeros);
                    let co = ((l * b + slot) * s + t) * d;
                    let go = ((l * b + slot) * s + t) * ng;
                    let (dc, ds, dz) = if want_v { (vc, vs, vz) } else { (kc, ks, kz) };
                    // SAFETY: each active slot owns disjoint staging
                    // regions (indexed by `slot`), and par_for joins
                    // before the buffers are read again.
                    unsafe {
                        std::ptr::copy_nonoverlapping(codes.as_ptr(),
                                                      dc.get().add(co), d);
                        std::ptr::copy_nonoverlapping(scales.as_ptr(),
                                                      ds.get().add(go), ng);
                        std::ptr::copy_nonoverlapping(zeros.as_ptr(),
                                                      dz.get().add(go), ng);
                    }
                }
            }
        });
    }

    /// One engine tick: admit, batched decode, append, sample, retire.
    /// Returns number of tokens produced this tick.
    pub fn tick(&mut self) -> Result<usize> {
        self.admit()?;
        let cfg = self.runner.cfg.clone();
        let b = cfg.decode_batch;
        let active: Vec<usize> = (0..b).filter(|&i| self.slots[i].is_some()).collect();
        if active.is_empty() {
            return Ok(0);
        }
        let mut tokens = vec![0i32; b];
        let mut lens = vec![0i32; b];
        for &i in &active {
            let sl = self.slots[i].as_ref().unwrap();
            tokens[i] = sl.next_token as i32;
            lens[i] = sl.cache.len as i32;
        }
        let t0 = Instant::now();
        let (logits, k_new, v_new) = self.runner.decode(&tokens, &lens, &self.staging)?;
        let step_ms = t0.elapsed().as_secs_f64() * 1e3;
        self.stats.decode_steps += 1;
        self.stats.decode_tokens += active.len();
        self.stats.total_decode_ms += step_ms;

        let v = cfg.vocab;
        let mut produced = 0;
        // Phase 1: sample + retire, in slot order (keeps the RNG stream
        // and therefore generations identical to the sequential engine).
        // Finished slots release their pages *before* any appends, so a
        // tight pool can recycle pages within the tick, and a retiring
        // slot's final K/V — which nothing would ever read — is never
        // appended at all.
        let mut survivors: Vec<usize> = Vec::with_capacity(active.len());
        for &i in &active {
            let sl = self.slots[i].as_mut().unwrap();
            let next = sample(&logits[i * v..(i + 1) * v], sl.req.sampling,
                              &mut self.rng) as u16;
            sl.generated.push(next);
            sl.next_token = next;
            produced += 1;
            let hit_stop = sl.req.stop_token == Some(next);
            // `+ 2` = this tick's append (phase 2) plus the next tick's —
            // the same bound the old post-append `len + 1` check enforced.
            let full = sl.generated.len() >= sl.req.max_new_tokens
                || sl.cache.len + 2 >= cfg.cache_seq;
            if hit_stop || full {
                let mut slot = self.slots[i].take().unwrap();
                let decode_ms = slot.started.elapsed().as_secs_f64() * 1e3;
                slot.cache.free(&mut self.pool);
                self.stats.completed += 1;
                self.completions.push(Completion {
                    id: slot.req.id,
                    prompt_len: slot.req.prompt.len(),
                    tokens: slot.generated,
                    ttft_ms: slot.ttft_ms,
                    decode_ms,
                    queued_ms: slot.enqueued.elapsed().as_secs_f64() * 1e3,
                });
            } else {
                survivors.push(i);
            }
        }
        // Phase 2: append into the authoritative caches (page allocation
        // is shared state — sequential), then fan the staging
        // write-through over batch slots on the compute backend.
        for &i in &survivors {
            self.append_to_cache(i, &k_new, &v_new)?;
        }
        if self.runner.spec.kv_bits != 16 && !survivors.is_empty() {
            self.refresh_staging_for(&survivors);
        }
        let cache_bytes: usize = self.slots.iter().flatten().map(|s| s.cache.bytes()).sum();
        let fp16_bytes: usize = self.slots.iter().flatten()
            .map(|s| s.cache.fp16_equiv_bytes()).sum();
        self.stats.peak_cache_bytes = self.stats.peak_cache_bytes.max(cache_bytes);
        self.stats.peak_cache_fp16_bytes =
            self.stats.peak_cache_fp16_bytes.max(fp16_bytes);
        Ok(produced)
    }

    /// Drive until every submitted request completes.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        while self.pending() > 0 {
            self.tick()?;
        }
        Ok(self.take_completions())
    }

    pub fn pool_in_use(&self) -> usize {
        self.pool.in_use()
    }
}
