//! Continuous-batching generation engine.
//!
//! vLLM-style loop specialised to the AOT decode graph's fixed batch width:
//! requests queue FIFO behind a bounded admission gate; free slots take the
//! next request (prefill on the B=1 graph, K/V quantized into the paged
//! cache = the paper's `Init`), then every engine tick runs ONE batched
//! decode step over all active slots (`Decode`), appends the new K/V
//! (`Append`) and samples the next token.  Finished/failed/cancelled slots
//! release their pages immediately.
//!
//! Admission consults the shared prefix cache (`coordinator::prefix`)
//! first: a page-aligned cached prompt prefix is grafted into the new
//! sequence's cache (refcounted, read-only — CoW at page granularity)
//! and only the uncached suffix runs a forward pass, through the decode
//! graph so suffix tokens attend over the grafted prefix at their true
//! positions.  Cold prefills donate their prompt's full pages back to
//! the trie; sessioned requests ([`crate::session`]) additionally donate
//! their *generated* pages at retirement and pin the resulting chain, so
//! the next turn of the conversation grafts prompt and replies both and
//! prefills only the new user text.
//!
//! The engine is *event-oriented*: every lifecycle step is emitted as a
//! [`GenerationEvent`] tagged with the request id (`Queued` on submit,
//! `Started`/first `Token` at admit, one `Token` per decode tick, exactly
//! one terminal `Finished`/`Failed`).  Consumers drain them with
//! [`GenerationEngine::take_events`]; the `quarot::api` layer is the
//! intended front door.  [`GenerationEngine::run_to_completion`] survives
//! as a thin compatibility shim that folds the event stream back into
//! [`Completion`] records, keeping the benches deterministic.

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use super::kvcache::{PageGroup, PagePool, PoolStats, SeqCache};
use super::prefix::{PrefixCache, PrefixStats};
use super::runner::{DecodeStaging, Runner};
use super::sampler::{sample, Sampling};
use crate::api::{FinishReason, GenerationEvent, Priority, QualityTier,
                 RequestStats, SubmitError};
use crate::attention::{DecodeF32Seq, DecodeQuantSeq, KvCodes, KvF32View,
                       KvQuantView};
use crate::audit::LockScope;
use crate::backend::pool::SendPtr;
use crate::backend::ComputeBackend;
use crate::model::ModelConfig;
use crate::session::{SessionSpec, SessionStore, DEFAULT_SESSION_BUDGET};
use crate::telemetry::{Clock, Histogram, MonotonicClock, Span, SpanRecorder};
use crate::util::prng::Rng;

/// Tokens per KV page — the unit of paging, of prefix sharing, and of
/// the cluster router's prefix-affinity hashing.
pub const TOKENS_PER_PAGE: usize = 16;

/// Default per-tick prefill token budget shared between chunked-prefill
/// jobs and decode slots (`--prefill-chunk` overrides).
pub const DEFAULT_PREFILL_CHUNK: usize = 32;

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u16>,
    pub max_new_tokens: usize,
    pub sampling: Sampling,
    /// stop generation at this token (e.g. a synthetic EOS); None = run to max
    pub stop_token: Option<u16>,
    /// admission class — the fair-share queue schedules across classes
    pub priority: Priority,
    /// deadline in ms from enqueue; expired requests (queued or active)
    /// retire with `FinishReason::DeadlineExceeded`
    pub deadline_ms: Option<u64>,
    /// KV-cache precision tier of this sequence (already resolved — the
    /// priority-default fallback happens in `GenerationParams`).  Pins
    /// the per-sequence cache width and tags its prefix-trie entries;
    /// ignored by the fp16 baseline, whose K/V never hit the paged cache.
    pub tier: QualityTier,
    /// Multi-turn chat: `New` starts a conversation, `Resume(id)` makes
    /// the engine prepend the session's stored history to `prompt` at
    /// submit and replay it from donated prefix-cache pages.  `try_submit`
    /// normalizes this to `Resume(assigned id)`; `None` = plain one-shot.
    pub session: Option<SessionSpec>,
}

/// The resolved session id of a request (post-`try_submit` every
/// sessioned request carries `Resume(id)`).
fn session_id(req: &Request) -> Option<u64> {
    match req.session {
        Some(SessionSpec::Resume(id)) => Some(id),
        _ => None,
    }
}

/// Whether `req`'s deadline lapsed, on the engine's [`Clock`] timeline
/// (`enqueued_ms`/`now_ms` are readings of the same clock — tests drive
/// this with a `ManualClock` instead of sleeping).
fn deadline_expired(req: &Request, enqueued_ms: f64, now_ms: f64) -> bool {
    req.deadline_ms.is_some_and(|d| now_ms - enqueued_ms >= d as f64)
}

/// Priority-class admission queue: one FIFO lane per [`Priority`] class,
/// scheduled by weighted deficit round-robin.  With both lanes backlogged
/// and weights 4:1, pops interleave I,I,B,I,I — Interactive dominates but
/// Batch is never starved (and an empty competitor hands its share over
/// entirely).  Within a lane, FIFO order is preserved.
pub(crate) struct FairQueue {
    /// Each entry carries its enqueue time as a [`Clock`] ms reading.
    classes: [VecDeque<(Request, f64)>; Priority::COUNT],
    credit: [i64; Priority::COUNT],
}

const CLASS_WEIGHTS: [i64; Priority::COUNT] =
    [Priority::Interactive.weight(), Priority::Batch.weight()];

impl FairQueue {
    fn new() -> FairQueue {
        FairQueue {
            classes: std::array::from_fn(|_| VecDeque::new()),
            credit: [0; Priority::COUNT],
        }
    }

    fn len(&self) -> usize {
        self.classes.iter().map(|c| c.len()).sum()
    }

    fn push_back(&mut self, req: Request, enqueued_ms: f64) {
        self.classes[req.priority.index()].push_back((req, enqueued_ms));
    }

    /// The class the next [`Self::pop`] will serve, plus the credit state
    /// that pop would leave behind.  Pure — repeated calls are stable, so
    /// admission can [`Self::peek`] the scheduled request (e.g. for the
    /// page-admission hold) without charging its class a quantum.
    fn scheduled(&self) -> Option<(usize, [i64; Priority::COUNT])> {
        let nonempty: Vec<usize> = (0..Priority::COUNT)
            .filter(|&c| !self.classes[c].is_empty())
            .collect();
        match nonempty.len() {
            0 => None,
            // a lone class takes the whole link; reset credits so a long
            // solo run does not bank unfair priority for later
            1 => Some((nonempty[0], [0; Priority::COUNT])),
            _ => {
                let total: i64 = nonempty.iter().map(|&c| CLASS_WEIGHTS[c]).sum();
                let mut credit = self.credit;
                for &c in &nonempty {
                    credit[c] += CLASS_WEIGHTS[c];
                }
                // max credit; ties go to the lower class index (Interactive)
                let &c = nonempty.iter()
                    .max_by_key(|&&c| (credit[c], std::cmp::Reverse(c)))
                    .unwrap();
                credit[c] -= total;
                Some((c, credit))
            }
        }
    }

    /// The request the next pop will return, scheduler state untouched.
    fn peek(&self) -> Option<&(Request, f64)> {
        let (c, _) = self.scheduled()?;
        self.classes[c].front()
    }

    /// Next request under weighted deficit round-robin.
    fn pop(&mut self) -> Option<(Request, f64)> {
        let (c, credit) = self.scheduled()?;
        self.credit = credit;
        self.classes[c].pop_front()
    }

    fn remove_by_id(&mut self, id: u64) -> Option<(Request, f64)> {
        for class in self.classes.iter_mut() {
            if let Some(pos) = class.iter().position(|(r, _)| r.id == id) {
                return class.remove(pos);
            }
        }
        None
    }

    /// Class-order drain (engine teardown — scheduling no longer matters).
    fn pop_any(&mut self) -> Option<(Request, f64)> {
        self.classes.iter_mut().find_map(|c| c.pop_front())
    }

    fn has_deadlines(&self) -> bool {
        self.classes.iter().flatten().any(|(r, _)| r.deadline_ms.is_some())
    }

    /// Remove every queued request whose deadline has lapsed at `now_ms`.
    fn take_expired(&mut self, now_ms: f64) -> Vec<(Request, f64)> {
        let mut out = Vec::new();
        for class in self.classes.iter_mut() {
            let mut keep = VecDeque::with_capacity(class.len());
            for (req, enq) in class.drain(..) {
                if deadline_expired(&req, enq, now_ms) {
                    out.push((req, enq));
                } else {
                    keep.push_back((req, enq));
                }
            }
            *class = keep;
        }
        out
    }
}

#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<u16>,
    pub ttft_ms: f64,
    pub decode_ms: f64,
    pub queued_ms: f64,
}

struct Slot {
    req: Request,
    cache: SeqCache,
    generated: Vec<u16>,
    next_token: u16,
    /// Enqueue / first-token times as [`Clock`] ms readings.
    enqueued_ms: f64,
    started_ms: f64,
    /// When this slot's most recent token was sampled — the inter-token
    /// latency histogram records `now - last_token_ms` every tick.
    last_token_ms: f64,
    ttft_ms: f64,
}

impl Slot {
    fn stats(&self, now_ms: f64) -> RequestStats {
        RequestStats {
            prompt_len: self.req.prompt.len(),
            generated: self.generated.len(),
            ttft_ms: self.ttft_ms,
            decode_ms: now_ms - self.started_ms,
            queued_ms: now_ms - self.enqueued_ms,
            session: session_id(&self.req),
        }
    }
}

/// A prefix-hit admission whose uncached suffix is still prefilling.
/// The job occupies a slot index (it owns that slot's staging lane); the
/// tick advances it chunk by chunk under the shared prefill/decode token
/// budget and promotes it to a live [`Slot`] when the prompt completes.
/// Until then the request has emitted no `Started` — a deadline or cancel
/// retires it mid-prefill, freeing its pages immediately.
struct PrefillJob {
    req: Request,
    /// Grafted prefix plus every suffix token appended so far;
    /// `cache.len` is the prompt position the next chunk starts at.
    cache: SeqCache,
    /// Tokens grafted from the prefix cache at admission.
    graft_tokens: usize,
    /// Accumulated forward-pass wall time across chunks (becomes the
    /// "prefill" span duration at completion).
    pf_ms: f64,
    enqueued_ms: f64,
    /// Queue wait recorded when the request was popped (feeds the
    /// queue-wait histogram at `Started`).
    wait_ms: f64,
}

impl PrefillJob {
    /// Terminal stats for a job retired before its first token.
    fn stats(&self, now_ms: f64) -> RequestStats {
        RequestStats {
            prompt_len: self.req.prompt.len(),
            generated: 0,
            ttft_ms: 0.0,
            decode_ms: 0.0,
            queued_ms: now_ms - self.enqueued_ms,
            session: session_id(&self.req),
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub completed: usize,
    pub cancelled: usize,
    pub failed: usize,
    /// requests retired because their server-side deadline lapsed
    pub deadline_exceeded: usize,
    pub decode_steps: usize,
    pub decode_tokens: usize,
    /// per-tier splits — the mixed KV4/KV8 workload observability the
    /// tier subsystem promises.  Both splits are exact partitions:
    /// `kv4_completed + kv8_completed == completed` and
    /// `kv4_decode_tokens + kv8_decode_tokens == decode_tokens`
    /// (cancelled / expired requests count in neither split, matching
    /// their exclusion from `completed`).
    pub kv4_completed: usize,
    pub kv8_completed: usize,
    pub kv4_decode_tokens: usize,
    pub kv8_decode_tokens: usize,
    /// prompt tokens prefilled through the executor on the prefix-cache
    /// hit path (the uncached suffixes)
    pub suffix_prefill_tokens: usize,
    /// chunked-prefill accounting: forward passes (one per
    /// [`Runner::prefill_chunk`] call) and the suffix tokens they
    /// covered.  `prefill_chunk_tokens == suffix_prefill_tokens` always;
    /// `prefill_chunks` is what the per-tick budget bounds — a lone
    /// S-token suffix on an idle engine takes exactly
    /// `ceil(S / prefill_chunk)` chunks, one per tick
    pub prefill_chunks: usize,
    pub prefill_chunk_tokens: usize,
    pub total_decode_ms: f64,
    pub total_prefill_ms: f64,
    pub peak_cache_bytes: usize,
    pub peak_cache_fp16_bytes: usize,
    /// sum/count of per-request TTFT (time from enqueue to first token);
    /// the averaging lives in `cluster::ShardMetrics::avg_ttft_ms`, which
    /// needs the raw sum/count to weight the cluster-wide mean correctly
    pub ttft_sum_ms: f64,
    pub ttft_count: usize,
    /// conversation turns retired into a session's history (natural
    /// retirements of sessioned requests only — cancelled / expired /
    /// failed turns are not remembered and count in neither gauge)
    pub session_turns: usize,
    /// prompt tokens a resumed turn did NOT prefill because they were
    /// grafted from pages an earlier turn of the same session donated —
    /// the headline win of generated-token donation (on turn k this is
    /// ≈ the full turn-1..k-1 history length)
    pub session_prefill_tokens_saved: usize,
    /// time-to-first-token distribution (one sample per started request);
    /// log-bucketed and mergeable, so the cluster layer aggregates by
    /// merging shard histograms rather than averaging shard averages
    pub ttft_hist: Histogram,
    /// inter-token latency: one sample per decode token after the first
    pub itl_hist: Histogram,
    /// admission queue wait (enqueue → pop) per started request
    pub queue_wait_hist: Histogram,
    /// wall duration of every decode tick (ticks with no active slots
    /// are not recorded)
    pub tick_hist: Histogram,
}

impl EngineStats {
    pub fn tokens_per_sec(&self) -> f64 {
        if self.total_decode_ms <= 0.0 {
            return 0.0;
        }
        self.decode_tokens as f64 / (self.total_decode_ms / 1e3)
    }
}

/// The generation engine: owns the runner, page pool and slot table.
pub struct GenerationEngine {
    pub runner: Runner,
    /// Native compute backend (shared with the runner): staging dequant
    /// and the per-slot decode-tick fan-out route through this.
    backend: Arc<dyn ComputeBackend>,
    pool: PagePool,
    /// Shared prompt-prefix cache over the page pool: a trie of retained
    /// full pages, consulted at admission (budget 0 = disabled; always
    /// disabled on the fp16 baseline, whose authoritative K/V live in
    /// dense staging rather than pages).
    prefix: PrefixCache,
    slots: Vec<Option<Slot>>,
    /// In-flight chunked suffix prefills, indexed like `slots` — a slot
    /// is free for admission only when both its entries are `None`.
    prefill_jobs: Vec<Option<PrefillJob>>,
    /// Per-tick prefill token budget shared with decode: each active
    /// decode slot reserves one token, the remainder is split across
    /// jobs (minimum one each, so neither side ever starves).
    prefill_chunk: usize,
    /// Fair-share admission queue (weighted deficit across priority
    /// classes — see [`FairQueue`]).
    queue: FairQueue,
    /// Admission bound on the waiting queue (not counting active slots);
    /// `try_submit` rejects with `SubmitError::QueueFull` beyond it.
    queue_bound: usize,
    /// Multi-turn conversation registry (`crate::session`): histories,
    /// LRU/TTL eviction, and which trie chain each session pins.
    sessions: SessionStore,
    staging: DecodeStaging,
    rng: Rng,
    pub stats: EngineStats,
    tokens_per_page: usize,
    /// Undelivered lifecycle events, in emission order.
    events: VecDeque<(u64, GenerationEvent)>,
    next_id: u64,
    /// Time source for every request timestamp (TTFT, queue wait,
    /// deadlines, span times).  Tests inject a `ManualClock` for
    /// deterministic latency assertions; production keeps the default
    /// [`MonotonicClock`].
    clock: Arc<dyn Clock>,
    /// Lifecycle/phase span ring, owned and written only by the tick
    /// thread (capacity 0 — the default — disables tracing entirely).
    recorder: SpanRecorder,
    /// Configured 1-in-N sampling for per-token decode spans, kept here
    /// so `set_trace_buffer` can rebuild the ring without losing it.
    trace_sample: u64,
}

impl GenerationEngine {
    pub fn new(runner: Runner, pool_pages: usize, seed: u64) -> GenerationEngine {
        let cfg = runner.cfg.clone();
        let tokens_per_page = TOKENS_PER_PAGE;
        // Pool pages are sized for the *widest* tier (KV8): a KV4
        // sequence's tighter page layout fits in the same page with
        // slack, so one pool serves a mixed KV4/KV8 workload.  Page
        // *counts* (admission, trie budgets) are width-independent.
        let geom = SeqCache::new(&cfg, 8, runner.spec.kv_clip,
                                 tokens_per_page).geom();
        let fp = runner.spec.kv_is_fp();
        GenerationEngine {
            backend: runner.backend.clone(),
            staging: DecodeStaging::new(&cfg, fp),
            pool: PagePool::new(geom.page_bytes(), pool_pages),
            // default on at half the pool — enough to absorb common
            // system prompts without starving live sequences; resize or
            // disable via `set_prefix_cache_pages` (`--prefix-cache`)
            prefix: PrefixCache::new(tokens_per_page, cfg.n_layers,
                                     if fp { 0 } else { pool_pages / 2 }),
            slots: (0..cfg.decode_batch).map(|_| None).collect(),
            prefill_jobs: (0..cfg.decode_batch).map(|_| None).collect(),
            prefill_chunk: DEFAULT_PREFILL_CHUNK,
            queue: FairQueue::new(),
            queue_bound: usize::MAX,
            sessions: SessionStore::new(DEFAULT_SESSION_BUDGET),
            rng: Rng::new(seed),
            stats: EngineStats::default(),
            tokens_per_page,
            events: VecDeque::new(),
            next_id: 1,
            clock: Arc::new(MonotonicClock::new()),
            recorder: SpanRecorder::new(0),
            trace_sample: 1,
            runner,
        }
    }

    /// Inject a time source for request timestamps (TTFT, queue wait,
    /// deadlines, span times).  Tests pass a
    /// [`crate::telemetry::ManualClock`] and advance it explicitly; the
    /// default is wall-clock [`MonotonicClock`].
    pub fn set_clock(&mut self, clock: Arc<dyn Clock>) {
        self.clock = clock;
    }

    /// Size the lifecycle span ring (`serve --trace-buffer N`): keep the
    /// most recent `capacity` spans for `{"cmd":"trace"}` / `quarot
    /// trace` export.  0 (the default) disables tracing — every record
    /// call is a cheap early-out.  Resizing discards buffered spans.
    pub fn set_trace_buffer(&mut self, capacity: usize) {
        self.recorder = SpanRecorder::new(capacity);
        self.recorder.set_sample_every(self.trace_sample);
    }

    /// Keep only 1-in-`n` per-token `decode_token` spans (`serve
    /// --trace-sample N`) — the one span class that scales with tokens
    /// rather than requests.  1 (the default) keeps them all.
    pub fn set_trace_sample(&mut self, every: u64) {
        self.trace_sample = every.max(1);
        self.recorder.set_sample_every(self.trace_sample);
    }

    /// Whether span recording is active (trace buffer > 0).
    pub fn trace_enabled(&self) -> bool {
        self.recorder.enabled()
    }

    /// Take every buffered span, oldest first, emptying the ring.  Called
    /// from the shard's control mailbox between ticks — never concurrent
    /// with recording.
    pub fn drain_spans(&mut self) -> Vec<Span> {
        self.recorder.drain()
    }

    /// Spans overwritten because the trace ring was full.
    pub fn spans_dropped(&self) -> u64 {
        self.recorder.dropped()
    }

    /// Set the per-tick prefill token budget (`serve --prefill-chunk N`)
    /// shared between chunked-prefill jobs and decode slots: each active
    /// decode slot reserves one budget token (decode never stalls behind
    /// a long prompt), and the remainder is split evenly across in-flight
    /// jobs — but every job always advances by at least one token per
    /// tick, so prefill cannot be starved by a full decode batch either.
    pub fn set_prefill_chunk(&mut self, tokens: usize) {
        self.prefill_chunk = tokens.max(1);
    }

    /// The per-tick prefill token budget.
    pub fn prefill_chunk(&self) -> usize {
        self.prefill_chunk
    }

    /// Cap the waiting queue; submissions beyond it are rejected with
    /// [`SubmitError::QueueFull`] (the serving layer's backpressure).
    pub fn set_queue_bound(&mut self, bound: usize) {
        self.queue_bound = bound.max(1);
    }

    pub fn queue_bound(&self) -> usize {
        self.queue_bound
    }

    /// Admission-controlled submit: checks the *engine-side* limits — the
    /// model's `max_seq` and the queue bound — assigns an id, and emits
    /// the `Queued` event.  Model-independent shape checks (empty prompt,
    /// zero budget, sampling) live in `GenerationParams::validate`, which
    /// the `api` layer runs before reaching here; a raw engine caller
    /// skipping them gets a `Failed` event at admission (empty prompt)
    /// or a single token (`max_new_tokens == 0` is treated as 1), never
    /// undefined behaviour.
    pub fn try_submit(&mut self, mut req: Request) -> Result<u64, SubmitError> {
        if req.prompt.len() > self.runner.cfg.max_seq {
            return Err(SubmitError::InvalidParams(format!(
                "prompt length {} exceeds max_seq {}",
                req.prompt.len(), self.runner.cfg.max_seq)));
        }
        if self.queue.len() >= self.queue_bound {
            return Err(SubmitError::QueueFull { bound: self.queue_bound });
        }
        // Session resolution — after the queue-bound check so a rejected
        // submit never creates a phantom session.  A resume prepends the
        // stored history (served from donated prefix-cache pages at
        // admission) and inherits the session's tier, keeping every turn's
        // chain graftable in the tier-keyed trie.
        if let Some(spec) = req.session {
            match self.sessions.resolve(spec, req.tier) {
                Some(res) => {
                    for e in res.evicted {
                        if let Some(chain) = e.pinned {
                            self.prefix.unpin_chain(e.tier, &chain);
                        }
                    }
                    req.tier = res.tier;
                    if !res.history.is_empty() {
                        let mut full = res.history;
                        full.extend_from_slice(&req.prompt);
                        req.prompt = full;
                    }
                    req.session = Some(SessionSpec::Resume(res.id));
                }
                // budget 0: sessions disabled — serve as a plain one-shot
                None => req.session = None,
            }
            if req.prompt.len() > self.runner.cfg.max_seq {
                return Err(SubmitError::InvalidParams(format!(
                    "conversation history + prompt ({} tokens) exceeds \
                     max_seq {} — start a new session",
                    req.prompt.len(), self.runner.cfg.max_seq)));
            }
        }
        if req.id == 0 {
            req.id = self.next_id;
            self.next_id += 1;
        } else {
            // caller-assigned ids (the cluster router) must not collide
            // with engine-assigned ones later
            self.next_id = self.next_id.max(req.id + 1);
        }
        let id = req.id;
        let now = self.clock.now_ms();
        self.events.push_back((id, GenerationEvent::Queued));
        if self.recorder.enabled() {
            self.recorder.record(Span::new("queued", id, now, 0.0)
                .arg("queue_depth", self.queue.len() as f64)
                .arg("prompt_len", req.prompt.len() as f64));
        }
        self.queue.push_back(req, now);
        Ok(id)
    }

    /// Legacy unchecked submit (benches, compatibility shims).  Panics on
    /// rejection — use [`Self::try_submit`] for typed admission control.
    pub fn submit(&mut self, req: Request) -> u64 {
        self.try_submit(req).expect("submit rejected; use try_submit")
    }

    /// Cancel a request by id, queued or mid-flight.  An active slot's
    /// cache pages return to the pool immediately; the request's stream
    /// terminates with `Finished { reason: Cancelled }`.  Returns false
    /// if the id is unknown or already terminal.
    pub fn cancel(&mut self, id: u64) -> bool {
        if let Some((req, enq)) = self.queue.remove_by_id(id) {
            let now = self.clock.now_ms();
            self.emit_finish(id, req.tier, FinishReason::Cancelled, RequestStats {
                prompt_len: req.prompt.len(),
                generated: 0,
                ttft_ms: 0.0,
                decode_ms: 0.0,
                queued_ms: now - enq,
                session: session_id(&req),
            });
            return true;
        }
        // mid-prefill cancellation: a chunked suffix job retires between
        // chunks, grafted refs and allocated pages freed immediately
        for i in 0..self.prefill_jobs.len() {
            let hit = self.prefill_jobs[i].as_ref()
                .is_some_and(|j| j.req.id == id);
            if hit {
                let mut job = self.prefill_jobs[i].take().unwrap();
                let _own = crate::audit::owner(|| format!("seq:{id}"));
                let stats = job.stats(self.clock.now_ms());
                job.cache.free(&mut self.pool);
                self.emit_finish(id, job.req.tier, FinishReason::Cancelled,
                                 stats);
                return true;
            }
        }
        for i in 0..self.slots.len() {
            let hit = self.slots[i].as_ref().is_some_and(|s| s.req.id == id);
            if hit {
                let mut slot = self.slots[i].take().unwrap();
                let _own = crate::audit::owner(|| format!("seq:{id}"));
                let stats = slot.stats(self.clock.now_ms());
                slot.cache.free(&mut self.pool);
                self.emit_finish(id, slot.req.tier, FinishReason::Cancelled,
                                 stats);
                return true;
            }
        }
        false
    }

    /// Terminate every queued and active request with `Failed` (used when
    /// a tick-level error poisons the whole batch, e.g. the decode graph
    /// dying).  All cache pages return to the pool.
    pub fn fail_all(&mut self, error: &str) {
        while let Some((req, _)) = self.queue.pop_any() {
            self.stats.failed += 1;
            self.events.push_back((req.id, GenerationEvent::Failed {
                error: error.to_string(),
            }));
        }
        for i in 0..self.prefill_jobs.len() {
            if let Some(mut job) = self.prefill_jobs[i].take() {
                job.cache.free(&mut self.pool);
                self.stats.failed += 1;
                self.events.push_back((job.req.id, GenerationEvent::Failed {
                    error: error.to_string(),
                }));
            }
        }
        for i in 0..self.slots.len() {
            if let Some(mut slot) = self.slots[i].take() {
                slot.cache.free(&mut self.pool);
                self.stats.failed += 1;
                self.events.push_back((slot.req.id, GenerationEvent::Failed {
                    error: error.to_string(),
                }));
            }
        }
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.active_slot_count() + self.prefill_jobs_active()
    }

    /// Prefix-hit admissions whose suffix is still chunk-prefilling
    /// (each occupies a slot but has not emitted `Started` yet).
    pub fn prefill_jobs_active(&self) -> usize {
        self.prefill_jobs.iter().filter(|j| j.is_some()).count()
    }

    /// Requests waiting for admission (the router's primary load signal).
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Slots currently generating.
    pub fn active_slot_count(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Page-pool occupancy snapshot (routing + metrics).
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// Prefix-cache counters and pinned-page gauge.
    pub fn prefix_stats(&self) -> PrefixStats {
        self.prefix.stats()
    }

    /// Drop every prefix-cache entry, releasing the trie's page
    /// references (pages still grafted by live sequences stay allocated
    /// until those sequences finish).
    pub fn clear_prefix_cache(&mut self) {
        self.prefix.clear(&mut self.pool);
    }

    /// Reconfigure the prefix-cache page budget (0 disables).  Existing
    /// entries are flushed and counters restart.  The fp16 baseline
    /// keeps its authoritative K/V in dense staging, not pages, so the
    /// cache stays disabled there regardless of the budget.
    pub fn set_prefix_cache_pages(&mut self, pages: usize) {
        self.prefix.clear(&mut self.pool);
        let budget = if self.runner.spec.kv_is_fp() { 0 } else { pages };
        self.prefix = PrefixCache::new(self.tokens_per_page,
                                       self.runner.cfg.n_layers, budget);
    }

    /// Page granularity of the KV store (tokens per page).
    pub fn tokens_per_page(&self) -> usize {
        self.tokens_per_page
    }

    /// Cap the number of live sessions (`serve --sessions N`; 0 disables
    /// the subsystem — `chat` requests run as plain one-shots).  Sessions
    /// over the new budget are evicted LRU-first and their pinned trie
    /// chains released immediately.
    pub fn set_session_budget(&mut self, max_sessions: usize) {
        for e in self.sessions.set_budget(max_sessions) {
            if let Some(chain) = e.pinned {
                self.prefix.unpin_chain(e.tier, &chain);
            }
        }
    }

    /// Evict sessions idle longer than `ttl_ms` (lazily, at the next
    /// submit); `None` disables TTL eviction.
    pub fn set_session_ttl_ms(&mut self, ttl_ms: Option<u64>) {
        self.sessions.set_ttl_ms(ttl_ms);
    }

    /// Partition the session-id space (`start + k·stride`) — the cluster
    /// gives each shard a disjoint residue class so session ids are
    /// unique across shards and the router can learn id → shard.
    pub fn set_session_id_space(&mut self, start: u64, stride: u64) {
        self.sessions.set_id_space(start, stride);
    }

    /// Live conversations (the `sessions_live` gauge).
    pub fn sessions_live(&self) -> usize {
        self.sessions.live()
    }

    /// Drain the undelivered lifecycle events, in emission order.
    pub fn take_events(&mut self) -> Vec<(u64, GenerationEvent)> {
        self.events.drain(..).collect()
    }

    pub fn has_events(&self) -> bool {
        !self.events.is_empty()
    }

    /// Cache width for one sequence: its tier's bits, except on the
    /// fp16 baseline where the paged cache is a staging mirror and
    /// always uses the 8-bit codec.
    fn cache_bits_for(&self, tier: QualityTier) -> u32 {
        if self.runner.spec.kv_is_fp() { 8 } else { tier.kv_bits() }
    }

    fn emit_finish(&mut self, id: u64, tier: QualityTier,
                   reason: FinishReason, stats: RequestStats) {
        match reason {
            FinishReason::Cancelled => self.stats.cancelled += 1,
            FinishReason::DeadlineExceeded => self.stats.deadline_exceeded += 1,
            _ => {
                self.stats.completed += 1;
                match tier {
                    QualityTier::Kv4 => self.stats.kv4_completed += 1,
                    QualityTier::Kv8 => self.stats.kv8_completed += 1,
                }
            }
        }
        if self.recorder.enabled() {
            let name = match reason {
                FinishReason::Stop => "finish:stop",
                FinishReason::MaxTokens => "finish:max_tokens",
                FinishReason::CacheFull => "finish:cache_full",
                FinishReason::Cancelled => "finish:cancelled",
                FinishReason::DeadlineExceeded => "finish:deadline",
            };
            let now = self.clock.now_ms();
            self.recorder.record(Span::new(name, id, now, 0.0)
                .arg("generated", stats.generated as f64));
        }
        self.events.push_back((id, GenerationEvent::Finished { reason, stats }));
    }

    /// Retire every request whose deadline has lapsed: queued ones are
    /// removed before they ever prefill; active slots release their KV
    /// pages immediately (same path as cancellation).  Runs at the top of
    /// every tick, so enforcement is mid-stream at tick granularity.
    fn expire_deadlines(&mut self) {
        let now = self.clock.now_ms();
        if self.queue.has_deadlines() {
            for (req, enq) in self.queue.take_expired(now) {
                self.emit_finish(req.id, req.tier,
                                 FinishReason::DeadlineExceeded,
                                 RequestStats {
                                     prompt_len: req.prompt.len(),
                                     generated: 0,
                                     ttft_ms: 0.0,
                                     decode_ms: 0.0,
                                     queued_ms: now - enq,
                                     session: session_id(&req),
                                 });
            }
        }
        // mid-prefill enforcement: a long-prompt request whose deadline
        // lapses between chunks retires here, before its next chunk ever
        // runs, with every grafted and allocated page freed
        for i in 0..self.prefill_jobs.len() {
            let expired = self.prefill_jobs[i].as_ref()
                .is_some_and(|j| deadline_expired(&j.req, j.enqueued_ms, now));
            if expired {
                let mut job = self.prefill_jobs[i].take().unwrap();
                let _own = crate::audit::owner(
                    || format!("seq:{}", job.req.id));
                let stats = job.stats(now);
                job.cache.free(&mut self.pool);
                self.emit_finish(job.req.id, job.req.tier,
                                 FinishReason::DeadlineExceeded, stats);
            }
        }
        for i in 0..self.slots.len() {
            let expired = self.slots[i].as_ref()
                .is_some_and(|s| deadline_expired(&s.req, s.enqueued_ms, now));
            if expired {
                let mut slot = self.slots[i].take().unwrap();
                let _own = crate::audit::owner(
                    || format!("seq:{}", slot.req.id));
                let stats = slot.stats(now);
                slot.cache.free(&mut self.pool);
                self.emit_finish(slot.req.id, slot.req.tier,
                                 FinishReason::DeadlineExceeded, stats);
            }
        }
    }

    /// Admit queued requests into free slots, consulting the shared
    /// prefix cache first: a page-aligned cached prefix is grafted
    /// (read-only, refcounted) and only the uncached suffix runs a
    /// forward pass; a miss takes the cold full-prefill path.
    ///
    /// A request can terminate *at admission* — sampled first token hits
    /// the stop token, `max_new_tokens == 1`, or prefill fails — in
    /// which case the slot stays free (the cold path never touched the
    /// page pool; the hit path frees everything it grafted) and the next
    /// queued request is pulled immediately.
    fn admit(&mut self) -> Result<()> {
        'slots: for slot_idx in 0..self.slots.len() {
            if self.slots[slot_idx].is_some()
                || self.prefill_jobs[slot_idx].is_some()
            {
                continue;
            }
            loop {
                let cfg = self.runner.cfg.clone();
                let fp = self.runner.spec.kv_is_fp();
                let mut shared: Vec<PageGroup> = Vec::new();
                if !fp {
                    // Prefix consult + page-admission check on the
                    // *scheduled-next* request, before it is popped: one
                    // that can NEVER fit (needs more pages than the
                    // whole pool) fails fast — it must not stall the
                    // queue behind it until every in-flight request
                    // drains.  One that merely can't fit *right now*
                    // first reclaims idle prefix-cache pages, then holds
                    // admission with the scheduler state untouched, so
                    // it keeps head-of-line priority and the other class
                    // cannot leapfrog it to the freed pages.
                    let Some((head, _)) = self.queue.peek() else {
                        break 'slots;
                    };
                    let (plen, head_max_new) =
                        (head.prompt.len(), head.max_new_tokens);
                    // longest cached prefix, page-granular; at least one
                    // suffix token stays uncached — its forward pass
                    // produces the first-token logits
                    let max_groups =
                        plen.saturating_sub(1) / self.tokens_per_page;
                    shared = self.prefix.lookup(head.tier, &head.prompt,
                                                max_groups);
                    let full_need = admission_pages(
                        plen, head_max_new, cfg.n_layers,
                        self.tokens_per_page, 0);
                    let need = admission_pages(
                        plen, head_max_new, cfg.n_layers,
                        self.tokens_per_page, shared.len());
                    if full_need > self.pool.capacity() {
                        let (req, _enq) = self.queue.pop().unwrap();
                        self.prefix.record_use(0);
                        self.stats.failed += 1;
                        self.events.push_back((req.id, GenerationEvent::Failed {
                            error: format!(
                                "prompt needs {full_need} KV pages but the pool \
                                 only holds {}", self.pool.capacity()),
                        }));
                        continue;
                    }
                    if need > self.pool.available() {
                        self.prefix.evict_for(&mut self.pool, need);
                        if need > self.pool.available() {
                            break 'slots;
                        }
                    }
                }
                let Some((req, enq)) = self.queue.pop() else {
                    break 'slots;
                };
                // admission queue wait: enqueue → this pop, on the
                // engine clock (feeds the queue-wait histogram at the
                // Started emission below)
                let wait_ms = self.clock.now_ms() - enq;
                // ledger owner for every page this admission touches
                // (graft retains, prefill allocs, terminal frees)
                let _own = crate::audit::owner(|| format!("seq:{}", req.id));
                if !fp {
                    self.prefix.record_use(shared.len());
                }
                // Donation-savings gauge: on a resumed turn the grafted
                // prefix is conversation history an earlier turn of this
                // session donated — every grafted token is prefill the
                // turn did not pay for.
                if !shared.is_empty() {
                    if let Some(sid) = session_id(&req) {
                        if self.sessions.prior_turns(sid) > 0 {
                            self.stats.session_prefill_tokens_saved +=
                                shared.len() * self.tokens_per_page;
                        }
                    }
                }
                // A prompt the staging/cache geometry cannot hold at all
                // fails fast (real configs have cache_seq >= max_seq, so
                // this only guards pathological configurations).
                if req.prompt.len() > cfg.cache_seq {
                    self.stats.failed += 1;
                    self.events.push_back((req.id, GenerationEvent::Failed {
                        error: format!("prompt ({} tokens) exceeds cache_seq {}",
                                       req.prompt.len(), cfg.cache_seq),
                    }));
                    continue;
                }

                if !shared.is_empty() {
                    // ---- prefix-hit path: graft the shared pages
                    // (retained, read-only) and hand the uncached suffix
                    // to a chunked-prefill job.  The tick advances the
                    // job alongside live decode slots under the shared
                    // token budget, so a long suffix no longer
                    // monopolises admission, and the request can retire
                    // mid-prefill on deadline or cancel.  `Started` and
                    // the first token are emitted when the job's final
                    // chunk lands ([`Self::finish_prefill_job`]).
                    let mut cache = SeqCache::new(&cfg,
                                                  self.cache_bits_for(req.tier),
                                                  self.runner.spec.kv_clip,
                                                  self.tokens_per_page);
                    cache.graft_prefix(&mut self.pool, &shared);
                    // Tail continuation: a retired turn's partially-
                    // filled last page copies in (never shared — the
                    // sequence keeps appending into it).  Failure just
                    // leaves the tokens to the suffix prefill.
                    if let Some((tg, tlen)) = self.prefix.lookup_tail(
                        req.tier, &req.prompt, shared.len())
                    {
                        if cache.graft_partial_tail(&mut self.pool, &tg,
                                                    tlen).is_ok()
                        {
                            if let Some(sid) = session_id(&req) {
                                if self.sessions.prior_turns(sid) > 0 {
                                    self.stats.session_prefill_tokens_saved
                                        += tlen;
                                }
                            }
                        }
                    }
                    debug_assert!(cache.len < req.prompt.len(),
                                  "at least one suffix token must stay \
                                   uncached");
                    self.load_slot_staging(slot_idx, &cache);
                    self.prefill_jobs[slot_idx] = Some(PrefillJob {
                        graft_tokens: cache.len,
                        pf_ms: 0.0,
                        enqueued_ms: enq,
                        wait_ms,
                        req,
                        cache,
                    });
                    break;
                }

                // ---- cold path: full prefill ----
                let pf_start = self.clock.now_ms();
                let t0 = Instant::now();
                let pre = match self.runner.prefill(&req.prompt) {
                    Ok(p) => p,
                    Err(e) => {
                        self.stats.failed += 1;
                        self.events.push_back((req.id, GenerationEvent::Failed {
                            error: format!("prefill failed: {e:#}"),
                        }));
                        continue;
                    }
                };
                let pf_ms = t0.elapsed().as_secs_f64() * 1e3;
                self.stats.total_prefill_ms += pf_ms;
                if self.recorder.enabled() {
                    self.recorder.record(
                        Span::new("prefill", req.id, pf_start, pf_ms)
                            .arg("suffix_tokens", req.prompt.len() as f64)
                            .arg("graft_tokens", 0.0));
                }

                // Sample the first token from the prefill logits *before*
                // building any cache state: a request that ends here (stop
                // token, one-token budget) never touches the page pool or
                // the staging buffers at all.
                let v = cfg.vocab;
                let last = &pre.logits[(pre.len - 1) * v..pre.len * v];
                let first_tok = sample(last, req.sampling, &mut self.rng) as u16;
                let now = self.clock.now_ms();
                let ttft = now - enq;
                self.stats.ttft_sum_ms += ttft;
                self.stats.ttft_count += 1;
                self.stats.ttft_hist.record(ttft);
                self.stats.queue_wait_hist.record(wait_ms);
                if self.recorder.enabled() {
                    self.recorder.record(
                        Span::new("admitted", req.id, enq, wait_ms)
                            .arg("graft_tokens", 0.0)
                            .arg("prompt_len", req.prompt.len() as f64));
                }
                self.events.push_back((req.id, GenerationEvent::Started {
                    ttft_ms: ttft,
                }));
                self.events.push_back((req.id, GenerationEvent::Token {
                    token: first_tok, index: 0,
                }));
                let hit_stop = req.stop_token == Some(first_tok);
                let budget_done = req.max_new_tokens <= 1;
                if hit_stop || budget_done {
                    // no cache exists yet on this path — the turn is
                    // remembered but nothing can be donated; the next
                    // resume re-prefills it (correct, just cold)
                    self.complete_session_turn(&req, &[first_tok], None);
                    let reason = if hit_stop {
                        FinishReason::Stop
                    } else {
                        FinishReason::MaxTokens
                    };
                    self.emit_finish(req.id, req.tier, reason, RequestStats {
                        prompt_len: req.prompt.len(),
                        generated: 1,
                        ttft_ms: ttft,
                        decode_ms: 0.0,
                        queued_ms: self.clock.now_ms() - enq,
                        session: session_id(&req),
                    });
                    continue; // slot is still free — pull the next request
                }
                // Near-capacity prompts (len + 2 >= cache_seq) still admit:
                // the decode tick retires them after sampling, *before* any
                // append, so one decode step is always safe — matching the
                // pre-event engine's behavior exactly.

                let mut cache = SeqCache::new(&cfg,
                                              self.cache_bits_for(req.tier),
                                              self.runner.spec.kv_clip,
                                              self.tokens_per_page);
                if fp {
                    // fp16-baseline: authoritative values live in the f32
                    // staging
                    let (l_n, b, s, d) = (cfg.n_layers, cfg.decode_batch,
                                          cfg.cache_seq, cfg.d_kv());
                    for l in 0..l_n {
                        for t in 0..pre.len {
                            let src = (l * pre.len + t) * d;
                            let dst = ((l * b + slot_idx) * s + t) * d;
                            self.staging.k_f32[dst..dst + d]
                                .copy_from_slice(&pre.ks[src..src + d]);
                            self.staging.v_f32[dst..dst + d]
                                .copy_from_slice(&pre.vs[src..src + d]);
                        }
                    }
                    cache.set_len(pre.len);
                } else {
                    if let Err(e) = cache.init_from_prefill(
                        &mut self.pool, &pre.ks, &pre.vs, pre.len, cfg.kv_group)
                    {
                        cache.free(&mut self.pool);
                        self.stats.failed += 1;
                        self.events.push_back((req.id, GenerationEvent::Failed {
                            error: format!("cache init failed: {e:#}"),
                        }));
                        continue;
                    }
                    // also write the dense staging region for this slot
                    self.load_slot_staging(slot_idx, &cache);
                    // cold prefills seed the shared prefix cache: donate
                    // the prompt's full pages (retained by the trie, so
                    // they outlive this request)
                    self.donate_prompt_pages(&req.prompt, &cache, req.tier);
                }

                self.slots[slot_idx] = Some(Slot {
                    generated: vec![first_tok],
                    next_token: first_tok,
                    enqueued_ms: enq,
                    started_ms: now,
                    last_token_ms: now,
                    ttft_ms: ttft,
                    req,
                    cache,
                });
                break;
            }
        }
        Ok(())
    }

    /// Advance every in-flight chunked-prefill job under the shared tick
    /// budget.  Of the `prefill_chunk` prefill-token budget, each active
    /// decode slot reserves one token (decode keeps advancing every tick
    /// regardless of prefill load), and the remainder is split evenly
    /// across jobs — but a job always gets at least one token, so
    /// prefill can never be starved either.  A lone job on an otherwise
    /// idle engine therefore processes `prefill_chunk` tokens per tick:
    /// an S-token suffix completes in `ceil(S / prefill_chunk)` ticks,
    /// not S.
    fn advance_prefill_jobs(&mut self) {
        let n_jobs = self.prefill_jobs_active();
        if n_jobs == 0 {
            return;
        }
        let decoding = self.slots.iter().filter(|s| s.is_some()).count();
        let spare = self.prefill_chunk.saturating_sub(decoding);
        let per_job = (spare / n_jobs).max(1);
        for idx in 0..self.prefill_jobs.len() {
            if self.prefill_jobs[idx].is_some() {
                self.advance_prefill_job(idx, per_job);
            }
        }
    }

    /// Run up to `quota` suffix tokens of the job in `slot_idx` through
    /// one [`Runner::prefill_chunk`] call — the executor computes them at
    /// their true positions against the slot's staging lane (attending
    /// over the grafted prefix) and quantizes their K/V into the lane as
    /// it goes — then append the chunk's raw K/V to the job's paged
    /// cache.  When the chunk finishes the prompt, the job is promoted to
    /// a live slot and joins the same tick's decode batch.  Any failure
    /// frees the cache (grafted refs included) and retires the request
    /// with `Failed`; concurrent slots are untouched.
    fn advance_prefill_job(&mut self, slot_idx: usize, quota: usize) {
        let mut job = self.prefill_jobs[slot_idx].take().unwrap();
        let id = job.req.id;
        let _own = crate::audit::owner(|| format!("seq:{id}"));
        let cfg = self.runner.cfg.clone();
        let remaining = job.req.prompt.len() - job.cache.len;
        let take = quota.min(remaining);
        let chunk = job.req.prompt[job.cache.len..job.cache.len + take].to_vec();
        let start_pos = job.cache.len;
        let bits = self.cache_bits_for(job.req.tier);
        let pf_start = self.clock.now_ms();
        let t0 = Instant::now();
        let res = self.runner.prefill_chunk(&chunk, start_pos, slot_idx, bits,
                                            &mut self.staging);
        let pf_ms = t0.elapsed().as_secs_f64() * 1e3;
        self.stats.total_prefill_ms += pf_ms;
        job.pf_ms += pf_ms;
        let res = match res {
            Ok(r) => r,
            Err(e) => {
                job.cache.free(&mut self.pool);
                self.stats.failed += 1;
                self.events.push_back((id, GenerationEvent::Failed {
                    error: format!("suffix prefill failed: {e:#}"),
                }));
                return;
            }
        };
        // Append the chunk's raw K/V token-major: chunk position j of
        // layer l lives at (l·T + j)·d in the `[L][T][d_kv]` slabs.  The
        // pool reservation is all-or-nothing per token (admission sized
        // the pool for the whole suffix, so exhaustion here means that
        // estimate was broken).
        let d = cfg.d_kv();
        for j in 0..take {
            if self.pool.available() < job.cache.pages_needed_for_append() {
                job.cache.free(&mut self.pool);
                self.stats.failed += 1;
                self.events.push_back((id, GenerationEvent::Failed {
                    error: "KV page pool exhausted during suffix prefill"
                        .to_string(),
                }));
                return;
            }
            for l in 0..cfg.n_layers {
                let o = (l * take + j) * d;
                if let Err(e) = job.cache.append_layer(
                    &mut self.pool, l, &res.k[o..o + d], &res.v[o..o + d],
                    cfg.kv_group)
                {
                    job.cache.free(&mut self.pool);
                    self.stats.failed += 1;
                    self.events.push_back((id, GenerationEvent::Failed {
                        error: format!("suffix prefill failed: {e:#}"),
                    }));
                    return;
                }
            }
            job.cache.bump();
        }
        self.stats.suffix_prefill_tokens += take;
        self.stats.prefill_chunks += 1;
        self.stats.prefill_chunk_tokens += take;
        if self.recorder.enabled() {
            self.recorder.record(
                Span::new("prefill.chunk", id, pf_start, pf_ms)
                    .arg("tokens", take as f64)
                    .arg("pos", start_pos as f64));
        }
        if job.cache.len < job.req.prompt.len() {
            self.prefill_jobs[slot_idx] = Some(job);
            return;
        }
        let v = cfg.vocab;
        let first_logits = res.logits[(take - 1) * v..take * v].to_vec();
        self.finish_prefill_job(slot_idx, job, first_logits);
    }

    /// A job's final chunk just landed: sample the first token off the
    /// last chunk row's logits (the same distribution the cold path reads
    /// off the prefill output's last prompt position), emit the admission
    /// telemetry, and either retire at admission (stop token, one-token
    /// budget) or install the live slot.
    fn finish_prefill_job(&mut self, slot_idx: usize, job: PrefillJob,
                          first_logits: Vec<f32>) {
        let PrefillJob { req, mut cache, graft_tokens, pf_ms,
                         enqueued_ms: enq, wait_ms } = job;
        if self.recorder.enabled() {
            let end = self.clock.now_ms();
            self.recorder.record(
                Span::new("prefill", req.id, end - pf_ms, pf_ms)
                    .arg("suffix_tokens",
                         (req.prompt.len() - graft_tokens) as f64)
                    .arg("graft_tokens", graft_tokens as f64));
        }
        let first_tok = sample(&first_logits, req.sampling,
                               &mut self.rng) as u16;
        let now = self.clock.now_ms();
        let ttft = now - enq;
        self.stats.ttft_sum_ms += ttft;
        self.stats.ttft_count += 1;
        self.stats.ttft_hist.record(ttft);
        self.stats.queue_wait_hist.record(wait_ms);
        if self.recorder.enabled() {
            self.recorder.record(
                Span::new("admitted", req.id, enq, wait_ms)
                    .arg("graft_tokens", graft_tokens as f64)
                    .arg("prompt_len", req.prompt.len() as f64));
        }
        self.events.push_back((req.id, GenerationEvent::Started {
            ttft_ms: ttft,
        }));
        self.events.push_back((req.id, GenerationEvent::Token {
            token: first_tok, index: 0,
        }));
        let hit_stop = req.stop_token == Some(first_tok);
        if hit_stop || req.max_new_tokens <= 1 {
            // admission-terminal: the cache covers exactly the prompt, so
            // the session donation matches the non-terminal path; free it
            // (grafted refs included) — the slot stays open
            self.complete_session_turn(&req, &[first_tok], Some(&cache));
            cache.free(&mut self.pool);
            let reason = if hit_stop {
                FinishReason::Stop
            } else {
                FinishReason::MaxTokens
            };
            let stats = RequestStats {
                prompt_len: req.prompt.len(),
                generated: 1,
                ttft_ms: ttft,
                decode_ms: 0.0,
                queued_ms: self.clock.now_ms() - enq,
                session: session_id(&req),
            };
            self.emit_finish(req.id, req.tier, reason, stats);
            return;
        }
        self.donate_prompt_pages(&req.prompt, &cache, req.tier);
        self.slots[slot_idx] = Some(Slot {
            generated: vec![first_tok],
            next_token: first_tok,
            enqueued_ms: enq,
            started_ms: now,
            last_token_ms: now,
            ttft_ms: ttft,
            req,
            cache,
        });
    }

    /// Donate a freshly admitted cache's full prompt pages to the
    /// prefix trie — prompt content recurs across unrelated requests, so
    /// cold prefills seed the cache eagerly, before a single token is
    /// generated.
    fn donate_prompt_pages(&mut self, prompt: &[u16], cache: &SeqCache,
                           tier: QualityTier) {
        self.donate_chain_pages(prompt, cache, tier);
    }

    /// Donate the full pages of a token chain resident in `cache` to the
    /// prefix trie (no-op when the cache is disabled or the chain is
    /// shorter than one page); returns the donated token count
    /// (`⌊len/tpp⌋·tpp`).  `tokens` must be a prefix of the cache's
    /// contents — the prompt at admission, or `prompt ++ generated` at a
    /// sessioned request's retirement (generated-token donation is what
    /// lets the next turn graft the whole conversation).  Donations carry
    /// the donor's precision tier: pages hold tier-width codes, so a
    /// graft across tiers would silently misdecode (the trie keys by
    /// tier to make that impossible).
    fn donate_chain_pages(&mut self, tokens: &[u16], cache: &SeqCache,
                          tier: QualityTier) -> usize {
        let tpp = self.tokens_per_page;
        let full = tokens.len() / tpp;
        if full == 0 || !self.prefix.enabled() {
            return 0;
        }
        let groups: Vec<PageGroup> =
            (0..full).map(|i| cache.page_group(i)).collect();
        self.prefix.insert(&mut self.pool, tier, &tokens[..full * tpp],
                           &groups);
        full * tpp
    }

    /// Retire one conversation turn into its session: donate the chain
    /// actually resident in the cache (`prompt ++ generated` minus the
    /// final sampled-but-never-appended token), record the full reply in
    /// the session history, and move the session's trie pin to the new,
    /// longer chain so it survives LRU eviction until the next turn.
    /// Only natural retirements reach here — cancelled / expired / failed
    /// turns are not remembered.  `cache: None` (cold admission-terminal
    /// path) records history without donating.
    fn complete_session_turn(&mut self, req: &Request, generated: &[u16],
                             cache: Option<&SeqCache>) {
        let Some(sid) = session_id(req) else { return };
        let _own = crate::audit::owner(|| format!("session:{sid}"));
        let don_start = self.clock.now_ms();
        let mut chain =
            Vec::with_capacity(req.prompt.len() + generated.len());
        chain.extend_from_slice(&req.prompt);
        chain.extend_from_slice(generated);
        let donated = match cache {
            Some(c) => {
                let cached = c.len.min(chain.len());
                let mut donated =
                    self.donate_chain_pages(&chain[..cached], c, req.tier);
                // The partially-filled last page goes in too: the next
                // turn copies it instead of re-prefilling the sub-page
                // remainder, making donation savings token-exact.
                if cached == c.len && self.prefix.enabled() {
                    if let Some((tg, tlen)) = c.tail_page_group() {
                        self.prefix.insert_tail(&mut self.pool, req.tier,
                                                &chain[..cached], &tg);
                        donated += tlen;
                    }
                }
                donated
            }
            None => 0,
        };
        if self.recorder.enabled() {
            let dur = self.clock.now_ms() - don_start;
            self.recorder.record(
                Span::new("session.donate", req.id, don_start, dur)
                    .arg("donated_tokens", donated as f64));
        }
        let donated_chain = (donated > 0).then(|| chain[..donated].to_vec());
        if let Some(upd) = self.sessions.complete(sid, chain, donated_chain) {
            if let Some(pin) = upd.pin {
                self.prefix.pin_chain(upd.tier, &pin);
            }
            if let Some(unpin) = upd.unpin {
                self.prefix.unpin_chain(upd.tier, &unpin);
            }
        }
        self.stats.session_turns += 1;
    }

    /// Refresh the whole dense staging view of one slot from its pages.
    /// The token gather is page-granular, but the fp-baseline dequant runs
    /// as ONE backend `kv_dequant` per (layer, K/V) over the slot's whole
    /// contiguous staging region instead of a per-token call.
    fn load_slot_staging(&mut self, slot: usize, cache: &SeqCache) {
        let cfg = self.runner.cfg.clone();
        let (l_n, b, s) = (cfg.n_layers, cfg.decode_batch, cfg.cache_seq);
        let d = cfg.d_kv();
        let ng = d / cfg.kv_group;
        let n = cache.len;
        let fp = self.runner.spec.kv_is_fp();
        let backend = self.backend.clone();
        let mut codes = vec![0i8; n * d];
        let mut scales = vec![0.0f32; n * ng];
        let mut zeros = vec![0.0f32; n * ng];
        for l in 0..l_n {
            for (want_v, which) in [(false, 0), (true, 1)] {
                for t in 0..n {
                    cache.read_token(&self.pool, l, t, want_v,
                                     &mut codes[t * d..(t + 1) * d],
                                     &mut scales[t * ng..(t + 1) * ng],
                                     &mut zeros[t * ng..(t + 1) * ng]);
                }
                // tokens 0..n of one (layer, slot) are contiguous in staging
                let co = (l * b + slot) * s * d;
                let go = (l * b + slot) * s * ng;
                if fp {
                    let dst = if which == 0 { &mut self.staging.k_f32 }
                              else { &mut self.staging.v_f32 };
                    backend.kv_dequant(&codes, &scales, &zeros, cfg.kv_group,
                                       &mut dst[co..co + n * d]);
                } else {
                    let (dst_c, dst_s, dst_z) = if which == 0 {
                        (&mut self.staging.k_codes, &mut self.staging.k_scale,
                         &mut self.staging.k_zero)
                    } else {
                        (&mut self.staging.v_codes, &mut self.staging.v_scale,
                         &mut self.staging.v_zero)
                    };
                    dst_c[co..co + n * d].copy_from_slice(&codes);
                    dst_s[go..go + n * ng].copy_from_slice(&scales);
                    dst_z[go..go + n * ng].copy_from_slice(&zeros);
                }
            }
        }
    }

    /// Append one token's K/V into the authoritative store of one slot:
    /// the dense staging view for the fp16 baseline, the packed pages
    /// otherwise.  Paged slots get their staging write-through afterwards,
    /// batched over all active slots, in [`Self::refresh_staging_for`].
    fn append_to_cache(&mut self, slot: usize, k_new: &[f32], v_new: &[f32])
                       -> Result<()> {
        let cfg = self.runner.cfg.clone();
        let (l_n, b, s) = (cfg.n_layers, cfg.decode_batch, cfg.cache_seq);
        let d = cfg.d_kv();
        let fp = self.runner.spec.kv_is_fp();
        if fp {
            let sl = self.slots[slot].as_mut().unwrap();
            let t = sl.cache.len;
            for l in 0..l_n {
                let src = (l * b + slot) * d;
                let dst = ((l * b + slot) * s + t) * d;
                self.staging.k_f32[dst..dst + d]
                    .copy_from_slice(&k_new[src..src + d]);
                self.staging.v_f32[dst..dst + d]
                    .copy_from_slice(&v_new[src..src + d]);
            }
            sl.cache.bump();
            return Ok(());
        }
        let sl = self.slots[slot].as_mut().unwrap();
        // all-or-nothing across the per-layer loop: reserve the whole
        // token's pages up front so an exhausted pool cannot leave some
        // layers one token longer than others (with shared refcounted
        // pages that skew would be silent cross-request corruption)
        let need = sl.cache.pages_needed_for_append();
        if self.pool.available() < need {
            // reclaim idle prefix-cache pages before failing a live
            // request — the trie's pins (up to half the pool by default)
            // are the one revocable page source, and admission already
            // does the same for queued requests
            self.prefix.evict_for(&mut self.pool, need);
        }
        if self.pool.available() < need {
            bail!("KV page pool exhausted (append needs {need} pages, \
                   {} free)", self.pool.available());
        }
        for l in 0..l_n {
            let o = (l * b + slot) * d;
            sl.cache.append_layer(&mut self.pool, l, &k_new[o..o + d],
                                  &v_new[o..o + d], cfg.kv_group)?;
        }
        sl.cache.bump();
        Ok(())
    }

    /// Staging write-through for the just-appended token of every slot in
    /// `active` (paged caches only): read back the quantized token so the
    /// dense view is bit-identical to the authoritative pages.  This is
    /// the decode tick's per-batch-slot fan-out — slots are independent
    /// and write disjoint staging regions, so the backend may run them in
    /// parallel ([`ComputeBackend::par_for`]).
    fn refresh_staging_for(&mut self, active: &[usize]) {
        let cfg = self.runner.cfg.clone();
        let (l_n, b, s) = (cfg.n_layers, cfg.decode_batch, cfg.cache_seq);
        let d = cfg.d_kv();
        let ng = d / cfg.kv_group;
        let backend = self.backend.clone();
        let pool = &self.pool;
        let slots = &self.slots;
        let kc = SendPtr::new(self.staging.k_codes.as_mut_ptr());
        let ks = SendPtr::new(self.staging.k_scale.as_mut_ptr());
        let kz = SendPtr::new(self.staging.k_zero.as_mut_ptr());
        let vc = SendPtr::new(self.staging.v_codes.as_mut_ptr());
        let vs = SendPtr::new(self.staging.v_scale.as_mut_ptr());
        let vz = SendPtr::new(self.staging.v_zero.as_mut_ptr());
        backend.par_for(active.len(), &|ai| {
            let slot = active[ai];
            let sl = slots[slot].as_ref().unwrap();
            let t = sl.cache.len - 1; // the token appended this tick
            let mut codes = vec![0i8; d];
            let mut scales = vec![0.0f32; ng];
            let mut zeros = vec![0.0f32; ng];
            for l in 0..l_n {
                for want_v in [false, true] {
                    sl.cache.read_token(pool, l, t, want_v,
                                        &mut codes, &mut scales, &mut zeros);
                    let co = ((l * b + slot) * s + t) * d;
                    let go = ((l * b + slot) * s + t) * ng;
                    let (dc, ds, dz) = if want_v { (vc, vs, vz) } else { (kc, ks, kz) };
                    // SAFETY: each active slot owns disjoint staging
                    // regions (indexed by `slot`), and par_for joins
                    // before the buffers are read again.
                    unsafe {
                        std::ptr::copy_nonoverlapping(codes.as_ptr(),
                                                      dc.get().add(co), d);
                        std::ptr::copy_nonoverlapping(scales.as_ptr(),
                                                      ds.get().add(go), ng);
                        std::ptr::copy_nonoverlapping(zeros.as_ptr(),
                                                      dz.get().add(go), ng);
                    }
                }
            }
        });
    }

    /// One engine tick: expire deadlines, admit, batched decode, append,
    /// sample, retire.  Returns number of tokens produced this tick
    /// (events are queued for [`Self::take_events`]).
    pub fn tick(&mut self) -> Result<usize> {
        // lock-order class: the tick body acquires pool/prefix classes
        // beneath it, pinning the engine.tick → coordinator.* ordering
        let _audit = LockScope::enter("engine.tick");
        let tick_t0 = Instant::now();
        let admit_start = self.clock.now_ms();
        self.expire_deadlines();
        self.admit()?;
        if self.recorder.enabled() {
            let dur = self.clock.now_ms() - admit_start;
            self.recorder.record(Span::new("tick.admit", 0, admit_start, dur));
        }
        // chunked suffix prefills advance before the decode step: a job
        // whose final chunk lands this tick installs its slot in time to
        // join this very decode batch (continuous batching, no idle tick)
        self.advance_prefill_jobs();
        let cfg = self.runner.cfg.clone();
        let b = cfg.decode_batch;
        let active: Vec<usize> = (0..b).filter(|&i| self.slots[i].is_some()).collect();
        if active.is_empty() {
            return Ok(0);
        }
        let mut tokens = vec![0i32; b];
        let mut lens = vec![0i32; b];
        for &i in &active {
            let sl = self.slots[i].as_ref().unwrap();
            tokens[i] = sl.next_token as i32;
            lens[i] = sl.cache.len as i32;
        }
        let dec_start = self.clock.now_ms();
        let t0 = Instant::now();
        let (logits, k_new, v_new) = self.runner.decode(&tokens, &lens, &self.staging)?;
        let step_ms = t0.elapsed().as_secs_f64() * 1e3;
        if self.recorder.enabled() {
            self.recorder.record(
                Span::new("tick.decode", 0, dec_start, step_ms)
                    .arg("batch", active.len() as f64));
        }
        self.stats.decode_steps += 1;
        self.stats.decode_tokens += active.len();
        for &i in &active {
            match self.slots[i].as_ref().unwrap().req.tier {
                QualityTier::Kv4 => self.stats.kv4_decode_tokens += 1,
                QualityTier::Kv8 => self.stats.kv8_decode_tokens += 1,
            }
        }
        self.stats.total_decode_ms += step_ms;

        let v = cfg.vocab;
        let mut produced = 0;
        // Phase 1: sample + retire, in slot order (keeps the RNG stream
        // and therefore generations identical to the sequential engine).
        // Finished slots release their pages *before* any appends, so a
        // tight pool can recycle pages within the tick, and a retiring
        // slot's final K/V — which nothing would ever read — is never
        // appended at all.
        let sample_start = self.clock.now_ms();
        let mut survivors: Vec<usize> = Vec::with_capacity(active.len());
        for &i in &active {
            let sl = self.slots[i].as_mut().unwrap();
            let next = sample(&logits[i * v..(i + 1) * v], sl.req.sampling,
                              &mut self.rng) as u16;
            sl.generated.push(next);
            sl.next_token = next;
            produced += 1;
            let id = sl.req.id;
            let index = sl.generated.len() - 1;
            // inter-token latency: every tick token has a predecessor
            // (the first token lands at admission), so record
            // unconditionally against the slot's last-token timestamp
            let itl = sample_start - sl.last_token_ms;
            sl.last_token_ms = sample_start;
            self.stats.itl_hist.record(itl);
            if self.recorder.enabled() {
                self.recorder.record_sampled(
                    Span::new("decode_token", id, dec_start, step_ms)
                        .arg("index", index as f64));
            }
            self.events.push_back((id, GenerationEvent::Token {
                token: next, index,
            }));
            let sl = self.slots[i].as_ref().unwrap();
            let hit_stop = sl.req.stop_token == Some(next);
            // `+ 2` = this tick's append (phase 2) plus the next tick's —
            // the same bound the old post-append `len + 1` check enforced.
            let budget_done = sl.generated.len() >= sl.req.max_new_tokens;
            let cache_full = sl.cache.len + 2 >= cfg.cache_seq;
            if hit_stop || budget_done || cache_full {
                let mut slot = self.slots[i].take().unwrap();
                let _own = crate::audit::owner(|| format!("seq:{id}"));
                let stats = slot.stats(sample_start);
                // generated-token donation: the retiring cache holds
                // `prompt ++ generated[..len-1]` — hand its full pages to
                // the trie (and the session's pin) before freeing, so the
                // next turn of this conversation grafts the whole chain
                self.complete_session_turn(&slot.req, &slot.generated,
                                           Some(&slot.cache));
                slot.cache.free(&mut self.pool);
                let reason = if hit_stop {
                    FinishReason::Stop
                } else if budget_done {
                    FinishReason::MaxTokens
                } else {
                    FinishReason::CacheFull
                };
                self.emit_finish(id, slot.req.tier, reason, stats);
            } else {
                survivors.push(i);
            }
        }
        // Phase 2: append into the authoritative caches (page allocation
        // is shared state — sequential), then fan the staging
        // write-through over batch slots on the compute backend.  An
        // append failure (pool exhausted mid-decode) retires only the
        // offending slot with `Failed` — concurrent requests keep
        // running; freed pages may even unblock them next tick.
        if self.recorder.enabled() {
            let dur = self.clock.now_ms() - sample_start;
            self.recorder.record(Span::new("tick.sample", 0, sample_start, dur)
                .arg("batch", active.len() as f64));
        }
        let append_start = self.clock.now_ms();
        let mut appended: Vec<usize> = Vec::with_capacity(survivors.len());
        for &i in &survivors {
            let Some(rid) = self.slots[i].as_ref().map(|s| s.req.id) else {
                continue;
            };
            let _own = crate::audit::owner(|| format!("seq:{rid}"));
            match self.append_to_cache(i, &k_new, &v_new) {
                Ok(()) => appended.push(i),
                Err(e) => {
                    let mut slot = self.slots[i].take().unwrap();
                    slot.cache.free(&mut self.pool);
                    self.stats.failed += 1;
                    self.events.push_back((slot.req.id, GenerationEvent::Failed {
                        error: format!("KV append failed: {e:#}"),
                    }));
                }
            }
        }
        if !self.runner.spec.kv_is_fp() && !appended.is_empty() {
            self.refresh_staging_for(&appended);
        }
        if self.recorder.enabled() {
            let dur = self.clock.now_ms() - append_start;
            self.recorder.record(
                Span::new("tick.append", 0, append_start, dur)
                    .arg("batch", appended.len() as f64));
        }
        let cache_bytes: usize = self.slots.iter().flatten().map(|s| s.cache.bytes()).sum();
        let fp16_bytes: usize = self.slots.iter().flatten()
            .map(|s| s.cache.fp16_equiv_bytes()).sum();
        self.stats.peak_cache_bytes = self.stats.peak_cache_bytes.max(cache_bytes);
        self.stats.peak_cache_fp16_bytes =
            self.stats.peak_cache_fp16_bytes.max(fp16_bytes);
        self.stats.tick_hist.record(tick_t0.elapsed().as_secs_f64() * 1e3);
        Ok(produced)
    }

    /// Compatibility shim over the event loop: drive until every
    /// submitted request terminates, folding the event stream back into
    /// [`Completion`] records (in retirement order).  Cancelled requests
    /// yield their partial completions; failed ones are dropped.  The
    /// tick sequence is identical to event-API consumption, so outputs
    /// stay byte-identical at a fixed seed.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        let mut partial: HashMap<u64, Vec<u16>> = HashMap::new();
        let mut done = Vec::new();
        loop {
            for (id, ev) in self.take_events() {
                match ev {
                    GenerationEvent::Token { token, .. } => {
                        partial.entry(id).or_default().push(token);
                    }
                    GenerationEvent::Finished { stats, .. } => {
                        done.push(Completion {
                            id,
                            prompt_len: stats.prompt_len,
                            tokens: partial.remove(&id).unwrap_or_default(),
                            ttft_ms: stats.ttft_ms,
                            decode_ms: stats.decode_ms,
                            queued_ms: stats.queued_ms,
                        });
                    }
                    GenerationEvent::Failed { .. } => {
                        partial.remove(&id);
                    }
                    GenerationEvent::Queued | GenerationEvent::Started { .. } => {}
                }
            }
            if self.pending() == 0 && !self.has_events() {
                break;
            }
            self.tick()?;
        }
        Ok(done)
    }

    pub fn pool_in_use(&self) -> usize {
        self.pool.in_use()
    }

    /// `(slot index, current cache length)` of every active slot — the
    /// batch shape [`staged_decode_attention`] consumes.
    pub fn active_slots(&self) -> Vec<(usize, usize)> {
        self.slots.iter().enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|sl| (i, sl.cache.len)))
            .collect()
    }

    /// Native batched paged-decode attention over all active slots for one
    /// layer (see [`staged_decode_attention`]).  `qs`/`out` are
    /// `active × n_heads × d_head`, in [`Self::active_slots`] order.
    ///
    /// NOT on the serving path yet: [`Self::tick`] runs attention inside
    /// the AOT decode graph (which fuses it with the projections, and is
    /// the only place per-layer queries exist today).  This entry is the
    /// bench/test surface and staging-consistency gate; hoisting it into
    /// the tick is the ROADMAP follow-up.
    pub fn decode_attention_native(&self, layer: usize, qs: &[f32],
                                   out: &mut [f32]) {
        let slots = self.active_slots();
        staged_decode_attention(self.backend.as_ref(), &self.runner.cfg,
                                self.runner.spec.kv_is_fp(), &self.staging,
                                layer, &slots, qs, out);
    }
}

/// Pool pages the admission gate must see available before taking a
/// request: every K/V stream page for the prompt *plus one decode-append
/// token of headroom* — a prompt that exactly fills its pages must wait
/// for pages rather than admit and then die on its first append with a
/// spurious `KV append failed` — minus the pages covered by the grafted
/// shared prefix (those are already allocated).  Requests that finish at
/// admission (`max_new_tokens <= 1`) never append, so they need no
/// headroom.
fn admission_pages(prompt_len: usize, max_new_tokens: usize, n_layers: usize,
                   tokens_per_page: usize, shared_groups: usize) -> usize {
    let toks = prompt_len + usize::from(max_new_tokens > 1);
    2 * n_layers
        * toks.div_ceil(tokens_per_page).saturating_sub(shared_groups)
}

/// Native batched paged-decode attention — the rust twin of the decode
/// graph's `Decode` stage (Appendix A.10) over the engine's dense staging
/// slabs, dispatched through the [`ComputeBackend`].
///
/// The serving tick hands the same staging buffers to the AOT decode graph
/// (which fuses this stage with the projections around it); this entry
/// point gives the native backends authority over the identical attention
/// computation, borrowing the per-slot K/V streams straight out of the
/// staging slabs (zero copies — the batcher keeps 4-bit codes unpacked
/// there, which the [`KvCodes::I8`] view consumes directly).
///
/// `slots` is `(slot index, current length)` per sequence (ragged lengths
/// fine, empty caches produce zero output); `qs` and `out` are
/// `slots.len() × n_heads × d_head`.
#[allow(clippy::too_many_arguments)]
pub fn staged_decode_attention(backend: &dyn ComputeBackend, cfg: &ModelConfig,
                               fp: bool, staging: &DecodeStaging, layer: usize,
                               slots: &[(usize, usize)], qs: &[f32],
                               out: &mut [f32]) {
    let (b, s) = (cfg.decode_batch, cfg.cache_seq);
    let (hk, dh, h) = (cfg.n_kv_heads, cfg.d_head, cfg.n_heads);
    let d = cfg.d_kv();
    let ng = d / cfg.kv_group;
    assert!(layer < cfg.n_layers, "layer {layer} out of range");
    assert_eq!(qs.len(), slots.len() * h * dh, "qs shape");
    for &(slot, len) in slots {
        assert!(slot < b && len <= s, "slot ({slot}, {len}) out of range");
    }
    fn f32_view(data: &[f32], base: usize, len: usize, d: usize, hk: usize,
                dh: usize) -> KvF32View<'_> {
        KvF32View {
            n_kv_heads: hk,
            d_head: dh,
            len,
            data: &data[base * d..(base + len) * d],
        }
    }
    #[allow(clippy::too_many_arguments)]
    fn quant_view<'a>(codes: &'a [i8], scales: &'a [f32], zeros: &'a [f32],
                      base: usize, len: usize, d: usize, ng: usize, hk: usize,
                      dh: usize, group: usize) -> KvQuantView<'a> {
        KvQuantView {
            n_kv_heads: hk,
            d_head: dh,
            group,
            len,
            codes: KvCodes::I8(&codes[base * d..(base + len) * d]),
            scales: &scales[base * ng..(base + len) * ng],
            zeros: &zeros[base * ng..(base + len) * ng],
        }
    }
    if fp {
        let seqs: Vec<DecodeF32Seq> = slots.iter().enumerate()
            .map(|(i, &(slot, len))| {
                let base = (layer * b + slot) * s;
                DecodeF32Seq {
                    q: &qs[i * h * dh..(i + 1) * h * dh],
                    k: f32_view(&staging.k_f32, base, len, d, hk, dh),
                    v: f32_view(&staging.v_f32, base, len, d, hk, dh),
                }
            })
            .collect();
        backend.decode_f32_batch(&seqs, h, out);
    } else {
        let seqs: Vec<DecodeQuantSeq> = slots.iter().enumerate()
            .map(|(i, &(slot, len))| {
                let base = (layer * b + slot) * s;
                DecodeQuantSeq {
                    q: &qs[i * h * dh..(i + 1) * h * dh],
                    k: quant_view(&staging.k_codes, &staging.k_scale,
                                  &staging.k_zero, base, len, d, ng, hk, dh,
                                  cfg.kv_group),
                    v: quant_view(&staging.v_codes, &staging.v_scale,
                                  &staging.v_zero, base, len, d, ng, hk, dh,
                                  cfg.kv_group),
                }
            })
            .collect();
        backend.decode_quant_batch(&seqs, h, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{CacheF32, CacheQuant};
    use crate::backend::{self, BackendKind, ScalarRef};

    fn req(id: u64, priority: Priority, deadline_ms: Option<u64>) -> Request {
        Request {
            id,
            prompt: vec![1, 2, 3],
            max_new_tokens: 4,
            sampling: Sampling::Greedy,
            stop_token: None,
            priority,
            deadline_ms,
            tier: QualityTier::from_priority(priority),
            session: None,
        }
    }

    #[test]
    fn fair_queue_weighted_interleave() {
        // both classes backlogged: weights 4:1 give the cycle I,I,B,I,I
        let mut q = FairQueue::new();
        for i in 0..8 {
            q.push_back(req(100 + i, Priority::Interactive, None), 0.0);
        }
        for i in 0..2 {
            q.push_back(req(200 + i, Priority::Batch, None), 0.0);
        }
        assert_eq!(q.len(), 10);
        let order: Vec<Priority> =
            std::iter::from_fn(|| q.pop()).map(|(r, _)| r.priority).collect();
        assert_eq!(order.len(), 10);
        assert_eq!(order[0], Priority::Interactive,
                   "interactive must go first from a cold start");
        let batch_pos: Vec<usize> = order.iter().enumerate()
            .filter(|(_, p)| **p == Priority::Batch)
            .map(|(i, _)| i)
            .collect();
        // the 4:1 deficit cycle serves batch on pops 3 and 8 (0-indexed 2, 7)
        assert_eq!(batch_pos, vec![2, 7],
                   "batch must be interleaved, not starved: {order:?}");
    }

    #[test]
    fn fair_queue_single_class_is_fifo() {
        let mut q = FairQueue::new();
        for i in 0..5 {
            q.push_back(req(i, Priority::Batch, None), 0.0);
        }
        let ids: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|(r, _)| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
        assert!(q.pop().is_none());
    }

    #[test]
    fn fair_queue_peek_is_pure_and_matches_pop() {
        // the page-admission hold peeks (possibly many times across many
        // ticks) before pages free up — peeking must never advance the
        // deficit scheduler or change which request pops next
        let mut q = FairQueue::new();
        for i in 0..4 {
            q.push_back(req(100 + i, Priority::Interactive, None), 0.0);
            q.push_back(req(200 + i, Priority::Batch, None), 0.0);
        }
        let mut popped = Vec::new();
        while let Some(head_id) = q.peek().map(|(r, _)| r.id) {
            for _ in 0..3 {
                assert_eq!(q.peek().unwrap().0.id, head_id,
                           "repeated peeks must be stable");
            }
            let (r, _) = q.pop().unwrap();
            assert_eq!(r.id, head_id, "pop must return the peeked request");
            popped.push(r.priority);
        }
        // the full 4:1 cycle is preserved despite all the interleaved peeks
        let batch_pos: Vec<usize> = popped.iter().enumerate()
            .filter(|(_, p)| **p == Priority::Batch)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(batch_pos, vec![2, 7], "{popped:?}");
    }

    #[test]
    fn fair_queue_remove_and_expiry() {
        let mut q = FairQueue::new();
        q.push_back(req(1, Priority::Interactive, None), 0.0);
        q.push_back(req(2, Priority::Batch, Some(0)), 0.0); // expired on arrival
        q.push_back(req(3, Priority::Batch, Some(60_000)), 0.0);
        assert!(q.has_deadlines());
        let expired = q.take_expired(0.0);
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].0.id, 2);
        assert_eq!(q.len(), 2);
        assert_eq!(q.remove_by_id(3).unwrap().0.id, 3);
        assert!(q.remove_by_id(3).is_none());
        assert!(!q.has_deadlines());
        assert_eq!(q.len(), 1);
    }

    /// Satellite of the telemetry clock: deadlines are evaluated on an
    /// injected [`Clock`] reading, so a `ManualClock` pins the exact
    /// expiry tick — no sleeping, no scheduler jitter.
    #[test]
    fn queued_deadlines_fire_exactly_on_the_manual_clock() {
        use crate::telemetry::{Clock, ManualClock};
        let clock = ManualClock::new();
        let mut q = FairQueue::new();
        q.push_back(req(1, Priority::Interactive, Some(50)), clock.now_ms());
        q.push_back(req(2, Priority::Batch, None), clock.now_ms());
        clock.advance_ms(49.0);
        assert!(q.take_expired(clock.now_ms()).is_empty(),
                "one ms short of the deadline must not expire");
        clock.advance_ms(1.0);
        let expired = q.take_expired(clock.now_ms());
        assert_eq!(expired.len(), 1, "deadline must fire at exactly 50 ms");
        assert_eq!(expired[0].0.id, 1);
        assert_eq!(q.len(), 1, "the deadline-free request stays queued");
    }

    #[test]
    fn fair_queue_no_class_starves_under_sustained_load() {
        // keep both lanes topped up for many pops: each class must get
        // within one quantum of its weight share
        let mut q = FairQueue::new();
        let mut next = 0u64;
        let mut served = [0usize; 2];
        for _ in 0..500 {
            while q.classes[0].len() < 2 {
                q.push_back(req(next, Priority::Interactive, None), 0.0);
                next += 1;
            }
            while q.classes[1].len() < 2 {
                q.push_back(req(next, Priority::Batch, None), 0.0);
                next += 1;
            }
            let (r, _) = q.pop().unwrap();
            served[r.priority.index()] += 1;
        }
        // weights 4:1 → 400/100 exactly, but allow one quantum of drift
        assert!((served[0] as i64 - 400).abs() <= 5, "served {served:?}");
        assert!(served[1] >= 95, "batch starved: {served:?}");
    }

    #[test]
    fn fair_queue_invariants_hold_under_random_schedules() {
        // Randomized push/pop interleavings, then a sustained
        // dual-backlog drain.  Invariants after every pop:
        //   * credits sum to zero and stay within one scheduling quantum
        //     (the deficit counter never runs away in either direction);
        //   * each class pops in FIFO order;
        // and over the backlogged phase:
        //   * service converges on the 4:1 weight ratio;
        //   * neither class ever waits more than one full quantum of
        //     consecutive foreign pops (no starvation).
        let quantum: i64 = CLASS_WEIGHTS.iter().sum();
        crate::util::prop::check("fair_queue_random_schedules", 40, |rng| {
            let mut q = FairQueue::new();
            let mut next_id = 0u64;
            let mut last_popped = [None::<u64>; Priority::COUNT];
            let mut check_pop = |q: &mut FairQueue,
                                 last: &mut [Option<u64>; Priority::COUNT]|
                                 -> Result<Option<Priority>, String> {
                let Some((r, _)) = q.pop() else { return Ok(None) };
                let c = r.priority.index();
                crate::prop_assert!(
                    q.credit.iter().sum::<i64>() == 0,
                    "credits must sum to zero, got {:?}", q.credit);
                crate::prop_assert!(
                    q.credit.iter().all(|d| d.abs() <= quantum),
                    "deficit ran away: {:?} (quantum {quantum})", q.credit);
                crate::prop_assert!(
                    !last[c].is_some_and(|prev| prev >= r.id),
                    "class {c} popped id {} after {:?} (FIFO broken)",
                    r.id, last[c]);
                last[c] = Some(r.id);
                Ok(Some(r.priority))
            };
            // phase 1: random arrivals and pops
            for _ in 0..rng.below(120) {
                if rng.f64() < 0.55 {
                    let pri = if rng.f64() < 0.5 { Priority::Interactive }
                              else { Priority::Batch };
                    q.push_back(req(next_id, pri, None), 0.0);
                    next_id += 1;
                } else {
                    check_pop(&mut q, &mut last_popped)?;
                }
            }
            // phase 2: both lanes kept backlogged — measure shares and
            // the longest run a class goes unserved
            let mut served = [0i64; Priority::COUNT];
            let mut unserved_run = [0i64; Priority::COUNT];
            let pops = 100 + rng.below(100) as i64;
            for _ in 0..pops {
                for c in [Priority::Interactive, Priority::Batch] {
                    while q.classes[c.index()].len() < 2 {
                        q.push_back(req(next_id, c, None), 0.0);
                        next_id += 1;
                    }
                }
                let Some(pri) = check_pop(&mut q, &mut last_popped)? else {
                    return Err("backlogged queue returned None".into());
                };
                for c in 0..Priority::COUNT {
                    if c == pri.index() {
                        served[c] += 1;
                        unserved_run[c] = 0;
                    } else {
                        unserved_run[c] += 1;
                        crate::prop_assert!(
                            unserved_run[c] <= quantum,
                            "class {c} starved for {} consecutive pops",
                            unserved_run[c]);
                    }
                }
            }
            // 4:1 convergence within one quantum of the exact share
            let want_batch = pops / quantum;
            crate::prop_assert!(
                (served[Priority::Batch.index()] - want_batch).abs() <= quantum,
                "batch share off: served {served:?} over {pops} pops \
                 (want ~{want_batch})");
            Ok(())
        });
    }

    /// The admission page estimate must reserve first-decode-append
    /// headroom — at an exact page boundary the old `ceil(prompt/tpp)`
    /// sizing admitted, then the first append needed `2·L` fresh pages
    /// and the request died with a spurious `KV append failed`.
    #[test]
    fn admission_pages_reserves_decode_headroom() {
        // L = 2, tpp = 4; mid-page prompt: 6 + 1 tokens → 2 pages/stream
        assert_eq!(admission_pages(6, 8, 2, 4, 0), 2 * 2 * 2);
        // exact page boundary: 8 tokens must reserve a 3rd page/stream
        assert_eq!(admission_pages(8, 8, 2, 4, 0), 2 * 2 * 3);
        // one-token budgets finish at admission — no headroom, so the
        // old exact-fit sizing is preserved for them
        assert_eq!(admission_pages(8, 1, 2, 4, 0), 2 * 2 * 2);
        // grafted shared-prefix pages are already allocated
        assert_eq!(admission_pages(8, 8, 2, 4, 2), 2 * 2 * 1);
        // an over-shared estimate saturates at zero
        assert_eq!(admission_pages(3, 1, 2, 4, 5), 0);
    }

    fn test_cfg() -> ModelConfig {
        ModelConfig {
            name: "staged-test".into(),
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            d_head: 8,
            d_ff: 64,
            max_seq: 16,
            cache_seq: 12,
            decode_batch: 3,
            kv_group: 8,
            rope_theta: 1e4,
            train_ppl: 0.0,
        }
    }

    /// The staged (paged) views must decode bit-identically to the same
    /// tokens decoded through owned caches, on every backend — this is the
    /// decode tick's native-attention consistency gate.
    #[test]
    fn staged_decode_matches_cache_decode_all_backends() {
        let cfg = test_cfg();
        let (d, dh, h) = (cfg.d_kv(), cfg.d_head, cfg.n_heads);
        let ng = d / cfg.kv_group;
        let (b, s) = (cfg.decode_batch, cfg.cache_seq);
        let lens = [5usize, 0, 3]; // ragged, including an empty slot
        let layer = 1usize;
        let mut rng = Rng::new(42);

        // quantized path: append through CacheQuant (8-bit stores raw i8
        // codes — the same unpacked layout staging keeps), then copy the
        // codec output into the staging slabs
        let mut staging = DecodeStaging::new(&cfg, false);
        let mut caches: Vec<(CacheQuant, CacheQuant)> = Vec::new();
        for (slot, &len) in lens.iter().enumerate() {
            let mut kq = CacheQuant::new(cfg.n_kv_heads, dh, cfg.kv_group, 8);
            let mut vq = CacheQuant::new(cfg.n_kv_heads, dh, cfg.kv_group, 8);
            for _ in 0..len {
                kq.append(&rng.normal_vec(d), 0.95);
                vq.append(&rng.normal_vec(d), 0.95);
            }
            for l in 0..cfg.n_layers {
                let co = (l * b + slot) * s * d;
                let go = (l * b + slot) * s * ng;
                for (cache, dst_c, dst_s, dst_z) in [
                    (&kq, &mut staging.k_codes, &mut staging.k_scale,
                     &mut staging.k_zero),
                    (&vq, &mut staging.v_codes, &mut staging.v_scale,
                     &mut staging.v_zero),
                ] {
                    for (i, &c) in cache.codes.iter().enumerate() {
                        dst_c[co + i] = c as i8;
                    }
                    dst_s[go..go + len * ng].copy_from_slice(&cache.scales);
                    dst_z[go..go + len * ng].copy_from_slice(&cache.zeros);
                }
            }
            caches.push((kq, vq));
        }
        let active: Vec<(usize, usize)> =
            lens.iter().enumerate().map(|(i, &l)| (i, l)).collect();
        let qs = rng.normal_vec(lens.len() * h * dh);

        // oracle: decode each slot through its owned cache views
        let oracle = ScalarRef;
        let mut want = vec![0.0f32; lens.len() * h * dh];
        let seqs: Vec<DecodeQuantSeq> = caches.iter().enumerate()
            .map(|(i, (kq, vq))| DecodeQuantSeq {
                q: &qs[i * h * dh..(i + 1) * h * dh],
                k: kq.view(),
                v: vq.view(),
            })
            .collect();
        oracle.decode_quant_batch(&seqs, h, &mut want);

        for kind in BackendKind::all() {
            let be = backend::make(kind);
            let mut got = vec![f32::NAN; lens.len() * h * dh];
            staged_decode_attention(be.as_ref(), &cfg, false, &staging, layer,
                                    &active, &qs, &mut got);
            assert!(got == want, "staged quant decode diverged on {}", be.name());
        }

        // fp path: staging carries raw f32 streams
        let mut staging = DecodeStaging::new(&cfg, true);
        let mut fcaches: Vec<(CacheF32, CacheF32)> = Vec::new();
        for (slot, &len) in lens.iter().enumerate() {
            let mut kf = CacheF32::new(cfg.n_kv_heads, dh, len);
            let mut vf = CacheF32::new(cfg.n_kv_heads, dh, len);
            for _ in 0..len {
                kf.append(&rng.normal_vec(d));
                vf.append(&rng.normal_vec(d));
            }
            for l in 0..cfg.n_layers {
                let co = (l * b + slot) * s * d;
                staging.k_f32[co..co + len * d].copy_from_slice(&kf.data);
                staging.v_f32[co..co + len * d].copy_from_slice(&vf.data);
            }
            fcaches.push((kf, vf));
        }
        let mut want = vec![0.0f32; lens.len() * h * dh];
        let seqs: Vec<DecodeF32Seq> = fcaches.iter().enumerate()
            .map(|(i, (kf, vf))| DecodeF32Seq {
                q: &qs[i * h * dh..(i + 1) * h * dh],
                k: kf.view(),
                v: vf.view(),
            })
            .collect();
        oracle.decode_f32_batch(&seqs, h, &mut want);
        for kind in BackendKind::all() {
            let be = backend::make(kind);
            let mut got = vec![f32::NAN; lens.len() * h * dh];
            staged_decode_attention(be.as_ref(), &cfg, true, &staging, layer,
                                    &active, &qs, &mut got);
            assert!(got == want, "staged f32 decode diverged on {}", be.name());
        }
    }

    /// Engine-level tests over the native executor — the first serving
    /// tests that run without PJRT artifacts (`Runner::new_native_*`
    /// needs no compiled graphs, so plain `cargo test` drives the full
    /// submit → tick → events pipeline end to end).
    mod native_engine {
        use super::*;
        use crate::coordinator::runner::QuantSpec;
        use crate::forward::native::tests::archive_for;
        use crate::forward::weights::canonical_weight_order;
        use crate::telemetry::ManualClock;

        fn engine_cfg() -> ModelConfig {
            ModelConfig {
                name: "native-engine".into(),
                vocab: 32,
                d_model: 16,
                n_layers: 2,
                n_heads: 4,
                n_kv_heads: 2,
                d_head: 4,
                d_ff: 24,
                max_seq: 48,
                cache_seq: 64,
                decode_batch: 2,
                kv_group: 4,
                rope_theta: 1e4,
                train_ppl: 0.0,
            }
        }

        /// Engine on the scalar backend: per-row arithmetic is bit-stable
        /// there regardless of how many rows share a forward pass, which
        /// the chunk-size-invariance assertions below rely on.
        fn engine(pool_pages: usize, seed: u64) -> GenerationEngine {
            let cfg = engine_cfg();
            let weights = archive_for(&cfg, 11);
            let runner = Runner::new_native_with_backend(
                &cfg, &canonical_weight_order(), &weights,
                QuantSpec::quarot(4), None,
                backend::make(BackendKind::Scalar)).unwrap();
            GenerationEngine::new(runner, pool_pages, seed)
        }

        fn request(prompt: Vec<u16>, max_new: usize,
                   deadline_ms: Option<u64>) -> Request {
            Request {
                id: 0,
                prompt,
                max_new_tokens: max_new,
                sampling: Sampling::Greedy,
                stop_token: None,
                priority: Priority::Interactive,
                deadline_ms,
                tier: QualityTier::Kv4,
                session: None,
            }
        }

        /// Two full pages of head tokens shared by the warm and hit
        /// prompts (TOKENS_PER_PAGE = 16).
        fn head() -> Vec<u16> {
            (0..32u16).map(|i| i * 5 % 31).collect()
        }

        /// Seed the prefix cache: a cold request whose prompt covers the
        /// two-page head (cold admission donates the full prompt pages).
        fn warm(eng: &mut GenerationEngine) {
            let mut prompt = head();
            prompt.extend_from_slice(&[1, 2, 3]);
            eng.submit(request(prompt, 2, None));
            eng.run_to_completion().unwrap();
        }

        #[test]
        fn native_engine_serves_end_to_end() {
            let mut eng = engine(256, 5);
            assert_eq!(eng.runner.executor_name(), "native");
            let prompt: Vec<u16> = (0..20u16).map(|i| i * 7 % 31).collect();
            let id = eng.submit(request(prompt.clone(), 6, None));
            let done = eng.run_to_completion().unwrap();
            assert_eq!(done.len(), 1);
            assert_eq!(done[0].id, id);
            assert_eq!(done[0].prompt_len, prompt.len());
            assert_eq!(done[0].tokens.len(), 6);
            assert!(done[0].tokens.iter().all(|&t| (t as usize) < 32));
            // all pages back except what the prefix trie retains
            eng.clear_prefix_cache();
            assert_eq!(eng.pool_in_use(), 0);
        }

        /// One warm + one prefix-hit request at the given chunk budget;
        /// returns the hit's generated tokens and the final stats.
        fn run_hit_workload(chunk: usize) -> (Vec<u16>, EngineStats) {
            let mut eng = engine(256, 9);
            eng.set_prefill_chunk(chunk);
            warm(&mut eng);
            let mut hit = head();
            hit.extend_from_slice(&[9, 4, 22, 13, 30, 2, 17]);
            eng.submit(request(hit, 5, None));
            let done = eng.run_to_completion().unwrap();
            assert_eq!(done.len(), 1);
            assert_eq!(done[0].tokens.len(), 5);
            (done[0].tokens.clone(), eng.stats.clone())
        }

        /// Satellite: chunked suffix prefill is bit-exact across chunk
        /// sizes — chunk 1 IS the old token-at-a-time loop, so agreement
        /// at 1 / 3 / whole-suffix pins the refactor's numerics, and the
        /// chunk counters pin the ceil(S/chunk) budget accounting.
        #[test]
        fn chunked_suffix_prefill_is_chunk_size_invariant() {
            let (t1, s1) = run_hit_workload(1);
            let (t3, s3) = run_hit_workload(3);
            let (tn, sn) = run_hit_workload(64);
            assert_eq!(t1, t3, "chunk 3 diverged from token-at-a-time");
            assert_eq!(t1, tn, "whole-suffix chunk diverged");
            for s in [&s1, &s3, &sn] {
                assert_eq!(s.suffix_prefill_tokens, 7);
                assert_eq!(s.prefill_chunk_tokens, 7);
            }
            assert_eq!(s1.prefill_chunks, 7); // ceil(7/1)
            assert_eq!(s3.prefill_chunks, 3); // ceil(7/3)
            assert_eq!(sn.prefill_chunks, 1); // ceil(7/64)
        }

        /// Acceptance: an S-token uncached suffix on an idle engine
        /// completes in ceil(S/chunk) ticks, not S — `Started` fires on
        /// exactly that tick.
        #[test]
        fn suffix_completes_in_ceil_s_over_chunk_ticks() {
            let mut eng = engine(256, 2);
            eng.set_prefill_chunk(3);
            warm(&mut eng);
            let mut hit = head();
            hit.extend_from_slice(&[5, 11, 2, 28, 7, 19, 3]); // S = 7
            eng.submit(request(hit, 4, None));
            eng.take_events();
            let mut started_tick = None;
            for tick in 1..=6 {
                eng.tick().unwrap();
                let started = eng.take_events().iter().any(|(_, e)| {
                    matches!(e, GenerationEvent::Started { .. })
                });
                if started {
                    started_tick = Some(tick);
                    break;
                }
            }
            assert_eq!(started_tick, Some(3), "ceil(7/3) = 3 ticks");
            assert_eq!(eng.stats.prefill_chunks, 3);
            assert_eq!(eng.stats.suffix_prefill_tokens, 7);
        }

        /// Satellite regression (ManualClock): a request whose deadline
        /// lapses mid-prefill retires between chunks with
        /// `DeadlineExceeded`, never emits `Started`, and returns every
        /// page — grafted refs included — to the pool.
        #[test]
        fn deadline_retires_job_mid_prefill_and_frees_pages() {
            let clock = Arc::new(ManualClock::new());
            let mut eng = engine(256, 7);
            eng.set_clock(clock.clone());
            eng.set_prefill_chunk(2);
            warm(&mut eng);
            let retained = eng.pool_in_use();
            let mut hit = head();
            hit.extend((0..10u16).map(|i| i + 3));
            let id = eng.submit(request(hit, 8, Some(50)));
            eng.take_events();
            eng.tick().unwrap(); // admits the job, runs its first chunk
            assert_eq!(eng.prefill_jobs_active(), 1);
            assert!(eng.stats.suffix_prefill_tokens < 10,
                    "prefill must still be in flight");
            clock.advance_ms(60.0);
            eng.tick().unwrap(); // deadline fires before the next chunk
            let evs = eng.take_events();
            assert!(evs.iter().any(|(eid, e)| *eid == id && matches!(e,
                GenerationEvent::Finished {
                    reason: FinishReason::DeadlineExceeded, ..
                })), "expected DeadlineExceeded, got {evs:?}");
            assert!(!evs.iter().any(
                        |(_, e)| matches!(e, GenerationEvent::Started { .. })),
                    "an expired job must never start");
            assert_eq!(eng.prefill_jobs_active(), 0);
            assert_eq!(eng.pool_in_use(), retained,
                       "job pages must return to the pool");
            assert_eq!(eng.stats.deadline_exceeded, 1);
        }

        /// Mid-prefill cancellation takes the same retirement path.
        #[test]
        fn cancel_retires_job_mid_prefill() {
            let mut eng = engine(256, 4);
            eng.set_prefill_chunk(2);
            warm(&mut eng);
            let retained = eng.pool_in_use();
            let mut hit = head();
            hit.extend((0..9u16).map(|i| i + 6));
            let id = eng.submit(request(hit, 8, None));
            eng.tick().unwrap();
            assert_eq!(eng.prefill_jobs_active(), 1);
            assert!(eng.cancel(id));
            let evs = eng.take_events();
            assert!(evs.iter().any(|(eid, e)| *eid == id && matches!(e,
                GenerationEvent::Finished {
                    reason: FinishReason::Cancelled, ..
                })));
            assert_eq!(eng.prefill_jobs_active(), 0);
            assert_eq!(eng.pool_in_use(), retained);
            eng.tick().unwrap();
            assert_eq!(eng.pending(), 0);
        }

        /// Satellite: generated-token donation is token-exact — turn 2
        /// grafts the full pages AND the copied tail page of turn 1's
        /// resident chain, so the savings gauge equals
        /// `prev_prompt + generated − 1` exactly (not page-rounded).
        #[test]
        fn session_tail_donation_savings_are_token_exact() {
            let mut eng = engine(256, 6);
            let prompt1: Vec<u16> =
                (0..20u16).map(|i| (i * 3 + 1) % 31).collect();
            let mut req = request(prompt1, 6, None);
            req.session = Some(SessionSpec::New);
            let id1 = eng.submit(req);
            let mut sid = None;
            while eng.pending() > 0 {
                eng.tick().unwrap();
                for (eid, e) in eng.take_events() {
                    if eid == id1 {
                        if let GenerationEvent::Finished { stats, .. } = e {
                            sid = stats.session;
                        }
                    }
                }
            }
            let sid = sid.expect("turn 1 must resolve a session");
            assert_eq!(eng.stats.session_prefill_tokens_saved, 0);

            let mut req2 = request(vec![7, 9, 11, 13, 2], 4, None);
            req2.session = Some(SessionSpec::Resume(sid));
            eng.submit(req2);
            eng.run_to_completion().unwrap();
            // resident chain of turn 1: 20 prompt + 6 generated − 1
            // never-appended = 25 tokens = 1 full page + a 9-token tail
            assert_eq!(eng.stats.session_prefill_tokens_saved, 25);
            // turn-2 prompt = 26-token history + 5 new = 31; 25 grafted,
            // 6 prefilled
            assert_eq!(eng.stats.suffix_prefill_tokens, 31 - 25);
        }

        /// Acceptance: decode slots advance every tick while a chunked
        /// prefill is in flight — the shared budget never stalls decode.
        #[test]
        fn decode_advances_every_tick_alongside_prefill_jobs() {
            let mut eng = engine(256, 3);
            eng.set_prefill_chunk(2);
            warm(&mut eng);
            // slot A: short cold prompt, long decode budget
            let cold: Vec<u16> = (0..8u16).map(|i| 30 - i).collect();
            let d_id = eng.submit(request(cold, 10, None));
            eng.tick().unwrap();
            // slot B: prefix hit with an 8-token suffix; at budget 2 with
            // one decoding slot it advances 1 token/tick
            let mut hit = head();
            hit.extend((0..8u16).map(|i| i + 12));
            eng.submit(request(hit, 2, None));
            eng.take_events();
            for tick in 0..3 {
                eng.tick().unwrap();
                assert_eq!(eng.prefill_jobs_active(), 1,
                           "suffix must still be prefilling at tick {tick}");
                let evs = eng.take_events();
                assert!(evs.iter().any(|(eid, e)| *eid == d_id && matches!(e,
                    GenerationEvent::Token { .. })),
                    "decode slot must produce a token every tick, tick {tick}");
            }
        }
    }
}
