//! Dense linear algebra built from scratch (no LAPACK offline): Cholesky,
//! triangular solves, SPD inverse, Householder QR.  Sized for GPTQ Hessians
//! (d ≤ ~2k) and the Table-8 random-orthogonal ablation.

use crate::tensor::Mat;
use crate::util::prng::Rng;

/// Lower-triangular Cholesky of an SPD matrix; returns None if not PD.
pub fn cholesky(a: &Mat) -> Option<Mat> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[(i, j)] as f64;
            for k in 0..j {
                sum -= l[(i, k)] as f64 * l[(j, k)] as f64;
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[(i, j)] = sum.sqrt() as f32;
            } else {
                l[(i, j)] = (sum / l[(j, j)] as f64) as f32;
            }
        }
    }
    Some(l)
}

/// Cholesky with escalating diagonal damping — the standard GPTQ trick
/// (`percdamp`): retries with `damp * mean(diag)` added until PD.
pub fn cholesky_damped(a: &Mat, mut damp: f64) -> (Mat, f64) {
    let n = a.rows;
    let mean_diag = (0..n).map(|i| a[(i, i)] as f64).sum::<f64>() / n as f64;
    loop {
        let mut ad = a.clone();
        for i in 0..n {
            ad[(i, i)] += (damp * mean_diag.max(1e-8)) as f32;
        }
        if let Some(l) = cholesky(&ad) {
            return (l, damp);
        }
        damp *= 10.0;
        assert!(damp < 1e6, "cholesky_damped: matrix is hopeless");
    }
}

/// Solve L x = b with L lower-triangular.
pub fn solve_lower(l: &Mat, b: &[f32]) -> Vec<f32> {
    let n = l.rows;
    let mut x = vec![0.0f32; n];
    for i in 0..n {
        let mut sum = b[i] as f64;
        for j in 0..i {
            sum -= l[(i, j)] as f64 * x[j] as f64;
        }
        x[i] = (sum / l[(i, i)] as f64) as f32;
    }
    x
}

/// Solve Lᵀ x = b with L lower-triangular.
pub fn solve_lower_t(l: &Mat, b: &[f32]) -> Vec<f32> {
    let n = l.rows;
    let mut x = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut sum = b[i] as f64;
        for j in (i + 1)..n {
            sum -= l[(j, i)] as f64 * x[j] as f64;
        }
        x[i] = (sum / l[(i, i)] as f64) as f32;
    }
    x
}

/// Inverse of an SPD matrix via Cholesky (column-by-column solves).
pub fn spd_inverse(a: &Mat, damp: f64) -> Mat {
    let (l, _) = cholesky_damped(a, damp);
    let n = a.rows;
    let mut inv = Mat::zeros(n, n);
    let mut e = vec![0.0f32; n];
    for c in 0..n {
        e[c] = 1.0;
        let y = solve_lower(&l, &e);
        let x = solve_lower_t(&l, &y);
        inv.set_col(c, &x);
        e[c] = 0.0;
    }
    inv
}

/// Upper Cholesky factor of A⁻¹, i.e. the `U` such that A⁻¹ = Uᵀ U …
/// GPTQ wants chol(H⁻¹, upper).  We compute inv then its Cholesky and
/// transpose; fine at toolchain sizes.
pub fn inverse_cholesky_upper(a: &Mat, damp: f64) -> Mat {
    let inv = spd_inverse(a, damp);
    let (l, _) = cholesky_damped(&inv, 1e-10);
    l.t()
}

/// Householder QR; returns Q (m×n, orthonormal columns) for a square input,
/// sign-fixed so diag(R) > 0 (unique, matches numpy convention in
/// hadamard_utils.random_orthogonal).
pub fn qr_orthogonal(a: &Mat) -> Mat {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut r = a.clone();
    let mut q = Mat::eye(n);
    for k in 0..n {
        // Householder vector for column k
        let mut norm = 0.0f64;
        for i in k..n {
            norm += (r[(i, k)] as f64).powi(2);
        }
        let norm = norm.sqrt();
        if norm < 1e-12 {
            continue;
        }
        let alpha = if r[(k, k)] >= 0.0 { -norm } else { norm };
        let mut v = vec![0.0f64; n];
        for i in k..n {
            v[i] = r[(i, k)] as f64;
        }
        v[k] -= alpha;
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 < 1e-24 {
            continue;
        }
        // apply H = I - 2vvᵀ/|v|² to R (left) and accumulate into Q (right)
        for c in 0..n {
            let dot: f64 = (k..n).map(|i| v[i] * r[(i, c)] as f64).sum();
            let f = 2.0 * dot / vnorm2;
            for i in k..n {
                r[(i, c)] = (r[(i, c)] as f64 - f * v[i]) as f32;
            }
        }
        for rr in 0..n {
            let dot: f64 = (k..n).map(|i| q[(rr, i)] as f64 * v[i]).sum();
            let f = 2.0 * dot / vnorm2;
            for i in k..n {
                q[(rr, i)] = (q[(rr, i)] as f64 - f * v[i]) as f32;
            }
        }
    }
    // sign fix: make diag(R) positive
    for k in 0..n {
        if r[(k, k)] < 0.0 {
            for rr in 0..n {
                q[(rr, k)] = -q[(rr, k)];
            }
        }
    }
    q
}

/// Random orthogonal matrix (QR of Gaussian) — Table 8's ablation rotation.
pub fn random_orthogonal(n: usize, rng: &mut Rng) -> Mat {
    qr_orthogonal(&Mat::randn(n, n, rng))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let a = Mat::randn(n, n, &mut rng);
        let mut h = a.matmul(&a.t());
        for i in 0..n {
            h[(i, i)] += n as f32; // well conditioned
        }
        h
    }

    #[test]
    fn cholesky_reconstructs() {
        let h = spd(8, 0);
        let l = cholesky(&h).unwrap();
        let rec = l.matmul(&l.t());
        for (x, y) in rec.data.iter().zip(&h.data) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut m = Mat::eye(3);
        m[(2, 2)] = -1.0;
        assert!(cholesky(&m).is_none());
        let (l, damp) = cholesky_damped(&m, 0.01);
        assert!(damp > 0.01);
        assert_eq!(l.rows, 3);
    }

    #[test]
    fn solves_are_inverses() {
        let h = spd(6, 1);
        let l = cholesky(&h).unwrap();
        let b: Vec<f32> = (0..6).map(|i| i as f32 - 2.5).collect();
        let y = solve_lower(&l, &b);
        let x = solve_lower_t(&l, &y);
        // H x should equal b
        let hx: Vec<f32> = (0..6)
            .map(|i| (0..6).map(|j| h[(i, j)] * x[j]).sum())
            .collect();
        for (a, b) in hx.iter().zip(&b) {
            assert!((a - b).abs() < 1e-2, "{a} vs {b}");
        }
    }

    #[test]
    fn spd_inverse_works() {
        let h = spd(5, 2);
        let inv = spd_inverse(&h, 1e-10);
        let prod = h.matmul(&inv);
        for i in 0..5 {
            for j in 0..5 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod[(i, j)] - want).abs() < 1e-2);
            }
        }
    }

    #[test]
    fn qr_gives_orthogonal() {
        let mut rng = Rng::new(3);
        let q = random_orthogonal(16, &mut rng);
        let qtq = q.t().matmul(&q);
        for i in 0..16 {
            for j in 0..16 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((qtq[(i, j)] - want).abs() < 1e-4,
                        "({i},{j}) {}", qtq[(i, j)]);
            }
        }
    }

    #[test]
    fn inverse_cholesky_upper_property() {
        // U from chol(H⁻¹): UᵀU… we use U Uᵀ = H⁻¹ with U upper.
        let h = spd(6, 4);
        let u = inverse_cholesky_upper(&h, 1e-10);
        let rec = u.t().matmul(&u); // (Lᵀ)ᵀ Lᵀ... U = Lᵀ so UᵀU = L Lᵀ = H⁻¹
        let inv = spd_inverse(&h, 1e-10);
        for (x, y) in rec.data.iter().zip(&inv.data) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }
}
