//! Evaluation harness: perplexity (the WikiText-2 stand-in), the six
//! zero-shot probe tasks (Table 2 stand-in) and activation outlier
//! statistics (Fig. 1).

use anyhow::Result;

use crate::coordinator::runner::Runner;
use crate::coordinator::sampler::log_softmax_at;
use crate::model::corpus::{ProbeTask};

/// Perplexity of `tokens` under the runner's model, measured in windows of
/// `max_seq` exactly like python/compile/train.evaluate_ppl.
/// `max_windows` caps the cost for table sweeps.
pub fn perplexity(runner: &Runner, tokens: &[u16], max_windows: usize) -> Result<f64> {
    let s = runner.cfg.max_seq;
    let v = runner.cfg.vocab;
    let n = ((tokens.len() - 1) / s).min(max_windows);
    assert!(n > 0, "not enough eval tokens");
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for w in 0..n {
        let window = &tokens[w * s..w * s + s + 1];
        let pre = runner.prefill(&window[..s])?;
        for t in 0..s {
            let logits = &pre.logits[t * v..(t + 1) * v];
            nll -= log_softmax_at(logits, window[t + 1] as usize);
            count += 1;
        }
    }
    Ok((nll / count as f64).exp())
}

/// Score of one continuation: total logprob of `cont` given `ctx`.
fn continuation_logprob(runner: &Runner, ctx: &[u16], cont: &[u16]) -> Result<f64> {
    let v = runner.cfg.vocab;
    let mut seq = ctx.to_vec();
    seq.extend_from_slice(cont);
    let pre = runner.prefill(&seq)?;
    let mut lp = 0.0f64;
    for (i, &tok) in cont.iter().enumerate() {
        let pos = ctx.len() + i - 1; // logits at pos predict token pos+1
        let logits = &pre.logits[pos * v..(pos + 1) * v];
        lp += log_softmax_at(logits, tok as usize);
    }
    Ok(lp)
}

#[derive(Clone, Debug)]
pub struct TaskScore {
    pub name: String,
    pub accuracy: f64,
    pub items: usize,
}

/// Accuracy on one probe task (multiple-choice ranking, or exact next-token
/// for the LAMBADA-style task).
pub fn score_task(runner: &Runner, task: &ProbeTask, max_items: usize)
                  -> Result<TaskScore> {
    let v = runner.cfg.vocab;
    let mut correct = 0usize;
    let items = task.items.len().min(max_items);
    for item in task.items.iter().take(items) {
        if item.choices.is_empty() {
            let pre = runner.prefill(&item.ctx)?;
            let pos = item.ctx.len() - 1;
            let logits = &pre.logits[pos * v..(pos + 1) * v];
            let am = crate::coordinator::sampler::argmax(logits);
            if am == item.gold_token as usize {
                correct += 1;
            }
        } else {
            let mut best = (f64::MIN, 0usize);
            for (ci, cont) in item.choices.iter().enumerate() {
                let lp = continuation_logprob(runner, &item.ctx, cont)?;
                if lp > best.0 {
                    best = (lp, ci);
                }
            }
            if best.1 == item.gold {
                correct += 1;
            }
        }
    }
    Ok(TaskScore {
        name: task.name.clone(),
        accuracy: correct as f64 / items as f64,
        items,
    })
}

/// Run all probe tasks; returns scores plus the average (the paper's Avg).
pub fn score_all(runner: &Runner, tasks: &[ProbeTask], max_items: usize)
                 -> Result<(Vec<TaskScore>, f64)> {
    let scores: Vec<TaskScore> = tasks.iter()
        .map(|t| score_task(runner, t, max_items))
        .collect::<Result<_>>()?;
    let avg = scores.iter().map(|s| s.accuracy).sum::<f64>() / scores.len() as f64;
    Ok((scores, avg))
}

/// Fig. 1 statistics from calibration amax: per-layer max/median channel
/// ratio and a flatness summary, per site.
#[derive(Clone, Debug)]
pub struct OutlierStats {
    pub site: usize,
    pub layer: usize,
    pub max_channel: f32,
    pub median_channel: f32,
    pub ratio: f32,
}

pub fn outlier_stats(amax: &[Vec<Vec<f32>>]) -> Vec<OutlierStats> {
    let mut out = Vec::new();
    for (site, layers) in amax.iter().enumerate() {
        for (layer, ch) in layers.iter().enumerate() {
            let mut sorted = ch.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let median = sorted[sorted.len() / 2];
            let mx = *sorted.last().unwrap();
            out.push(OutlierStats {
                site,
                layer,
                max_channel: mx,
                median_channel: median,
                ratio: mx / median.max(1e-8),
            });
        }
    }
    out
}
