//! Evaluation harness: perplexity (the WikiText-2 stand-in), the six
//! zero-shot probe tasks (Table 2 stand-in) and activation outlier
//! statistics (Fig. 1).
//!
//! The NLL inner loops dispatch through the runner's
//! [`crate::backend::ComputeBackend`]: each perplexity window (and each
//! continuation score) is one batched `nll_rows` reduction instead of a
//! per-token scalar `log_softmax_at` loop, so `Blocked`/`Threaded` (and
//! future SIMD/GPU backends) own this hot path too.
//!
//! Degenerate inputs are hardened: token streams shorter than one window
//! return a typed `Err` (no underflow panics), empty contexts score from
//! the first predictable position, and zero-item tasks report accuracy
//! 0.0 instead of `0/0 = NaN`.

use anyhow::{bail, Result};

use crate::coordinator::runner::Runner;
use crate::model::corpus::ProbeTask;

/// How many windows of `window` tokens (each needing one next-token
/// target) a stream of `n_tokens` supports, capped at `max_windows`.
/// Too-short streams are a typed `Err`, never an underflow panic.
pub(crate) fn plan_windows(n_tokens: usize, window: usize, max_windows: usize)
                           -> Result<usize> {
    if n_tokens < window + 1 {
        bail!("perplexity needs at least {} tokens (one window of {window} \
               plus a next-token target); got {n_tokens}", window + 1);
    }
    if max_windows == 0 {
        bail!("perplexity needs max_windows >= 1");
    }
    Ok(((n_tokens - 1) / window).min(max_windows))
}

/// Perplexity of `tokens` under the runner's model, measured in windows of
/// `max_seq` exactly like python/compile/train.evaluate_ppl.
/// `max_windows` caps the cost for table sweeps.
pub fn perplexity(runner: &Runner, tokens: &[u16], max_windows: usize) -> Result<f64> {
    let s = runner.cfg.max_seq;
    let v = runner.cfg.vocab;
    let n = plan_windows(tokens.len(), s, max_windows)?;
    let mut nll = 0.0f64;
    let mut count = 0usize;
    let mut row_nll = vec![0.0f64; s];
    for w in 0..n {
        let window = &tokens[w * s..w * s + s + 1];
        let pre = runner.prefill(&window[..s])?;
        // one batched NLL reduction per window: logits row t scores target
        // window[t + 1]
        runner.backend.nll_rows(&pre.logits, v, &window[1..], &mut row_nll);
        for &r in &row_nll {
            nll += r;
        }
        count += s;
    }
    Ok((nll / count as f64).exp())
}

/// Score of one continuation: total logprob of `cont` given `ctx`, as one
/// batched NLL reduction over the continuation's (consecutive) logit rows.
/// With an empty context the first continuation token has no predicting
/// position, so scoring starts at the first predictable one.
fn continuation_logprob(runner: &Runner, ctx: &[u16], cont: &[u16]) -> Result<f64> {
    let v = runner.cfg.vocab;
    let skip = usize::from(ctx.is_empty());
    if cont.len() <= skip {
        return Ok(0.0);
    }
    let mut seq = ctx.to_vec();
    seq.extend_from_slice(cont);
    let pre = runner.prefill(&seq)?;
    // logits at position p predict token p + 1
    let p0 = ctx.len() + skip - 1;
    let targets = &cont[skip..];
    let mut row_nll = vec![0.0f64; targets.len()];
    runner.backend.nll_rows(&pre.logits[p0 * v..(p0 + targets.len()) * v], v,
                            targets, &mut row_nll);
    Ok(-row_nll.iter().sum::<f64>())
}

#[derive(Clone, Debug)]
pub struct TaskScore {
    pub name: String,
    pub accuracy: f64,
    pub items: usize,
}

impl TaskScore {
    /// Accuracy from raw counts; zero-item tasks score 0.0, not `0/0 = NaN`.
    pub fn from_counts(name: String, correct: usize, items: usize) -> TaskScore {
        let accuracy = if items == 0 {
            0.0
        } else {
            correct as f64 / items as f64
        };
        TaskScore { name, accuracy, items }
    }
}

/// Accuracy on one probe task (multiple-choice ranking, or exact next-token
/// for the LAMBADA-style task).
pub fn score_task(runner: &Runner, task: &ProbeTask, max_items: usize)
                  -> Result<TaskScore> {
    let v = runner.cfg.vocab;
    let mut correct = 0usize;
    let items = task.items.len().min(max_items);
    for item in task.items.iter().take(items) {
        if item.choices.is_empty() {
            if item.ctx.is_empty() {
                continue; // no predicting position — scored incorrect
            }
            let pre = runner.prefill(&item.ctx)?;
            let pos = item.ctx.len() - 1;
            let logits = &pre.logits[pos * v..(pos + 1) * v];
            let am = crate::coordinator::sampler::argmax(logits);
            if am == item.gold_token as usize {
                correct += 1;
            }
        } else {
            // A choice with no scoreable tokens — empty, or single-token
            // under an empty context (whose first token has no predicting
            // position) — would score an empty product (logprob 0 =
            // certainty) and win any ranking; such items are unscoreable,
            // counted incorrect.
            let min_len = usize::from(item.ctx.is_empty()) + 1;
            if item.choices.iter().any(|c| c.len() < min_len) {
                continue;
            }
            let mut best = (f64::MIN, 0usize);
            for (ci, cont) in item.choices.iter().enumerate() {
                let lp = continuation_logprob(runner, &item.ctx, cont)?;
                if lp > best.0 {
                    best = (lp, ci);
                }
            }
            if best.1 == item.gold {
                correct += 1;
            }
        }
    }
    Ok(TaskScore::from_counts(task.name.clone(), correct, items))
}

/// Mean accuracy over task scores; an empty list averages to 0.0 (not NaN).
pub fn average_accuracy(scores: &[TaskScore]) -> f64 {
    if scores.is_empty() {
        return 0.0;
    }
    scores.iter().map(|s| s.accuracy).sum::<f64>() / scores.len() as f64
}

/// Run all probe tasks; returns scores plus the average (the paper's Avg).
pub fn score_all(runner: &Runner, tasks: &[ProbeTask], max_items: usize)
                 -> Result<(Vec<TaskScore>, f64)> {
    let scores: Vec<TaskScore> = tasks.iter()
        .map(|t| score_task(runner, t, max_items))
        .collect::<Result<_>>()?;
    let avg = average_accuracy(&scores);
    Ok((scores, avg))
}

/// Fig. 1 statistics from calibration amax: per-layer max/median channel
/// ratio and a flatness summary, per site.
#[derive(Clone, Debug)]
pub struct OutlierStats {
    pub site: usize,
    pub layer: usize,
    pub max_channel: f32,
    pub median_channel: f32,
    pub ratio: f32,
}

pub fn outlier_stats(amax: &[Vec<Vec<f32>>]) -> Vec<OutlierStats> {
    let mut out = Vec::new();
    for (site, layers) in amax.iter().enumerate() {
        for (layer, ch) in layers.iter().enumerate() {
            let mut sorted = ch.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let median = sorted[sorted.len() / 2];
            let mx = *sorted.last().unwrap();
            out.push(OutlierStats {
                site,
                layer,
                max_channel: mx,
                median_channel: median,
                ratio: mx / median.max(1e-8),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    // Regression (pre-fix code panicked): an empty stream underflowed
    // `tokens.len() - 1` and a short one tripped a bare `assert!(n > 0)`.
    #[test]
    fn short_streams_are_typed_errors() {
        assert!(plan_windows(0, 16, 4).is_err());
        assert!(plan_windows(16, 16, 4).is_err()); // no next-token target
        assert!(plan_windows(17, 16, 0).is_err()); // zero window budget
        assert_eq!(plan_windows(17, 16, 4).unwrap(), 1);
        assert_eq!(plan_windows(100, 16, 4).unwrap(), 4);
        assert_eq!(plan_windows(100, 16, 8).unwrap(), 6);
    }

    // Regression: zero-item tasks divided 0/0 into a NaN accuracy.
    #[test]
    fn zero_item_task_scores_zero_not_nan() {
        let s = TaskScore::from_counts("empty".into(), 0, 0);
        assert_eq!(s.accuracy, 0.0);
        assert!(!s.accuracy.is_nan());
        let s = TaskScore::from_counts("half".into(), 2, 4);
        assert_eq!(s.accuracy, 0.5);
    }

    // Regression: an empty task list averaged to NaN and poisoned the
    // paper-style Avg column.
    #[test]
    fn empty_task_list_averages_zero() {
        assert_eq!(average_accuracy(&[]), 0.0);
        let scores = [TaskScore::from_counts("a".into(), 1, 2),
                      TaskScore::from_counts("b".into(), 3, 4)];
        assert!((average_accuracy(&scores) - 0.625).abs() < 1e-12);
    }

    #[test]
    fn outlier_stats_shape() {
        let amax = vec![vec![vec![1.0f32, 10.0, 2.0]; 2]; 1];
        let st = outlier_stats(&amax);
        assert_eq!(st.len(), 2);
        assert!((st[0].ratio - 5.0).abs() < 1e-6);
    }
}
