//! `quarot` CLI — leader entrypoint for the serving stack and the
//! experiment toolchain.
//!
//! Subcommands:
//!   serve         start the TCP serving front-end (QuaRot-INT4 by
//!                 default; v2 event-frame protocol, --queue-bound for
//!                 per-shard admission, --shards N engine shards,
//!                 --prefix-cache N shared-prefix page budget,
//!                 --executor pjrt|native to pick the graph or the
//!                 graph-free pure-rust forward path, --prefill-chunk N
//!                 for the per-tick chunked-prefill budget)
//!   generate      generation from a token prompt (--stream prints tokens
//!                 incrementally; --priority / --deadline-ms / --tier
//!                 scheduling; --self-spec for KV4-draft speculative
//!                 greedy decode)
//!   chat          multi-turn conversation against a running server:
//!                 each turn sends only the new user tokens, the server
//!                 threads the history and replays prior turns from
//!                 donated prefix-cache pages (--turns "1,2;3,4" scripted,
//!                 otherwise interactive; --session ID resumes)
//!   cluster-bench drive a sharded cluster with synthetic mixed
//!                 Interactive/Batch traffic and print the per-shard
//!                 metrics table (p50/p99 TTFT and inter-token latency
//!                 per class)
//!   trace         drain a running server's span rings
//!                 (`{"cmd":"trace"}`) and write Chrome-trace JSON to
//!                 --out (or stdout) — open in chrome://tracing or
//!                 Perfetto; serve with --trace-buffer N to record
//!   ppl        perplexity of a quantization spec on the eval split
//!   zeroshot   probe-task accuracies
//!   outliers   Fig.1 activation outlier statistics (base vs rotated)
//!   verify     cross-language check: rust QuaRot transform == python's
//!              (--rotation selects the scheme to reconstruct)
//!   info       print the model manifest summary

use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use quarot::api::{GenerationEvent, GenerationParams, LocalSession, Priority,
                  Sampling, SessionConfig};
use quarot::bench_support::{self, Artifacts};
use quarot::cluster::{ClusterConfig, ClusterService, EngineFactory,
                      LatencySummary};
use quarot::coordinator::batcher::{GenerationEngine, DEFAULT_PREFILL_CHUNK};
use quarot::coordinator::runner::{ExecutorKind, QuantSpec, Runner, Variant,
                                  WeightQuant};
use quarot::coordinator::selfspec::{self, SelfSpecDecoder};
use quarot::eval;
use quarot::quant;
use quarot::rotation::{self, RotationKind};
use quarot::util::bench::Table;
use quarot::util::cli::Args;

fn spec_from_args(a: &Args) -> Result<QuantSpec> {
    let scheme = a.str_or("scheme", "quarot-int4");
    let mut spec = match scheme.as_str() {
        "fp16" => QuantSpec::fp16_baseline(),
        "quarot-int4" => QuantSpec::quarot(4),
        "quarot-int6" => QuantSpec::quarot(6),
        "quarot-int8" => QuantSpec::quarot(8),
        "rtn-int4" => QuantSpec {
            variant: Variant::Baseline,
            act_bits: 4, act_clip: 0.9, kv_bits: 4, kv_bits_v: 4, kv_clip: 0.95,
            weights: WeightQuant::Rtn(quant::rtn::WeightQuantCfg::rtn(4)),
            outliers: 0, smooth: false,
        },
        other => bail!("unknown scheme {other} \
                        (fp16|quarot-int4|quarot-int6|quarot-int8|rtn-int4)"),
    };
    if let Some(bits) = a.get("act-bits") {
        spec.act_bits = parse_bits("act-bits", bits)?;
    }
    if let Some(bits) = a.get("kv-bits") {
        // one knob, both streams: a K/V width split is expressible in
        // QuantSpec but not worth a second flag
        spec.kv_bits = parse_bits("kv-bits", bits)?;
        spec.kv_bits_v = spec.kv_bits;
    }
    if let Some(r) = a.get("rotation") {
        let kind = RotationKind::parse(r)?;
        kind.apply_to_spec(&mut spec)?;
    }
    Ok(spec)
}

/// Bit widths the kernels and KV codec actually implement; anything
/// else would quantize to garbage or crash deep in a graph, so reject
/// it at the flag with the valid set spelled out.
const VALID_BITS: [u32; 5] = [3, 4, 6, 8, 16];

fn parse_bits(flag: &str, s: &str) -> Result<u32> {
    let bits: u32 = s.parse()
        .with_context(|| format!("--{flag} '{s}' is not an integer"))?;
    if !VALID_BITS.contains(&bits) {
        bail!("--{flag} {bits} unsupported (valid widths: 3|4|6|8|16)");
    }
    Ok(bits)
}

/// `--executor` dispatch: `pjrt` runs the AOT-compiled graphs (the
/// default), `native` runs the pure-rust forward pass and loads zero
/// PJRT graphs (only the manifest and weights).
fn executor_from_args(a: &Args) -> Result<ExecutorKind> {
    ExecutorKind::parse(&a.str_or("executor", "pjrt"))
}

/// `--prefill-chunk`: prompt tokens prefilled per engine tick, sharing
/// the tick budget with active decode slots (continuous batching).
fn prefill_chunk_from_args(a: &Args) -> usize {
    a.usize_or("prefill-chunk", DEFAULT_PREFILL_CHUNK)
}

fn main() -> Result<()> {
    let args = Args::from_env();
    // Compute-backend selection applies to every subcommand (serve /
    // generate / ppl / ...).  Default is shape-aware auto; QUAROT_BACKEND
    // is the env-var equivalent, QUAROT_THREADS caps the worker pool.
    if let Some(b) = args.get("backend") {
        let kind = quarot::backend::BackendKind::parse(b).with_context(|| {
            format!("unknown backend '{b}' (scalar|blocked|threaded|auto)")
        })?;
        quarot::backend::set_default(kind);
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "serve" => serve(&args),
        "generate" => generate(&args),
        "chat" => chat(&args),
        "cluster-bench" => cluster_bench(&args),
        "trace" => trace(&args),
        "ppl" => ppl(&args),
        "zeroshot" => zeroshot(&args),
        "outliers" => outliers(&args),
        "verify" => verify(&args),
        "info" => info(&args),
        _ => {
            println!(
                "quarot — outlier-free 4-bit inference (paper reproduction)\n\
                 usage: quarot <serve|generate|chat|cluster-bench|trace|ppl|\
                 zeroshot|outliers|verify|info>\n\
                 common flags: --model tiny-mha --scheme quarot-int4\n\
                               --rotation hadamard|random|scaled-hadamard\n\
                               --act-bits / --kv-bits 3|4|6|8|16\n\
                               --backend scalar|blocked|threaded|auto (default auto)\n\
                               --executor pjrt|native (AOT graphs vs the\n\
                               pure-rust forward pass; native loads zero\n\
                               PJRT graphs)\n\
                               --prefill-chunk N (prompt tokens prefilled\n\
                               per tick, budget shared with decode;\n\
                               default 32)\n\
                 generate:     --stream (incremental tokens) --temperature --top-k\n\
                               --stop-token --priority interactive|batch\n\
                               --deadline-ms N (server-side deadline)\n\
                               --tier kv4|kv8 (KV-cache precision tier)\n\
                               --self-spec [--draft N] (KV4 drafts,\n\
                               verified greedy decode)\n\
                 chat:         --port N --turns \"1,2;3,4\" (scripted turns;\n\
                               omit for interactive) --session ID (resume)\n\
                               --max-new N\n\
                 serve:        --queue-bound N (per-shard admission)\n\
                               --shards N (engine shards behind one front)\n\
                               --prefix-cache N (shared-prefix page budget\n\
                               per shard; 0 disables, default pages/2)\n\
                               --sessions N (live chat sessions per shard;\n\
                               0 disables) --session-ttl-ms N (idle expiry)\n\
                               --trace-buffer N (per-shard span ring; 0 off)\n\
                               --trace-sample K (keep 1-in-K decode spans)\n\
                 trace:        --port N --out trace.json (Chrome-trace\n\
                               export; omit --out for stdout)\n\
                 cluster-bench: --shards N --interactive N --batch N\n\
                               --max-new N --batch-max-new N\n\
                               --prefix-cache N (0 disables)\n\
                 see README.md for the full matrix"
            );
            Ok(())
        }
    }
}

/// Build a runner for `spec`, collecting calibration stats when the
/// spec needs them (the scaled-hadamard rotation folds per-channel
/// scales into the weights, which requires activation amax).
fn runner_for_spec(art: &Artifacts, spec: &QuantSpec, kind: ExecutorKind)
                   -> Result<Runner> {
    let stats = if spec.smooth {
        if kind == ExecutorKind::Native {
            bail!("--executor native cannot run smoothed schemes: the \
                   calibration pass needs the PJRT collect graph \
                   (use --executor pjrt)");
        }
        Some(art.calib(spec.variant.is_rotated(), 4)?)
    } else {
        None
    };
    art.runner_kind(kind, spec.clone(), stats.as_ref())
}

fn build_runner(args: &Args) -> Result<(Artifacts, Runner)> {
    let model = args.str_or("model", "tiny-mha");
    let art = Artifacts::load(&model)?;
    let spec = spec_from_args(args)?;
    let runner = runner_for_spec(&art, &spec, executor_from_args(args)?)?;
    Ok((art, runner))
}

fn serve(args: &Args) -> Result<()> {
    let model = args.str_or("model", "tiny-mha");
    let spec = spec_from_args(args)?;
    let pages = args.usize_or("pages", 4096);
    let port = args.usize_or("port", 8747) as u16;
    let shards = args.usize_or("shards", 1);
    let queue_bound = args.usize_or("queue-bound",
                                    quarot::server::DEFAULT_QUEUE_BOUND);
    // shared-prefix page budget per shard: 0 disables, default half the
    // pool (the engine's own default, restated here so the flag is
    // self-documenting)
    let prefix_pages = args.usize_or("prefix-cache", pages / 2);
    // chat-session budget per shard (0 disables multi-turn serving) and
    // optional idle expiry
    let sessions = args.usize_or("sessions",
                                 quarot::session::DEFAULT_SESSION_BUDGET);
    let session_ttl_ms: Option<u64> = args.get("session-ttl-ms")
        .map(|s| s.parse().context("bad --session-ttl-ms"))
        .transpose()?;
    // per-shard span-ring capacity (0 = tracing off) and decode-token
    // sampling rate for `{"cmd":"trace"}` / `quarot trace`
    let trace_buffer = args.usize_or("trace-buffer", 0);
    let trace_sample = args.usize_or("trace-sample", 1) as u64;
    let executor = executor_from_args(args)?;
    let prefill_chunk = prefill_chunk_from_args(args);
    let handle = quarot::server::serve_sharded(
        move || {
            let art = Artifacts::load(&model)?;
            let runner = runner_for_spec(&art, &spec, executor)?;
            let mut engine = GenerationEngine::new(runner, pages, 7);
            engine.set_prefill_chunk(prefill_chunk);
            engine.set_prefix_cache_pages(prefix_pages);
            engine.set_session_budget(sessions);
            engine.set_session_ttl_ms(session_ttl_ms);
            engine.set_trace_buffer(trace_buffer);
            engine.set_trace_sample(trace_sample);
            Ok(engine)
        },
        port,
        queue_bound,
        shards,
    )?;
    println!("serving on 127.0.0.1:{} — v2 event-frame protocol \
              (one JSON frame per event; {{\"cmd\":\"submit\"}} / \
              {{\"cmd\":\"chat\"}} / {{\"cmd\":\"cancel\"}} / \
              {{\"cmd\":\"stats\"}} / {{\"cmd\":\"metrics\"}} / \
              {{\"cmd\":\"trace\"}} / {{\"cmd\":\"flush-prefix\"}} / \
              {{\"cmd\":\"shutdown\"}}); \
              {} shard(s) on the {} executor, per-shard admission bound {}, \
              {} session(s) per shard, prefill chunk {}",
             handle.port, shards, executor.name(), queue_bound, sessions,
             prefill_chunk);
    // blocks until a wire shutdown stops the engine and accept loops,
    // then exits cleanly instead of lingering as a serving-nothing zombie
    handle.wait();
    println!("server shut down");
    Ok(())
}

fn generate(args: &Args) -> Result<()> {
    let (_art, runner) = build_runner(args)?;
    let prompt: Vec<u16> = args.str_or("prompt", "1,2,3")
        .split(',')
        .map(|t| t.trim().parse().context("bad prompt token"))
        .collect::<Result<_>>()?;
    let temperature = args.f64_or("temperature", 0.0);
    if args.bool("self-spec") {
        // self-speculative mode: KV4 drafts, one causal prefill
        // verifies — greedy by construction (the accept rule compares
        // argmaxes, not samples)
        if temperature > 0.0 {
            bail!("--self-spec is greedy-only (drop --temperature)");
        }
        let draft = args.usize_or("draft", selfspec::DEFAULT_DRAFT);
        let dec = SelfSpecDecoder::new(&runner, draft)?;
        let t0 = std::time::Instant::now();
        let out = dec.generate(&prompt, args.usize_or("max-new", 32))?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        println!("tokens: {:?}", out.tokens);
        let s = out.stats;
        println!("self-spec: {} tokens in {ms:.1} ms — {} rounds, \
                  {} verify prefills, {}/{} drafts accepted ({:.0}%)",
                 out.tokens.len(), s.rounds, s.verify_prefills,
                 s.accepted, s.drafted, s.acceptance_rate() * 100.0);
        return Ok(());
    }
    let sampling = if temperature > 0.0 {
        Sampling::TopK {
            temperature: temperature as f32,
            k: args.usize_or("top-k", 0),
        }
    } else {
        Sampling::Greedy
    };
    let mut params = GenerationParams::new(prompt)
        .max_new(args.usize_or("max-new", 32))
        .sampling(sampling);
    if let Some(st) = args.get("stop-token") {
        params = params.stop_at(st.parse().context("bad stop token")?);
    }
    if let Some(p) = args.get("priority") {
        params = params.priority(Priority::parse(p).with_context(|| {
            format!("unknown priority '{p}' (interactive|batch)")
        })?);
    }
    if let Some(d) = args.get("deadline-ms") {
        params = params.deadline(d.parse().context("bad deadline")?);
    }
    if let Some(t) = args.get("tier") {
        params = params.tier(quarot::api::QualityTier::parse(t)
            .with_context(|| format!("unknown tier '{t}' (kv4|kv8)"))?);
    }
    let mut engine = GenerationEngine::new(runner, 1024, 7);
    engine.set_prefill_chunk(prefill_chunk_from_args(args));
    let session = LocalSession::new(engine, SessionConfig::default());
    let handle = session.submit(params).map_err(|e| anyhow!("{e}"))?;

    if args.bool("stream") {
        // print tokens incrementally as the engine produces them
        use std::io::Write as _;
        while let Some(ev) = handle.next_event()? {
            match ev {
                GenerationEvent::Started { ttft_ms } => {
                    eprintln!("[ttft {ttft_ms:.1} ms]");
                }
                GenerationEvent::Token { token, .. } => {
                    print!("{token} ");
                    std::io::stdout().flush()?;
                }
                GenerationEvent::Finished { reason, stats } => {
                    println!();
                    println!("[done: {reason} — {} tokens, {:.1} tok/s]",
                             stats.generated, stats.tokens_per_sec());
                }
                GenerationEvent::Failed { error } => {
                    println!();
                    bail!("generation failed: {error}");
                }
                GenerationEvent::Queued => {}
            }
        }
        return Ok(());
    }

    let out = handle.wait()?;
    println!("tokens: {:?}", out.tokens);
    println!("finish: {} | ttft {:.1} ms, decode {:.1} ms, {:.1} tok/s",
             out.reason, out.stats.ttft_ms, out.stats.decode_ms,
             out.stats.tokens_per_sec());
    Ok(())
}

/// Multi-turn chat against a running server.  Each turn sends *only the
/// new user tokens* over `{"cmd":"chat"}`; the server threads the
/// conversation history onto the prompt and replays the prior turns from
/// the session's donated prefix-cache pages, so a resumed turn prefills
/// just the new text.  The session id is assigned by the server on the
/// first turn (it arrives in the terminal frame's `session` key) and
/// reused for every turn after.
fn chat(args: &Args) -> Result<()> {
    let port = args.usize_or("port", 8747) as u16;
    let max_new = args.usize_or("max-new", 32);
    let client = quarot::server::Client::connect(port)
        .with_context(|| format!("connect to 127.0.0.1:{port} \
                                  (is `quarot serve` running?)"))?;
    let mut session: Option<u64> = args.get("session")
        .map(|s| s.parse().context("bad --session id"))
        .transpose()?;
    let parse_turn = |s: &str| -> Result<Vec<u16>> {
        s.split(',').map(|t| t.trim().parse().context("bad turn token"))
            .collect()
    };
    let mut turn_no = 0usize;
    let mut run_turn = |prompt: Vec<u16>| -> Result<()> {
        turn_no += 1;
        let handle = client
            .chat(session, &GenerationParams::new(prompt).max_new(max_new))
            .map_err(|e| anyhow!("{e}"))?;
        let out = handle.wait()?;
        if let Some(sid) = out.stats.session {
            session = Some(sid);
        }
        println!("turn {turn_no} [session {}]: {:?}",
                 session.map_or("-".into(), |s| s.to_string()), out.tokens);
        println!("  {} — ttft {:.1} ms, {:.1} tok/s",
                 out.reason, out.stats.ttft_ms, out.stats.tokens_per_sec());
        Ok(())
    };
    if let Some(spec) = args.get("turns") {
        for turn in spec.split(';') {
            run_turn(parse_turn(turn)?)?;
        }
        return Ok(());
    }
    // interactive: one comma-separated token line per turn
    println!("chat — enter comma-separated token ids per turn \
              (empty line or EOF ends)");
    let stdin = std::io::stdin();
    let mut line = String::new();
    loop {
        line.clear();
        if stdin.read_line(&mut line)? == 0 {
            break;
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            break;
        }
        run_turn(parse_turn(trimmed)?)?;
    }
    Ok(())
}

/// Drive a local sharded cluster with synthetic mixed-priority traffic
/// and print per-class latency plus the per-shard metrics table — the
/// interactive cousin of `benches/serving_cluster.rs`.
fn cluster_bench(args: &Args) -> Result<()> {
    let model = args.str_or("model", "tiny-mha");
    let spec = spec_from_args(args)?;
    let shards = args.usize_or("shards", 2);
    let pages = args.usize_or("pages", 2048);
    let n_interactive = args.usize_or("interactive", 8);
    let n_batch = args.usize_or("batch", 8);
    let max_new = args.usize_or("max-new", 16);
    let batch_max_new = args.usize_or("batch-max-new", 48);

    let art = Artifacts::load(&model)?;
    let eval_toks = art.corpus.split("eval")?.to_vec();
    if eval_toks.len() < 8 {
        bail!("eval split too short ({} tokens) for prompts", eval_toks.len());
    }
    let prefix_pages = args.usize_or("prefix-cache", pages / 2);
    let executor = executor_from_args(args)?;
    let prefill_chunk = prefill_chunk_from_args(args);
    let m = model.clone();
    let factory: EngineFactory = Arc::new(move || {
        let art = Artifacts::load(&m)?;
        let runner = runner_for_spec(&art, &spec, executor)?;
        let mut engine = GenerationEngine::new(runner, pages, 7);
        engine.set_prefill_chunk(prefill_chunk);
        engine.set_prefix_cache_pages(prefix_pages);
        Ok(engine)
    });
    let cluster = ClusterService::new(
        factory,
        ClusterConfig { shards, queue_bound: quarot::server::DEFAULT_QUEUE_BOUND });

    let span = eval_toks.len().saturating_sub(8).max(1);
    let prompt = |i: usize| {
        let off = (i * 13) % span;
        eval_toks[off..off + 8].to_vec()
    };
    let t0 = std::time::Instant::now();
    // batch backlog first, then the interactive arrivals it must not delay
    let batch: Vec<_> = (0..n_batch)
        .map(|i| cluster.submit(GenerationParams::new(prompt(i))
                                    .max_new(batch_max_new)
                                    .priority(Priority::Batch))
            .map_err(|e| anyhow!("{e}")))
        .collect::<Result<_>>()?;
    let interactive: Vec<_> = (0..n_interactive)
        .map(|i| cluster.submit(GenerationParams::new(prompt(n_batch + i))
                                    .max_new(max_new))
            .map_err(|e| anyhow!("{e}")))
        .collect::<Result<_>>()?;

    let mut tokens = 0usize;
    let mut report = |label: &str, handles: &[quarot::api::RequestHandle]|
                     -> Result<()> {
        let class = bench_support::drain_class(handles)?;
        let lat = LatencySummary::of(&class.ttfts);
        let itl = LatencySummary::of(&class.itls);
        println!("  {label:11} {} reqs, {} tokens, \
                  ttft p50 {:.1} / p99 {:.1} ms (mean {:.1}), \
                  itl p50 {:.2} / p99 {:.2} ms",
                 handles.len(), class.tokens, lat.p50_ms, lat.p99_ms,
                 lat.mean_ms, itl.p50_ms, itl.p99_ms);
        tokens += class.tokens;
        Ok(())
    };
    report("interactive", &interactive)?;
    report("batch", &batch)?;
    let wall = t0.elapsed().as_secs_f64();
    println!("  aggregate   {:.1} tok/s over {wall:.2} s wall",
             tokens as f64 / wall);
    println!("{}", cluster.metrics().render());
    Ok(())
}

/// Drain a running server's span rings into a Chrome-trace JSON file
/// (`--out`, stdout otherwise).  The server must be running with
/// `--trace-buffer N > 0`, or the document is valid but empty; each
/// invocation returns the window recorded since the previous drain.
fn trace(args: &Args) -> Result<()> {
    use quarot::util::json;
    let port = args.usize_or("port", 8747) as u16;
    let mut client = quarot::server::Client::connect(port)
        .with_context(|| format!("connect to 127.0.0.1:{port} \
                                  (is `quarot serve` running?)"))?;
    let frame = client.trace()?;
    // re-shape the wire frame into a plain Chrome-trace document:
    // chrome://tracing and Perfetto expect {"traceEvents":[..]} with no
    // protocol envelope
    let events = frame.get("traceEvents").cloned()
        .unwrap_or(json::Value::Arr(Vec::new()));
    let n_events = match &events {
        json::Value::Arr(a) => a.len(),
        _ => 0,
    };
    let doc = json::write(&json::obj(vec![("traceEvents", events)]));
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &doc)
                .with_context(|| format!("write {path}"))?;
            eprintln!("wrote {n_events} trace event(s) to {path} — open in \
                       chrome://tracing or https://ui.perfetto.dev");
            if n_events == 0 {
                eprintln!("(empty trace: is the server running with \
                           --trace-buffer N?)");
            }
        }
        None => println!("{doc}"),
    }
    Ok(())
}

fn ppl(args: &Args) -> Result<()> {
    let (art, runner) = build_runner(args)?;
    let windows = args.usize_or("windows", bench_support::eval_windows());
    let p = eval::perplexity(&runner, art.corpus.split("eval")?, windows)?;
    println!("{} / {:?}: ppl {:.4} ({} windows)",
             runner.cfg.name, runner.spec.variant, p, windows);
    Ok(())
}

fn zeroshot(args: &Args) -> Result<()> {
    let (art, runner) = build_runner(args)?;
    let items = args.usize_or("items", bench_support::probe_items());
    let (scores, avg) = eval::score_all(&runner, &art.probes, items)?;
    let mut t = Table::new("zero-shot probes", &["task", "acc"]);
    for s in &scores {
        t.row(vec![s.name.clone(), format!("{:.3}", s.accuracy)]);
    }
    t.row(vec!["Avg.".into(), format!("{avg:.3}")]);
    t.print();
    Ok(())
}

fn outliers(args: &Args) -> Result<()> {
    let model = args.str_or("model", "tiny-mha");
    let art = Artifacts::load(&model)?;
    let windows = args.usize_or("windows", 4);
    let mut t = Table::new(
        "Fig.1 — channel max/median ratio of linear-layer inputs",
        &["site", "layer", "baseline", "quarot"]);
    let base = art.calib(false, windows)?;
    let rot = art.calib(true, windows)?;
    let sb = eval::outlier_stats(&base.amax);
    let sr = eval::outlier_stats(&rot.amax);
    let site_names = ["attn-in", "out-proj-in", "ffn-in", "down-proj-in"];
    for (b, r) in sb.iter().zip(&sr) {
        t.row(vec![
            site_names[b.site].into(),
            format!("{}", b.layer),
            format!("{:.2}", b.ratio),
            format!("{:.2}", r.ratio),
        ]);
    }
    t.print();
    Ok(())
}

fn verify(args: &Args) -> Result<()> {
    let model = args.str_or("model", "tiny-mha");
    let art = Artifacts::load(&model)?;
    let engine = art.engine_graphs(&[])?; // manifest only
    // precedence: --rotation flag > manifest `rotation` field > hadamard
    let kind = match args.get("rotation") {
        Some(r) => RotationKind::parse(r)?,
        None => match engine.manifest.rotation.as_deref() {
            Some(r) => RotationKind::parse(r)
                .context("manifest `rotation` field")?,
            None => RotationKind::default(),
        },
    };
    let mismatch =
        rotation::verify_mismatch(kind, &engine.manifest.model, &art.weights)?;
    println!("rust-vs-python rotation relative mismatch ({kind}): \
              {mismatch:.3e}");
    if mismatch > 1e-3 {
        bail!("transform mismatch too large");
    }
    println!("OK — rust QuaRot transform reproduces the python artifacts");
    Ok(())
}

fn info(args: &Args) -> Result<()> {
    let model = args.str_or("model", "tiny-mha");
    let art = Artifacts::load(&model)?;
    let engine = art.engine_graphs(&[])?;
    let m = &engine.manifest;
    println!("model {}: d={} L={} heads={}/{} dff={} vocab={} (train ppl {:.2})",
             m.model.name, m.model.d_model, m.model.n_layers, m.model.n_heads,
             m.model.n_kv_heads, m.model.d_ff, m.model.vocab, m.model.train_ppl);
    println!("graphs:");
    for g in &m.graphs {
        println!("  {:24} {:2} inputs {:2} outputs  ({})",
                 g.name, g.inputs.len(), g.outputs.len(), g.file);
    }
    Ok(())
}
