//! `quarot-lint` — repo-specific source lints, run in CI and locally
//! via `cargo run --bin quarot-lint` (exit code 0 = clean).
//!
//! Rules:
//!
//! 1. `wire-keys` — the pair lists behind the `stats` / `metrics` /
//!    `per_shard` / `finished` frames (rust/src/cluster/metrics.rs,
//!    rust/src/api/wire.rs) must match tests/golden/wire_keys.txt in
//!    order.  New keys may only be *appended*, and must be appended to
//!    the golden in the same change.  (util::json serializes objects
//!    alphabetically, so source pair order is the only place the
//!    append-only contract is observable — rust/tests/wire_golden.rs
//!    covers the runtime half.)
//! 2. `no-unwrap` — non-test code under rust/src must not call
//!    `.unwrap()` / `.expect(`; deliberate exceptions are listed in
//!    quarot-lint.allow as `path: trimmed line`.  Allow entries that no
//!    longer match anything are themselves findings, so the list can
//!    only shrink.
//! 3. `bench-check` — every benches/*.rs must expose a `-- --check`
//!    smoke mode (the CI acceptance hook).
//! 4. `pub-docs` — every `pub` item declaration (fn / struct / enum /
//!    trait / const / static / type) in rust/src/api, rust/src/cluster
//!    and rust/src/telemetry carries a `///` doc comment.  `pub use`
//!    re-exports, `pub mod` declarations (documented module-side with
//!    `//!`) and struct fields are out of scope.
//!
//! The analyzer is deliberately line-based, std-only and dependency
//! free: string/char literals are blanked and `//` comments stripped
//! before matching, and everything from the first `#[cfg(test)]` to
//! end-of-file is skipped (test modules sit at the bottom of files in
//! this repo).

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

struct Finding {
    /// repo-relative path
    file: String,
    /// 1-based; 0 for whole-file findings
    line: usize,
    rule: &'static str,
    msg: String,
    /// set on rule-2 findings: the `path: trimmed line` allowlist key
    allow_key: Option<String>,
}

fn main() -> ExitCode {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    match run(root) {
        Ok(findings) if findings.is_empty() => {
            println!("quarot-lint: clean");
            ExitCode::SUCCESS
        }
        Ok(findings) => {
            for f in &findings {
                eprintln!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.msg);
            }
            eprintln!("quarot-lint: {} finding(s)", findings.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("quarot-lint: error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run(root: &Path) -> Result<Vec<Finding>, String> {
    let mut findings = Vec::new();
    check_wire_keys(root, &mut findings)?;
    check_unwrap_policy(root, &mut findings)?;
    check_bench_check(root, &mut findings)?;
    check_pub_docs(root, &mut findings)?;
    apply_allowlist(root, &mut findings)?;
    Ok(findings)
}

// ---------------------------------------------------------------- util

fn read(root: &Path, rel: &str) -> Result<String, String> {
    fs::read_to_string(root.join(rel)).map_err(|e| format!("read {rel}: {e}"))
}

fn rel_path(root: &Path, p: &Path) -> String {
    p.strip_prefix(root).unwrap_or(p).display().to_string()
}

/// All .rs files under `dir`, recursively, in sorted order.
fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let rd = fs::read_dir(dir).map_err(|e| format!("scan {}: {e}", dir.display()))?;
    let mut paths = Vec::new();
    for ent in rd {
        let ent = ent.map_err(|e| format!("scan {}: {e}", dir.display()))?;
        paths.push(ent.path());
    }
    paths.sort();
    for p in paths {
        if p.is_dir() {
            rs_files(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Where a char literal starting at `bytes[start] == '\''` ends
/// (exclusive), or None if this is a lifetime tick.
fn char_literal_end(bytes: &[u8], start: usize) -> Option<usize> {
    let next = *bytes.get(start + 1)?;
    if next == b'\\' {
        // escaped: '\n', '\\', '\u{1f600}', ... — scan to the close
        let mut i = start + 3;
        while i < bytes.len() && bytes[i] != b'\'' {
            i += 1;
        }
        if i < bytes.len() {
            return Some(i + 1);
        }
        return None;
    }
    // plain 'x' (possibly multibyte): close quote within a few bytes;
    // anything farther is a lifetime ('a, 'static)
    let limit = (start + 6).min(bytes.len());
    (start + 2..limit).find(|&j| bytes[j] == b'\'').map(|j| j + 1)
}

/// Strip `//` comments (outside literals); with `blank_strings`, also
/// blank out string/char-literal contents so needles inside them never
/// match.
fn scrub(line: &str, blank_strings: bool) -> String {
    let bytes = line.as_bytes();
    let mut out = String::with_capacity(line.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'"' => {
                let start = i;
                i += 1;
                while i < bytes.len() {
                    match bytes[i] {
                        b'\\' => i += 2,
                        b'"' => {
                            i += 1;
                            break;
                        }
                        _ => i += 1,
                    }
                }
                if blank_strings {
                    out.push_str("\"\"");
                } else {
                    let end = i.min(bytes.len());
                    out.push_str(&String::from_utf8_lossy(&bytes[start..end]));
                }
            }
            b'\'' => {
                if let Some(end) = char_literal_end(bytes, i) {
                    if blank_strings {
                        out.push_str("' '");
                    } else {
                        out.push_str(&String::from_utf8_lossy(&bytes[i..end]));
                    }
                    i = end;
                } else {
                    out.push('\'');
                    i += 1;
                }
            }
            b'/' if bytes.get(i + 1) == Some(&b'/') => break,
            b => {
                out.push(b as char);
                i += 1;
            }
        }
    }
    out
}

// ------------------------------------------------------- rule 1: wire

/// Golden file sections: `[name]` headers, one key per line, trailing
/// `?` = optional (presence varies, position does not).
fn parse_golden(text: &str) -> Vec<(String, Vec<String>)> {
    let mut sections: Vec<(String, Vec<String>)> = Vec::new();
    for raw in text.lines() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            sections.push((name.to_string(), Vec::new()));
        } else if let Some((_, keys)) = sections.last_mut() {
            keys.push(line.strip_suffix('?').unwrap_or(line).to_string());
        }
    }
    sections
}

fn golden_section<'a>(sections: &'a [(String, Vec<String>)], name: &str)
                      -> Result<&'a [String], String> {
    sections.iter()
        .find(|(n, _)| n == name)
        .map(|(_, keys)| keys.as_slice())
        .ok_or_else(|| format!("tests/golden/wire_keys.txt: section [{name}] missing"))
}

/// The source slice from `start_marker` to (exclusive) `end_marker`.
fn source_region<'a>(text: &'a str, start_marker: &str, end_marker: &str,
                     rel: &str) -> Result<(usize, &'a str), String> {
    let s = text.find(start_marker).ok_or_else(|| {
        format!("{rel}: marker `{start_marker}` not found — update quarot-lint's wire-key rule")
    })?;
    let line = text[..s].lines().count().max(1);
    let rest = &text[s..];
    let e = rest.find(end_marker).unwrap_or(rest.len());
    Ok((line, &rest[..e]))
}

/// Extract `("key",` literals, in order, from a comment-stripped region.
fn pair_keys(region: &str) -> Vec<String> {
    let mut keys = Vec::new();
    for line in region.lines() {
        let clean = scrub(line, false);
        let bytes = clean.as_bytes();
        let mut i = 0;
        while i + 1 < bytes.len() {
            if bytes[i] == b'(' && bytes[i + 1] == b'"' {
                let mut j = i + 2;
                while j < bytes.len() && bytes[j] != b'"' {
                    j += 1;
                }
                if j + 1 < bytes.len() && bytes[j + 1] == b',' {
                    keys.push(clean[i + 2..j].to_string());
                }
                i = j + 1;
            } else {
                i += 1;
            }
        }
    }
    keys
}

/// Append-only check: `golden` must be an exact prefix of `actual`;
/// keys past the golden are new and must be recorded there.
fn compare_keys(golden: &[String], actual: &[String], what: &str,
                file: &str, line: usize, findings: &mut Vec<Finding>) {
    for (i, name) in golden.iter().enumerate() {
        if actual.get(i).map(String::as_str) != Some(name.as_str()) {
            findings.push(Finding {
                file: file.to_string(),
                line,
                rule: "wire-keys",
                msg: format!(
                    "{what}: key #{i} is {:?} but the golden says {name:?} — \
                     wire keys are append-only (tests/golden/wire_keys.txt)",
                    actual.get(i).map(String::as_str).unwrap_or("<missing>")),
                allow_key: None,
            });
            return;
        }
    }
    if actual.len() > golden.len() {
        findings.push(Finding {
            file: file.to_string(),
            line,
            rule: "wire-keys",
            msg: format!(
                "{what}: new key(s) {:?} not recorded in \
                 tests/golden/wire_keys.txt — append them to the section",
                &actual[golden.len()..]),
            allow_key: None,
        });
    }
}

fn check_wire_keys(root: &Path, findings: &mut Vec<Finding>)
                   -> Result<(), String> {
    let golden = parse_golden(&read(root, "tests/golden/wire_keys.txt")?);
    let stats = golden_section(&golden, "stats")?;
    let per_shard = golden_section(&golden, "per_shard")?;
    let finished = golden_section(&golden, "finished")?;
    let envelope = ["v".to_string(), "event".to_string()];
    if stats.len() < 3 || stats[..2] != envelope || finished.len() < 4
        || finished[..2] != envelope || finished[2] != "id" {
        return Err("tests/golden/wire_keys.txt: [stats] must open with \
                    v,event and [finished] with v,event,id".to_string());
    }

    let metrics_rel = "rust/src/cluster/metrics.rs";
    let metrics = read(root, metrics_rel)?;
    let (line, region) =
        source_region(&metrics, "fn summary_pairs", "fn full_pairs", metrics_rel)?;
    compare_keys(&stats[2..], &pair_keys(region), "summary_pairs()",
                 metrics_rel, line, findings);

    let (line, region) =
        source_region(&metrics, "fn to_value", "impl ClusterMetrics", metrics_rel)?;
    compare_keys(per_shard, &pair_keys(region), "ShardMetrics::to_value()",
                 metrics_rel, line, findings);

    let (line, region) =
        source_region(&metrics, "fn full_pairs", "fn render", metrics_rel)?;
    if pair_keys(region) != ["per_shard"] {
        findings.push(Finding {
            file: metrics_rel.to_string(),
            line,
            rule: "wire-keys",
            msg: "full_pairs() must extend summary_pairs() with exactly \
                  one appended `per_shard` key".to_string(),
            allow_key: None,
        });
    }

    let wire_rel = "rust/src/api/wire.rs";
    let wire = read(root, wire_rel)?;
    let (line, region) = source_region(
        &wire, "GenerationEvent::Finished { reason, stats } =>",
        "GenerationEvent::Failed", wire_rel)?;
    // `id` rides in via the shared `idv` binding, not a literal pair
    if !region.contains("idv") {
        findings.push(Finding {
            file: wire_rel.to_string(),
            line,
            rule: "wire-keys",
            msg: "finished frame no longer leads with the shared `idv` \
                  id pair".to_string(),
            allow_key: None,
        });
    }
    compare_keys(&finished[3..], &pair_keys(region), "finished frame",
                 wire_rel, line, findings);

    if !(wire.contains("pairs.insert(0, (\"v\"")
         && wire.contains("pairs.insert(1, (\"event\"")) {
        findings.push(Finding {
            file: wire_rel.to_string(),
            line: 0,
            rule: "wire-keys",
            msg: "tag() no longer pins `v` / `event` at the head of every \
                  frame".to_string(),
            allow_key: None,
        });
    }
    Ok(())
}

// --------------------------------------------------- rule 2: no-unwrap

fn check_unwrap_policy(root: &Path, findings: &mut Vec<Finding>)
                       -> Result<(), String> {
    let mut files = Vec::new();
    rs_files(&root.join("rust/src"), &mut files)?;
    for path in files {
        let rel = rel_path(root, &path);
        let text = fs::read_to_string(&path).map_err(|e| format!("read {rel}: {e}"))?;
        for (idx, raw) in text.lines().enumerate() {
            let code = scrub(raw, true);
            // scrubbed, so the attribute in a comment or string (this
            // file's own docs, say) doesn't end the scan early
            if code.contains("#[cfg(test)]") {
                break; // test modules sit at the bottom of the file
            }
            for needle in [".unwrap()", ".expect("] {
                if code.contains(needle) {
                    findings.push(Finding {
                        file: rel.clone(),
                        line: idx + 1,
                        rule: "no-unwrap",
                        msg: format!(
                            "`{needle}` in non-test code — recover or \
                             propagate, or record the line in \
                             quarot-lint.allow"),
                        allow_key: Some(format!("{rel}: {}", raw.trim())),
                    });
                }
            }
        }
    }
    Ok(())
}

// ------------------------------------------------- rule 3: bench-check

fn check_bench_check(root: &Path, findings: &mut Vec<Finding>)
                     -> Result<(), String> {
    let mut files = Vec::new();
    rs_files(&root.join("benches"), &mut files)?;
    for path in files {
        let rel = rel_path(root, &path);
        let text = fs::read_to_string(&path).map_err(|e| format!("read {rel}: {e}"))?;
        // `CheckSink::new` parses `--check` itself, so using it counts
        if !text.contains("--check") && !text.contains("CheckSink") {
            findings.push(Finding {
                file: rel,
                line: 0,
                rule: "bench-check",
                msg: "bench lacks a `-- --check` smoke mode (every bench \
                      must be runnable as a CI acceptance check; use \
                      bench_support::CheckSink)".to_string(),
                allow_key: None,
            });
        }
    }
    Ok(())
}

// ---------------------------------------------------- rule 4: pub-docs

const DOC_ITEM_KEYWORDS: [&str; 7] =
    ["fn", "struct", "enum", "trait", "const", "static", "type"];

fn is_pub_item(trimmed: &str) -> bool {
    let Some(rest) = trimmed.strip_prefix("pub ") else {
        return false;
    };
    // `pub unsafe fn`, `pub async fn` would land here too if they ever
    // appear; today the repo is sync + safe, so plain keywords suffice.
    DOC_ITEM_KEYWORDS.iter().any(|kw| {
        rest.strip_prefix(kw)
            .is_some_and(|r| r.starts_with(' ') || r.starts_with('<'))
    })
}

fn check_pub_docs(root: &Path, findings: &mut Vec<Finding>)
                  -> Result<(), String> {
    for sub in ["rust/src/api", "rust/src/cluster", "rust/src/forward",
                "rust/src/telemetry"] {
        let mut files = Vec::new();
        rs_files(&root.join(sub), &mut files)?;
        for path in files {
            let rel = rel_path(root, &path);
            let text = fs::read_to_string(&path)
                .map_err(|e| format!("read {rel}: {e}"))?;
            let lines: Vec<&str> = text.lines().collect();
            for (idx, raw) in lines.iter().enumerate() {
                if scrub(raw, true).contains("#[cfg(test)]") {
                    break;
                }
                let trimmed = raw.trim_start();
                if !is_pub_item(trimmed) {
                    continue;
                }
                // walk back over attribute lines to the doc (or not)
                let mut j = idx;
                while j > 0 && lines[j - 1].trim_start().starts_with("#[") {
                    j -= 1;
                }
                let documented =
                    j > 0 && lines[j - 1].trim_start().starts_with("///");
                if !documented {
                    let name = trimmed.split_whitespace().take(3)
                        .collect::<Vec<_>>().join(" ");
                    findings.push(Finding {
                        file: rel.clone(),
                        line: idx + 1,
                        rule: "pub-docs",
                        msg: format!("public item `{name} ...` has no doc \
                                      comment"),
                        allow_key: None,
                    });
                }
            }
        }
    }
    Ok(())
}

// -------------------------------------------------------- allowlisting

fn apply_allowlist(root: &Path, findings: &mut Vec<Finding>)
                   -> Result<(), String> {
    let path = root.join("quarot-lint.allow");
    let text = match fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => String::new(),
        Err(e) => return Err(format!("read quarot-lint.allow: {e}")),
    };
    let entries: Vec<(usize, String)> = text.lines().enumerate()
        .map(|(i, l)| (i + 1, l.trim()))
        .filter(|(_, l)| !l.is_empty() && !l.starts_with('#'))
        .map(|(i, l)| (i, l.to_string()))
        .collect();
    let mut used = vec![false; entries.len()];
    findings.retain(|f| {
        let Some(key) = &f.allow_key else {
            return true;
        };
        match entries.iter().position(|(_, e)| e == key) {
            Some(pos) => {
                used[pos] = true;
                false // deliberately allowed
            }
            None => true,
        }
    });
    for (pos, (lineno, entry)) in entries.iter().enumerate() {
        if !used[pos] {
            findings.push(Finding {
                file: "quarot-lint.allow".to_string(),
                line: *lineno,
                rule: "stale-allow",
                msg: format!("entry matches no finding any more — remove \
                              it: `{entry}`"),
                allow_key: None,
            });
        }
    }
    Ok(())
}
