//! Pluggable compute backends for the QuaRot hot paths.
//!
//! The paper's end-to-end wins (Tables 14–16) come from routing every
//! matmul, online Hadamard and KV quant op through a fast low-bit kernel.
//! This subsystem makes that routing explicit: [`ComputeBackend`] covers
//! the hot ops, and three implementations ship today —
//!
//! * [`ScalarRef`] — the original naive kernels, kept as the bit-exact
//!   correctness oracle and bench baseline;
//! * [`Blocked`]   — cache-blocked, column-tiled kernels (weights stream
//!   once instead of once per activation row);
//! * [`Threaded`]  — the blocked kernels fanned over a home-grown
//!   persistent worker pool ([`pool`]), partitioning over output columns
//!   for GEMMs and over batch slots for the decode tick.
//!
//! Selection: the engine defaults to [`BackendKind::Auto`], which picks
//! per call by shape and available parallelism.  Explicit override comes
//! from the `--backend` CLI flag ([`set_default`]) or the
//! `QUAROT_BACKEND` env var; `QUAROT_THREADS` caps the pool.
//!
//! Later backends (SIMD microkernels, sharded/NUMA pools, GPU offload)
//! are drop-in `ComputeBackend` impls — nothing above this module needs
//! to change.

pub mod blocked;
pub mod pool;
pub mod scalar;
pub mod threaded;

pub use blocked::Blocked;
pub use scalar::ScalarRef;
pub use threaded::Threaded;

use std::cell::RefCell;
use std::sync::{Arc, Mutex};

use crate::attention::{DecodeF32Seq, DecodeQuantSeq, DecodeScratch, KvCodes};
use crate::gemm::{WeightsF32, WeightsI4, WeightsI8};

thread_local! {
    // Reused decode scratch, one instance per thread — the single-thread
    // backends reuse it across calls and every `Threaded` pool lane gets
    // its own, so the decode tick never pays a per-call (or per-task)
    // allocation on the serving hot path.
    pub(crate) static DECODE_SCRATCH: RefCell<DecodeScratch> =
        RefCell::new(DecodeScratch::default());
}

/// The kernel surface every backend provides.  All GEMMs take activations
/// row-major `(t × k)` and the column-major weight containers from
/// [`crate::gemm`]; int paths fuse per-token activation quantization and
/// the dequant epilogue exactly like the scalar reference.
pub trait ComputeBackend: Send + Sync {
    /// Short stable name ("scalar" / "blocked" / "threaded" / "auto").
    fn name(&self) -> &'static str;

    /// `y (t×n) = x (t×k) @ W`, f32.
    fn gemm_f32(&self, x: &[f32], t: usize, w: &WeightsF32, y: &mut [f32]);

    /// Fused linear layer: per-token symmetric activation quant at
    /// `bits`, int8-code GEMM with i32 accumulation, dequant epilogue.
    fn gemm_i8(&self, x: &[f32], t: usize, w: &WeightsI8, bits: u32, clip: f32,
               y: &mut [f32]);

    /// As [`gemm_i8`](Self::gemm_i8) with nibble-packed int4 weights.
    fn gemm_i4(&self, x: &[f32], t: usize, w: &WeightsI4, clip: f32, y: &mut [f32]);

    /// Online Hadamard: orthonormal WHT applied to every `d`-length row.
    fn had_rows(&self, x: &mut [f32], d: usize);

    /// Per-token symmetric activation quantization: `codes` receives the
    /// `(rows × d)` int codes, `scales` one scale per row.
    fn quant_rows(&self, x: &[f32], d: usize, bits: u32, clip: f32,
                  codes: &mut [i8], scales: &mut [f32]);

    /// Group-wise asymmetric KV quantization of a `(rows × d)` slab
    /// (layout identical to [`crate::quant::kv::quant_slab`]).
    fn kv_quant_slab(&self, x: &[f32], d: usize, group: usize, bits: u32, clip: f32)
                     -> (Vec<i8>, Vec<f32>, Vec<f32>);

    /// Dequantize grouped KV codes into `out` (staging refresh path).
    fn kv_dequant(&self, codes: &[i8], scales: &[f32], zeros: &[f32],
                  group: usize, out: &mut [f32]);

    /// Batched decode attention over f32 KV streams: one step for every
    /// sequence in `seqs` (ragged lengths allowed; an empty cache yields a
    /// zero output).  `out` is `seqs.len() × n_heads × d_head`, sequence-
    /// major.  All sequences must share the kv geometry.
    fn decode_f32_batch(&self, seqs: &[DecodeF32Seq<'_>], n_heads: usize,
                        out: &mut [f32]);

    /// As [`decode_f32_batch`](Self::decode_f32_batch) over group-wise
    /// quantized KV streams (packed int4 or unpacked i8 codes), fusing the
    /// affine dequant into the score/value reductions like the oracle.
    fn decode_quant_batch(&self, seqs: &[DecodeQuantSeq<'_>], n_heads: usize,
                          out: &mut [f32]);

    /// Batched log-softmax / NLL reduction: `out[r]` receives the negative
    /// log-probability of `targets[r]` under row `r` of `logits`
    /// (`targets.len()` rows of `vocab` logits).  The eval harness'
    /// perplexity windows and continuation scores run through this.
    fn nll_rows(&self, logits: &[f32], vocab: usize, targets: &[u16],
                out: &mut [f64]);

    /// Run `f(i)` for `i in 0..n`, possibly in parallel (used by the
    /// decode tick to partition staging refresh over batch slots).
    /// Tasks must touch disjoint state.
    fn par_for(&self, n: usize, f: &(dyn Fn(usize) + Sync));
}

// ---------------------------------------------------------------------------
// shared sequential helpers (ScalarRef + Blocked row-wise ops)

pub(crate) fn wht_rows_seq(x: &mut [f32], d: usize) {
    for row in x.chunks_exact_mut(d) {
        crate::hadamard::wht(row);
    }
}

pub(crate) fn quantize_rows(x: &[f32], d: usize, bits: u32, clip: f32)
                            -> (Vec<i8>, Vec<f32>) {
    let rows = x.len() / d;
    let mut codes = vec![0i8; rows * d];
    let mut scales = vec![0.0f32; rows];
    for (r, row) in x.chunks_exact(d).enumerate() {
        scales[r] = crate::gemm::quant_row(row, bits, clip,
                                           &mut codes[r * d..(r + 1) * d]);
    }
    (codes, scales)
}

pub(crate) fn kv_quant_seq(x: &[f32], d: usize, group: usize, bits: u32, clip: f32)
                           -> (Vec<i8>, Vec<f32>, Vec<f32>) {
    crate::quant::kv::quant_slab(x, d, group, bits, clip)
}

pub(crate) fn kv_dequant_seq(codes: &[i8], scales: &[f32], zeros: &[f32],
                             group: usize, out: &mut [f32]) {
    for (g, o) in out.chunks_exact_mut(group).enumerate() {
        crate::quant::kv::dequant_group(&codes[g * group..(g + 1) * group],
                                        scales[g], zeros[g], o);
    }
}

/// log-softmax of one index over one logits row, f64 accumulation — the
/// scalar oracle behind [`ComputeBackend::nll_rows`] (and the single-row
/// `sampler::log_softmax_at` helper).
pub(crate) fn log_softmax_row(logits: &[f32], idx: usize) -> f64 {
    let mx = logits.iter().fold(f32::MIN, |m, &v| m.max(v)) as f64;
    let lse: f64 =
        logits.iter().map(|&v| ((v as f64) - mx).exp()).sum::<f64>().ln() + mx;
    logits[idx] as f64 - lse
}

pub(crate) fn nll_rows_seq(logits: &[f32], vocab: usize, targets: &[u16],
                           out: &mut [f64]) {
    assert!(vocab > 0 && logits.len() >= targets.len() * vocab,
            "nll_rows: {} logits for {} rows of {vocab}",
            logits.len(), targets.len());
    assert!(out.len() >= targets.len());
    for (r, (&tgt, o)) in targets.iter().zip(out.iter_mut()).enumerate() {
        let row = &logits[r * vocab..(r + 1) * vocab];
        *o = -log_softmax_row(row, tgt as usize);
    }
}

// ---------------------------------------------------------------------------
// batched-decode geometry checks (shared by all backends)

/// Uniform geometry of one decode batch.
#[derive(Clone, Copy)]
pub(crate) struct DecodeGeom {
    pub hk: usize,
    pub dh: usize,
    pub rep: usize,
    /// total score work (MACs) across the batch, for auto dispatch
    pub macs: usize,
}

pub(crate) fn f32_batch_geom(seqs: &[DecodeF32Seq], n_heads: usize,
                             out_len: usize) -> Option<DecodeGeom> {
    if seqs.is_empty() {
        assert_eq!(out_len, 0, "decode batch: out for an empty batch");
        return None;
    }
    let first = seqs.first()?;
    let (hk, dh) = (first.k.n_kv_heads, first.k.d_head);
    assert!(hk > 0 && dh > 0 && n_heads % hk == 0,
            "decode batch: {n_heads} q-heads not a multiple of {hk} kv-heads");
    assert_eq!(out_len, seqs.len() * n_heads * dh, "decode batch: out length");
    let mut macs = 0usize;
    for seq in seqs {
        for kv in [&seq.k, &seq.v] {
            assert!(kv.n_kv_heads == hk && kv.d_head == dh,
                    "decode batch: mixed kv geometry");
            assert!(kv.data.len() >= kv.len * hk * dh,
                    "decode batch: kv stream shorter than its length");
        }
        assert_eq!(seq.k.len, seq.v.len, "decode batch: k/v length mismatch");
        assert_eq!(seq.q.len(), n_heads * dh, "decode batch: q length");
        macs += 2 * seq.k.len * n_heads * dh;
    }
    Some(DecodeGeom { hk, dh, rep: n_heads / hk, macs })
}

pub(crate) fn quant_batch_geom(seqs: &[DecodeQuantSeq], n_heads: usize,
                               out_len: usize) -> Option<DecodeGeom> {
    if seqs.is_empty() {
        assert_eq!(out_len, 0, "decode batch: out for an empty batch");
        return None;
    }
    let first = seqs.first()?;
    let (hk, dh, group) = (first.k.n_kv_heads, first.k.d_head, first.k.group);
    assert!(hk > 0 && dh > 0 && n_heads % hk == 0,
            "decode batch: {n_heads} q-heads not a multiple of {hk} kv-heads");
    assert!(group > 0 && dh % group == 0,
            "decode batch: group {group} must divide d_head {dh}");
    assert_eq!(out_len, seqs.len() * n_heads * dh, "decode batch: out length");
    let d = hk * dh;
    let gpt = d / group;
    let mut macs = 0usize;
    for seq in seqs {
        for kv in [&seq.k, &seq.v] {
            assert!(kv.n_kv_heads == hk && kv.d_head == dh && kv.group == group,
                    "decode batch: mixed kv geometry");
            let codes_len = match kv.codes {
                KvCodes::I8(c) => c.len(),
                KvCodes::Packed4(c) => {
                    assert!(group % 2 == 0,
                            "decode batch: packed int4 needs an even group");
                    c.len() * 2
                }
            };
            assert!(codes_len >= kv.len * d,
                    "decode batch: code stream shorter than its length");
            assert!(kv.scales.len() >= kv.len * gpt
                        && kv.zeros.len() >= kv.len * gpt,
                    "decode batch: scales/zeros shorter than the stream");
        }
        assert_eq!(seq.k.len, seq.v.len, "decode batch: k/v length mismatch");
        assert_eq!(seq.q.len(), n_heads * dh, "decode batch: q length");
        macs += 2 * seq.k.len * n_heads * dh;
    }
    Some(DecodeGeom { hk, dh, rep: n_heads / hk, macs })
}

// ---------------------------------------------------------------------------
// auto-selection

/// Work thresholds (MACs / elements) above which threading pays for the
/// dispatch+wakeup overhead on the serving shapes; below them the blocked
/// single-thread kernels win.
const GEMM_THREAD_MIN_MACS: usize = 1 << 18;
const ROWWISE_THREAD_MIN_ELEMS: usize = 1 << 15;

/// Shape-aware dispatcher: blocked kernels for small ops, the worker pool
/// for large ones; degrades to single-thread when the host (or
/// `QUAROT_THREADS=1`) has no parallelism.
pub struct Auto {
    blocked: Blocked,
    threaded: Option<Threaded>,
}

impl Auto {
    pub fn new() -> Auto {
        Auto {
            blocked: Blocked,
            threaded: (pool::parallelism() > 1).then(Threaded::new),
        }
    }

    fn for_gemm(&self, macs: usize) -> &dyn ComputeBackend {
        match &self.threaded {
            Some(th) if macs >= GEMM_THREAD_MIN_MACS => th,
            _ => &self.blocked,
        }
    }

    fn for_rowwise(&self, elems: usize) -> &dyn ComputeBackend {
        match &self.threaded {
            Some(th) if elems >= ROWWISE_THREAD_MIN_ELEMS => th,
            _ => &self.blocked,
        }
    }
}

impl Default for Auto {
    fn default() -> Auto {
        Auto::new()
    }
}

impl ComputeBackend for Auto {
    fn name(&self) -> &'static str {
        "auto"
    }

    fn gemm_f32(&self, x: &[f32], t: usize, w: &WeightsF32, y: &mut [f32]) {
        self.for_gemm(t * w.k * w.n).gemm_f32(x, t, w, y);
    }

    fn gemm_i8(&self, x: &[f32], t: usize, w: &WeightsI8, bits: u32, clip: f32,
               y: &mut [f32]) {
        self.for_gemm(t * w.k * w.n).gemm_i8(x, t, w, bits, clip, y);
    }

    fn gemm_i4(&self, x: &[f32], t: usize, w: &WeightsI4, clip: f32, y: &mut [f32]) {
        self.for_gemm(t * w.k * w.n).gemm_i4(x, t, w, clip, y);
    }

    fn had_rows(&self, x: &mut [f32], d: usize) {
        self.for_rowwise(x.len()).had_rows(x, d);
    }

    fn quant_rows(&self, x: &[f32], d: usize, bits: u32, clip: f32,
                  codes: &mut [i8], scales: &mut [f32]) {
        self.for_rowwise(x.len()).quant_rows(x, d, bits, clip, codes, scales);
    }

    fn kv_quant_slab(&self, x: &[f32], d: usize, group: usize, bits: u32, clip: f32)
                     -> (Vec<i8>, Vec<f32>, Vec<f32>) {
        self.for_rowwise(x.len()).kv_quant_slab(x, d, group, bits, clip)
    }

    fn kv_dequant(&self, codes: &[i8], scales: &[f32], zeros: &[f32],
                  group: usize, out: &mut [f32]) {
        self.for_rowwise(out.len()).kv_dequant(codes, scales, zeros, group, out);
    }

    fn decode_f32_batch(&self, seqs: &[DecodeF32Seq<'_>], n_heads: usize,
                        out: &mut [f32]) {
        let Some(geom) = f32_batch_geom(seqs, n_heads, out.len()) else {
            return;
        };
        self.for_gemm(geom.macs).decode_f32_batch(seqs, n_heads, out);
    }

    fn decode_quant_batch(&self, seqs: &[DecodeQuantSeq<'_>], n_heads: usize,
                          out: &mut [f32]) {
        let Some(geom) = quant_batch_geom(seqs, n_heads, out.len()) else {
            return;
        };
        self.for_gemm(geom.macs).decode_quant_batch(seqs, n_heads, out);
    }

    fn nll_rows(&self, logits: &[f32], vocab: usize, targets: &[u16],
                out: &mut [f64]) {
        self.for_rowwise(logits.len()).nll_rows(logits, vocab, targets, out);
    }

    fn par_for(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        match &self.threaded {
            Some(th) if n > 1 => th.par_for(n, f),
            _ => {
                for i in 0..n {
                    f(i);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// selection plumbing

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    Scalar,
    Blocked,
    Threaded,
    Auto,
}

impl BackendKind {
    /// Parse a CLI / env spelling; `None` for unknown values.
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" | "scalar-ref" | "ref" => Some(BackendKind::Scalar),
            "blocked" => Some(BackendKind::Blocked),
            "threaded" | "threads" => Some(BackendKind::Threaded),
            "auto" => Some(BackendKind::Auto),
            _ => None,
        }
    }

    pub fn all() -> [BackendKind; 4] {
        [BackendKind::Scalar, BackendKind::Blocked, BackendKind::Threaded,
         BackendKind::Auto]
    }
}

/// Instantiate a backend of the given kind.
pub fn make(kind: BackendKind) -> Arc<dyn ComputeBackend> {
    match kind {
        BackendKind::Scalar => Arc::new(ScalarRef),
        BackendKind::Blocked => Arc::new(Blocked),
        BackendKind::Threaded => Arc::new(Threaded::new()),
        BackendKind::Auto => Arc::new(Auto::new()),
    }
}

static OVERRIDE: Mutex<Option<BackendKind>> = Mutex::new(None);

/// Process-wide explicit selection (the `--backend` flag); wins over the
/// `QUAROT_BACKEND` env var.
pub fn set_default(kind: BackendKind) {
    *OVERRIDE.lock().unwrap() = Some(kind);
}

/// Effective default kind: explicit [`set_default`] override, else
/// `QUAROT_BACKEND`, else [`BackendKind::Auto`].
pub fn default_kind() -> BackendKind {
    if let Some(k) = *OVERRIDE.lock().unwrap() {
        return k;
    }
    if let Ok(v) = std::env::var("QUAROT_BACKEND") {
        if let Some(k) = BackendKind::parse(&v) {
            return k;
        }
    }
    BackendKind::Auto
}

/// Construct the process-default backend (what `Runner::new` uses).
pub fn default_backend() -> Arc<dyn ComputeBackend> {
    make(default_kind())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn alt_backends() -> Vec<Box<dyn ComputeBackend>> {
        vec![Box::new(Blocked), Box::new(Threaded::new()), Box::new(Auto::new())]
    }

    /// Satellite contract: Blocked/Threaded are bit-exact with ScalarRef
    /// on the int8/int4 paths and within 1e-5 on f32, across random
    /// shapes including ragged K/N.
    #[test]
    fn backends_match_scalar_on_random_shapes() {
        prop::check("backend-vs-scalar", 12, |rng| {
            let t = 1 + rng.below(5);
            let k = 1 + rng.below(97); // ragged, including odd K (int4 tail)
            let n = 1 + rng.below(67); // ragged N (partial column tiles)
            let x = rng.normal_vec(t * k);
            let w = rng.normal_vec(k * n);
            let wf = WeightsF32::from_row_major(&w, k, n);
            let w8 = WeightsI8::quantize(&w, k, n, 8);
            let w4 = WeightsI4::quantize(&w, k, n);

            let oracle = ScalarRef;
            let mut yf_ref = vec![0.0f32; t * n];
            let mut y8_ref = vec![0.0f32; t * n];
            let mut y4_ref = vec![0.0f32; t * n];
            oracle.gemm_f32(&x, t, &wf, &mut yf_ref);
            oracle.gemm_i8(&x, t, &w8, 8, 0.9, &mut y8_ref);
            oracle.gemm_i4(&x, t, &w4, 0.9, &mut y4_ref);
            let fscale = yf_ref.iter().fold(1.0f32, |m, &v| m.max(v.abs()));

            for be in alt_backends() {
                let mut yf = vec![0.0f32; t * n];
                let mut y8 = vec![0.0f32; t * n];
                let mut y4 = vec![0.0f32; t * n];
                be.gemm_f32(&x, t, &wf, &mut yf);
                be.gemm_i8(&x, t, &w8, 8, 0.9, &mut y8);
                be.gemm_i4(&x, t, &w4, 0.9, &mut y4);
                crate::prop_assert!(y8 == y8_ref,
                    "{} int8 not bit-exact at t={t} k={k} n={n}", be.name());
                crate::prop_assert!(y4 == y4_ref,
                    "{} int4 not bit-exact at t={t} k={k} n={n}", be.name());
                prop::assert_close(&yf, &yf_ref, 1e-5 * fscale)
                    .map_err(|e| format!("{} f32: {e}", be.name()))?;
            }
            Ok(())
        });
    }

    #[test]
    fn rowwise_ops_match_scalar() {
        prop::check("backend-rowwise", 10, |rng| {
            let rows = 1 + rng.below(8);
            let d = 32 << rng.below(3); // 32/64/128: valid Hadamard dims
            let group = 16;
            let x = rng.normal_vec(rows * d);

            let oracle = ScalarRef;
            let mut had_ref = x.clone();
            oracle.had_rows(&mut had_ref, d);
            let mut codes_ref = vec![0i8; rows * d];
            let mut scales_ref = vec![0.0f32; rows];
            oracle.quant_rows(&x, d, 4, 0.9, &mut codes_ref, &mut scales_ref);
            let (kc_ref, ks_ref, kz_ref) = oracle.kv_quant_slab(&x, d, group, 4, 0.95);
            let mut deq_ref = vec![0.0f32; rows * d];
            oracle.kv_dequant(&kc_ref, &ks_ref, &kz_ref, group, &mut deq_ref);

            for be in alt_backends() {
                let mut had = x.clone();
                be.had_rows(&mut had, d);
                crate::prop_assert!(had == had_ref, "{} had_rows", be.name());

                let mut codes = vec![0i8; rows * d];
                let mut scales = vec![0.0f32; rows];
                be.quant_rows(&x, d, 4, 0.9, &mut codes, &mut scales);
                crate::prop_assert!(codes == codes_ref && scales == scales_ref,
                                    "{} quant_rows", be.name());

                let (kc, ks, kz) = be.kv_quant_slab(&x, d, group, 4, 0.95);
                crate::prop_assert!(kc == kc_ref && ks == ks_ref && kz == kz_ref,
                                    "{} kv_quant_slab", be.name());

                let mut deq = vec![0.0f32; rows * d];
                be.kv_dequant(&kc, &ks, &kz, group, &mut deq);
                crate::prop_assert!(deq == deq_ref, "{} kv_dequant", be.name());
            }
            Ok(())
        });
    }

    #[test]
    fn par_for_covers_all_indices() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        for be in alt_backends() {
            let hits: Vec<AtomicUsize> = (0..37).map(|_| AtomicUsize::new(0)).collect();
            be.par_for(37, &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                    "{} par_for coverage", be.name());
        }
    }

    /// Tentpole contract: batched decode on Blocked/Threaded/Auto is
    /// bit-exact with the ScalarRef oracle across GQA shapes, 4/8-bit
    /// caches and ragged per-sequence lengths (including empty caches).
    #[test]
    fn batched_decode_matches_scalar_on_ragged_gqa() {
        use crate::attention::{CacheF32, CacheQuant, DecodeF32Seq,
                               DecodeQuantSeq};
        prop::check("decode-batch-vs-scalar", 10, |rng| {
            let hk = 1 + rng.below(3); // 1..=3 kv heads
            let rep = 1 << rng.below(3); // 1/2/4 q-heads per kv head
            let nh = hk * rep;
            let dh = 8 << rng.below(2); // 8 or 16
            let group = if rng.below(2) == 0 { dh } else { dh / 2 };
            let bits = if rng.below(2) == 0 { 4 } else { 8 };
            let nseq = 1 + rng.below(4);
            let mut caches = Vec::new();
            let mut qs: Vec<Vec<f32>> = Vec::new();
            for _ in 0..nseq {
                let len = rng.below(9); // ragged 0..=8, empty allowed
                let mut kf = CacheF32::new(hk, dh, len);
                let mut vf = CacheF32::new(hk, dh, len);
                let mut kq = CacheQuant::new(hk, dh, group, bits);
                let mut vq = CacheQuant::new(hk, dh, group, bits);
                for _ in 0..len {
                    let kt = rng.normal_vec(hk * dh);
                    let vt = rng.normal_vec(hk * dh);
                    kf.append(&kt);
                    vf.append(&vt);
                    kq.append(&kt, 0.95);
                    vq.append(&vt, 0.95);
                }
                caches.push((kf, vf, kq, vq));
                qs.push(rng.normal_vec(nh * dh));
            }
            let seqs_f: Vec<DecodeF32Seq> = caches.iter().zip(&qs)
                .map(|((kf, vf, _, _), q)| DecodeF32Seq {
                    q, k: kf.view(), v: vf.view(),
                })
                .collect();
            let seqs_q: Vec<DecodeQuantSeq> = caches.iter().zip(&qs)
                .map(|((_, _, kq, vq), q)| DecodeQuantSeq {
                    q, k: kq.view(), v: vq.view(),
                })
                .collect();

            let oracle = ScalarRef;
            let mut of_ref = vec![0.0f32; nseq * nh * dh];
            let mut oq_ref = vec![0.0f32; nseq * nh * dh];
            oracle.decode_f32_batch(&seqs_f, nh, &mut of_ref);
            oracle.decode_quant_batch(&seqs_q, nh, &mut oq_ref);
            crate::prop_assert!(of_ref.iter().all(|v| v.is_finite()),
                                "oracle f32 produced non-finite values");
            crate::prop_assert!(oq_ref.iter().all(|v| v.is_finite()),
                                "oracle quant produced non-finite values");

            for be in alt_backends() {
                // NaN-seeded so any unwritten element fails the comparison
                let mut of = vec![f32::NAN; nseq * nh * dh];
                let mut oq = vec![f32::NAN; nseq * nh * dh];
                be.decode_f32_batch(&seqs_f, nh, &mut of);
                be.decode_quant_batch(&seqs_q, nh, &mut oq);
                crate::prop_assert!(of == of_ref,
                    "{} f32 decode not bit-exact at hk={hk} rep={rep} dh={dh}",
                    be.name());
                crate::prop_assert!(oq == oq_ref,
                    "{} quant decode not bit-exact at hk={hk} rep={rep} \
                     dh={dh} group={group} bits={bits}", be.name());
            }
            Ok(())
        });
    }

    /// Regression: an empty cache used to produce `0/0 = NaN` outputs —
    /// every backend must yield a well-defined all-zero output instead.
    #[test]
    fn empty_cache_decode_is_zero_on_every_backend() {
        use crate::attention::{CacheF32, CacheQuant, DecodeF32Seq,
                               DecodeQuantSeq};
        use crate::util::prng::Rng;
        let (hk, dh, nh) = (2usize, 16usize, 4usize);
        let mut rng = Rng::new(3);
        let q = rng.normal_vec(nh * dh);
        let (kf, vf) = (CacheF32::new(hk, dh, 0), CacheF32::new(hk, dh, 0));
        let (kq, vq) = (CacheQuant::new(hk, dh, dh, 4),
                        CacheQuant::new(hk, dh, dh, 4));
        for kind in BackendKind::all() {
            let be = make(kind);
            let mut out = vec![f32::NAN; nh * dh];
            be.decode_f32_batch(&[DecodeF32Seq {
                q: &q, k: kf.view(), v: vf.view(),
            }], nh, &mut out);
            assert!(out.iter().all(|&v| v == 0.0),
                    "{} f32 empty-cache decode", be.name());
            out.fill(f32::NAN);
            be.decode_quant_batch(&[DecodeQuantSeq {
                q: &q, k: kq.view(), v: vq.view(),
            }], nh, &mut out);
            assert!(out.iter().all(|&v| v == 0.0),
                    "{} quant empty-cache decode", be.name());
        }
    }

    /// The batched NLL reduction must agree exactly with the single-row
    /// helper on every backend.
    #[test]
    fn nll_rows_matches_single_row_on_every_backend() {
        prop::check("nll-rows-vs-scalar", 8, |rng| {
            let vocab = 1 + rng.below(40);
            let rows = 1 + rng.below(12);
            let logits = rng.normal_vec(rows * vocab);
            let targets: Vec<u16> =
                (0..rows).map(|_| rng.below(vocab) as u16).collect();
            let mut want = vec![0.0f64; rows];
            ScalarRef.nll_rows(&logits, vocab, &targets, &mut want);
            for (r, &t) in targets.iter().enumerate() {
                let lp = crate::coordinator::sampler::log_softmax_at(
                    &logits[r * vocab..(r + 1) * vocab], t as usize);
                crate::prop_assert!(want[r] == -lp,
                                    "row {r}: batched vs single-row NLL");
            }
            for be in alt_backends() {
                let mut got = vec![f64::NAN; rows];
                be.nll_rows(&logits, vocab, &targets, &mut got);
                crate::prop_assert!(got == want, "{} nll_rows", be.name());
            }
            Ok(())
        });
    }

    #[test]
    fn kind_parsing() {
        assert_eq!(BackendKind::parse("scalar"), Some(BackendKind::Scalar));
        assert_eq!(BackendKind::parse("Blocked"), Some(BackendKind::Blocked));
        assert_eq!(BackendKind::parse("THREADED"), Some(BackendKind::Threaded));
        assert_eq!(BackendKind::parse("auto"), Some(BackendKind::Auto));
        assert_eq!(BackendKind::parse("gpu"), None);
        for k in BackendKind::all() {
            let be = make(k);
            assert!(!be.name().is_empty());
        }
    }
}
