//! Home-grown persistent worker pool behind the [`super::Threaded`]
//! backend — std-only (no rayon/crossbeam in this environment).
//!
//! Model: `lanes` execution lanes = the submitting thread plus
//! `lanes - 1` long-lived workers parked on a condvar.  A job is a
//! chunked parallel-for: workers race on an atomic chunk counter, so
//! uneven chunks self-balance.  `run` blocks until every lane has checked
//! in, which is what makes the lifetime erasure of the borrowed closure
//! sound (the borrow strictly outlives every use).
//!
//! Re-entrant `run` calls (a task spawning parallel work) execute inline
//! on the calling lane — nesting degrades gracefully instead of
//! deadlocking on the submit lock.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, OnceLock};
use std::thread;

use crate::audit::AuditedMutex;

type Task = dyn Fn(usize) + Sync;

#[derive(Clone, Copy)]
struct Job {
    /// Lifetime-erased borrow of the caller's closure; only dereferenced
    /// between job publication and the final `remaining == 0` check-in,
    /// during which `run` is blocked and the borrow is live.
    task: &'static Task,
    n_chunks: usize,
}

struct State {
    job: Option<Job>,
    /// Job sequence number — lets a late-waking worker distinguish "new
    /// job" from "the job I already finished".
    seq: u64,
    /// Workers that have not yet checked in for the current job.
    remaining: usize,
    shutdown: bool,
}

struct Shared {
    state: AuditedMutex<State>,
    work: Condvar,
    done: Condvar,
    next_chunk: AtomicUsize,
}

thread_local! {
    static IN_PARALLEL: Cell<bool> = const { Cell::new(false) };
}

pub struct WorkerPool {
    shared: Arc<Shared>,
    /// Serializes job submission (one job in flight at a time).  The
    /// submit → state nesting in [`Self::run`] is the pool's one lock
    /// order, recorded by the audit layer in debug builds.
    submit: AuditedMutex<()>,
    lanes: usize,
    handles: Vec<thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Pool with `lanes` total execution lanes (≥ 1); spawns `lanes - 1`
    /// worker threads — the submitting thread is always the first lane.
    pub fn new(lanes: usize) -> WorkerPool {
        let lanes = lanes.max(1);
        let shared = Arc::new(Shared {
            state: AuditedMutex::new("backend.pool.state", State {
                job: None,
                seq: 0,
                remaining: 0,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
            next_chunk: AtomicUsize::new(0),
        });
        let handles = (1..lanes)
            .map(|i| {
                let sh = shared.clone();
                thread::Builder::new()
                    .name(format!("quarot-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool {
            shared,
            submit: AuditedMutex::new("backend.pool.submit", ()),
            lanes,
            handles,
        }
    }

    /// Total execution lanes (submitter + workers).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Run `f(i)` for every `i in 0..n_chunks`, distributing chunks over
    /// all lanes; returns after the last chunk completes.  Chunks must be
    /// independent (they run concurrently in unspecified order).
    pub fn run(&self, n_chunks: usize, f: &Task) {
        if n_chunks == 0 {
            return;
        }
        // Inline paths: no workers, a single chunk, or a re-entrant call
        // from inside a running task (avoids self-deadlock on `submit`).
        if self.handles.is_empty()
            || n_chunks == 1
            || IN_PARALLEL.with(|p| p.get())
        {
            for i in 0..n_chunks {
                f(i);
            }
            return;
        }
        // A chunk that panicked on a previous submitter poisons this
        // mutex on unwind; the guarded section holds no invariant-bearing
        // state (it only serializes submissions), so clear the poison
        // instead of bricking the process-global pool.
        let _guard = self.submit.lock_recover();
        // SAFETY: workers dereference `task` only while `remaining > 0`,
        // and `JoinGuard` blocks — even on unwind from a panicking chunk
        // on this thread — until `remaining == 0`, so `f` strictly
        // outlives every use.
        let task: &'static Task = unsafe { std::mem::transmute::<&Task, &'static Task>(f) };
        {
            let mut st = self.shared.state.lock();
            self.shared.next_chunk.store(0, Ordering::SeqCst);
            st.job = Some(Job { task, n_chunks });
            st.seq = st.seq.wrapping_add(1);
            st.remaining = self.handles.len();
            self.shared.work.notify_all();
        }
        let _join = JoinGuard(&self.shared);
        // The submitting thread is a full lane.
        IN_PARALLEL.with(|p| p.set(true));
        loop {
            let i = self.shared.next_chunk.fetch_add(1, Ordering::Relaxed);
            if i >= n_chunks {
                break;
            }
            f(i);
        }
        IN_PARALLEL.with(|p| p.set(false));
        // `_join` drops here: waits for every worker to check in and
        // clears the job.
    }
}

/// Blocks on drop until every worker has checked in for the current job,
/// then clears it — this is what keeps the lifetime erasure in [`WorkerPool::run`]
/// sound even when a chunk panics on the submitting thread.
struct JoinGuard<'a>(&'a Shared);

impl Drop for JoinGuard<'_> {
    fn drop(&mut self) {
        IN_PARALLEL.with(|p| p.set(false));
        let mut st = self.0.state.lock();
        while st.remaining > 0 {
            st = self.0.state.wait_on(st, &self.0.done);
        }
        st.job = None;
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock();
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    IN_PARALLEL.with(|p| p.set(true));
    let mut last_seq = 0u64;
    loop {
        let job;
        {
            let mut st = shared.state.lock();
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(j) = st.job {
                    if st.seq != last_seq {
                        last_seq = st.seq;
                        job = j;
                        break;
                    }
                }
                st = shared.state.wait_on(st, &shared.work);
            }
        }
        loop {
            let i = shared.next_chunk.fetch_add(1, Ordering::Relaxed);
            if i >= job.n_chunks {
                break;
            }
            // A panicking chunk on a worker would leave `remaining` stuck
            // (deadlocking the submitter) or require unwind-across-job
            // reasoning; neither is recoverable for a kernel — abort.
            let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                (job.task)(i)
            }));
            if ok.is_err() {
                eprintln!("worker pool: kernel chunk {i} panicked — aborting");
                std::process::abort();
            }
        }
        let mut st = shared.state.lock();
        st.remaining -= 1;
        if st.remaining == 0 {
            shared.done.notify_all();
        }
    }
}

/// Lane count for the process: `QUAROT_THREADS` override, else the OS
/// parallelism report (read once).
pub fn parallelism() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("QUAROT_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

/// Shared process-wide pool: every `Threaded` backend instance uses this,
/// so the crate spawns at most one set of workers.
pub fn global() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(|| WorkerPool::new(parallelism()))
}

/// Raw mutable pointer wrapper for fan-out writes to *disjoint* regions of
/// one buffer.  Callers must guarantee tasks never touch the same element.
#[derive(Clone, Copy)]
pub(crate) struct SendPtr<T>(*mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub(crate) fn new(p: *mut T) -> SendPtr<T> {
        SendPtr(p)
    }

    pub(crate) fn get(self) -> *mut T {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_every_chunk_exactly_once() {
        let pool = WorkerPool::new(4);
        for n in [1usize, 2, 3, 7, 64, 257] {
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.run(n, &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "chunk {i} of {n}");
            }
        }
    }

    #[test]
    fn reusable_across_many_jobs() {
        let pool = WorkerPool::new(3);
        let total = AtomicU64::new(0);
        for round in 0..50u64 {
            pool.run(16, &|i| {
                total.fetch_add(round + i as u64, Ordering::Relaxed);
            });
        }
        // Σ_round Σ_i (round + i) = 50·(0+..+15) + 16·(0+..+49)
        assert_eq!(total.load(Ordering::Relaxed), 50 * 120 + 16 * 1225);
    }

    #[test]
    fn nested_run_degrades_to_inline() {
        let pool = WorkerPool::new(2);
        let count = AtomicUsize::new(0);
        pool.run(4, &|_| {
            // re-entrant: must not deadlock
            pool.run(4, &|_| {
                count.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(count.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn single_lane_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        let count = AtomicUsize::new(0);
        pool.run(9, &|_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 9);
    }

    /// A panicking task on an inline lane (single-lane pool: every chunk
    /// runs on the submitter) must unwind to the caller and leave the
    /// pool fully usable.  Worker-lane panics abort the process by design
    /// (see `worker_loop`), so this is the *recoverable* panic surface.
    #[test]
    fn inline_task_panic_leaves_pool_reusable() {
        let pool = WorkerPool::new(1);
        let before = AtomicUsize::new(0);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(4, &|i| {
                if i == 2 {
                    panic!("task boom");
                }
                before.fetch_add(1, Ordering::Relaxed);
            });
        }));
        assert!(r.is_err(), "panic must propagate to the caller");
        // the pool is not poisoned: subsequent jobs run every chunk
        let count = AtomicUsize::new(0);
        pool.run(7, &|_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 7);
    }

    /// On a multi-lane pool a single-chunk job also runs inline on the
    /// submitting lane; its panic must not wedge the workers or poison
    /// the submit lock for later multi-chunk jobs.
    #[test]
    fn submitter_panic_on_multi_lane_pool_recovers() {
        let pool = WorkerPool::new(4);
        for round in 0..3 {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                pool.run(1, &|_| panic!("submitter boom {round}"));
            }));
            assert!(r.is_err());
            let hits: Vec<AtomicUsize> =
                (0..32).map(|_| AtomicUsize::new(0)).collect();
            pool.run(32, &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1,
                           "round {round} chunk {i}");
            }
        }
    }

    /// The cluster spins up (and tears down) one pool per shard, so
    /// repeated shutdown/re-create cycles must neither leak workers nor
    /// lose work: every cycle's pool distributes all chunks and `drop`
    /// joins its threads before the next cycle starts.
    #[test]
    fn repeated_shutdown_recreate_cycles() {
        for cycle in 0..12u64 {
            let pool = WorkerPool::new(3);
            let total = AtomicU64::new(0);
            pool.run(8, &|i| {
                total.fetch_add(i as u64 + 1, Ordering::Relaxed);
            });
            assert_eq!(total.load(Ordering::Relaxed), 36, "cycle {cycle}");
            // second job on the same pool (worker reuse inside a cycle)
            let n = AtomicUsize::new(0);
            pool.run(5, &|_| {
                n.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(n.load(Ordering::Relaxed), 5, "cycle {cycle}");
            drop(pool); // joins both workers
        }
        // pools dropped without ever running a job must also shut down
        for _ in 0..8 {
            let _idle = WorkerPool::new(4);
        }
        // and a fresh pool after all the churn still works
        let pool = WorkerPool::new(3);
        let count = AtomicUsize::new(0);
        pool.run(16, &|_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 16);
    }
}
