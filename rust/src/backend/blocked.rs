//! `Blocked` backend: cache-blocked, column-tiled GEMM kernels.
//!
//! The `ScalarRef` kernels iterate rows-outer / columns-inner, which
//! streams the whole weight matrix from memory once **per activation
//! row** — t× more DRAM traffic than necessary.  These kernels invert the
//! loop nest into column tiles ([`COL_TILE`] weight columns held hot)
//! with all activation rows inner, so the weight matrix streams exactly
//! once and activations replay from cache.  Activation rows are quantized
//! once up front instead of per GEMM row pass.
//!
//! Per-column accumulation replicates the `ScalarRef` lane structure
//! statement-for-statement, so results are **bit-identical** to the
//! scalar oracle on all three dtypes (integer accumulation is exactly
//! associative; the f32 lane order is reproduced verbatim).  The
//! backend property tests pin this down.
//!
//! The `*_cols` kernels take a raw output pointer and a `[c0, c1)` column
//! range so the `Threaded` backend can fan disjoint column ranges of one
//! output buffer across the worker pool.

use crate::attention::{unpack_nibble_pair, DecodeF32Seq, DecodeQuantSeq,
                       DecodeScratch, KvCodes, KvF32View, KvQuantView};
use crate::gemm::{nibble_lut, WeightsF32, WeightsI4, WeightsI8};

use super::{f32_batch_geom, kv_dequant_seq, kv_quant_seq, nll_rows_seq,
            quant_batch_geom, quantize_rows, wht_rows_seq, ComputeBackend,
            DECODE_SCRATCH};

/// Weight columns kept hot per tile; 4 keeps tile state within L1
/// alongside one activation row for every shape in the tables.
pub(crate) const COL_TILE: usize = 4;

/// One f32 column dot — bit-identical to the `gemm::gemm_f32` inner loop.
#[inline(always)]
fn dot_f32(xr: &[f32], wc: &[f32], k: usize) -> f32 {
    let mut acc = 0.0f32;
    let mut i = 0;
    let kk = k & !3;
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0, 0.0, 0.0);
    while i < kk {
        a0 += xr[i] * wc[i];
        a1 += xr[i + 1] * wc[i + 1];
        a2 += xr[i + 2] * wc[i + 2];
        a3 += xr[i + 3] * wc[i + 3];
        i += 4;
    }
    acc += a0 + a1 + a2 + a3;
    while i < k {
        acc += xr[i] * wc[i];
        i += 1;
    }
    acc
}

/// One int8 column dot (i32 accumulation, exactly associative).
#[inline(always)]
fn dot_i8(xr: &[i8], wc: &[i8], k: usize) -> i32 {
    let mut acc = 0i32;
    let mut i = 0;
    let kk = k & !3;
    let (mut a0, mut a1, mut a2, mut a3) = (0i32, 0, 0, 0);
    while i < kk {
        a0 += xr[i] as i32 * wc[i] as i32;
        a1 += xr[i + 1] as i32 * wc[i + 1] as i32;
        a2 += xr[i + 2] as i32 * wc[i + 2] as i32;
        a3 += xr[i + 3] as i32 * wc[i + 3] as i32;
        i += 4;
    }
    acc += a0 + a1 + a2 + a3;
    while i < k {
        acc += xr[i] as i32 * wc[i] as i32;
        i += 1;
    }
    acc
}

/// f32 GEMM over columns `[c0, c1)`, all `t` rows.
///
/// # Safety
/// `y` must be valid for `t * w.n` f32 writes; concurrent callers must
/// use disjoint column ranges.
pub(crate) unsafe fn f32_cols(x: &[f32], t: usize, w: &WeightsF32,
                              c0: usize, c1: usize, y: *mut f32) {
    let (k, n) = (w.k, w.n);
    debug_assert!(c1 <= n);
    debug_assert!(x.len() >= t * k);
    let mut c = c0;
    while c < c1 {
        let tile_end = (c + COL_TILE).min(c1);
        for r in 0..t {
            let xr = &x[r * k..(r + 1) * k];
            for cc in c..tile_end {
                let wc = &w.cols[cc * k..(cc + 1) * k];
                *y.add(r * n + cc) = dot_f32(xr, wc, k);
            }
        }
        c = tile_end;
    }
}

/// int8 GEMM over columns `[c0, c1)` from pre-quantized activation rows
/// (`codes` is t×k, `row_scales` one scale per row).
///
/// # Safety
/// As [`f32_cols`].
pub(crate) unsafe fn i8_cols(codes: &[i8], row_scales: &[f32], t: usize,
                             w: &WeightsI8, c0: usize, c1: usize, y: *mut f32) {
    let (k, n) = (w.k, w.n);
    debug_assert!(c1 <= n);
    debug_assert!(codes.len() >= t * k);
    let mut c = c0;
    while c < c1 {
        let tile_end = (c + COL_TILE).min(c1);
        for r in 0..t {
            let xr = &codes[r * k..(r + 1) * k];
            let xs = row_scales[r];
            for cc in c..tile_end {
                let wc = &w.cols[cc * k..(cc + 1) * k];
                let acc = dot_i8(xr, wc, k);
                *y.add(r * n + cc) = acc as f32 * xs * w.scales[cc];
            }
        }
        c = tile_end;
    }
}

/// Packed-int4 GEMM over columns `[c0, c1)` from pre-quantized activation
/// rows.  Mirrors the `gemm::gemm_i4` nibble-LUT inner loop per column.
///
/// # Safety
/// As [`f32_cols`].
pub(crate) unsafe fn i4_cols(codes: &[i8], row_scales: &[f32], t: usize,
                             w: &WeightsI4, c0: usize, c1: usize, y: *mut f32) {
    let (k, n) = (w.k, w.n);
    let kp = k.div_ceil(2);
    let lut = nibble_lut();
    debug_assert!(c1 <= n);
    debug_assert!(codes.len() >= t * k);
    let mut c = c0;
    while c < c1 {
        let tile_end = (c + COL_TILE).min(c1);
        for r in 0..t {
            let xr = &codes[r * k..(r + 1) * k];
            let xs = row_scales[r];
            for cc in c..tile_end {
                let wc = &w.cols[cc * kp..(cc + 1) * kp];
                let pairs = k / 2;
                let (mut a0, mut a1) = (0i32, 0i32);
                for i in 0..pairs {
                    let (lo, hi) = lut[wc[i] as usize];
                    a0 += xr[2 * i] as i32 * lo as i32;
                    a1 += xr[2 * i + 1] as i32 * hi as i32;
                }
                let mut acc = a0 + a1;
                if k % 2 == 1 {
                    let (lo, _) = lut[wc[kp - 1] as usize];
                    acc += xr[k - 1] as i32 * lo as i32;
                }
                *y.add(r * n + cc) = acc as f32 * xs * w.scales[cc];
            }
        }
        c = tile_end;
    }
}

/// Tokens per decode tile: one K (or V) block of `DECODE_TOK_BLOCK × dh`
/// f32 stays L1-resident while every q-head of the kv-group replays it.
pub(crate) const DECODE_TOK_BLOCK: usize = 32;

/// One kv-head group of one sequence over f32 streams: the `rep` q-heads
/// sharing kv-head `kvh`, walked token-blocked so each K/V tile streams
/// from memory once and replays from cache for every head (the scalar
/// oracle re-streams the whole cache per q-head).  `out` is the group's
/// contiguous `rep × dh` output region; `scratch` is reused across calls.
///
/// Per head, every float reduction (dot lanes, running max, softmax denom,
/// value accumulation) runs in exactly the oracle's order, so results are
/// **bit-identical** to [`crate::attention::decode_seq_f32_ref`].
pub(crate) fn decode_kvh_f32(q: &[f32], kvh: usize, rep: usize,
                             k: &KvF32View, v: &KvF32View, out: &mut [f32],
                             scratch: &mut DecodeScratch) {
    let (hk, dh) = (k.n_kv_heads, k.d_head);
    let s = k.len;
    if s == 0 {
        out.fill(0.0);
        return;
    }
    let sm = 1.0 / (dh as f32).sqrt();
    let q0 = kvh * rep * dh; // first q-head of this group
    scratch.scores.clear();
    scratch.scores.resize(rep * s, 0.0);
    scratch.mxs.clear();
    scratch.mxs.resize(rep, f32::MIN);
    scratch.denoms.clear();
    scratch.denoms.resize(rep, 0.0);
    let scores = &mut scratch.scores;
    let mxs = &mut scratch.mxs;
    let denoms = &mut scratch.denoms;
    // score pass: stream K once, heads replay the hot tile
    let mut tb = 0;
    while tb < s {
        let te = (tb + DECODE_TOK_BLOCK).min(s);
        for r in 0..rep {
            let qh = &q[q0 + r * dh..][..dh];
            let mut mx = mxs[r];
            for t in tb..te {
                let kt = &k.data[(t * hk + kvh) * dh..][..dh];
                let mut dot = 0.0f32;
                for i in 0..dh {
                    dot += qh[i] * kt[i];
                }
                let sc = dot * sm;
                scores[r * s + t] = sc;
                mx = mx.max(sc);
            }
            mxs[r] = mx;
        }
        tb = te;
    }
    // value pass: stream V once, same per-head reduction order
    out.fill(0.0);
    let mut tb = 0;
    while tb < s {
        let te = (tb + DECODE_TOK_BLOCK).min(s);
        for r in 0..rep {
            let oh = &mut out[r * dh..(r + 1) * dh];
            let mut denom = denoms[r];
            for t in tb..te {
                let p = (scores[r * s + t] - mxs[r]).exp();
                denom += p;
                let vt = &v.data[(t * hk + kvh) * dh..][..dh];
                for i in 0..dh {
                    oh[i] += p * vt[i];
                }
            }
            denoms[r] = denom;
        }
        tb = te;
    }
    for r in 0..rep {
        let inv = 1.0 / denoms[r];
        for o in &mut out[r * dh..(r + 1) * dh] {
            *o *= inv;
        }
    }
}

/// Quantized twin of [`decode_kvh_f32`]: walks the packed (or unpacked)
/// code stream token-blocked with the affine dequant folded into the
/// reductions exactly like the oracle, per-head scratch reused across
/// tiles.  Bit-identical to [`crate::attention::decode_seq_quant_ref`].
pub(crate) fn decode_kvh_quant(q: &[f32], kvh: usize, rep: usize,
                               k: &KvQuantView, v: &KvQuantView,
                               out: &mut [f32], scratch: &mut DecodeScratch) {
    let (hk, dh) = (k.n_kv_heads, k.d_head);
    let s = k.len;
    if s == 0 {
        out.fill(0.0);
        return;
    }
    let sm = 1.0 / (dh as f32).sqrt();
    let d = hk * dh;
    let groups_per_tok = d / k.group;
    let gh = dh / k.group; // groups per head
    let q0 = kvh * rep * dh;
    scratch.scores.clear();
    scratch.scores.resize(rep * s, 0.0);
    scratch.mxs.clear();
    scratch.mxs.resize(rep, f32::MIN);
    scratch.denoms.clear();
    scratch.denoms.resize(rep, 0.0);
    // per-(head, group) Σq for the zero-point correction
    scratch.qsum.clear();
    for r in 0..rep {
        let qh = &q[q0 + r * dh..][..dh];
        scratch.qsum.extend(qh.chunks_exact(k.group)
            .map(|g| g.iter().sum::<f32>()));
    }
    // Σₜ pₜ·zeroₜ per (head, group)
    scratch.zacc.clear();
    scratch.zacc.resize(rep * gh, 0.0);
    let scores = &mut scratch.scores;
    let mxs = &mut scratch.mxs;
    let denoms = &mut scratch.denoms;
    let qsum = &scratch.qsum;
    let zacc = &mut scratch.zacc;
    // score pass
    let mut tb = 0;
    while tb < s {
        let te = (tb + DECODE_TOK_BLOCK).min(s);
        for r in 0..rep {
            let qh = &q[q0 + r * dh..][..dh];
            let mut mx = mxs[r];
            for t in tb..te {
                let base = t * d + kvh * dh;
                let gbase = t * groups_per_tok + kvh * gh;
                let mut sc = 0.0f32;
                for gi in 0..gh {
                    let scale = k.scales[gbase + gi];
                    let zero = k.zeros[gbase + gi];
                    let mut dot = 0.0f32;
                    let goff = gi * k.group;
                    match k.codes {
                        KvCodes::Packed4(codes) => {
                            let cb = (base + goff) / 2;
                            for (j, &byte) in codes[cb..cb + k.group / 2]
                                .iter().enumerate() {
                                let (lo, hi) = unpack_nibble_pair(byte);
                                dot += qh[goff + 2 * j] * lo
                                    + qh[goff + 2 * j + 1] * hi;
                            }
                        }
                        KvCodes::I8(codes) => {
                            let cb = base + goff;
                            for (j, &c) in codes[cb..cb + k.group].iter()
                                .enumerate() {
                                dot += qh[goff + j] * c as f32;
                            }
                        }
                    }
                    sc += scale * dot + zero * qsum[r * gh + gi];
                }
                let sc = sc * sm;
                scores[r * s + t] = sc;
                mx = mx.max(sc);
            }
            mxs[r] = mx;
        }
        tb = te;
    }
    // value pass
    out.fill(0.0);
    let mut tb = 0;
    while tb < s {
        let te = (tb + DECODE_TOK_BLOCK).min(s);
        for r in 0..rep {
            let oh = &mut out[r * dh..(r + 1) * dh];
            let mut denom = denoms[r];
            for t in tb..te {
                let p = (scores[r * s + t] - mxs[r]).exp();
                denom += p;
                let base = t * d + kvh * dh;
                let gbase = t * groups_per_tok + kvh * gh;
                for gi in 0..gh {
                    let ps = p * v.scales[gbase + gi];
                    zacc[r * gh + gi] += p * v.zeros[gbase + gi];
                    let goff = gi * v.group;
                    match v.codes {
                        KvCodes::Packed4(codes) => {
                            let cb = (base + goff) / 2;
                            for (j, &byte) in codes[cb..cb + v.group / 2]
                                .iter().enumerate() {
                                let (lo, hi) = unpack_nibble_pair(byte);
                                oh[goff + 2 * j] += ps * lo;
                                oh[goff + 2 * j + 1] += ps * hi;
                            }
                        }
                        KvCodes::I8(codes) => {
                            let cb = base + goff;
                            for (j, &c) in codes[cb..cb + v.group].iter()
                                .enumerate() {
                                oh[goff + j] += ps * c as f32;
                            }
                        }
                    }
                }
            }
            denoms[r] = denom;
        }
        tb = te;
    }
    for r in 0..rep {
        let inv = 1.0 / denoms[r];
        let oh = &mut out[r * dh..(r + 1) * dh];
        for gi in 0..gh {
            for o in &mut oh[gi * v.group..(gi + 1) * v.group] {
                *o = (*o + zacc[r * gh + gi]) * inv;
            }
        }
    }
}

/// Cache-blocked single-thread backend.
pub struct Blocked;

impl ComputeBackend for Blocked {
    fn name(&self) -> &'static str {
        "blocked"
    }

    fn gemm_f32(&self, x: &[f32], t: usize, w: &WeightsF32, y: &mut [f32]) {
        assert_eq!(x.len(), t * w.k);
        assert_eq!(y.len(), t * w.n);
        unsafe { f32_cols(x, t, w, 0, w.n, y.as_mut_ptr()) }
    }

    fn gemm_i8(&self, x: &[f32], t: usize, w: &WeightsI8, bits: u32, clip: f32,
               y: &mut [f32]) {
        assert_eq!(x.len(), t * w.k);
        assert_eq!(y.len(), t * w.n);
        let (codes, scales) = quantize_rows(x, w.k, bits, clip);
        unsafe { i8_cols(&codes, &scales, t, w, 0, w.n, y.as_mut_ptr()) }
    }

    fn gemm_i4(&self, x: &[f32], t: usize, w: &WeightsI4, clip: f32, y: &mut [f32]) {
        assert_eq!(x.len(), t * w.k);
        assert_eq!(y.len(), t * w.n);
        let (codes, scales) = quantize_rows(x, w.k, 4, clip);
        unsafe { i4_cols(&codes, &scales, t, w, 0, w.n, y.as_mut_ptr()) }
    }

    fn had_rows(&self, x: &mut [f32], d: usize) {
        wht_rows_seq(x, d);
    }

    fn quant_rows(&self, x: &[f32], d: usize, bits: u32, clip: f32,
                  codes: &mut [i8], scales: &mut [f32]) {
        for (r, row) in x.chunks_exact(d).enumerate() {
            scales[r] = crate::gemm::quant_row(row, bits, clip,
                                               &mut codes[r * d..(r + 1) * d]);
        }
    }

    fn kv_quant_slab(&self, x: &[f32], d: usize, group: usize, bits: u32, clip: f32)
                     -> (Vec<i8>, Vec<f32>, Vec<f32>) {
        kv_quant_seq(x, d, group, bits, clip)
    }

    fn kv_dequant(&self, codes: &[i8], scales: &[f32], zeros: &[f32],
                  group: usize, out: &mut [f32]) {
        kv_dequant_seq(codes, scales, zeros, group, out);
    }

    fn decode_f32_batch(&self, seqs: &[DecodeF32Seq<'_>], n_heads: usize,
                        out: &mut [f32]) {
        let Some(geom) = f32_batch_geom(seqs, n_heads, out.len()) else {
            return;
        };
        let (dh, rep) = (geom.dh, geom.rep);
        let stride = n_heads * dh;
        DECODE_SCRATCH.with(|s| {
            let scratch = &mut *s.borrow_mut();
            for (seq, o) in seqs.iter().zip(out.chunks_exact_mut(stride)) {
                for kvh in 0..geom.hk {
                    decode_kvh_f32(seq.q, kvh, rep, &seq.k, &seq.v,
                                   &mut o[kvh * rep * dh..(kvh + 1) * rep * dh],
                                   scratch);
                }
            }
        });
    }

    fn decode_quant_batch(&self, seqs: &[DecodeQuantSeq<'_>], n_heads: usize,
                          out: &mut [f32]) {
        let Some(geom) = quant_batch_geom(seqs, n_heads, out.len()) else {
            return;
        };
        let (dh, rep) = (geom.dh, geom.rep);
        let stride = n_heads * dh;
        DECODE_SCRATCH.with(|s| {
            let scratch = &mut *s.borrow_mut();
            for (seq, o) in seqs.iter().zip(out.chunks_exact_mut(stride)) {
                for kvh in 0..geom.hk {
                    decode_kvh_quant(seq.q, kvh, rep, &seq.k, &seq.v,
                                     &mut o[kvh * rep * dh..(kvh + 1) * rep * dh],
                                     scratch);
                }
            }
        });
    }

    fn nll_rows(&self, logits: &[f32], vocab: usize, targets: &[u16],
                out: &mut [f64]) {
        nll_rows_seq(logits, vocab, targets, out);
    }

    fn par_for(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        for i in 0..n {
            f(i);
        }
    }
}
