//! `Blocked` backend: cache-blocked, column-tiled GEMM kernels.
//!
//! The `ScalarRef` kernels iterate rows-outer / columns-inner, which
//! streams the whole weight matrix from memory once **per activation
//! row** — t× more DRAM traffic than necessary.  These kernels invert the
//! loop nest into column tiles ([`COL_TILE`] weight columns held hot)
//! with all activation rows inner, so the weight matrix streams exactly
//! once and activations replay from cache.  Activation rows are quantized
//! once up front instead of per GEMM row pass.
//!
//! Per-column accumulation replicates the `ScalarRef` lane structure
//! statement-for-statement, so results are **bit-identical** to the
//! scalar oracle on all three dtypes (integer accumulation is exactly
//! associative; the f32 lane order is reproduced verbatim).  The
//! backend property tests pin this down.
//!
//! The `*_cols` kernels take a raw output pointer and a `[c0, c1)` column
//! range so the `Threaded` backend can fan disjoint column ranges of one
//! output buffer across the worker pool.

use crate::gemm::{nibble_lut, WeightsF32, WeightsI4, WeightsI8};

use super::{kv_dequant_seq, kv_quant_seq, quantize_rows, wht_rows_seq, ComputeBackend};

/// Weight columns kept hot per tile; 4 keeps tile state within L1
/// alongside one activation row for every shape in the tables.
pub(crate) const COL_TILE: usize = 4;

/// One f32 column dot — bit-identical to the `gemm::gemm_f32` inner loop.
#[inline(always)]
fn dot_f32(xr: &[f32], wc: &[f32], k: usize) -> f32 {
    let mut acc = 0.0f32;
    let mut i = 0;
    let kk = k & !3;
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0, 0.0, 0.0);
    while i < kk {
        a0 += xr[i] * wc[i];
        a1 += xr[i + 1] * wc[i + 1];
        a2 += xr[i + 2] * wc[i + 2];
        a3 += xr[i + 3] * wc[i + 3];
        i += 4;
    }
    acc += a0 + a1 + a2 + a3;
    while i < k {
        acc += xr[i] * wc[i];
        i += 1;
    }
    acc
}

/// One int8 column dot (i32 accumulation, exactly associative).
#[inline(always)]
fn dot_i8(xr: &[i8], wc: &[i8], k: usize) -> i32 {
    let mut acc = 0i32;
    let mut i = 0;
    let kk = k & !3;
    let (mut a0, mut a1, mut a2, mut a3) = (0i32, 0, 0, 0);
    while i < kk {
        a0 += xr[i] as i32 * wc[i] as i32;
        a1 += xr[i + 1] as i32 * wc[i + 1] as i32;
        a2 += xr[i + 2] as i32 * wc[i + 2] as i32;
        a3 += xr[i + 3] as i32 * wc[i + 3] as i32;
        i += 4;
    }
    acc += a0 + a1 + a2 + a3;
    while i < k {
        acc += xr[i] as i32 * wc[i] as i32;
        i += 1;
    }
    acc
}

/// f32 GEMM over columns `[c0, c1)`, all `t` rows.
///
/// # Safety
/// `y` must be valid for `t * w.n` f32 writes; concurrent callers must
/// use disjoint column ranges.
pub(crate) unsafe fn f32_cols(x: &[f32], t: usize, w: &WeightsF32,
                              c0: usize, c1: usize, y: *mut f32) {
    let (k, n) = (w.k, w.n);
    debug_assert!(c1 <= n);
    debug_assert!(x.len() >= t * k);
    let mut c = c0;
    while c < c1 {
        let tile_end = (c + COL_TILE).min(c1);
        for r in 0..t {
            let xr = &x[r * k..(r + 1) * k];
            for cc in c..tile_end {
                let wc = &w.cols[cc * k..(cc + 1) * k];
                *y.add(r * n + cc) = dot_f32(xr, wc, k);
            }
        }
        c = tile_end;
    }
}

/// int8 GEMM over columns `[c0, c1)` from pre-quantized activation rows
/// (`codes` is t×k, `row_scales` one scale per row).
///
/// # Safety
/// As [`f32_cols`].
pub(crate) unsafe fn i8_cols(codes: &[i8], row_scales: &[f32], t: usize,
                             w: &WeightsI8, c0: usize, c1: usize, y: *mut f32) {
    let (k, n) = (w.k, w.n);
    debug_assert!(c1 <= n);
    debug_assert!(codes.len() >= t * k);
    let mut c = c0;
    while c < c1 {
        let tile_end = (c + COL_TILE).min(c1);
        for r in 0..t {
            let xr = &codes[r * k..(r + 1) * k];
            let xs = row_scales[r];
            for cc in c..tile_end {
                let wc = &w.cols[cc * k..(cc + 1) * k];
                let acc = dot_i8(xr, wc, k);
                *y.add(r * n + cc) = acc as f32 * xs * w.scales[cc];
            }
        }
        c = tile_end;
    }
}

/// Packed-int4 GEMM over columns `[c0, c1)` from pre-quantized activation
/// rows.  Mirrors the `gemm::gemm_i4` nibble-LUT inner loop per column.
///
/// # Safety
/// As [`f32_cols`].
pub(crate) unsafe fn i4_cols(codes: &[i8], row_scales: &[f32], t: usize,
                             w: &WeightsI4, c0: usize, c1: usize, y: *mut f32) {
    let (k, n) = (w.k, w.n);
    let kp = k.div_ceil(2);
    let lut = nibble_lut();
    debug_assert!(c1 <= n);
    debug_assert!(codes.len() >= t * k);
    let mut c = c0;
    while c < c1 {
        let tile_end = (c + COL_TILE).min(c1);
        for r in 0..t {
            let xr = &codes[r * k..(r + 1) * k];
            let xs = row_scales[r];
            for cc in c..tile_end {
                let wc = &w.cols[cc * kp..(cc + 1) * kp];
                let pairs = k / 2;
                let (mut a0, mut a1) = (0i32, 0i32);
                for i in 0..pairs {
                    let (lo, hi) = lut[wc[i] as usize];
                    a0 += xr[2 * i] as i32 * lo as i32;
                    a1 += xr[2 * i + 1] as i32 * hi as i32;
                }
                let mut acc = a0 + a1;
                if k % 2 == 1 {
                    let (lo, _) = lut[wc[kp - 1] as usize];
                    acc += xr[k - 1] as i32 * lo as i32;
                }
                *y.add(r * n + cc) = acc as f32 * xs * w.scales[cc];
            }
        }
        c = tile_end;
    }
}

/// Cache-blocked single-thread backend.
pub struct Blocked;

impl ComputeBackend for Blocked {
    fn name(&self) -> &'static str {
        "blocked"
    }

    fn gemm_f32(&self, x: &[f32], t: usize, w: &WeightsF32, y: &mut [f32]) {
        assert_eq!(x.len(), t * w.k);
        assert_eq!(y.len(), t * w.n);
        unsafe { f32_cols(x, t, w, 0, w.n, y.as_mut_ptr()) }
    }

    fn gemm_i8(&self, x: &[f32], t: usize, w: &WeightsI8, bits: u32, clip: f32,
               y: &mut [f32]) {
        assert_eq!(x.len(), t * w.k);
        assert_eq!(y.len(), t * w.n);
        let (codes, scales) = quantize_rows(x, w.k, bits, clip);
        unsafe { i8_cols(&codes, &scales, t, w, 0, w.n, y.as_mut_ptr()) }
    }

    fn gemm_i4(&self, x: &[f32], t: usize, w: &WeightsI4, clip: f32, y: &mut [f32]) {
        assert_eq!(x.len(), t * w.k);
        assert_eq!(y.len(), t * w.n);
        let (codes, scales) = quantize_rows(x, w.k, 4, clip);
        unsafe { i4_cols(&codes, &scales, t, w, 0, w.n, y.as_mut_ptr()) }
    }

    fn had_rows(&self, x: &mut [f32], d: usize) {
        wht_rows_seq(x, d);
    }

    fn quant_rows(&self, x: &[f32], d: usize, bits: u32, clip: f32,
                  codes: &mut [i8], scales: &mut [f32]) {
        for (r, row) in x.chunks_exact(d).enumerate() {
            scales[r] = crate::gemm::quant_row(row, bits, clip,
                                               &mut codes[r * d..(r + 1) * d]);
        }
    }

    fn kv_quant_slab(&self, x: &[f32], d: usize, group: usize, bits: u32, clip: f32)
                     -> (Vec<i8>, Vec<f32>, Vec<f32>) {
        kv_quant_seq(x, d, group, bits, clip)
    }

    fn kv_dequant(&self, codes: &[i8], scales: &[f32], zeros: &[f32],
                  group: usize, out: &mut [f32]) {
        kv_dequant_seq(codes, scales, zeros, group, out);
    }

    fn par_for(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        for i in 0..n {
            f(i);
        }
    }
}
