//! `Threaded` backend: fans the blocked kernels across the persistent
//! worker pool ([`super::pool`]).
//!
//! Partitioning follows the access patterns of the serving loop:
//! * GEMMs split over **output columns** — weight columns are contiguous
//!   (column-major), every lane streams its own disjoint panel, and the
//!   scheme works for prefill (t ≫ 1) and decode (t = 1) alike;
//! * row-wise ops (activation quant, online Hadamard, KV codec) split
//!   over rows/groups;
//! * the decode tick partitions over **batch slots** via [`par_for`]
//!   (see `coordinator::batcher`).
//!
//! All fan-out writes go to disjoint regions through [`SendPtr`];
//! numerical results are bit-identical to `ScalarRef` on the integer
//! paths and to `Blocked` everywhere (same per-element kernels).
//!
//! [`par_for`]: ComputeBackend::par_for

use crate::attention::{DecodeF32Seq, DecodeQuantSeq};
use crate::gemm::{quant_row, WeightsF32, WeightsI4, WeightsI8};
use crate::hadamard;
use crate::quant::kv;

use super::pool::{self, SendPtr, WorkerPool};
use super::{blocked, f32_batch_geom, log_softmax_row, quant_batch_geom,
            ComputeBackend, DECODE_SCRATCH};

pub struct Threaded {
    pool: &'static WorkerPool,
}

impl Threaded {
    /// Backend over the shared process-wide pool (workers are spawned
    /// once, lazily, on first use).
    pub fn new() -> Threaded {
        Threaded { pool: pool::global() }
    }

    /// Split `total` work items into (chunk_size, n_chunks): ~4 chunks
    /// per lane for load balance, but never below `min_chunk` items.
    fn chunks(total: usize, min_chunk: usize, lanes: usize) -> (usize, usize) {
        if total == 0 {
            return (1, 0);
        }
        let per = total.div_ceil(lanes * 4).max(min_chunk).max(1);
        (per, total.div_ceil(per))
    }
}

impl Default for Threaded {
    fn default() -> Threaded {
        Threaded::new()
    }
}

impl ComputeBackend for Threaded {
    fn name(&self) -> &'static str {
        "threaded"
    }

    fn gemm_f32(&self, x: &[f32], t: usize, w: &WeightsF32, y: &mut [f32]) {
        assert_eq!(x.len(), t * w.k);
        assert_eq!(y.len(), t * w.n);
        let n = w.n;
        let (per, n_chunks) = Self::chunks(n, 8, self.pool.lanes());
        let yp = SendPtr::new(y.as_mut_ptr());
        self.pool.run(n_chunks, &|i| {
            let c0 = i * per;
            let c1 = ((i + 1) * per).min(n);
            unsafe { blocked::f32_cols(x, t, w, c0, c1, yp.get()) }
        });
    }

    fn gemm_i8(&self, x: &[f32], t: usize, w: &WeightsI8, bits: u32, clip: f32,
               y: &mut [f32]) {
        assert_eq!(x.len(), t * w.k);
        assert_eq!(y.len(), t * w.n);
        let (k, n) = (w.k, w.n);
        let mut codes = vec![0i8; t * k];
        let mut scales = vec![0.0f32; t];
        self.quant_rows(x, k, bits, clip, &mut codes, &mut scales);
        let (per, n_chunks) = Self::chunks(n, 8, self.pool.lanes());
        let yp = SendPtr::new(y.as_mut_ptr());
        let codes = &codes;
        let scales = &scales;
        self.pool.run(n_chunks, &|i| {
            let c0 = i * per;
            let c1 = ((i + 1) * per).min(n);
            unsafe { blocked::i8_cols(codes, scales, t, w, c0, c1, yp.get()) }
        });
    }

    fn gemm_i4(&self, x: &[f32], t: usize, w: &WeightsI4, clip: f32, y: &mut [f32]) {
        assert_eq!(x.len(), t * w.k);
        assert_eq!(y.len(), t * w.n);
        let (k, n) = (w.k, w.n);
        let mut codes = vec![0i8; t * k];
        let mut scales = vec![0.0f32; t];
        self.quant_rows(x, k, 4, clip, &mut codes, &mut scales);
        let (per, n_chunks) = Self::chunks(n, 8, self.pool.lanes());
        let yp = SendPtr::new(y.as_mut_ptr());
        let codes = &codes;
        let scales = &scales;
        self.pool.run(n_chunks, &|i| {
            let c0 = i * per;
            let c1 = ((i + 1) * per).min(n);
            unsafe { blocked::i4_cols(codes, scales, t, w, c0, c1, yp.get()) }
        });
    }

    fn had_rows(&self, x: &mut [f32], d: usize) {
        let rows = x.len() / d;
        let (per, n_chunks) = Self::chunks(rows, 2, self.pool.lanes());
        let xp = SendPtr::new(x.as_mut_ptr());
        self.pool.run(n_chunks, &|i| {
            let r0 = i * per;
            let r1 = ((i + 1) * per).min(rows);
            for r in r0..r1 {
                // disjoint rows per chunk
                let row = unsafe {
                    std::slice::from_raw_parts_mut(xp.get().add(r * d), d)
                };
                hadamard::wht(row);
            }
        });
    }

    fn quant_rows(&self, x: &[f32], d: usize, bits: u32, clip: f32,
                  codes: &mut [i8], scales: &mut [f32]) {
        let rows = x.len() / d;
        assert!(codes.len() >= rows * d);
        assert!(scales.len() >= rows);
        let (per, n_chunks) = Self::chunks(rows, 4, self.pool.lanes());
        let cp = SendPtr::new(codes.as_mut_ptr());
        let sp = SendPtr::new(scales.as_mut_ptr());
        self.pool.run(n_chunks, &|i| {
            let r0 = i * per;
            let r1 = ((i + 1) * per).min(rows);
            for r in r0..r1 {
                let out = unsafe {
                    std::slice::from_raw_parts_mut(cp.get().add(r * d), d)
                };
                let s = quant_row(&x[r * d..(r + 1) * d], bits, clip, out);
                unsafe { *sp.get().add(r) = s };
            }
        });
    }

    fn kv_quant_slab(&self, x: &[f32], d: usize, group: usize, bits: u32, clip: f32)
                     -> (Vec<i8>, Vec<f32>, Vec<f32>) {
        assert_eq!(d % group, 0);
        let rows = x.len() / d;
        let gpr = d / group;
        let mut codes = vec![0i8; rows * d];
        let mut scales = vec![0.0f32; rows * gpr];
        let mut zeros = vec![0.0f32; rows * gpr];
        let (per, n_chunks) = Self::chunks(rows, 2, self.pool.lanes());
        let cp = SendPtr::new(codes.as_mut_ptr());
        let sp = SendPtr::new(scales.as_mut_ptr());
        let zp = SendPtr::new(zeros.as_mut_ptr());
        self.pool.run(n_chunks, &|i| {
            let r0 = i * per;
            let r1 = ((i + 1) * per).min(rows);
            for r in r0..r1 {
                let row = &x[r * d..(r + 1) * d];
                for (gi, g) in row.chunks_exact(group).enumerate() {
                    let (c, s, z) = kv::quant_group(g, bits, clip);
                    unsafe {
                        std::ptr::copy_nonoverlapping(
                            c.as_ptr(), cp.get().add(r * d + gi * group), group);
                        *sp.get().add(r * gpr + gi) = s;
                        *zp.get().add(r * gpr + gi) = z;
                    }
                }
            }
        });
        (codes, scales, zeros)
    }

    fn kv_dequant(&self, codes: &[i8], scales: &[f32], zeros: &[f32],
                  group: usize, out: &mut [f32]) {
        let n_groups = out.len() / group;
        assert!(codes.len() >= n_groups * group);
        assert!(scales.len() >= n_groups && zeros.len() >= n_groups);
        let (per, n_chunks) = Self::chunks(n_groups, 64, self.pool.lanes());
        let op = SendPtr::new(out.as_mut_ptr());
        self.pool.run(n_chunks, &|i| {
            let g0 = i * per;
            let g1 = ((i + 1) * per).min(n_groups);
            for g in g0..g1 {
                let o = unsafe {
                    std::slice::from_raw_parts_mut(op.get().add(g * group), group)
                };
                kv::dequant_group(&codes[g * group..(g + 1) * group],
                                  scales[g], zeros[g], o);
            }
        });
    }

    fn decode_f32_batch(&self, seqs: &[DecodeF32Seq<'_>], n_heads: usize,
                        out: &mut [f32]) {
        let Some(geom) = f32_batch_geom(seqs, n_heads, out.len()) else {
            return;
        };
        let (hk, dh, rep) = (geom.hk, geom.dh, geom.rep);
        let stride = n_heads * dh;
        let op = SendPtr::new(out.as_mut_ptr());
        // one task per (sequence, kv-head group): the group's rep q-heads
        // share one contiguous, disjoint output region
        self.pool.run(seqs.len() * hk, &|ti| {
            let (i, kvh) = (ti / hk, ti % hk);
            let seq = &seqs[i];
            // SAFETY: task ti owns exactly out[i*stride + kvh*rep*dh ..][..rep*dh];
            // regions are pairwise disjoint and the pool joins before `out`
            // is read again.
            let o = unsafe {
                std::slice::from_raw_parts_mut(
                    op.get().add(i * stride + kvh * rep * dh), rep * dh)
            };
            DECODE_SCRATCH.with(|s| {
                blocked::decode_kvh_f32(seq.q, kvh, rep, &seq.k, &seq.v, o,
                                        &mut s.borrow_mut());
            });
        });
    }

    fn decode_quant_batch(&self, seqs: &[DecodeQuantSeq<'_>], n_heads: usize,
                          out: &mut [f32]) {
        let Some(geom) = quant_batch_geom(seqs, n_heads, out.len()) else {
            return;
        };
        let (hk, dh, rep) = (geom.hk, geom.dh, geom.rep);
        let stride = n_heads * dh;
        let op = SendPtr::new(out.as_mut_ptr());
        self.pool.run(seqs.len() * hk, &|ti| {
            let (i, kvh) = (ti / hk, ti % hk);
            let seq = &seqs[i];
            // SAFETY: as in decode_f32_batch — disjoint per-task regions.
            let o = unsafe {
                std::slice::from_raw_parts_mut(
                    op.get().add(i * stride + kvh * rep * dh), rep * dh)
            };
            DECODE_SCRATCH.with(|s| {
                blocked::decode_kvh_quant(seq.q, kvh, rep, &seq.k, &seq.v, o,
                                          &mut s.borrow_mut());
            });
        });
    }

    fn nll_rows(&self, logits: &[f32], vocab: usize, targets: &[u16],
                out: &mut [f64]) {
        let rows = targets.len();
        assert!(vocab > 0 && logits.len() >= rows * vocab);
        assert!(out.len() >= rows);
        let (per, n_chunks) = Self::chunks(rows, 4, self.pool.lanes());
        let op = SendPtr::new(out.as_mut_ptr());
        self.pool.run(n_chunks, &|i| {
            let r0 = i * per;
            let r1 = ((i + 1) * per).min(rows);
            for r in r0..r1 {
                let row = &logits[r * vocab..(r + 1) * vocab];
                // SAFETY: disjoint rows per chunk.
                unsafe {
                    *op.get().add(r) = -log_softmax_row(row, targets[r] as usize);
                }
            }
        });
    }

    fn par_for(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        self.pool.run(n, f);
    }
}
