//! `ScalarRef` backend: the original single-threaded kernels from
//! [`crate::gemm`], [`crate::hadamard`] and [`crate::quant::kv`] behind
//! the [`ComputeBackend`] trait.  This is the correctness oracle every
//! other backend is property-tested against (bit-exact on the integer
//! paths), and the baseline the bench tables report speedups over.

use std::cell::RefCell;

use super::{f32_batch_geom, kv_dequant_seq, kv_quant_seq, nll_rows_seq,
            quant_batch_geom, wht_rows_seq, ComputeBackend, DECODE_SCRATCH};
use crate::attention::{decode_seq_f32_ref, decode_seq_quant_ref, DecodeF32Seq,
                       DecodeQuantSeq};
use crate::gemm::{self, WeightsF32, WeightsI4, WeightsI8};

thread_local! {
    // Reused activation-quant scratch, matching what the pre-backend
    // call sites did with their long-lived `scratch` vectors — the
    // oracle's bench timings must not pay a per-call allocation the old
    // code didn't.
    static SCRATCH: RefCell<Vec<i8>> = const { RefCell::new(Vec::new()) };
}

pub struct ScalarRef;

impl ComputeBackend for ScalarRef {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn gemm_f32(&self, x: &[f32], t: usize, w: &WeightsF32, y: &mut [f32]) {
        gemm::gemm_f32(x, t, w, y);
    }

    fn gemm_i8(&self, x: &[f32], t: usize, w: &WeightsI8, bits: u32, clip: f32,
               y: &mut [f32]) {
        SCRATCH.with(|s| gemm::gemm_i8(x, t, w, bits, clip, y, &mut s.borrow_mut()));
    }

    fn gemm_i4(&self, x: &[f32], t: usize, w: &WeightsI4, clip: f32, y: &mut [f32]) {
        SCRATCH.with(|s| gemm::gemm_i4(x, t, w, clip, y, &mut s.borrow_mut()));
    }

    fn had_rows(&self, x: &mut [f32], d: usize) {
        wht_rows_seq(x, d);
    }

    fn quant_rows(&self, x: &[f32], d: usize, bits: u32, clip: f32,
                  codes: &mut [i8], scales: &mut [f32]) {
        for (r, row) in x.chunks_exact(d).enumerate() {
            scales[r] = gemm::quant_row(row, bits, clip,
                                        &mut codes[r * d..(r + 1) * d]);
        }
    }

    fn kv_quant_slab(&self, x: &[f32], d: usize, group: usize, bits: u32, clip: f32)
                     -> (Vec<i8>, Vec<f32>, Vec<f32>) {
        kv_quant_seq(x, d, group, bits, clip)
    }

    fn kv_dequant(&self, codes: &[i8], scales: &[f32], zeros: &[f32],
                  group: usize, out: &mut [f32]) {
        kv_dequant_seq(codes, scales, zeros, group, out);
    }

    fn decode_f32_batch(&self, seqs: &[DecodeF32Seq<'_>], n_heads: usize,
                        out: &mut [f32]) {
        let Some(geom) = f32_batch_geom(seqs, n_heads, out.len()) else {
            return;
        };
        let stride = n_heads * geom.dh;
        DECODE_SCRATCH.with(|s| {
            let scratch = &mut *s.borrow_mut();
            for (seq, o) in seqs.iter().zip(out.chunks_exact_mut(stride)) {
                decode_seq_f32_ref(seq, n_heads, o, scratch);
            }
        });
    }

    fn decode_quant_batch(&self, seqs: &[DecodeQuantSeq<'_>], n_heads: usize,
                          out: &mut [f32]) {
        let Some(geom) = quant_batch_geom(seqs, n_heads, out.len()) else {
            return;
        };
        let stride = n_heads * geom.dh;
        DECODE_SCRATCH.with(|s| {
            let scratch = &mut *s.borrow_mut();
            for (seq, o) in seqs.iter().zip(out.chunks_exact_mut(stride)) {
                decode_seq_quant_ref(seq, n_heads, o, scratch);
            }
        });
    }

    fn nll_rows(&self, logits: &[f32], vocab: usize, targets: &[u16],
                out: &mut [f64]) {
        nll_rows_seq(logits, vocab, targets, out);
    }

    fn par_for(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        for i in 0..n {
            f(i);
        }
    }
}
