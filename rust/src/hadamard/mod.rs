//! Fast Walsh–Hadamard transforms — the rust twin of the Pallas kernel
//! (python/compile/kernels/hadamard.py) and of python's hadamard_utils.
//!
//! Conventions match the python side exactly (tested cross-language through
//! the weights.bin round-trip): orthonormal transforms, Kronecker
//! construction `H_d = H_{2^n} ⊗ H_m` with m ∈ {1, 12, 20} (Paley tables),
//! randomized variant `Q = H · diag(s)`.

use crate::tensor::Mat;
use crate::util::prng::Rng;

/// Paley-I Hadamard matrix of order q+1 (q prime, q ≡ 3 mod 4), entries ±1.
fn paley(q: usize) -> Mat {
    assert_eq!(q % 4, 3);
    let residues: std::collections::HashSet<usize> =
        (1..q).map(|i| (i * i) % q).collect();
    let chi = |a: i64| -> f32 {
        let a = a.rem_euclid(q as i64) as usize;
        if a == 0 {
            0.0
        } else if residues.contains(&a) {
            1.0
        } else {
            -1.0
        }
    };
    let n = q + 1;
    let mut h = Mat::zeros(n, n);
    for v in h.data.iter_mut() {
        *v = 1.0;
    }
    for i in 0..q {
        for j in 0..q {
            h[(i + 1, j + 1)] = if i == j { -1.0 } else { chi(j as i64 - i as i64) };
        }
    }
    h
}

fn known_table(m: usize) -> Option<Mat> {
    match m {
        1 => Some(Mat::eye(1)),
        12 => Some(paley(11)),
        20 => Some(paley(19)),
        _ => None,
    }
}

/// Split d = 2^n · m with m in the known table; None if impossible.
pub fn decompose_dim(d: usize) -> Option<(usize, usize)> {
    for m in [20usize, 12, 1] {
        if d % m == 0 {
            let p = d / m;
            if p.is_power_of_two() {
                return Some((p, m));
            }
        }
    }
    None
}

/// In-place orthonormal WHT of a pow-2-length vector.
pub fn wht_pow2(x: &mut [f32]) {
    let n = x.len();
    assert!(n.is_power_of_two());
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let (a, b) = (x[j], x[j + h]);
                x[j] = a + b;
                x[j + h] = a - b;
            }
            i += 2 * h;
        }
        h *= 2;
    }
    let norm = 1.0 / (n as f32).sqrt();
    for v in x {
        *v *= norm;
    }
}

/// Orthonormal x ← x @ H_d for general d = 2^n·m (Kronecker construction).
///
/// Index convention matches python ref.wht_rows: i = i_pow2 * m + i_m.
pub fn wht(x: &mut [f32]) {
    let d = x.len();
    let (p, m) = decompose_dim(d).unwrap_or_else(|| panic!("no Hadamard for {d}"));
    if m > 1 {
        let hm = known_table(m).unwrap();
        let norm = 1.0 / (m as f32).sqrt();
        let mut buf = vec![0.0f32; m];
        for blk in x.chunks_exact_mut(m) {
            for (j, b) in buf.iter_mut().enumerate() {
                // row-vector times hm: out[j] = Σ_i blk[i] hm[i][j]
                *b = (0..m).map(|i| blk[i] * hm[(i, j)]).sum::<f32>() * norm;
            }
            blk.copy_from_slice(&buf);
        }
    }
    // butterfly over the pow-2 axis with lane stride m
    let mut h = 1;
    while h < p {
        let stride = h * m;
        let mut i = 0;
        while i < d {
            for j in i..i + stride {
                let (a, b) = (x[j], x[j + stride]);
                x[j] = a + b;
                x[j + stride] = a - b;
            }
            i += 2 * stride;
        }
        h *= 2;
    }
    let norm = 1.0 / (p as f32).sqrt();
    for v in x {
        *v *= norm;
    }
}

/// Apply WHT to every row of a matrix.
pub fn wht_rows(m: &mut Mat) {
    let cols = m.cols;
    for r in 0..m.rows {
        let _ = cols;
        wht(m.row_mut(r));
    }
}

/// Dense orthonormal Hadamard matrix (oracle / fusion path).
pub fn hadamard_matrix(d: usize) -> Mat {
    let mut h = Mat::eye(d);
    wht_rows(&mut h);
    // rows of I transformed give Hᵀ; H may be asymmetric for Kronecker m>1.
    // wht computes x@H, so row e_i ↦ H[i,:]… e_i @ H = H[i,:]: correct.
    h
}

/// Randomized Hadamard Q = H · diag(s) with deterministic ±1 signs.
pub fn randomized_hadamard(d: usize, seed: u64) -> Mat {
    let mut q = hadamard_matrix(d);
    let signs = Rng::new(seed).signs(d);
    q.scale_cols(&signs);
    q
}

/// Online randomized transform x ← x @ (H diag(s)): fast WHT then signs.
pub fn randomized_wht(x: &mut [f32], signs: &[f32]) {
    wht(x);
    for (v, s) in x.iter_mut().zip(signs) {
        *v *= s;
    }
}

/// Head-wise transform: x (…, n_heads·d_head) ← x · (I ⊗ H_dh).
pub fn had_headdim(x: &mut [f32], d_head: usize) {
    for h in x.chunks_exact_mut(d_head) {
        wht(h);
    }
}

/// Hadamard-heads (paper Stage 1c): x ← x · (H_nh ⊗ I_dh), mixing heads.
pub fn had_heads(x: &mut [f32], n_heads: usize) {
    let d = x.len();
    let dh = d / n_heads;
    let mut lane = vec![0.0f32; n_heads];
    for j in 0..dh {
        for h in 0..n_heads {
            lane[h] = x[h * dh + j];
        }
        wht(&mut lane);
        for h in 0..n_heads {
            x[h * dh + j] = lane[h];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn decompose() {
        assert_eq!(decompose_dim(256), Some((256, 1)));
        assert_eq!(decompose_dim(1536), Some((128, 12)));
        assert_eq!(decompose_dim(320), Some((16, 20)));
        assert_eq!(decompose_dim(24), Some((2, 12)));
        assert_eq!(decompose_dim(6), None);
    }

    #[test]
    fn hadamard_orthonormal() {
        for d in [2usize, 8, 12, 20, 24, 64, 256, 1536] {
            let h = hadamard_matrix(d);
            let prod = h.matmul(&h.t());
            for i in 0..d {
                for j in 0..d {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((prod[(i, j)] - want).abs() < 1e-3,
                            "d={d} ({i},{j}): {}", prod[(i, j)]);
                }
            }
        }
    }

    #[test]
    fn wht_matches_dense() {
        let mut rng = Rng::new(0);
        for d in [8usize, 12, 24, 48, 128] {
            let x: Vec<f32> = rng.normal_vec(d);
            let h = hadamard_matrix(d);
            let want: Vec<f32> = (0..d)
                .map(|j| (0..d).map(|i| x[i] * h[(i, j)]).sum())
                .collect();
            let mut got = x.clone();
            wht(&mut got);
            prop::assert_close(&got, &want, 1e-4).unwrap();
        }
    }

    #[test]
    fn wht_preserves_norm_property() {
        prop::check("wht-norm", 30, |rng| {
            let d = 1usize << (1 + rng.below(8));
            let x = rng.normal_vec(d);
            let n0: f32 = x.iter().map(|v| v * v).sum();
            let mut y = x.clone();
            wht(&mut y);
            let n1: f32 = y.iter().map(|v| v * v).sum();
            crate::prop_assert!((n0 - n1).abs() < 1e-2 * n0.max(1.0),
                                "norm {n0} vs {n1} at d={d}");
            Ok(())
        });
    }

    #[test]
    fn pow2_wht_is_involution() {
        let mut rng = Rng::new(1);
        let x = rng.normal_vec(64);
        let mut y = x.clone();
        wht(&mut y);
        wht(&mut y);
        prop::assert_close(&y, &x, 1e-4).unwrap();
    }

    #[test]
    fn randomized_is_orthogonal() {
        let q = randomized_hadamard(64, 7);
        let prod = q.matmul(&q.t());
        for i in 0..64 {
            assert!((prod[(i, i)] - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn kronecker_heads_identity() {
        // (I ⊗ H_dh)(H_nh ⊗ I) == full H for pow-2 heads (paper eq. 9)
        let (nh, dh) = (4usize, 8usize);
        let d = nh * dh;
        let mut rng = Rng::new(2);
        let x = rng.normal_vec(d);
        let mut via_steps = x.clone();
        had_headdim(&mut via_steps, dh);
        had_heads(&mut via_steps, nh);
        let mut direct = x.clone();
        wht(&mut direct);
        prop::assert_close(&via_steps, &direct, 1e-4).unwrap();
    }

    #[test]
    fn randomized_wht_matches_matrix() {
        let d = 32;
        let seed = 9;
        let q = randomized_hadamard(d, seed);
        let signs = Rng::new(seed).signs(d);
        let mut rng = Rng::new(3);
        let x = rng.normal_vec(d);
        let want: Vec<f32> = (0..d)
            .map(|j| (0..d).map(|i| x[i] * q[(i, j)]).sum())
            .collect();
        let mut got = x.clone();
        randomized_wht(&mut got, &signs);
        prop::assert_close(&got, &want, 1e-4).unwrap();
    }
}
