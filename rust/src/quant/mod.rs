//! Quantization toolchain (paper Stage 2 + every baseline of Table 1).
//!
//! * [`rtn`]     — round-to-nearest weight quantization, per-column or
//!                 group-wise, symmetric/asymmetric, with the paper's
//!                 linear clip-ratio search over squared error.
//! * [`gptq`]    — GPTQ from scratch: Hessian-driven per-column rounding
//!                 with error feedback (Frantar et al., the paper default).
//! * [`kv`]      — group-wise asymmetric KV-cache codec, bit-exact with the
//!                 python ref (signed code storage) + int4 nibble packing.
//! * [`smooth`]  — SmoothQuant α-migration baseline.
//! * [`outlier`] — QUIK-style outlier-feature selection baseline.

pub mod gptq;
pub mod kv;
pub mod outlier;
pub mod rtn;
pub mod smooth;

/// Largest representable integer for b-bit symmetric quantization (2^(b-1)-1).
pub fn sym_levels(bits: u32) -> i32 {
    (1 << (bits - 1)) - 1
}

/// Fake-quantize an activation row per-token-symmetric (mirror of the
/// Pallas quant kernel; used by native benches and tests).
pub fn fake_quant_token(x: &mut [f32], bits: u32, clip: f32) {
    let levels = sym_levels(bits) as f32;
    let amax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let s = (amax * clip).max(1e-8) / levels;
    for v in x.iter_mut() {
        *v = (*v / s).round().clamp(-levels, levels) * s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels() {
        assert_eq!(sym_levels(4), 7);
        assert_eq!(sym_levels(6), 31);
        assert_eq!(sym_levels(8), 127);
        assert_eq!(sym_levels(2), 1);
    }

    #[test]
    fn fake_quant_token_bound() {
        let mut rng = crate::util::prng::Rng::new(0);
        let x: Vec<f32> = rng.normal_vec(64);
        let mut q = x.clone();
        fake_quant_token(&mut q, 4, 1.0);
        let amax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let step = amax / 7.0;
        for (a, b) in x.iter().zip(&q) {
            assert!((a - b).abs() <= step / 2.0 + 1e-6);
        }
    }
}
