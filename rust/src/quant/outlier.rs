//! QUIK-style outlier-feature retention (Ashkboos et al. 2023) — the
//! "#Outlier Features = 256" baseline of Table 1.
//!
//! From calibration per-channel activation maxima, the top-k channels are
//! marked as outliers; the serving graphs keep those activation features in
//! high precision (the `mask_*` inputs of `baseline_prefill`), and the
//! corresponding weight *rows* are kept unquantized too.

use crate::tensor::Mat;

/// Indices of the k channels with the largest calibration |activation|.
pub fn top_k_outliers(act_amax: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..act_amax.len()).collect();
    idx.sort_by(|&a, &b| act_amax[b].partial_cmp(&act_amax[a]).unwrap());
    let mut top: Vec<usize> = idx.into_iter().take(k).collect();
    top.sort_unstable();
    top
}

/// Build a {0,1} mask (1 = keep in high precision) from outlier indices.
pub fn outlier_mask(d: usize, outliers: &[usize]) -> Vec<f32> {
    let mut m = vec![0.0f32; d];
    for &i in outliers {
        m[i] = 1.0;
    }
    m
}

/// Fake-quantize a weight matrix per-column *except* the outlier input rows,
/// which stay in full precision (QUIK keeps them in higher precision).
pub fn fake_quant_weight_with_outliers(
    w: &mut Mat,
    outliers: &[usize],
    cfg: &super::rtn::WeightQuantCfg,
) {
    let saved: Vec<Vec<f32>> = outliers.iter().map(|&r| w.row(r).to_vec()).collect();
    // exclude outlier rows from the quantization range (QUIK semantics):
    // zero them so column scales reflect only the quantized bulk...
    for &r in outliers {
        w.row_mut(r).fill(0.0);
    }
    super::rtn::fake_quant_weight(w, cfg);
    // ...then restore them at full precision.
    for (&r, vals) in outliers.iter().zip(&saved) {
        w.row_mut(r).copy_from_slice(vals);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::WeightQuantCfg;
    use crate::util::prng::Rng;

    #[test]
    fn top_k_finds_hot_channels() {
        let mut amax = vec![1.0f32; 16];
        amax[3] = 9.0;
        amax[11] = 5.0;
        assert_eq!(top_k_outliers(&amax, 2), vec![3, 11]);
        let m = outlier_mask(16, &[3, 11]);
        assert_eq!(m.iter().sum::<f32>(), 2.0);
        assert_eq!(m[3], 1.0);
    }

    #[test]
    fn outlier_rows_survive_quantization() {
        let mut rng = Rng::new(0);
        let mut w = Mat::randn(16, 8, &mut rng);
        for c in 0..8 {
            w[(5, c)] *= 40.0; // hot row would dominate column scales
        }
        let orig = w.clone();
        fake_quant_weight_with_outliers(
            &mut w, &[5], &WeightQuantCfg { clip_steps: 1, ..WeightQuantCfg::rtn(4) });
        // outlier row exact
        for c in 0..8 {
            assert_eq!(w[(5, c)], orig[(5, c)]);
        }
        // the rest changed (quantized)
        let mut diff = 0.0f32;
        for r in 0..16 {
            if r == 5 {
                continue;
            }
            for c in 0..8 {
                diff += (w[(r, c)] - orig[(r, c)]).abs();
            }
        }
        assert!(diff > 0.0);
    }

    #[test]
    fn retention_beats_plain_rtn_with_outliers() {
        let mut rng = Rng::new(1);
        let mut w = Mat::randn(32, 8, &mut rng);
        for c in 0..8 {
            w[(7, c)] *= 30.0;
        }
        let cfg = WeightQuantCfg { clip_steps: 1, ..WeightQuantCfg::rtn(4) };
        let mut plain = w.clone();
        super::super::rtn::fake_quant_weight(&mut plain, &cfg);
        let mut kept = w.clone();
        fake_quant_weight_with_outliers(&mut kept, &[7], &cfg);
        assert!(kept.sub(&w).frob() < plain.sub(&w).frob());
    }
}
