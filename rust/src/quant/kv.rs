//! KV-cache codec: group-wise asymmetric quantization with *signed* code
//! storage, bit-exact with python's `ref.kv_quant`/`kv_dequant` (the decode
//! graph dequantizes with exactly these scales/zeros), plus int4 nibble
//! packing for the in-memory cache (2 codes/byte — where the paper's 3.89×
//! memory saving comes from).

/// Quantize one group of `x` at `bits`; returns (codes, scale, zero) with
/// codes shifted by -2^(bits-1) so any bits ≤ 8 fits i8.
pub fn quant_group(x: &[f32], bits: u32, clip: f32) -> (Vec<i8>, f32, f32) {
    let qmax = ((1u32 << bits) - 1) as f32;
    let offset = (1i32 << (bits - 1)) as f32;
    let mx = x.iter().fold(f32::MIN, |m, &v| m.max(v));
    let mn = x.iter().fold(f32::MAX, |m, &v| m.min(v));
    let center = (mx + mn) * 0.5;
    let half = (mx - mn) * 0.5 * clip;
    let lo = center - half;
    let scale = (2.0 * half).max(1e-8) / qmax;
    let zero = lo + offset * scale;
    let codes = x
        .iter()
        .map(|&v| (((v - lo) / scale).round().clamp(0.0, qmax) - offset) as i8)
        .collect();
    (codes, scale, zero)
}

pub fn dequant_group(codes: &[i8], scale: f32, zero: f32, out: &mut [f32]) {
    for (o, &c) in out.iter_mut().zip(codes) {
        *o = c as f32 * scale + zero;
    }
}

/// Quantize a (tokens × d) slab with groups of `group` along d.
/// Returns codes (len = x.len()), scales and zeros (len = x.len()/group).
pub fn quant_slab(x: &[f32], d: usize, group: usize, bits: u32, clip: f32)
                  -> (Vec<i8>, Vec<f32>, Vec<f32>) {
    assert_eq!(d % group, 0);
    let mut codes = Vec::with_capacity(x.len());
    let mut scales = Vec::with_capacity(x.len() / group);
    let mut zeros = Vec::with_capacity(x.len() / group);
    for row in x.chunks_exact(d) {
        for g in row.chunks_exact(group) {
            let (c, s, z) = quant_group(g, bits, clip);
            codes.extend_from_slice(&c);
            scales.push(s);
            zeros.push(z);
        }
    }
    (codes, scales, zeros)
}

/// Pack signed 4-bit codes (−8..=7) two per byte (lo nibble first).
pub fn pack_nibbles(codes: &[i8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(codes.len().div_ceil(2));
    for pair in codes.chunks(2) {
        let lo = (pair[0] as u8) & 0x0F;
        let hi = if pair.len() > 1 { (pair[1] as u8) & 0x0F } else { 0 };
        out.push(lo | (hi << 4));
    }
    out
}

/// Unpack nibble-packed codes back to sign-extended i8.
pub fn unpack_nibbles(packed: &[u8], n: usize, out: &mut [i8]) {
    assert!(out.len() >= n);
    for i in 0..n {
        let byte = packed[i / 2];
        let nib = if i % 2 == 0 { byte & 0x0F } else { byte >> 4 };
        // sign-extend 4-bit two's complement
        out[i] = ((nib << 4) as i8) >> 4;
    }
}

/// Bytes required to store `n` codes at `bits` (packed), vs f16 baseline.
pub fn packed_bytes(n: usize, bits: u32) -> usize {
    (n * bits as usize).div_ceil(8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prng::Rng, prop};

    #[test]
    fn roundtrip_bound() {
        let mut rng = Rng::new(0);
        for bits in [2u32, 3, 4, 8] {
            let x = rng.normal_vec(32);
            let (c, s, z) = quant_group(&x, bits, 1.0);
            let mut back = vec![0.0; 32];
            dequant_group(&c, s, z, &mut back);
            let range = x.iter().fold(f32::MIN, |m, &v| m.max(v))
                - x.iter().fold(f32::MAX, |m, &v| m.min(v));
            let step = range / ((1u32 << bits) - 1) as f32;
            for (a, b) in x.iter().zip(&back) {
                assert!((a - b).abs() <= step / 2.0 + 1e-5, "bits={bits}");
            }
        }
    }

    #[test]
    fn codes_fit_signed_storage() {
        let mut rng = Rng::new(1);
        let x = rng.normal_vec(64);
        for bits in [2u32, 3, 4, 8] {
            let (c, _, _) = quant_group(&x, bits, 0.95);
            let lo = -(1i32 << (bits - 1)) as i32;
            let hi = (1i32 << (bits - 1)) - 1;
            for &v in &c {
                assert!((v as i32) >= lo && (v as i32) <= hi, "bits={bits} v={v}");
            }
        }
    }

    #[test]
    fn matches_python_semantics() {
        // mirror of ref.kv_quant on a fixed vector: scale = clipped-range/qmax,
        // zero folded with the signed offset
        let x = [1.0f32, -1.0, 0.5, 0.25];
        let (c, s, z) = quant_group(&x, 4, 1.0);
        assert!((s - 2.0 / 15.0).abs() < 1e-6);
        let mut back = vec![0.0; 4];
        dequant_group(&c, s, z, &mut back);
        prop::assert_close(&back, &x, s / 2.0 + 1e-6).unwrap();
    }

    #[test]
    fn constant_group_exact() {
        let x = [1.234f32; 16];
        let (c, s, z) = quant_group(&x, 4, 0.95);
        let mut back = vec![0.0; 16];
        dequant_group(&c, s, z, &mut back);
        prop::assert_close(&back, &x, 1e-5).unwrap();
    }

    #[test]
    fn nibble_roundtrip_exact() {
        prop::check("nibble-roundtrip", 30, |rng| {
            let n = 1 + rng.below(100);
            let codes: Vec<i8> =
                (0..n).map(|_| (rng.below(16) as i8) - 8).collect();
            let packed = pack_nibbles(&codes);
            crate::prop_assert!(packed.len() == n.div_ceil(2), "len");
            let mut back = vec![0i8; n];
            unpack_nibbles(&packed, n, &mut back);
            crate::prop_assert!(back == codes, "mismatch {codes:?} vs {back:?}");
            Ok(())
        });
    }

    #[test]
    fn slab_layout() {
        let mut rng = Rng::new(2);
        let (d, group, rows) = (16usize, 4usize, 3usize);
        let x = rng.normal_vec(d * rows);
        let (codes, scales, zeros) = quant_slab(&x, d, group, 4, 0.95);
        assert_eq!(codes.len(), x.len());
        assert_eq!(scales.len(), rows * d / group);
        assert_eq!(zeros.len(), scales.len());
        // dequant slab-wise and check bound
        for (i, g) in x.chunks_exact(group).enumerate() {
            let mut back = vec![0.0; group];
            dequant_group(&codes[i * group..(i + 1) * group], scales[i], zeros[i],
                          &mut back);
            let range: f32 = g.iter().fold(f32::MIN, |m, &v| m.max(v))
                - g.iter().fold(f32::MAX, |m, &v| m.min(v));
            for (a, b) in g.iter().zip(&back) {
                assert!((a - b).abs() <= range * 0.05 + range / 15.0 + 1e-5);
            }
        }
    }

    #[test]
    fn memory_accounting() {
        assert_eq!(packed_bytes(256, 4), 128);
        assert_eq!(packed_bytes(256, 3), 96);
        assert_eq!(packed_bytes(255, 4), 128); // ceil
        assert_eq!(packed_bytes(256, 8), 256);
    }
}
