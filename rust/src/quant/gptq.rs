//! GPTQ (Frantar et al. 2022) from scratch — the paper's default weight
//! quantizer (Stage 2a).
//!
//! Per weight matrix W (in × out) with layer-input Hessian H = Σ x xᵀ
//! (accumulated by the `collect_*` graphs):
//!
//! 1. dampen H (percdamp · mean diag), compute U = chol(H⁻¹) upper;
//! 2. walk input rows left→right; quantize row i of W against the running
//!    residual, distribute the rounding error onto not-yet-quantized rows
//!    via U's column — exactly the blocked error-feedback recursion of the
//!    paper (here unblocked; at toolchain sizes the O(d²·out) cost is fine).
//!
//! Supports per-column symmetric scales (paper default) and group-wise
//! scales recomputed every `group` rows (the 64G/128G/256G rows of Table 4).

use crate::linalg;
use crate::tensor::Mat;

#[derive(Clone, Copy, Debug)]
pub struct GptqCfg {
    pub bits: u32,
    /// 0 → per-column scales from the full column; else rows per group.
    pub group: usize,
    pub percdamp: f64,
    /// clip-ratio linear-search steps for the scale of each (group, column).
    pub clip_steps: usize,
    pub min_clip: f32,
}

impl GptqCfg {
    pub fn new(bits: u32) -> Self {
        GptqCfg { bits, group: 0, percdamp: 0.01, clip_steps: 8, min_clip: 0.7 }
    }

    pub fn grouped(bits: u32, group: usize) -> Self {
        GptqCfg { group, ..Self::new(bits) }
    }
}

/// Pick the per-column scale minimizing squared error over a row range.
fn best_scale(w: &Mat, rows: std::ops::Range<usize>, col: usize, cfg: &GptqCfg) -> f32 {
    let levels = super::sym_levels(cfg.bits) as f32;
    let amax = rows.clone().fold(0.0f32, |m, r| m.max(w[(r, col)].abs()));
    if amax < 1e-12 {
        return 1e-8;
    }
    let mut best = (f64::MAX, amax / levels);
    for i in 0..cfg.clip_steps.max(1) {
        let clip = if cfg.clip_steps <= 1 {
            1.0
        } else {
            1.0 - (1.0 - cfg.min_clip) * i as f32 / (cfg.clip_steps - 1) as f32
        };
        let s = (amax * clip).max(1e-8) / levels;
        let err: f64 = rows
            .clone()
            .map(|r| {
                let v = w[(r, col)];
                let q = (v / s).round().clamp(-levels, levels) * s;
                ((v - q) as f64).powi(2)
            })
            .sum();
        if err < best.0 {
            best = (err, s);
        }
    }
    best.1
}

/// Quantize `w` (in × out) in place against Hessian `h` (in × in).
/// Returns the final quantized (dequantized-value) matrix's scales per
/// (group, column), row-major by group.
pub fn gptq_quantize(w: &mut Mat, h: &Mat, cfg: &GptqCfg) -> Vec<f32> {
    let d = w.rows;
    assert_eq!(h.rows, d);
    assert_eq!(h.cols, d);
    let levels = super::sym_levels(cfg.bits) as f32;
    let group = if cfg.group == 0 { d } else { cfg.group };
    assert_eq!(d % group, 0);

    // U = chol(H⁻¹) upper-triangular: U[i][j], j >= i
    let u = linalg::inverse_cholesky_upper(h, cfg.percdamp);
    let n_groups = d / group;
    let mut scales = vec![0.0f32; n_groups * w.cols];

    for gi in 0..n_groups {
        let rows = gi * group..(gi + 1) * group;
        // scales from the *current* (error-compensated) residual weights
        for c in 0..w.cols {
            scales[gi * w.cols + c] = best_scale(w, rows.clone(), c, cfg);
        }
        for i in rows {
            let uii = u[(i, i)].max(1e-12);
            for c in 0..w.cols {
                let s = scales[gi * w.cols + c];
                let v = w[(i, c)];
                let q = (v / s).round().clamp(-levels, levels) * s;
                let err = (v - q) / uii;
                w[(i, c)] = q;
                // propagate error to the not-yet-quantized rows
                for j in (i + 1)..d {
                    w[(j, c)] -= err * u[(i, j)];
                }
            }
        }
    }
    scales
}

/// Layer-wise proxy loss GPTQ minimizes: tr((W−Q)ᵀ H (W−Q)).
pub fn proxy_loss(w_orig: &Mat, w_quant: &Mat, h: &Mat) -> f64 {
    let diff = w_orig.sub(w_quant);
    let hd = h.matmul(&diff);
    let mut tr = 0.0f64;
    for c in 0..diff.cols {
        for r in 0..diff.rows {
            tr += diff[(r, c)] as f64 * hd[(r, c)] as f64;
        }
    }
    tr
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::{fake_quant_weight, WeightQuantCfg};
    use crate::util::prng::Rng;

    /// Correlated calibration Hessian: H = XᵀX from AR(1)-ish rows.
    fn hessian(d: usize, n: usize, rng: &mut Rng) -> Mat {
        let mut h = Mat::zeros(d, d);
        let mut x = vec![0.0f32; d];
        for _ in 0..n {
            let mut prev = 0.0f32;
            for v in x.iter_mut() {
                prev = 0.7 * prev + rng.normal_f32();
                *v = prev;
            }
            for i in 0..d {
                for j in 0..d {
                    h[(i, j)] += x[i] * x[j];
                }
            }
        }
        h
    }

    #[test]
    fn beats_rtn_on_proxy_loss() {
        let mut rng = Rng::new(0);
        let d = 32;
        let w = Mat::randn(d, 16, &mut rng);
        let h = hessian(d, 256, &mut rng);

        let mut rtn_w = w.clone();
        fake_quant_weight(&mut rtn_w,
            &WeightQuantCfg { clip_steps: 1, ..WeightQuantCfg::rtn(3) });
        let mut gptq_w = w.clone();
        gptq_quantize(&mut gptq_w, &h, &GptqCfg { clip_steps: 1, ..GptqCfg::new(3) });

        let l_rtn = proxy_loss(&w, &rtn_w, &h);
        let l_gptq = proxy_loss(&w, &gptq_w, &h);
        assert!(l_gptq < l_rtn, "gptq {l_gptq} !< rtn {l_rtn}");
    }

    #[test]
    fn identity_hessian_reduces_to_rtn() {
        // with H = I the error feedback does nothing: GPTQ == RTN
        let mut rng = Rng::new(1);
        let d = 16;
        let w = Mat::randn(d, 8, &mut rng);
        let h = Mat::eye(d);
        let mut g = w.clone();
        gptq_quantize(&mut g, &h, &GptqCfg { clip_steps: 1, percdamp: 1e-9, ..GptqCfg::new(4) });
        let mut r = w.clone();
        fake_quant_weight(&mut r,
            &WeightQuantCfg { clip_steps: 1, ..WeightQuantCfg::rtn(4) });
        for (a, b) in g.data.iter().zip(&r.data) {
            assert!((a - b).abs() < 2e-2, "{a} vs {b}");
        }
    }

    #[test]
    fn quantized_values_on_grid() {
        let mut rng = Rng::new(2);
        let d = 16;
        let w = Mat::randn(d, 4, &mut rng);
        let h = hessian(d, 64, &mut rng);
        let mut g = w.clone();
        let scales = gptq_quantize(&mut g, &h, &GptqCfg { clip_steps: 1, ..GptqCfg::new(4) });
        assert_eq!(scales.len(), 4);
        for c in 0..4 {
            for r in 0..d {
                let ratio = g[(r, c)] / scales[c];
                assert!((ratio - ratio.round()).abs() < 1e-3,
                        "off grid: {} / {}", g[(r, c)], scales[c]);
                assert!(ratio.round().abs() <= 7.0);
            }
        }
    }

    #[test]
    fn group_scales_layout() {
        let mut rng = Rng::new(3);
        let d = 32;
        let w0 = Mat::randn(d, 6, &mut rng);
        let h = hessian(d, 64, &mut rng);
        let mut w = w0.clone();
        let scales = gptq_quantize(&mut w, &h, &GptqCfg::grouped(4, 8));
        assert_eq!(scales.len(), (d / 8) * 6);
    }

    #[test]
    fn more_bits_lower_loss() {
        let mut rng = Rng::new(4);
        let d = 24;
        let w = Mat::randn(d, 8, &mut rng);
        let h = hessian(d, 128, &mut rng);
        let mut prev = f64::MAX;
        for bits in [2u32, 4, 8] {
            let mut q = w.clone();
            gptq_quantize(&mut q, &h, &GptqCfg::new(bits));
            let loss = proxy_loss(&w, &q, &h);
            assert!(loss <= prev, "bits {bits}: {loss} > {prev}");
            prev = loss;
        }
    }
}
