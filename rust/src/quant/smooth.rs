//! SmoothQuant (Xiao et al. 2023) — the calibration-based baseline of
//! Table 1.  Migrates quantization difficulty from activations to weights
//! with per-channel scales s_j = amax_act_j^α / amax_w_j^(1−α); activations
//! are divided by s (folded into the *preceding* weight / norm) and the
//! weight rows are multiplied by s.

use crate::tensor::Mat;

#[derive(Clone, Copy, Debug)]
pub struct SmoothCfg {
    pub alpha: f32,
}

impl Default for SmoothCfg {
    fn default() -> Self {
        SmoothCfg { alpha: 0.5 }
    }
}

/// Compute migration scales from calibration per-channel activation maxima
/// and the weight matrix (in × out): s_j over input channels j.
pub fn smooth_scales(act_amax: &[f32], w: &Mat, cfg: &SmoothCfg) -> Vec<f32> {
    assert_eq!(act_amax.len(), w.rows);
    (0..w.rows)
        .map(|j| {
            let wmax = (0..w.cols).fold(0.0f32, |m, c| m.max(w[(j, c)].abs()));
            let a = act_amax[j].max(1e-5);
            let s = a.powf(cfg.alpha) / wmax.max(1e-5).powf(1.0 - cfg.alpha);
            s.clamp(1e-3, 1e3)
        })
        .collect()
}

/// Apply the migration: scale weight rows by s (the activation side divides
/// by s, which the caller folds into the producer of this activation).
pub fn apply_to_weight(w: &mut Mat, scales: &[f32]) {
    w.scale_rows(scales);
}

/// Fold 1/s into the producer's output columns (e.g. a norm gamma or the
/// up-projection that feeds this activation).
pub fn fold_into_producer(producer_cols: &mut [f32], scales: &[f32]) {
    assert_eq!(producer_cols.len(), scales.len());
    for (g, s) in producer_cols.iter_mut().zip(scales) {
        *g /= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn migration_preserves_product() {
        // (x / s) @ (diag(s) W) == x @ W
        let mut rng = Rng::new(0);
        let d = 16;
        let mut w = Mat::randn(d, 8, &mut rng);
        let x: Vec<f32> = rng.normal_vec(d);
        let amax: Vec<f32> = x.iter().map(|v| v.abs() * 3.0).collect();
        let y0: Vec<f32> = (0..8)
            .map(|c| (0..d).map(|j| x[j] * w[(j, c)]).sum())
            .collect();
        let s = smooth_scales(&amax, &w, &SmoothCfg::default());
        apply_to_weight(&mut w, &s);
        let xs: Vec<f32> = x.iter().zip(&s).map(|(v, si)| v / si).collect();
        let y1: Vec<f32> = (0..8)
            .map(|c| (0..d).map(|j| xs[j] * w[(j, c)]).sum())
            .collect();
        for (a, b) in y0.iter().zip(&y1) {
            assert!((a - b).abs() < 1e-3 * a.abs().max(1.0));
        }
    }

    #[test]
    fn flattens_outlier_channels() {
        let mut rng = Rng::new(1);
        let d = 32;
        let w = Mat::randn(d, 8, &mut rng);
        let mut amax = vec![1.0f32; d];
        amax[3] = 100.0; // hot activation channel
        let s = smooth_scales(&amax, &w, &SmoothCfg::default());
        // after division the hot channel's effective activation range shrinks
        let effective: Vec<f32> = amax.iter().zip(&s).map(|(a, si)| a / si).collect();
        let ratio = effective[3] / effective[0];
        assert!(ratio < 100.0 / 5.0, "migration too weak: {ratio}");
    }

    #[test]
    fn alpha_zero_is_weight_only() {
        let mut rng = Rng::new(2);
        let w = Mat::randn(8, 4, &mut rng);
        let amax = vec![2.0f32; 8];
        let s = smooth_scales(&amax, &w, &SmoothCfg { alpha: 0.0 });
        // α=0: s = 1 / wmax → equalizes weight rows regardless of acts
        for (j, &si) in s.iter().enumerate() {
            let wmax = (0..4).fold(0.0f32, |m, c| m.max(w[(j, c)].abs()));
            assert!((si - 1.0 / wmax).abs() < 1e-4);
        }
    }
}
