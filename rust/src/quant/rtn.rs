//! Round-to-nearest weight quantization (paper Stage 2a, RTN variant).
//!
//! Weights are (in, out) matrices quantized **per column** (the paper's
//! per-channel symmetric scheme) or in groups of `group` input rows
//! (the paper's 64G/128G/256G group-wise scheme, Table 4).  The clip ratio
//! per column is found by the paper's linear search over squared error.

use crate::tensor::Mat;

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WeightQuantCfg {
    pub bits: u32,
    /// 0 → whole-column groups (per-channel); else rows per group.
    pub group: usize,
    pub symmetric: bool,
    /// linear clip search steps; 1 → fixed clip 1.0.
    pub clip_steps: usize,
    pub min_clip: f32,
}

impl WeightQuantCfg {
    pub fn rtn(bits: u32) -> Self {
        WeightQuantCfg { bits, group: 0, symmetric: true, clip_steps: 10, min_clip: 0.6 }
    }

    pub fn grouped(bits: u32, group: usize) -> Self {
        WeightQuantCfg { group, ..Self::rtn(bits) }
    }

    pub fn asymmetric(bits: u32) -> Self {
        WeightQuantCfg { symmetric: false, ..Self::rtn(bits) }
    }
}

/// Quantize+dequantize one contiguous group of values with the best clip
/// found by linear search (MSE objective, like the paper).
fn fq_group(vals: &mut [f32], cfg: &WeightQuantCfg) {
    if vals.is_empty() {
        return;
    }
    let clips = (0..cfg.clip_steps.max(1)).map(|i| {
        if cfg.clip_steps <= 1 {
            1.0
        } else {
            1.0 - (1.0 - cfg.min_clip) * i as f32 / (cfg.clip_steps - 1) as f32
        }
    });
    let orig = vals.to_vec();
    let mut best: Option<(f64, Vec<f32>)> = None;
    for clip in clips {
        let mut cand = orig.clone();
        fq_group_fixed(&mut cand, cfg, clip);
        let err: f64 = cand.iter().zip(&orig)
            .map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        if best.as_ref().map(|(e, _)| err < *e).unwrap_or(true) {
            best = Some((err, cand));
        }
    }
    vals.copy_from_slice(&best.unwrap().1);
}

fn fq_group_fixed(vals: &mut [f32], cfg: &WeightQuantCfg, clip: f32) {
    if cfg.symmetric {
        let levels = super::sym_levels(cfg.bits) as f32;
        let amax = vals.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let s = (amax * clip).max(1e-8) / levels;
        for v in vals.iter_mut() {
            *v = (*v / s).round().clamp(-levels, levels) * s;
        }
    } else {
        let qmax = ((1u32 << cfg.bits) - 1) as f32;
        let mx = vals.iter().fold(f32::MIN, |m, &v| m.max(v));
        let mn = vals.iter().fold(f32::MAX, |m, &v| m.min(v));
        let center = (mx + mn) * 0.5;
        let half = (mx - mn) * 0.5 * clip;
        let lo = center - half;
        let s = (2.0 * half).max(1e-8) / qmax;
        for v in vals.iter_mut() {
            *v = ((*v - lo) / s).round().clamp(0.0, qmax) * s + lo;
        }
    }
}

/// Fake-quantize a weight matrix in place (per-column / group-wise).
pub fn fake_quant_weight(w: &mut Mat, cfg: &WeightQuantCfg) {
    let group = if cfg.group == 0 { w.rows } else { cfg.group };
    assert_eq!(w.rows % group, 0, "rows {} not divisible by group {group}", w.rows);
    for c in 0..w.cols {
        let mut col = w.col(c);
        for g in col.chunks_mut(group) {
            fq_group(g, cfg);
        }
        w.set_col(c, &col);
    }
}

/// Integer-emitting per-column symmetric quantization with the same MSE
/// clip search as [`fake_quant_weight`] (per-channel only: `group == 0`,
/// `symmetric`).  Returns **column-major** codes (`cols[c * rows + r]`,
/// the [`crate::gemm::WeightsI8::cols`] layout) plus per-column scales
/// whose dequantization `code · scale` is bit-identical to the values
/// [`fake_quant_weight`] writes — so integer-GEMM containers built from
/// them compute on exactly the weight grid the compiled graphs were
/// handed, rather than re-quantizing an already-quantized matrix.
pub fn quant_weight_int_searched(w: &Mat, cfg: &WeightQuantCfg)
                                 -> (Vec<i8>, Vec<f32>) {
    assert!(cfg.symmetric && cfg.group == 0,
            "searched int codes are per-channel symmetric only");
    let levels = super::sym_levels(cfg.bits) as f32;
    let mut codes = vec![0i8; w.rows * w.cols];
    let mut scales = vec![0.0f32; w.cols];
    for c in 0..w.cols {
        let col = w.col(c);
        let amax = col.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        // identical candidate sequence, error arithmetic (f32 residual
        // cast to f64) and strict-improvement tie-break as `fq_group`
        let mut best: Option<(f64, f32)> = None;
        for i in 0..cfg.clip_steps.max(1) {
            let clip = if cfg.clip_steps <= 1 {
                1.0
            } else {
                1.0 - (1.0 - cfg.min_clip) * i as f32
                    / (cfg.clip_steps - 1) as f32
            };
            let s = (amax * clip).max(1e-8) / levels;
            let err: f64 = col.iter().map(|&v| {
                let q = (v / s).round().clamp(-levels, levels) * s;
                ((q - v) as f64).powi(2)
            }).sum();
            if best.as_ref().map(|(e, _)| err < *e).unwrap_or(true) {
                best = Some((err, s));
            }
        }
        let s = best.unwrap().1;
        scales[c] = s;
        for (r, &v) in col.iter().enumerate() {
            codes[c * w.rows + r] = (v / s).round().clamp(-levels, levels) as i8;
        }
    }
    (codes, scales)
}

/// Integer-emitting per-column symmetric quantization: (codes, scales).
/// Codes in [-levels, levels]; used by the native int GEMM benches.
pub fn quant_weight_int(w: &Mat, bits: u32) -> (Vec<i8>, Vec<f32>) {
    let levels = super::sym_levels(bits) as f32;
    let mut scales = vec![0.0f32; w.cols];
    for c in 0..w.cols {
        let amax = (0..w.rows).fold(0.0f32, |m, r| m.max(w[(r, c)].abs()));
        scales[c] = amax.max(1e-8) / levels;
    }
    let mut codes = vec![0i8; w.rows * w.cols];
    for r in 0..w.rows {
        for c in 0..w.cols {
            codes[r * w.cols + c] =
                (w[(r, c)] / scales[c]).round().clamp(-levels, levels) as i8;
        }
    }
    (codes, scales)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prng::Rng, prop};

    #[test]
    fn roundtrip_error_bounded() {
        let mut rng = Rng::new(0);
        let w = Mat::randn(32, 16, &mut rng);
        let mut q = w.clone();
        fake_quant_weight(&mut q, &WeightQuantCfg { clip_steps: 1, ..WeightQuantCfg::rtn(4) });
        for c in 0..w.cols {
            let amax = (0..w.rows).fold(0.0f32, |m, r| m.max(w[(r, c)].abs()));
            let step = amax / 7.0;
            for r in 0..w.rows {
                assert!((w[(r, c)] - q[(r, c)]).abs() <= step / 2.0 + 1e-6);
            }
        }
    }

    #[test]
    fn clip_search_never_worse() {
        prop::check("clip-search", 20, |rng| {
            let w = Mat::randn(16, 4, &mut rng.clone());
            let mut fixed = w.clone();
            fake_quant_weight(&mut fixed,
                &WeightQuantCfg { clip_steps: 1, ..WeightQuantCfg::rtn(3) });
            let mut searched = w.clone();
            fake_quant_weight(&mut searched, &WeightQuantCfg::rtn(3));
            let e_fixed = fixed.sub(&w).frob();
            let e_search = searched.sub(&w).frob();
            crate::prop_assert!(e_search <= e_fixed + 1e-6,
                                "search {e_search} > fixed {e_fixed}");
            Ok(())
        });
    }

    #[test]
    fn grouping_improves_outlier_columns() {
        // one hot input row makes whole-column scales terrible; groups fix it
        let mut rng = Rng::new(1);
        let mut w = Mat::randn(64, 8, &mut rng);
        for c in 0..8 {
            w[(0, c)] *= 50.0;
        }
        let mut per_col = w.clone();
        fake_quant_weight(&mut per_col,
            &WeightQuantCfg { clip_steps: 1, ..WeightQuantCfg::rtn(4) });
        let mut grouped = w.clone();
        fake_quant_weight(&mut grouped,
            &WeightQuantCfg { clip_steps: 1, ..WeightQuantCfg::grouped(4, 16) });
        assert!(grouped.sub(&w).frob() < per_col.sub(&w).frob() * 0.6);
    }

    #[test]
    fn asymmetric_handles_shifted_data() {
        let mut rng = Rng::new(2);
        let mut w = Mat::randn(32, 4, &mut rng);
        for v in w.data.iter_mut() {
            *v = *v * 0.1 + 3.0; // all-positive, far from zero
        }
        let mut sym = w.clone();
        fake_quant_weight(&mut sym,
            &WeightQuantCfg { clip_steps: 1, ..WeightQuantCfg::rtn(3) });
        let mut asym = w.clone();
        fake_quant_weight(&mut asym,
            &WeightQuantCfg { clip_steps: 1, ..WeightQuantCfg::asymmetric(3) });
        assert!(asym.sub(&w).frob() < sym.sub(&w).frob() * 0.5);
    }

    #[test]
    fn int_codes_match_fake_quant() {
        let mut rng = Rng::new(3);
        let w = Mat::randn(24, 6, &mut rng);
        let (codes, scales) = quant_weight_int(&w, 4);
        let mut fq = w.clone();
        fake_quant_weight(&mut fq,
            &WeightQuantCfg { clip_steps: 1, ..WeightQuantCfg::rtn(4) });
        for r in 0..w.rows {
            for c in 0..w.cols {
                let deq = codes[r * w.cols + c] as f32 * scales[c];
                assert!((deq - fq[(r, c)]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn searched_int_codes_bit_identical_to_fake_quant() {
        // the native executor's whole parity story: codes · scale must
        // reproduce the clip-searched fake-quant grid *bitwise*
        let mut rng = Rng::new(5);
        let w = Mat::randn(32, 12, &mut rng);
        let cfg = WeightQuantCfg::rtn(4);
        let (codes, scales) = quant_weight_int_searched(&w, &cfg);
        let mut fq = w.clone();
        fake_quant_weight(&mut fq, &cfg);
        for c in 0..w.cols {
            for r in 0..w.rows {
                let deq = codes[c * w.rows + r] as f32 * scales[c];
                assert_eq!(deq.to_bits(), fq[(r, c)].to_bits(),
                           "({r},{c}): {deq} != {}", fq[(r, c)]);
            }
        }
    }

    #[test]
    fn bits_monotonicity() {
        let mut rng = Rng::new(4);
        let w = Mat::randn(64, 8, &mut rng);
        let mut errs = Vec::new();
        for bits in [2u32, 3, 4, 6, 8] {
            let mut q = w.clone();
            fake_quant_weight(&mut q, &WeightQuantCfg::rtn(bits));
            errs.push(q.sub(&w).frob());
        }
        for pair in errs.windows(2) {
            assert!(pair[1] <= pair[0] + 1e-6, "more bits must not hurt: {errs:?}");
        }
    }
}
