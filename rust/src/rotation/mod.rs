//! Pluggable rotation schemes — the "which orthogonal Q" axis of QuaRot.
//!
//! The paper's incoherence processing is one point in a family: Table 8
//! ablates randomized Hadamard against random orthogonal matrices, and
//! follow-ups (SpinQuant, DFRot, SmoothRot — see PAPERS.md) treat the
//! rotation itself as a tunable.  This module makes the choice explicit:
//! a [`RotationScheme`] bundles the offline residual-rotation construction
//! (the Q fused into weights by `model::transform::rotate`) with the two
//! knobs the serving stack threads through weight prep — whether
//! per-channel SmoothQuant scales are folded around Q
//! ([`RotationScheme::channel_scaled`]) and which online per-head
//! transform runs inside the kernels.
//!
//! Three implementations, selected by `--rotation` on the CLI (and the
//! optional `rotation` manifest field):
//!
//! * [`RandomizedHadamard`] — `Q = H·diag(s)`, the paper's default.
//!   Artifacts: the `rot.*` weight set; Q is reconstructible from
//!   `meta.q_signs`, so `verify` can check `rotation_mismatch`.
//! * [`RandomOrthogonal`] — QR-orthogonalized Gaussian Q (Table 8's
//!   weaker ablation).  Artifacts: the `rnd.*` weight set plus the full
//!   Q itself as `meta.rnd_q` (a QR factorization is not reproducible
//!   from a seed across languages), so `verify --rotation random`
//!   re-rotates `base.*` with the stored Q and checks `rnd.*`.
//! * [`ChannelScaledHadamard`] — SmoothRot-style scale-then-rotate: the
//!   same Hadamard Q, with SmoothQuant α-migration scales folded into
//!   the norm/producer weights around it at prep time.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::coordinator::runner::{QuantSpec, Variant};
use crate::hadamard;
use crate::linalg;
use crate::model::transform;
use crate::model::{ModelConfig, Tensor, Weights};
use crate::tensor::Mat;
use crate::util::prng::Rng;

/// Which orthogonal rotation family is fused into the weights.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RotationKind {
    #[default]
    Hadamard,
    Random,
    ScaledHadamard,
}

impl RotationKind {
    pub const ALL: [RotationKind; 3] =
        [RotationKind::Hadamard, RotationKind::Random,
         RotationKind::ScaledHadamard];

    pub fn as_str(&self) -> &'static str {
        match self {
            RotationKind::Hadamard => "hadamard",
            RotationKind::Random => "random",
            RotationKind::ScaledHadamard => "scaled-hadamard",
        }
    }

    pub fn parse(s: &str) -> Result<RotationKind> {
        Ok(match s {
            "hadamard" => RotationKind::Hadamard,
            "random" => RotationKind::Random,
            "scaled-hadamard" => RotationKind::ScaledHadamard,
            other => bail!("unknown rotation '{other}' \
                            (hadamard|random|scaled-hadamard)"),
        })
    }

    /// Retarget a quantization spec at this rotation's artifact set:
    /// `random` switches to the `rnd.*` weights (`Variant::QuarotRandom`),
    /// `scaled-hadamard` keeps the `rot.*` weights but turns on the
    /// SmoothQuant fold (which then requires calibration stats at
    /// runner construction).  Rotations only exist for rotated variants —
    /// the fp16/RTN baseline has no Q to choose.
    pub fn apply_to_spec(&self, spec: &mut QuantSpec) -> Result<()> {
        if !spec.variant.is_rotated() {
            bail!("--rotation requires a rotated scheme (quarot-int4/6/8), \
                   not the baseline");
        }
        match self {
            RotationKind::Hadamard => {}
            RotationKind::Random => {
                if spec.variant == Variant::QuarotH16 {
                    bail!("--rotation random has no fp16-head artifact set \
                           (rnd.* ships int-head graphs only)");
                }
                spec.variant = Variant::QuarotRandom;
            }
            RotationKind::ScaledHadamard => spec.smooth = true,
        }
        Ok(())
    }
}

impl std::fmt::Display for RotationKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// A rotation scheme: how the residual rotation Q is constructed offline
/// and which per-head/channel treatment rides along at weight prep.
pub trait RotationScheme: Sync {
    fn kind(&self) -> RotationKind;

    /// Construct the residual rotation Q (d × d, orthogonal).  The same
    /// (d, seed) must always reproduce the same Q — `rotation_mismatch`
    /// style verification depends on deterministic reconstruction.
    fn build_q(&self, d: usize, seed: u64) -> Mat;

    /// Scale-then-rotate: fold SmoothQuant per-channel scales around Q
    /// during weight prep (requires calibration activation maxima).
    fn channel_scaled(&self) -> bool {
        false
    }

    /// The online per-head transform the kernels apply to V/O streams
    /// (paper Stage 1c).  Every current scheme keeps the Hadamard here —
    /// it is the only transform with an O(d log d) online form.
    fn online_headdim(&self, x: &mut [f32], d_head: usize) {
        hadamard::had_headdim(x, d_head);
    }

    /// Rotate a base checkpoint with this scheme's Q — the full Stage-1
    /// fusion of `model::transform::rotate`.
    fn rotate(&self, cfg: &ModelConfig, base: &BTreeMap<String, &Tensor>,
              seed: u64) -> Result<BTreeMap<String, Tensor>> {
        transform::rotate(cfg, base, &self.build_q(cfg.d_model, seed))
    }
}

/// `Q = H·diag(s)` — the paper's randomized Hadamard (default).
pub struct RandomizedHadamard;

impl RotationScheme for RandomizedHadamard {
    fn kind(&self) -> RotationKind {
        RotationKind::Hadamard
    }

    fn build_q(&self, d: usize, seed: u64) -> Mat {
        hadamard::randomized_hadamard(d, seed)
    }
}

/// QR-orthogonalized Gaussian Q — Table 8's random-orthogonal ablation.
pub struct RandomOrthogonal;

impl RotationScheme for RandomOrthogonal {
    fn kind(&self) -> RotationKind {
        RotationKind::Random
    }

    fn build_q(&self, d: usize, seed: u64) -> Mat {
        linalg::random_orthogonal(d, &mut Rng::new(seed))
    }
}

/// SmoothRot-style scale-then-rotate: Hadamard Q plus SmoothQuant
/// per-channel scales folded around it at weight prep.
pub struct ChannelScaledHadamard;

impl RotationScheme for ChannelScaledHadamard {
    fn kind(&self) -> RotationKind {
        RotationKind::ScaledHadamard
    }

    fn build_q(&self, d: usize, seed: u64) -> Mat {
        hadamard::randomized_hadamard(d, seed)
    }

    fn channel_scaled(&self) -> bool {
        true
    }
}

/// The scheme singleton for a kind.
pub fn scheme(kind: RotationKind) -> &'static dyn RotationScheme {
    match kind {
        RotationKind::Hadamard => &RandomizedHadamard,
        RotationKind::Random => &RandomOrthogonal,
        RotationKind::ScaledHadamard => &ChannelScaledHadamard,
    }
}

/// Relative Frobenius distance between two rotated weight maps — the
/// reduction `rotation_mismatch` uses, exposed for any pair of maps.
pub fn map_mismatch(ours: &BTreeMap<String, Tensor>,
                    theirs: &BTreeMap<String, Tensor>) -> Result<f64> {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (k, t) in ours {
        let Some(want) = theirs.get(k) else {
            bail!("mismatch: peer map missing {k}");
        };
        let (got, want) = (t.as_f32(), want.as_f32());
        for (a, b) in got.iter().zip(&want) {
            num += ((a - b) as f64).powi(2);
            den += (*b as f64).powi(2);
        }
    }
    Ok((num / den.max(1e-12)).sqrt())
}

/// Verify shipped artifacts against this scheme's reconstruction:
/// re-rotate `base.*` with the scheme's Q and compare to the stored
/// rotated set.  Hadamard-family schemes reconstruct Q from
/// `meta.q_signs` (both use the same `rot.*` set — channel scales are a
/// runtime fold, not baked into the artifacts); the random-orthogonal
/// scheme reads its full Q back from the `meta.rnd_q` artifact (a QR-
/// orthogonalized Gaussian is not reconstructible from a seed across
/// languages) and checks the `rnd.*` set with it.
pub fn verify_mismatch(kind: RotationKind, cfg: &ModelConfig, w: &Weights)
                       -> Result<f64> {
    match kind {
        RotationKind::Hadamard | RotationKind::ScaledHadamard => {
            transform::rotation_mismatch(cfg, w)
        }
        RotationKind::Random => {
            let d = cfg.d_model;
            let q_t = w.get("meta.rnd_q").context(
                "rnd.* artifacts predate the exported random-orthogonal Q \
                 — re-run `make artifacts` to regenerate meta.rnd_q")?;
            if q_t.shape != [d, d] {
                bail!("meta.rnd_q shape {:?} != [{d}, {d}]", q_t.shape);
            }
            let q = Mat::from_vec(d, d, q_t.as_f32());
            let ours = transform::rotate(cfg, &w.with_prefix("base."), &q)?;
            let rnd = w.with_prefix("rnd.");
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            for (k, t) in &ours {
                let want = rnd.get(k.as_str())
                    .with_context(|| format!("missing tensor rnd.{k}"))?
                    .as_f32();
                for (a, b) in t.as_f32().iter().zip(&want) {
                    num += ((a - b) as f64).powi(2);
                    den += (*b as f64).powi(2);
                }
            }
            Ok((num / den.max(1e-12)).sqrt())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::transform::tests::{demo_cfg, demo_weights};

    fn max_abs_qqt_minus_i(q: &Mat) -> f32 {
        let d = q.rows;
        let p = q.matmul(&q.t());
        let mut worst = 0.0f32;
        for i in 0..d {
            for j in 0..d {
                let want = if i == j { 1.0 } else { 0.0 };
                worst = worst.max((p[(i, j)] - want).abs());
            }
        }
        worst
    }

    #[test]
    fn kind_roundtrip_and_parse_error() {
        for kind in RotationKind::ALL {
            assert_eq!(RotationKind::parse(kind.as_str()).unwrap(), kind);
            assert_eq!(scheme(kind).kind(), kind);
        }
        let err = RotationKind::parse("spin").unwrap_err().to_string();
        assert!(err.contains("hadamard|random|scaled-hadamard"), "{err}");
        assert_eq!(RotationKind::default(), RotationKind::Hadamard);
    }

    /// ISSUE property: every scheme's Q satisfies ‖QQᵀ − I‖∞ < 1e-4,
    /// including on a Kronecker (non-pow-2) dimension.
    #[test]
    fn every_scheme_q_is_orthogonal() {
        for kind in RotationKind::ALL {
            for d in [8usize, 16, 24] {
                let q = scheme(kind).build_q(d, 11);
                assert_eq!((q.rows, q.cols), (d, d));
                let worst = max_abs_qqt_minus_i(&q);
                assert!(worst < 1e-4,
                        "{kind} d={d}: ‖QQᵀ−I‖∞ = {worst}");
            }
        }
    }

    #[test]
    fn build_q_is_deterministic_and_seed_sensitive() {
        for kind in RotationKind::ALL {
            let s = scheme(kind);
            let (a, b) = (s.build_q(16, 5), s.build_q(16, 5));
            assert_eq!(a.data, b.data, "{kind}: same seed must reproduce Q");
            let c = s.build_q(16, 6);
            assert!(a.data != c.data, "{kind}: seed must matter");
        }
    }

    /// ISSUE property: re-rotating a base checkpoint with the scheme's
    /// deterministically rebuilt Q matches the first rotation at fp-noise
    /// level, and a drifted Q is actually detected — the contract the
    /// `verify` command's `rotation_mismatch` check stands on.
    #[test]
    fn reconstruction_mismatch_is_fp_noise_for_every_scheme() {
        let cfg = demo_cfg();
        let mut rng = Rng::new(0);
        let base = demo_weights(&cfg, &mut rng);
        let base_ref: BTreeMap<String, &Tensor> =
            base.iter().map(|(k, v)| (k.clone(), v)).collect();
        for kind in RotationKind::ALL {
            let s = scheme(kind);
            let rot = s.rotate(&cfg, &base_ref, 7).unwrap();
            let again = transform::rotate(&cfg, &base_ref,
                                          &s.build_q(cfg.d_model, 7)).unwrap();
            let mm = map_mismatch(&rot, &again).unwrap();
            assert!(mm < 1e-6, "{kind}: reconstruction mismatch {mm}");
            let drifted = transform::rotate(&cfg, &base_ref,
                                            &s.build_q(cfg.d_model, 8)).unwrap();
            let mm = map_mismatch(&rot, &drifted).unwrap();
            assert!(mm > 1e-2, "{kind}: drifted Q must be detected, got {mm}");
        }
    }

    /// Satellite property: `verify --rotation random` checks the `rnd.*`
    /// set against the Q stored in `meta.rnd_q` — matching at fp-noise
    /// level with the right Q, erroring (not silently passing) when the
    /// artifact is missing, and catching a drifted Q.
    #[test]
    fn random_verify_reads_q_from_the_artifact() {
        let cfg = demo_cfg();
        let mut rng = Rng::new(3);
        let base = demo_weights(&cfg, &mut rng);
        let base_ref: BTreeMap<String, &Tensor> =
            base.iter().map(|(k, v)| (k.clone(), v)).collect();
        let q = scheme(RotationKind::Random).build_q(cfg.d_model, 23);
        let rnd = transform::rotate(&cfg, &base_ref, &q).unwrap();
        let mut w = Weights::default();
        for (k, v) in &base {
            w.tensors.insert(format!("base.{k}"), v.clone());
        }
        for (k, v) in rnd {
            w.tensors.insert(format!("rnd.{k}"), v);
        }
        let err = verify_mismatch(RotationKind::Random, &cfg, &w)
            .unwrap_err().to_string();
        assert!(err.contains("make artifacts"),
                "missing Q must point at regeneration, got: {err}");
        let dq = |q: &Mat| Tensor::from_f32(vec![cfg.d_model, cfg.d_model],
                                            &q.data);
        w.tensors.insert("meta.rnd_q".into(), dq(&q));
        let mm = verify_mismatch(RotationKind::Random, &cfg, &w).unwrap();
        assert!(mm < 1e-6, "stored-Q reconstruction mismatch {mm}");
        let drifted = scheme(RotationKind::Random).build_q(cfg.d_model, 24);
        w.tensors.insert("meta.rnd_q".into(), dq(&drifted));
        let mm = verify_mismatch(RotationKind::Random, &cfg, &w).unwrap();
        assert!(mm > 1e-2, "drifted Q must be detected, got {mm}");
    }

    #[test]
    fn online_headdim_matches_dense_hadamard() {
        let dh = 8usize;
        let h = hadamard::hadamard_matrix(dh);
        let mut rng = Rng::new(9);
        let x = rng.normal_vec(2 * dh);
        for kind in RotationKind::ALL {
            let mut got = x.clone();
            scheme(kind).online_headdim(&mut got, dh);
            for (head, got_head) in x.chunks_exact(dh)
                .zip(got.chunks_exact(dh))
            {
                for j in 0..dh {
                    let want: f32 =
                        (0..dh).map(|i| head[i] * h[(i, j)]).sum();
                    assert!((want - got_head[j]).abs() < 1e-4);
                }
            }
        }
    }

    #[test]
    fn spec_mapping_and_verify_gates() {
        let mut spec = QuantSpec::quarot(4);
        RotationKind::Hadamard.apply_to_spec(&mut spec).unwrap();
        assert_eq!(spec.variant, Variant::Quarot);
        assert!(!spec.smooth);
        RotationKind::Random.apply_to_spec(&mut spec).unwrap();
        assert_eq!(spec.variant, Variant::QuarotRandom);
        let mut spec = QuantSpec::quarot(4);
        RotationKind::ScaledHadamard.apply_to_spec(&mut spec).unwrap();
        assert_eq!(spec.variant, Variant::Quarot);
        assert!(spec.smooth, "scaled-hadamard folds SmoothQuant scales");
        let mut fp = QuantSpec::fp16_baseline();
        for kind in RotationKind::ALL {
            assert!(kind.apply_to_spec(&mut fp).is_err(),
                    "{kind}: baseline has no rotation");
        }
    }
}
