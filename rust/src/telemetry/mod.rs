//! Request-lifecycle tracing and latency histograms for the serving
//! stack — the measurement substrate behind every "p99 TTFT" claim.
//!
//! Three building blocks, all std-only and allocation-free on the hot
//! path:
//!
//! * [`Clock`] — injectable monotonic time source.  The engine and the
//!   cluster take an `Arc<dyn Clock>` so latency/deadline tests run
//!   against a [`ManualClock`] deterministically instead of sleeping;
//!   production uses [`MonotonicClock`] (an `Instant` origin).
//! * [`Histogram`] — fixed 128-bucket log-scale (HDR-style) latency
//!   histogram: O(1) record, mergeable across shards (merge = add the
//!   bucket counts, so cluster aggregates are computed over the *union*
//!   of samples, never by averaging per-shard averages), quantiles with
//!   a bounded ~19 % relative bucket error.  TTFT, inter-token latency,
//!   queue wait and tick duration all flow through it, surfaced as
//!   p50/p90/p99/p99.9 on the wire `stats`/`metrics` frames.
//! * [`Span`] / [`SpanRecorder`] — a fixed-capacity ring of lifecycle
//!   spans (submit → queued → admitted → prefill → per-token decode →
//!   finish, plus per-tick engine phases).  The recorder is owned by
//!   the engine's tick thread — recording is a plain ring store with no
//!   locks or allocation — and is drained through the shard's existing
//!   control mailbox, so no reader ever blocks the tick.  Drained spans
//!   export as Chrome-trace / Perfetto JSON ([`chrome_trace_json`],
//!   the wire `{"cmd":"trace"}` command, `quarot trace --out f.json`).
//!
//! [`Timed`] wraps any [`crate::backend::ComputeBackend`] with per-op
//! call/time counters (lock-free atomics) for op-level attribution in
//! benches and tests.

pub mod clock;
pub mod histogram;
pub mod span;
pub mod timed;

pub use clock::{Clock, ManualClock, MonotonicClock};
pub use histogram::Histogram;
pub use span::{chrome_trace_events, chrome_trace_json, Span, SpanRecorder};
pub use timed::{OpTiming, Timed};
