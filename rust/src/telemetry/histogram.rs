//! Fixed-size log-bucketed latency histogram (HDR-style).
//!
//! 128 buckets, 4 per octave, starting at 1 µs: bucket `i` covers
//! `[2^(i/4), 2^((i+1)/4))` µs, so the range spans 1 µs … ~4.3 × 10⁶ ms
//! with a bounded `2^(1/4) − 1 ≈ 19 %` relative quantization error —
//! plenty for latency percentiles, tiny enough (~1 KiB) to embed one
//! per metric per shard and ship in snapshots.
//!
//! The histogram is *mergeable*: [`Histogram::merge`] adds bucket
//! counts, so a cluster aggregate is the histogram of the union of all
//! shards' samples.  That is the fix for average-of-averages bias —
//! a shard serving 9× the traffic weighs 9× in the merged quantile,
//! exactly as it should.

/// Number of log buckets (4 per octave over ~32 octaves).
const BUCKETS: usize = 128;

/// Smallest resolvable value: 1 µs expressed in ms.
const MIN_MS: f64 = 1e-3;

/// Buckets per octave (factor-of-2 range).
const PER_OCTAVE: f64 = 4.0;

/// Log-bucketed latency histogram over milliseconds.
///
/// O(1) record, O(buckets) quantile, mergeable across shards; `sum` and
/// `count` are kept exactly so [`Histogram::mean_ms`] has no bucket
/// error (only quantiles are quantized).
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: [u64; BUCKETS],
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

// [u64; 128] has no derived Default (std arrays stop at 32); spell the
// empty histogram out by hand.
impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            counts: [0; BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: 0.0,
        }
    }
}

fn bucket_of(ms: f64) -> usize {
    if ms <= MIN_MS {
        return 0;
    }
    let b = ((ms / MIN_MS).log2() * PER_OCTAVE) as usize;
    b.min(BUCKETS - 1)
}

/// Geometric midpoint of a bucket — the value a quantile in that bucket
/// reports.  Strictly increasing in `i`, which is what keeps quantiles
/// monotone in `q`.
fn representative(i: usize) -> f64 {
    MIN_MS * ((i as f64 + 0.5) / PER_OCTAVE).exp2()
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Record one sample in milliseconds.  Non-finite samples are
    /// dropped; negatives clamp to zero (a clock can never run
    /// backwards through `telemetry::Clock`, but a subtraction upstream
    /// might round below zero).
    pub fn record(&mut self, ms: f64) {
        if !ms.is_finite() {
            return;
        }
        let ms = ms.max(0.0);
        self.counts[bucket_of(ms)] += 1;
        self.count += 1;
        self.sum += ms;
        self.min = self.min.min(ms);
        self.max = self.max.max(ms);
    }

    /// Fold another histogram in (bucket-count addition).  After the
    /// merge, `self` is the histogram of the concatenation of both
    /// sample sets.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact sum of all samples in ms.
    pub fn sum_ms(&self) -> f64 {
        self.sum
    }

    /// Exact mean in ms (0.0 when empty) — `sum/count`, no bucket error.
    pub fn mean_ms(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.sum / self.count as f64 }
    }

    /// Smallest recorded sample (0.0 when empty).
    pub fn min_ms(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.min }
    }

    /// Largest recorded sample (0.0 when empty).
    pub fn max_ms(&self) -> f64 {
        self.max
    }

    /// Nearest-rank quantile, `q` in `[0, 1]`; 0.0 when empty.
    ///
    /// The reported value is the containing bucket's geometric midpoint
    /// clamped into `[min, max]`, so single-bucket histograms answer
    /// exactly and `quantile(a) <= quantile(b)` whenever `a <= b`.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64)
            .clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return representative(i).clamp(self.min, self.max);
            }
        }
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_answers_zero_everywhere() {
        let h = Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean_ms(), 0.0);
        assert_eq!(h.min_ms(), 0.0);
        assert_eq!(h.max_ms(), 0.0);
        for q in [0.0, 0.5, 0.9, 0.99, 0.999, 1.0] {
            assert_eq!(h.quantile(q), 0.0);
        }
    }

    #[test]
    fn single_value_histogram_is_exact() {
        let mut h = Histogram::new();
        for _ in 0..10 {
            h.record(7.0);
        }
        // clamping into [min, max] makes a one-value histogram exact
        assert_eq!(h.quantile(0.5), 7.0);
        assert_eq!(h.quantile(0.999), 7.0);
        assert_eq!(h.mean_ms(), 7.0);
        assert_eq!(h.min_ms(), 7.0);
        assert_eq!(h.max_ms(), 7.0);
    }

    #[test]
    fn quantiles_are_monotone_and_bucket_accurate() {
        let mut h = Histogram::new();
        // log-spaced samples over 5 decades
        for i in 0..1000 {
            h.record(1e-2 * 1.02f64.powi(i % 500));
        }
        let qs = [0.0, 0.1, 0.5, 0.9, 0.99, 0.999, 1.0];
        let vs: Vec<f64> = qs.iter().map(|&q| h.quantile(q)).collect();
        for w in vs.windows(2) {
            assert!(w[0] <= w[1], "quantiles must be monotone: {vs:?}");
        }
        assert!(vs[0] >= h.min_ms() && *vs.last().unwrap() <= h.max_ms());
        // bucket error bound: a known p50 over uniform ranks
        let mut h = Histogram::new();
        for i in 1..=1001u32 {
            h.record(i as f64 * 0.1); // 0.1 .. 100.1 ms, median 50.1
        }
        let p50 = h.quantile(0.5);
        assert!((p50 / 50.1 - 1.0).abs() < 0.2,
                "p50 {p50} strayed past the 19% bucket bound");
    }

    #[test]
    fn merge_equals_concatenation() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut both = Histogram::new();
        for i in 0..200 {
            let v = 0.05 * (i as f64 + 1.0);
            if i % 3 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            both.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        assert_eq!(a.sum_ms(), both.sum_ms());
        assert_eq!(a.min_ms(), both.min_ms());
        assert_eq!(a.max_ms(), both.max_ms());
        for q in [0.1, 0.5, 0.9, 0.99, 0.999] {
            assert_eq!(a.quantile(q), both.quantile(q),
                       "merged quantile must equal the union's at q={q}");
        }
    }

    #[test]
    fn garbage_samples_are_dropped_or_clamped() {
        let mut h = Histogram::new();
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        assert!(h.is_empty(), "non-finite samples must be dropped");
        h.record(-3.0);
        assert_eq!(h.count(), 1);
        assert_eq!(h.min_ms(), 0.0, "negatives clamp to zero");
        // extreme-but-finite values land in the terminal buckets
        h.record(1e12);
        h.record(1e-12);
        assert_eq!(h.count(), 3);
        assert!(h.quantile(1.0) <= h.max_ms());
    }
}
