//! Injectable monotonic time source.
//!
//! Everything in the serving stack that timestamps a request (TTFT,
//! queue wait, deadlines, span start/duration) reads time through a
//! [`Clock`] instead of calling `Instant::now()` directly.  Production
//! injects [`MonotonicClock`]; deterministic tests inject a
//! [`ManualClock`] and advance it explicitly — a deadline test asserts
//! "expired after `advance_ms(50)`" instead of sleeping and hoping the
//! scheduler cooperates.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Monotonic milliseconds-since-origin time source.
///
/// Implementations must be monotone non-decreasing; the absolute origin
/// is arbitrary (only differences are meaningful).  `Send + Sync` so one
/// clock can be shared by the batcher, the cluster shards and tests via
/// `Arc<dyn Clock>`.
pub trait Clock: Send + Sync {
    /// Milliseconds since this clock's origin.
    fn now_ms(&self) -> f64;
}

/// Wall-clock [`Clock`] over a fixed `Instant` origin — the production
/// default.
#[derive(Debug)]
pub struct MonotonicClock {
    origin: Instant,
}

impl MonotonicClock {
    /// A clock whose origin is "now".
    pub fn new() -> MonotonicClock {
        MonotonicClock { origin: Instant::now() }
    }
}

impl Default for MonotonicClock {
    fn default() -> MonotonicClock {
        MonotonicClock::new()
    }
}

impl Clock for MonotonicClock {
    fn now_ms(&self) -> f64 {
        self.origin.elapsed().as_secs_f64() * 1e3
    }
}

/// Hand-advanced [`Clock`] for deterministic latency/deadline tests.
///
/// Time only moves when a test calls [`ManualClock::advance_ms`] (or
/// [`ManualClock::set_ms`]), stored as integer microseconds in an atomic
/// so shared `Arc<ManualClock>` handles stay `Sync` without a lock.
#[derive(Debug, Default)]
pub struct ManualClock {
    micros: AtomicU64,
}

impl ManualClock {
    /// A manual clock starting at 0 ms.
    pub fn new() -> ManualClock {
        ManualClock { micros: AtomicU64::new(0) }
    }

    /// Advance the clock by `ms` (saturating; negative/NaN ignored).
    pub fn advance_ms(&self, ms: f64) {
        if ms.is_finite() && ms > 0.0 {
            self.micros.fetch_add((ms * 1e3) as u64, Ordering::SeqCst);
        }
    }

    /// Jump the clock to an absolute `ms` reading (monotone use is the
    /// caller's responsibility).
    pub fn set_ms(&self, ms: f64) {
        let us = if ms.is_finite() && ms > 0.0 { (ms * 1e3) as u64 } else { 0 };
        self.micros.store(us, Ordering::SeqCst);
    }
}

impl Clock for ManualClock {
    fn now_ms(&self) -> f64 {
        self.micros.load(Ordering::SeqCst) as f64 / 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_clock_is_deterministic() {
        let c = ManualClock::new();
        assert_eq!(c.now_ms(), 0.0);
        c.advance_ms(12.5);
        assert_eq!(c.now_ms(), 12.5);
        c.advance_ms(0.25);
        assert_eq!(c.now_ms(), 12.75);
        // garbage advances are ignored, not panics
        c.advance_ms(-5.0);
        c.advance_ms(f64::NAN);
        assert_eq!(c.now_ms(), 12.75);
        c.set_ms(1000.0);
        assert_eq!(c.now_ms(), 1000.0);
    }

    #[test]
    fn monotonic_clock_is_nondecreasing() {
        let c = MonotonicClock::new();
        let a = c.now_ms();
        let b = c.now_ms();
        assert!(b >= a && a >= 0.0);
    }
}
