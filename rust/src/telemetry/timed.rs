//! Op-level backend timing: [`Timed`] wraps any
//! [`ComputeBackend`] and counts calls + wall time per op with
//! lock-free atomics, so benches and tests can attribute a slow tick to
//! the kernel that spent it (staging dequant vs decode attention vs
//! sampling GEMMs) without touching the backends themselves.
//!
//! The counters are `AtomicU64` (call count, total nanoseconds), safe
//! under the `Threaded` pool's concurrent op calls; `snapshot()` reads
//! them without stopping the world.  Timing uses `Instant` directly —
//! op durations are real kernel wall time, not the engine's injectable
//! [`super::Clock`] timeline (which exists for *deterministic* request
//! timestamps, the opposite of what a kernel profile wants).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::attention::{DecodeF32Seq, DecodeQuantSeq};
use crate::backend::ComputeBackend;
use crate::gemm::{WeightsF32, WeightsI4, WeightsI8};

/// Stable op names, index-aligned with the internal counter array.
const OP_NAMES: [&str; N_OPS] = [
    "gemm_f32", "gemm_i8", "gemm_i4", "had_rows", "quant_rows",
    "kv_quant_slab", "kv_dequant", "decode_f32_batch", "decode_quant_batch",
    "nll_rows", "par_for",
];

const N_OPS: usize = 11;

const GEMM_F32: usize = 0;
const GEMM_I8: usize = 1;
const GEMM_I4: usize = 2;
const HAD_ROWS: usize = 3;
const QUANT_ROWS: usize = 4;
const KV_QUANT_SLAB: usize = 5;
const KV_DEQUANT: usize = 6;
const DECODE_F32: usize = 7;
const DECODE_QUANT: usize = 8;
const NLL_ROWS: usize = 9;
const PAR_FOR: usize = 10;

#[derive(Default)]
struct OpCounter {
    calls: AtomicU64,
    nanos: AtomicU64,
}

/// One op's accumulated timing, as read by [`Timed::snapshot`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OpTiming {
    /// Backend op name (`"gemm_i4"`, `"decode_quant_batch"`, …).
    pub op: &'static str,
    /// Calls observed since construction.
    pub calls: u64,
    /// Total wall time spent inside the op, ms.
    pub total_ms: f64,
}

/// A [`ComputeBackend`] decorator adding per-op call/time counters.
/// Delegates every op to the inner backend bit-for-bit; the only cost
/// is two `Instant` reads and two relaxed atomic adds per call.
pub struct Timed<B> {
    inner: B,
    ops: [OpCounter; N_OPS],
}

impl<B> Timed<B> {
    /// Wrap `inner` with zeroed counters.
    pub fn new(inner: B) -> Timed<B> {
        Timed { inner, ops: Default::default() }
    }

    /// The wrapped backend.
    pub fn inner(&self) -> &B {
        &self.inner
    }

    /// Per-op timings in stable op order (every op listed, including
    /// never-called ones at zero).
    pub fn snapshot(&self) -> Vec<OpTiming> {
        self.ops.iter().zip(OP_NAMES.iter())
            .map(|(c, &op)| OpTiming {
                op,
                calls: c.calls.load(Ordering::Relaxed),
                total_ms: c.nanos.load(Ordering::Relaxed) as f64 / 1e6,
            })
            .collect()
    }

    /// Total calls across every op.
    pub fn total_calls(&self) -> u64 {
        self.ops.iter().map(|c| c.calls.load(Ordering::Relaxed)).sum()
    }

    fn timed<T>(&self, op: usize, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        let c = &self.ops[op];
        c.calls.fetch_add(1, Ordering::Relaxed);
        c.nanos.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        out
    }
}

impl<B: ComputeBackend> ComputeBackend for Timed<B> {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn gemm_f32(&self, x: &[f32], t: usize, w: &WeightsF32, y: &mut [f32]) {
        self.timed(GEMM_F32, || self.inner.gemm_f32(x, t, w, y))
    }

    fn gemm_i8(&self, x: &[f32], t: usize, w: &WeightsI8, bits: u32, clip: f32,
               y: &mut [f32]) {
        self.timed(GEMM_I8, || self.inner.gemm_i8(x, t, w, bits, clip, y))
    }

    fn gemm_i4(&self, x: &[f32], t: usize, w: &WeightsI4, clip: f32,
               y: &mut [f32]) {
        self.timed(GEMM_I4, || self.inner.gemm_i4(x, t, w, clip, y))
    }

    fn had_rows(&self, x: &mut [f32], d: usize) {
        self.timed(HAD_ROWS, || self.inner.had_rows(x, d))
    }

    fn quant_rows(&self, x: &[f32], d: usize, bits: u32, clip: f32,
                  codes: &mut [i8], scales: &mut [f32]) {
        self.timed(QUANT_ROWS,
                   || self.inner.quant_rows(x, d, bits, clip, codes, scales))
    }

    fn kv_quant_slab(&self, x: &[f32], d: usize, group: usize, bits: u32,
                     clip: f32) -> (Vec<i8>, Vec<f32>, Vec<f32>) {
        self.timed(KV_QUANT_SLAB,
                   || self.inner.kv_quant_slab(x, d, group, bits, clip))
    }

    fn kv_dequant(&self, codes: &[i8], scales: &[f32], zeros: &[f32],
                  group: usize, out: &mut [f32]) {
        self.timed(KV_DEQUANT,
                   || self.inner.kv_dequant(codes, scales, zeros, group, out))
    }

    fn decode_f32_batch(&self, seqs: &[DecodeF32Seq<'_>], n_heads: usize,
                        out: &mut [f32]) {
        self.timed(DECODE_F32,
                   || self.inner.decode_f32_batch(seqs, n_heads, out))
    }

    fn decode_quant_batch(&self, seqs: &[DecodeQuantSeq<'_>], n_heads: usize,
                          out: &mut [f32]) {
        self.timed(DECODE_QUANT,
                   || self.inner.decode_quant_batch(seqs, n_heads, out))
    }

    fn nll_rows(&self, logits: &[f32], vocab: usize, targets: &[u16],
                out: &mut [f64]) {
        self.timed(NLL_ROWS, || self.inner.nll_rows(logits, vocab, targets, out))
    }

    fn par_for(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        self.timed(PAR_FOR, || self.inner.par_for(n, f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::ScalarRef;

    #[test]
    fn timed_backend_counts_calls_and_stays_bit_exact() {
        let timed = Timed::new(ScalarRef);
        let base = ScalarRef;
        assert_eq!(timed.total_calls(), 0);

        // had_rows: d=4 WHT on two rows, vs the bare backend
        let mut a = vec![1.0f32, 2.0, 3.0, 4.0, -1.0, 0.5, 2.0, -2.0];
        let mut b = a.clone();
        timed.had_rows(&mut a, 4);
        base.had_rows(&mut b, 4);
        assert_eq!(a, b, "Timed must delegate bit-for-bit");

        // nll_rows
        let logits = vec![0.1f32, 0.7, 0.2, 0.9, 0.1, 0.0];
        let mut out = vec![0.0f64; 2];
        timed.nll_rows(&logits, 3, &[1, 0], &mut out);
        assert!(out.iter().all(|v| v.is_finite()));

        // par_for is counted once however many tasks it fans out
        timed.par_for(8, &|_| {});

        let snap = timed.snapshot();
        assert_eq!(snap.len(), OP_NAMES.len());
        let get = |op: &str| snap.iter().find(|t| t.op == op)
            .map(|t| t.calls).unwrap_or(0);
        assert_eq!(get("had_rows"), 1);
        assert_eq!(get("nll_rows"), 1);
        assert_eq!(get("par_for"), 1);
        assert_eq!(get("gemm_f32"), 0);
        assert_eq!(timed.total_calls(), 3);
        assert!(snap.iter().all(|t| t.total_ms >= 0.0));
    }
}
