//! Lifecycle spans and the fixed-capacity ring recorder.
//!
//! A [`Span`] is a closed interval on the engine's [`super::Clock`]
//! timeline: a request-lifecycle step (`queued`, `admitted`, `prefill`,
//! `decode_token`, `finish:<reason>`) tagged with its request id, or a
//! per-tick engine phase (`tick.admit`, `tick.decode`, `tick.sample`,
//! `tick.append`, `session.donate`) tagged track 0.  Spans are `Copy`
//! and carry at most two fixed key/value args — recording never
//! allocates.
//!
//! The [`SpanRecorder`] is a plain preallocated ring owned by the
//! engine: exactly one writer (the tick thread), no locks, no atomics.
//! Readers never touch it directly — a drain request rides the shard's
//! control mailbox and the tick thread answers with
//! [`SpanRecorder::drain`] between ticks, so tracing can never block
//! the hot path.  When the ring is full the *oldest* spans are
//! overwritten (a trace buffer wants the most recent window) and
//! [`SpanRecorder::dropped`] counts the overwrites.

use crate::util::json::{self, n, obj, Value};

/// Maximum fixed args per span (keyed slots; an empty-string key means
/// the slot is unused).
pub const MAX_SPAN_ARGS: usize = 2;

/// One recorded interval on the engine timeline.  `track` is the
/// request id, or 0 for engine-phase spans.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Span {
    /// Static span name (e.g. `"queued"`, `"decode_token"`,
    /// `"tick.decode"`).
    pub name: &'static str,
    /// Request id, or 0 for per-tick engine phases.
    pub track: u64,
    /// Start, in the recording engine's [`super::Clock`] ms timeline.
    pub start_ms: f64,
    /// Duration in ms (0 for instant markers).
    pub dur_ms: f64,
    /// Up to [`MAX_SPAN_ARGS`] numeric args; `""` keys are unused slots.
    pub args: [(&'static str, f64); MAX_SPAN_ARGS],
}

impl Span {
    /// A span with no args.
    pub fn new(name: &'static str, track: u64, start_ms: f64, dur_ms: f64)
               -> Span {
        Span { name, track, start_ms, dur_ms, args: [("", 0.0); MAX_SPAN_ARGS] }
    }

    /// Attach a numeric arg (first free slot; silently dropped once all
    /// [`MAX_SPAN_ARGS`] slots are taken — spans are fixed-size by
    /// design).
    pub fn arg(mut self, key: &'static str, v: f64) -> Span {
        for slot in self.args.iter_mut() {
            if slot.0.is_empty() {
                *slot = (key, v);
                break;
            }
        }
        self
    }
}

/// Fixed-capacity single-writer ring of [`Span`]s (see module docs for
/// the threading contract).  Capacity 0 disables recording entirely —
/// every `record` is a cheap early-out.
#[derive(Debug)]
pub struct SpanRecorder {
    buf: Vec<Span>,
    /// Next write position when the ring has wrapped.
    head: usize,
    wrapped: bool,
    dropped: u64,
    /// Keep 1-in-N `decode_token` spans (1 = all, 0 treated as 1).
    sample_every: u64,
    token_seq: u64,
}

impl SpanRecorder {
    /// A recorder holding at most `capacity` spans (preallocated;
    /// 0 disables recording).
    pub fn new(capacity: usize) -> SpanRecorder {
        SpanRecorder {
            buf: Vec::with_capacity(capacity),
            head: 0,
            wrapped: false,
            dropped: 0,
            sample_every: 1,
            token_seq: 0,
        }
    }

    /// Whether spans are being recorded at all (capacity > 0).
    pub fn enabled(&self) -> bool {
        self.buf.capacity() > 0
    }

    /// Down-sample per-token spans to 1-in-`n` (`record_sampled`); 0 and
    /// 1 both mean "keep every span".
    pub fn set_sample_every(&mut self, n: u64) {
        self.sample_every = n.max(1);
    }

    /// Record a span unconditionally (subject to capacity).
    pub fn record(&mut self, span: Span) {
        if self.buf.capacity() == 0 {
            return;
        }
        if self.buf.len() < self.buf.capacity() {
            self.buf.push(span);
            return;
        }
        // full: overwrite the oldest entry
        self.buf[self.head] = span;
        self.head = (self.head + 1) % self.buf.len();
        self.wrapped = true;
        self.dropped += 1;
    }

    /// Record a high-frequency span (per-token decode) through the
    /// sampling rate: only every `sample_every`-th call lands.
    pub fn record_sampled(&mut self, span: Span) {
        if self.buf.capacity() == 0 {
            return;
        }
        self.token_seq += 1;
        if self.token_seq % self.sample_every == 0 {
            self.record(span);
        }
    }

    /// Spans currently buffered.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when no spans are buffered.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Spans overwritten because the ring was full (monotone counter,
    /// not reset by drains).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Take every buffered span in record order, emptying the ring.
    /// Called by the owning tick thread between ticks.
    pub fn drain(&mut self) -> Vec<Span> {
        let head = std::mem::take(&mut self.head);
        let wrapped = std::mem::take(&mut self.wrapped);
        let mut out = std::mem::take(&mut self.buf);
        // keep the allocation contract: the fresh buf must preserve the
        // recorder's capacity (capacity 0 stays disabled)
        self.buf = Vec::with_capacity(out.capacity());
        if wrapped {
            out.rotate_left(head);
        }
        out
    }
}

/// Chrome-trace (`chrome://tracing` / Perfetto) complete-event objects
/// for `spans`, one `"ph":"X"` event each.  `pid` is the shard index;
/// the request id (or 0 for engine phases) becomes the `tid` so every
/// request renders as its own row.  Times convert ms → µs as the format
/// requires.
pub fn chrome_trace_events(spans: &[Span], pid: u64) -> Vec<Value> {
    spans.iter()
        .map(|s| {
            let mut pairs = vec![
                ("name", json::s(s.name)),
                ("ph", json::s("X")),
                ("ts", n(s.start_ms * 1e3)),
                ("dur", n(s.dur_ms * 1e3)),
                ("pid", n(pid as f64)),
                ("tid", n(s.track as f64)),
            ];
            let args: Vec<(&str, Value)> = s.args.iter()
                .filter(|(k, _)| !k.is_empty())
                .map(|&(k, v)| (k, n(v)))
                .collect();
            if !args.is_empty() {
                pairs.push(("args", obj(args)));
            }
            obj(pairs)
        })
        .collect()
}

/// A complete Chrome-trace JSON document (`{"traceEvents":[...]}`) —
/// what `quarot trace --out trace.json` writes and Perfetto opens
/// directly.
pub fn chrome_trace_json(spans: &[Span], pid: u64) -> Value {
    obj(vec![("traceEvents", Value::Arr(chrome_trace_events(spans, pid)))])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sp(i: u64) -> Span {
        Span::new("s", i, i as f64, 1.0)
    }

    #[test]
    fn ring_preserves_order_and_drops_oldest() {
        let mut r = SpanRecorder::new(4);
        assert!(r.enabled() && r.is_empty());
        for i in 0..3 {
            r.record(sp(i));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped(), 0);
        let spans = r.drain();
        assert_eq!(spans.iter().map(|s| s.track).collect::<Vec<_>>(),
                   vec![0, 1, 2]);
        assert!(r.is_empty());

        // wrap: capacity 4, record 6 → oldest two overwritten
        for i in 0..6 {
            r.record(sp(i));
        }
        assert_eq!(r.dropped(), 2);
        let spans = r.drain();
        assert_eq!(spans.iter().map(|s| s.track).collect::<Vec<_>>(),
                   vec![2, 3, 4, 5],
                   "drain must return the newest window in record order");
        // the recorder keeps working after a post-wrap drain
        r.record(sp(9));
        assert_eq!(r.drain().len(), 1);
    }

    #[test]
    fn zero_capacity_disables_recording() {
        let mut r = SpanRecorder::new(0);
        assert!(!r.enabled());
        r.record(sp(1));
        r.record_sampled(sp(2));
        assert!(r.is_empty());
        assert_eq!(r.dropped(), 0);
        assert!(r.drain().is_empty());
        // a drain must not accidentally enable a disabled recorder
        r.record(sp(3));
        assert!(r.is_empty());
    }

    #[test]
    fn sampling_keeps_one_in_n() {
        let mut r = SpanRecorder::new(64);
        r.set_sample_every(4);
        for i in 0..16 {
            r.record_sampled(sp(i));
        }
        assert_eq!(r.len(), 4, "1-in-4 sampling must keep 4 of 16");
        // unsampled records are unaffected
        r.record(sp(99));
        assert_eq!(r.len(), 5);
    }

    #[test]
    fn span_args_fill_fixed_slots() {
        let s = Span::new("admitted", 7, 1.0, 2.0)
            .arg("graft_tokens", 32.0)
            .arg("prompt_len", 40.0)
            .arg("overflow", 1.0); // silently dropped: slots are fixed
        assert_eq!(s.args[0], ("graft_tokens", 32.0));
        assert_eq!(s.args[1], ("prompt_len", 40.0));
    }

    #[test]
    fn chrome_trace_shapes_complete_events() {
        let spans = [
            Span::new("queued", 7, 1.5, 0.5).arg("queue_depth", 3.0),
            Span::new("tick.decode", 0, 2.0, 4.0),
        ];
        let doc = chrome_trace_json(&spans, 1);
        let events = doc.get("traceEvents").and_then(|v| v.as_arr())
            .expect("traceEvents array");
        assert_eq!(events.len(), 2);
        let e0 = &events[0];
        assert_eq!(e0.get("name").and_then(|v| v.as_str()), Some("queued"));
        assert_eq!(e0.get("ph").and_then(|v| v.as_str()), Some("X"));
        assert_eq!(e0.get("ts").and_then(|v| v.as_f64()), Some(1500.0));
        assert_eq!(e0.get("dur").and_then(|v| v.as_f64()), Some(500.0));
        assert_eq!(e0.get("pid").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(e0.get("tid").and_then(|v| v.as_f64()), Some(7.0));
        assert_eq!(e0.get("args").and_then(|a| a.get("queue_depth"))
                       .and_then(|v| v.as_f64()),
                   Some(3.0));
        // arg-less spans omit the args object entirely
        assert!(events[1].get("args").is_none());
        // the document round-trips through the json writer/parser
        let txt = json::write(&doc);
        let back = json::parse(&txt).expect("valid JSON");
        assert_eq!(back.get("traceEvents").and_then(|v| v.as_arr())
                       .map(|a| a.len()),
                   Some(2));
    }
}
