//! Model configuration, parsed from the artifact manifest's `model` object.

use crate::util::json::Value;

#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub cache_seq: usize,
    pub decode_batch: usize,
    pub kv_group: usize,
    pub rope_theta: f64,
    pub train_ppl: f64,
}

impl ModelConfig {
    pub fn from_json(v: &Value) -> Option<ModelConfig> {
        let g = |k: &str| v.get(k)?.as_usize();
        Some(ModelConfig {
            name: v.get("name")?.as_str()?.to_string(),
            vocab: g("vocab")?,
            d_model: g("d_model")?,
            n_layers: g("n_layers")?,
            n_heads: g("n_heads")?,
            n_kv_heads: g("n_kv_heads")?,
            d_head: g("d_head")?,
            d_ff: g("d_ff")?,
            max_seq: g("max_seq")?,
            cache_seq: g("cache_seq")?,
            decode_batch: g("decode_batch")?,
            kv_group: g("kv_group")?,
            rope_theta: v.get("rope_theta")?.as_f64()?,
            train_ppl: v.get("train_ppl").and_then(|x| x.as_f64()).unwrap_or(0.0),
        })
    }

    pub fn d_attn(&self) -> usize {
        self.n_heads * self.d_head
    }

    pub fn d_kv(&self) -> usize {
        self.n_kv_heads * self.d_head
    }

    /// Bytes of one token's K+V at the given bit-width (+ group scales),
    /// the quantity behind the paper's Table 17.
    pub fn kv_token_bytes(&self, bits: u32) -> usize {
        let codes = 2 * self.n_layers * self.d_kv();
        let groups = 2 * self.n_layers * (self.d_kv() / self.kv_group);
        if bits == 16 {
            codes * 2 // fp16 baseline, no side tensors
        } else {
            (codes * bits as usize).div_ceil(8) + groups * 8 // scale+zero f32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn demo() -> ModelConfig {
        let src = r#"{"name":"t","vocab":512,"d_model":256,"n_layers":4,
            "n_heads":8,"n_kv_heads":2,"d_head":32,"d_ff":1024,"max_seq":128,
            "cache_seq":256,"decode_batch":8,"kv_group":32,"rope_theta":10000.0,
            "train_ppl":12.5}"#;
        ModelConfig::from_json(&json::parse(src).unwrap()).unwrap()
    }

    #[test]
    fn parses() {
        let c = demo();
        assert_eq!(c.d_attn(), 256);
        assert_eq!(c.d_kv(), 64);
        assert_eq!(c.n_kv_heads, 2);
        assert!((c.train_ppl - 12.5).abs() < 1e-9);
    }

    #[test]
    fn kv_byte_accounting() {
        let c = demo();
        // int4: codes = 2*4*64 = 512 codes → 256 bytes; groups = 2*4*2 = 16 → 128B
        assert_eq!(c.kv_token_bytes(4), 256 + 128);
        // fp16 baseline: 512 * 2
        assert_eq!(c.kv_token_bytes(16), 1024);
        // the ratio is what Table 17 reports
        let r = c.kv_token_bytes(16) as f64 / c.kv_token_bytes(4) as f64;
        assert!(r > 2.0 && r < 4.0);
    }
}
