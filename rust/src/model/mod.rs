//! Artifact containers + model metadata (shared formats with python/compile/io.py)
//! and the rust-side QuaRot weight transform.

pub mod config;
pub mod corpus;
pub mod transform;
pub mod weights;

pub use config::ModelConfig;
pub use weights::{Dtype, Tensor, Weights};
