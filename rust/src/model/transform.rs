//! Rust-side QuaRot Stage-1 weight transform — mirror of
//! python/compile/quarot.py, kept in lock-step by an integration test that
//! checks `rot.*` in weights.bin equals this transform applied to `base.*`
//! (the sign vector of the randomized Hadamard ships as `meta.q_signs`).
//!
//! Having the transform natively means the serving stack can rotate a raw
//! checkpoint without any python in the loop.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use super::config::ModelConfig;
use super::weights::{Tensor, Weights};
use crate::hadamard;
use crate::tensor::Mat;

/// Per-layer slice of a stacked (L, r, c) tensor as a Mat.
fn layer_mat(t: &Tensor, l: usize) -> Mat {
    assert_eq!(t.shape.len(), 3);
    let (rows, cols) = (t.shape[1], t.shape[2]);
    let data = t.as_f32();
    Mat::from_vec(rows, cols, data[l * rows * cols..(l + 1) * rows * cols].to_vec())
}

fn layer_vec(t: &Tensor, l: usize) -> Vec<f32> {
    assert_eq!(t.shape.len(), 2);
    let d = t.shape[1];
    t.as_f32()[l * d..(l + 1) * d].to_vec()
}

fn stack_mats(mats: &[Mat]) -> Tensor {
    let (r, c) = (mats[0].rows, mats[0].cols);
    let mut data = Vec::with_capacity(mats.len() * r * c);
    for m in mats {
        data.extend_from_slice(&m.data);
    }
    Tensor::from_f32(vec![mats.len(), r, c], &data)
}

fn stack_vecs(vecs: &[Vec<f32>]) -> Tensor {
    let d = vecs[0].len();
    let mut data = Vec::with_capacity(vecs.len() * d);
    for v in vecs {
        data.extend_from_slice(v);
    }
    Tensor::from_f32(vec![vecs.len(), d], &data)
}

/// The full Stage-1 transform (1a norm fusion + residual rotation Q,
/// 1b FFN Hadamard fusion, 1c value/out-projection head transforms).
/// `q` is the residual rotation (d_model × d_model orthogonal).
pub fn rotate(cfg: &ModelConfig, base: &BTreeMap<String, &Tensor>, q: &Mat)
              -> Result<BTreeMap<String, Tensor>> {
    let d = cfg.d_model;
    let (dh, nh, nkv) = (cfg.d_head, cfg.n_heads, cfg.n_kv_heads);
    let get = |k: &str| base.get(k).copied().with_context(|| format!("missing {k}"));

    let qt = q.t();
    let h_dh = hadamard::hadamard_matrix(dh);
    let h_ff = hadamard::hadamard_matrix(cfg.d_ff);

    let embed_t = get("embed")?;
    let lm_t = get("lm_head")?;
    let fnorm = get("final_norm")?.as_f32();

    let mut out: BTreeMap<String, Tensor> = BTreeMap::new();

    // embed ← embed @ Q
    let embed = Mat::from_vec(cfg.vocab, d, embed_t.as_f32()).matmul(q);
    out.insert("embed".into(), Tensor::from_f32(vec![cfg.vocab, d], &embed.data));

    // lm_head ← Qᵀ diag(final_norm) lm_head
    let mut lm = Mat::from_vec(d, cfg.vocab, lm_t.as_f32());
    lm.scale_rows(&fnorm);
    let lm = qt.matmul(&lm);
    out.insert("lm_head".into(), Tensor::from_f32(vec![d, cfg.vocab], &lm.data));
    out.insert("final_norm".into(), Tensor::from_f32(vec![d], &vec![1.0; d]));

    let (mut wqs, mut wks, mut wvs, mut wos) = (vec![], vec![], vec![], vec![]);
    let (mut wups, mut wgates, mut wdowns) = (vec![], vec![], vec![]);
    for l in 0..cfg.n_layers {
        let an = layer_vec(get("attn_norm")?, l);
        let fnv = layer_vec(get("ffn_norm")?, l);

        // input-side: W ← Qᵀ diag(norm) W
        let fuse_in = |w: Mat, norm: &[f32]| -> Mat {
            let mut w = w;
            w.scale_rows(norm);
            qt.matmul(&w)
        };
        let wq = fuse_in(layer_mat(get("wq")?, l), &an);
        let wk = fuse_in(layer_mat(get("wk")?, l), &an);
        let mut wv = fuse_in(layer_mat(get("wv")?, l), &an);
        let wup = fuse_in(layer_mat(get("wup")?, l), &fnv);
        let wgate = fuse_in(layer_mat(get("wgate")?, l), &fnv);

        // Stage 1c: W_v ← W_v (I ⊗ H_dh) per kv-head (output columns)
        for r in 0..wv.rows {
            hadamard::had_headdim(&mut wv.row_mut(r)[..nkv * dh], dh);
        }

        // W_o: output side gets Q, input side undoes (I⊗H_dh)(H_nh⊗I)
        let wo0 = layer_mat(get("wo")?, l).matmul(q);
        // input-side transform = apply the transform to each *column* of W_o,
        // i.e. to the rows of W_oᵀ: (H_nh⊗I)ᵀ(I⊗H_dh)ᵀ W_o
        let mut wot = wo0.t();
        for r in 0..wot.rows {
            let row = wot.row_mut(r);
            hadamard::had_headdim(row, dh); // (I⊗H_dh)ᵀ: H_dh symmetric? use explicit
            hadamard::had_heads(row, nh);
        }
        let wo = wot.t();
        let _ = &h_dh; // symmetry note: Sylvester H_dh/H_nh are symmetric, so
                       // applying the forward transforms on columns equals the
                       // transpose-side fusion. Kronecker (m>1) never appears
                       // in head dims (pow-2 enforced by configs).

        // W_down ← H_ffᵀ (W_down Q): apply H_ff to columns of (W_down Q)
        let wd0 = layer_mat(get("wdown")?, l).matmul(q);
        let mut wdt = wd0.t();
        for r in 0..wdt.rows {
            hadamard::wht(wdt.row_mut(r)); // rows of Wᵀ = columns of W
        }
        let wdown = wdt.t();
        let _ = &h_ff;

        wqs.push(wq);
        wks.push(wk);
        wvs.push(wv);
        wos.push(wo);
        wups.push(wup);
        wgates.push(wgate);
        wdowns.push(wdown);
    }

    let ones_ld = vec![vec![1.0f32; d]; cfg.n_layers];
    out.insert("attn_norm".into(), stack_vecs(&ones_ld));
    out.insert("ffn_norm".into(), stack_vecs(&ones_ld));
    out.insert("wq".into(), stack_mats(&wqs));
    out.insert("wk".into(), stack_mats(&wks));
    out.insert("wv".into(), stack_mats(&wvs));
    out.insert("wo".into(), stack_mats(&wos));
    out.insert("wup".into(), stack_mats(&wups));
    out.insert("wgate".into(), stack_mats(&wgates));
    out.insert("wdown".into(), stack_mats(&wdowns));
    Ok(out)
}

/// Build the residual rotation from the sign vector python stored in
/// weights.bin (`meta.q_signs`), so rust and python produce the same Q.
pub fn q_from_signs(d: usize, signs: &[f32]) -> Mat {
    let mut q = hadamard::hadamard_matrix(d);
    q.scale_cols(signs);
    q
}

/// Convenience: check ‖rust-rotated(base) − rot‖ / ‖rot‖ over all tensors.
pub fn rotation_mismatch(cfg: &ModelConfig, w: &Weights) -> Result<f64> {
    let base = w.with_prefix("base.");
    let rot = w.with_prefix("rot.");
    let signs = w.get("meta.q_signs")?.as_f32();
    let q = q_from_signs(cfg.d_model, &signs);
    let ours = rotate(cfg, &base, &q)?;
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (k, t) in &ours {
        let want = rot.get(k.as_str()).with_context(|| format!("rot.{k}"))?.as_f32();
        let got = t.as_f32();
        for (a, b) in got.iter().zip(&want) {
            num += ((a - b) as f64).powi(2);
            den += (*b as f64).powi(2);
        }
    }
    Ok((num / den.max(1e-12)).sqrt())
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::util::prng::Rng;

    pub(crate) fn demo_cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(), vocab: 32, d_model: 16, n_layers: 2, n_heads: 4,
            n_kv_heads: 2, d_head: 4, d_ff: 24, max_seq: 8, cache_seq: 16,
            decode_batch: 2, kv_group: 4, rope_theta: 1e4, train_ppl: 0.0,
        }
    }

    pub(crate) fn demo_weights(cfg: &ModelConfig, rng: &mut Rng)
                               -> BTreeMap<String, Tensor> {
        let (d, da, dkv, dff, l, v) =
            (cfg.d_model, cfg.d_attn(), cfg.d_kv(), cfg.d_ff, cfg.n_layers, cfg.vocab);
        let t = |shape: Vec<usize>, rng: &mut Rng| {
            let n: usize = shape.iter().product();
            Tensor::from_f32(shape, &rng.normal_vec(n))
        };
        let mut m = BTreeMap::new();
        m.insert("embed".into(), t(vec![v, d], rng));
        m.insert("final_norm".into(), t(vec![d], rng));
        m.insert("lm_head".into(), t(vec![d, v], rng));
        m.insert("attn_norm".into(), t(vec![l, d], rng));
        m.insert("wq".into(), t(vec![l, d, da], rng));
        m.insert("wk".into(), t(vec![l, d, dkv], rng));
        m.insert("wv".into(), t(vec![l, d, dkv], rng));
        m.insert("wo".into(), t(vec![l, da, d], rng));
        m.insert("ffn_norm".into(), t(vec![l, d], rng));
        m.insert("wup".into(), t(vec![l, d, dff], rng));
        m.insert("wgate".into(), t(vec![l, d, dff], rng));
        m.insert("wdown".into(), t(vec![l, dff, d], rng));
        m
    }

    #[test]
    fn rotate_shapes_and_norm_preservation() {
        let cfg = demo_cfg();
        let mut rng = Rng::new(0);
        let base = demo_weights(&cfg, &mut rng);
        let base_ref: BTreeMap<String, &Tensor> =
            base.iter().map(|(k, v)| (k.clone(), v)).collect();
        let q = q_from_signs(cfg.d_model, &Rng::new(7).signs(cfg.d_model));
        let rot = rotate(&cfg, &base_ref, &q).unwrap();
        // shapes preserved
        for (k, t) in &rot {
            assert_eq!(t.shape, base[k].shape, "{k}");
        }
        // orthogonal transforms preserve Frobenius norms of pure-rotation
        // tensors (wq gets diag(norm) fused, so compare wdown: H W Q)
        let f0 = {
            let t = &base["wdown"];
            t.as_f32().iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt()
        };
        let f1 = {
            let t = &rot["wdown"];
            t.as_f32().iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt()
        };
        assert!((f0 - f1).abs() < 1e-2 * f0, "{f0} vs {f1}");
        // norms are ones
        assert!(rot["attn_norm"].as_f32().iter().all(|&x| x == 1.0));
    }

    #[test]
    fn q_from_signs_is_orthogonal() {
        let q = q_from_signs(16, &Rng::new(3).signs(16));
        let p = q.matmul(&q.t());
        for i in 0..16 {
            for j in 0..16 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((p[(i, j)] - want).abs() < 1e-4);
            }
        }
    }
}
