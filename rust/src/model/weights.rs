//! weights.bin ("QWTS") reader/writer — named tensor archive, little-endian.
//! Mirror of python/compile/io.py::write_weights/read_weights.

use std::collections::BTreeMap;
use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I8,
    I32,
}

impl Dtype {
    fn code(self) -> u8 {
        match self {
            Dtype::F32 => 0,
            Dtype::I8 => 1,
            Dtype::I32 => 2,
        }
    }

    fn from_code(c: u8) -> Result<Dtype> {
        Ok(match c {
            0 => Dtype::F32,
            1 => Dtype::I8,
            2 => Dtype::I32,
            _ => bail!("unknown dtype code {c}"),
        })
    }

    pub fn size(self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::I8 => 1,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Tensor {
    pub dtype: Dtype,
    pub shape: Vec<usize>,
    pub raw: Vec<u8>,
}

impl Tensor {
    pub fn from_f32(shape: Vec<usize>, data: &[f32]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        let mut raw = Vec::with_capacity(data.len() * 4);
        for v in data {
            raw.extend_from_slice(&v.to_le_bytes());
        }
        Tensor { dtype: Dtype::F32, shape, raw }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Vec<f32> {
        assert_eq!(self.dtype, Dtype::F32);
        self.raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect()
    }

    pub fn as_i8(&self) -> &[u8] {
        assert_eq!(self.dtype, Dtype::I8);
        &self.raw
    }
}

#[derive(Debug, Default)]
pub struct Weights {
    pub tensors: BTreeMap<String, Tensor>,
}

impl Weights {
    pub fn load(path: &str) -> Result<Weights> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {path}"))?);
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != b"QWTS" {
            bail!("{path}: bad magic {magic:?}");
        }
        let version = read_u32(&mut f)?;
        if version != 1 {
            bail!("{path}: unsupported version {version}");
        }
        let n = read_u32(&mut f)? as usize;
        let mut tensors = BTreeMap::new();
        for _ in 0..n {
            let name_len = read_u16(&mut f)? as usize;
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            let name = String::from_utf8(name)?;
            let mut hdr = [0u8; 2];
            f.read_exact(&mut hdr)?;
            let dtype = Dtype::from_code(hdr[0])?;
            let ndim = hdr[1] as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u32(&mut f)? as usize);
            }
            let nbytes = read_u64(&mut f)? as usize;
            let expect = shape.iter().product::<usize>() * dtype.size();
            if nbytes != expect {
                bail!("{name}: payload {nbytes} != shape-implied {expect}");
            }
            let mut raw = vec![0u8; nbytes];
            f.read_exact(&mut raw)?;
            tensors.insert(name, Tensor { dtype, shape, raw });
        }
        Ok(Weights { tensors })
    }

    pub fn save(&self, path: &str) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(b"QWTS")?;
        f.write_all(&1u32.to_le_bytes())?;
        f.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, t) in &self.tensors {
            f.write_all(&(name.len() as u16).to_le_bytes())?;
            f.write_all(name.as_bytes())?;
            f.write_all(&[t.dtype.code(), t.shape.len() as u8])?;
            for &d in &t.shape {
                f.write_all(&(d as u32).to_le_bytes())?;
            }
            f.write_all(&(t.raw.len() as u64).to_le_bytes())?;
            f.write_all(&t.raw)?;
        }
        Ok(())
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name).with_context(|| format!("missing tensor {name}"))
    }

    /// All tensors under a prefix ("base."/"rot."/"rnd."), prefix stripped.
    pub fn with_prefix(&self, prefix: &str) -> BTreeMap<String, &Tensor> {
        self.tensors
            .iter()
            .filter_map(|(k, v)| k.strip_prefix(prefix).map(|s| (s.to_string(), v)))
            .collect()
    }
}

fn read_u16(f: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    f.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(f: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("quarot_wtest");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        let mut w = Weights::default();
        w.tensors.insert("base.a".into(),
                         Tensor::from_f32(vec![2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]));
        w.tensors.insert("rot.b".into(), Tensor {
            dtype: Dtype::I8,
            shape: vec![4],
            raw: vec![1, 255, 0, 7],
        });
        w.save(path.to_str().unwrap()).unwrap();
        let back = Weights::load(path.to_str().unwrap()).unwrap();
        assert_eq!(back.get("base.a").unwrap().as_f32(), vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(back.get("base.a").unwrap().shape, vec![2, 3]);
        assert_eq!(back.get("rot.b").unwrap().as_i8(), &[1, 255, 0, 7]);
        let pre = back.with_prefix("rot.");
        assert_eq!(pre.len(), 1);
        assert!(pre.contains_key("b"));
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("quarot_wtest2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(Weights::load(path.to_str().unwrap()).is_err());
    }
}
