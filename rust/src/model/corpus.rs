//! corpus.bin ("QCRP") + probes.bin ("QPRB") readers — the synthetic
//! WikiText-2 / zero-shot stand-ins (python/compile/data.py).

use std::collections::BTreeMap;
use std::io::Read;

use anyhow::{bail, Context, Result};

#[derive(Debug)]
pub struct Corpus {
    pub vocab: usize,
    pub splits: BTreeMap<String, Vec<u16>>,
}

impl Corpus {
    pub fn load(path: &str) -> Result<Corpus> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {path}"))?);
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != b"QCRP" {
            bail!("bad corpus magic");
        }
        let mut hdr = [0u8; 12];
        f.read_exact(&mut hdr)?;
        let vocab = u32::from_le_bytes(hdr[4..8].try_into().unwrap()) as usize;
        let n = u32::from_le_bytes(hdr[8..12].try_into().unwrap()) as usize;
        let mut splits = BTreeMap::new();
        for _ in 0..n {
            let mut nl = [0u8; 2];
            f.read_exact(&mut nl)?;
            let mut name = vec![0u8; u16::from_le_bytes(nl) as usize];
            f.read_exact(&mut name)?;
            let mut cnt = [0u8; 4];
            f.read_exact(&mut cnt)?;
            let cnt = u32::from_le_bytes(cnt) as usize;
            let mut raw = vec![0u8; cnt * 2];
            f.read_exact(&mut raw)?;
            let toks = raw.chunks_exact(2)
                .map(|b| u16::from_le_bytes([b[0], b[1]]))
                .collect();
            splits.insert(String::from_utf8(name)?, toks);
        }
        Ok(Corpus { vocab, splits })
    }

    pub fn split(&self, name: &str) -> Result<&[u16]> {
        self.splits.get(name).map(|v| v.as_slice())
            .with_context(|| format!("missing split {name}"))
    }
}

#[derive(Debug)]
pub struct ProbeItem {
    pub ctx: Vec<u16>,
    /// empty → exact-next-token task, answer in `gold_token`.
    pub choices: Vec<Vec<u16>>,
    pub gold: usize,
    pub gold_token: u16,
}

#[derive(Debug)]
pub struct ProbeTask {
    pub name: String,
    pub items: Vec<ProbeItem>,
}

pub fn load_probes(path: &str) -> Result<Vec<ProbeTask>> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {path}"))?);
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != b"QPRB" {
        bail!("bad probes magic");
    }
    let mut hdr = [0u8; 8];
    f.read_exact(&mut hdr)?;
    let n_tasks = u32::from_le_bytes(hdr[4..8].try_into().unwrap()) as usize;
    let mut tasks = Vec::with_capacity(n_tasks);
    for _ in 0..n_tasks {
        let mut nl = [0u8; 2];
        f.read_exact(&mut nl)?;
        let mut name = vec![0u8; u16::from_le_bytes(nl) as usize];
        f.read_exact(&mut name)?;
        let mut cb = [0u8; 4];
        f.read_exact(&mut cb)?;
        let n_items = u32::from_le_bytes(cb) as usize;
        let mut items = Vec::with_capacity(n_items);
        for _ in 0..n_items {
            let mut ih = [0u8; 3];
            f.read_exact(&mut ih)?;
            let ctx_len = u16::from_le_bytes([ih[0], ih[1]]) as usize;
            let n_choices = ih[2] as usize;
            let mut raw = vec![0u8; ctx_len * 2];
            f.read_exact(&mut raw)?;
            let ctx: Vec<u16> = raw.chunks_exact(2)
                .map(|b| u16::from_le_bytes([b[0], b[1]])).collect();
            if n_choices > 0 {
                let mut g = [0u8; 1];
                f.read_exact(&mut g)?;
                let mut choices = Vec::with_capacity(n_choices);
                for _ in 0..n_choices {
                    let mut cl = [0u8; 2];
                    f.read_exact(&mut cl)?;
                    let mut raw = vec![0u8; u16::from_le_bytes(cl) as usize * 2];
                    f.read_exact(&mut raw)?;
                    choices.push(raw.chunks_exact(2)
                        .map(|b| u16::from_le_bytes([b[0], b[1]])).collect());
                }
                items.push(ProbeItem { ctx, choices, gold: g[0] as usize, gold_token: 0 });
            } else {
                let mut gt = [0u8; 2];
                f.read_exact(&mut gt)?;
                items.push(ProbeItem {
                    ctx,
                    choices: Vec::new(),
                    gold: 0,
                    gold_token: u16::from_le_bytes(gt),
                });
            }
        }
        tasks.push(ProbeTask { name: String::from_utf8(name)?, items });
    }
    Ok(tasks)
}
