//! Pin-balance auditor for
//! [`crate::coordinator::prefix::PrefixCache`] chain pins.
//!
//! The trie's `pin_chain`/`unpin_chain` counts are *stacking*: several
//! sessions may pin a shared chain, and unpins on nodes that were never
//! pinned (or already unpinned) deliberately saturate at zero — stale
//! unpins after an eviction must stay harmless no-ops.  That tolerance
//! makes genuine imbalance invisible at runtime, so the auditor keeps
//! an independent mirror of every node's pin count plus a tally of
//! saturating unpins on *live* nodes, and tests opt into strictness via
//! [`PinAudit::assert_balanced`]:
//!
//! * mirror counts can never go negative (saturation is tallied, not
//!   wrapped);
//! * `clear()` must zero every count (forced evictions reset the
//!   mirror);
//! * LRU eviction of a still-pinned node panics immediately — the trie
//!   promises pinned chains survive eviction.
//!
//! Unpins on *evicted* nodes never reach the auditor at all: the
//! chain walk stops at the missing child, which is exactly the no-op
//! the trie documents.  Release builds compile everything to no-ops.

#[cfg(debug_assertions)]
use std::collections::HashMap;

/// Mirror of the prefix trie's per-node pin counts (keyed by node slot
/// index), independent of the trie's own bookkeeping.  Zero-sized and
/// inert in release builds.
#[derive(Default)]
pub struct PinAudit {
    #[cfg(debug_assertions)]
    counts: HashMap<usize, u32>,
    #[cfg(debug_assertions)]
    underflows: u64,
}

impl PinAudit {
    /// A fresh, balanced auditor.
    pub fn new() -> PinAudit {
        PinAudit::default()
    }

    /// A node slot was (re)created.  Slot indices are recycled after
    /// eviction, so the mirror entry starts fresh at zero.
    pub fn on_insert(&mut self, node: usize) {
        #[cfg(debug_assertions)]
        self.counts.insert(node, 0);
        #[cfg(not(debug_assertions))]
        let _ = node;
    }

    /// A pin landed on `node`; `pins` is the trie's count *after* the
    /// increment, cross-checked against the mirror.
    pub fn on_pin(&mut self, node: usize, pins: u32) {
        #[cfg(debug_assertions)]
        {
            let c = self.counts.entry(node).or_insert(0);
            *c += 1;
            assert_eq!(*c, pins,
                       "pin mirror diverged on node {node}: audit {c} vs \
                        trie {pins}");
        }
        #[cfg(not(debug_assertions))]
        let _ = (node, pins);
    }

    /// An unpin landed on a live `node`.  `saturated` means the trie
    /// found the count already at zero — tolerated at runtime, tallied
    /// for [`Self::assert_balanced`]; the mirror itself never goes
    /// below zero.
    pub fn on_unpin(&mut self, node: usize, saturated: bool) {
        #[cfg(debug_assertions)]
        {
            if saturated {
                self.underflows += 1;
            } else if let Some(c) = self.counts.get_mut(&node) {
                *c = c.saturating_sub(1);
            }
        }
        #[cfg(not(debug_assertions))]
        let _ = (node, saturated);
    }

    /// `node` left the trie.  Normal (LRU / pressure) eviction requires
    /// a pin-free node; `forced` eviction (`clear()`) zeroes the mirror
    /// no matter the count.
    pub fn on_evict(&mut self, node: usize, forced: bool) {
        #[cfg(debug_assertions)]
        {
            if let Some(c) = self.counts.remove(&node) {
                assert!(forced || c == 0,
                        "evicting node {node} with {c} live pin(s)");
            }
        }
        #[cfg(not(debug_assertions))]
        let _ = (node, forced);
    }

    /// The trie was cleared wholesale: every mirror count resets.
    pub fn on_clear(&mut self) {
        #[cfg(debug_assertions)]
        {
            self.counts.clear();
            self.underflows = 0;
        }
    }

    /// Saturating unpins observed on live nodes (0 in release builds).
    pub fn underflows(&self) -> u64 {
        #[cfg(debug_assertions)]
        {
            self.underflows
        }
        #[cfg(not(debug_assertions))]
        {
            0
        }
    }

    /// Opt-in strict check for tests: every mirror count is back at
    /// zero and no live-node unpin ever hit an already-zero count.
    /// No-op in release builds.
    pub fn assert_balanced(&self) {
        #[cfg(debug_assertions)]
        {
            let mut pinned: Vec<(usize, u32)> = self.counts.iter()
                .filter(|&(_, &c)| c > 0)
                .map(|(&n, &c)| (n, c))
                .collect();
            pinned.sort_unstable();
            assert!(pinned.is_empty() && self.underflows == 0,
                    "pin audit unbalanced: {} node(s) still pinned {:?}, \
                     {} unpin underflow(s)",
                    pinned.len(), pinned, self.underflows);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stacked_pins_balance_out() {
        let mut audit = PinAudit::new();
        audit.on_insert(0);
        audit.on_pin(0, 1);
        audit.on_pin(0, 2); // two sessions share the node
        audit.on_unpin(0, false);
        audit.on_unpin(0, false);
        audit.on_evict(0, false);
        audit.assert_balanced();
    }

    #[test]
    fn slot_reuse_resets_the_mirror() {
        let mut audit = PinAudit::new();
        audit.on_insert(3);
        audit.on_pin(3, 1);
        audit.on_unpin(3, false);
        audit.on_evict(3, false);
        // the slot index comes back for a brand-new node
        audit.on_insert(3);
        audit.on_pin(3, 1); // trie count restarts at 1: mirror must too
        audit.on_unpin(3, false);
        audit.assert_balanced();
    }

    #[test]
    fn forced_clear_zeroes_pinned_mirrors() {
        let mut audit = PinAudit::new();
        audit.on_insert(1);
        audit.on_pin(1, 1);
        audit.on_evict(1, true); // clear() path: pinned but forced
        audit.on_clear();
        audit.assert_balanced();
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "pin audit unbalanced")]
    fn saturating_unpin_fails_the_strict_check() {
        let mut audit = PinAudit::new();
        audit.on_insert(0);
        audit.on_pin(0, 1);
        audit.on_unpin(0, false);
        audit.on_unpin(0, true); // live node, count already zero
        audit.assert_balanced();
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "live pin(s)")]
    fn lru_evicting_a_pinned_node_panics() {
        let mut audit = PinAudit::new();
        audit.on_insert(2);
        audit.on_pin(2, 1);
        audit.on_evict(2, false); // unforced eviction of a pinned node
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "pin mirror diverged")]
    fn mirror_divergence_is_caught_at_the_pin() {
        let mut audit = PinAudit::new();
        audit.on_insert(0);
        audit.on_pin(0, 5); // trie claims 5, mirror says 1
    }
}
