//! Refcount ledger: *who* holds every page of a
//! [`crate::coordinator::kvcache::PagePool`].
//!
//! The pool's refcounts say how many references a page has; the ledger
//! says whose they are.  Debug builds charge every `alloc`/`retain` to
//! the ambient *owner label* — set with [`owner`] RAII scopes around
//! the admission, donation and eviction paths (`"seq:<id>"`,
//! `"prefix:node<slot>"`, `"session:<sid>"`) — one label per
//! outstanding reference, and every `release` removes one.  A leak then
//! reports the holders by name instead of a bare page count, through
//! `PagePool::assert_drained` at the end of the existing leak smokes.
//!
//! Release builds carry a zero-sized [`PageLedger`] and skip the label
//! formatting entirely (the [`owner`] closure never runs).

#[cfg(debug_assertions)]
use std::cell::RefCell;
#[cfg(debug_assertions)]
use std::collections::HashMap;

#[cfg(debug_assertions)]
thread_local! {
    static OWNER: RefCell<Vec<String>> = RefCell::new(Vec::new());
}

/// RAII owner scope: pages allocated or retained while this is live are
/// charged to its label.  Scopes nest; the innermost label wins.
pub struct OwnerScope {
    _priv: (),
}

/// Enter an owner scope.  The label closure runs only in debug builds,
/// so release callers pay neither the `format!` nor the allocation.
pub fn owner<F: FnOnce() -> String>(label: F) -> OwnerScope {
    #[cfg(debug_assertions)]
    OWNER.with(|o| o.borrow_mut().push(label()));
    #[cfg(not(debug_assertions))]
    let _ = label;
    OwnerScope { _priv: () }
}

impl Drop for OwnerScope {
    fn drop(&mut self) {
        #[cfg(debug_assertions)]
        OWNER.with(|o| {
            o.borrow_mut().pop();
        });
    }
}

#[cfg(debug_assertions)]
fn current_owner() -> String {
    OWNER.with(|o| {
        o.borrow().last().cloned().unwrap_or_else(|| "untagged".to_string())
    })
}

/// Per-pool ledger mapping page index → outstanding owner labels (one
/// per live reference).  Inert and field-free in release builds.
#[derive(Default)]
pub struct PageLedger {
    #[cfg(debug_assertions)]
    held: HashMap<usize, Vec<String>>,
}

impl PageLedger {
    /// An empty ledger (every page unreferenced).
    pub fn new() -> PageLedger {
        PageLedger::default()
    }

    /// A fresh allocation: the page's first reference, charged to the
    /// current owner scope.
    pub fn on_alloc(&mut self, page: usize) {
        #[cfg(debug_assertions)]
        self.held.entry(page).or_default().push(current_owner());
        #[cfg(not(debug_assertions))]
        let _ = page;
    }

    /// An additional reference (CoW graft, donation), charged to the
    /// current owner scope.
    pub fn on_retain(&mut self, page: usize) {
        #[cfg(debug_assertions)]
        self.held.entry(page).or_default().push(current_owner());
        #[cfg(not(debug_assertions))]
        let _ = page;
    }

    /// One reference dropped.  Prefers removing a label matching the
    /// current owner scope (so symmetric retain/release pairs cancel
    /// exactly); otherwise the oldest label goes, keeping the most
    /// recent — most diagnostic — holders on a leak report.
    pub fn on_release(&mut self, page: usize) {
        #[cfg(debug_assertions)]
        {
            if let Some(labels) = self.held.get_mut(&page) {
                let me = current_owner();
                let pos = labels.iter().rposition(|l| *l == me).unwrap_or(0);
                if !labels.is_empty() {
                    labels.remove(pos);
                }
                if labels.is_empty() {
                    self.held.remove(&page);
                }
            }
        }
        #[cfg(not(debug_assertions))]
        let _ = page;
    }

    /// Outstanding `(page, owners)` pairs, page-ordered.  Always empty
    /// in release builds.
    pub fn outstanding(&self) -> Vec<(usize, Vec<String>)> {
        #[cfg(debug_assertions)]
        {
            let mut v: Vec<(usize, Vec<String>)> =
                self.held.iter().map(|(&p, ls)| (p, ls.clone())).collect();
            v.sort_by_key(|&(p, _)| p);
            v
        }
        #[cfg(not(debug_assertions))]
        {
            Vec::new()
        }
    }

    /// Live references still on the books (0 in release builds).
    pub fn live_refs(&self) -> usize {
        #[cfg(debug_assertions)]
        {
            self.held.values().map(Vec::len).sum()
        }
        #[cfg(not(debug_assertions))]
        {
            0
        }
    }

    /// Panic with the per-owner breakdown if any reference is live.
    /// No-op in release builds (the pool's own `in_use` check still
    /// runs there — see `PagePool::assert_drained`).
    pub fn assert_drained(&self, context: &str) {
        #[cfg(debug_assertions)]
        {
            if !self.held.is_empty() {
                let mut lines = String::new();
                for (page, owners) in self.outstanding() {
                    lines.push_str(&format!(
                        "\n  page {page}: held by {owners:?}"));
                }
                panic!("page ledger leak ({context}): {} page(s) still \
                        referenced{lines}",
                       self.held.len());
            }
        }
        #[cfg(not(debug_assertions))]
        let _ = context;
    }

    /// Forget all bookkeeping (pool teardown paths).
    pub fn clear(&mut self) {
        #[cfg(debug_assertions)]
        self.held.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_traffic_drains() {
        let mut led = PageLedger::new();
        {
            let _o = owner(|| "seq:1".to_string());
            led.on_alloc(3);
            led.on_retain(3);
        }
        {
            let _o = owner(|| "seq:1".to_string());
            led.on_release(3);
            led.on_release(3);
        }
        assert_eq!(led.live_refs(), 0);
        led.assert_drained("balanced test");
    }

    #[cfg(debug_assertions)]
    #[test]
    fn release_cancels_the_matching_owner_first() {
        let mut led = PageLedger::new();
        {
            let _a = owner(|| "prefix:node0".to_string());
            led.on_alloc(9);
        }
        {
            let _b = owner(|| "seq:7".to_string());
            led.on_retain(9);
            led.on_release(9); // cancels seq:7, not prefix:node0
        }
        let out = led.outstanding();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].1, vec!["prefix:node0".to_string()]);
    }

    #[cfg(debug_assertions)]
    #[test]
    fn nested_scopes_innermost_wins_and_unwinds() {
        let _outer = owner(|| "session:4".to_string());
        let mut led = PageLedger::new();
        {
            let _inner = owner(|| "seq:2".to_string());
            led.on_alloc(0);
        }
        led.on_alloc(1);
        let out = led.outstanding();
        assert_eq!(out[0].1, vec!["seq:2".to_string()]);
        assert_eq!(out[1].1, vec!["session:4".to_string()]);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "page ledger leak")]
    fn leak_reports_the_holder_by_name() {
        let mut led = PageLedger::new();
        let _o = owner(|| "seq:42".to_string());
        led.on_alloc(5);
        led.assert_drained("deliberate leak");
    }
}
