//! Debug-gated runtime invariant auditors for the concurrent subsystems.
//!
//! Everything in this module is active only under `debug_assertions`
//! (i.e. `cargo test` and dev builds); release builds compile every
//! tracker to a no-op so the serving hot paths pay nothing.  Three
//! auditors cover the invariants that PRs 1–7 enforced by convention:
//!
//! * [`lock_order`] — a lockdep-lite: every named lock / critical
//!   section ([`AuditedMutex`], [`LockScope`]) feeds a global
//!   acquisition-order graph, and the first cycle (a schedule that
//!   *could* deadlock) panics with both witness chains — on the run
//!   that merely establishes the order, not the unlucky interleaving.
//! * [`ledger`] — a refcount ledger for `coordinator::kvcache::PagePool`:
//!   every alloc/retain is charged to the ambient [`owner`] label
//!   (seq id, prefix node, session chain), so a leaked page reports
//!   *who* held it, and `PagePool::assert_drained` turns the existing
//!   end-of-test pool checks into ledger-backed ones.
//! * [`pins`] — a mirror of `coordinator::prefix::PrefixCache` pin
//!   stacking: counts never go negative, `clear()` zeroes them, and
//!   saturating unpins on live nodes are tallied for the opt-in
//!   [`PinAudit::assert_balanced`] check.
//!
//! The companion *static* checks live in the `quarot-lint` binary
//! (`rust/src/bin/quarot-lint.rs`): wire-key append-only order against
//! `tests/golden/wire_keys.txt`, no `unwrap`/`expect` on non-test hot
//! paths, bench `--check` gates, and doc coverage of the public API.

pub mod ledger;
pub mod lock_order;
pub mod pins;

pub use ledger::{owner, OwnerScope, PageLedger};
pub use lock_order::{AuditedGuard, AuditedMutex, LockScope};
pub use pins::PinAudit;
