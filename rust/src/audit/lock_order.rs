//! Lock-order (deadlock-potential) detector — a lockdep-lite.
//!
//! Debug builds keep, per thread, the stack of audit *classes* (named
//! locks or critical sections) currently held.  The first time class
//! `B` is acquired while `A` is held, the edge `A → B` enters a global
//! order graph together with a witness (thread name + held chain).  If
//! inserting an edge would close a cycle, the process panics
//! immediately, reporting the new acquisition chain *and* the recorded
//! witness of every edge on the conflicting path — so the schedule that
//! would deadlock is caught on the first run that merely establishes
//! both orders, not the unlucky run that interleaves them.
//!
//! Classes are interned by name: all locks sharing a name are one
//! class, and same-class edges are ignored, so re-entry across distinct
//! objects of one class (e.g. two `PagePool`s) is not flagged.
//!
//! Release builds compile all tracking to no-ops; [`AuditedMutex`]
//! degenerates to a plain poison-policy wrapper over
//! [`std::sync::Mutex`] and [`LockScope`] to a zero-work marker.

use std::ops::{Deref, DerefMut};
use std::sync::{Condvar, Mutex, MutexGuard};

#[cfg(debug_assertions)]
use std::cell::RefCell;
#[cfg(debug_assertions)]
use std::collections::HashMap;
#[cfg(debug_assertions)]
use std::sync::OnceLock;

/// Interned id of one lock class (see the module docs for class
/// semantics).  Opaque; obtained by [`LockScope::enter`] and
/// [`AuditedMutex`] internally.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct ClassId(u32);

#[cfg(debug_assertions)]
#[derive(Default)]
struct Registry {
    ids: HashMap<&'static str, u32>,
    names: Vec<&'static str>,
    /// `(from, to)` → witness chain that first recorded the edge.
    edges: HashMap<(u32, u32), String>,
    /// Adjacency of `edges` for the cycle check.
    out: HashMap<u32, Vec<u32>>,
}

#[cfg(debug_assertions)]
impl Registry {
    fn name(&self, id: u32) -> &'static str {
        self.names.get(id as usize).copied().unwrap_or("?")
    }

    /// Some path `src → … → dst` through recorded edges, if any.
    fn path(&self, src: u32, dst: u32) -> Option<Vec<u32>> {
        let mut parent: HashMap<u32, u32> = HashMap::new();
        let mut stack = vec![src];
        while let Some(n) = stack.pop() {
            if n == dst {
                let mut p = vec![dst];
                let mut cur = dst;
                while cur != src {
                    match parent.get(&cur) {
                        Some(&prev) => {
                            p.push(prev);
                            cur = prev;
                        }
                        None => break,
                    }
                }
                p.reverse();
                return Some(p);
            }
            for &next in self.out.get(&n).map(Vec::as_slice).unwrap_or(&[]) {
                if next != src && !parent.contains_key(&next) {
                    parent.insert(next, n);
                    stack.push(next);
                }
            }
        }
        None
    }
}

#[cfg(debug_assertions)]
fn registry() -> &'static Mutex<Registry> {
    static REG: OnceLock<Mutex<Registry>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Registry::default()))
}

#[cfg(debug_assertions)]
thread_local! {
    static HELD: RefCell<Vec<ClassId>> = RefCell::new(Vec::new());
}

#[cfg(debug_assertions)]
fn intern(name: &'static str) -> ClassId {
    let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    if let Some(&id) = reg.ids.get(name) {
        return ClassId(id);
    }
    let id = reg.names.len() as u32;
    reg.names.push(name);
    reg.ids.insert(name, id);
    ClassId(id)
}

#[cfg(not(debug_assertions))]
#[inline(always)]
fn intern(_name: &'static str) -> ClassId {
    ClassId(0)
}

/// Record edges `held[i] → class` and cycle-check each new one.  The
/// panic message (if any) is built under the registry lock but raised
/// after releasing it, so the registry stays usable for other threads'
/// reports.
#[cfg(debug_assertions)]
fn record_edges(held: &[ClassId], class: ClassId) {
    let witness = {
        let reg = registry().lock().unwrap_or_else(|e| e.into_inner());
        let chain: Vec<&str> =
            held.iter().map(|&ClassId(h)| reg.name(h)).collect();
        format!("thread '{}' held [{}] while acquiring '{}'",
                std::thread::current().name().unwrap_or("<unnamed>"),
                chain.join(" -> "), reg.name(class.0))
    };
    let mut failure: Option<String> = None;
    {
        let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
        let mut seen_from: Vec<u32> = Vec::new();
        for &ClassId(from) in held {
            if from == class.0 || seen_from.contains(&from) {
                continue;
            }
            seen_from.push(from);
            if reg.edges.contains_key(&(from, class.0)) {
                continue;
            }
            // inserting from → class closes a cycle iff class already
            // reaches from
            if let Some(p) = reg.path(class.0, from) {
                let mut msg = format!(
                    "lock-order cycle: acquiring '{}' while holding '{}' \
                     adds '{}' -> '{}', but the reverse order is already \
                     recorded:\n  new: {}",
                    reg.name(class.0), reg.name(from), reg.name(from),
                    reg.name(class.0), witness);
                for w in p.windows(2) {
                    let recorded = reg.edges.get(&(w[0], w[1]))
                        .map(String::as_str)
                        .unwrap_or("<missing witness>");
                    msg.push_str(&format!("\n  recorded '{}' -> '{}': {}",
                                          reg.name(w[0]), reg.name(w[1]),
                                          recorded));
                }
                failure = Some(msg);
                break;
            }
            reg.edges.insert((from, class.0), witness.clone());
            reg.out.entry(from).or_default().push(class.0);
        }
    }
    if let Some(msg) = failure {
        panic!("{msg}");
    }
}

#[cfg(debug_assertions)]
fn on_acquire(class: ClassId) {
    let held: Vec<ClassId> = HELD.with(|h| h.borrow().clone());
    if !held.is_empty() {
        record_edges(&held, class);
    }
    HELD.with(|h| h.borrow_mut().push(class));
}

#[cfg(not(debug_assertions))]
#[inline(always)]
fn on_acquire(_class: ClassId) {}

#[cfg(debug_assertions)]
fn on_release(class: ClassId) {
    HELD.with(|h| {
        let mut v = h.borrow_mut();
        if let Some(pos) = v.iter().rposition(|&c| c == class) {
            v.remove(pos);
        }
    });
}

#[cfg(not(debug_assertions))]
#[inline(always)]
fn on_release(_class: ClassId) {}

/// Classes currently held by this thread (debug builds; empty in
/// release).  For tests and diagnostics.
pub fn held_depth() -> usize {
    #[cfg(debug_assertions)]
    {
        HELD.with(|h| h.borrow().len())
    }
    #[cfg(not(debug_assertions))]
    {
        0
    }
}

/// A [`Mutex`] wrapped with lock-order auditing (debug builds) and an
/// explicit poison policy per call site.
pub struct AuditedMutex<T> {
    name: &'static str,
    inner: Mutex<T>,
}

impl<T> AuditedMutex<T> {
    /// `name` is the lock's audit class (shared by all locks with the
    /// same name — see the module docs).
    pub const fn new(name: &'static str, value: T) -> AuditedMutex<T> {
        AuditedMutex { name, inner: Mutex::new(value) }
    }

    /// The lock's audit-class name.
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Lock, panicking if a previous holder panicked mid-update
    /// (poison): for state that cannot be trusted after a partial
    /// mutation.
    pub fn lock(&self) -> AuditedGuard<'_, T> {
        let class = intern(self.name);
        on_acquire(class);
        match self.inner.lock() {
            Ok(guard) => AuditedGuard { guard: Some(guard), class },
            Err(_) => {
                on_release(class);
                panic!("lock '{}' poisoned by a panicking holder", self.name);
            }
        }
    }

    /// Lock, clearing poison: for state that stays consistent across a
    /// holder's panic (flags, fully-reassigned values, monotone sets) —
    /// a panicking peer must not take the whole subsystem down with it.
    pub fn lock_recover(&self) -> AuditedGuard<'_, T> {
        let class = intern(self.name);
        on_acquire(class);
        let guard = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        AuditedGuard { guard: Some(guard), class }
    }

    /// [`Condvar`] wait through the audit layer: the class is released
    /// while parked (the lock is genuinely not held) and re-acquired on
    /// wake, so blocked waiters never look like lock holders in the
    /// order graph.  Poison on the wakeup re-acquire is cleared,
    /// matching [`Self::lock_recover`].
    pub fn wait_on<'a>(&'a self, mut held: AuditedGuard<'a, T>, cv: &Condvar)
                       -> AuditedGuard<'a, T> {
        let class = held.class;
        let Some(inner) = held.guard.take() else {
            unreachable!("audited guard lost its inner guard before drop")
        };
        on_release(class);
        let inner = match cv.wait(inner) {
            Ok(g) => g,
            Err(e) => e.into_inner(),
        };
        on_acquire(class);
        AuditedGuard { guard: Some(inner), class }
    }
}

/// Guard returned by [`AuditedMutex`]; releases the audit class on
/// drop.
pub struct AuditedGuard<'a, T> {
    guard: Option<MutexGuard<'a, T>>,
    class: ClassId,
}

impl<T> Deref for AuditedGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        match &self.guard {
            Some(g) => g,
            None => unreachable!("audited guard accessed after wait_on"),
        }
    }
}

impl<T> DerefMut for AuditedGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        match &mut self.guard {
            Some(g) => g,
            None => unreachable!("audited guard accessed after wait_on"),
        }
    }
}

impl<T> Drop for AuditedGuard<'_, T> {
    fn drop(&mut self) {
        // wait_on takes the inner guard out before handing it to the
        // condvar; only a guard that still owns the lock releases the
        // audit class
        if self.guard.take().is_some() {
            on_release(self.class);
        }
    }
}

/// RAII audit marker for a critical section that is not a literal
/// mutex — an engine tick, a subsystem entry point — so its ordering
/// against real locks still lands in the order graph.  Re-entering the
/// same class nests without recording a self-edge.
#[must_use = "the scope audits only while it is held"]
pub struct LockScope {
    class: ClassId,
}

impl LockScope {
    /// Enter the named critical section until the scope drops.
    pub fn enter(name: &'static str) -> LockScope {
        let class = intern(name);
        on_acquire(class);
        LockScope { class }
    }
}

impl Drop for LockScope {
    fn drop(&mut self) {
        on_release(self.class);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    // NOTE: the order graph is process-global and `cargo test` runs
    // tests concurrently, so every test here uses class names unique to
    // itself ("test.<case>.<lock>") — consistent with each other and
    // disjoint from the production classes.

    #[test]
    fn consistent_order_is_silent_and_stack_balances() {
        let a = AuditedMutex::new("test.consistent.a", 1u32);
        let b = AuditedMutex::new("test.consistent.b", 2u32);
        for _ in 0..3 {
            let ga = a.lock();
            let gb = b.lock();
            assert_eq!(*ga + *gb, 3);
            drop(gb);
            drop(ga);
        }
        assert_eq!(held_depth(), 0, "release must pop the held stack");
    }

    #[test]
    fn scopes_and_mutexes_share_one_graph() {
        let m = AuditedMutex::new("test.scope.m", ());
        let s = LockScope::enter("test.scope.outer");
        let g = m.lock();
        assert!(held_depth() >= 2);
        drop(g);
        drop(s);
        assert_eq!(held_depth(), 0);
    }

    #[test]
    fn same_class_reentry_is_not_a_cycle() {
        // two distinct pools of one class, nested: lockdep-style class
        // semantics say this is one class and self-edges are ignored
        let p1 = AuditedMutex::new("test.reentry.pool", 0u8);
        let p2 = AuditedMutex::new("test.reentry.pool", 0u8);
        let g1 = p1.lock();
        let g2 = p2.lock();
        drop(g2);
        drop(g1);
        assert_eq!(held_depth(), 0);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "lock-order cycle")]
    fn reversed_order_panics_with_both_witnesses() {
        let a = AuditedMutex::new("test.cycle.a", ());
        let b = AuditedMutex::new("test.cycle.b", ());
        {
            let _ga = a.lock();
            let _gb = b.lock(); // records a -> b
        }
        let _gb = b.lock();
        let _ga = a.lock(); // b -> a closes the cycle: must panic
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "lock-order cycle")]
    fn transitive_cycle_is_detected() {
        let a = AuditedMutex::new("test.chain.a", ());
        let b = AuditedMutex::new("test.chain.b", ());
        let c = AuditedMutex::new("test.chain.c", ());
        {
            let _ga = a.lock();
            let _gb = b.lock(); // a -> b
        }
        {
            let _gb = b.lock();
            let _gc = c.lock(); // b -> c
        }
        let _gc = c.lock();
        let _ga = a.lock(); // c -> a closes a 3-cycle through b
    }

    #[test]
    fn wait_on_releases_the_class_while_parked() {
        let m = Arc::new(AuditedMutex::new("test.wait.m", false));
        let cv = Arc::new(Condvar::new());
        let (m2, cv2) = (m.clone(), cv.clone());
        let waiter = std::thread::spawn(move || {
            let mut g = m2.lock();
            while !*g {
                g = m2.wait_on(g, &cv2);
            }
            assert_eq!(held_depth(), 1, "woken waiter holds the class");
            drop(g);
            assert_eq!(held_depth(), 0);
        });
        loop {
            let mut g = m.lock();
            *g = true;
            cv.notify_all();
            drop(g);
            if waiter.is_finished() {
                break;
            }
            std::thread::yield_now();
        }
        if let Err(e) = waiter.join() {
            std::panic::resume_unwind(e);
        }
    }

    #[test]
    fn lock_recover_clears_poison() {
        let m = Arc::new(AuditedMutex::new("test.poison.m", 7u32));
        let m2 = m.clone();
        let t = std::thread::spawn(move || {
            let _g = m2.lock();
            panic!("poison the lock on purpose");
        });
        assert!(t.join().is_err());
        assert_eq!(*m.lock_recover(), 7, "recover must see the value");
    }
}
