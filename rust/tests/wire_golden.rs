//! Golden-file regression test for the append-only wire key contract.
//!
//! `tests/golden/wire_keys.txt` records, per frame, the SOURCE order of
//! the key/value pairs each frame is built from.  That order is the v2
//! compatibility contract: keys may be appended, never renamed, removed
//! or reordered.  This test checks the running code against the golden:
//!
//! - pair-list order for `summary_pairs()` / `full_pairs()` (the pair
//!   Vec preserves source order, so order is directly observable);
//! - key *sets* for the serialized `stats` / `metrics` / `per_shard` /
//!   `finished` frames (util::json stores objects in a BTreeMap, so the
//!   serialized byte order is alphabetical and only membership is
//!   observable after encoding).
//!
//! The source-level ORDER of the obj()-built frames is enforced by
//! `cargo run --bin quarot-lint`, which parses the pair lists in
//! rust/src/cluster/metrics.rs and rust/src/api/wire.rs and compares
//! them against the same golden file.

use quarot::api::wire;
use quarot::api::{FinishReason, GenerationEvent, RequestStats};
use quarot::cluster::{ClusterMetrics, ShardMetrics};
use quarot::util::json::Value;

const GOLDEN: &str = include_str!("../../tests/golden/wire_keys.txt");

/// One golden key: name plus whether a trailing `?` marked it optional.
struct Key {
    name: String,
    optional: bool,
}

fn golden_section(section: &str) -> Vec<Key> {
    let mut keys = Vec::new();
    let mut in_section = false;
    for raw in GOLDEN.lines() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            in_section = name.strip_suffix(']') == Some(section);
            continue;
        }
        if in_section {
            let (name, optional) = match line.strip_suffix('?') {
                Some(base) => (base, true),
                None => (line, false),
            };
            keys.push(Key { name: name.to_string(), optional });
        }
    }
    assert!(!keys.is_empty(), "golden section [{section}] missing or empty");
    keys
}

fn obj_keys(v: &Value) -> Vec<String> {
    v.as_obj()
        .unwrap_or_else(|| panic!("expected an object frame, got {v:?}"))
        .keys()
        .cloned()
        .collect()
}

fn assert_key_set(frame: &Value, golden: &[Key], skip_optional: bool,
                  what: &str) {
    let mut want: Vec<&str> = golden.iter()
        .filter(|k| !(skip_optional && k.optional))
        .map(|k| k.name.as_str())
        .collect();
    want.sort_unstable();
    let got = obj_keys(frame);
    let got: Vec<&str> = got.iter().map(String::as_str).collect();
    // BTreeMap keys come out sorted, so sorted-golden vs keys() is an
    // exact set comparison that also reports order of the diff stably.
    assert_eq!(got, want, "{what}: serialized key set drifted");
}

/// A metrics value with every source populated, so no key is skipped
/// by an is-empty fast path anywhere.
fn sample_metrics() -> ClusterMetrics {
    let mut shard = ShardMetrics {
        shard: 0,
        alive: true,
        queue_depth: 2,
        active_slots: 1,
        queue_bound: 64,
        completed: 5,
        cancelled: 1,
        failed: 1,
        deadline_exceeded: 1,
        decode_steps: 40,
        decode_tokens: 80,
        tokens_per_sec: 123.4,
        ttft_sum_ms: 50.0,
        ttft_count: 5,
        peak_cache_bytes: 4096,
        sessions_live: 1,
        session_turns: 3,
        session_prefill_tokens_saved: 17,
        executor: "pjrt".to_string(),
        prefill_chunks: 4,
        prefill_chunk_tokens: 96,
        ..ShardMetrics::default()
    };
    // populate every latency histogram so the percentile keys are
    // computed from real samples, not the empty-histogram zero path
    for ms in [2.0, 5.0, 40.0] {
        shard.ttft_hist.record(ms);
        shard.itl_hist.record(ms / 4.0);
        shard.queue_wait_hist.record(ms / 2.0);
        shard.tick_hist.record(ms / 8.0);
    }
    ClusterMetrics { queue_bound: 64, shards: vec![shard] }
}

#[test]
fn stats_pair_order_matches_golden() {
    let golden = golden_section("stats");
    assert_eq!(golden[0].name, "v");
    assert_eq!(golden[1].name, "event");
    let want: Vec<&str> = golden[2..].iter().map(|k| k.name.as_str()).collect();

    let m = sample_metrics();
    let got: Vec<&str> = m.summary_pairs().iter().map(|(k, _)| *k).collect();
    assert_eq!(got, want,
               "summary_pairs() order drifted from [stats] golden \
                (keys are append-only)");

    // full_pairs (the `metrics` frame) = stats pairs + per_shard tail.
    let full: Vec<&str> = m.full_pairs().iter().map(|(k, _)| *k).collect();
    assert_eq!(&full[..want.len()], &want[..]);
    assert_eq!(&full[want.len()..], &["per_shard"][..]);
}

#[test]
fn stats_and_metrics_frames_match_golden_key_sets() {
    let m = sample_metrics();
    let stats = golden_section("stats");
    assert_key_set(&wire::encode_stats(m.summary_pairs()), &stats, false,
                   "stats frame");

    let mut with_per_shard: Vec<Key> = golden_section("stats");
    with_per_shard.push(Key { name: "per_shard".to_string(), optional: false });
    let metrics = wire::encode_metrics(m.full_pairs());
    assert_key_set(&metrics, &with_per_shard, false, "metrics frame");

    let per_shard = golden_section("per_shard");
    match metrics.get("per_shard") {
        Some(Value::Arr(rows)) if !rows.is_empty() => {
            for row in rows {
                assert_key_set(row, &per_shard, false, "per_shard row");
            }
        }
        other => panic!("metrics frame lost per_shard rows: {other:?}"),
    }
}

#[test]
fn finished_frame_matches_golden_key_set() {
    let golden = golden_section("finished");
    let stats = RequestStats {
        prompt_len: 7,
        generated: 3,
        ttft_ms: 1.0,
        decode_ms: 2.0,
        queued_ms: 0.5,
        session: None,
    };

    // one-shot: every required key, no optional ones
    let ev = GenerationEvent::Finished {
        reason: FinishReason::Stop,
        stats: stats.clone(),
    };
    assert_key_set(&wire::encode_event(9, &ev, None), &golden, true,
                   "finished frame (one-shot)");

    // chat turn: the optional `session` key rides along
    let ev = GenerationEvent::Finished {
        reason: FinishReason::Stop,
        stats: RequestStats { session: Some(12), ..stats },
    };
    assert_key_set(&wire::encode_event(9, &ev, None), &golden, false,
                   "finished frame (chat)");
}
