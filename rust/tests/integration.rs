//! Integration tests over the full stack: PJRT runtime + artifacts +
//! quantization toolchain + coordinator + server.
//!
//! These need `make artifacts` to have run; when artifacts are absent each
//! test skips (prints a notice) so plain `cargo test` stays green in a
//! fresh checkout.

use quarot::bench_support::Artifacts;
use quarot::coordinator::batcher::{GenerationEngine, Request};
use quarot::coordinator::runner::{QuantSpec, Variant, WeightQuant};
use quarot::coordinator::sampler::Sampling;
use quarot::eval;
use quarot::model::transform;
use quarot::quant::gptq::GptqCfg;

fn art() -> Option<Artifacts> {
    match Artifacts::load("tiny-mha") {
        Ok(a) => Some(a),
        Err(_) => {
            eprintln!("[skip] artifacts missing — run `make artifacts`");
            None
        }
    }
}

#[test]
fn manifest_and_weights_consistent() {
    let Some(art) = art() else { return };
    let engine = art.engine_graphs(&[]).unwrap();
    let m = &engine.manifest;
    assert_eq!(m.model.name, "tiny-mha");
    assert_eq!(m.weight_order.len(), 12);
    // every weight tensor exists under all three prefixes
    for prefix in ["base.", "rot.", "rnd."] {
        for name in &m.weight_order {
            assert!(art.weights.get(&format!("{prefix}{name}")).is_ok(),
                    "missing {prefix}{name}");
        }
    }
    assert!(art.weights.get("meta.q_signs").is_ok());
}

#[test]
fn rust_transform_matches_python() {
    let Some(art) = art() else { return };
    let engine = art.engine_graphs(&[]).unwrap();
    let mismatch =
        transform::rotation_mismatch(&engine.manifest.model, &art.weights).unwrap();
    assert!(mismatch < 1e-3, "rotation mismatch {mismatch}");
}

#[test]
fn computational_invariance_through_compiled_graphs() {
    // the heart of the paper: rotated graph + rotated weights ==
    // baseline graph + base weights, in full precision
    let Some(art) = art() else { return };
    let toks = art.corpus.split("eval").unwrap()[..64].to_vec();
    let base = art.runner_prefill_only(QuantSpec::fp16_baseline(), None).unwrap();
    let l0 = base.prefill(&toks).unwrap().logits;
    drop(base);
    let rot_spec = QuantSpec {
        variant: Variant::Quarot, act_bits: 0, kv_bits: 16, kv_bits_v: 16,
        weights: WeightQuant::None, ..QuantSpec::quarot(4)
    };
    let rot = art.runner_prefill_only(rot_spec, None).unwrap();
    let l1 = rot.prefill(&toks).unwrap().logits;
    let scale = l0.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let max_err = l0.iter().zip(&l1)
        .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
    assert!(max_err < 5e-3 * scale, "invariance violated: {max_err} vs {scale}");
}

#[test]
fn quantization_ordering_int8_beats_int4() {
    let Some(art) = art() else { return };
    let eval_toks = art.corpus.split("eval").unwrap();
    let windows = 3;
    let p_fp = {
        let r = art.runner_prefill_only(QuantSpec::fp16_baseline(), None).unwrap();
        eval::perplexity(&r, eval_toks, windows).unwrap()
    };
    let p8 = {
        let r = art.runner_prefill_only(QuantSpec::quarot(8), None).unwrap();
        eval::perplexity(&r, eval_toks, windows).unwrap()
    };
    let p4 = {
        let r = art.runner_prefill_only(QuantSpec::quarot(4), None).unwrap();
        eval::perplexity(&r, eval_toks, windows).unwrap()
    };
    assert!(p_fp <= p8 * 1.02, "fp {p_fp} vs int8 {p8}");
    assert!(p8 < p4, "int8 {p8} !< int4 {p4}");
    assert!(p4 < p_fp * 3.0, "int4 catastrophically bad: {p4} vs {p_fp}");
}

#[test]
fn quarot_beats_naive_rtn_at_4bit() {
    let Some(art) = art() else { return };
    let eval_toks = art.corpus.split("eval").unwrap();
    let windows = 3;
    let naive = QuantSpec {
        variant: Variant::Baseline,
        ..QuantSpec::quarot(4)
    };
    let p_naive = {
        let r = art.runner_prefill_only(naive, None).unwrap();
        eval::perplexity(&r, eval_toks, windows).unwrap()
    };
    let p_quarot = {
        let r = art.runner_prefill_only(QuantSpec::quarot(4), None).unwrap();
        eval::perplexity(&r, eval_toks, windows).unwrap()
    };
    assert!(p_quarot < p_naive,
            "QuaRot {p_quarot} must beat unrotated RTN {p_naive}");
}

#[test]
fn gptq_no_worse_than_rtn() {
    let Some(art) = art() else { return };
    let eval_toks = art.corpus.split("eval").unwrap();
    let windows = 3;
    let calib = art.calib(true, 6).unwrap();
    let p_rtn = {
        let r = art.runner_prefill_only(QuantSpec::quarot(4), None).unwrap();
        eval::perplexity(&r, eval_toks, windows).unwrap()
    };
    let p_gptq = {
        let spec = QuantSpec {
            weights: WeightQuant::Gptq(GptqCfg::new(4), calib),
            ..QuantSpec::quarot(4)
        };
        let r = art.runner_prefill_only(spec, None).unwrap();
        eval::perplexity(&r, eval_toks, windows).unwrap()
    };
    // GPTQ optimizes a layer-wise proxy loss; at this calibration budget it
    // must land in RTN's neighbourhood (the paper's margins need the full
    // 128×2048 calibration set) — the hard ordering is tested at the proxy
    // level in quant::gptq::tests::beats_rtn_on_proxy_loss.
    assert!(p_gptq <= p_rtn * 1.15, "gptq {p_gptq} vs rtn {p_rtn}");
}

#[test]
fn generation_decode_consistency() {
    // decode path must continue what prefill started: generating N tokens
    // step-by-step equals prefilling prompt+k and decoding from there
    let Some(art) = art() else { return };
    let prompt = art.corpus.split("eval").unwrap()[100..110].to_vec();
    let runner = art.runner(QuantSpec::quarot(8), None).unwrap();
    let mut engine = GenerationEngine::new(runner, 512, 1);
    engine.submit(Request {
        id: 0, prompt: prompt.clone(), max_new_tokens: 6,
        sampling: Sampling::Greedy, stop_token: None,
    });
    let c1 = engine.run_to_completion().unwrap();
    assert_eq!(c1.len(), 1);
    assert_eq!(c1[0].tokens.len(), 6);
    assert_eq!(engine.pool_in_use(), 0, "pages leaked after completion");

    // deterministic: same request twice → same tokens
    engine.submit(Request {
        id: 0, prompt, max_new_tokens: 6,
        sampling: Sampling::Greedy, stop_token: None,
    });
    let c2 = engine.run_to_completion().unwrap();
    assert_eq!(c1[0].tokens, c2[0].tokens);
}

#[test]
fn batched_serving_matches_sequential() {
    // continuous batching must not change greedy outputs vs one-at-a-time
    let Some(art) = art() else { return };
    let eval_toks = art.corpus.split("eval").unwrap();
    let prompts: Vec<Vec<u16>> = (0..3)
        .map(|i| eval_toks[i * 37..i * 37 + 8].to_vec())
        .collect();
    let run = |batched: bool| -> Vec<Vec<u16>> {
        let runner = art.runner(QuantSpec::quarot(8), None).unwrap();
        let mut engine = GenerationEngine::new(runner, 1024, 1);
        let mut out = vec![Vec::new(); prompts.len()];
        if batched {
            let ids: Vec<u64> = prompts.iter().map(|p| {
                engine.submit(Request {
                    id: 0, prompt: p.clone(), max_new_tokens: 5,
                    sampling: Sampling::Greedy, stop_token: None,
                })
            }).collect();
            for c in engine.run_to_completion().unwrap() {
                let idx = ids.iter().position(|&i| i == c.id).unwrap();
                out[idx] = c.tokens;
            }
        } else {
            for (i, p) in prompts.iter().enumerate() {
                engine.submit(Request {
                    id: 0, prompt: p.clone(), max_new_tokens: 5,
                    sampling: Sampling::Greedy, stop_token: None,
                });
                out[i] = engine.run_to_completion().unwrap()[0].tokens.clone();
            }
        }
        out
    };
    let seq = run(false);
    let bat = run(true);
    assert_eq!(seq, bat, "batched decode diverged from sequential");
}

#[test]
fn server_roundtrip() {
    if art().is_none() {
        return;
    }
    let handle = quarot::server::serve(
        move || {
            let art = Artifacts::load("tiny-mha")?;
            let runner = art.runner(QuantSpec::quarot(4), None)?;
            Ok(GenerationEngine::new(runner, 512, 3))
        },
        0,
    ).unwrap();
    let mut client = quarot::server::Client::connect(handle.port).unwrap();
    let resp = client.generate(&[5, 6, 7, 8], 4).unwrap();
    assert!(resp.get("error").is_none(), "{resp:?}");
    let toks = resp.get("tokens").unwrap().as_arr().unwrap();
    assert_eq!(toks.len(), 4);
    let stats = client.stats().unwrap();
    assert!(stats.get("completed").unwrap().as_f64().unwrap() >= 1.0);
    handle.shutdown();
}

#[test]
fn zeroshot_probes_above_chance_fp16() {
    let Some(art) = art() else { return };
    let runner = art.runner_prefill_only(QuantSpec::fp16_baseline(), None).unwrap();
    let (scores, avg) = eval::score_all(&runner, &art.probes, 12).unwrap();
    assert_eq!(scores.len(), 6);
    // trained model must beat chance on average (2-4 way MC → chance ≈ 0.33)
    assert!(avg > 0.30, "avg probe accuracy {avg}");
}
