//! Integration tests over the full stack: PJRT runtime + artifacts +
//! quantization toolchain + coordinator + server.
//!
//! These need `make artifacts` to have run; when artifacts are absent each
//! test skips (prints a notice) so plain `cargo test` stays green in a
//! fresh checkout.

use quarot::api::{FinishReason, GenerationParams, LocalSession, SessionConfig};
use quarot::bench_support::Artifacts;
use quarot::coordinator::batcher::GenerationEngine;
use quarot::coordinator::runner::{QuantSpec, Variant, WeightQuant};
use quarot::coordinator::selfspec::{self, SelfSpecDecoder};
use quarot::eval;
use quarot::model::transform;
use quarot::quant::gptq::GptqCfg;

fn art() -> Option<Artifacts> {
    match Artifacts::load("tiny-mha") {
        Ok(a) => Some(a),
        Err(_) => {
            eprintln!("[skip] artifacts missing — run `make artifacts`");
            None
        }
    }
}

#[test]
fn manifest_and_weights_consistent() {
    let Some(art) = art() else { return };
    let engine = art.engine_graphs(&[]).unwrap();
    let m = &engine.manifest;
    assert_eq!(m.model.name, "tiny-mha");
    assert_eq!(m.weight_order.len(), 12);
    // every weight tensor exists under all three prefixes
    for prefix in ["base.", "rot.", "rnd."] {
        for name in &m.weight_order {
            assert!(art.weights.get(&format!("{prefix}{name}")).is_ok(),
                    "missing {prefix}{name}");
        }
    }
    assert!(art.weights.get("meta.q_signs").is_ok());
}

#[test]
fn rust_transform_matches_python() {
    let Some(art) = art() else { return };
    let engine = art.engine_graphs(&[]).unwrap();
    let mismatch =
        transform::rotation_mismatch(&engine.manifest.model, &art.weights).unwrap();
    assert!(mismatch < 1e-3, "rotation mismatch {mismatch}");
}

#[test]
fn computational_invariance_through_compiled_graphs() {
    // the heart of the paper: rotated graph + rotated weights ==
    // baseline graph + base weights, in full precision
    let Some(art) = art() else { return };
    let toks = art.corpus.split("eval").unwrap()[..64].to_vec();
    let base = art.runner_prefill_only(QuantSpec::fp16_baseline(), None).unwrap();
    let l0 = base.prefill(&toks).unwrap().logits;
    drop(base);
    let rot_spec = QuantSpec {
        variant: Variant::Quarot, act_bits: 0, kv_bits: 16, kv_bits_v: 16,
        weights: WeightQuant::None, ..QuantSpec::quarot(4)
    };
    let rot = art.runner_prefill_only(rot_spec, None).unwrap();
    let l1 = rot.prefill(&toks).unwrap().logits;
    let scale = l0.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let max_err = l0.iter().zip(&l1)
        .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
    assert!(max_err < 5e-3 * scale, "invariance violated: {max_err} vs {scale}");
}

/// Argmax per logits row — the token a greedy sampler would pick.
fn argmaxes(logits: &[f32], vocab: usize) -> Vec<usize> {
    logits.chunks(vocab)
        .map(|row| {
            row.iter().enumerate()
                .fold((0usize, f32::NEG_INFINITY),
                      |best, (i, &v)| if v > best.1 { (i, v) } else { best })
                .0
        })
        .collect()
}

/// Tentpole parity gate: the native (graph-free) executor must agree
/// with the PJRT graph path on the same artifact weights.  Bitwise
/// equality is off the table — XLA fuses and reorders fp32 summations
/// differently than the in-process backend — so the contract is
/// numeric: per-position logits within a small relative tolerance and
/// greedy-argmax agreement on (almost) every position, under both the
/// fp16 baseline spec and the full QuaRot A4KV4 spec.
#[test]
fn native_executor_matches_pjrt_logits() {
    let Some(art) = art() else { return };
    let toks = art.corpus.split("eval").unwrap()[..64].to_vec();
    for (label, spec, tol) in [
        ("fp16-baseline", QuantSpec::fp16_baseline(), 5e-3f32),
        ("quarot-a4kv4", QuantSpec::quarot(4), 2e-2f32),
    ] {
        let pjrt = art.runner_prefill_only(spec.clone(), None).unwrap();
        let vocab = pjrt.cfg.vocab;
        let l_pjrt = pjrt.prefill(&toks).unwrap().logits;
        drop(pjrt);
        let native = art.runner_native(spec, None).unwrap();
        assert_eq!(native.executor_name(), "native");
        let l_native = native.prefill(&toks).unwrap().logits;
        assert_eq!(l_pjrt.len(), l_native.len(), "{label}: logits shape");
        let scale = l_pjrt.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let max_err = l_pjrt.iter().zip(&l_native)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
        assert!(max_err < tol * scale,
                "{label}: native drifted from PJRT: {max_err} vs scale {scale}");
        let (a, b) = (argmaxes(&l_pjrt, vocab), argmaxes(&l_native, vocab));
        let mismatches = a.iter().zip(&b).filter(|(x, y)| x != y).count();
        // near-ties may flip under a different summation order; more
        // than a few positions flipping means a real numeric bug
        assert!(mismatches * 20 <= a.len(),
                "{label}: greedy argmax diverged on {mismatches}/{} positions",
                a.len());
    }
}

#[test]
fn quantization_ordering_int8_beats_int4() {
    let Some(art) = art() else { return };
    let eval_toks = art.corpus.split("eval").unwrap();
    let windows = 3;
    let p_fp = {
        let r = art.runner_prefill_only(QuantSpec::fp16_baseline(), None).unwrap();
        eval::perplexity(&r, eval_toks, windows).unwrap()
    };
    let p8 = {
        let r = art.runner_prefill_only(QuantSpec::quarot(8), None).unwrap();
        eval::perplexity(&r, eval_toks, windows).unwrap()
    };
    let p4 = {
        let r = art.runner_prefill_only(QuantSpec::quarot(4), None).unwrap();
        eval::perplexity(&r, eval_toks, windows).unwrap()
    };
    assert!(p_fp <= p8 * 1.02, "fp {p_fp} vs int8 {p8}");
    assert!(p8 < p4, "int8 {p8} !< int4 {p4}");
    assert!(p4 < p_fp * 3.0, "int4 catastrophically bad: {p4} vs {p_fp}");
}

#[test]
fn quarot_beats_naive_rtn_at_4bit() {
    let Some(art) = art() else { return };
    let eval_toks = art.corpus.split("eval").unwrap();
    let windows = 3;
    let naive = QuantSpec {
        variant: Variant::Baseline,
        ..QuantSpec::quarot(4)
    };
    let p_naive = {
        let r = art.runner_prefill_only(naive, None).unwrap();
        eval::perplexity(&r, eval_toks, windows).unwrap()
    };
    let p_quarot = {
        let r = art.runner_prefill_only(QuantSpec::quarot(4), None).unwrap();
        eval::perplexity(&r, eval_toks, windows).unwrap()
    };
    assert!(p_quarot < p_naive,
            "QuaRot {p_quarot} must beat unrotated RTN {p_naive}");
}

#[test]
fn gptq_no_worse_than_rtn() {
    let Some(art) = art() else { return };
    let eval_toks = art.corpus.split("eval").unwrap();
    let windows = 3;
    let calib = art.calib(true, 6).unwrap();
    let p_rtn = {
        let r = art.runner_prefill_only(QuantSpec::quarot(4), None).unwrap();
        eval::perplexity(&r, eval_toks, windows).unwrap()
    };
    let p_gptq = {
        let spec = QuantSpec {
            weights: WeightQuant::Gptq(GptqCfg::new(4), calib),
            ..QuantSpec::quarot(4)
        };
        let r = art.runner_prefill_only(spec, None).unwrap();
        eval::perplexity(&r, eval_toks, windows).unwrap()
    };
    // GPTQ optimizes a layer-wise proxy loss; at this calibration budget it
    // must land in RTN's neighbourhood (the paper's margins need the full
    // 128×2048 calibration set) — the hard ordering is tested at the proxy
    // level in quant::gptq::tests::beats_rtn_on_proxy_loss.
    assert!(p_gptq <= p_rtn * 1.15, "gptq {p_gptq} vs rtn {p_rtn}");
}

#[test]
fn generation_decode_consistency() {
    // decode path must continue what prefill started: generating N tokens
    // step-by-step equals prefilling prompt+k and decoding from there
    let Some(art) = art() else { return };
    let prompt = art.corpus.split("eval").unwrap()[100..110].to_vec();
    let runner = art.runner(QuantSpec::quarot(8), None).unwrap();
    let session = LocalSession::new(GenerationEngine::new(runner, 512, 1),
                                    SessionConfig::default());
    let h1 = session.submit(GenerationParams::new(prompt.clone()).max_new(6))
        .unwrap();
    let o1 = h1.wait().unwrap();
    assert_eq!(o1.tokens.len(), 6);
    assert_eq!(o1.reason, FinishReason::MaxTokens);
    assert_eq!(session.pool_in_use(), 0, "pages leaked after completion");

    // deterministic: same request twice → same tokens
    let h2 = session.submit(GenerationParams::new(prompt).max_new(6)).unwrap();
    let o2 = h2.wait().unwrap();
    assert_eq!(o1.tokens, o2.tokens);
}

#[test]
fn batched_serving_matches_sequential() {
    // continuous batching must not change greedy outputs vs one-at-a-time
    let Some(art) = art() else { return };
    let eval_toks = art.corpus.split("eval").unwrap();
    let prompts: Vec<Vec<u16>> = (0..3)
        .map(|i| eval_toks[i * 37..i * 37 + 8].to_vec())
        .collect();
    let run = |batched: bool| -> Vec<Vec<u16>> {
        let runner = art.runner(QuantSpec::quarot(8), None).unwrap();
        let session = LocalSession::new(GenerationEngine::new(runner, 1024, 1),
                                        SessionConfig::default());
        let mut out = vec![Vec::new(); prompts.len()];
        if batched {
            let handles: Vec<_> = prompts.iter().map(|p| {
                session.submit(GenerationParams::new(p.clone()).max_new(5))
                    .unwrap()
            }).collect();
            for (i, h) in handles.iter().enumerate() {
                out[i] = h.wait().unwrap().tokens;
            }
        } else {
            for (i, p) in prompts.iter().enumerate() {
                let h = session
                    .submit(GenerationParams::new(p.clone()).max_new(5))
                    .unwrap();
                out[i] = h.wait().unwrap().tokens;
            }
        }
        out
    };
    let seq = run(false);
    let bat = run(true);
    assert_eq!(seq, bat, "batched decode diverged from sequential");
}

#[test]
fn server_roundtrip() {
    if art().is_none() {
        return;
    }
    let handle = quarot::server::serve(
        move || {
            let art = Artifacts::load("tiny-mha")?;
            let runner = art.runner(QuantSpec::quarot(4), None)?;
            Ok(GenerationEngine::new(runner, 512, 3))
        },
        0,
        quarot::server::DEFAULT_QUEUE_BOUND,
    ).unwrap();
    // event-frame path
    let client = quarot::server::Client::connect(handle.port).unwrap();
    let h = client.submit(&GenerationParams::new(vec![5, 6, 7, 8]).max_new(4))
        .unwrap();
    let out = h.wait().unwrap();
    assert_eq!(out.tokens.len(), 4);
    assert_eq!(out.reason, FinishReason::MaxTokens);
    // one-shot convenience wrapper on a fresh connection (raw v1 wire
    // compatibility is covered in rust/tests/api_stream.rs)
    let mut legacy = quarot::server::Client::connect(handle.port).unwrap();
    let resp = legacy.generate(&[5, 6, 7, 8], 4).unwrap();
    assert!(resp.get("error").is_none(), "{resp:?}");
    let toks = resp.get("tokens").unwrap().as_arr().unwrap();
    assert_eq!(toks.len(), 4);
    let stats = legacy.stats().unwrap();
    assert!(stats.get("completed").unwrap().as_f64().unwrap() >= 2.0);
    assert_eq!(stats.get("pool_pages_in_use").unwrap().as_f64().unwrap(), 0.0);
    handle.shutdown();
}

#[test]
fn eval_edge_cases_are_errors_not_panics() {
    use quarot::model::corpus::{ProbeItem, ProbeTask};
    let Some(art) = art() else { return };
    let r = art.runner_prefill_only(QuantSpec::fp16_baseline(), None).unwrap();

    // regression: empty/short streams used to underflow `tokens.len() - 1`
    // or trip a bare `assert!(n > 0)` — they must be typed errors now
    assert!(eval::perplexity(&r, &[], 3).is_err());
    let short = vec![1u16; r.cfg.max_seq]; // no next-token target
    assert!(eval::perplexity(&r, &short, 3).is_err());
    let ok_len = vec![1u16; r.cfg.max_seq + 1];
    assert!(eval::perplexity(&r, &ok_len, 0).is_err()); // zero window budget

    // regression: zero-item tasks divided 0/0 into NaN accuracy
    let empty = ProbeTask { name: "empty".into(), items: vec![] };
    let s = eval::score_task(&r, &empty, 10).unwrap();
    assert_eq!(s.accuracy, 0.0);
    let s = eval::score_task(&r, &art.probes[0], 0).unwrap();
    assert!(s.accuracy == 0.0 && s.items == 0, "max_items=0 gave {s:?}");
    let (scores, avg) = eval::score_all(&r, &[], 5).unwrap();
    assert!(scores.is_empty() && avg == 0.0, "empty task list avg {avg}");

    // regression: an empty context wrapped `ctx.len() + i - 1` — scoring
    // must start from the first predictable position; an empty-ctx item
    // with a single-token choice is unscoreable (counted incorrect, never
    // a free win for the one-token distractor)
    let task = ProbeTask {
        name: "empty-ctx".into(),
        items: vec![ProbeItem {
            ctx: vec![],
            choices: vec![vec![1, 2], vec![3]],
            gold: 0,
            gold_token: 0,
        }],
    };
    let s = eval::score_task(&r, &task, 10).unwrap();
    assert!(s.items == 1 && s.accuracy == 0.0, "{s:?}");

    // multi-token choices under an empty context are still rankable
    let task = ProbeTask {
        name: "empty-ctx-multi".into(),
        items: vec![ProbeItem {
            ctx: vec![],
            choices: vec![vec![1, 2], vec![3, 4]],
            gold: 0,
            gold_token: 0,
        }],
    };
    let s = eval::score_task(&r, &task, 10).unwrap();
    assert!(s.items == 1 && !s.accuracy.is_nan());
}

#[test]
fn zeroshot_probes_above_chance_fp16() {
    let Some(art) = art() else { return };
    let runner = art.runner_prefill_only(QuantSpec::fp16_baseline(), None).unwrap();
    let (scores, avg) = eval::score_all(&runner, &art.probes, 12).unwrap();
    assert_eq!(scores.len(), 6);
    // trained model must beat chance on average (2-4 way MC → chance ≈ 0.33)
    assert!(avg > 0.30, "avg probe accuracy {avg}");
}

#[test]
fn self_spec_decode_is_bit_exact_vs_pure_verifier() {
    // self-speculation must be an optimization, never an approximation:
    // for every draft length the accepted stream equals plain iterated
    // greedy prefill at the verifier's own spec, token for token (the
    // KV4 draft cache only proposes; the causal verify prefill decides)
    let Some(art) = art() else { return };
    let prompt = art.corpus.split("eval").unwrap()[40..52].to_vec();
    let runner = art.runner(QuantSpec::quarot(8), None).unwrap();
    let max_new = 10;
    let reference =
        selfspec::prefill_greedy(&runner, &prompt, max_new).unwrap();
    assert_eq!(reference.len(), max_new);
    for draft_k in [1usize, 3, 4, 7] {
        let dec = SelfSpecDecoder::new(&runner, draft_k).unwrap();
        let out = dec.generate(&prompt, max_new).unwrap();
        assert_eq!(out.tokens, reference,
                   "draft_k={draft_k} diverged from the pure verifier");
        assert!(out.stats.accepted <= out.stats.drafted,
                "accepted {} > drafted {}",
                out.stats.accepted, out.stats.drafted);
        assert!(out.stats.verify_prefills >= 1);
    }
}
