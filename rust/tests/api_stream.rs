//! Tests for the unified streaming inference API: event ordering,
//! cancellation returning pages to the pool, bounded-admission
//! rejection, byte-identical output between the event path and the
//! legacy `run_to_completion` shim, and the v2 TCP event-frame protocol
//! (interleaving, cancel, raw v1 compatibility).
//!
//! Like `integration.rs`, every test needs `make artifacts` and skips
//! with a notice when they are absent.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use quarot::api::{FinishReason, GenerationEvent, GenerationParams,
                  LocalSession, SessionConfig, SubmitError};
use quarot::bench_support::Artifacts;
use quarot::coordinator::batcher::{GenerationEngine, Request};
use quarot::coordinator::runner::QuantSpec;
use quarot::coordinator::sampler::Sampling;
use quarot::server::{serve, Client};
use quarot::util::json;

fn art() -> Option<Artifacts> {
    match Artifacts::load("tiny-mha") {
        Ok(a) => Some(a),
        Err(_) => {
            eprintln!("[skip] artifacts missing — run `make artifacts`");
            None
        }
    }
}

fn session(art: &Artifacts, pages: usize, seed: u64, queue_bound: usize)
           -> LocalSession {
    let runner = art.runner(QuantSpec::quarot(4), None).unwrap();
    LocalSession::new(GenerationEngine::new(runner, pages, seed),
                      SessionConfig { queue_bound })
}

#[test]
fn event_stream_is_ordered_with_one_terminal() {
    let Some(art) = art() else { return };
    let prompt = art.corpus.split("eval").unwrap()[..8].to_vec();
    let s = session(&art, 512, 7, 16);
    let h = s.submit(GenerationParams::new(prompt).max_new(6)).unwrap();

    let mut events = Vec::new();
    while let Some(ev) = h.next_event().unwrap() {
        events.push(ev);
    }
    // exact shape: Queued, Started, Token ×6 (contiguous indices), Finished
    assert!(matches!(events[0], GenerationEvent::Queued), "{events:?}");
    assert!(matches!(events[1], GenerationEvent::Started { .. }), "{events:?}");
    let tokens: Vec<(u16, usize)> = events.iter().filter_map(|e| match e {
        GenerationEvent::Token { token, index } => Some((*token, *index)),
        _ => None,
    }).collect();
    assert_eq!(tokens.len(), 6);
    for (i, &(_, idx)) in tokens.iter().enumerate() {
        assert_eq!(idx, i, "token indices must be contiguous from 0");
    }
    let terminals: Vec<&GenerationEvent> =
        events.iter().filter(|e| e.is_terminal()).collect();
    assert_eq!(terminals.len(), 1, "exactly one terminal event");
    match terminals[0] {
        GenerationEvent::Finished { reason, stats } => {
            assert_eq!(*reason, FinishReason::MaxTokens);
            assert_eq!(stats.generated, 6);
            assert_eq!(stats.prompt_len, 8);
        }
        other => panic!("wrong terminal {other:?}"),
    }
    assert!(events.last().unwrap().is_terminal(),
            "terminal must come last: {events:?}");
    // a drained handle stays drained
    assert!(h.next_event().unwrap().is_none());
}

#[test]
fn cancellation_frees_pool_pages() {
    let Some(art) = art() else { return };
    let prompt = art.corpus.split("eval").unwrap()[..8].to_vec();
    let s = session(&art, 512, 7, 16);
    assert_eq!(s.pool_in_use(), 0);

    let h = s.submit(GenerationParams::new(prompt).max_new(64)).unwrap();
    // stream a few tokens so the request is mid-flight with pages held
    let mut seen_tokens = 0;
    while seen_tokens < 3 {
        match h.next_event().unwrap().expect("stream ended early") {
            GenerationEvent::Token { .. } => seen_tokens += 1,
            e => assert!(!e.is_terminal(), "finished before cancel: {e:?}"),
        }
    }
    assert!(s.pool_in_use() > 0, "mid-flight request must hold pages");
    assert!(h.cancel().unwrap());
    assert_eq!(s.pool_in_use(), 0,
               "cancel must return every page to the pool");

    // the stream still terminates in exactly one Finished{Cancelled}
    let mut terminals = 0;
    while let Some(ev) = h.next_event().unwrap() {
        if let GenerationEvent::Finished { reason, .. } = &ev {
            assert_eq!(*reason, FinishReason::Cancelled);
            terminals += 1;
        } else {
            assert!(!ev.is_terminal());
        }
    }
    assert_eq!(terminals, 1);
    // cancelling again is a no-op
    assert!(!h.cancel().unwrap());
}

#[test]
fn queue_full_rejection_at_the_bound() {
    let Some(art) = art() else { return };
    let prompt = art.corpus.split("eval").unwrap()[..4].to_vec();
    let s = session(&art, 512, 7, 2);

    let h1 = s.submit(GenerationParams::new(prompt.clone()).max_new(3)).unwrap();
    let h2 = s.submit(GenerationParams::new(prompt.clone()).max_new(3)).unwrap();
    // third submit exceeds the bound of 2 waiting requests
    match s.submit(GenerationParams::new(prompt.clone()).max_new(3)) {
        Err(SubmitError::QueueFull { bound }) => assert_eq!(bound, 2),
        Err(e) => panic!("expected QueueFull, got {e:?}"),
        Ok(_) => panic!("expected QueueFull, got an accepted request"),
    }
    // draining the queue frees admission capacity again
    h1.wait().unwrap();
    h2.wait().unwrap();
    let h3 = s.submit(GenerationParams::new(prompt).max_new(3)).unwrap();
    assert_eq!(h3.wait().unwrap().tokens.len(), 3);
}

#[test]
fn invalid_params_are_typed_rejections() {
    let Some(art) = art() else { return };
    let s = session(&art, 512, 7, 16);
    assert!(matches!(s.submit(GenerationParams::new(vec![])),
                     Err(SubmitError::InvalidParams(_))));
    assert!(matches!(s.submit(GenerationParams::new(vec![1]).max_new(0)),
                     Err(SubmitError::InvalidParams(_))));
    let too_long = vec![1u16; 100_000];
    assert!(matches!(s.submit(GenerationParams::new(too_long)),
                     Err(SubmitError::InvalidParams(_))));
}

#[test]
fn event_path_matches_legacy_shim_byte_identical() {
    let Some(art) = art() else { return };
    let prompt = art.corpus.split("eval").unwrap()[20..30].to_vec();
    let sampling = Sampling::TopK { temperature: 0.8, k: 8 };

    // legacy path: run_to_completion shim at a fixed seed
    let runner = art.runner(QuantSpec::quarot(4), None).unwrap();
    let mut engine = GenerationEngine::new(runner, 512, 11);
    engine.submit(Request {
        id: 0, prompt: prompt.clone(), max_new_tokens: 8,
        sampling, stop_token: None,
    });
    let legacy = engine.run_to_completion().unwrap();
    assert_eq!(legacy.len(), 1);
    assert_eq!(legacy[0].tokens.len(), 8);

    // event path: same seed, same request, fresh engine
    let runner = art.runner(QuantSpec::quarot(4), None).unwrap();
    let s = LocalSession::new(GenerationEngine::new(runner, 512, 11),
                              SessionConfig::default());
    let h = s.submit(GenerationParams::new(prompt).max_new(8)
                         .sampling(sampling)).unwrap();
    let streamed = h.wait().unwrap();

    assert_eq!(legacy[0].tokens, streamed.tokens,
               "event path must be byte-identical to the shim");
}

#[test]
fn stop_token_on_first_prefill_token_retires_immediately() {
    let Some(art) = art() else { return };
    let prompt = art.corpus.split("eval").unwrap()[..8].to_vec();
    // learn what the first greedy token is
    let s = session(&art, 512, 7, 16);
    let probe = s.submit(GenerationParams::new(prompt.clone()).max_new(2))
        .unwrap().wait().unwrap();
    let first = probe.tokens[0];

    // resubmit with that token as the stop token: the request must
    // finish at admission with reason Stop, never occupying a slot
    let s = session(&art, 512, 7, 16);
    let h = s.submit(GenerationParams::new(prompt).max_new(32).stop_at(first))
        .unwrap();
    let out = h.wait().unwrap();
    assert_eq!(out.tokens, vec![first]);
    assert_eq!(out.reason, FinishReason::Stop);
    assert_eq!(s.pool_in_use(), 0, "admission-time stop must free pages");
    let stats = s.stats();
    assert_eq!(stats.decode_steps, 0,
               "a first-token stop must not run decode ticks");
}

#[test]
fn tcp_interleaved_requests_and_cancel() {
    if art().is_none() {
        return;
    }
    let handle = serve(
        move || {
            let art = Artifacts::load("tiny-mha")?;
            let runner = art.runner(QuantSpec::quarot(4), None)?;
            Ok(GenerationEngine::new(runner, 512, 3))
        },
        0,
        16,
    ).unwrap();

    let client = Client::connect(handle.port).unwrap();
    let ha = client.submit(&GenerationParams::new(vec![5, 6, 7, 8]).max_new(12))
        .unwrap();
    // B gets a budget ~200 ticks long and is cancelled at its first token
    // frame, so the cancel cannot lose the race to natural completion
    let hb = client.submit(&GenerationParams::new(vec![9, 10, 11, 12]).max_new(200))
        .unwrap();
    assert_ne!(ha.id(), hb.id());

    // pull B's frames; cancel it as soon as it streams
    let mut b_tokens = 0;
    let mut b_reason = None;
    let mut b_terminals = 0;
    while let Some(ev) = hb.next_event().unwrap() {
        match ev {
            GenerationEvent::Token { .. } => {
                b_tokens += 1;
                if b_tokens == 1 {
                    hb.cancel().unwrap();
                }
            }
            GenerationEvent::Finished { reason, .. } => {
                b_terminals += 1;
                b_reason = Some(reason);
            }
            GenerationEvent::Failed { .. } => b_terminals += 1,
            _ => {}
        }
    }
    assert_eq!(b_terminals, 1, "exactly one terminal event for B");
    assert_eq!(b_reason, Some(FinishReason::Cancelled));
    assert!(b_tokens < 200, "cancel must land mid-generation");

    // A is untouched: full budget, single natural terminal
    let out_a = ha.wait().unwrap();
    assert_eq!(out_a.tokens.len(), 12);
    assert_eq!(out_a.reason, FinishReason::MaxTokens);

    // cancelled pages are back in the pool (server-side accounting)
    let mut c2 = Client::connect(handle.port).unwrap();
    let stats = c2.stats().unwrap();
    assert_eq!(stats.get("pool_pages_in_use").unwrap().as_f64().unwrap(), 0.0);
    assert!(stats.get("cancelled").unwrap().as_f64().unwrap() >= 1.0);
    handle.shutdown();
}

#[test]
fn raw_v1_one_shot_line_still_answered() {
    if art().is_none() {
        return;
    }
    let handle = serve(
        move || {
            let art = Artifacts::load("tiny-mha")?;
            let runner = art.runner(QuantSpec::quarot(4), None)?;
            Ok(GenerationEngine::new(runner, 512, 3))
        },
        0,
        16,
    ).unwrap();

    // speak v1 by hand: one bare JSON line in, one completion object out
    let stream = TcpStream::connect(("127.0.0.1", handle.port)).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    writeln!(w, r#"{{"prompt":[5,6,7,8],"max_new_tokens":4}}"#).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let resp = json::parse(line.trim()).unwrap();
    assert!(resp.get("error").is_none(), "{resp:?}");
    assert_eq!(resp.get("tokens").unwrap().as_arr().unwrap().len(), 4);
    assert!(resp.get("tokens_per_sec").is_some());
    handle.shutdown();
}

#[test]
fn wire_shutdown_cmd_stops_the_whole_server() {
    if art().is_none() {
        return;
    }
    let handle = serve(
        move || {
            let art = Artifacts::load("tiny-mha")?;
            let runner = art.runner(QuantSpec::quarot(4), None)?;
            Ok(GenerationEngine::new(runner, 512, 3))
        },
        0,
        16,
    ).unwrap();
    let port = handle.port;
    let mut c = Client::connect(port).unwrap();
    c.shutdown_server().unwrap();
    // both loops must exit: join returns (would hang forever before the
    // fix, when shutdown only closed the issuing connection)
    handle.shutdown();
    // and new connections are no longer served
    std::thread::sleep(std::time::Duration::from_millis(50));
    let refused = match TcpStream::connect(("127.0.0.1", port)) {
        Err(_) => true,
        Ok(s) => {
            // listener may linger in TIME_WAIT; a served connection would
            // answer a stats line, a dead one hangs up
            let mut r = BufReader::new(s.try_clone().unwrap());
            let mut w = s;
            let _ = writeln!(w, r#"{{"v":2,"cmd":"stats"}}"#);
            let mut line = String::new();
            matches!(r.read_line(&mut line), Ok(0) | Err(_))
        }
    };
    assert!(refused, "server still answering after wire shutdown");
}